"""Dynamic load balancing on the paper's matrix sequences — perf trajectory.

Runs distributed SP2 purification on the three structure families from
``benchmarks/spamm_sequences.py`` (banded, exp-decay, random-offdiag) on an
8-worker CPU mesh, from a deliberately skewed initial layout (every block on
worker 0 — the scatter a naive driver produces), comparing:

* ``static``      — the layout is never revisited (rebalance=None);
* ``rebalanced``  — ``RebalancePolicy()``: the measured per-worker cost model
                    (:mod:`repro.dist.balance`) re-lays the iterate out on
                    device whenever the combined max/mean imbalance crosses
                    the threshold.

Reported per (structure, mode): measured imbalance trajectory (max / mean /
tail), wall seconds per iteration, bytes migrated by re-layouts, and plan
cache misses.  Results are written machine-readable to
``BENCH_balance.json`` at the repo root so future PRs can track the
trajectory.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       PYTHONPATH=src python benchmarks/dist_balance.py [--smoke]
"""

from __future__ import annotations

import json
import os
import sys
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402

import spamm_sequences  # noqa: E402  (banded / exp_decay / random_offdiag)
from repro.core import BSMatrix  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import (  # noqa: E402
    PlanCache,
    RebalancePolicy,
    dist_sp2_purify,
    scatter,
)

P = 8
BS = spamm_sequences.BS  # 16
IDEM_TOL, TRUNC_TAU, SPAMM_TAU = 1e-5, 1e-5, 1e-6
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_balance.json")


def sequences(n: int) -> dict[str, BSMatrix]:
    """The three paper-style structures, SP2-ready (symmetric + spread)."""
    raw = {
        "banded": spamm_sequences.banded(n, 24),
        "exp-decay": spamm_sequences.exp_decay(n, rate=0.08),
        "random-offdiag": spamm_sequences.random_offdiag(n, density=0.08),
    }
    out = {}
    for name, a in raw.items():
        d = np.asarray(a.to_dense(), dtype=np.float64)
        h = 0.2 * (d + d.T) / (2 * max(np.abs(d).max(), 1e-12))
        h += np.diag(np.linspace(-1.0, 1.0, n))
        out[name] = BSMatrix.from_dense(h.astype(np.float32), BS)
    return out


def eig_bounds(f: BSMatrix) -> tuple[float, float]:
    w = np.linalg.eigvalsh(np.asarray(f.to_dense(), np.float64))
    return float(w.min()) - 0.05, float(w.max()) + 0.05


def run_mode(f, nocc, lmin, lmax, mesh, policy, max_iter):
    skew = np.zeros(f.nnzb, dtype=np.int32)  # skewed initial layout
    df = scatter(f, mesh, owner=skew)
    cache = PlanCache()
    t0 = time.perf_counter()
    d, st = dist_sp2_purify(
        df, nocc, lmin, lmax, max_iter=max_iter, idem_tol=IDEM_TOL,
        trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU, cache=cache,
        rebalance=policy,
    )
    total = time.perf_counter() - t0
    imbs = [pi["imbalance"] for pi in st.per_iter if pi["imbalance"] is not None]
    misses = [pi["cache_misses"] for pi in st.per_iter]
    return d, dict(
        iterations=st.iterations,
        rebalances=st.rebalances,
        wall_s_total=total,
        wall_s_per_iter=total / max(st.iterations, 1),
        imbalance_max=float(max(imbs)) if imbs else None,
        imbalance_mean=float(np.mean(imbs)) if imbs else None,
        imbalance_tail=float(np.mean(imbs[-3:])) if imbs else None,
        imbalance_per_iter=[float(i) for i in imbs],
        migrated_bytes_total=int(sum(pi["migrated_bytes"] for pi in st.per_iter)),
        plan_misses_total=int(sum(misses)),
        plan_misses_tail=[int(m) for m in misses[-3:]],
        cache=dict(hits=st.cache["hits"], misses=st.cache["misses"]),
        calibration=st.calibration,
    )


def main() -> None:
    smoke = "--smoke" in sys.argv
    n = 256 if smoke else 512
    max_iter = 25 if smoke else 40
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)

    results: dict = {}
    for name, f in sequences(n).items():
        nocc = int(0.3 * n)
        lmin, lmax = eig_bounds(f)
        print(f"\n== {name}: n={n} bs={BS} nnzb={f.nnzb} workers={P} "
              f"(skewed initial layout: all blocks on worker 0) ==")
        row: dict = {}
        d_ref = None
        for mode, policy in (("static", None), ("rebalanced", RebalancePolicy())):
            d, r = run_mode(f, nocc, lmin, lmax, mesh, policy, max_iter)
            if d_ref is None:
                d_ref = d
            else:
                bitwise = bool(np.array_equal(
                    np.asarray(d_ref.to_dense()), np.asarray(d.to_dense())))
                r["bit_identical_to_static"] = bitwise
                assert bitwise, "re-layouts changed the math"
            row[mode] = r
            print(f"  [{mode:10s}] iters={r['iterations']:3d}  "
                  f"wall/iter {r['wall_s_per_iter']*1e3:7.1f} ms  "
                  f"imb max {r['imbalance_max']:.2f} mean {r['imbalance_mean']:.3f} "
                  f"tail {r['imbalance_tail']:.3f}  "
                  f"migrated {r['migrated_bytes_total']/1e3:.1f} kB  "
                  f"misses {r['plan_misses_total']} (tail {r['plan_misses_tail']})")
        ratio = row["static"]["imbalance_max"] / row["rebalanced"]["imbalance_max"]
        row["peak_imbalance_reduction"] = float(ratio)
        print(f"  peak imbalance reduction: {ratio:.2f}x")
        cal = row["rebalanced"].get("calibration")
        if cal and cal.get("fitted"):
            print(f"  wall-clock calibration: task {cal['task_s']*1e6:.1f} us  "
                  f"recv {cal['recv_cost']:.3f} send {cal['send_cost']:.3f} "
                  f"block {cal['block_cost']:.3f} "
                  f"(rms resid {cal['rms_resid_s']*1e3:.2f} ms, "
                  f"{cal['samples']} samples)")
        results[name] = row

    payload = dict(
        meta=dict(
            n=n, bs=BS, workers=P, smoke=smoke, max_iter=max_iter,
            idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU,
            initial_layout="all blocks on worker 0",
            policy=dict(RebalancePolicy().__dict__),
        ),
        structures=results,
    )
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
