"""Resident vs host inverse-factorization benchmark (repro.dist.inverse).

Measures what the device-resident refinement loop buys on the localized
inverse factorization workload (the paper's multiplication-heavy §2.2
scenario), mirroring benchmarks/dist_purify.py:

* refinement iterations + residual trajectory,
* per-iteration plan-cache misses and planning/symbolic seconds — with
  delta-plan SpAMM + hierarchical truncation a stabilized pattern incurs
  zero misses, and an SCF-style repeated solve replays every iteration
  (including the first) from the cache (asserted),
* bytes moved per iteration: the planned p2p exchange of the executed
  multiply plan and the shared [nnzb] norm-table fetch,
* host (core/inverse with SymbolicCache) vs resident wall-clock.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/dist_inverse.py
"""

from __future__ import annotations

import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix, SymbolicCache, localized_inverse_factorization  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import PlanCache, dist_localized_inverse_factorization, scatter  # noqa: E402

P = 8
N, BS = 256, 16
TOL, TRUNC_TAU, SPAMM_TAU = 1e-6, 1e-6, 1e-7


def overlap(n: int, bs: int) -> BSMatrix:
    rng = np.random.default_rng(11)
    b = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - 4), min(n, i + 5)
        b[i, lo:hi] = rng.standard_normal(hi - lo)
    return BSMatrix.from_dense(b @ b.T + n * np.eye(n, dtype=np.float32), bs)


def host_run(s: BSMatrix):
    cache = SymbolicCache()
    t0 = time.perf_counter()
    z, stats = localized_inverse_factorization(
        s, tol=TOL, trunc_tau=TRUNC_TAU, impl="ref", cache=cache
    )
    return z, stats, time.perf_counter() - t0


def resident_run(s: BSMatrix, mesh, cache: PlanCache):
    ds = scatter(s, mesh)
    t0 = time.perf_counter()
    z, stats = dist_localized_inverse_factorization(
        ds, cache, tol=TOL, trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU
    )
    return z, stats, time.perf_counter() - t0


def report(stats, total):
    per = stats.per_iter
    misses = [pi["cache_misses"] for pi in per]
    all_hit = sum(1 for m in misses if m == 0)
    print(f"  iterations          {stats.iterations}  "
          f"residual {stats.factorization_residual:.2e}")
    print(f"  wall/iter           {total/max(stats.iterations,1)*1e3:9.1f} ms")
    print(f"  plan misses/iter    {misses}")
    print(f"  all-hit iterations  {all_hit}/{len(per)}")
    sym = [pi["symbolic_s"] * 1e3 for pi in per]
    build = [pi["plan_build_s"] * 1e3 for pi in per]
    print(f"  symbolic ms/iter    mean {np.mean(sym):7.2f}  tail {np.mean(sym[-3:]):7.2f}")
    print(f"  plan+jit ms/iter    mean {np.mean(build):7.2f}  tail {np.mean(build[-3:]):7.2f}")
    print(f"  recv MB/worker tail {per[-1]['recv_bytes_mean']/1e6:.3f}")
    print(f"  norm fetch/iter     {per[-1]['norm_fetch_bytes']/1e3:.2f} kB "
          f"([nnzb] stack-order vector, fused psum)")
    hit_iters = [pi["wall_s"] for pi in per if pi["cache_misses"] == 0]
    if hit_iters:
        print(f"  wall/iter (all-hit) {np.mean(hit_iters)*1e3:9.1f} ms "
              f"({len(hit_iters)} iterations, zero planning/compile)")


def main():
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)
    s = overlap(N, BS)
    print(f"S: n={N} bs={BS} nnzb={s.nnzb}  workers={P}")

    z_h, st_h, total_h = host_run(s)
    print("\n-- host: core localized_inverse_factorization + SymbolicCache --")
    print(f"  iterations          {st_h.iterations}  "
          f"residual {st_h.factorization_residual:.2e}")
    print(f"  wall/iter           {total_h/max(st_h.iterations,1)*1e3:9.1f} ms")
    print(f"  symbolic misses/it  {st_h.cache_misses_history}")

    cache = PlanCache()
    z_r, st_r, total_r = resident_run(s, mesh, cache)
    print("\n-- resident: dist_localized_inverse_factorization "
          "(delta-SpAMM + hierarchical truncation) --")
    report(st_r, total_r)

    # SCF-style repeated solve: every structure is cached, every iteration
    # (including the first) replays with zero plan-cache misses
    z_r2, st_r2, total_r2 = resident_run(s, mesh, cache)
    print("\n-- resident, second solve (SCF replay) --")
    report(st_r2, total_r2)
    misses2 = [pi["cache_misses"] for pi in st_r2.per_iter]
    assert all(m == 0 for m in misses2), misses2
    print("\nzero plan-cache misses across the repeated solve: OK")

    err = np.abs(z_r.gather().to_dense() - z_h.to_dense()).max()
    print(f"max |Z_resident - Z_host| = {err:.2e}")
    speedup = (total_h / max(st_h.iterations, 1)) / (
        total_r2 / max(st_r2.iterations, 1)
    )
    print(f"warm resident vs host wall/iter: {speedup:.2f}x")


if __name__ == "__main__":
    main()
