"""Resident vs re-shard-per-call distributed purification benchmark.

Measures what the device-resident runtime (repro.dist) buys over calling the
one-shot ``make_spgemm_plan`` + ``dist_spgemm`` path every iteration, on the
SP2 purification workload (the paper's multiplication-heavy scenario):

* per-iteration wall time (resident path amortizes planning, compilation and
  plan-array shipping through the structure-keyed PlanCache),
* host->device bytes moved per iteration (resident: operand stores stay on
  the mesh; baseline: both operand stores + plan index arrays re-ship every
  multiply),
* plan-cache hit/miss counts per iteration.

A second section compares error-control modes on the SpAMM-enabled loop:
leaf truncation + replan SpAMM (a wiggling prune pattern re-plans and
re-jits) against hierarchical truncation + delta-plan SpAMM (the prune
pattern is a task mask over the cached full plan — zero misses once the
sparsity pattern stabilizes), reporting per-iteration plan-cache misses,
planning/compile time, and host symbolic time.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/dist_purify.py
"""

from __future__ import annotations

import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix, add, add_scaled_identity, truncate  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    dist_spgemm,
    make_worker_mesh,
    shard_stores,
    unshard_result,
)
from repro.core.purify import Sp2Monitor, sp2_init_coeffs, sp2_should_square  # noqa: E402
from repro.core.schedule import make_spgemm_plan  # noqa: E402
from repro.dist import PlanCache, dist_sp2_purify, scatter  # noqa: E402

P = 8
N, BS, NOCC = 512, 32, 160
IDEM_TOL, TRUNC_TAU = 1e-5, 1e-5


def hamiltonian(n: int, bs: int) -> BSMatrix:
    rng = np.random.default_rng(7)
    h = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - 6), min(n, i + 7)
        h[i, lo:hi] = 0.2 * rng.standard_normal(hi - lo)
    h = (h + h.T) / 2 + np.diag(np.linspace(-2.0, 2.0, n))
    return BSMatrix.from_dense(h, bs)


def eig_bounds(f: BSMatrix) -> tuple[float, float]:
    w = np.linalg.eigvalsh(np.asarray(f.to_dense(), np.float64))
    return float(w.min()) - 0.05, float(w.max()) + 0.05


def baseline_reshard_purify(f, n_occ, lmin, lmax, mesh, max_iter=60):
    """SP2 where every multiply re-plans, re-shards from host, and re-jits —
    what the library did before repro.dist.  Returns (iters, times, h2d)."""
    scale, shift = sp2_init_coeffs(lmin, lmax)
    x = add_scaled_identity(f.scale(scale), shift)
    monitor = Sp2Monitor(IDEM_TOL)
    times, h2d_bytes = [], []
    for it in range(max_iter):
        t0 = time.perf_counter()
        plan = make_spgemm_plan(x.coords, x.coords, P, x.bs)
        a_store, b_store = shard_stores(plan, x.data, x.data)
        h2d = a_store.nbytes + b_store.nbytes
        h2d += plan.task_a.nbytes + plan.task_b.nbytes + plan.task_c.nbytes
        h2d += sum(plan.a_send[d].nbytes for d in plan.a_offsets)
        h2d += sum(plan.b_send[d].nbytes for d in plan.b_offsets)
        c_stores = dist_spgemm(plan, x.data, x.data, mesh)
        x2 = unshard_result(plan, c_stores, x.shape, x.bs)
        idem = add(x2, x, 1.0, -1.0).frobenius_norm()
        tr = x.trace()
        times.append(time.perf_counter() - t0)
        h2d_bytes.append(h2d)
        if monitor.update(it, idem):
            break
        x = x2 if sp2_should_square(tr, n_occ) else add(x, x2, 2.0, -1.0)
        if TRUNC_TAU > 0:
            x = truncate(x, TRUNC_TAU)
    return it + 1, times, h2d_bytes


def resident_purify(f, n_occ, lmin, lmax, mesh):
    cache = PlanCache()
    t0 = time.perf_counter()
    df = scatter(f, mesh)  # the one-time host->device shipment of F
    scatter_s = time.perf_counter() - t0
    scatter_bytes = df.store.nbytes

    t_all0 = time.perf_counter()
    d, stats = dist_sp2_purify(
        df, n_occ, lmin, lmax, idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU, cache=cache
    )
    total = time.perf_counter() - t_all0
    return d, stats, total, scatter_s, scatter_bytes


def error_control_comparison(f, n_occ, lmin, lmax, mesh, spamm_tau=1e-6):
    """Leaf/replan vs hierarchical/delta error control on the same SP2 run."""
    modes = [
        ("leaf + replan-SpAMM", dict(trunc_method="leaf", spamm_method="replan")),
        ("hier + delta-SpAMM", dict(trunc_method="hierarchical", spamm_method="delta")),
    ]
    print("\n-- error-control modes (spamm_tau=%g, trunc_tau=%g) --" % (spamm_tau, TRUNC_TAU))
    for name, kw in modes:
        cache = PlanCache()
        df = scatter(f, mesh)
        t0 = time.perf_counter()
        _, stats = dist_sp2_purify(
            df, n_occ, lmin, lmax, idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU,
            spamm_tau=spamm_tau, cache=cache, **kw,
        )
        total = time.perf_counter() - t0
        per = stats.per_iter
        misses = [pi["cache_misses"] for pi in per]
        sym_ms = [pi["symbolic_s"] * 1e3 for pi in per]
        build_ms = [pi["plan_build_s"] * 1e3 for pi in per]
        all_hit = sum(1 for m in misses if m == 0)
        print(f"\n  [{name}]  iters={stats.iterations}  wall/iter "
              f"{total/max(stats.iterations,1)*1e3:.1f} ms")
        print(f"    plan misses/iter    {misses}")
        print(f"    all-hit iterations  {all_hit}/{len(per)}")
        print(f"    symbolic ms/iter    mean {np.mean(sym_ms):7.2f}  "
              f"tail {np.mean(sym_ms[-5:]):7.2f}")
        print(f"    plan+jit ms/iter    mean {np.mean(build_ms):7.2f}  "
              f"tail {np.mean(build_ms[-5:]):7.2f}")
        print(f"    recv MB/worker tail {per[-1]['recv_bytes_mean']/1e6:.3f}")


def main():
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)
    f = hamiltonian(N, BS)
    lmin, lmax = eig_bounds(f)
    print(f"F: n={N} bs={BS} nnzb={f.nnzb}  workers={P}")

    # both paths measured cold: compile time lands in miss iterations for the
    # resident path and in every iteration's plan/jit for the baseline
    iters_b, times_b, h2d_b = baseline_reshard_purify(f, NOCC, lmin, lmax, mesh)
    d, stats, total_r, scatter_s, scatter_bytes = resident_purify(
        f, NOCC, lmin, lmax, mesh
    )

    print("\n-- baseline: make_spgemm_plan + dist_spgemm per iteration --")
    print(f"iterations            {iters_b}")
    print(f"wall/iter             {np.mean(times_b)*1e3:9.1f} ms")
    print(f"host->device/iter     {np.mean(h2d_b)/1e6:9.3f} MB")
    print(f"host->device total    {np.sum(h2d_b)/1e6:9.3f} MB")

    print("\n-- resident: repro.dist (DistBSMatrix + PlanCache) --")
    print(f"iterations            {stats.iterations}")
    print(f"wall/iter             {total_r/max(stats.iterations,1)*1e3:9.1f} ms")
    print(f"scatter once          {scatter_bytes/1e6:9.3f} MB in {scatter_s*1e3:.1f} ms")
    print(
        f"host->device/iter     {0.0:9.3f} MB operand blocks "
        f"(plan index arrays ship once per new structure)"
    )
    c = stats.cache
    print(
        f"plan cache            {c['hits']} hits / {c['misses']} misses "
        f"(hit rate {c['hit_rate']:.2f})"
    )
    tail = stats.per_iter[-5:]
    print(
        "steady-state iters    "
        + ", ".join(f"{pi['cache_hits']}h/{pi['cache_misses']}m" for pi in tail)
    )
    hit_iters = [pi["wall_s"] for pi in stats.per_iter if pi["cache_misses"] == 0]
    if hit_iters:
        print(
            f"wall/iter (all-hit)   {np.mean(hit_iters)*1e3:9.1f} ms "
            f"({len(hit_iters)} iterations with zero planning/compile)"
        )
    print(
        f"recv bytes/worker     {stats.per_iter[-1]['recv_bytes_mean']/1e6:.3f} MB "
        f"(planned p2p exchange, device<->device)"
    )
    assert c["hits"] > 0, "expected plan-cache hits across iterations"
    speedup = np.mean(times_b) / (total_r / max(stats.iterations, 1))
    print(f"\nresident speedup      {speedup:9.2f}x per iteration")
    print(f"h2d reduction         {np.sum(h2d_b)/max(scatter_bytes,1):9.1f}x "
          f"({np.sum(h2d_b)/1e6:.1f} MB -> {scatter_bytes/1e6:.1f} MB once)")

    error_control_comparison(f, NOCC, lmin, lmax, mesh)


if __name__ == "__main__":
    main()
