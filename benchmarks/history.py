"""Append benchmark results to the commit-stamped trajectory store.

Extracts one-schema history entries (see :mod:`repro.obs.regress`) from the
``BENCH_*.json`` files the benchmarks write and appends them to
``BENCH_HISTORY.jsonl``::

    PYTHONPATH=src python benchmarks/history.py BENCH_trace.json
    PYTHONPATH=src python benchmarks/history.py BENCH_*.json --history BENCH_HISTORY.jsonl
    PYTHONPATH=src python -m repro.obs.regress --check   # then gate

Each BENCH file maps to its bench kind by content: trace (overhead gate),
balance (one entry per structure), locality (data-locality ledger, one
entry per structure), kernel (fused leaf engine).  Boolean
gates (bit identity, precision bounds) become 0/1 metrics so the regression
gate treats a flipped gate as an exact-tolerance failure.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.regress import HISTORY_FILENAME, append_history  # noqa: E402

__all__ = ["git_commit", "make_entry", "entries_from_bench_json", "main"]


def git_commit(root: str | None = None) -> str:
    """Short hash of HEAD, or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=root or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.TimeoutExpired):
        pass
    return "unknown"


def make_entry(bench: str, metrics: dict, *, config: str = "default",
               meta: dict | None = None, ts: float | None = None,
               commit: str | None = None) -> dict:
    return dict(
        ts=float(ts if ts is not None else time.time()),
        commit=commit if commit is not None else git_commit(),
        bench=str(bench),
        config=str(config),
        metrics={k: float(v) for k, v in metrics.items()},
        meta=dict(meta or {}),
    )


def _config(meta: dict, base: str = "") -> str:
    mode = "smoke" if meta.get("smoke") else "full"
    return f"{base}-{mode}" if base else mode


def entries_from_bench_json(path: str, *, ts: float | None = None,
                            commit: str | None = None) -> list[dict]:
    """History entries for one written BENCH file (kind sniffed by schema)."""
    with open(path) as fh:
        data = json.load(fh)
    meta = data.get("meta", {})
    kw = dict(ts=ts, commit=commit)

    if "overhead" in data:  # BENCH_trace.json
        ov = data["overhead"]
        metrics = dict(
            overhead_pct=ov["overhead_pct"],
            overhead_sync_pct=ov["overhead_sync_pct"],
            min_untraced_s=ov["min_untraced_s"],
            min_traced_s=ov["min_traced_s"],
            bit_identical=1.0 if ov["bit_identical"] else 0.0,
        )
        entry_meta = dict(n=meta.get("n"), workers=meta.get("workers"),
                          source=os.path.basename(path))
        if "observatory" in meta:
            entry_meta["observatory"] = bool(meta["observatory"])
        return [make_entry("trace", metrics, config=_config(meta),
                           meta=entry_meta, **kw)]

    if "structures" in data:  # BENCH_balance.json
        entries = []
        for name, row in sorted(data["structures"].items()):
            reb = row["rebalanced"]
            metrics = dict(
                peak_imbalance_reduction=row["peak_imbalance_reduction"],
                bit_identical=1.0 if reb["bit_identical_to_static"] else 0.0,
                imbalance_tail=reb["imbalance_tail"],
                wall_s_per_iter=reb["wall_s_per_iter"],
            )
            entries.append(make_entry(
                "balance", metrics, config=_config(meta, name),
                meta=dict(n=meta.get("n"), workers=meta.get("workers"),
                          source=os.path.basename(path)), **kw))
        return entries

    if "locality" in data:  # BENCH_locality.json
        entries = []
        for name, row in sorted(data["locality"].items()):
            stat, reb = row["static"], row["rebalanced"]
            tg = row.get("taskgraph") or {}
            metrics = dict(
                locality_flops_static=stat["locality_flops"],
                locality_flops_rebalanced=reb["locality_flops"],
                locality_bytes_rebalanced=reb["locality_bytes"],
                rebalanced_locality_gain=(
                    reb["locality_flops"] / max(stat["locality_flops"], 1e-12)),
                wire_mb_rebalanced=reb["wire_recv_bytes"] / 1e6,
            )
            if tg.get("after"):
                metrics["critical_path_ratio"] = (
                    tg["after"]["critical_path"]
                    / max(tg["before"]["critical_path"], 1e-12))
            entries.append(make_entry(
                "locality", metrics, config=_config(meta, name),
                meta=dict(n=meta.get("n"), workers=meta.get("workers"),
                          source=os.path.basename(path)), **kw))
        return entries

    if "fused_vs_staged" in data:  # BENCH_kernel.json
        fvs = data["fused_vs_staged"]
        prec = data["precision"]
        metrics = dict(
            fused_speedup=fvs["speedup"],
            bit_identical=1.0 if fvs["bit_identical"] else 0.0,
            bf16_fro_err=prec["bf16"]["fro_err"],
            within_bounds=1.0 if prec["within_bounds"] else 0.0,
            autotune_roundtrip=1.0 if data["autotune"]["roundtrip_ok"] else 0.0,
        )
        return [make_entry("kernel", metrics, config=_config(meta),
                           meta=dict(backend=meta.get("backend"),
                                     bs=meta.get("bs"),
                                     source=os.path.basename(path)), **kw)]

    raise ValueError(f"{path}: unrecognized BENCH schema "
                     f"(top-level keys {sorted(data)})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append BENCH_*.json results to the benchmark history")
    ap.add_argument("bench_files", nargs="+", help="written BENCH_*.json files")
    ap.add_argument("--history", default=HISTORY_FILENAME)
    args = ap.parse_args(argv)

    commit = git_commit()
    ts = time.time()
    total = 0
    for path in args.bench_files:
        for entry in entries_from_bench_json(path, ts=ts, commit=commit):
            append_history(args.history, entry)
            total += 1
            print(f"history: + {entry['bench']}/{entry['config']} "
                  f"@ {entry['commit']} "
                  f"({len(entry['metrics'])} metrics) from {path}")
    print(f"history: {total} entr{'y' if total == 1 else 'ies'} "
          f"appended to {args.history}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
