"""Kernel microbenchmarks: leaf engine (staged vs fused), precision, tiles.

Timing honesty on this CPU container: the Pallas kernels run in *interpret
mode*, which is orders of magnitude slower than compiled Mosaic and says
nothing about TPU performance.  Every interpret-mode row is therefore
labeled ``smoke_only=True`` and claims no GFLOP/s; the *timed* comparisons
(reference vs reference at the real problem sizes — staged concatenate +
grouped matmul vs the fused gather engine, fp32 vs mixed precision) are
XLA:CPU against XLA:CPU and are the honest numbers.

Results are written machine-readable to ``BENCH_kernel.json`` at the repo
root (sections ``meta`` / ``rows`` / ``fused_vs_staged`` / ``precision`` /
``autotune``) so future PRs can track them.

Run:   PYTHONPATH=src python benchmarks/kernel_micro.py [--smoke]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSMatrix, multiply
from repro.core.spgemm import spgemm_symbolic
from repro.kernels.autotune import (
    autotune_tiles,
    clear_memo,
    heuristic_tiles,
    pick_tiles,
    time_call,
    tile_key,
)
from repro.kernels.block_spmm import block_spmm_kernel_call
from repro.kernels.fused_leaf import (
    fused_block_spmm_kernel_call,
    fused_block_spmm_ref,
)
from repro.kernels.precision import ROUND2_BOUND, low_precision_task_mask
from repro.kernels.ref import block_spmm_ref

_time = time_call  # one stopwatch for benches and autotune decisions

OUT_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_kernel.json"
)


def bench_block_spmm(bs: int = 128, T: int = 64, nout: int = 16) -> list[dict]:
    """Grouped block matmul: reference timed at the real size; the interpret
    kernel exercised at a tiny size purely as a smoke signal (no GFLOP/s —
    interpret time is not kernel time)."""
    rng = np.random.default_rng(0)
    na = nb = 32
    A = jnp.asarray(rng.standard_normal((na, bs, bs)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((nb, bs, bs)), jnp.float32)
    a = jnp.asarray(rng.integers(0, na, T), jnp.int32)
    b = jnp.asarray(rng.integers(0, nb, T), jnp.int32)
    c = jnp.asarray(np.sort(rng.integers(0, nout, T)), jnp.int32)
    flops = 2.0 * T * bs**3

    t_ref = _time(lambda: block_spmm_ref(A, B, a, b, c, nout).block_until_ready())
    rows = [
        dict(
            name=f"block_spmm_ref_bs{bs}",
            us=t_ref * 1e6,
            gflops=flops / t_ref / 1e9,
            smoke_only=False,
        )
    ]
    # interpret-mode correctness smoke at a size the interpreter can afford;
    # timing it at bs=128 and reporting GFLOP/s would be dishonest
    sbs, sT, snout = 16, 8, 4
    As, Bs = A[:4, :sbs, :sbs], B[:4, :sbs, :sbs]
    sa = jnp.asarray(rng.integers(0, 4, sT), jnp.int32)
    sb = jnp.asarray(rng.integers(0, 4, sT), jnp.int32)
    sc = jnp.asarray(np.sort(rng.integers(0, snout, sT)), jnp.int32)
    t_k = _time(
        lambda: block_spmm_kernel_call(
            As, Bs, sa, sb, sc, num_out=snout, interpret=True
        ).block_until_ready(),
        reps=2,
    )
    rows.append(
        dict(
            name=f"block_spmm_pallas_interpret_smoke_bs{sbs}",
            us=t_k * 1e6,
            gflops=0.0,
            smoke_only=True,
        )
    )
    return rows


def bench_spgemm_end_to_end(n: int = 4096, bs: int = 128) -> list[dict]:
    """Library-level multiply incl. symbolic phase (banded matrix)."""
    rng = np.random.default_rng(1)
    nb = n // bs
    i = np.arange(nb)
    coords = []
    for d in (-1, 0, 1):
        j = i + d
        m = (j >= 0) & (j < nb)
        coords.append(np.stack([i[m], j[m]], 1))
    coords = np.concatenate(coords)
    from repro.core.quadtree import morton_sort

    coords = coords[morton_sort(coords)]
    data = jnp.asarray(rng.standard_normal((len(coords), bs, bs)), jnp.float32)
    a = BSMatrix(shape=(n, n), bs=bs, coords=coords, data=data)

    t_sym = _time(lambda: spgemm_symbolic(a.coords, a.coords), reps=10)
    t_full = _time(lambda: multiply(a, a).data.block_until_ready(), reps=3)
    tasks = spgemm_symbolic(a.coords, a.coords)
    flops = 2.0 * tasks.num_tasks * bs**3
    return [
        dict(name=f"spgemm_symbolic_n{n}", us=t_sym * 1e6, gflops=0.0,
             smoke_only=False),
        dict(name=f"spgemm_full_n{n}", us=t_full * 1e6,
             gflops=flops / t_full / 1e9, smoke_only=False),
    ]


def _fused_problem(bs: int, T: int, n_store: int = 64, rounds: int = 3,
                   cap_u: int = 32, seed: int = 3):
    """A device-local leaf workload shaped like one worker's share of a plan:
    own store + stacked receive buffers, tasks addressing both."""
    rng = np.random.default_rng(seed)
    a_store = jnp.asarray(rng.standard_normal((n_store, bs, bs)), jnp.float32)
    b_store = jnp.asarray(rng.standard_normal((n_store, bs, bs)), jnp.float32)
    a_recv = jnp.asarray(rng.standard_normal((rounds, cap_u, bs, bs)), jnp.float32)
    b_recv = jnp.asarray(rng.standard_normal((rounds, cap_u, bs, bs)), jnp.float32)
    a_src = rng.integers(0, rounds + 1, T).astype(np.int32)
    b_src = rng.integers(0, rounds + 1, T).astype(np.int32)
    a_off = np.where(a_src == 0, rng.integers(0, n_store, T),
                     rng.integers(0, cap_u, T)).astype(np.int32)
    b_off = np.where(b_src == 0, rng.integers(0, n_store, T),
                     rng.integers(0, cap_u, T)).astype(np.int32)
    nout = max(T // 4, 1)
    c_idx = np.sort(rng.integers(0, nout, T)).astype(np.int32)
    a_lin = np.where(a_src == 0, a_off, n_store + (a_src - 1) * cap_u + a_off)
    b_lin = np.where(b_src == 0, b_off, n_store + (b_src - 1) * cap_u + b_off)
    j = lambda x: jnp.asarray(x, jnp.int32)
    return dict(
        a_store=a_store, b_store=b_store, a_recv=a_recv, b_recv=b_recv,
        a_src=j(a_src), a_off=j(a_off), b_src=j(b_src), b_off=j(b_off),
        c_idx=j(c_idx), a_lin=j(a_lin), b_lin=j(b_lin), nout=nout,
        bs=bs, T=T,
    )


def bench_fused_vs_staged(bs: int = 64, T: int = 512) -> dict:
    """The tentpole comparison: staged path (materialize the concatenated
    ``[own | recv...]`` operand buffer, then grouped matmul) vs the fused
    engine (gather straight from store + receive stacks — no concatenate).
    Both are XLA:CPU at the real size; results must be bit-identical."""
    p = _fused_problem(bs, T)

    # the concatenate is a separate dispatch, exactly as the staged numeric
    # phase ran it (jitting it together with the matmul would let XLA fuse
    # across the boundary the real staged path had — and change the bits)
    def staged():
        a_cat = jnp.concatenate(
            [p["a_store"], p["a_recv"].reshape(-1, bs, bs)])
        b_cat = jnp.concatenate(
            [p["b_store"], p["b_recv"].reshape(-1, bs, bs)])
        return block_spmm_ref(
            a_cat, b_cat, p["a_lin"], p["b_lin"], p["c_idx"], p["nout"])

    def fused():
        return fused_block_spmm_ref(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            num_out=p["nout"])

    c_staged, c_fused = np.asarray(staged()), np.asarray(fused())
    bit_identical = bool((c_staged == c_fused).all())
    t_staged = _time(lambda: staged().block_until_ready())
    t_fused = _time(lambda: fused().block_until_ready())
    flops = 2.0 * T * bs**3
    out = dict(
        bs=bs, T=T, bit_identical=bit_identical,
        staged_us=t_staged * 1e6, fused_us=t_fused * 1e6,
        speedup=t_staged / t_fused,
        staged_gflops=flops / t_staged / 1e9,
        fused_gflops=flops / t_fused / 1e9,
        operand_buffer_bytes_eliminated=int(
            2 * (p["a_store"].shape[0] + p["a_recv"].shape[0] * p["a_recv"].shape[1])
            * bs * bs * 4),
    )
    assert bit_identical, "fused engine diverged from the staged path"
    return out


def bench_precision_modes(bs: int = 64, T: int = 512) -> dict:
    """fp32 vs bf16 storage vs norm-adaptive per-task rounding, with the
    measured error against the analytic ``(2u+u^2) sum ||A_t|| ||B_t||``
    bound each mode promises."""
    p = _fused_problem(bs, T, seed=4)

    def run(a_store, b_store, a_recv, b_recv, low=None, adaptive=False):
        return fused_block_spmm_ref(
            a_store, a_recv, b_store, b_recv,
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            None if low is None else jnp.asarray(low, jnp.int32),
            num_out=p["nout"], adaptive=adaptive)

    exact = np.asarray(run(p["a_store"], p["b_store"], p["a_recv"], p["b_recv"]))
    t_fp32 = _time(lambda: run(
        p["a_store"], p["b_store"], p["a_recv"], p["b_recv"]).block_until_ready())

    bf = lambda x: jnp.asarray(x, jnp.bfloat16)
    a_cat = np.concatenate([np.asarray(p["a_store"]),
                            np.asarray(p["a_recv"]).reshape(-1, bs, bs)])
    b_cat = np.concatenate([np.asarray(p["b_store"]),
                            np.asarray(p["b_recv"]).reshape(-1, bs, bs)])
    a_n = np.linalg.norm(a_cat.astype(np.float64), axis=(1, 2))
    b_n = np.linalg.norm(b_cat.astype(np.float64), axis=(1, 2))
    a_lin, b_lin = np.asarray(p["a_lin"]), np.asarray(p["b_lin"])
    full_bound = float(ROUND2_BOUND * (a_n[a_lin] * b_n[b_lin]).sum())

    c_bf16 = np.asarray(run(bf(p["a_store"]), bf(p["b_store"]),
                            bf(p["a_recv"]), bf(p["b_recv"])))
    t_bf16 = _time(lambda: run(
        bf(p["a_store"]), bf(p["b_store"]), bf(p["a_recv"]),
        bf(p["b_recv"])).block_until_ready())
    err_bf16 = float(np.linalg.norm((c_bf16 - exact).ravel()))

    budget = 0.25 * full_bound
    low, spent = low_precision_task_mask(a_n, b_n, a_lin, b_lin, budget)
    c_ad = np.asarray(run(p["a_store"], p["b_store"], p["a_recv"], p["b_recv"],
                          low=low.astype(np.int32), adaptive=True))
    t_ad = _time(lambda: run(
        p["a_store"], p["b_store"], p["a_recv"], p["b_recv"],
        low=low.astype(np.int32), adaptive=True).block_until_ready())
    err_ad = float(np.linalg.norm((c_ad - exact).ravel()))

    out = dict(
        bs=bs, T=T,
        fp32=dict(us=t_fp32 * 1e6, fro_err=0.0, bound=0.0),
        bf16=dict(us=t_bf16 * 1e6, fro_err=err_bf16, bound=full_bound,
                  wire_bytes_ratio=0.5),
        adaptive=dict(us=t_ad * 1e6, fro_err=err_ad, bound=spent,
                      budget=budget, low_tasks=int(low.sum()),
                      tasks=T),
        within_bounds=bool(err_bf16 <= full_bound and err_ad <= spent + 1e-12),
    )
    assert out["within_bounds"], (err_bf16, full_bound, err_ad, spent)
    return out


def bench_autotune(smoke: bool = True) -> dict:
    """Tile autotuner exercised end to end on the fused kernel (interpret on
    CPU — the timings steer nothing real here, this validates the machinery:
    winner measured, persisted, and picked back up on the next dispatch)."""
    bs = 16 if smoke else 32
    p = _fused_problem(bs, 16 if smoke else 64, n_store=8, rounds=1, cap_u=4)
    low = jnp.zeros(p["T"], jnp.int32)

    def bench(tm, tn, tk):
        return lambda: fused_block_spmm_kernel_call(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"], low,
            num_out=p["nout"], tm=tm, tn=tn, tk=tk, interpret=True,
        ).block_until_ready()

    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    os.unlink(path)
    try:
        clear_memo()
        miss = pick_tiles(bs, bs, bs, "float32", path=path)
        best, rows = autotune_tiles(
            bs, bs, bs, "float32", bench=bench,
            candidates=[(bs, bs, bs), (bs // 2, bs // 2, bs // 2)],
            reps=1, path=path)
        clear_memo()
        hit = pick_tiles(bs, bs, bs, "float32", path=path)
    finally:
        if os.path.exists(path):
            os.unlink(path)
        clear_memo()
    return dict(
        bs=bs,
        heuristic=list(heuristic_tiles(bs, bs, bs)),
        pre_tune_pick=list(miss),
        winner=list(best),
        post_tune_pick=list(hit),
        roundtrip_ok=bool(tuple(hit) == tuple(best)),
        key=tile_key(jax.default_backend(), bs, bs, bs, "float32"),
        candidates=[dict(tiles=list(r["tiles"]),
                         us=r["us"], error=r.get("error"))
                    for r in rows],
        smoke_only=True,  # interpret-mode timings steer nothing off-CPU
    )


def main() -> None:
    smoke = "--smoke" in sys.argv
    bs, T = (32, 64) if smoke else (64, 512)
    spg_n = 1024 if smoke else 4096
    spg_bs = 64 if smoke else 128

    rows = bench_block_spmm(bs=spg_bs, T=32, nout=8)
    rows += bench_spgemm_end_to_end(n=spg_n, bs=spg_bs)
    for r in rows:
        tag = "  [smoke-only, no perf claim]" if r["smoke_only"] else ""
        print(f"{r['name']:44s} {r['us']:10.1f} us  "
              f"gflops={r['gflops']:.2f}{tag}")

    fvs = bench_fused_vs_staged(bs=bs, T=T)
    print(f"\nfused vs staged (bs={bs}, T={T}): "
          f"staged {fvs['staged_us']:.1f} us, fused {fvs['fused_us']:.1f} us "
          f"({fvs['speedup']:.2f}x), bit_identical={fvs['bit_identical']}, "
          f"buffer eliminated {fvs['operand_buffer_bytes_eliminated']/1e6:.2f} MB")

    prec = bench_precision_modes(bs=bs, T=T)
    for mode in ("fp32", "bf16", "adaptive"):
        r = prec[mode]
        print(f"precision {mode:8s}: {r['us']:10.1f} us  "
              f"fro_err={r['fro_err']:.3e} bound={r['bound']:.3e}")

    at = bench_autotune(smoke=smoke)
    print(f"autotune bs={at['bs']}: winner={at['winner']} "
          f"roundtrip_ok={at['roundtrip_ok']} (interpret smoke)")

    payload = dict(
        meta=dict(
            backend=jax.default_backend(), smoke=smoke, bs=bs, T=T,
            note="interpret-mode rows are smoke_only: CPU interpret time "
                 "is not kernel time and claims no GFLOP/s",
        ),
        rows=rows,
        fused_vs_staged=fvs,
        precision=prec,
        autotune=at,
    )
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
