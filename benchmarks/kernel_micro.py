"""Kernel microbenchmarks: grouped block matmul + flash attention.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled Mosaic), so the *timed* numbers compare the
jnp reference against XLA:CPU; the kernel path is timed at tiny sizes purely
as a smoke signal.  The derived column reports achieved GFLOP/s.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSMatrix, multiply
from repro.core.spgemm import spgemm_symbolic
from repro.kernels.block_spmm import block_spmm_kernel_call
from repro.kernels.ref import block_spmm_ref


def _time(fn, reps=5):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def bench_block_spmm(bs: int = 128, T: int = 64, nout: int = 16) -> list[dict]:
    rng = np.random.default_rng(0)
    na = nb = 32
    A = jnp.asarray(rng.standard_normal((na, bs, bs)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((nb, bs, bs)), jnp.float32)
    a = jnp.asarray(rng.integers(0, na, T), jnp.int32)
    b = jnp.asarray(rng.integers(0, nb, T), jnp.int32)
    c = jnp.asarray(np.sort(rng.integers(0, nout, T)), jnp.int32)
    flops = 2.0 * T * bs**3

    t_ref = _time(lambda: block_spmm_ref(A, B, a, b, c, nout).block_until_ready())
    rows = [
        dict(name=f"block_spmm_ref_bs{bs}", us=t_ref * 1e6, gflops=flops / t_ref / 1e9)
    ]
    t_k = _time(
        lambda: block_spmm_kernel_call(
            A, B, a, b, c, num_out=nout, interpret=True
        ).block_until_ready(),
        reps=2,
    )
    rows.append(
        dict(
            name=f"block_spmm_pallas_interpret_bs{bs}",
            us=t_k * 1e6,
            gflops=flops / t_k / 1e9,
        )
    )
    return rows


def bench_spgemm_end_to_end(n: int = 4096, bs: int = 128) -> list[dict]:
    """Library-level multiply incl. symbolic phase (banded matrix)."""
    rng = np.random.default_rng(1)
    nb = n // bs
    i = np.arange(nb)
    coords = []
    for d in (-1, 0, 1):
        j = i + d
        m = (j >= 0) & (j < nb)
        coords.append(np.stack([i[m], j[m]], 1))
    coords = np.concatenate(coords)
    from repro.core.quadtree import morton_sort

    coords = coords[morton_sort(coords)]
    data = jnp.asarray(rng.standard_normal((len(coords), bs, bs)), jnp.float32)
    a = BSMatrix(shape=(n, n), bs=bs, coords=coords, data=data)

    t_sym = _time(lambda: spgemm_symbolic(a.coords, a.coords), reps=10)
    t_full = _time(lambda: multiply(a, a).data.block_until_ready(), reps=3)
    tasks = spgemm_symbolic(a.coords, a.coords)
    flops = 2.0 * tasks.num_tasks * bs**3
    return [
        dict(name=f"spgemm_symbolic_n{n}", us=t_sym * 1e6, gflops=0.0),
        dict(name=f"spgemm_full_n{n}", us=t_full * 1e6, gflops=flops / t_full / 1e9),
    ]
