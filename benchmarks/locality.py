"""Data-locality trajectory on the paper's matrix sequences.

Runs distributed SP2 purification on the three structure families from
``benchmarks/spamm_sequences.py`` on an 8-worker CPU mesh, starting from
the deliberately skewed initial layout (every block on worker 0), with a
:class:`repro.obs.locality.LocalityLedger` riding on the plan cache:

* ``static``      — the skewed layout is never revisited, so almost every
                    operand byte a task reads has to cross the wire;
* ``rebalanced``  — ``RebalancePolicy()`` migrates the iterate to the
                    measured cut, after which tasks mostly read bytes their
                    own worker holds.

Reported per (structure, mode): locality fraction (locally-owned flops and
bytes over totals), shipped vs wire bytes (delta-mask pruning and bf16 wire
halving applied), the per-worker split, and the most re-fetched blocks.
Plus, per structure, the executed-task-graph analysis of the skewed
first-iteration plan — critical path, slack, and the what-if projections
(perfect balance / zero exchange / the measured rebalanced cut), the
analytic preview that the locality gain validates end-to-end.

The rebalanced locality fraction must come out strictly higher than the
static one on every structure — that is the bench's own gate; the history
gate (``repro.obs.regress``) tracks the trajectory.  Results are written to
``BENCH_locality.json`` at the repo root.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       PYTHONPATH=src python benchmarks/locality.py [--smoke]
"""

from __future__ import annotations

import json
import os
import sys
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402

import dist_balance  # noqa: E402  (sequences / eig_bounds, same families)
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.core.schedule import make_spgemm_plan  # noqa: E402
from repro.dist import (  # noqa: E402
    PlanCache,
    RebalancePolicy,
    dist_sp2_purify,
    scatter,
)
from repro.obs.locality import LocalityLedger  # noqa: E402
from repro.obs.report import locality_table  # noqa: E402
from repro.obs.taskgraph import whatif_rebalanced  # noqa: E402

P = 8
BS = dist_balance.BS  # 16
IDEM_TOL, TRUNC_TAU, SPAMM_TAU = (
    dist_balance.IDEM_TOL, dist_balance.TRUNC_TAU, dist_balance.SPAMM_TAU)
OUT_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_locality.json")


def run_mode(f, nocc, lmin, lmax, mesh, policy, max_iter):
    skew = np.zeros(f.nnzb, dtype=np.int32)  # skewed initial layout
    df = scatter(f, mesh, owner=skew)
    cache = PlanCache()
    ledger = LocalityLedger().install(cache)
    t0 = time.perf_counter()
    d, st = dist_sp2_purify(
        df, nocc, lmin, lmax, max_iter=max_iter, idem_tol=IDEM_TOL,
        trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU, cache=cache,
        rebalance=policy,
    )
    total = time.perf_counter() - t0
    r = ledger.summary()
    r["iterations"] = st.iterations
    r["rebalances"] = st.rebalances
    r["wall_s_total"] = float(total)
    # per-iteration locality trajectory from the driver rows the ledger fed
    r["locality_flops_per_iter"] = [
        float(pi["locality_flops"]) for pi in st.per_iter
        if "locality_flops" in pi]
    return d, r


def taskgraph_row(f):
    """What-if analysis of the skewed first-iteration plan — pure host."""
    skew = np.zeros(f.nnzb, dtype=np.int32)
    plan = make_spgemm_plan(f.coords, f.coords, P, BS,
                            a_owner=skew, b_owner=skew)
    w = whatif_rebalanced(plan, f.coords)
    return dict(
        before=w["before"].as_dict(),
        after=w["after"].as_dict(),
        predicted_gain=w["predicted_gain"],
    )


def main() -> None:
    smoke = "--smoke" in sys.argv
    n = 256 if smoke else 512
    max_iter = 12 if smoke else 25
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)

    results: dict = {}
    for name, f in dist_balance.sequences(n).items():
        nocc = int(0.3 * n)
        lmin, lmax = dist_balance.eig_bounds(f)
        print(f"\n== {name}: n={n} bs={BS} nnzb={f.nnzb} workers={P} "
              f"(skewed initial layout: all blocks on worker 0) ==")
        row: dict = {}
        d_ref = None
        for mode, policy in (("static", None), ("rebalanced", RebalancePolicy())):
            d, r = run_mode(f, nocc, lmin, lmax, mesh, policy, max_iter)
            if d_ref is None:
                d_ref = d
            else:
                bitwise = bool(np.array_equal(
                    np.asarray(d_ref.to_dense()), np.asarray(d.to_dense())))
                r["bit_identical_to_static"] = bitwise
                assert bitwise, "the ledger is an observer: math must not move"
            row[mode] = r
            print(f"  [{mode:10s}] iters={r['iterations']:3d}  "
                  f"locality {r['locality_flops'] * 100:5.1f}% flops / "
                  f"{r['locality_bytes'] * 100:5.1f}% bytes  "
                  f"shipped {r['shipped_bytes'] / 1e6:7.2f} MB  "
                  f"wire {r['wire_recv_bytes'] / 1e6:7.2f} MB")
        row["taskgraph"] = taskgraph_row(f)
        tg = row["taskgraph"]
        print(f"  what-if (skewed plan): critical path "
              f"{tg['before']['critical_path']:.1f} -> rebalanced cut "
              f"{tg['after']['critical_path']:.1f} "
              f"(predicted gain {tg['predicted_gain']:.2f}x)")
        gain = (row["rebalanced"]["locality_flops"]
                / max(row["static"]["locality_flops"], 1e-12))
        print(f"  rebalanced locality gain: {gain:.2f}x")
        assert (row["rebalanced"]["locality_flops"]
                > row["static"]["locality_flops"]), (
            f"{name}: rebalancing must raise the locality fraction on the "
            f"skewed layout")
        results[name] = row

    payload = dict(
        meta=dict(
            n=n, bs=BS, workers=P, smoke=smoke, max_iter=max_iter,
            idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU,
            initial_layout="all blocks on worker 0",
            policy=dict(RebalancePolicy().__dict__),
        ),
        locality=results,
    )
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"\nwrote {os.path.abspath(OUT_PATH)}\n")
    print(locality_table(payload))


if __name__ == "__main__":
    main()
