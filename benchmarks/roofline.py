"""Roofline table emitter: reads launch/dryrun JSONs -> EXPERIMENTS.md rows."""

from __future__ import annotations

import glob
import json
import os

HW = dict(peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


def load(dirname: str = "results/dryrun") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "16x16") -> str:
    head = (
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [head]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped: {r['why']} | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        lines.append(
            "| {arch} | {shape} | {c:.3e} | {m:.3e} | {k:.3e} | {b} | {u:.2f} | {f:.3f} |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=r["compute_term_s"],
                m=r["memory_term_s"],
                k=r["collective_term_s"],
                b=r["bottleneck"],
                u=r["useful_flops_ratio"],
                f=r["roofline_fraction"],
            )
        )
    return "\n".join(lines)


def summary(recs: list[dict]) -> dict:
    ok = [r for r in recs if r["status"] == "ok"]
    by_cell = {}
    for r in ok:
        by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    single = [r for r in ok if r["mesh"] == "16x16"]
    worst = sorted(single, key=lambda r: r["roofline_fraction"])[:5]
    coll = sorted(
        single, key=lambda r: -r["collective_term_s"] / max(r["compute_term_s"], 1e-12)
    )[:5]
    return {
        "cells_ok": len(ok),
        "cells_skipped": len([r for r in recs if r["status"] == "skipped"]),
        "cells_error": len([r for r in recs if r["status"] == "error"]),
        "worst_fraction": [(r["arch"], r["shape"], r["roofline_fraction"]) for r in worst],
        "most_collective_bound": [
            (
                r["arch"],
                r["shape"],
                r["collective_term_s"] / max(r["compute_term_s"], 1e-12),
            )
            for r in coll
        ],
    }


if __name__ == "__main__":
    recs = load()
    print(table(recs))
    print(json.dumps(summary(recs), indent=1))
