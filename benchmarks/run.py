"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = table-specific:
Tflop for Table 1, MB for Fig 1c, GFLOP/s for kernels).
"""

from __future__ import annotations

import sys


def bench_table1():
    """Paper Table 1: weak-scaling flop counts (validated vs paper values)."""
    from . import weak_scaling as ws

    rows = ws.table1()
    out = []
    for r in rows:
        rel = abs(r["banded_tflop"] - r["paper_banded"]) / r["paper_banded"]
        out.append(
            (
                f"table1_banded_n{r['n']}",
                0.0,
                f"tflop={r['banded_tflop']:.3f} paper={r['paper_banded']} rel_err={rel:.3f}",
            )
        )
        out.append(
            (
                f"table1_growing_n{r['n']}",
                0.0,
                f"tflop={r['growing_tflop']:.3f} paper={r['paper_blocked']}",
            )
        )
        out.append(
            (
                f"table1_random_n{r['n']}",
                0.0,
                f"tflop={r['random_tflop']:.3f} paper={r['paper_blocked']}",
            )
        )
    return out


def bench_fig1c(full: bool = False):
    """Paper Fig 1c: data received per worker (locality vs allgather)."""
    from . import weak_scaling as ws

    rows = ws.fig1c(max_idx=7 if full else 4)
    return [
        (
            f"fig1c_{r['family']}_p{r['workers']}",
            0.0,
            f"locality_mb={r['locality_recv_mb']:.1f} outer_mb={r.get('outer_recv_mb', -1):.1f} "
            f"allgather_mb={r['allgather_recv_mb']:.1f} balance={r['balance']:.2f}",
        )
        for r in rows
    ]


def bench_fig1a():
    """Paper Fig 1a (reduced scale): measured multiply wall time on CPU."""
    from . import weak_scaling as ws

    rows = ws.measured_weak_scaling()
    return [
        (f"fig1a_banded_n{r['n']}", r["wall_s"] * 1e6, f"gflops={r['gflops']:.2f}")
        for r in rows
    ]


def bench_kernels():
    """Leaf-level BLAS analogue: grouped block matmul kernel."""
    from . import kernel_micro as km

    out = []
    for r in km.bench_block_spmm(bs=128, T=32, nout=8):
        out.append((r["name"], r["us"], f"gflops={r['gflops']:.2f}"))
    for r in km.bench_spgemm_end_to_end():
        out.append((r["name"], r["us"], f"gflops={r['gflops']:.2f}"))
    return out


def bench_roofline():
    """Dry-run roofline summary (requires results/dryrun JSONs)."""
    from . import roofline as rl

    recs = rl.load()
    if not recs:
        return [("roofline", 0.0, "no dryrun results yet — run launch/dryrun first")]
    s = rl.summary(recs)
    out = [
        (
            "roofline_cells",
            0.0,
            f"ok={s['cells_ok']} skipped={s['cells_skipped']} error={s['cells_error']}",
        )
    ]
    for arch, shape, frac in s["worst_fraction"]:
        out.append((f"roofline_worst_{arch}_{shape}", 0.0, f"fraction={frac:.3f}"))
    return out


def main() -> None:
    benches = [bench_table1, bench_fig1c, bench_fig1a, bench_kernels, bench_roofline]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for b in benches:
        if only and only not in b.__name__:
            continue
        try:
            for name, us, derived in b():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{b.__name__},0.0,ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
