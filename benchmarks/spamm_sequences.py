"""SpAMM on the paper's matrix sequences: leaf-level vs hierarchical pruning.

Generates three structure families (the sequences the paper's experiments
sweep) and, for a range of tolerances tau, compares the two SpAMM symbolic
phases:

* ``leaf``          — enumerate every leaf task, then greedily prune
                      (symbolic cost scales with the *full* task list);
* ``hierarchical``  — apply the ||A_node||*||B_node|| bound during the
                      quadtree descent, so pruned subtrees are never
                      enumerated (symbolic cost shrinks with the kept work).

Reported per (sequence, tau): symbolic wall time, node pairs visited, tasks
kept/pruned, the returned error bound, and the true ||AB - C||_F.

Run:  PYTHONPATH=src python benchmarks/spamm_sequences.py
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BSMatrix, spamm_symbolic, spgemm_symbolic
from repro.core.matrix import block_frobenius_norms
from repro.core.spgemm import _common_depth

N, BS = 1024, 16
TAUS = (1e-2, 1e-1, 1e0, 1e1)


def banded(n: int, halfwidth: int, seed: int = 0) -> BSMatrix:
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - halfwidth), min(n, i + halfwidth + 1)
        a[i, lo:hi] = rng.standard_normal(hi - lo)
    return BSMatrix.from_dense(a, BS)


def exp_decay(n: int, rate: float, seed: int = 1) -> BSMatrix:
    """Exponential off-diagonal decay — the electronic-structure regime."""
    rng = np.random.default_rng(seed)
    i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    a = rng.standard_normal((n, n)).astype(np.float32)
    a *= np.exp(-rate * np.abs(i - j)).astype(np.float32)
    return BSMatrix.from_dense(a, BS, prune_tol=1e-6)


def random_offdiag(n: int, density: float, seed: int = 2) -> BSMatrix:
    """Strong diagonal + sparse random off-diagonal blocks of decaying size."""
    rng = np.random.default_rng(seed)
    nb = n // BS
    a = np.zeros((n, n), dtype=np.float32)
    for b in range(nb):
        a[b * BS : (b + 1) * BS, b * BS : (b + 1) * BS] = rng.standard_normal(
            (BS, BS)
        )
    mask = rng.random((nb, nb)) < density
    np.fill_diagonal(mask, False)
    for i, j in zip(*np.nonzero(mask)):
        scale = 10.0 ** rng.uniform(-4, 0)  # widely varying block magnitudes
        a[i * BS : (i + 1) * BS, j * BS : (j + 1) * BS] = scale * rng.standard_normal(
            (BS, BS)
        )
    return BSMatrix.from_dense(a, BS)


def leaf_spamm_symbolic(a: BSMatrix, b: BSMatrix, tau: float):
    """Flat reference: full enumeration, then greedy leaf pruning."""
    t0 = time.perf_counter()
    tasks = spgemm_symbolic(a.coords, b.coords)
    na = np.asarray(block_frobenius_norms(a.data), dtype=np.float64)
    nb = np.asarray(block_frobenius_norms(b.data), dtype=np.float64)
    bound = na[tasks.a_idx] * nb[tasks.b_idx]
    order = np.argsort(bound)
    csum = np.cumsum(bound[order])
    ndrop = int(np.searchsorted(csum, tau, side="right"))
    err = float(csum[ndrop - 1]) if ndrop else 0.0
    dt = time.perf_counter() - t0
    # every leaf task was visited (that is the point of the comparison)
    return dict(
        time_s=dt,
        visited=tasks.num_tasks,
        kept=tasks.num_tasks - ndrop,
        pruned=ndrop,
        err_bound=err,
    )


def hier_spamm_symbolic(a: BSMatrix, b: BSMatrix, tau: float):
    depth = _common_depth(a, b)
    ia, ib = a.quadtree_index(depth), b.quadtree_index(depth)  # cached across taus
    t0 = time.perf_counter()
    tasks, err, visited = spamm_symbolic(ia, ib, tau)
    dt = time.perf_counter() - t0
    full = spgemm_symbolic(a.coords, b.coords).num_tasks
    return dict(
        time_s=dt,
        visited=visited,
        kept=tasks.num_tasks,
        pruned=full - tasks.num_tasks,
        err_bound=err,
        tasks=tasks,
    )


def true_error(a: BSMatrix, b: BSMatrix, tasks) -> float:
    from repro.core import spgemm_numeric

    data = spgemm_numeric(a.data, b.data, tasks, impl="ref")
    c = BSMatrix(
        shape=(a.shape[0], b.shape[1]), bs=a.bs, coords=tasks.c_coords, data=data
    )
    return float(np.linalg.norm(c.to_dense() - a.to_dense() @ b.to_dense()))


def main():
    sequences = {
        "banded": banded(N, 24),
        "exp-decay": exp_decay(N, rate=0.08),
        "random-offdiag": random_offdiag(N, density=0.08),
    }
    for name, a in sequences.items():
        full = spgemm_symbolic(a.coords, a.coords).num_tasks
        depth = _common_depth(a, a)
        ia = a.quadtree_index(depth)
        _, _, full_visits = spamm_symbolic(ia, ia, 0.0)
        print(
            f"\n== {name}: n={N} bs={BS} nnzb={a.nnzb} full tasks={full} "
            f"(descent visits {full_visits} node pairs at tau=0) =="
        )
        print(
            f"{'tau':>8} | {'leaf t(ms)':>10} {'visited':>9} | "
            f"{'hier t(ms)':>10} {'visited':>9} {'pruned':>8} | "
            f"{'bound':>9} {'true err':>9}"
        )
        a.quadtree_index(_common_depth(a, a))  # build once outside the timing
        for tau in TAUS:
            leaf = leaf_spamm_symbolic(a, a, tau)
            hier = hier_spamm_symbolic(a, a, tau)
            err = true_error(a, a, hier["tasks"])
            assert hier["err_bound"] <= tau + 1e-9
            assert err <= hier["err_bound"] + 1e-2
            print(
                f"{tau:8.0e} | {leaf['time_s']*1e3:10.2f} {leaf['visited']:9d} | "
                f"{hier['time_s']*1e3:10.2f} {hier['visited']:9d} "
                f"{hier['pruned']:8d} | {hier['err_bound']:9.2e} {err:9.2e}"
            )
        print(
            "hier 'visited' counts internal + leaf node pairs; pruning during "
            "the descent shrinks it below the tau=0 descent (and, once whole "
            "subtrees go, below the flat leaf enumeration), while the leaf "
            "reference always pays for the full task list"
        )


if __name__ == "__main__":
    main()
