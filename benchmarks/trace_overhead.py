"""Tracing overhead + utilization cap for the full resident pipeline.

Runs ``dist_sqrt_inv_pipeline`` (S -> Z -> Z^T H Z -> SP2 -> Z D Z^T) on an
8-worker CPU mesh from a deliberately skewed initial layout (so re-layout
migrations appear in the trace), three ways:

* warm-cache repeats with observability **off** (the pre-PR fast path);
* warm-cache repeats with the **full observatory on** (fresh
  ``Tracer(sync=False)`` + in-memory ``EventLog`` + ``HealthPolicy`` +
  ``MemoryMeter`` per repeat on the same plan cache) — the overhead gate:
  the arms run back-to-back within each round and the **median of the
  per-round paired process-CPU overheads** must stay under the acceptance
  cap, with the density matrix **bit-identical** either way.  CPU seconds
  are the measurement basis because the observatory's cost is
  deterministic extra host work, and that is what ``time.process_time``
  isolates: on an oversubscribed host (CI containers run the 8-device
  mesh on 1-2 cores) wall clock measures thread-timeslicing luck — A/A
  calibration showed identical code swinging +/-20% wall run-to-run.
  Pairing per round is the robust statistic on top of that: per-arm CPU
  floors still drift a few percent between runs (frequency scaling,
  cache pressure from whatever ran before), but both arms of one round
  see the same machine state, so their ratio cancels the drift — and the
  median ignores the occasional round where one arm eats a scheduler
  hiccup.  Because neighbor noise comes in bursts, the bench is also
  noise-aware: it computes a distribution-free 95% CI for the median
  (sign-test order statistics) and keeps adding rounds — up to 4x the
  base count — while the CI straddles the cap, so a loud minute extends
  the measurement instead of deciding it.  The unpaired best-of-arm
  floors (CPU and wall) are reported alongside
  (``overhead_cpu_min_pct``, ``overhead_wall_pct``), unguarded.
  ``sync=False`` measures the recording machinery itself;
  ``Tracer(sync=True)`` additionally blocks on device values inside
  dispatch spans so span durations measure execution rather than async
  dispatch — that serializes the host/device overlap the bare path enjoys,
  so its (larger) cost is reported separately as ``overhead_sync_pct``,
  not gated;
* one **cold** traced run (``sync=True``, execution-true spans) on a fresh
  cache, so the exported Chrome trace also carries the plan-build spans,
  and the per-worker utilization + peak-memory report is derived from it.

Results go to ``BENCH_trace.json`` at the repo root (overhead %, span
counts by category, counters, per-worker busy/idle fractions, timeline
imbalance vs the per-iteration cost-model imbalance); the Perfetto-loadable
trace itself is written next to it as ``trace_pipeline.json``.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       PYTHONPATH=src python benchmarks/trace_overhead.py [--smoke]
"""

from __future__ import annotations

import gc
import json
import math
import os
import statistics
import sys
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import (  # noqa: E402
    PlanCache,
    RebalancePolicy,
    dist_sqrt_inv_pipeline,
    scatter,
)
from repro.obs import (  # noqa: E402
    EventLog,
    HealthPolicy,
    LocalityLedger,
    MemoryMeter,
    Tracer,
    utilization_table,
    worker_utilization,
    write_chrome_trace,
)

P = 8
BS = 16
TOL, IDEM_TOL, TRUNC_TAU, SPAMM_TAU = 1e-6, 1e-5, 1e-6, 1e-7
OVERHEAD_CAP_PCT = 2.0
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_trace.json")
TRACE_PATH = os.path.join(ROOT, "trace_pipeline.json")


def problem(n: int) -> tuple[BSMatrix, BSMatrix, int]:
    """Banded SPD overlap S + symmetric Hamiltonian H, SP2-ready."""
    rng = np.random.default_rng(11)
    b = np.zeros((n, n), dtype=np.float32)
    h = 12
    for i in range(n):
        lo, hi = max(0, i - h), min(n, i + h + 1)
        b[i, lo:hi] = rng.standard_normal(hi - lo)
    s = (b @ b.T / n + np.eye(n)).astype(np.float32)
    hm = 0.2 * rng.standard_normal((n, n)).astype(np.float32)
    ham = ((hm + hm.T) / 2 + np.diag(np.linspace(-1.0, 1.0, n))).astype(
        np.float32
    )
    return (
        BSMatrix.from_dense(s, BS),
        BSMatrix.from_dense(ham, BS),
        int(0.3 * n),
    )


def run_once(dS, dH, nocc, mesh, cache, tracer=None, log=None, health=None):
    d, st = dist_sqrt_inv_pipeline(
        dS, dH, nocc, mesh, tol=TOL, idem_tol=IDEM_TOL,
        trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU, cache=cache,
        rebalance=RebalancePolicy(), tracer=tracer, log=log, health=health,
    )
    return np.asarray(d.to_dense()), st


def _median_ci(xs: list, conf: float = 0.95) -> tuple:
    """Distribution-free confidence interval for the median.

    Order-statistic (sign-test inversion) bounds: the rank of the median
    among n iid samples is Binomial(n, 1/2), so ``(x_(l), x_(n-1-l))``
    covers the true median with >= ``conf`` regardless of the noise
    distribution — no normality assumption, which per-round overhead
    ratios on a shared host badly violate."""
    s = sorted(xs)
    n = len(s)
    alpha = (1.0 - conf) / 2.0
    cum, lo = 0.0, 0
    for k in range(n + 1):
        cum += math.comb(n, k) * 0.5 ** n
        if cum > alpha:
            lo = k
            break
    hi = n - 1 - lo
    if lo > hi:  # too few samples for the requested confidence
        return s[0], s[-1]
    return s[lo], s[hi]


def full_observatory(sync: bool) -> dict:
    """One repeat's worth of the whole observability stack: tracer +
    in-memory event log + health monitoring + device-memory accounting +
    data-locality ledger."""
    return dict(
        tracer=Tracer(sync=sync),
        log=EventLog(path=None, level="info"),
        health=HealthPolicy(),
        memory=MemoryMeter(),
        locality=LocalityLedger(),
    )


def main() -> None:
    smoke = "--smoke" in sys.argv
    n, repeats, sync_repeats = (128, 2, 2) if smoke else (256, 12, 4)
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)

    s, ham, nocc = problem(n)
    skew = np.zeros(s.nnzb, dtype=np.int32)  # everything on worker 0
    dS = scatter(s, mesh, owner=skew)
    dH = scatter(ham, mesh, owner=np.zeros(ham.nnzb, dtype=np.int32))
    print(f"pipeline: n={n} bs={BS} nnzb(S)={s.nnzb} workers={P} "
          f"(skewed initial layout, rebalancing on)")

    # -- warm the plan cache + compile, untraced reference density ----------
    # sized so the whole pipeline's plan vocabulary fits: at n=256 the run
    # touches ~130+ distinct structures, and the default 128-entry LRU would
    # silently evict — every "warm" repeat would replan from scratch and the
    # overhead measurement would gate on replan noise, not on observability
    cache = PlanCache(max_entries=4096)
    d_ref, _ = run_once(dS, dH, nocc, mesh, cache)
    warm_misses = cache.misses
    _, _ = run_once(dS, dH, nocc, mesh, cache)
    replay_misses = cache.misses - warm_misses
    print(f"plan cache: {warm_misses} builds, replay misses {replay_misses}")
    assert replay_misses == 0, (
        f"warm replay still missed {replay_misses} plans — grow max_entries")

    # -- warm-cache medians: observatory off vs on --------------------------
    # the three arms are interleaved round-robin (direction alternating per
    # round) and the gate takes the median of *per-round paired* overheads:
    # on a shared-CPU container the run-to-run drift (thread-pool
    # contention, frequency scaling) is larger than the observatory cost
    # itself, so back-to-back pairing cancels it where sequential
    # arm-at-a-time medians would gate on whichever arm drew the slow window
    # GC hygiene (pyperf-style): settle the previous sample's garbage
    # outside the timed window and keep the cyclic collector off inside it.
    # A gen-2 pass scans the whole process (jax's tracing caches dominate)
    # and lands in whichever arm's allocations tick the threshold over —
    # the observatory allocates ~10x more objects per run, so without this
    # it gets charged a whole-process scan the bare arm dodges by luck.
    # Allocation and refcount-free cost (the observatory's real footprint)
    # stays inside the measurement.
    def one_run(obs_factory):
        cache.tracer = None
        cache.event_log = None
        cache.memory_meter = None
        cache.locality_ledger = None
        kw = obs_factory() if obs_factory else {}
        mm = kw.pop("memory", None)
        if mm is not None:
            mm.install(cache)
        lld = kw.pop("locality", None)
        if lld is not None:
            lld.install(cache)
        gc.collect()
        gc.disable()
        try:
            c0 = time.process_time()
            t0 = time.perf_counter()
            d, _ = run_once(dS, dH, nocc, mesh, cache, **kw)
            wall = time.perf_counter() - t0
            cpu = time.process_time() - c0
        finally:
            gc.enable()
        assert np.array_equal(d, d_ref), "repeat diverged from reference"
        return wall, cpu

    # the gated bare/observatory arms sample every round (the paired
    # median tightens with N); the sync arm rides the first few rounds only
    arms = (None,
            lambda: full_observatory(sync=False),
            lambda: full_observatory(sync=True))
    walls = ([], [], [])
    max_rounds = repeats if smoke else 4 * repeats
    rounds = 0
    while True:
        idxs = (0, 1, 2) if rounds < sync_repeats else (0, 1)
        for i in (idxs if rounds % 2 == 0 else idxs[::-1]):
            walls[i].append(one_run(arms[i]))
        rounds += 1
        if rounds < repeats:
            continue
        pcts = [(on - off) / off * 100.0
                for (_, off), (_, on) in zip(walls[0], walls[1])]
        ci_lo, ci_hi = _median_ci(pcts)
        if (ci_hi < OVERHEAD_CAP_PCT or ci_lo >= OVERHEAD_CAP_PCT
                or rounds >= max_rounds):
            break
    if rounds > repeats:
        print(f"noisy host: paired-overhead 95% CI straddled the "
              f"{OVERHEAD_CAP_PCT}% cap at n={repeats}, extended sampling "
              f"to n={rounds}")
    off_s, on_s, sync_s = ([w for w, _ in arm] for arm in walls)
    off_c, on_c, sync_c = ([c for _, c in arm] for arm in walls)
    min_off, min_on, min_sync = min(off_s), min(on_s), min(sync_s)
    cmin_off, cmin_on, cmin_sync = min(off_c), min(on_c), min(sync_c)
    # gated statistic: median over rounds of the within-round CPU overhead
    # (both arms of a round see the same machine state, so the ratio
    # cancels run-scale drift the unpaired floors cannot)
    overhead_pct = statistics.median(pcts)
    overhead_sync_pct = statistics.median(
        (s - off) / off * 100.0 for off, s in zip(off_c, sync_c))
    overhead_cpu_min_pct = (cmin_on - cmin_off) / cmin_off * 100.0
    overhead_wall_pct = (min_on - min_off) / min_off * 100.0
    print(f"warm cpu paired median of {rounds}: "
          f"overhead {overhead_pct:+.2f}%  "
          f"(95% CI [{ci_lo:+.2f}%, {ci_hi:+.2f}%];  sync spans "
          f"{overhead_sync_pct:+.2f}%;  unpaired cpu floors bare "
          f"{cmin_off*1e3:.1f} ms / observatory {cmin_on*1e3:.1f} ms, "
          f"{overhead_cpu_min_pct:+.2f}%, unguarded)")
    print(f"warm wall (best of {rounds}): bare {min_off*1e3:.1f} ms  "
          f"observatory {min_on*1e3:.1f} ms  ({overhead_wall_pct:+.2f}%, "
          f"unguarded)  bit-identical: True")
    print("cpu samples bare: " + " ".join(f"{c:.3f}" for c in sorted(off_c)))
    print("cpu samples obs:  " + " ".join(f"{c:.3f}" for c in sorted(on_c)))

    # -- cold observed run -> exported trace + utilization/memory report ----
    tracer = Tracer()
    log = EventLog(path=None, level="info")
    mm = MemoryMeter()
    cold_cache = PlanCache(tracer=tracer, event_log=log)
    mm.install(cold_cache)
    lld = LocalityLedger().install(cold_cache)
    d_cold, st = run_once(dS, dH, nocc, mesh, cold_cache, tracer=tracer,
                          log=log, health=HealthPolicy())
    assert np.array_equal(d_cold, d_ref), "cold traced run diverged"
    mm.flush(tracer)  # per-worker peak gauges -> trace counter track
    summary = write_chrome_trace(tracer, TRACE_PATH)
    util = worker_utilization(tracer)
    print(f"\nwrote {os.path.abspath(TRACE_PATH)} "
          f"({summary['events']} events, {summary['host_spans']} host spans, "
          f"{summary['workers']} worker tracks)")
    print(utilization_table(util, memory=mm.worker_peak()))
    loc = lld.summary()
    print(f"locality: {loc['locality_flops'] * 100:.1f}% of flops / "
          f"{loc['locality_bytes'] * 100:.1f}% of bytes read locally; "
          f"wire {loc['wire_recv_bytes'] / 1e6:.2f} MB over "
          f"{loc['dispatches']} dispatches")

    cats: dict[str, int] = {}
    for sp in tracer.spans:
        cats[sp.cat or "?"] = cats.get(sp.cat or "?", 0) + 1
    imbs = [pi["imbalance"] for pi in
            st.purify.per_iter + st.inverse.per_iter
            if pi.get("imbalance") is not None]

    events_by_kind: dict[str, int] = {}
    for rec in log.recent:
        events_by_kind[rec["event"]] = events_by_kind.get(rec["event"], 0) + 1
    health_summaries = {
        name: stats.health
        for name, stats in (("inverse", st.inverse), ("purify", st.purify))
        if getattr(stats, "health", None) is not None
    }

    payload = dict(
        meta=dict(n=n, bs=BS, workers=P, smoke=smoke, repeats=repeats,
                  repeats_run=rounds,
                  tol=TOL, idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU,
                  spamm_tau=SPAMM_TAU, overhead_cap_pct=OVERHEAD_CAP_PCT,
                  observatory=True,
                  initial_layout="all blocks on worker 0"),
        overhead=dict(
            untraced_s=[float(t) for t in off_s],
            traced_s=[float(t) for t in on_s],
            traced_sync_s=[float(t) for t in sync_s],
            untraced_cpu_s=[float(t) for t in off_c],
            traced_cpu_s=[float(t) for t in on_c],
            traced_sync_cpu_s=[float(t) for t in sync_c],
            min_untraced_s=float(min_off),
            min_traced_s=float(min_on),
            min_traced_sync_s=float(min_sync),
            min_untraced_cpu_s=float(cmin_off),
            min_traced_cpu_s=float(cmin_on),
            min_traced_sync_cpu_s=float(cmin_sync),
            overhead_pct=float(overhead_pct),
            overhead_ci_pct=[float(ci_lo), float(ci_hi)],
            overhead_sync_pct=float(overhead_sync_pct),
            overhead_cpu_min_pct=float(overhead_cpu_min_pct),
            overhead_wall_pct=float(overhead_wall_pct),
            bit_identical=True,
        ),
        trace=dict(path=os.path.basename(TRACE_PATH), summary=summary,
                   spans_by_cat=cats, counter_totals=tracer.metrics_flat()),
        utilization=util,
        observatory=dict(
            events_by_kind=events_by_kind,
            health=health_summaries,
            memory=mm.summary(),
            locality=lld.summary(),
        ),
        per_iter_imbalance_mean=float(np.mean(imbs)) if imbs else None,
        per_iter_imbalance_max=float(np.max(imbs)) if imbs else None,
    )
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")

    # gate last so a failing run still leaves the full sample arrays,
    # trace, and report on disk for diagnosis
    if not smoke:
        assert overhead_pct < OVERHEAD_CAP_PCT, (
            f"observatory overhead {overhead_pct:.2f}% "
            f"(95% CI [{ci_lo:+.2f}%, {ci_hi:+.2f}%] over {rounds} paired "
            f"rounds) exceeds {OVERHEAD_CAP_PCT}% cap")


if __name__ == "__main__":
    main()
