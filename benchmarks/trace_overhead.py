"""Tracing overhead + utilization cap for the full resident pipeline.

Runs ``dist_sqrt_inv_pipeline`` (S -> Z -> Z^T H Z -> SP2 -> Z D Z^T) on an
8-worker CPU mesh from a deliberately skewed initial layout (so re-layout
migrations appear in the trace), three ways:

* warm-cache repeats with tracing **off** (the pre-PR fast path);
* warm-cache repeats with tracing **on** (fresh ``Tracer(sync=False)`` per
  repeat on the same plan cache) — the overhead gate: median traced vs
  untraced wall time must stay under the acceptance cap, and the density
  matrix must be **bit-identical** either way.  ``sync=False`` measures the
  recording machinery itself; ``Tracer(sync=True)`` additionally blocks on
  device values inside dispatch spans so span durations measure execution
  rather than async dispatch — that serializes the host/device overlap the
  untraced path enjoys, so its (larger) cost is reported separately as
  ``overhead_sync_pct``, not gated;
* one **cold** traced run (``sync=True``, execution-true spans) on a fresh
  cache, so the exported Chrome trace also carries the plan-build spans,
  and the per-worker utilization report is derived from it.

Results go to ``BENCH_trace.json`` at the repo root (overhead %, span
counts by category, counters, per-worker busy/idle fractions, timeline
imbalance vs the per-iteration cost-model imbalance); the Perfetto-loadable
trace itself is written next to it as ``trace_pipeline.json``.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       PYTHONPATH=src python benchmarks/trace_overhead.py [--smoke]
"""

from __future__ import annotations

import json
import os
import statistics
import sys
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import (  # noqa: E402
    PlanCache,
    RebalancePolicy,
    dist_sqrt_inv_pipeline,
    scatter,
)
from repro.obs import (  # noqa: E402
    Tracer,
    utilization_table,
    worker_utilization,
    write_chrome_trace,
)

P = 8
BS = 16
TOL, IDEM_TOL, TRUNC_TAU, SPAMM_TAU = 1e-6, 1e-5, 1e-6, 1e-7
OVERHEAD_CAP_PCT = 2.0
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_trace.json")
TRACE_PATH = os.path.join(ROOT, "trace_pipeline.json")


def problem(n: int) -> tuple[BSMatrix, BSMatrix, int]:
    """Banded SPD overlap S + symmetric Hamiltonian H, SP2-ready."""
    rng = np.random.default_rng(11)
    b = np.zeros((n, n), dtype=np.float32)
    h = 12
    for i in range(n):
        lo, hi = max(0, i - h), min(n, i + h + 1)
        b[i, lo:hi] = rng.standard_normal(hi - lo)
    s = (b @ b.T / n + np.eye(n)).astype(np.float32)
    hm = 0.2 * rng.standard_normal((n, n)).astype(np.float32)
    ham = ((hm + hm.T) / 2 + np.diag(np.linspace(-1.0, 1.0, n))).astype(
        np.float32
    )
    return (
        BSMatrix.from_dense(s, BS),
        BSMatrix.from_dense(ham, BS),
        int(0.3 * n),
    )


def run_once(dS, dH, nocc, mesh, cache, tracer=None):
    d, st = dist_sqrt_inv_pipeline(
        dS, dH, nocc, mesh, tol=TOL, idem_tol=IDEM_TOL,
        trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU, cache=cache,
        rebalance=RebalancePolicy(), tracer=tracer,
    )
    return np.asarray(d.to_dense()), st


def main() -> None:
    smoke = "--smoke" in sys.argv
    n, repeats = (128, 2) if smoke else (256, 5)
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)

    s, ham, nocc = problem(n)
    skew = np.zeros(s.nnzb, dtype=np.int32)  # everything on worker 0
    dS = scatter(s, mesh, owner=skew)
    dH = scatter(ham, mesh, owner=np.zeros(ham.nnzb, dtype=np.int32))
    print(f"pipeline: n={n} bs={BS} nnzb(S)={s.nnzb} workers={P} "
          f"(skewed initial layout, rebalancing on)")

    # -- warm the plan cache + compile, untraced reference density ----------
    cache = PlanCache()
    d_ref, _ = run_once(dS, dH, nocc, mesh, cache)

    # -- warm-cache medians: tracing off vs on ------------------------------
    def timed_runs(tracer_factory):
        walls = []
        for _ in range(repeats):
            cache.tracer = None
            t0 = time.perf_counter()
            d, _ = run_once(dS, dH, nocc, mesh, cache,
                            tracer=tracer_factory() if tracer_factory else None)
            walls.append(time.perf_counter() - t0)
            assert np.array_equal(d, d_ref), "repeat diverged from reference"
        return walls

    off_s = timed_runs(None)
    on_s = timed_runs(lambda: Tracer(sync=False))
    sync_s = timed_runs(lambda: Tracer(sync=True))
    med_off = statistics.median(off_s)
    med_on = statistics.median(on_s)
    med_sync = statistics.median(sync_s)
    overhead_pct = (med_on - med_off) / med_off * 100.0
    overhead_sync_pct = (med_sync - med_off) / med_off * 100.0
    print(f"warm wall: untraced {med_off*1e3:.1f} ms  "
          f"traced {med_on*1e3:.1f} ms  overhead {overhead_pct:+.2f}%  "
          f"(sync spans {med_sync*1e3:.1f} ms, {overhead_sync_pct:+.2f}%)  "
          f"bit-identical: True")
    if not smoke:
        assert overhead_pct < OVERHEAD_CAP_PCT, (
            f"tracing overhead {overhead_pct:.2f}% exceeds "
            f"{OVERHEAD_CAP_PCT}% cap")

    # -- cold traced run -> exported trace + utilization report -------------
    tracer = Tracer()
    d_cold, st = run_once(dS, dH, nocc, mesh, PlanCache(tracer=tracer),
                          tracer=tracer)
    assert np.array_equal(d_cold, d_ref), "cold traced run diverged"
    summary = write_chrome_trace(tracer, TRACE_PATH)
    util = worker_utilization(tracer)
    print(f"\nwrote {os.path.abspath(TRACE_PATH)} "
          f"({summary['events']} events, {summary['host_spans']} host spans, "
          f"{summary['workers']} worker tracks)")
    print(utilization_table(util))

    cats: dict[str, int] = {}
    for sp in tracer.spans:
        cats[sp.cat or "?"] = cats.get(sp.cat or "?", 0) + 1
    imbs = [pi["imbalance"] for pi in
            st.purify.per_iter + st.inverse.per_iter
            if pi.get("imbalance") is not None]

    payload = dict(
        meta=dict(n=n, bs=BS, workers=P, smoke=smoke, repeats=repeats,
                  tol=TOL, idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU,
                  spamm_tau=SPAMM_TAU, overhead_cap_pct=OVERHEAD_CAP_PCT,
                  initial_layout="all blocks on worker 0"),
        overhead=dict(
            untraced_s=[float(t) for t in off_s],
            traced_s=[float(t) for t in on_s],
            traced_sync_s=[float(t) for t in sync_s],
            median_untraced_s=float(med_off),
            median_traced_s=float(med_on),
            median_traced_sync_s=float(med_sync),
            overhead_pct=float(overhead_pct),
            overhead_sync_pct=float(overhead_sync_pct),
            bit_identical=True,
        ),
        trace=dict(path=os.path.basename(TRACE_PATH), summary=summary,
                   spans_by_cat=cats, counter_totals=tracer.metrics_flat()),
        utilization=util,
        per_iter_imbalance_mean=float(np.mean(imbs)) if imbs else None,
        per_iter_imbalance_max=float(np.max(imbs)) if imbs else None,
    )
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
