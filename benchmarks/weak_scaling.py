"""Paper Table 1 + Figure 1 reproduction (weak scaling of sparse A*A).

Three matrix families at the paper's exact sizes (1e5 .. 6.4e6):

* Table 1 Tflop column: reproduced ANALYTICALLY from the element-level
  structure (multiplies = sum_k col_nnz(k) * row_nnz(k); flops = 2x) — no
  matrices are materialized, so the full 6.4e6 sizes run on a laptop.
* Fig 1c (data received per worker): reproduced STRUCTURALLY — the exchange
  plans of the locality-aware schedule vs the allgather baseline are built at
  the paper's block granularity (leaf 2048) and their per-worker receive
  bytes reported for 2..128 workers.
* Fig 1a/b (wall time / efficiency): measured at reduced scale on CPU with
  the same weak-scaling protocol (flops per worker held constant), plus the
  structural roofline estimate at paper scale.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BSMatrix, multiply
from repro.core.schedule import make_spgemm_plan, plan_stats
from repro.core.spgemm import spgemm_symbolic

BANDW = 3000  # paper: bandwidth 2*3000 + 1
LEAF = 2048  # paper leaf matrix dimension

# paper Table 1
SIZES = [100_000, 200_000, 400_000, 800_000, 1_600_000, 3_200_000, 6_400_000]
WORKERS = [2, 4, 8, 16, 32, 64, 128]
PAPER_TFLOP_BANDED = [7.022, 14.22, 28.63, 57.44, 115.1, 230.3, 460.8]
PAPER_TFLOP_BLOCKED = [14.04, 28.45, 57.26, 114.9, 230.1, 460.6, 921.6]
GROWING_BLOCK_SIZE = [15716, 19652, 24621, 30899, 38825, 48828, 61446]
RANDOM_BLOCK_SIZE = [15716, 15705, 15700, 15697, 15696, 15695, 15695]
RANDOM_BLOCK_NUM = [1, 2, 4, 8, 16, 32, 64]


# ---------------------------------------------------------------------------
# Table 1: analytic flop counts from element-level structure
# ---------------------------------------------------------------------------


def _band_counts(n: int, h: int) -> np.ndarray:
    k = np.arange(n, dtype=np.int64)
    return np.minimum(n - 1, k + h) - np.maximum(0, k - h) + 1


def banded_flops(n: int, h: int = BANDW) -> float:
    c = _band_counts(n, h).astype(np.float64)
    return float(2.0 * np.sum(c * c))  # A is symmetric in structure: rows == cols


def growing_block_flops(n: int, s: int, h: int = BANDW) -> float:
    c = _band_counts(n, h).astype(np.float64)
    k = np.arange(n, dtype=np.int64)
    # dense corner block [0,s) x [0,s): column k < s gains (s - overlap with band)
    overlap = np.where(
        k < s, np.minimum(s - 1, k + h) - np.maximum(0, k - h) + 1, 0
    ).astype(np.float64)
    extra = np.where(k < s, s - overlap, 0.0)
    tot = c + extra
    return float(2.0 * np.sum(tot * tot))


def random_blocks_flops(n: int, s: int, nblocks: int, h: int = BANDW, seed=0) -> float:
    c = _band_counts(n, h).astype(np.float64)
    starts = _random_block_starts(n, s, nblocks, seed)
    k = np.arange(n, dtype=np.int64)
    extra = np.zeros(n, dtype=np.float64)
    for st in starts:
        kk = k[st : st + s]
        overlap = np.minimum(st + s - 1, kk + h) - np.maximum(st, kk - h) + 1
        extra[st : st + s] = s - np.maximum(overlap, 0)
    tot = c + extra
    return float(2.0 * np.sum(tot * tot))


def _random_block_starts(n, s, nblocks, seed=0):
    """Non-overlapping blocks at random diagonal positions (paper setup)."""
    rng = np.random.default_rng(seed)
    slots = n - s * nblocks
    gaps = rng.multinomial(slots, np.ones(nblocks + 1) / (nblocks + 1))
    starts, pos = [], 0
    for i in range(nblocks):
        pos += gaps[i]
        starts.append(pos)
        pos += s
    return starts


def table1() -> list[dict]:
    rows = []
    for i, n in enumerate(SIZES):
        banded = banded_flops(n)
        growing = growing_block_flops(n, GROWING_BLOCK_SIZE[i])
        rnd = random_blocks_flops(n, RANDOM_BLOCK_SIZE[i], RANDOM_BLOCK_NUM[i])
        rows.append(
            dict(
                n=n,
                workers=WORKERS[i],
                banded_tflop=banded / 1e12,
                paper_banded=PAPER_TFLOP_BANDED[i],
                growing_tflop=growing / 1e12,
                random_tflop=rnd / 1e12,
                paper_blocked=PAPER_TFLOP_BLOCKED[i],
            )
        )
    return rows


# ---------------------------------------------------------------------------
# structural matrices at paper block granularity (for comm / task analysis)
# ---------------------------------------------------------------------------


def _band_block_coords(nb: int, hw_blocks: int) -> np.ndarray:
    i = np.arange(nb)
    rows, cols = [], []
    for d in range(-hw_blocks, hw_blocks + 1):
        j = i + d
        m = (j >= 0) & (j < nb)
        rows.append(i[m])
        cols.append(j[m])
    from repro.core.quadtree import morton_sort

    coords = np.stack([np.concatenate(rows), np.concatenate(cols)], 1)
    return coords[morton_sort(coords)]


def structure_coords(family: str, n: int, idx: int, bs: int = LEAF) -> np.ndarray:
    """Block coordinates of each family at the paper's scale."""
    nb = -(-n // bs)
    hw = -(-BANDW // bs)
    band = _band_block_coords(nb, hw)
    keys = {tuple(x) for x in band.tolist()}
    extra = []
    if family == "banded":
        pass
    elif family == "growing":
        sb = -(-GROWING_BLOCK_SIZE[idx] // bs)
        for i in range(sb):
            for j in range(sb):
                if (i, j) not in keys:
                    extra.append((i, j))
    elif family == "random":
        s = RANDOM_BLOCK_SIZE[idx]
        sb = -(-s // bs)
        for st in _random_block_starts(n, s, RANDOM_BLOCK_NUM[idx]):
            b0 = st // bs
            for i in range(b0, min(b0 + sb + 1, nb)):
                for j in range(b0, min(b0 + sb + 1, nb)):
                    if (i, j) not in keys:
                        extra.append((i, j))
    else:
        raise ValueError(family)
    if extra:
        coords = np.concatenate([band, np.array(extra, dtype=np.int64)])
        from repro.core.quadtree import morton_sort

        return coords[morton_sort(coords)]
    return band


def fig1c(max_idx: int = 7, include_outer: bool = True) -> list[dict]:
    """Data received per worker: locality schedule vs baselines, paper scale.

    include_outer also plans the outer-product schedule (the paper's §5
    future work) — the structure-adaptive chooser takes the cheaper one.
    """
    from repro.core.outer import make_outer_plan, plan_outer_stats

    rows = []
    for i in range(max_idx):
        n, P = SIZES[i], WORKERS[i]
        for family in ("banded", "growing", "random"):
            coords = structure_coords(family, n, i)
            tasks = spgemm_symbolic(coords, coords)
            loc = plan_stats(
                make_spgemm_plan(coords, coords, P, LEAF, placement="morton", tasks=tasks)
            )
            ag = plan_stats(
                make_spgemm_plan(
                    coords, coords, P, LEAF, placement="random", exchange="allgather", tasks=tasks
                )
            )
            row = dict(
                family=family,
                n=n,
                workers=P,
                nnzb=len(coords),
                tasks=tasks.num_tasks,
                locality_recv_mb=loc["recv_bytes_mean"] / 2**20 * 2,  # fp64 (paper)
                allgather_recv_mb=ag["recv_bytes_mean"] / 2**20 * 2,
                balance=loc["task_balance"],
            )
            if include_outer:
                op = plan_outer_stats(make_outer_plan(coords, coords, P, LEAF, tasks=tasks))
                row["outer_recv_mb"] = op["recv_bytes_mean"] / 2**20 * 2
                row["outer_balance"] = op["task_balance"]
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Fig 1a at reduced scale: measured weak scaling on CPU
# ---------------------------------------------------------------------------


def measured_weak_scaling(base_n: int = 2048, bs: int = 128, reps: int = 3) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    h = bs  # reduced bandwidth
    for scale in (1, 2, 4):
        n = base_n * scale
        nb = n // bs
        coords = _band_block_coords(nb, 1)
        data = rng.standard_normal((len(coords), bs, bs)).astype(np.float32)
        import jax.numpy as jnp

        a = BSMatrix(shape=(n, n), bs=bs, coords=coords, data=jnp.asarray(data))
        multiply(a, a).data.block_until_ready()  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            multiply(a, a).data.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        tasks = spgemm_symbolic(coords, coords)
        flops = 2.0 * tasks.num_tasks * bs**3
        rows.append(
            dict(
                n=n,
                nnzb=len(coords),
                tasks=tasks.num_tasks,
                wall_s=dt,
                gflops=flops / dt / 1e9,
            )
        )
    return rows
