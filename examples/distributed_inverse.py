"""Distributed inverse factorization pipeline on a worker mesh, end to end.

The paper's full electronic-structure workflow on the resident runtime
(repro.dist): overlap matrix S enters the mesh once, the localized inverse
factorization (Z^T S Z = I) refines through delta-plan SpAMM + hierarchical
truncation, the congruence transform Z^T H Z and the SP2 purification chain
on resident matrices, and the density matrix leaves at the single boundary
gather — S -> Z -> Z^T H Z -> SP2 -> Z D Z^T without the devices ever
re-shipping operands.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_inverse.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix, localized_inverse_factorization, multiply, sp2_purify  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import PlanCache, dist_sqrt_inv_pipeline  # noqa: E402

P = 8
N, BS, NOCC = 128, 16, 40
TOL, IDEM_TOL, TRUNC_TAU, SPAMM_TAU = 1e-6, 1e-5, 1e-6, 1e-7

assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"

# banded SPD overlap matrix + symmetric Hamiltonian with a spectral gap
rng = np.random.default_rng(7)
b = np.zeros((N, N), dtype=np.float32)
for i in range(N):
    lo, hi = max(0, i - 3), min(N, i + 4)
    b[i, lo:hi] = rng.standard_normal(hi - lo)
s_dense = b @ b.T + N * np.eye(N, dtype=np.float32)
S = BSMatrix.from_dense(s_dense, BS)
hm = np.zeros((N, N), dtype=np.float32)
for i in range(N):
    lo, hi = max(0, i - 4), min(N, i + 5)
    hm[i, lo:hi] = 0.2 * rng.standard_normal(hi - lo)
H = BSMatrix.from_dense((hm + hm.T) / 2 + np.diag(np.linspace(-1, 1, N)).astype(np.float32), BS)
print(f"S: n={N} bs={BS} nnzb={S.nnzb}  H: nnzb={H.nnzb}  mesh={P}")

mesh = make_worker_mesh(P)
# verify="always" re-proves every plan on hits too — the CI smoke run
# doubles as the static verifier's end-to-end exercise on real plans
cache = PlanCache(verify="always")
D, stats = dist_sqrt_inv_pipeline(
    S, H, NOCC, mesh, tol=TOL, idem_tol=IDEM_TOL,
    trunc_tau=TRUNC_TAU, spamm_tau=SPAMM_TAU, cache=cache,
)

inv = stats.inverse
print(f"\ninverse factor:  {inv.iterations} refinement iterations, "
      f"residual {inv.factorization_residual:.2e}")
print(f"SP2 bounds from resident norm table: [{stats.bounds[0]:.3f}, {stats.bounds[1]:.3f}]")
print(f"purification:    {stats.purify.iterations} iterations")
print(f"congruence:      {stats.congruence['cache_hits']}h/"
      f"{stats.congruence['cache_misses']}m in {stats.congruence['wall_s']*1e3:.1f} ms")
tail = inv.per_iter[-3:]
print("refinement tail: "
      + ", ".join(f"{pi['cache_hits']}h/{pi['cache_misses']}m" for pi in tail))

c = stats.cache
print(f"plan cache:      {c['hits']} hits / {c['misses']} misses "
      f"(hit rate {c['hit_rate']:.2f})")
print(f"static verifier: {c['plans_verified']} plans proved, "
      f"{c['verify_violations']} violations in {c['verify_s']*1e3:.1f} ms")
assert c["plans_verified"] > 0 and c["verify_violations"] == 0

# cross-check against the host pipeline
z, _ = localized_inverse_factorization(S, tol=TOL, trunc_tau=TRUNC_TAU, impl="ref")
f_o = multiply(multiply(z.transpose(), H, impl="ref"), z, impl="ref")
w = np.linalg.eigvalsh(np.asarray(f_o.to_dense(), np.float64))
d_o, _ = sp2_purify(f_o, NOCC, float(w.min()) - 0.05, float(w.max()) + 0.05,
                    idem_tol=IDEM_TOL, trunc_tau=TRUNC_TAU, impl="ref")
d_host = multiply(multiply(z, d_o, impl="ref"), z.transpose(), impl="ref")
err = np.abs(D.to_dense() - d_host.to_dense()).max()
tr = multiply(D, S, impl="ref").trace()
print(f"\nmax |D_dist - D_host| = {err:.2e}")
print(f"trace(D S) = {tr:.3f}  (n_occ = {NOCC})")
assert err < 1e-3
assert abs(tr - NOCC) < 0.05
print("OK")
