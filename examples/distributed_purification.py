"""Distributed density-matrix purification on a worker mesh, end to end.

The full iterative SP2 loop on device-resident matrices (repro.dist): the
Hamiltonian is scattered to the mesh once, every iterate (multiply, add,
trace, Frobenius norm, truncate) stays sharded across the workers, and the
structure-keyed PlanCache makes iterations on a stationary sparsity pattern
pure device work — the CHT chunk-cache behaviour of the paper, on XLA.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_purification.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix, multiply, sp2_purify  # noqa: E402
from repro.core.distributed import make_worker_mesh  # noqa: E402
from repro.dist import PlanCache, dist_sp2_purify  # noqa: E402

P = 8
N, BS, NOCC = 512, 32, 160

assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"

# banded Hamiltonian with decaying off-diagonals + spectral gap
rng = np.random.default_rng(7)
h = np.zeros((N, N), dtype=np.float32)
for i in range(N):
    lo, hi = max(0, i - 6), min(N, i + 7)
    h[i, lo:hi] = 0.2 * rng.standard_normal(hi - lo)
h = (h + h.T) / 2 + np.diag(np.linspace(-2.0, 2.0, N))
f = BSMatrix.from_dense(h, BS)
w = np.linalg.eigvalsh(h.astype(np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
print(f"F: n={N} bs={BS} nnzb={f.nnzb}  spec=[{lmin:.2f}, {lmax:.2f}]  mesh={P}")

mesh = make_worker_mesh(P)
cache = PlanCache()
d, stats = dist_sp2_purify(
    f, NOCC, lmin, lmax, mesh, idem_tol=1e-5, trunc_tau=1e-5, cache=cache
)

print(f"\nconverged in {stats.iterations} iterations")
print(f"trace(D) = {d.trace():.3f}  (n_occ = {NOCC})")
idem = np.abs(multiply(d, d).to_dense() - d.to_dense()).max()
print(f"max |D^2 - D| = {idem:.2e}  (idempotency)")

c = stats.cache
print(f"\nplan cache: {c['hits']} hits / {c['misses']} misses over "
      f"{stats.iterations} iterations")
all_hit = sum(1 for pi in stats.per_iter if pi["cache_misses"] == 0)
warm = [pi["wall_s"] for pi in stats.per_iter if pi["cache_misses"] == 0]
cold = [pi["wall_s"] for pi in stats.per_iter if pi["cache_misses"] > 0]
if warm and cold:
    print(f"{all_hit} iterations ran with zero planning/compilation: "
          f"{np.mean(warm)*1e3:.1f} ms vs {np.mean(cold)*1e3:.1f} ms "
          f"({np.mean(cold)/np.mean(warm):.0f}x)")
print("\nper-iteration (last 5):")
for pi in stats.per_iter[-5:]:
    print(f"  it={pi['iteration']:3d} nnzb={pi['nnzb']:4d} idem={pi['idem']:.2e} "
          f"hits={pi['cache_hits']} misses={pi['cache_misses']} "
          f"wall={pi['wall_s']*1e3:6.1f} ms")

# cross-check against the single-host driver
d_ref, _ = sp2_purify(f, NOCC, lmin, lmax, idem_tol=1e-5, trunc_tau=1e-5, impl="ref")
err = np.abs(d.to_dense() - d_ref.to_dense()).max()
print(f"\nmax |D_dist - D_host| = {err:.2e}")
assert err < 1e-4
