"""Distributed sparse matrix-matrix multiply — the paper's headline demo.

Runs the weak-scaling protocol from the paper (banded / growing block /
random blocks) at reduced scale on 8 simulated workers, executing the real
shard_map program, and reports the Fig-1 quantities: load balance and data
received per worker, locality-aware schedule vs allgather baseline.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_spgemm.py
"""

import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BSMatrix, multiply  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    dist_spgemm,
    make_worker_mesh,
    unshard_result,
)
from repro.core.schedule import make_spgemm_plan, plan_stats  # noqa: E402

P = 8
N, BS, HW = 1024, 64, 96
rng = np.random.default_rng(0)


def banded(n):
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - HW), min(n, i + HW + 1)
        a[i, lo:hi] = rng.standard_normal(hi - lo)
    return a


def growing(n):
    a = banded(n)
    s = n // 4
    a[:s, :s] = rng.standard_normal((s, s))
    return a


def random_blocks(n):
    a = banded(n)
    s = n // 16
    for start in rng.choice(n // s - 1, size=4, replace=False) * s:
        a[start : start + s, start : start + s] = rng.standard_normal((s, s))
    return a


def main():
    assert jax.device_count() == P, f"need {P} devices, got {jax.device_count()}"
    mesh = make_worker_mesh(P)
    print(f"workers: {P} | matrix {N}x{N}, leaf {BS}, band halfwidth {HW}\n")
    print(f"{'family':<14} {'schedule':<22} {'err':>9} {'balance':>8} {'recv/worker':>12}")
    for family, builder in [
        ("banded", banded),
        ("growing_block", growing),
        ("random_blocks", random_blocks),
    ]:
        a = BSMatrix.from_dense(builder(N), BS)
        ref = multiply(a, a).to_dense()
        for placement, exchange in [("morton", "p2p"), ("random", "p2p"), ("morton", "allgather")]:
            plan = make_spgemm_plan(
                a.coords, a.coords, P, BS, placement=placement, exchange=exchange
            )
            out = dist_spgemm(plan, a.data, a.data, mesh, impl="ref")
            c = unshard_result(plan, out, a.shape, BS)
            err = np.abs(c.to_dense() - ref).max()
            st = plan_stats(plan)
            print(
                f"{family:<14} {placement + '/' + exchange:<22} {err:9.2e} "
                f"{st['task_balance']:8.2f} {st['recv_bytes_mean']/2**20:10.2f} MiB"
            )
        print()
    print("locality-aware schedule: same flops, balanced, least data movement —")
    print("the paper's Fig 1 claims, executed as a real SPMD program.")


if __name__ == "__main__":
    main()
