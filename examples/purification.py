"""Density-matrix purification — the paper's driving application, end to end.

Electronic-structure workflow (the reason this library exists, paper §4):
  1. build a sparse "Fock" matrix F with banded structure + decay,
  2. inverse-factorize the overlap S (congruence to orthogonal basis),
  3. SP2 purification: D = theta(mu I - F) via repeated sparse A@A,
  4. truncation keeps every iterate sparse with controlled error.

Run:  PYTHONPATH=src python examples/purification.py
"""

import numpy as np

from repro.core import (
    BSMatrix,
    factorization_residual,
    inv_chol,
    multiply,
    sp2_purify,
)

rng = np.random.default_rng(7)
n, bs, nocc = 512, 32, 160

# 1) banded Hamiltonian with decaying off-diagonals + spectral gap
h = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    for j in range(max(0, i - 8), min(n, i + 9)):
        h[i, j] = 0.3 * np.exp(-0.5 * abs(i - j)) * rng.standard_normal()
h = (h + h.T) / 2 + np.diag(np.linspace(-2.0, 2.0, n))
f = BSMatrix.from_dense(h, bs)
print(f"F: {f.shape}, {f.nnzb}/{f.nblocks[0]**2} blocks")

# 2) overlap-like SPD matrix and its inverse Cholesky (Z^T S Z = I)
s_dense = np.eye(n, dtype=np.float32) + 0.01 * np.abs(h)
s = BSMatrix.from_dense(s_dense, bs)
z = inv_chol(s)
print(f"inv_chol(S): residual = {factorization_residual(s, z):.2e}")

# 3) transform F to orthogonal basis: F_o = Z^T F Z (two sparse multiplies)
f_o = multiply(multiply(z.transpose(), f), z)

# 4) SP2 purification with truncation
w = np.linalg.eigvalsh(np.asarray(f_o.to_dense(), dtype=np.float64))
d, stats = sp2_purify(
    f_o, nocc, float(w.min()) - 0.05, float(w.max()) + 0.05,
    idem_tol=1e-6, trunc_tau=1e-5,
)
ev = np.linalg.eigh(np.asarray(f_o.to_dense(), dtype=np.float64))
d_ref = ev.eigenvectors[:, :nocc] @ ev.eigenvectors[:, :nocc].T
print(f"SP2: {stats.iterations} iterations")
print(f"     trace(D) = {d.trace():.3f} (target {nocc})")
print(f"     max |D - D_ref| = {np.abs(d.to_dense() - d_ref).max():.2e}")
print(f"     density-matrix sparsity: {d.nnzb}/{d.nblocks[0]**2} blocks")
print(f"     idempotency history: "
      + " ".join(f"{x:.1e}" for x in stats.idempotency_history[:8]) + " ...")
