"""Quickstart: the Chunks-and-Tasks matrix library public API in 60 lines.

Builds a block-sparse banded matrix, multiplies, truncates, factorizes —
every operation the paper's library exposes — then plans the distributed
multiply and prints the locality win.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    BSMatrix,
    add_scaled_identity,
    factorization_residual,
    inv_chol,
    multiply,
    spamm,
    truncate,
)
from repro.core.schedule import make_spgemm_plan, plan_stats

# 1) construct a block-sparse matrix (banded + random values)
rng = np.random.default_rng(0)
n, bs, halfwidth = 1024, 64, 96
dense = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - halfwidth), min(n, i + halfwidth + 1)
    decay = np.exp(-0.05 * np.abs(np.arange(lo, hi) - i))  # magnitude decay
    dense[i, lo:hi] = rng.standard_normal(hi - lo) * decay / np.sqrt(halfwidth)
a = BSMatrix.from_dense(dense, bs)
print(f"A: {a.shape} blocks={a.nnzb}/{a.nblocks[0]**2} (zero branches pruned)")

# 2) multiply (symbolic quadtree join on host + grouped GEMM on device)
c = multiply(a, a)
err = np.abs(c.to_dense() - dense @ dense).max()
print(f"A@A: blocks={c.nnzb}, max err vs dense = {err:.2e}")

# 3) sparse approximate multiply with error bound (SpAMM)
tau = 0.05 * np.linalg.norm(dense @ dense)
c_approx, bound = spamm(a, a, tau=tau)
true_err = np.linalg.norm(c_approx.to_dense() - dense @ dense)
print(f"SpAMM(tau={tau:.2f}): {c.nnzb - c_approx.nnzb} output blocks pruned, "
      f"||err||_F = {true_err:.2e} <= bound {bound:.2e} <= tau")

# 4) truncation with global error control
t = truncate(c, tau=0.5)
print(f"truncate(C, 0.5): {c.nnzb} -> {t.nnzb} blocks, "
      f"||C - T||_F = {np.linalg.norm(c.to_dense() - t.to_dense()):.2e} <= 0.5")

# 5) inverse Cholesky of an SPD shift (Z^T A Z = I)
spd = add_scaled_identity(multiply(a, a.transpose()), 4.0)
z = inv_chol(spd)
print(f"inv_chol residual ||I - Z^T A Z||_F = {factorization_residual(spd, z):.2e}")

# 6) distributed schedule: locality-aware vs allgather baseline (8 workers)
for placement, exchange in [("morton", "p2p"), ("random", "p2p")]:
    plan = make_spgemm_plan(a.coords, a.coords, 8, bs, placement=placement, exchange=exchange)
    st = plan_stats(plan)
    print(f"schedule {placement:6s}/{exchange}: balance={st['task_balance']:.2f} "
          f"recv/worker={st['recv_bytes_mean']/2**20:.2f} MiB")
plan = make_spgemm_plan(a.coords, a.coords, 8, bs, exchange="allgather")
print(f"schedule allgather baseline: recv/worker="
      f"{plan_stats(plan)['recv_bytes_mean']/2**20:.2f} MiB")
