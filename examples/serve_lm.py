"""Batched serving example: greedy decode with KV cache (reduced qwen2).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.launch.serve import generate
from repro.models import transformer


def main():
    cfg = reduced_config("qwen2-0.5b")
    params, _ = transformer.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    B, P, G = 4, 8, 24
    prompts = rng.integers(0, cfg.vocab_size, (B, P)).astype(np.int32)

    t0 = time.perf_counter()
    seqs = generate(cfg, params, prompts, G)
    dt = time.perf_counter() - t0
    assert seqs.shape == (B, P + G)
    assert (seqs[:, :P] == prompts).all(), "prompt must be preserved"
    print(f"generated {B}x{P + G} tokens in {dt:.2f}s (incl. compile)")
    for i, s in enumerate(seqs[:2]):
        print(f"seq {i}: prompt={s[:P].tolist()} -> gen={s[P:].tolist()}")

    # hybrid (recurrent + local attention) serving exercises state caches
    cfg2 = reduced_config("recurrentgemma-9b")
    params2, _ = transformer.init_params(jax.random.key(1), cfg2)
    seqs2 = generate(cfg2, params2, prompts[:2], 8)
    print(f"recurrentgemma reduced decode ok: {seqs2.shape}")


if __name__ == "__main__":
    main()
