"""End-to-end LM training with checkpoint/restart (reduced olmo-1b family).

Trains a ~1-2M-param reduced config for a few hundred steps on CPU through
the full production stack: data pipeline -> train_step (jit) -> TrainLoop
(retries, straggler detection, async checkpoints).  Kill it mid-run and
re-run: it resumes from the last committed checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data import TokenPipeline
from repro.models import model as model_mod
from repro.runtime.fault_tolerance import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true", help="wipe checkpoints first")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = reduced_config("olmo-1b")
    pipe = TokenPipeline(cfg, batch=16, seq=64, seed=0)
    step = jax.jit(
        model_mod.make_train_step(
            cfg,
            None,
            compute_dtype=jnp.float32,
            lr_peak=3e-3,
            warmup=20,
            total_steps=args.steps,
        )
    )
    loop = TrainLoop(step, pipe, args.ckpt_dir, ckpt_every=100)
    state, start = loop.resume_or_init(
        model_mod.init_train_state(jax.random.key(0), cfg)
    )
    if start:
        print(f"[resume] continuing from step {start}")
    state, hist = loop.run(state, start, args.steps, log_every=25)
    print(
        f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} over "
        f"{len(hist)} steps (retries={loop.retries}, stragglers={loop.straggler.events})"
    )
    assert hist[-1]["loss"] < hist[0]["loss"], "model did not learn"


if __name__ == "__main__":
    main()
