"""Static analysis for the resident runtime — plan verifier, race detector,
repo-custom lint.

Only :mod:`repro.analysis.errors` (pure dataclasses) is imported eagerly so
low layers (``repro.core.schedule``) can raise :class:`PlanError` without a
cycle; the verifier and lint load lazily on first attribute access.  See
``python -m repro.analysis`` for the CLI.
"""

from __future__ import annotations

from .errors import PlanError, Violation

__all__ = [
    "PlanError",
    "Violation",
    "verify_spgemm_plan",
    "verify_task_mask",
    "verify_relayout_plan",
    "verify_norm_table",
    "verify_value",
    "lint_paths",
    "CORRUPTIONS",
]

_LAZY = {
    "verify_spgemm_plan": "verify",
    "verify_task_mask": "verify",
    "verify_relayout_plan": "verify",
    "verify_norm_table": "verify",
    "verify_value": "verify",
    "lint_paths": "lint",
    "CORRUPTIONS": "mutate",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)
