"""``python -m repro.analysis`` — verify benchmark-structure plans, lint the
runtime tree, and (optionally) prove the verifier detects via the seeded
mutation suite.

Exit status is nonzero on any violation, unwaived lint finding, or missed
mutation, so CI can gate on it directly.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

from ..core.schedule import make_spgemm_plan
from . import lint as lint_mod
from .mutate import CORRUPTIONS, NotApplicable
from .verify import verify_spgemm_plan, verify_task_mask

# the benchmark structure families (benchmarks/spamm_sequences.py), scaled
# down: plan building and verification are pure host work, no devices needed
N, BS = 512, 16


def _coords(mask: np.ndarray) -> np.ndarray:
    from ..core.quadtree import morton_encode

    i, j = np.nonzero(mask)
    order = np.argsort(morton_encode(i, j), kind="stable")
    return np.stack([i[order], j[order]], axis=1).astype(np.int64)


def _structures() -> dict[str, np.ndarray]:
    nb = N // BS
    ii, jj = np.meshgrid(np.arange(nb), np.arange(nb), indexing="ij")
    rng = np.random.default_rng(0)
    return {
        "banded": np.abs(ii - jj) <= 2,
        "exp_decay": rng.random((nb, nb)) < np.exp(-0.45 * np.abs(ii - jj)),
        "random_offdiag": (ii == jj) | (rng.random((nb, nb)) < 0.08),
    }


def run_verify() -> int:
    failures = 0
    for sname, mask in _structures().items():
        coords = _coords(mask)
        for nparts in (1, 3, 4, 8):
            for exchange in ("p2p", "allgather"):
                plan = make_spgemm_plan(coords, coords, nparts, BS,
                                        exchange=exchange)
                report = verify_spgemm_plan(plan)
                if exchange == "p2p":
                    rng = np.random.default_rng(nparts)
                    mask_on = rng.random(plan.tasks.num_tasks) < 0.5
                    report += verify_task_mask(plan, mask_on)
                    report += verify_task_mask(
                        plan, np.zeros(plan.tasks.num_tasks, bool))
                tag = f"{sname}/P={nparts}/{exchange}"
                if report:
                    failures += len(report)
                    print(f"FAIL {tag}: {len(report)} violation(s)")
                    for v in report[:8]:
                        print(f"  {v}")
                else:
                    print(f"ok   {tag}: {plan.tasks.num_tasks} tasks, "
                          f"{len(plan.a_offsets) + len(plan.b_offsets)} rounds")
    return failures


def run_selftest() -> int:
    coords = _coords(_structures()["random_offdiag"])
    plan = make_spgemm_plan(coords, coords, 4, BS)
    missed = 0
    for name, (fn, expected) in CORRUPTIONS.items():
        try:
            bad, kwargs = fn(plan)
        except NotApplicable as exc:
            print(f"MISS {name}: not applicable ({exc})")
            missed += 1
            continue
        checks = {v.check for v in verify_spgemm_plan(bad, **kwargs)}
        if expected in checks:
            print(f"ok   {name}: caught as {expected!r}")
        else:
            print(f"MISS {name}: wanted {expected!r}, got {sorted(checks)}")
            missed += 1
    return missed


def run_lint(roots, fix: bool = False) -> int:
    if fix:
        for relpath, n in lint_mod.fix_paths(roots):
            print(f"FIX  {relpath}: {n} edit(s)")
    findings, waived = lint_mod.lint_paths(roots)
    for f in findings:
        print(f"LINT {f}")
    print(f"lint: {len(findings)} finding(s), {len(waived)} waived")
    return len(findings)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--verify-only", action="store_true")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="also run the seeded mutation suite")
    ap.add_argument("--fix", action="store_true",
                    help="rewrite fixable perf-counter findings in place "
                         "(Stopwatch/wall_clock), then lint the result")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="lint roots (default: src/repro)")
    args = ap.parse_args(argv)
    problems = 0
    if not args.lint_only:
        problems += run_verify()
    if not args.verify_only:
        problems += run_lint(args.paths or None, fix=args.fix)
    if args.selftest and not args.lint_only and not args.verify_only:
        problems += run_selftest()
    print("analysis:", "clean" if not problems else f"{problems} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
