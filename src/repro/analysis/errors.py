"""Typed verification errors — shared by the planner and the static verifier.

These are pure dataclasses with no jax/numpy imports so that low layers
(``repro.core.schedule``) can raise :class:`PlanError` without creating an
import cycle with the verifier (which imports the planner).
"""

from __future__ import annotations

import dataclasses

__all__ = ["Violation", "PlanError"]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One verified-false invariant, with enough provenance to debug it.

    ``check`` is a stable kebab-case id (``send-conflict`` /
    ``src-off-oob`` / ``round-permutation`` / ``use-before-receive`` /
    ``c-slot-race`` / ``c-slot-order`` / ``accumulation-order`` /
    ``owner-fingerprint`` / ``owner-map`` / ``mask-redirect`` /
    ``capacity-mismatch`` / ``exchange-starvation`` / ``task-gidx`` /
    ``operand-mismatch`` / ``send-oob`` / ``gather-gap`` / ``norm-scatter``);
    ``provenance`` carries the task/round/device coordinates of the failure.
    """

    check: str
    message: str
    provenance: dict = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        where = ", ".join(f"{k}={v}" for k, v in self.provenance.items())
        return f"[{self.check}] {self.message}" + (f" ({where})" if where else "")


class PlanError(RuntimeError):
    """A plan (or pinned plan input) violates a scheduling invariant.

    Raised by :func:`repro.core.schedule.make_spgemm_plan` for malformed
    inputs and by the plan-cache admission hook when
    :func:`repro.analysis.verify.verify_value` reports violations.  Unlike
    the bare ``assert`` guards it replaces, this survives ``python -O``.
    """

    def __init__(self, message: str, violations: tuple | list = ()):
        super().__init__(message)
        self.violations = tuple(violations)
