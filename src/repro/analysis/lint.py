"""Repo-custom lint — runtime idioms that keep the resident path honest.

Pure-AST (no imports of the linted modules), four rules:

* ``perf-counter`` — ``time.perf_counter`` belongs to ``obs/timing.py``
  alone; everything else routes through :func:`repro.obs.timing.wall_clock`
  / :class:`repro.obs.timing.Stopwatch` / :class:`timed_into` so timing
  accounting stays uniform (and traceable) across the runtime.
* ``host-sync`` — executable program builders (``_build_program`` methods,
  ``_mapped*`` shard_map bodies) must stay device-pure: no ``np.asarray``,
  ``.block_until_ready()`` or ``jax.device_get`` host syncs inside — one
  stray sync serializes every round of a resident iteration.
* ``plan-key-fields`` — multiply-family plan-cache keys (tuples tagged
  ``"spgemm"`` / ``"spamm"`` / ``"spamm-delta"`` that fingerprint a mesh)
  must carry both operand dtypes and the precision policy key; a key
  missing them silently reuses a plan compiled for other numerics.
* ``device-transfer`` — no ``jax.device_put`` / ``jax.device_get`` inside
  resident collective bodies (``dist_*`` functions): the whole point of the
  resident runtime is that iterates never cross host<->device mid-run, and
  one stray transfer inside a collective reintroduces per-call motion that
  planning can't see.  Construction-time entry points (``dist_zeros``
  builds a fresh sharded store) are baseline-waived.

Findings are waived by ``<relpath>::<rule>`` lines in a checked-in baseline
file (``lint_baseline.txt`` next to this module) — the escape hatch for the
one legitimate exception (``obs/tracer.py`` defaults its clock to
``time.perf_counter`` because ``obs/timing`` imports the tracer, not the
other way around).
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["Finding", "lint_file", "lint_paths", "load_baseline",
           "fix_perf_counter_source", "fix_paths",
           "DEFAULT_BASELINE", "default_root"]

# files allowed to touch time.perf_counter directly
_CLOCK_HOME = ("obs/timing.py",)
# plan-key kinds that must fingerprint numerics (dtype + precision)
_PLAN_KEY_KINDS = {"spgemm", "spamm", "spamm-delta"}
# host-sync is checked inside functions with these names
_PROGRAM_FUNCS = ("_build_program", "_mapped")

DEFAULT_BASELINE = Path(__file__).with_name("lint_baseline.txt")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str  # posix path relative to the lint root
    line: int
    rule: str
    message: str

    @property
    def key(self) -> str:
        """Baseline waiver key — stable across line-number churn."""
        return f"{self.path}::{self.rule}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _is_perf_counter(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "perf_counter") or (
        isinstance(node, ast.Name) and node.id == "perf_counter"
    )


def _check_perf_counter(tree, relpath, out):
    if relpath.endswith(_CLOCK_HOME):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name == "perf_counter":
                    out.append(Finding(
                        relpath, node.lineno, "perf-counter",
                        "import time.perf_counter outside obs/timing.py — "
                        "use repro.obs.timing.wall_clock/Stopwatch",
                    ))
        elif isinstance(node, ast.Attribute) and node.attr == "perf_counter":
            out.append(Finding(
                relpath, node.lineno, "perf-counter",
                "time.perf_counter outside obs/timing.py — use "
                "repro.obs.timing.wall_clock/Stopwatch",
            ))


def _check_host_sync(tree, relpath, out):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not (fn.name == _PROGRAM_FUNCS[0]
                or fn.name.startswith(_PROGRAM_FUNCS[1])):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            sync = None
            if isinstance(f, ast.Attribute):
                if f.attr == "block_until_ready":
                    sync = ".block_until_ready()"
                elif f.attr == "asarray" and isinstance(f.value, ast.Name) \
                        and f.value.id in ("np", "numpy"):
                    sync = "np.asarray()"
                elif f.attr == "device_get" and isinstance(f.value, ast.Name) \
                        and f.value.id == "jax":
                    sync = "jax.device_get()"
            if sync:
                out.append(Finding(
                    relpath, node.lineno, "host-sync",
                    f"{sync} inside {fn.name}() — executable programs must "
                    f"stay device-pure (host syncs serialize the rounds)",
                ))


def _tuple_has(node: ast.Tuple, pred) -> int:
    return sum(1 for elt in node.elts for sub in ast.walk(elt) if pred(sub))


def _check_plan_keys(tree, relpath, out):
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Tuple) and node.elts):
            continue
        head = node.elts[0]
        if not (isinstance(head, ast.Constant)
                and head.value in _PLAN_KEY_KINDS):
            continue
        # only distributed plan keys (they fingerprint the mesh); the
        # single-host symbolic cache keys share the tag but carry no mesh
        fingerprints_mesh = _tuple_has(node, lambda s: isinstance(s, ast.Call)
                                       and isinstance(s.func, ast.Name)
                                       and s.func.id == "mesh_key")
        if not fingerprints_mesh:
            continue
        dtypes = _tuple_has(
            node,
            lambda s: isinstance(s, ast.Call)
            and isinstance(s.func, ast.Name) and s.func.id == "str"
            and len(s.args) == 1 and isinstance(s.args[0], ast.Attribute)
            and s.args[0].attr == "dtype",
        )
        precision = _tuple_has(node, lambda s: isinstance(s, ast.Call)
                               and isinstance(s.func, ast.Attribute)
                               and s.func.attr == "key")
        if dtypes < 2 or precision < 1:
            out.append(Finding(
                relpath, node.lineno, "plan-key-fields",
                f"{head.value!r} plan key carries {dtypes} operand dtype "
                f"field(s) and {precision} precision key(s) — both operand "
                f"dtypes and precision.key() are mandatory (a stale key "
                f"reuses a plan compiled for other numerics)",
            ))


def _check_device_transfer(tree, relpath, out):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not fn.name.startswith("dist_"):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in ("device_put", "device_get")
                and isinstance(f.value, ast.Name)
                and f.value.id == "jax"
            ):
                out.append(Finding(
                    relpath, node.lineno, "device-transfer",
                    f"jax.{f.attr}() inside resident collective {fn.name}() "
                    f"— iterates must stay on device; scatter/gather are the "
                    f"only sanctioned boundary crossings",
                ))


_RULES = (
    _check_perf_counter,
    _check_host_sync,
    _check_plan_keys,
    _check_device_transfer,
)


# ---------------------------------------------------------------------------
# --fix: mechanical rewrites for the perf-counter rule
# ---------------------------------------------------------------------------

_TIMING_IMPORT = "repro.obs.timing"


def _line_starts(src: str) -> list[int]:
    starts, pos = [0], 0
    for line in src.splitlines(keepends=True):
        pos += len(line)
        starts.append(pos)
    return starts


def fix_perf_counter_source(src: str) -> tuple[str, int]:
    """Rewrite ``time.perf_counter`` idioms to their ``repro.obs.timing``
    equivalents; returns ``(new_source, edits)``.

    Three patterns, matched on the AST (so strings/comments are safe) and
    rewritten by exact source position:

    * ``t0 = time.perf_counter()``      -> ``t0 = Stopwatch()``
    * ``time.perf_counter() - t0``      -> ``t0.elapsed()``  (paired names)
    * any other bare call               -> ``wall_clock()``

    plus removal of ``perf_counter`` from ``from time import ...`` lines and
    insertion of the needed ``from repro.obs.timing import ...``.  Anything
    fancier (the callable passed as a clock default, calls with arguments)
    is left alone and stays a lint finding.  Running the fixer on its own
    output is a no-op: the rewritten source contains no matchable pattern.
    """
    tree = ast.parse(src)
    edits: list[tuple[int, int, int, int, str]] = []
    watches: set[str] = set()
    handled: set[int] = set()
    need: set[str] = set()

    def span(node):
        return (node.lineno, node.col_offset,
                node.end_lineno, node.end_col_offset)

    def bare_call(node):
        return (isinstance(node, ast.Call) and _is_perf_counter(node.func)
                and not node.args and not node.keywords)

    # names read back as `time.perf_counter() - NAME` are stopwatch starts;
    # an assignment never subtracted from is just a timestamp (wall_clock)
    for node in ast.walk(tree):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub)
                and bare_call(node.left)
                and isinstance(node.right, ast.Name)):
            watches.add(node.right.id)
    # stopwatch starts: NAME = time.perf_counter()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id in watches
                and bare_call(node.value)):
            handled.add(id(node.value))
            edits.append(span(node.value) + ("Stopwatch()",))
            need.add("Stopwatch")
    # stopwatch reads: time.perf_counter() - NAME
    for node in ast.walk(tree):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub)
                and bare_call(node.left)
                and isinstance(node.right, ast.Name)
                and node.right.id in watches):
            handled.add(id(node.left))
            edits.append(span(node) + (f"{node.right.id}.elapsed()",))
    # everything else that is a plain zero-arg call
    for node in ast.walk(tree):
        if bare_call(node) and id(node) not in handled:
            edits.append(span(node) + ("wall_clock()",))
            need.add("wall_clock")
    # import surgery: drop perf_counter from `from time import ...`
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            keep = [a for a in node.names if a.name != "perf_counter"]
            if len(keep) == len(node.names):
                continue
            repl = ("from time import " + ", ".join(
                a.name + (f" as {a.asname}" if a.asname else "")
                for a in keep)) if keep else ""
            edits.append(span(node) + (repl,))

    if not edits:
        return src, 0

    # the timing import the rewrites rely on (skip names already imported)
    for node in tree.body:
        if (isinstance(node, ast.ImportFrom)
                and node.module and node.module.endswith("obs.timing")):
            need -= {a.asname or a.name for a in node.names}
    n_edits = len(edits)
    starts = _line_starts(src)
    out = src
    dropped_lines: list[int] = []
    for l0, c0, l1, c1, repl in sorted(edits, reverse=True):
        lo, hi = starts[l0 - 1] + c0, starts[l1 - 1] + c1
        if repl == "" and c0 == 0 and out[hi:hi + 1] == "\n":
            hi += 1  # deleting a whole import line takes its newline along
            dropped_lines.append(l0)
        out = out[:lo] + repl + out[hi:]
    if need:
        line = f"from {_TIMING_IMPORT} import " + ", ".join(sorted(need))
        # insert after the last top-level import (they all precede code in
        # this tree), else after the module docstring / at the top
        anchor = 0
        for node in tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                anchor = max(anchor, node.end_lineno)
            elif (anchor == 0 and isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, str)):
                anchor = node.end_lineno
        anchor -= sum(1 for ln in dropped_lines if ln <= anchor)
        lines = out.splitlines(keepends=True)
        lines.insert(anchor, line + "\n")
        out = "".join(lines)
        n_edits += 1
    return out, n_edits


def fix_paths(roots: list[Path] | None = None,
              *, baseline: set[str] | None = None) -> list[tuple[str, int]]:
    """Apply :func:`fix_perf_counter_source` to every file with an unwaived
    ``perf-counter`` finding; returns ``[(relpath, edits), ...]``."""
    roots = [default_root()] if roots is None else [Path(r) for r in roots]
    baseline = load_baseline() if baseline is None else baseline
    done: list[tuple[str, int]] = []
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for f in files:
            hits = [x for x in lint_file(f, base)
                    if x.rule == "perf-counter" and x.key not in baseline]
            if not hits:
                continue
            new, n = fix_perf_counter_source(f.read_text())
            if n:
                f.write_text(new)
                done.append((hits[0].path, n))
    return done


def default_root() -> Path:
    """The runtime tree the lint governs: ``src/repro``."""
    return Path(__file__).resolve().parents[1]


def lint_file(path: Path, root: Path) -> list[Finding]:
    relpath = path.resolve().relative_to(root.resolve()).as_posix()
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:  # pragma: no cover - defensive
        return [Finding(relpath, exc.lineno or 0, "syntax", str(exc))]
    out: list[Finding] = []
    for rule in _RULES:
        rule(tree, relpath, out)
    return out


def load_baseline(path: Path | None = None) -> set[str]:
    path = DEFAULT_BASELINE if path is None else Path(path)
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def lint_paths(
    roots: list[Path] | None = None,
    *,
    baseline: set[str] | None = None,
) -> tuple[list[Finding], list[Finding]]:
    """Lint every ``.py`` under ``roots`` (default: ``src/repro``).

    Returns ``(findings, waived)`` — findings whose key appears in the
    baseline move to the waived list.
    """
    roots = [default_root()] if roots is None else [Path(r) for r in roots]
    baseline = load_baseline() if baseline is None else baseline
    findings: list[Finding] = []
    waived: list[Finding] = []
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for f in files:
            for finding in lint_file(f, base):
                (waived if finding.key in baseline else findings).append(finding)
    return findings, waived
