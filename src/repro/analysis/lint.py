"""Repo-custom lint — runtime idioms that keep the resident path honest.

Pure-AST (no imports of the linted modules), four rules:

* ``perf-counter`` — ``time.perf_counter`` belongs to ``obs/timing.py``
  alone; everything else routes through :func:`repro.obs.timing.wall_clock`
  / :class:`repro.obs.timing.Stopwatch` / :class:`timed_into` so timing
  accounting stays uniform (and traceable) across the runtime.
* ``host-sync`` — executable program builders (``_build_program`` methods,
  ``_mapped*`` shard_map bodies) must stay device-pure: no ``np.asarray``,
  ``.block_until_ready()`` or ``jax.device_get`` host syncs inside — one
  stray sync serializes every round of a resident iteration.
* ``plan-key-fields`` — multiply-family plan-cache keys (tuples tagged
  ``"spgemm"`` / ``"spamm"`` / ``"spamm-delta"`` that fingerprint a mesh)
  must carry both operand dtypes and the precision policy key; a key
  missing them silently reuses a plan compiled for other numerics.
* ``device-transfer`` — no ``jax.device_put`` / ``jax.device_get`` inside
  resident collective bodies (``dist_*`` functions): the whole point of the
  resident runtime is that iterates never cross host<->device mid-run, and
  one stray transfer inside a collective reintroduces per-call motion that
  planning can't see.  Construction-time entry points (``dist_zeros``
  builds a fresh sharded store) are baseline-waived.

Findings are waived by ``<relpath>::<rule>`` lines in a checked-in baseline
file (``lint_baseline.txt`` next to this module) — the escape hatch for the
one legitimate exception (``obs/tracer.py`` defaults its clock to
``time.perf_counter`` because ``obs/timing`` imports the tracer, not the
other way around).
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["Finding", "lint_file", "lint_paths", "load_baseline",
           "DEFAULT_BASELINE", "default_root"]

# files allowed to touch time.perf_counter directly
_CLOCK_HOME = ("obs/timing.py",)
# plan-key kinds that must fingerprint numerics (dtype + precision)
_PLAN_KEY_KINDS = {"spgemm", "spamm", "spamm-delta"}
# host-sync is checked inside functions with these names
_PROGRAM_FUNCS = ("_build_program", "_mapped")

DEFAULT_BASELINE = Path(__file__).with_name("lint_baseline.txt")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str  # posix path relative to the lint root
    line: int
    rule: str
    message: str

    @property
    def key(self) -> str:
        """Baseline waiver key — stable across line-number churn."""
        return f"{self.path}::{self.rule}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _is_perf_counter(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "perf_counter") or (
        isinstance(node, ast.Name) and node.id == "perf_counter"
    )


def _check_perf_counter(tree, relpath, out):
    if relpath.endswith(_CLOCK_HOME):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name == "perf_counter":
                    out.append(Finding(
                        relpath, node.lineno, "perf-counter",
                        "import time.perf_counter outside obs/timing.py — "
                        "use repro.obs.timing.wall_clock/Stopwatch",
                    ))
        elif isinstance(node, ast.Attribute) and node.attr == "perf_counter":
            out.append(Finding(
                relpath, node.lineno, "perf-counter",
                "time.perf_counter outside obs/timing.py — use "
                "repro.obs.timing.wall_clock/Stopwatch",
            ))


def _check_host_sync(tree, relpath, out):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not (fn.name == _PROGRAM_FUNCS[0]
                or fn.name.startswith(_PROGRAM_FUNCS[1])):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            sync = None
            if isinstance(f, ast.Attribute):
                if f.attr == "block_until_ready":
                    sync = ".block_until_ready()"
                elif f.attr == "asarray" and isinstance(f.value, ast.Name) \
                        and f.value.id in ("np", "numpy"):
                    sync = "np.asarray()"
                elif f.attr == "device_get" and isinstance(f.value, ast.Name) \
                        and f.value.id == "jax":
                    sync = "jax.device_get()"
            if sync:
                out.append(Finding(
                    relpath, node.lineno, "host-sync",
                    f"{sync} inside {fn.name}() — executable programs must "
                    f"stay device-pure (host syncs serialize the rounds)",
                ))


def _tuple_has(node: ast.Tuple, pred) -> int:
    return sum(1 for elt in node.elts for sub in ast.walk(elt) if pred(sub))


def _check_plan_keys(tree, relpath, out):
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Tuple) and node.elts):
            continue
        head = node.elts[0]
        if not (isinstance(head, ast.Constant)
                and head.value in _PLAN_KEY_KINDS):
            continue
        # only distributed plan keys (they fingerprint the mesh); the
        # single-host symbolic cache keys share the tag but carry no mesh
        fingerprints_mesh = _tuple_has(node, lambda s: isinstance(s, ast.Call)
                                       and isinstance(s.func, ast.Name)
                                       and s.func.id == "mesh_key")
        if not fingerprints_mesh:
            continue
        dtypes = _tuple_has(
            node,
            lambda s: isinstance(s, ast.Call)
            and isinstance(s.func, ast.Name) and s.func.id == "str"
            and len(s.args) == 1 and isinstance(s.args[0], ast.Attribute)
            and s.args[0].attr == "dtype",
        )
        precision = _tuple_has(node, lambda s: isinstance(s, ast.Call)
                               and isinstance(s.func, ast.Attribute)
                               and s.func.attr == "key")
        if dtypes < 2 or precision < 1:
            out.append(Finding(
                relpath, node.lineno, "plan-key-fields",
                f"{head.value!r} plan key carries {dtypes} operand dtype "
                f"field(s) and {precision} precision key(s) — both operand "
                f"dtypes and precision.key() are mandatory (a stale key "
                f"reuses a plan compiled for other numerics)",
            ))


def _check_device_transfer(tree, relpath, out):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not fn.name.startswith("dist_"):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in ("device_put", "device_get")
                and isinstance(f.value, ast.Name)
                and f.value.id == "jax"
            ):
                out.append(Finding(
                    relpath, node.lineno, "device-transfer",
                    f"jax.{f.attr}() inside resident collective {fn.name}() "
                    f"— iterates must stay on device; scatter/gather are the "
                    f"only sanctioned boundary crossings",
                ))


_RULES = (
    _check_perf_counter,
    _check_host_sync,
    _check_plan_keys,
    _check_device_transfer,
)


def default_root() -> Path:
    """The runtime tree the lint governs: ``src/repro``."""
    return Path(__file__).resolve().parents[1]


def lint_file(path: Path, root: Path) -> list[Finding]:
    relpath = path.resolve().relative_to(root.resolve()).as_posix()
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:  # pragma: no cover - defensive
        return [Finding(relpath, exc.lineno or 0, "syntax", str(exc))]
    out: list[Finding] = []
    for rule in _RULES:
        rule(tree, relpath, out)
    return out


def load_baseline(path: Path | None = None) -> set[str]:
    path = DEFAULT_BASELINE if path is None else Path(path)
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def lint_paths(
    roots: list[Path] | None = None,
    *,
    baseline: set[str] | None = None,
) -> tuple[list[Finding], list[Finding]]:
    """Lint every ``.py`` under ``roots`` (default: ``src/repro``).

    Returns ``(findings, waived)`` — findings whose key appears in the
    baseline move to the waived list.
    """
    roots = [default_root()] if roots is None else [Path(r) for r in roots]
    baseline = load_baseline() if baseline is None else baseline
    findings: list[Finding] = []
    waived: list[Finding] = []
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for f in files:
            for finding in lint_file(f, base):
                (waived if finding.key in baseline else findings).append(finding)
    return findings, waived
