"""Seeded plan corruptions — proof the verifier detects, not just passes.

Each corruption clones a clean :class:`~repro.core.schedule.SpgemmPlan`,
breaks exactly one scheduling invariant, and names the check that must
catch it.  The mutation suite (``tests/test_analysis.py``) and the CLI
selftest (``python -m repro.analysis --selftest``) run every corruption
against :func:`repro.analysis.verify.verify_spgemm_plan` and require the
named violation with non-empty provenance.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.schedule import SpgemmPlan

__all__ = ["clone_plan", "CORRUPTIONS", "NotApplicable"]


class NotApplicable(RuntimeError):
    """The clean plan lacks the structure this corruption needs (e.g. no
    exchange rounds on a single-worker plan)."""


def clone_plan(plan: SpgemmPlan) -> SpgemmPlan:
    """Deep-copy the plan's arrays so corruptions never touch the original
    (the memoized send-task spans are deliberately not carried over)."""
    kw = {}
    for f in dataclasses.fields(SpgemmPlan):
        val = getattr(plan, f.name)
        if isinstance(val, np.ndarray):
            val = val.copy()
        elif isinstance(val, dict):
            val = {k: np.array(v, copy=True) for k, v in val.items()}
        kw[f.name] = val
    return SpgemmPlan(**kw)


def _first_round(plan, min_count=1):
    for name in ("a", "b"):
        offsets = plan.a_offsets if name == "a" else plan.b_offsets
        cnts = plan.a_send_count if name == "a" else plan.b_send_count
        for d in offsets:
            for src in range(plan.nparts):
                if int(cnts[d][src]) >= min_count:
                    return name, d, src
    raise NotApplicable(f"no exchange round ships >= {min_count} blocks")


def corrupt_send_conflict(plan):
    """Duplicate a send slot within one round: two sends, one recv slot."""
    p = clone_plan(plan)
    name, d, src = _first_round(p, min_count=2)
    send = p.a_send if name == "a" else p.b_send
    send[d][src, 1] = send[d][src, 0]
    return p, {}


def corrupt_src_off_oob(plan):
    """Point a fused (src, off) address past its round's true capacity."""
    p = clone_plan(plan)
    if p.task_a_src is None:
        raise NotApplicable("plan has no fused addressing")
    hits = np.nonzero(np.asarray(p.task_a_src) > 0)
    if not hits[0].size:
        raise NotApplicable("no task reads a receive buffer")
    dev, t = int(hits[0][0]), int(hits[1][0])
    r = int(p.task_a_src[dev, t]) - 1
    width = p.a_send[p.a_offsets[r]].shape[1]
    p.task_a_off[dev, t] = width  # one past the round capacity
    return p, {}


def corrupt_round_permutation(plan):
    """Shift a round to ring offset 0 — a self-send, not a permutation."""
    p = clone_plan(plan)
    for name in ("a", "b"):
        offsets = getattr(p, f"{name}_offsets")
        if offsets:
            d0 = offsets[0]
            for attr in (f"{name}_send", f"{name}_send_count"):
                table = getattr(p, attr)
                table[0] = table.pop(d0)
            object.__setattr__(p, f"{name}_offsets", (0,) + offsets[1:])
            return p, {}
    raise NotApplicable("plan has no exchange rounds")


def corrupt_use_before_receive(plan):
    """Erase the delivery a remote task depends on (send count to zero)."""
    p = clone_plan(plan)
    for name in ("a", "b"):
        offsets = getattr(p, f"{name}_offsets")
        cnts = getattr(p, f"{name}_send_count")
        for d in offsets:
            src = int(np.argmax(cnts[d]))
            if int(cnts[d][src]):
                cnts[d][src] = 0
                return p, {}
    raise NotApplicable("plan has no exchange rounds")


def corrupt_c_slot_race(plan):
    """Merge two output blocks' accumulation chains into one slot."""
    p = clone_plan(plan)
    for dev in range(p.nparts):
        cnt = int(p.task_count[dev])
        tc = p.task_c[dev, :cnt]
        change = np.nonzero(np.diff(tc) > 0)[0]
        if change.size:
            t = int(change[0]) + 1  # first slot of the second run
            run2 = tc[t]
            p.task_c[dev, :cnt][tc == run2] = tc[t - 1]
            return p, {}
    raise NotApplicable("no device accumulates two distinct output blocks")


def corrupt_owner_fingerprint(plan):
    """Flip one owner entry so the plan disagrees with the fingerprinted
    owner map (and with its own slot/store layout)."""
    p = clone_plan(plan)
    if p.nparts < 2 or not p.a_owner.size:
        raise NotApplicable("needs >= 2 devices and a nonempty A")
    i = int(p.a_owner.shape[0] // 2)
    p.a_owner[i] = (int(p.a_owner[i]) + 1) % p.nparts
    return p, {"expected_a_owner": np.asarray(plan.a_owner).copy()}


def corrupt_mask_redirect(plan):
    """Aim a padded task slot at a live output row instead of the trash."""
    p = clone_plan(plan)
    pads = np.nonzero(np.asarray(p.task_count) < p.t_cap)[0]
    if not pads.size:
        raise NotApplicable("no device has padded task slots")
    dev = int(pads[0])
    p.task_c[dev, int(p.task_count[dev])] = p.c_cap - 1
    return p, {}


def corrupt_capacity_mismatch(plan):
    """Claim more sends than the padded round capacity holds."""
    p = clone_plan(plan)
    name, d, src = _first_round(p)
    cnts = p.a_send_count if name == "a" else p.b_send_count
    send = p.a_send if name == "a" else p.b_send
    cnts[d][src] = send[d].shape[1] + 1
    return p, {}


def corrupt_accumulation_order(plan):
    """Swap two tasks inside one accumulation chain, breaking the stable
    symbolic order fp32 bit-exactness under re-layout depends on."""
    p = clone_plan(plan)
    for dev in range(p.nparts):
        cnt = int(p.task_count[dev])
        tc = p.task_c[dev, :cnt]
        runs = np.nonzero(np.diff(tc) == 0)[0]
        if not runs.size:
            continue
        t = int(runs[0])  # tasks t, t+1 share an output slot
        for arr in (p.task_a, p.task_b, p.task_gidx,
                    p.task_a_src, p.task_a_off, p.task_b_src, p.task_b_off):
            if arr is not None:
                arr[dev, t], arr[dev, t + 1] = arr[dev, t + 1], arr[dev, t]
        return p, {}
    raise NotApplicable("no output slot accumulates two tasks")


# name -> (corruption, the check that must catch it)
CORRUPTIONS = {
    "send_conflict": (corrupt_send_conflict, "send-conflict"),
    "src_off_oob": (corrupt_src_off_oob, "src-off-oob"),
    "round_permutation": (corrupt_round_permutation, "round-permutation"),
    "use_before_receive": (corrupt_use_before_receive, "use-before-receive"),
    "c_slot_race": (corrupt_c_slot_race, "c-slot-race"),
    "owner_fingerprint": (corrupt_owner_fingerprint, "owner-fingerprint"),
    "mask_redirect": (corrupt_mask_redirect, "mask-redirect"),
    "capacity_mismatch": (corrupt_capacity_mismatch, "capacity-mismatch"),
    "accumulation_order": (corrupt_accumulation_order, "accumulation-order"),
}
