"""Static plan verifier and SPMD accumulation-race detector.

CHT gets race-freedom by construction (immutable chunks, explicit task
dependencies).  Our SPMD reproduction re-derives those guarantees by hand in
every :class:`~repro.core.schedule.SpgemmPlan` — ppermute exchange rounds,
``(src, off)`` scalar-prefetch addressing, the ``c_slot`` accumulation
layout — and this module re-proves them, pure-host, before a plan is
admitted to the cache:

* **Exchange rounds** — each round is a ring ``ppermute`` at a distinct
  offset in ``[1, nparts)`` (permutation-ness), sent slots address the
  sender's real store, and the blocks delivered to a device within a round
  land in strictly increasing distinct receive slots (no two sends into one
  slot), never duplicating a block across rounds or re-delivering a block
  the receiver owns.
* **Task addressing** — every task operand index resolves, in the staged
  ``[own store | recv per round]`` buffer layout, to exactly the global
  block the symbolic phase assigned it (anything undelivered is a
  use-before-receive), and the fused-engine ``(src, off)`` decomposition
  recomposes to the same index within each round's true capacity.
* **Accumulation chains** — per device, tasks are sorted by output slot
  (the fused kernel zeroes its accumulator on slot change, so a revisited
  slot would drop contributions — a write race between grid segments), each
  slot accumulates exactly one output block, and within a slot the global
  task order is preserved (the stable sort that keeps fp32 accumulation
  order — and hence result bits — invariant under owner re-layout).
* **Delta-plan safety** — the memoized send-slot→task spans that masked
  executables prune the exchange with must cover every (task, remote
  operand) pair, so *every* reachable runtime mask keeps the blocks its
  kept tasks read; padded task slots must redirect to the trash row.

Everything here is numpy over the host-side plan arrays — no devices, no
jax imports at module scope — so it also runs in lint/CI contexts.
"""

from __future__ import annotations

import numpy as np

from ..core.schedule import SpgemmPlan, _owner_slots
from .errors import PlanError, Violation

__all__ = [
    "verify_spgemm_plan",
    "verify_task_mask",
    "verify_relayout_plan",
    "verify_norm_table",
    "verify_add_plan",
    "verify_compact_plan",
    "verify_value",
    "PlanError",
    "Violation",
]


# ---------------------------------------------------------------------------
# shared reconstruction helpers
# ---------------------------------------------------------------------------


def _inverse_store(owner: np.ndarray, slot: np.ndarray, nparts: int, cap: int):
    """[P, cap] global block id resident at each store slot (-1 = empty)."""
    inv = np.full((nparts, cap), -1, dtype=np.int64)
    n = owner.shape[0]
    if n:
        ok = (
            (owner >= 0)
            & (owner < nparts)
            & (slot >= 0)
            & (slot < cap)
        )
        inv[owner[ok], slot[ok]] = np.nonzero(ok)[0]
    return inv


def _delivered_blocks(inv, send, send_cnt, d, nparts):
    """Per destination device: global blocks round ``d`` delivers, by slot.

    Returns ``[P, width]`` int64 (-1 at padded positions); row ``dst`` holds
    the blocks sent by ``src = (dst - d) % nparts``.
    """
    pad = np.asarray(send[d])
    width = pad.shape[1]
    out = np.full((nparts, width), -1, dtype=np.int64)
    for dst in range(nparts):
        src = (dst - d) % nparts
        cnt = min(int(send_cnt[d][src]), width)
        slots = pad[src, :cnt].astype(np.int64)
        ok = (slots >= 0) & (slots < inv.shape[1])
        vals = np.where(ok, inv[src, np.clip(slots, 0, inv.shape[1] - 1)], -1)
        out[dst, :cnt] = vals
    return out


def _staged_buffer(inv, cap, offsets, send, send_cnt, nparts):
    """[P, cap + sum(widths)] global block at each staged buffer position.

    Mirrors the execution-time layout ``[own store (cap) | recv per offset,
    in offset order]`` that :func:`repro.core.schedule.local_fetch_index`
    addresses; -1 marks padding / never-written positions.
    """
    parts = [inv[:, :cap] if inv.shape[1] >= cap else np.pad(
        inv, ((0, 0), (0, cap - inv.shape[1])), constant_values=-1)]
    widths = []
    for d in offsets:
        dv = _delivered_blocks(inv, send, send_cnt, d, nparts)
        widths.append(dv.shape[1])
        parts.append(dv)
    return np.concatenate(parts, axis=1), widths


def _check_layout(name, owner, slot, cap, expected, nparts, out, store_idx=None,
                  store_valid=None):
    """Owner/slot layout checks; returns the inverse store (or None if the
    owner map is unusable)."""
    owner = np.asarray(owner)
    slot = np.asarray(slot)
    n = owner.shape[0]
    if expected is not None and not np.array_equal(owner, np.asarray(expected)):
        i = int(np.nonzero(owner != np.asarray(expected))[0][0])
        out.append(Violation(
            "owner-fingerprint",
            f"operand {name!r}: plan owner map disagrees with the owner map "
            f"the cache key fingerprints (block {i}: plan {int(owner[i])}, "
            f"key {int(np.asarray(expected)[i])})",
            dict(operand=name, block=i),
        ))
    if n and ((owner < 0) | (owner >= nparts)).any():
        i = int(np.nonzero((owner < 0) | (owner >= nparts))[0][0])
        out.append(Violation(
            "owner-map",
            f"operand {name!r}: block {i} assigned to device {int(owner[i])} "
            f"outside the mesh of {nparts}",
            dict(operand=name, block=i, owner=int(owner[i])),
        ))
        return None
    sizes = np.bincount(owner, minlength=nparts) if n else np.zeros(nparts, np.int64)
    if cap < max(int(sizes.max()) if n else 0, 1):
        out.append(Violation(
            "capacity-mismatch",
            f"operand {name!r}: store capacity {cap} is below the largest "
            f"per-device store ({int(sizes.max())})",
            dict(operand=name, cap=int(cap), max_store=int(sizes.max())),
        ))
    # duplicate (owner, slot) pairs: two blocks resident in one store slot
    if n:
        key = owner.astype(np.int64) * (int(max(slot.max(), 0)) + 1) + slot
        uniq, counts = np.unique(key, return_counts=True)
        if (counts > 1).any():
            dup = uniq[counts > 1][0]
            blocks = np.nonzero(key == dup)[0][:2]
            check = "c-slot-race" if name == "c" else "slot-collision"
            out.append(Violation(
                check,
                f"operand {name!r}: blocks {int(blocks[0])} and "
                f"{int(blocks[1])} share store slot "
                f"{int(slot[blocks[0]])} on device {int(owner[blocks[0]])} — "
                f"two blocks (and their accumulation chains) would alias one "
                f"output row",
                dict(operand=name, device=int(owner[blocks[0]]),
                     slot=int(slot[blocks[0]]),
                     blocks=[int(b) for b in blocks]),
            ))
    # the ascending-global-order-within-owner invariant every planner and
    # the scatter/gather layout rely on
    ref_slot, _ = _owner_slots(owner, nparts)
    if not np.array_equal(slot, ref_slot):
        i = int(np.nonzero(slot != ref_slot)[0][0])
        out.append(Violation(
            "owner-map",
            f"operand {name!r}: store slots violate the ascending-Morton-"
            f"within-owner layout invariant (block {i} at slot "
            f"{int(slot[i])}, layout says {int(ref_slot[i])})",
            dict(operand=name, block=i, slot=int(slot[i]),
                 expected=int(ref_slot[i])),
        ))
    inv = _inverse_store(owner, slot, nparts, int(cap))
    if store_idx is not None:
        sidx = np.asarray(store_idx)
        svalid = np.asarray(store_valid)
        want_valid = inv >= 0
        if sidx.shape != (nparts, cap) or svalid.shape != (nparts, cap):
            out.append(Violation(
                "capacity-mismatch",
                f"operand {name!r}: store index arrays have shape "
                f"{sidx.shape}, plan capacity says ({nparts}, {cap})",
                dict(operand=name),
            ))
        elif (not np.array_equal(svalid, want_valid)
              or not np.array_equal(np.where(want_valid, sidx, 0),
                                    np.where(want_valid, inv, 0))):
            p, s = [int(x[0]) for x in np.nonzero(
                (svalid != want_valid)
                | (np.where(want_valid, sidx, 0) != np.where(want_valid, inv, 0)))]
            out.append(Violation(
                "owner-map",
                f"operand {name!r}: store index table disagrees with the "
                f"owner/slot maps at device {p} slot {s}",
                dict(operand=name, device=p, slot=s),
            ))
    return inv


def _check_rounds(name, offsets, send, send_cnt, inv, owner, nparts, out):
    """Per-round ppermute checks for one operand's exchange plan."""
    offs = tuple(int(d) for d in offsets)
    for r, d in enumerate(offs):
        if not (1 <= d < nparts):
            out.append(Violation(
                "round-permutation",
                f"operand {name!r} round {r}: ring offset {d} outside "
                f"[1, {nparts}) — the round is not a permutation of the mesh "
                f"(offset 0 aliases every device's own store)",
                dict(operand=name, round=r, offset=d),
            ))
        elif r and d <= offs[r - 1]:
            out.append(Violation(
                "round-permutation",
                f"operand {name!r} round {r}: ring offset {d} does not "
                f"increase over round {r - 1} (offset {offs[r - 1]}) — "
                f"duplicate offsets deliver into the same receive buffer",
                dict(operand=name, round=r, offset=d),
            ))
    sizes = np.bincount(owner, minlength=nparts) if owner.size else np.zeros(
        nparts, np.int64)
    seen = [dict() for _ in range(nparts)]  # dst -> {block: round}
    for r, d in enumerate(offs):
        pad = np.asarray(send[d])
        width = pad.shape[1]
        if pad.shape[0] != nparts:
            out.append(Violation(
                "capacity-mismatch",
                f"operand {name!r} round {r}: send table has "
                f"{pad.shape[0]} rows for a mesh of {nparts}",
                dict(operand=name, round=r),
            ))
            continue
        for src in range(nparts):
            cnt = int(send_cnt[d][src])
            if cnt > width:
                out.append(Violation(
                    "capacity-mismatch",
                    f"operand {name!r} round {r} (offset {d}): device {src} "
                    f"claims {cnt} sends but the padded round capacity is "
                    f"{width}",
                    dict(operand=name, round=r, offset=d, src=src,
                         count=cnt, width=width),
                ))
                cnt = width
            slots = pad[src, :cnt].astype(np.int64)
            bad = (slots < 0) | (slots >= int(sizes[src]))
            if bad.any():
                pos = int(np.nonzero(bad)[0][0])
                out.append(Violation(
                    "send-oob",
                    f"operand {name!r} round {r} (offset {d}): device {src} "
                    f"sends store slot {int(slots[pos])} at position {pos} "
                    f"but only holds {int(sizes[src])} blocks",
                    dict(operand=name, round=r, offset=d, src=src, pos=pos,
                         slot=int(slots[pos])),
                ))
                continue
            if d % nparts == 0:
                continue  # self-send already reported as round-permutation
            dst = (src + d) % nparts
            blocks = inv[src, slots] if cnt else np.zeros(0, np.int64)
            diffs = np.diff(blocks)
            if (diffs <= 0).any():
                pos = int(np.nonzero(diffs <= 0)[0][0]) + 1
                out.append(Violation(
                    "send-conflict",
                    f"operand {name!r} round {r} (offset {d}): device {src} "
                    f"delivers block {int(blocks[pos])} to device {dst} at "
                    f"position {pos}, not strictly after block "
                    f"{int(blocks[pos - 1])} — two sends land in one logical "
                    f"receive slot",
                    dict(operand=name, round=r, offset=d, src=src, dst=dst,
                         pos=pos, block=int(blocks[pos])),
                ))
            for pos, g in enumerate(blocks):
                g = int(g)
                if g < 0:
                    continue
                if owner[g] == dst:
                    out.append(Violation(
                        "send-conflict",
                        f"operand {name!r} round {r} (offset {d}): block {g} "
                        f"is delivered to device {dst}, which already owns "
                        f"it — the delivery aliases the resident store",
                        dict(operand=name, round=r, offset=d, src=src,
                             dst=dst, pos=pos, block=g),
                    ))
                elif g in seen[dst]:
                    out.append(Violation(
                        "send-conflict",
                        f"operand {name!r} round {r} (offset {d}): block {g} "
                        f"was already delivered to device {dst} in round "
                        f"{seen[dst][g]}",
                        dict(operand=name, round=r, offset=d, src=src,
                             dst=dst, pos=pos, block=g,
                             first_round=seen[dst][g]),
                    ))
                else:
                    seen[dst][g] = r


def _remote_refs(plan: SpgemmPlan, name: str):
    """Per-device remote operand references: (device, task slot, global task,
    round, sender, position-in-round) rows for every valid task whose
    operand index addresses a receive buffer."""
    offsets = plan.a_offsets if name == "a" else plan.b_offsets
    send = plan.a_send if name == "a" else plan.b_send
    cap = plan.a_cap if name == "a" else plan.b_cap
    task_x = plan.task_a if name == "a" else plan.task_b
    widths = [np.asarray(send[d]).shape[1] for d in offsets]
    bounds = np.concatenate([[cap], cap + np.cumsum(widths)]).astype(np.int64)
    rows = []
    for p in range(plan.nparts):
        cnt = int(plan.task_count[p])
        tx = task_x[p, :cnt].astype(np.int64)
        gid = plan.task_gidx[p, :cnt].astype(np.int64)
        remote = np.nonzero(tx >= cap)[0]
        if not remote.size:
            continue
        r = np.searchsorted(bounds, tx[remote], side="right") - 1
        r = np.clip(r, 0, max(len(offsets) - 1, 0))
        pos = tx[remote] - bounds[r]
        for t, rr, pp in zip(remote, r, pos):
            rr = int(rr)
            d = int(offsets[rr]) if rr < len(offsets) else -1
            src = (p - d) % plan.nparts if d >= 0 else -1
            rows.append((p, int(t), int(gid[t]), rr, src, int(pp)))
    return rows, widths


# ---------------------------------------------------------------------------
# the SpgemmPlan verifier
# ---------------------------------------------------------------------------


def verify_spgemm_plan(
    plan: SpgemmPlan,
    *,
    expected_a_owner: np.ndarray | None = None,
    expected_b_owner: np.ndarray | None = None,
    check_spans: bool = True,
    max_violations: int = 64,
) -> list[Violation]:
    """Re-prove every scheduling invariant of one multiply plan.

    Returns the (possibly empty) list of violations; callers that want an
    exception raise :class:`PlanError` on a non-empty report (the plan-cache
    admission hook in :mod:`repro.core.cache` does).
    """
    out: list[Violation] = []
    P = int(plan.nparts)
    tasks = plan.tasks
    nt = int(tasks.num_tasks)

    inv_a = _check_layout("a", plan.a_owner, plan.a_slot, plan.a_cap,
                          expected_a_owner, P, out,
                          store_idx=plan.a_store_idx,
                          store_valid=plan.a_store_valid)
    inv_b = _check_layout("b", plan.b_owner, plan.b_slot, plan.b_cap,
                          expected_b_owner, P, out,
                          store_idx=plan.b_store_idx,
                          store_valid=plan.b_store_valid)
    inv_c = _check_layout("c", plan.c_owner, plan.c_slot, plan.c_cap,
                          None, P, out,
                          store_idx=plan.c_store_idx,
                          store_valid=plan.c_store_valid)
    if inv_a is None or inv_b is None or inv_c is None:
        return out[:max_violations]

    if plan.exchange == "p2p":
        _check_rounds("a", plan.a_offsets, plan.a_send, plan.a_send_count,
                      inv_a, np.asarray(plan.a_owner), P, out)
        _check_rounds("b", plan.b_offsets, plan.b_send, plan.b_send_count,
                      inv_b, np.asarray(plan.b_owner), P, out)
        buf_a, widths_a = _staged_buffer(inv_a, plan.a_cap, plan.a_offsets,
                                         plan.a_send, plan.a_send_count, P)
        buf_b, widths_b = _staged_buffer(inv_b, plan.b_cap, plan.b_offsets,
                                         plan.b_send, plan.b_send_count, P)
    else:  # allgather baseline: [owner0 store | owner1 store | ...]
        buf_a = inv_a.reshape(1, -1).repeat(P, axis=0)
        buf_b = inv_b.reshape(1, -1).repeat(P, axis=0)
        widths_a, widths_b = [], []

    # -- task addressing, placement and accumulation chains -----------------
    c_owner = np.asarray(plan.c_owner)
    c_slot = np.asarray(plan.c_slot)
    cover = np.zeros(nt, dtype=np.int64)
    for p in range(P):
        cnt = int(plan.task_count[p])
        if cnt > plan.t_cap:
            out.append(Violation(
                "capacity-mismatch",
                f"device {p} schedules {cnt} tasks over task capacity "
                f"{plan.t_cap}",
                dict(device=p, count=cnt, t_cap=int(plan.t_cap)),
            ))
            cnt = int(plan.t_cap)
        gid = plan.task_gidx[p, :cnt].astype(np.int64)
        bad_gid = (gid < 0) | (gid >= nt)
        if bad_gid.any():
            t = int(np.nonzero(bad_gid)[0][0])
            out.append(Violation(
                "task-gidx",
                f"device {p} task slot {t} references global task "
                f"{int(gid[t])} outside the {nt}-task list",
                dict(device=p, slot=t, task=int(gid[t])),
            ))
            gid = np.clip(gid, 0, max(nt - 1, 0))
        if nt:
            cover += np.bincount(gid, minlength=nt)
        ga = tasks.a_idx[gid] if nt else gid
        gb = tasks.b_idx[gid] if nt else gid
        gc = tasks.c_idx[gid] if nt else gid

        if cnt and (c_owner[gc] != p).any():
            t = int(np.nonzero(c_owner[gc] != p)[0][0])
            out.append(Violation(
                "task-placement",
                f"device {p} task slot {t} computes C block {int(gc[t])} "
                f"owned by device {int(c_owner[gc[t]])} — owner-of-C is "
                f"violated",
                dict(device=p, slot=t, task=int(gid[t]), c_block=int(gc[t])),
            ))

        for name, task_x, buf, gx in (("a", plan.task_a, buf_a, ga),
                                      ("b", plan.task_b, buf_b, gb)):
            tx = task_x[p].astype(np.int64)
            oob = (tx < 0) | (tx >= buf.shape[1])
            if oob.any():
                t = int(np.nonzero(oob)[0][0])
                out.append(Violation(
                    "src-off-oob",
                    f"device {p} task slot {t}: operand {name!r} index "
                    f"{int(tx[t])} outside the staged buffer of "
                    f"{buf.shape[1]} rows",
                    dict(operand=name, device=p, slot=t, index=int(tx[t])),
                ))
            got = buf[p, np.clip(tx[:cnt], 0, buf.shape[1] - 1)]
            bad = (got != gx[:cnt]) | oob[:cnt]
            for t in np.nonzero(bad)[0][:4]:
                t = int(t)
                want = int(gx[t])
                delivered = bool((buf[p] == want).any())
                out.append(Violation(
                    "operand-mismatch" if delivered else "use-before-receive",
                    f"device {p} task slot {t} (global task {int(gid[t])}) "
                    f"reads operand {name!r} buffer row {int(tx[t])} which "
                    + (f"holds block {int(got[t])}, not block {want}"
                       if delivered and int(got[t]) >= 0 else
                       f"no exchange round ever delivers block {want} to")
                    + f" device {p}",
                    dict(operand=name, device=p, slot=t, task=int(gid[t]),
                         block=want, index=int(tx[t])),
                ))

        # accumulation race detector: one ordered chain per output slot
        tc = plan.task_c[p].astype(np.int64)
        if cnt:
            expect_tc = c_slot[gc]
            if (tc[:cnt] != expect_tc).any():
                t = int(np.nonzero(tc[:cnt] != expect_tc)[0][0])
                out.append(Violation(
                    "c-slot-race",
                    f"device {p} task slot {t} accumulates into output row "
                    f"{int(tc[t])} but its C block {int(gc[t])} lives in "
                    f"slot {int(expect_tc[t])} — the contribution lands in "
                    f"another block's accumulation chain",
                    dict(device=p, slot=t, task=int(gid[t]),
                         c_block=int(gc[t]), got=int(tc[t]),
                         expected=int(expect_tc[t])),
                ))
            # one definition of the kernel's zero-on-slot-change contract,
            # shared with the fused engine that relies on it
            from ..kernels.fused_leaf import first_accumulation_hazard

            hazard = first_accumulation_hazard(tc[:cnt])
            if hazard is not None:
                t = hazard
                out.append(Violation(
                    "c-slot-order",
                    f"device {p} task slot {t} revisits output row "
                    f"{int(tc[t])} after row {int(tc[t - 1])} — the fused "
                    f"grid zeroes its accumulator on every slot change, so "
                    f"the earlier chain's contributions are overwritten",
                    dict(device=p, slot=t, task=int(gid[t]),
                         c_slot=int(tc[t])),
                ))
            else:
                same = tc[1:cnt] == tc[:cnt - 1]
                mixed = same & (gc[1:] != gc[:-1])
                if mixed.any():
                    t = int(np.nonzero(mixed)[0][0]) + 1
                    out.append(Violation(
                        "c-slot-race",
                        f"device {p} output row {int(tc[t])} accumulates "
                        f"two different C blocks ({int(gc[t - 1])} and "
                        f"{int(gc[t])}) — two chains race into one slot",
                        dict(device=p, slot=t, c_slot=int(tc[t]),
                             blocks=[int(gc[t - 1]), int(gc[t])]),
                    ))
                unstable = same & (gid[1:] <= gid[:-1]) & (gc[1:] == gc[:-1])
                if unstable.any():
                    t = int(np.nonzero(unstable)[0][0]) + 1
                    out.append(Violation(
                        "accumulation-order",
                        f"device {p} task slots {t - 1},{t} accumulate C "
                        f"block {int(gc[t])} with global tasks "
                        f"{int(gid[t - 1])},{int(gid[t])} out of symbolic "
                        f"order — fp32 accumulation order (and result bits "
                        f"under re-layout) is no longer deterministic",
                        dict(device=p, slot=t, c_slot=int(tc[t]),
                             tasks=[int(gid[t - 1]), int(gid[t])]),
                    ))
        # padded task slots must redirect to the trash row
        if (tc[cnt:] != plan.c_cap).any():
            t = cnt + int(np.nonzero(tc[cnt:] != plan.c_cap)[0][0])
            out.append(Violation(
                "mask-redirect",
                f"device {p} padded task slot {t} writes output row "
                f"{int(tc[t])} instead of the trash row {plan.c_cap} — a "
                f"masked/padded task would corrupt a live output block",
                dict(device=p, slot=t, got=int(tc[t]),
                     trash=int(plan.c_cap)),
            ))

    if nt and not (cover == 1).all():
        g = int(np.nonzero(cover != 1)[0][0])
        out.append(Violation(
            "task-gidx",
            f"global task {g} is scheduled {int(cover[g])} times across the "
            f"mesh (every task must run exactly once)",
            dict(task=g, times=int(cover[g])),
        ))

    # fused (src, off) decomposition must recompose within true capacities
    if plan.exchange == "p2p" and plan.task_a_src is not None:
        for name, task_x, src_x, off_x, cap, widths in (
            ("a", plan.task_a, plan.task_a_src, plan.task_a_off,
             plan.a_cap, widths_a),
            ("b", plan.task_b, plan.task_b_src, plan.task_b_off,
             plan.b_cap, widths_b),
        ):
            caps = np.array([cap] + list(widths), dtype=np.int64)
            starts = np.concatenate([[0], np.cumsum(caps)[:-1]]).astype(np.int64)
            src = np.asarray(src_x, dtype=np.int64)
            off = np.asarray(off_x, dtype=np.int64)
            bad_src = (src < 0) | (src >= caps.shape[0])
            src_c = np.clip(src, 0, caps.shape[0] - 1)
            bad = bad_src | (off < 0) | (off >= caps[src_c]) | (
                starts[src_c] + off != np.asarray(task_x, dtype=np.int64))
            if bad.any():
                p, t = [int(x[0]) for x in np.nonzero(bad)]
                out.append(Violation(
                    "src-off-oob",
                    f"device {p} task slot {t}: fused operand {name!r} "
                    f"address (src={int(src[p, t])}, off={int(off[p, t])}) "
                    f"does not resolve inside "
                    + ("the own store" if int(src_c[p, t]) == 0 else
                       f"receive buffer {int(src_c[p, t]) - 1}")
                    + f" of capacity {int(caps[src_c[p, t]])} at buffer row "
                    f"{int(task_x[p, t])}",
                    dict(operand=name, device=p, slot=t,
                         src=int(src[p, t]), off=int(off[p, t]),
                         index=int(np.asarray(task_x)[p, t])),
                ))

    # masked/delta safety for every reachable mask: the memoized send spans
    # must cover each (task, remote operand) pair
    if check_spans and plan.exchange == "p2p" and nt:
        from ..core.distributed import _send_task_spans

        maps = _send_task_spans(plan)
        for name in ("a", "b"):
            offsets = plan.a_offsets if name == "a" else plan.b_offsets
            rows, widths = _remote_refs(plan, name)
            for p, t, g, r, src, pos in rows:
                if r >= len(offsets) or pos >= widths[r]:
                    continue  # already reported as src-off-oob
                starts, cat = maps[(name, int(offsets[r]))]
                s0 = starts[src * widths[r] + pos]
                s1 = starts[src * widths[r] + pos + 1]
                if g not in cat[s0:s1]:
                    out.append(Violation(
                        "exchange-starvation",
                        f"device {p} global task {g} reads operand {name!r} "
                        f"from round {r} send slot (src={src}, pos={pos}) "
                        f"but the memoized send-task span omits it — a "
                        f"delta mask keeping only this task would prune the "
                        f"delivery it depends on",
                        dict(operand=name, device=p, task=g, round=r,
                             src=src, pos=pos),
                    ))
                    if len(out) >= max_violations:
                        return out[:max_violations]

    return out[:max_violations]


def verify_task_mask(plan: SpgemmPlan, task_on: np.ndarray) -> list[Violation]:
    """Prove one concrete delta mask safe: every kept task's remote operands
    survive the pruned exchange (send keep masks + live rounds)."""
    from ..core.distributed import _exchange_keep_masks

    out: list[Violation] = []
    task_on = np.asarray(task_on).astype(bool)
    a_keeps, b_keeps, live_a, live_b, _ = _exchange_keep_masks(plan, task_on)
    for name, keeps, live in (("a", a_keeps, live_a), ("b", b_keeps, live_b)):
        rows, widths = _remote_refs(plan, name)
        for p, t, g, r, src, pos in rows:
            if not task_on[g] or r >= len(keeps) or pos >= widths[r]:
                continue
            if r not in live:
                out.append(Violation(
                    "exchange-starvation",
                    f"kept task {g} on device {p} reads operand {name!r} "
                    f"from round {r}, which the mask drops entirely",
                    dict(operand=name, device=p, task=g, round=r),
                ))
            elif not keeps[r][src, pos]:
                out.append(Violation(
                    "exchange-starvation",
                    f"kept task {g} on device {p} reads operand {name!r} "
                    f"from round {r} send slot (src={src}, pos={pos}), "
                    f"which the mask prunes to zero payload",
                    dict(operand=name, device=p, task=g, round=r,
                         src=src, pos=pos),
                ))
    return out


# ---------------------------------------------------------------------------
# relayout (transpose / repartition) and norm-table verification
# ---------------------------------------------------------------------------


def verify_relayout_plan(payload: dict) -> list[Violation]:
    """Verify a :func:`repro.dist.collectives._relayout_gather_plan` product
    (transpose / repartition executables retain the host-side arrays)."""
    out: list[Violation] = []
    P = int(payload["nparts"])
    x_owner = np.asarray(payload["x_owner"])
    x_slot = np.asarray(payload["x_slot"])
    x_cap = int(payload["x_cap"])
    src = np.asarray(payload["src"], dtype=np.int64)
    out_owner = np.asarray(payload["out_owner"])
    out_slot = np.asarray(payload["out_slot"])
    out_cap = int(payload["out_cap"])
    offsets = payload["offsets"]
    send, send_cnt = payload["send"], payload["send_cnt"]
    gidx = np.asarray(payload["gidx"])
    gval = np.asarray(payload["gval"])
    kind = payload.get("label", "relayout")

    inv_x = _check_layout(f"{kind}:src", x_owner, x_slot, x_cap, None, P, out)
    inv_o = _check_layout(f"{kind}:out", out_owner, out_slot, out_cap, None,
                          P, out)
    if inv_x is None or inv_o is None:
        return out
    _check_rounds(f"{kind}:src", offsets, send, send_cnt, inv_x, x_owner, P,
                  out)
    buf, _ = _staged_buffer(inv_x, x_cap, offsets, send, send_cnt, P)
    n_out = out_owner.shape[0]
    if src.shape[0] != n_out:
        out.append(Violation(
            "capacity-mismatch",
            f"{kind}: gather permutation covers {src.shape[0]} blocks for "
            f"{n_out} outputs",
            dict(kind=kind),
        ))
        return out
    for p in range(P):
        mine = np.nonzero(out_owner == p)[0]
        for local, o in enumerate(mine):
            if local >= out_cap or gval[p, local] != 1.0:
                out.append(Violation(
                    "gather-gap",
                    f"{kind}: output block {int(o)} (device {p} slot "
                    f"{local}) has no gather source — it would materialize "
                    f"as zeros",
                    dict(kind=kind, device=p, slot=int(local), block=int(o)),
                ))
                continue
            want = int(src[o])
            idx = int(gidx[p, local])
            got = int(buf[p, idx]) if 0 <= idx < buf.shape[1] else -1
            if got != want:
                delivered = bool((buf[p] == want).any())
                out.append(Violation(
                    "operand-mismatch" if delivered else "use-before-receive",
                    f"{kind}: output block {int(o)} on device {p} gathers "
                    f"buffer row {idx} which "
                    + (f"holds block {got}, not block {want}" if delivered
                       and got >= 0 else
                       f"no exchange round ever delivers block {want} to")
                    + f" device {p}",
                    dict(kind=kind, device=p, slot=int(local),
                         block=int(o), source=want, index=idx),
                ))
        # padding slots must be masked out by gval
        pad = np.nonzero(gval[p, len(mine):] != 0.0)[0]
        if pad.size:
            s = int(len(mine) + pad[0])
            out.append(Violation(
                "mask-redirect",
                f"{kind}: device {p} padding slot {s} has gather weight "
                f"{float(gval[p, s])} — padding must contribute zeros",
                dict(kind=kind, device=p, slot=s),
            ))
    return out


def verify_norm_table(payload: dict) -> list[Violation]:
    """Verify a norm-table scatter map: each resident block's norm lands at
    its global index exactly once; padding lands in the trash position."""
    out: list[Violation] = []
    P = int(payload["nparts"])
    gpos = np.asarray(payload["gpos"])
    owner = np.asarray(payload["owner"])
    slot = np.asarray(payload["slot"])
    nnzb = int(payload["nnzb"])
    cap = int(payload["cap"])
    if gpos.shape != (P, cap):
        out.append(Violation(
            "norm-scatter",
            f"norm table scatter map has shape {gpos.shape}, layout says "
            f"({P}, {cap})",
            dict(),
        ))
        return out
    want = np.full((P, cap), nnzb, dtype=np.int64)
    if nnzb:
        want[owner, slot] = np.arange(nnzb)
    if not np.array_equal(gpos, want):
        p, s = [int(x[0]) for x in np.nonzero(gpos != want)]
        out.append(Violation(
            "norm-scatter",
            f"norm table scatter: device {p} slot {s} writes position "
            f"{int(gpos[p, s])}, layout says {int(want[p, s])} — a block "
            f"norm would land on the wrong row (or clobber the trash row)",
            dict(device=p, slot=s, got=int(gpos[p, s]),
                 expected=int(want[p, s])),
        ))
    return out


# ---------------------------------------------------------------------------
# structure-union add and compaction verification
# ---------------------------------------------------------------------------


def verify_add_plan(payload: dict) -> list[Violation]:
    """Verify a :class:`repro.dist.collectives.AddExecutable` plan.

    Re-proves the union structure (A wins ownership on overlap, so A blocks
    never move; B-only blocks stay put), both operands' exchange rounds, and
    that every ``(idx, val)`` gather pair resolves — in the staged
    ``[own store | recv per round]`` buffer — to exactly the source block
    the union position demands, with padding masked to zero weight.
    """
    out: list[Violation] = []
    P = int(payload["nparts"])
    pos_a = np.asarray(payload["pos_a"], dtype=np.int64)
    pos_b = np.asarray(payload["pos_b"], dtype=np.int64)
    from_a = np.asarray(payload["from_a"], dtype=np.int64)
    from_b = np.asarray(payload["from_b"], dtype=np.int64)
    c_owner = np.asarray(payload["c_owner"])
    c_cap = int(payload["c_cap"])
    nc = c_owner.shape[0]

    inv_a = _check_layout("add:a", payload["a_owner"], payload["a_slot"],
                          int(payload["a_cap"]), None, P, out)
    inv_b = _check_layout("add:b", payload["b_owner"], payload["b_slot"],
                          int(payload["b_cap"]), None, P, out)
    inv_c = _check_layout("add:c", c_owner, payload["c_slot"], c_cap,
                          None, P, out)
    if inv_a is None or inv_b is None or inv_c is None:
        return out

    a_owner = np.asarray(payload["a_owner"])
    b_owner = np.asarray(payload["b_owner"])
    # union positions partition into {A (wins overlap), B-only}; each source
    # block appears exactly once and ownership is inherited (add is
    # communication-minimal: only overlap copies of B move)
    for name, pos, frm, n_src in (("a", pos_a, from_a, a_owner.shape[0]),
                                  ("b", pos_b, from_b, b_owner.shape[0])):
        if pos.shape[0] != n_src or (n_src and (
                (pos < 0) | (pos >= nc)).any()):
            out.append(Violation(
                "add-union",
                f"add: operand {name!r} union positions do not map its "
                f"{n_src} blocks into the {nc}-block union",
                dict(operand=name),
            ))
            return out
        back = np.nonzero(frm >= 0)[0]
        if not np.array_equal(np.sort(frm[back]), np.arange(n_src)):
            out.append(Violation(
                "add-union",
                f"add: operand {name!r} source map does not cover each of "
                f"its {n_src} blocks exactly once — a block would be "
                f"dropped or double-counted",
                dict(operand=name),
            ))
    if (c_owner[pos_a] != a_owner).any():
        i = int(np.nonzero(c_owner[pos_a] != a_owner)[0][0])
        out.append(Violation(
            "add-union",
            f"add: union block {int(pos_a[i])} does not inherit A block "
            f"{i}'s owner (A wins overlap so A blocks never move); got "
            f"device {int(c_owner[pos_a[i]])}, A owner {int(a_owner[i])}",
            dict(block=int(pos_a[i]), a_block=i),
        ))
    b_only = from_a[pos_b] < 0
    if b_only.any() and (c_owner[pos_b[b_only]] != b_owner[b_only]).any():
        j = int(np.nonzero(b_only & (c_owner[pos_b] != b_owner))[0][0])
        out.append(Violation(
            "add-union",
            f"add: B-only union block {int(pos_b[j])} does not inherit B "
            f"block {j}'s owner — a block with no overlap partner moved",
            dict(block=int(pos_b[j]), b_block=j),
        ))

    _check_rounds("add:a", payload["a_offsets"], payload["a_send"],
                  payload["a_send_cnt"], inv_a, a_owner, P, out)
    _check_rounds("add:b", payload["b_offsets"], payload["b_send"],
                  payload["b_send_cnt"], inv_b, b_owner, P, out)
    buf_a, _ = _staged_buffer(inv_a, int(payload["a_cap"]),
                              payload["a_offsets"], payload["a_send"],
                              payload["a_send_cnt"], P)
    buf_b, _ = _staged_buffer(inv_b, int(payload["b_cap"]),
                              payload["b_offsets"], payload["b_send"],
                              payload["b_send_cnt"], P)

    idx = dict(a=np.asarray(payload["idx_a"]), b=np.asarray(payload["idx_b"]))
    val = dict(a=np.asarray(payload["val_a"]), b=np.asarray(payload["val_b"]))
    frm = dict(a=from_a, b=from_b)
    buf = dict(a=buf_a, b=buf_b)
    for p in range(P):
        mine = np.nonzero(c_owner == p)[0]  # ascending == slot order
        for name in ("a", "b"):
            for local in range(c_cap):
                want = int(frm[name][mine[local]]) if local < mine.size else -1
                v = float(val[name][p, local])
                if want < 0:
                    if v != 0.0:
                        out.append(Violation(
                            "mask-redirect",
                            f"add: device {p} output slot {local} has "
                            f"operand {name!r} weight {v} but no source "
                            f"block — padding / absent operands must "
                            f"contribute zeros",
                            dict(operand=name, device=p, slot=local),
                        ))
                    continue
                i = int(idx[name][p, local])
                got = int(buf[name][p, i]) if 0 <= i < buf[name].shape[1] \
                    else -1
                if v != 1.0 or got != want:
                    delivered = bool((buf[name][p] == want).any())
                    out.append(Violation(
                        "operand-mismatch" if delivered
                        else "use-before-receive",
                        f"add: device {p} output slot {local} gathers "
                        f"operand {name!r} buffer row {i} which "
                        + (f"holds block {got}, not block {want}"
                           if delivered and got >= 0 else
                           f"no exchange round ever delivers block {want} "
                           f"to")
                        + f" device {p} (weight {v})",
                        dict(operand=name, device=p, slot=local,
                             source=want, index=i),
                    ))
    return out


def verify_compact_plan(payload: dict) -> list[Violation]:
    """Verify a :func:`repro.dist.collectives._compact_to_kept` gather map.

    Compaction must be communication-free (kept blocks keep their owners,
    slots close ranks in kept order) and each new slot must gather exactly
    its kept block's old store slot, with padding masked to zero weight.
    """
    out: list[Violation] = []
    P = int(payload["nparts"])
    kind = payload.get("label", "compact")
    a_owner = np.asarray(payload["a_owner"])
    a_slot = np.asarray(payload["a_slot"])
    kept = np.asarray(payload["kept"], dtype=np.int64)
    new_owner = np.asarray(payload["new_owner"])
    new_cap = int(payload["new_cap"])
    gidx = np.asarray(payload["gidx"])
    gval = np.asarray(payload["gval"])

    na = a_owner.shape[0]
    if kept.size and ((kept < 0) | (kept >= na)).any():
        i = int(np.nonzero((kept < 0) | (kept >= na))[0][0])
        out.append(Violation(
            "owner-map",
            f"{kind}: kept entry {i} references block {int(kept[i])} "
            f"outside the {na}-block source structure",
            dict(kind=kind, pos=i, block=int(kept[i])),
        ))
        return out
    if _check_layout(f"{kind}:src", a_owner, a_slot, int(payload["a_cap"]),
                     None, P, out) is None:
        return out
    if _check_layout(f"{kind}:out", new_owner, payload["new_slot"], new_cap,
                     a_owner[kept], P, out) is None:
        return out

    for p in range(P):
        mine = np.nonzero(new_owner == p)[0]  # ascending == slot order
        for local in range(new_cap):
            if local >= mine.size:
                if float(gval[p, local]) != 0.0:
                    out.append(Violation(
                        "mask-redirect",
                        f"{kind}: device {p} padding slot {local} has "
                        f"gather weight {float(gval[p, local])} — padding "
                        f"must contribute zeros",
                        dict(kind=kind, device=p, slot=local),
                    ))
                continue
            src = int(kept[mine[local]])
            want = int(a_slot[src])
            got = int(gidx[p, local])
            if float(gval[p, local]) != 1.0 or got != want:
                out.append(Violation(
                    "operand-mismatch",
                    f"{kind}: device {p} new slot {local} gathers old "
                    f"store row {got} (weight {float(gval[p, local])}), "
                    f"kept block {src} lives in slot {want} — compaction "
                    f"would materialize the wrong block",
                    dict(kind=kind, device=p, slot=local, block=src,
                         got=got, expected=want),
                ))
    return out


# ---------------------------------------------------------------------------
# cache-admission dispatcher
# ---------------------------------------------------------------------------


def verify_payload(payload: dict) -> list[Violation]:
    kind = payload.get("kind")
    if kind == "relayout":
        return verify_relayout_plan(payload)
    if kind == "norm-table":
        return verify_norm_table(payload)
    if kind == "add":
        return verify_add_plan(payload)
    if kind == "compact":
        return verify_compact_plan(payload)
    return []


def verify_value(key, value) -> list[Violation] | None:
    """Verify whatever a plan-cache builder returned.

    Returns ``None`` when the value carries nothing verifiable (symbolic
    task lists, scalar reductions, ...), else the violation report.  Plans
    appear directly or inside (plan, executable) tuples; relayout and
    norm-table executables retain their host-side plan arrays in a
    ``_verify_plan`` payload dict.
    """
    items = list(value) if isinstance(value, (tuple, list)) else [value]
    report: list[Violation] | None = None
    for item in items:
        if isinstance(item, SpgemmPlan):
            found = verify_spgemm_plan(item)
        else:
            payload = getattr(item, "_verify_plan", None)
            if payload is None:
                continue
            found = verify_payload(payload)
        report = (report if report is not None else []) + found
    return report
