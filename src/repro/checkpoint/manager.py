"""Fault-tolerant checkpointing: atomic commits, async save, elastic restore.

Layout per step::

    <dir>/step_000123/arrays.npz      flat {path: array} of the state pytree
    <dir>/step_000123/MANIFEST.json   committed LAST -> crash-safe marker

A checkpoint exists iff its manifest exists; partially written directories
(crash mid-save) are ignored by restore and cleaned by the manager.  Arrays
are stored *unsharded* with the state's logical-axes metadata, so restore can
re-shard onto any mesh shape (elastic scaling: see runtime/elastic.py).  On a
real multi-host pod each host would write its shard of the FSDP axis; the
single-process layout here keeps the same manifest protocol.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_MANIFEST = "MANIFEST.json"


def _flatten(state):
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save_checkpoint(directory: str, step: int, state, *, extra: dict | None = None) -> str:
    """Atomic save: arrays first, manifest last (commit point)."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    """Largest step with a committed manifest; ignores torn writes."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like`` (shapes validated).

    ``state_like`` may hold arrays or ShapeDtypeStructs; returns (state, step).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    with np.load(os.path.join(_step_dir(directory, step), "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    leaves = []
    for path, like in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(like.shape), (key, arr.shape, like.shape)
        leaves.append(arr.astype(like.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, step


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async (background) save."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = (
            concurrent.futures.ThreadPoolExecutor(max_workers=1) if async_save else None
        )
        self._pending: concurrent.futures.Future | None = None

    def save(self, step: int, state, extra: dict | None = None):
        state = jax.tree.map(np.asarray, state)  # snapshot off-device

        def work():
            save_checkpoint(self.directory, step, state, extra=extra)
            self._gc()

        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(work)
        else:
            work()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(_step_dir(self.directory, s), ignore_errors=True)

    def restore_latest(self, state_like):
        return restore_checkpoint(self.directory, state_like)
