"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeSpec

ARCH_IDS = [
    "qwen2-72b",
    "qwen2-0.5b",
    "olmo-1b",
    "stablelm-1.6b",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "hubert-xlarge",
    "paligemma-3b",
    "recurrentgemma-9b",
    "mamba2-370m",
]


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}"
    )
    return mod.CONFIG


def reduced_config(name: str) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims (CPU-runnable)."""
    import dataclasses

    cfg = get_config(name)
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads else 0
    pat_len = len(cfg.block_pattern)
    layers = max(2 * pat_len, 4)
    if pat_len > 1:
        layers = pat_len + 2  # one full period + remainder coverage
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 8),
        top_k=min(cfg.top_k, 2),
        d_inner=128 if cfg.d_inner else 0,
        ssm_heads=4 if cfg.ssm_heads else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        window=16 if cfg.window else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        num_patches=4 if cfg.num_patches else 0,
        remat="none",
        grad_accum=1,
        moe_capacity_factor=8.0,  # ~dropless at smoke scale (parity tests)
    )


__all__ = ["ARCH_IDS", "get_config", "reduced_config", "ArchConfig", "SHAPES", "ShapeSpec"]
