"""ArchConfig schema + the input-shape set shared by all LM architectures."""

from __future__ import annotations

import dataclasses

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention / norm / mlp options
    qkv_bias: bool = False
    mlp_act: str = "silu"  # silu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    positions: str = "rope"  # rope | sinusoidal | none
    # block structure
    block_pattern: tuple[str, ...] = ("attn",)  # attn | local | rec | ssm
    window: int = 0  # local attention window
    kind: str = "decoder"  # decoder | encoder
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25  # train/prefill; decode is dropless
    # SSM (mamba2)
    d_inner: int = 0
    ssm_heads: int = 0
    ssm_state: int = 0
    # modality stub frontends
    frontend: str = "none"  # none | audio_stub | vision_stub
    frontend_dim: int = 0  # audio frame feature dim
    num_patches: int = 0  # vision prefix length
    # capability flags
    sub_quadratic: bool = False  # can run long_500k
    # training defaults (overridable per shape at launch)
    remat: str = "full"  # none | full | dots
    grad_accum: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def supports(self, shape: ShapeSpec) -> tuple[bool, str]:
        """Whether a shape cell applies (per spec skips, DESIGN.md §4)."""
        if shape.kind == "decode" and self.kind == "encoder":
            return False, "encoder-only arch has no decode step"
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "pure full-attention arch: 500k decode is not sub-quadratic"
        return True, ""

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        n = v * d  # embedding
        if not self.tie_embeddings and self.kind != "encoder":
            n += d * v
        if self.kind == "encoder":
            n += d * v
        per = {}
        per["attn"] = d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2
        per["local"] = per["attn"]
        gated = 2 if self.mlp_act in ("silu", "geglu") else 1
        mlp = d * ff * (gated + 1)
        dh = d // max(self.num_heads, 1)
        per["rec"] = 3 * d * d + self.num_heads * dh * dh * 2
        if self.d_inner:
            per["ssm"] = (
                d * (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads)
                + self.d_inner * d
            )
        pattern = self.block_pattern
        for i in range(self.num_layers):
            kind = pattern[i % len(pattern)]
            n += per[kind]
            if kind in ("attn", "local"):
                if self.is_moe:
                    n += self.num_experts * d * ff * (gated + 1) + d * self.num_experts
                else:
                    n += mlp
            elif kind == "rec":
                n += mlp
        return n

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for MODEL_FLOPS)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        gated = 2 if self.mlp_act in ("silu", "geglu") else 1
        dense_total = self.param_count() - self.num_layers * self.num_experts * d * ff * (
            gated + 1
        )
        return dense_total + self.num_layers * self.top_k * d * ff * (gated + 1)

    def flops_param_count(self) -> int:
        """Params participating in matmuls (MODEL_FLOPS = 6*this*tokens).

        The input embedding is a gather, not a matmul: subtract it unless
        tied (tied tables run in the head matmul).  For encoders the unused
        token table is excluded too."""
        n = self.active_param_count()
        if not self.tie_embeddings or self.kind == "encoder":
            n -= self.vocab_size * self.d_model
        return n
