"""HuBERT-XLarge [arXiv:2106.07447; unverified]: encoder-only audio backbone.

Modality frontend (conv feature extractor) is a STUB: input_specs() provides
precomputed 512-d frame embeddings (per assignment spec).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
    mlp_act="gelu", norm="layernorm", kind="encoder",
    positions="sinusoidal", frontend="audio_stub", frontend_dim=512,
)
