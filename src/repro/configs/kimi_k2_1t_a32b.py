"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified]: MoE 384 experts top-8.

Assigned table prescribes GQA kv=8 (not MLA); expert d_ff=2048.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
    num_heads=64, num_kv_heads=8, head_dim=112, d_ff=2048,
    vocab_size=163840, mlp_act="silu", norm="rmsnorm",
    num_experts=384, top_k=8, rope_theta=5e4, grad_accum=8,
)
