"""Mamba2-370M [arXiv:2405.21060; unverified]: attention-free SSD.

d_inner = 2*d_model, headdim 64 -> 32 ssm heads; d_state 128.
Sub-quadratic: long_500k runs (O(1) decode state).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    norm="rmsnorm", tie_embeddings=True, block_pattern=("ssm",),
    positions="none", d_inner=2048, ssm_heads=32, ssm_state=128,
    sub_quadratic=True,
)
