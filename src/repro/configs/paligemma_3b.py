"""PaliGemma-3B [arXiv:2407.07726; hf]: SigLIP (stubbed) + Gemma decoder.

Prefix-LM attention: image patches + prompt attend bidirectionally, suffix
is causal.  input_specs() provides precomputed patch embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
    vocab_size=257216, mlp_act="geglu", norm="rmsnorm",
    tie_embeddings=True, rope_theta=1e4, frontend="vision_stub",
    num_patches=256,
)
