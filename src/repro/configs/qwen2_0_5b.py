"""Qwen2-0.5B [arXiv:2407.10671; hf]: dense, GQA kv=2, QKV bias, tied embeds."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, head_dim=64, d_ff=4864,
    vocab_size=151936, qkv_bias=True, mlp_act="silu", norm="rmsnorm",
    tie_embeddings=True, rope_theta=1e6,
)
