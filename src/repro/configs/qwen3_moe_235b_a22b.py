"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-*; hf]: MoE 128 experts top-8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    vocab_size=151936, mlp_act="silu", norm="rmsnorm",
    num_experts=128, top_k=8, rope_theta=1e6, grad_accum=4,
)
