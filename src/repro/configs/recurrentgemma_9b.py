"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].

Pattern 1:2 — (rec, rec, local-attn) repeating; RG-LRU recurrence; local
attention window 2048; MQA kv=1.  Sub-quadratic: long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, mlp_act="geglu", norm="rmsnorm",
    tie_embeddings=True, block_pattern=("rec", "rec", "local"),
    window=2048, rope_theta=1e4, sub_quadratic=True, grad_accum=4,
)
