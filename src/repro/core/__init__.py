"""Core: the paper's contribution — quadtree block-sparse matrix algebra.

Chunks = BSMatrix (host Morton structure + device block stacks);
Tasks   = host symbolic phases + device grouped-GEMM numeric phases;
the CHT runtime's dynamic scheduling maps to the locality-aware static
schedules in :mod:`repro.core.schedule` / :mod:`repro.core.distributed`.
"""

from .add import add, add_scaled_identity, identity
from .cache import SymbolicCache
from .inverse import (
    InverseStats,
    RefineMonitor,
    factorization_residual,
    inv_chol,
    localized_inverse_factorization,
    submatrix,
)
from .leaf import LeafSpec, exact_spgemm_flops, inner_masks, nnz_elements
from .matrix import BSMatrix
from .purify import sp2_purify
from .quadtree import QuadtreeIndex, build_quadtree_index, structure_fingerprint
from .spgemm import (
    Tasks,
    multiply,
    spamm,
    spamm_symbolic,
    spgemm_numeric,
    spgemm_symbolic,
    spgemm_symbolic_recursive,
    spgemm_symbolic_tree,
    symm_square,
    syrk,
    task_flops,
)
from .truncate import truncate, truncate_elementwise, truncate_hierarchical

__all__ = [
    "BSMatrix",
    "Tasks",
    "LeafSpec",
    "QuadtreeIndex",
    "build_quadtree_index",
    "structure_fingerprint",
    "SymbolicCache",
    "add",
    "add_scaled_identity",
    "identity",
    "multiply",
    "syrk",
    "symm_square",
    "spamm",
    "spamm_symbolic",
    "spgemm_symbolic",
    "spgemm_symbolic_tree",
    "spgemm_symbolic_recursive",
    "spgemm_numeric",
    "task_flops",
    "exact_spgemm_flops",
    "inner_masks",
    "nnz_elements",
    "truncate",
    "truncate_hierarchical",
    "truncate_elementwise",
    "inv_chol",
    "localized_inverse_factorization",
    "factorization_residual",
    "InverseStats",
    "RefineMonitor",
    "submatrix",
    "sp2_purify",
]
