"""Addition task types: C = alpha*A + beta*B, and A + alpha*I."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .matrix import BSMatrix

__all__ = ["add", "add_scaled_identity", "identity"]


def add(a: BSMatrix, b: BSMatrix, alpha=1.0, beta=1.0) -> BSMatrix:
    """C = alpha*A + beta*B.  Structure union; overlapping blocks summed."""
    assert a.shape == b.shape and a.bs == b.bs, (a.shape, b.shape, a.bs, b.bs)
    if a.nnzb == 0 and b.nnzb == 0:
        return BSMatrix.zeros(a.shape, a.bs, a.dtype)
    coords = np.concatenate([a.coords, b.coords])
    data = jnp.concatenate(
        [
            a.data.astype(jnp.float32) * jnp.float32(alpha),
            b.data.astype(jnp.float32) * jnp.float32(beta),
        ]
    ).astype(jnp.result_type(a.dtype, b.dtype))
    return BSMatrix.from_blocks(a.shape, a.bs, coords, data)


def identity(n: int, bs: int, dtype=jnp.float32) -> BSMatrix:
    """Block-sparse identity, partial trailing block handled."""
    nb = -(-n // bs)
    coords = np.stack([np.arange(nb), np.arange(nb)], axis=1).astype(np.int64)
    eye = jnp.eye(bs, dtype=dtype)
    data = jnp.tile(eye[None], (nb, 1, 1))
    tail = n - (nb - 1) * bs
    if tail < bs:
        mask = (jnp.arange(bs) < tail).astype(dtype)
        data = data.at[-1].set(jnp.diag(mask))
    return BSMatrix.from_blocks((n, n), bs, coords, data)


def add_scaled_identity(a: BSMatrix, alpha) -> BSMatrix:
    """A + alpha*I (paper: addition of a matrix with a scaled identity)."""
    assert a.shape[0] == a.shape[1]
    return add(a, identity(a.shape[0], a.bs, a.dtype), 1.0, alpha)
