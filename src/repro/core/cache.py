"""Structure-keyed host-side caches — the chunk-cache analogue on the host.

CHT workers cache the chunks tasks touch so iterative algorithms stop paying
for re-fetches once their access pattern stabilizes.  On the host side the
analogous repeated cost is the *symbolic phase*: quadtree descent, task-list
construction, truncation selection.  :class:`SymbolicCache` memoizes those
behind keys derived from :func:`repro.core.quadtree.structure_fingerprint`
of the operand structures — every `sp2_purify` iteration after the sparsity
pattern stabilizes under truncation skips the symbolic phase entirely,
mirroring what :class:`repro.dist.PlanCache` (a subclass) does for the
distributed plans, device plan arrays and jitted shard_map executables.

Hit/miss counters are surfaced via :meth:`SymbolicCache.stats`.
"""

from __future__ import annotations

import collections
from typing import Any, Callable, Hashable

from ..obs.log import NULL_LOG
from ..obs.timing import timed_into
from ..obs.tracer import NULL_TRACER

__all__ = ["SymbolicCache"]


class SymbolicCache:
    """LRU cache from structure keys to built symbolic results.

    Keys are hashable tuples (callers prefix them with a kind tag such as
    ``"spgemm"`` / ``"add"`` / ``"trace"``).  Values are whatever the builder
    returns — a :class:`~repro.core.spgemm.Tasks` list on the single-host
    path, a (plan, executable) pair on the distributed path.
    """

    #: verification policies: "off" never verifies; "cached-once" verifies
    #: each value once at admission (miss path) so the zero-miss steady
    #: state pays nothing; "always" re-verifies on every hit as well
    VERIFY_POLICIES = ("off", "cached-once", "always")

    def __init__(self, max_entries: int = 128, tracer=None,
                 verify: str = "cached-once", event_log=None):
        if verify not in self.VERIFY_POLICIES:
            raise ValueError(
                f"verify={verify!r} not in {self.VERIFY_POLICIES}")
        self.max_entries = max_entries
        self.tracer = tracer
        self.event_log = event_log
        self.verify = verify
        # optional observatory riders (repro.obs): a FlightRecorder dumps a
        # postmortem when plan admission raises PlanError or a driver's
        # divergence trip fires; a MemoryMeter accounts device-memory
        # watermarks at the dispatch sites; a LocalityLedger decomposes each
        # dispatch's operand reads into locally-owned vs shipped bytes.  All
        # default off and are read back with getattr so un-instrumented
        # paths pay nothing.
        self.flight_recorder = None
        self.memory_meter = None
        self.locality_ledger = None
        self._entries: collections.OrderedDict[Hashable, Any] = (
            collections.OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self._by_kind: collections.Counter = collections.Counter()
        # key of the plan used by the most recent multiply-family call (set by
        # repro.dist.multiply so drivers can peek the plan actually executed,
        # delta/SpAMM included); None when the last call built no plan
        self.last_plan_key: Hashable | None = None
        # per-worker count of tasks the most recent multiply-family call
        # actually executed (delta-plan SpAMM masks tasks at runtime, so the
        # plan's static task_count overstates the work) — the measured flop
        # load the dynamic load balancer (repro.dist.balance) consumes
        self.last_task_count = None
        # accumulated seconds spent in cache-miss builders (planning + jit)
        # and in per-call symbolic phases that run outside the cache (SpAMM
        # descent, hierarchical truncation selection — value-dependent work)
        self.build_s = 0.0
        self.symbolic_s = 0.0
        # static-verification accounting (repro.analysis): values verified,
        # violations raised, seconds spent — all zero in a zero-miss replay
        # under the default "cached-once" policy
        self.plans_verified = 0
        self.verify_violations = 0
        self.verify_s = 0.0

    # the tracer rides on the cache: the cache is already threaded through
    # every resident collective and driver, so instrumented call sites read
    # it back via repro.obs.tracer_of(cache); assigning None disables tracing
    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer) -> None:
        self._tracer = tracer if tracer is not None else NULL_TRACER

    # the structured event log rides on the cache the same way the tracer
    # does: call sites read it back via repro.obs.log_of(cache); assigning
    # None disables logging (the NULL_LOG no-op)
    @property
    def event_log(self):
        return self._event_log

    @event_log.setter
    def event_log(self, log) -> None:
        self._event_log = log if log is not None else NULL_LOG

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        kind = key[0] if isinstance(key, tuple) else "?"
        tr = self.tracer
        if key in self._entries:
            self.hits += 1
            self._by_kind[(kind, "hit")] += 1
            if tr.enabled:
                tr.counter("plan_hits").add()
            self._entries.move_to_end(key)
            value = self._entries[key]
            if self.verify == "always":
                self._verify_value(key, value)
            return value
        self.misses += 1
        self._by_kind[(kind, "miss")] += 1
        if tr.enabled:
            tr.counter("plan_misses").add()
        with timed_into(self, "build_s", tr, "plan_build", cat="plan",
                        kind=str(kind)) as tm:
            value = builder()
        lg = self._event_log
        if lg.debug_enabled:
            lg.debug("plan_build", kind=str(kind), build_s=tm.elapsed,
                     misses=self.misses)
        if self.verify != "off":
            self._verify_value(key, value)  # raises before a bad plan lands
        self._entries[key] = value
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
        return value

    def _verify_value(self, key: Hashable, value: Any) -> None:
        """Static-verification hook at cache admission (repro.analysis).

        Unverifiable values (symbolic task lists, scalars) pass through;
        plans and relayout/norm-table executables are re-proved and a
        non-empty violation report raises :class:`PlanError` — surfaced
        through the tracer as structured ``plan_verify_violation`` instants
        plus ``plans_verified`` / ``verify_violations`` counters.
        """
        from ..analysis.verify import PlanError, verify_value

        tr = self.tracer
        kind = key[0] if isinstance(key, tuple) else "?"
        with timed_into(self, "verify_s", tr, "plan_verify", cat="analysis",
                        kind=str(kind)):
            report = verify_value(key, value)
        if report is None:
            return
        self.plans_verified += 1
        if tr.enabled:
            tr.counter("plans_verified").add()
        if report:
            self.verify_violations += len(report)
            if tr.enabled:
                tr.counter("verify_violations").add(len(report))
                for viol in report[:32]:
                    tr.instant("plan_verify_violation", cat="analysis",
                               check=viol.check, message=viol.message,
                               **viol.provenance)
            message = (
                f"{kind} plan failed static verification with "
                f"{len(report)} violation(s); first: [{report[0].check}] "
                f"{report[0].message}")
            lg = self._event_log
            if lg.enabled:
                lg.error("plan_error", kind=str(kind), message=message,
                         violations=len(report), check=report[0].check)
            rec = self.flight_recorder
            if rec is not None:
                rec.dump("plan_error", self, kind=str(kind), message=message,
                         violations=[dict(check=v.check, message=v.message,
                                          **v.provenance)
                                     for v in report[:16]])
            raise PlanError(message, report)

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Read an entry without touching counters or LRU order."""
        return self._entries.get(key, default)

    def snapshot(self) -> tuple:
        """Counter snapshot for per-stage/per-iteration deltas (see delta)."""
        return (self.hits, self.misses, self.build_s, self.symbolic_s)

    def delta(self, snap: tuple) -> dict:
        """Counters accumulated since ``snap`` — the per-iteration cache rows
        reported by the SP2 / inverse-factorization drivers."""
        h, m, b, s = snap
        return dict(
            cache_hits=self.hits - h,
            cache_misses=self.misses - m,
            plan_build_s=self.build_s - b,
            symbolic_s=self.symbolic_s - s,
        )

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        """plan_stats-style cache metrics."""
        total = self.hits + self.misses
        return dict(
            entries=len(self._entries),
            hits=self.hits,
            misses=self.misses,
            hit_rate=self.hits / total if total else 0.0,
            build_s=self.build_s,
            symbolic_s=self.symbolic_s,
            verify=self.verify,
            verify_s=self.verify_s,
            plans_verified=self.plans_verified,
            verify_violations=self.verify_violations,
            by_kind={f"{k}/{o}": v for (k, o), v in sorted(self._by_kind.items())},
        )
