"""Distributed SpGEMM execution via shard_map (SPMD side of the schedule).

The host-side :class:`~repro.core.schedule.SpgemmPlan` becomes arrays sharded
over a 1-D "worker" mesh axis; inside shard_map each device sees its own task
list and exchange slots.  Two exchange modes:

* ``p2p``: one ``lax.ppermute`` round per active ring offset — only blocks
  actually referenced by remote tasks move (the paper's locality claim).
  For banded matrices under Morton placement only neighbour offsets appear,
  so the lowered HLO contains exactly the neighbour collective-permutes.
* ``allgather``: the baseline — both operands fully replicated with
  ``lax.all_gather`` (what random-permutation schemes effectively pay).

Numeric phase inside the mapped function is the grouped block matmul
(Pallas kernel on TPU, segment-sum oracle elsewhere); padded tasks write to a
trash row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.jax_compat import shard_map

from .matrix import BSMatrix
from .schedule import SpgemmPlan

__all__ = [
    "make_worker_mesh",
    "dist_spgemm",
    "shard_stores",
    "unshard_result",
    "make_spgemm_executable",
    "SpgemmExecutable",
    "make_masked_spgemm_executable",
    "MaskedSpgemmExecutable",
]

AXIS = "worker"


def make_worker_mesh(nworkers: int | None = None) -> Mesh:
    devs = np.array(jax.devices())
    nworkers = nworkers or devs.size
    return Mesh(devs[:nworkers].reshape(nworkers), (AXIS,))


def shard_stores(plan: SpgemmPlan, a_data: jax.Array, b_data: jax.Array):
    """Gather global block stacks into per-device padded stores [P, cap, bs, bs]."""
    av = jnp.asarray(plan.a_store_valid)[..., None, None]
    bv = jnp.asarray(plan.b_store_valid)[..., None, None]
    a_store = a_data[jnp.asarray(plan.a_store_idx)] * av.astype(a_data.dtype)
    b_store = b_data[jnp.asarray(plan.b_store_idx)] * bv.astype(b_data.dtype)
    return a_store, b_store


def _exchange_bufs(store, offsets, send_pads, nparts):
    """Run the planned ppermute rounds; return device-local operand buffer."""
    bufs = [store]
    for d, send in zip(offsets, send_pads):
        payload = store[send[0]]  # [cap_d, bs, bs]
        perm = [(p, (p + d) % nparts) for p in range(nparts)]
        recv = jax.lax.ppermute(payload, AXIS, perm=perm)
        bufs.append(recv)
    return jnp.concatenate(bufs, axis=0) if len(bufs) > 1 else store


def _assemble_operands(a_store, b_store, a_and_b_sends, plan: SpgemmPlan):
    """Device-local operand buffers per the plan's exchange mode."""
    na = len(plan.a_offsets)
    a_sends = a_and_b_sends[:na]
    b_sends = a_and_b_sends[na:]
    if plan.exchange == "p2p":
        a_all = _exchange_bufs(a_store[0], plan.a_offsets, a_sends, plan.nparts)
        b_all = _exchange_bufs(b_store[0], plan.b_offsets, b_sends, plan.nparts)
    else:  # allgather baseline
        a_all = jax.lax.all_gather(a_store[0], AXIS).reshape(
            -1, *a_store.shape[-2:]
        )
        b_all = jax.lax.all_gather(b_store[0], AXIS).reshape(
            -1, *b_store.shape[-2:]
        )
    return a_all, b_all


def _block_spmm_fn(impl: str):
    if impl == "kernel":
        from repro.kernels import ops as kops

        return kops.block_spmm
    from repro.kernels import ref as kref

    return kref.block_spmm_ref


def _mapped_multiply(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    *a_and_b_sends,
    plan: SpgemmPlan,
    impl: str,
):
    """Per-device body. Leading dim of every arg is this device's slice (1)."""
    a_all, b_all = _assemble_operands(a_store, b_store, a_and_b_sends, plan)
    num_out = plan.c_cap + 1  # trash row for padded tasks
    c = _block_spmm_fn(impl)(a_all, b_all, task_a[0], task_b[0], task_c[0], num_out)
    return c[None, : plan.c_cap]


def _mapped_multiply_masked(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    task_on,
    *a_and_b_sends,
    plan: SpgemmPlan,
    impl: str,
):
    """Masked multiply body: tasks with ``task_on`` False are redirected to the
    trash row — the same mechanism padding already uses — so one compiled
    program serves every prune pattern over a fixed structure."""
    a_all, b_all = _assemble_operands(a_store, b_store, a_and_b_sends, plan)
    num_out = plan.c_cap + 1
    tc = jnp.where(task_on[0], task_c[0], plan.c_cap)
    c = _block_spmm_fn(impl)(a_all, b_all, task_a[0], task_b[0], tc, num_out)
    return c[None, : plan.c_cap]


class SpgemmExecutable:
    """A planned multiply bound to a mesh, with plan arrays device-resident.

    The host index arrays (task lists, send slots) are shipped to the mesh
    once at construction; every subsequent ``__call__`` only touches the
    operand stores — when those are already resident (``repro.dist``), an
    iteration moves no host data at all.  The jitted ``shard_map`` program is
    cached on this object, so repeated calls skip tracing and compilation —
    together these are the chunk-cache analogue of the paper's runtime.
    """

    # subclasses swap the mapped body and declare how many extra per-call
    # sharded arguments it takes between the plan index arrays and the sends
    _body = staticmethod(_mapped_multiply)
    _n_runtime_args = 0

    def __init__(self, plan: SpgemmPlan, mesh: Mesh, *, impl: str = "ref"):
        assert mesh.devices.size == plan.nparts, (mesh.devices.size, plan.nparts)
        self.plan = plan
        self.mesh = mesh
        self.impl = impl
        self._sh = NamedSharding(mesh, P(AXIS))
        put = lambda x: jax.device_put(jnp.asarray(x), self._sh)
        self._idx_args = [
            put(plan.task_a),
            put(plan.task_b),
            put(plan.task_c),
        ]
        self._send_args = [put(plan.a_send[d]) for d in plan.a_offsets]
        self._send_args += [put(plan.b_send[d]) for d in plan.b_offsets]
        fn = functools.partial(type(self)._body, plan=plan, impl=impl)
        nargs = (
            2 + len(self._idx_args) + self._n_runtime_args + len(self._send_args)
        )
        self._mapped = jax.jit(
            shard_map(
                fn,
                mesh=mesh,
                in_specs=tuple(P(AXIS) for _ in range(nargs)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, a_store: jax.Array, b_store: jax.Array) -> jax.Array:
        """Run on per-device padded stores [P, cap, bs, bs]; returns C stores."""
        return self._mapped(a_store, b_store, *self._idx_args, *self._send_args)


def make_spgemm_executable(
    plan: SpgemmPlan, mesh: Mesh | None = None, *, impl: str = "ref"
) -> SpgemmExecutable:
    return SpgemmExecutable(plan, mesh or make_worker_mesh(plan.nparts), impl=impl)


class MaskedSpgemmExecutable(SpgemmExecutable):
    """A full-structure multiply that takes a per-task on/off mask at call time.

    Built once from the *full* (unpruned) plan; each ``__call__`` additionally
    receives ``task_on`` ``[P, t_cap]`` bool — False tasks write to the trash
    row, exactly like padding, so their contribution is dropped without
    re-planning, re-tracing, or recompiling.  This is the delta-plan SpAMM
    executable: one jitted program per structure serves every fluctuating
    ``tau``-prune pattern (``repro.dist.multiply.dist_spamm``), at full-plan
    exchange cost but zero per-pattern symbolic/compile cost.
    """

    _body = staticmethod(_mapped_multiply_masked)
    _n_runtime_args = 1

    def __call__(
        self, a_store: jax.Array, b_store: jax.Array, task_on: np.ndarray
    ) -> jax.Array:
        """Run with a [P, t_cap] bool task mask; returns C stores [P, c_cap, bs, bs].

        ``task_on`` is the only per-call host->device transfer — a tiny bool
        table, the delta against the cached full plan.
        """
        mask = jax.device_put(jnp.asarray(task_on, dtype=jnp.bool_), self._sh)
        return self._mapped(a_store, b_store, *self._idx_args, mask, *self._send_args)


def make_masked_spgemm_executable(
    plan: SpgemmPlan, mesh: Mesh | None = None, *, impl: str = "ref"
) -> MaskedSpgemmExecutable:
    return MaskedSpgemmExecutable(plan, mesh or make_worker_mesh(plan.nparts), impl=impl)


def dist_spgemm(
    plan: SpgemmPlan,
    a_data: jax.Array,
    b_data: jax.Array,
    mesh: Mesh | None = None,
    *,
    impl: str = "ref",
) -> jax.Array:
    """Execute the planned multiply. Returns sharded C stores [P, c_cap, bs, bs].

    One-shot form: ships host block stacks each call.  Iterative algorithms
    should hold a :class:`SpgemmExecutable` (via ``repro.dist``) instead.
    """
    mesh = mesh or make_worker_mesh(plan.nparts)
    exe = SpgemmExecutable(plan, mesh, impl=impl)
    a_store, b_store = shard_stores(plan, a_data, b_data)
    sh = NamedSharding(mesh, P(AXIS))
    return exe(
        jax.device_put(jnp.asarray(a_store), sh),
        jax.device_put(jnp.asarray(b_store), sh),
    )


def _mapped_outer(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    acc_idx,
    *sends,
    plan,
    impl: str,
):
    """Outer-product multiply body: all-local tasks -> partial C -> exchange
    partials to owners -> accumulate."""
    num_partial = plan.p_cap + 1  # trash row for padded tasks
    if impl == "kernel":
        from repro.kernels import ops as kops

        partials = kops.block_spmm(
            a_store[0], b_store[0], task_a[0], task_b[0], task_c[0], num_partial
        )
    else:
        from repro.kernels import ref as kref

        partials = kref.block_spmm_ref(
            a_store[0], b_store[0], task_a[0], task_b[0], task_c[0], num_partial
        )
    partials = partials[: plan.p_cap]
    bufs = [partials]
    for d, send in zip(plan.offsets, sends):
        payload = partials[send[0]]
        perm = [(p, (p + d) % plan.nparts) for p in range(plan.nparts)]
        bufs.append(jax.lax.ppermute(payload, AXIS, perm=perm))
    all_partials = jnp.concatenate(bufs, axis=0) if len(bufs) > 1 else partials
    c = jax.ops.segment_sum(all_partials, acc_idx[0], num_segments=plan.c_cap + 1)
    return c[None, : plan.c_cap]


def dist_spgemm_outer(plan, a_data, b_data, mesh=None, *, impl: str = "ref"):
    """Execute an OuterPlan (repro.core.outer).  Returns [P, c_cap, bs, bs]."""
    mesh = mesh or make_worker_mesh(plan.nparts)
    av = jnp.asarray(plan.a_store_valid)[..., None, None]
    bv = jnp.asarray(plan.b_store_valid)[..., None, None]
    a_store = a_data[jnp.asarray(plan.a_store_idx)] * av.astype(a_data.dtype)
    b_store = b_data[jnp.asarray(plan.b_store_idx)] * bv.astype(b_data.dtype)
    sh = NamedSharding(mesh, P(AXIS))
    put = lambda x: jax.device_put(jnp.asarray(x), sh)
    args = [
        put(a_store),
        put(b_store),
        put(plan.task_a),
        put(plan.task_b),
        put(plan.task_c),
        put(plan.acc_idx),
    ]
    sends = [put(plan.send[d]) for d in plan.offsets]
    fn = functools.partial(_mapped_outer, plan=plan, impl=impl)
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=tuple(P(AXIS) for _ in range(len(args) + len(sends))),
        out_specs=P(AXIS),
        check_vma=False,
    )
    return jax.jit(mapped)(*args, *sends)


def unshard_result(plan: SpgemmPlan, c_stores: jax.Array, shape, bs) -> BSMatrix:
    """Reassemble the global BSMatrix from per-device C stores."""
    c_stores = np.asarray(c_stores)
    nc = plan.c_coords.shape[0]
    data = np.zeros((nc, bs, bs), dtype=c_stores.dtype)
    for p in range(plan.nparts):
        valid = plan.c_store_valid[p]
        data[plan.c_store_idx[p][valid]] = c_stores[p][valid]
    return BSMatrix(shape=tuple(shape), bs=bs, coords=plan.c_coords, data=jnp.asarray(data))
