"""Distributed SpGEMM execution via shard_map (SPMD side of the schedule).

The host-side :class:`~repro.core.schedule.SpgemmPlan` becomes arrays sharded
over a 1-D "worker" mesh axis; inside shard_map each device sees its own task
list and exchange slots.  Two exchange modes:

* ``p2p``: one ``lax.ppermute`` round per active ring offset — only blocks
  actually referenced by remote tasks move (the paper's locality claim).
  For banded matrices under Morton placement only neighbour offsets appear,
  so the lowered HLO contains exactly the neighbour collective-permutes.
* ``allgather``: the baseline — both operands fully replicated with
  ``lax.all_gather`` (what random-permutation schemes effectively pay).

Numeric phase inside the mapped function is the grouped block matmul
(Pallas kernel on TPU, segment-sum oracle elsewhere); padded tasks write to a
trash row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.jax_compat import shard_map
from repro.kernels.precision import FP32, Precision

from .matrix import BSMatrix
from .schedule import SpgemmPlan

__all__ = [
    "make_worker_mesh",
    "dist_spgemm",
    "shard_stores",
    "unshard_result",
    "make_spgemm_executable",
    "SpgemmExecutable",
    "make_masked_spgemm_executable",
    "MaskedSpgemmExecutable",
    "make_fused_spgemm_executable",
    "FusedSpgemmExecutable",
    "make_masked_fused_spgemm_executable",
    "MaskedFusedSpgemmExecutable",
]

AXIS = "worker"


def make_worker_mesh(nworkers: int | None = None) -> Mesh:
    devs = np.array(jax.devices())
    nworkers = nworkers or devs.size
    return Mesh(devs[:nworkers].reshape(nworkers), (AXIS,))


def shard_stores(plan: SpgemmPlan, a_data: jax.Array, b_data: jax.Array):
    """Gather global block stacks into per-device padded stores [P, cap, bs, bs]."""
    av = jnp.asarray(plan.a_store_valid)[..., None, None]
    bv = jnp.asarray(plan.b_store_valid)[..., None, None]
    a_store = a_data[jnp.asarray(plan.a_store_idx)] * av.astype(a_data.dtype)
    b_store = b_data[jnp.asarray(plan.b_store_idx)] * bv.astype(b_data.dtype)
    return a_store, b_store


def _exchange_bufs(store, offsets, send_pads, nparts):
    """Run the planned ppermute rounds; return device-local operand buffer."""
    bufs = [store]
    for d, send in zip(offsets, send_pads):
        payload = store[send[0]]  # [cap_d, bs, bs]
        perm = [(p, (p + d) % nparts) for p in range(nparts)]
        recv = jax.lax.ppermute(payload, AXIS, perm=perm)
        bufs.append(recv)
    return jnp.concatenate(bufs, axis=0) if len(bufs) > 1 else store


def _assemble_operands(a_store, b_store, a_and_b_sends, plan: SpgemmPlan):
    """Device-local operand buffers per the plan's exchange mode."""
    na = len(plan.a_offsets)
    a_sends = a_and_b_sends[:na]
    b_sends = a_and_b_sends[na:]
    if plan.exchange == "p2p":
        a_all = _exchange_bufs(a_store[0], plan.a_offsets, a_sends, plan.nparts)
        b_all = _exchange_bufs(b_store[0], plan.b_offsets, b_sends, plan.nparts)
    else:  # allgather baseline
        a_all = jax.lax.all_gather(a_store[0], AXIS).reshape(
            -1, *a_store.shape[-2:]
        )
        b_all = jax.lax.all_gather(b_store[0], AXIS).reshape(
            -1, *b_store.shape[-2:]
        )
    return a_all, b_all


def _block_spmm_fn(impl: str):
    if impl == "kernel":
        from repro.kernels import ops as kops

        return kops.block_spmm
    from repro.kernels import ref as kref

    return kref.block_spmm_ref


def _exchange_stack(store, offsets, send_pads, nparts, keeps=None, live=None):
    """Planned ppermute rounds -> stacked receive buffers [R, capU, bs, bs].

    Unlike :func:`_exchange_bufs` the device's own store is NOT copied into
    an operand buffer — the fused kernel reads it in place — and each round's
    receive buffer is padded to the uniform ``capU`` so the stack is one
    array the kernel indexes by ``(round, row)``.  Padding happens locally,
    *after* the ppermute: the wire payload stays the round's true capacity.

    ``keeps``: optional per-round ``[1, cap_d]`` bool — send slots whose
    block no live task references ship zeros (delta-plan exchange pruning).
    ``live``: optional collection of round indices to run at all; dead
    rounds (every slot masked) produce zeros with no collective.
    """
    shape = store.shape[-2:]
    if len(offsets) == 0:
        # dummy stack: the kernel's recv branch prefetches row (0, 0) and the
        # select discards it (src is all-zero when there are no rounds)
        return jnp.zeros((1, 1) + shape, store.dtype)
    capU = max(send.shape[1] for send in send_pads)
    bufs = []
    for r, (d, send) in enumerate(zip(offsets, send_pads)):
        if live is not None and r not in live:
            bufs.append(jnp.zeros((capU,) + shape, store.dtype))
            continue
        payload = store[send[0]]  # [cap_d, bs, bs]
        if keeps is not None:
            payload = payload * keeps[r][0][:, None, None].astype(store.dtype)
        perm = [(p, (p + d) % nparts) for p in range(nparts)]
        recv = jax.lax.ppermute(payload, AXIS, perm=perm)
        pad = capU - recv.shape[0]
        if pad:
            recv = jnp.pad(recv, ((0, pad), (0, 0), (0, 0)))
        bufs.append(recv)
    return jnp.stack(bufs, axis=0)


def _fused_spmm_fn(impl: str):
    from repro.kernels import ops as kops

    if impl == "fused-interpret":  # force the Pallas interpreter (tests)
        return functools.partial(kops.fused_block_spmm, interpret=True)
    assert impl == "fused", impl
    return kops.fused_block_spmm


def _mapped_multiply_fused(
    a_store,
    b_store,
    a_src_t,
    a_off_t,
    b_src_t,
    b_off_t,
    task_c,
    *a_and_b_sends,
    plan: SpgemmPlan,
    impl: str,
    precision: Precision,
):
    """Fused per-device body: exchange -> one fused unpack+GEMM+accumulate
    dispatch over (own store | stacked receive buffers).  No concatenated
    operand buffer is materialized."""
    na = len(plan.a_offsets)
    a_sends, b_sends = a_and_b_sends[:na], a_and_b_sends[na:]
    a_own, b_own = a_store[0], b_store[0]
    if precision.mode == "bf16":
        # cast before the exchange: halves the ppermute payload bytes too
        a_own = a_own.astype(jnp.bfloat16)
        b_own = b_own.astype(jnp.bfloat16)
    a_recv = _exchange_stack(a_own, plan.a_offsets, a_sends, plan.nparts)
    b_recv = _exchange_stack(b_own, plan.b_offsets, b_sends, plan.nparts)
    c = _fused_spmm_fn(impl)(
        a_own, a_recv, b_own, b_recv,
        a_src_t[0], a_off_t[0], b_src_t[0], b_off_t[0], task_c[0],
        plan.c_cap + 1,
    )
    return c[None, : plan.c_cap]


def _mapped_multiply_fused_masked(
    a_store,
    b_store,
    a_src_t,
    a_off_t,
    b_src_t,
    b_off_t,
    task_c,
    task_on,
    task_low,
    *keeps_and_sends,
    plan: SpgemmPlan,
    impl: str,
    precision: Precision,
    live_a: tuple[int, ...],
    live_b: tuple[int, ...],
):
    """Masked fused body: off tasks go to the trash row, send slots feeding
    only off tasks ship zeros, and rounds with every slot masked skip their
    collective entirely.  ``task_low`` drives adaptive per-task rounding."""
    na, nb = len(plan.a_offsets), len(plan.b_offsets)
    a_keeps = keeps_and_sends[:na]
    b_keeps = keeps_and_sends[na : na + nb]
    a_sends = keeps_and_sends[na + nb : 2 * na + nb]
    b_sends = keeps_and_sends[2 * na + nb :]
    a_own, b_own = a_store[0], b_store[0]
    if precision.mode == "bf16":
        a_own = a_own.astype(jnp.bfloat16)
        b_own = b_own.astype(jnp.bfloat16)
    a_recv = _exchange_stack(
        a_own, plan.a_offsets, a_sends, plan.nparts, keeps=a_keeps, live=live_a
    )
    b_recv = _exchange_stack(
        b_own, plan.b_offsets, b_sends, plan.nparts, keeps=b_keeps, live=live_b
    )
    tc = jnp.where(task_on[0], task_c[0], plan.c_cap)
    adaptive = precision.mode == "adaptive"
    c = _fused_spmm_fn(impl)(
        a_own, a_recv, b_own, b_recv,
        a_src_t[0], a_off_t[0], b_src_t[0], b_off_t[0], tc,
        plan.c_cap + 1,
        low=task_low[0] if adaptive else None,
        adaptive=adaptive,
    )
    return c[None, : plan.c_cap]


def _mapped_multiply(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    *a_and_b_sends,
    plan: SpgemmPlan,
    impl: str,
):
    """Per-device body. Leading dim of every arg is this device's slice (1)."""
    a_all, b_all = _assemble_operands(a_store, b_store, a_and_b_sends, plan)
    num_out = plan.c_cap + 1  # trash row for padded tasks
    c = _block_spmm_fn(impl)(a_all, b_all, task_a[0], task_b[0], task_c[0], num_out)
    return c[None, : plan.c_cap]


def _mapped_multiply_masked(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    task_on,
    *a_and_b_sends,
    plan: SpgemmPlan,
    impl: str,
):
    """Masked multiply body: tasks with ``task_on`` False are redirected to the
    trash row — the same mechanism padding already uses — so one compiled
    program serves every prune pattern over a fixed structure."""
    a_all, b_all = _assemble_operands(a_store, b_store, a_and_b_sends, plan)
    num_out = plan.c_cap + 1
    tc = jnp.where(task_on[0], task_c[0], plan.c_cap)
    c = _block_spmm_fn(impl)(a_all, b_all, task_a[0], task_b[0], tc, num_out)
    return c[None, : plan.c_cap]


class SpgemmExecutable:
    """A planned multiply bound to a mesh, with plan arrays device-resident.

    The host index arrays (task lists, send slots) are shipped to the mesh
    once at construction; every subsequent ``__call__`` only touches the
    operand stores — when those are already resident (``repro.dist``), an
    iteration moves no host data at all.  The jitted ``shard_map`` program is
    cached on this object, so repeated calls skip tracing and compilation —
    together these are the chunk-cache analogue of the paper's runtime.
    """

    # subclasses swap the mapped body and declare how many extra per-call
    # sharded arguments it takes between the plan index arrays and the sends
    _body = staticmethod(_mapped_multiply)
    _n_runtime_args = 0

    def __init__(
        self, plan: SpgemmPlan, mesh: Mesh, *, impl: str = "ref", **body_kwargs
    ):
        if mesh.devices.size != plan.nparts:
            from ..analysis.errors import PlanError

            raise PlanError(
                f"plan partitions over {plan.nparts} workers but the mesh "
                f"has {mesh.devices.size} devices")
        self.plan = plan
        self.mesh = mesh
        self.impl = impl
        self._body_kwargs = body_kwargs
        self._sh = NamedSharding(mesh, P(AXIS))
        put = lambda x: jax.device_put(jnp.asarray(x), self._sh)
        self._idx_args = [put(x) for x in self._plan_index_arrays(plan)]
        self._send_args = [put(plan.a_send[d]) for d in plan.a_offsets]
        self._send_args += [put(plan.b_send[d]) for d in plan.b_offsets]
        self._mapped = self._build_program()

    @staticmethod
    def _plan_index_arrays(plan: SpgemmPlan) -> list[np.ndarray]:
        return [plan.task_a, plan.task_b, plan.task_c]

    def _n_runtime(self, plan: SpgemmPlan) -> int:
        return self._n_runtime_args

    def _build_program(self, **extra):
        """Jit the shard_mapped body; subclasses pass per-program statics
        (e.g. the live-round sets of a pruned exchange) via ``extra``."""
        fn = functools.partial(
            type(self)._body,
            plan=self.plan,
            impl=self.impl,
            **{**self._body_kwargs, **extra},
        )
        nargs = (
            2 + len(self._idx_args) + self._n_runtime(self.plan) + len(self._send_args)
        )
        return jax.jit(
            shard_map(
                fn,
                mesh=self.mesh,
                in_specs=tuple(P(AXIS) for _ in range(nargs)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, a_store: jax.Array, b_store: jax.Array) -> jax.Array:
        """Run on per-device padded stores [P, cap, bs, bs]; returns C stores."""
        return self._mapped(a_store, b_store, *self._idx_args, *self._send_args)


def make_spgemm_executable(
    plan: SpgemmPlan, mesh: Mesh | None = None, *, impl: str = "ref"
) -> SpgemmExecutable:
    return SpgemmExecutable(plan, mesh or make_worker_mesh(plan.nparts), impl=impl)


class MaskedSpgemmExecutable(SpgemmExecutable):
    """A full-structure multiply that takes a per-task on/off mask at call time.

    Built once from the *full* (unpruned) plan; each ``__call__`` additionally
    receives ``task_on`` ``[P, t_cap]`` bool — False tasks write to the trash
    row, exactly like padding, so their contribution is dropped without
    re-planning, re-tracing, or recompiling.  This is the delta-plan SpAMM
    executable: one jitted program per structure serves every fluctuating
    ``tau``-prune pattern (``repro.dist.multiply.dist_spamm``), at full-plan
    exchange cost but zero per-pattern symbolic/compile cost.
    """

    _body = staticmethod(_mapped_multiply_masked)
    _n_runtime_args = 1

    def __call__(
        self, a_store: jax.Array, b_store: jax.Array, task_on: np.ndarray
    ) -> jax.Array:
        """Run with a [P, t_cap] bool task mask; returns C stores [P, c_cap, bs, bs].

        ``task_on`` is the only per-call host->device transfer — a tiny bool
        table, the delta against the cached full plan.
        """
        mask = jax.device_put(jnp.asarray(task_on, dtype=jnp.bool_), self._sh)
        return self._mapped(a_store, b_store, *self._idx_args, mask, *self._send_args)


def make_masked_spgemm_executable(
    plan: SpgemmPlan, mesh: Mesh | None = None, *, impl: str = "ref"
) -> MaskedSpgemmExecutable:
    return MaskedSpgemmExecutable(plan, mesh or make_worker_mesh(plan.nparts), impl=impl)


class FusedSpgemmExecutable(SpgemmExecutable):
    """The planned multiply through the fused leaf engine.

    Ships the plan's ``(src, off)`` task operand decomposition instead of
    concatenated-buffer indices; the mapped body runs the exchange into a
    stacked receive buffer and one fused unpack+GEMM+accumulate dispatch.
    ``precision`` selects the storage/exchange dtype policy (``fp32`` |
    ``bf16``); ``adaptive`` needs a per-task mask and lives on the masked
    variant.
    """

    _body = staticmethod(_mapped_multiply_fused)

    def __init__(
        self,
        plan: SpgemmPlan,
        mesh: Mesh,
        *,
        impl: str = "fused",
        precision: Precision = FP32,
    ):
        assert plan.task_a_src is not None, (
            "fused engine needs a p2p plan with (src, off) task decomposition"
        )
        assert precision.mode != "adaptive", (
            "adaptive precision needs the masked fused executable"
        )
        self.precision = precision
        super().__init__(plan, mesh, impl=impl, precision=precision)

    @staticmethod
    def _plan_index_arrays(plan: SpgemmPlan) -> list[np.ndarray]:
        return [
            plan.task_a_src,
            plan.task_a_off,
            plan.task_b_src,
            plan.task_b_off,
            plan.task_c,
        ]


def make_fused_spgemm_executable(
    plan: SpgemmPlan,
    mesh: Mesh | None = None,
    *,
    impl: str = "fused",
    precision: Precision = FP32,
) -> FusedSpgemmExecutable:
    return FusedSpgemmExecutable(
        plan, mesh or make_worker_mesh(plan.nparts), impl=impl, precision=precision
    )


def _send_task_spans(plan: SpgemmPlan):
    """Per (operand, round) CSR map: send slot ``(src, pos)`` -> the global
    task ids that read the delivered block.  Host-side, memoized on the plan
    (same pattern as the obs statics) — this is what lets the masked fused
    executable decide per call which send slots still matter."""
    maps = getattr(plan, "_send_task_spans", None)
    if maps is not None:
        return maps
    nparts = plan.nparts
    t_owner = plan.c_owner[plan.tasks.c_idx]
    tasks_of = [np.nonzero(t_owner == p)[0] for p in range(nparts)]
    maps = {}
    for name, offsets, send, send_cnt, store_idx, x_idx in (
        ("a", plan.a_offsets, plan.a_send, plan.a_send_count,
         plan.a_store_idx, plan.tasks.a_idx),
        ("b", plan.b_offsets, plan.b_send, plan.b_send_count,
         plan.b_store_idx, plan.tasks.b_idx),
    ):
        for d in offsets:
            cap_d = send[d].shape[1]
            starts = np.zeros(nparts * cap_d + 1, np.int64)
            cat = []
            for src in range(nparts):
                dst = (src + d) % nparts
                cnt = int(send_cnt[d][src])
                t_dst = tasks_of[dst]
                refs = x_idx[t_dst]
                order = np.argsort(refs, kind="stable")
                sorted_refs = refs[order]
                blocks = store_idx[src][send[d][src, :cnt]]
                lo = np.searchsorted(sorted_refs, blocks, "left")
                hi = np.searchsorted(sorted_refs, blocks, "right")
                base = src * cap_d
                for pos in range(cap_d):
                    if pos < cnt:
                        ids = t_dst[order[lo[pos] : hi[pos]]]
                        cat.append(ids)
                        starts[base + pos + 1] = starts[base + pos] + ids.size
                    else:
                        starts[base + pos + 1] = starts[base + pos]
            maps[(name, d)] = (
                starts,
                np.concatenate(cat) if cat else np.zeros(0, np.int64),
            )
    object.__setattr__(plan, "_send_task_spans", maps)
    return maps


def _exchange_keep_masks(plan: SpgemmPlan, keep_task: np.ndarray):
    """Per-round send keep masks + live round sets from a global kept-task
    mask.  Returns ``(a_keeps, b_keeps, live_a, live_b, stats)`` where each
    keeps entry is ``[P, cap_d]`` bool and stats counts pruned payload."""
    maps = _send_task_spans(plan)
    nparts = plan.nparts
    keeps_by, live_by = {}, {}
    stats = {"send_blocks": 0, "kept_blocks": 0, "dropped_rounds": 0}
    for name, offsets, send, send_cnt in (
        ("a", plan.a_offsets, plan.a_send, plan.a_send_count),
        ("b", plan.b_offsets, plan.b_send, plan.b_send_count),
    ):
        keeps, live = [], []
        for r, d in enumerate(offsets):
            starts, cat = maps[(name, d)]
            kt = keep_task[cat].astype(np.int64)
            cs = np.concatenate([[0], np.cumsum(kt)])
            keep = (cs[starts[1:]] - cs[starts[:-1]]) > 0
            keep = keep.reshape(nparts, send[d].shape[1])
            stats["send_blocks"] += int(np.asarray(send_cnt[d]).sum())
            stats["kept_blocks"] += int(keep.sum())
            if keep.any():
                live.append(r)
            else:
                stats["dropped_rounds"] += 1
            keeps.append(keep)
        keeps_by[name] = keeps
        live_by[name] = tuple(live)
    return keeps_by["a"], keeps_by["b"], live_by["a"], live_by["b"], stats


class MaskedFusedSpgemmExecutable(FusedSpgemmExecutable):
    """Delta-plan SpAMM through the fused engine, with exchange pruning.

    Like :class:`MaskedSpgemmExecutable`, one full-structure program serves
    every prune pattern — but here the mask also reaches the exchange: send
    slots referenced only by masked-out tasks ship zero payload, and rounds
    whose every slot is masked skip their ppermute entirely (a distinct
    jitted program per live-round pattern, memoized — ring plans have few
    rounds, so the program set stays tiny).  ``task_low`` feeds the adaptive
    precision mask.  ``last_exchange`` records the pruning stats of the most
    recent call.
    """

    _body = staticmethod(_mapped_multiply_fused_masked)

    def __init__(
        self,
        plan: SpgemmPlan,
        mesh: Mesh,
        *,
        impl: str = "fused",
        precision: Precision = FP32,
        prune_exchange: bool = True,
    ):
        assert plan.task_a_src is not None, (
            "fused engine needs a p2p plan with (src, off) task decomposition"
        )
        self.precision = precision
        self.prune_exchange = prune_exchange
        self.last_exchange: dict | None = None
        # keep-mask pair (a_keeps, b_keeps) of the most recent pruned call —
        # the locality ledger reads it to meter only the blocks that shipped
        # (None when the last call ran the full exchange)
        self.last_keeps: tuple | None = None
        all_a = tuple(range(len(plan.a_offsets)))
        all_b = tuple(range(len(plan.b_offsets)))
        self._all_keeps = None  # built lazily for the unpruned path
        SpgemmExecutable.__init__(
            self, plan, mesh, impl=impl,
            precision=precision, live_a=all_a, live_b=all_b,
        )
        self._programs = {(all_a, all_b): self._mapped}

    def _n_runtime(self, plan: SpgemmPlan) -> int:
        # task_on, task_low, then one keep mask per exchange round
        return 2 + len(plan.a_offsets) + len(plan.b_offsets)

    def _keep_task_from_mask(self, task_on: np.ndarray) -> np.ndarray:
        plan = self.plan
        valid = np.arange(plan.t_cap)[None, :] < plan.task_count[:, None]
        keep_task = np.zeros(max(plan.tasks.num_tasks, 1), dtype=bool)
        keep_task[plan.task_gidx[task_on & valid]] = True
        return keep_task

    def __call__(
        self,
        a_store: jax.Array,
        b_store: jax.Array,
        task_on: np.ndarray,
        task_low: np.ndarray | None = None,
    ) -> jax.Array:
        plan = self.plan
        task_on = np.asarray(task_on, dtype=bool)
        if task_low is None:
            task_low = np.zeros(task_on.shape, dtype=np.int32)
        if self.prune_exchange and (plan.a_offsets or plan.b_offsets):
            keep_task = self._keep_task_from_mask(task_on)
            a_keeps, b_keeps, live_a, live_b, stats = _exchange_keep_masks(
                plan, keep_task
            )
            self.last_exchange = stats
            self.last_keeps = (a_keeps, b_keeps)
        else:
            if self._all_keeps is None:
                self._all_keeps = (
                    [np.ones((plan.nparts, plan.a_send[d].shape[1]), bool)
                     for d in plan.a_offsets],
                    [np.ones((plan.nparts, plan.b_send[d].shape[1]), bool)
                     for d in plan.b_offsets],
                )
            a_keeps, b_keeps = self._all_keeps
            live_a = tuple(range(len(plan.a_offsets)))
            live_b = tuple(range(len(plan.b_offsets)))
            self.last_exchange = None
            self.last_keeps = None
        program = self._programs.get((live_a, live_b))
        if program is None:
            program = self._build_program(live_a=live_a, live_b=live_b)
            self._programs[(live_a, live_b)] = program
        put = lambda x: jax.device_put(jnp.asarray(x), self._sh)
        return program(
            a_store,
            b_store,
            *self._idx_args,
            put(task_on),
            put(np.asarray(task_low, np.int32)),
            *[put(k) for k in a_keeps],
            *[put(k) for k in b_keeps],
            *self._send_args,
        )


def make_masked_fused_spgemm_executable(
    plan: SpgemmPlan,
    mesh: Mesh | None = None,
    *,
    impl: str = "fused",
    precision: Precision = FP32,
    prune_exchange: bool = True,
) -> MaskedFusedSpgemmExecutable:
    return MaskedFusedSpgemmExecutable(
        plan,
        mesh or make_worker_mesh(plan.nparts),
        impl=impl,
        precision=precision,
        prune_exchange=prune_exchange,
    )


def dist_spgemm(
    plan: SpgemmPlan,
    a_data: jax.Array,
    b_data: jax.Array,
    mesh: Mesh | None = None,
    *,
    impl: str = "ref",
) -> jax.Array:
    """Execute the planned multiply. Returns sharded C stores [P, c_cap, bs, bs].

    One-shot form: ships host block stacks each call.  Iterative algorithms
    should hold a :class:`SpgemmExecutable` (via ``repro.dist``) instead.
    """
    mesh = mesh or make_worker_mesh(plan.nparts)
    exe = SpgemmExecutable(plan, mesh, impl=impl)
    a_store, b_store = shard_stores(plan, a_data, b_data)
    sh = NamedSharding(mesh, P(AXIS))
    return exe(
        jax.device_put(jnp.asarray(a_store), sh),
        jax.device_put(jnp.asarray(b_store), sh),
    )


def _mapped_outer(
    a_store,
    b_store,
    task_a,
    task_b,
    task_c,
    acc_idx,
    *sends,
    plan,
    impl: str,
):
    """Outer-product multiply body: all-local tasks -> partial C -> exchange
    partials to owners -> accumulate."""
    num_partial = plan.p_cap + 1  # trash row for padded tasks
    if impl == "kernel":
        from repro.kernels import ops as kops

        partials = kops.block_spmm(
            a_store[0], b_store[0], task_a[0], task_b[0], task_c[0], num_partial
        )
    else:
        from repro.kernels import ref as kref

        partials = kref.block_spmm_ref(
            a_store[0], b_store[0], task_a[0], task_b[0], task_c[0], num_partial
        )
    partials = partials[: plan.p_cap]
    bufs = [partials]
    for d, send in zip(plan.offsets, sends):
        payload = partials[send[0]]
        perm = [(p, (p + d) % plan.nparts) for p in range(plan.nparts)]
        bufs.append(jax.lax.ppermute(payload, AXIS, perm=perm))
    all_partials = jnp.concatenate(bufs, axis=0) if len(bufs) > 1 else partials
    c = jax.ops.segment_sum(all_partials, acc_idx[0], num_segments=plan.c_cap + 1)
    return c[None, : plan.c_cap]


def dist_spgemm_outer(plan, a_data, b_data, mesh=None, *, impl: str = "ref"):
    """Execute an OuterPlan (repro.core.outer).  Returns [P, c_cap, bs, bs]."""
    mesh = mesh or make_worker_mesh(plan.nparts)
    av = jnp.asarray(plan.a_store_valid)[..., None, None]
    bv = jnp.asarray(plan.b_store_valid)[..., None, None]
    a_store = a_data[jnp.asarray(plan.a_store_idx)] * av.astype(a_data.dtype)
    b_store = b_data[jnp.asarray(plan.b_store_idx)] * bv.astype(b_data.dtype)
    sh = NamedSharding(mesh, P(AXIS))
    put = lambda x: jax.device_put(jnp.asarray(x), sh)
    args = [
        put(a_store),
        put(b_store),
        put(plan.task_a),
        put(plan.task_b),
        put(plan.task_c),
        put(plan.acc_idx),
    ]
    sends = [put(plan.send[d]) for d in plan.offsets]
    fn = functools.partial(_mapped_outer, plan=plan, impl=impl)
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=tuple(P(AXIS) for _ in range(len(args) + len(sends))),
        out_specs=P(AXIS),
        check_vma=False,
    )
    return jax.jit(mapped)(*args, *sends)


def unshard_result(plan: SpgemmPlan, c_stores: jax.Array, shape, bs) -> BSMatrix:
    """Reassemble the global BSMatrix from per-device C stores."""
    c_stores = np.asarray(c_stores)
    nc = plan.c_coords.shape[0]
    data = np.zeros((nc, bs, bs), dtype=c_stores.dtype)
    for p in range(plan.nparts):
        valid = plan.c_store_valid[p]
        data[plan.c_store_idx[p][valid]] = c_stores[p][valid]
    return BSMatrix(shape=tuple(shape), bs=bs, coords=plan.c_coords, data=jnp.asarray(data))
