"""Inverse factorization task types (paper §2.2).

Finds Z with Z^T A Z = I for symmetric positive definite A.

* :func:`inv_chol` — recursive inverse Cholesky over the quadtree split
  (Schur-complement recursion; every step is library multiply/add/transpose,
  i.e. multiplication-heavy exactly as the paper emphasises).
* :func:`localized_inverse_factorization` — divide-and-conquer: factorize the
  two diagonal quadrants independently, then correct the coupling by
  iterative refinement Z <- Z(I + delta/2), delta = I - Z^T A Z  [paper refs
  4, 19].  Truncation keeps the iterates sparse.

The refinement *policy* (convergence / divergence tests, best-iterate
tracking) lives in :class:`RefineMonitor` so the host driver here and the
device-resident driver in :mod:`repro.dist.inverse` run the identical
iteration on different matrix backends — the same split as
:class:`repro.core.purify.Sp2Monitor` for SP2.  Both drivers thread a
structure-keyed :class:`~repro.core.cache.SymbolicCache` through every
multiply, so refinement iterations on a stabilized sparsity pattern skip the
symbolic phase entirely.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .add import add, identity
from .cache import SymbolicCache
from .matrix import BSMatrix
from .spgemm import multiply
from .truncate import truncate

__all__ = [
    "submatrix",
    "assemble2x2",
    "inv_chol",
    "localized_inverse_factorization",
    "factorization_residual",
    "RefineMonitor",
    "InverseStats",
]


def submatrix(a: BSMatrix, r0: int, r1: int, c0: int, c1: int) -> BSMatrix:
    """Block-range slice a[r0:r1, c0:c1] (block coordinates)."""
    m = (
        (a.coords[:, 0] >= r0)
        & (a.coords[:, 0] < r1)
        & (a.coords[:, 1] >= c0)
        & (a.coords[:, 1] < c1)
    )
    idx = np.nonzero(m)[0]
    coords = a.coords[idx] - np.array([[r0, c0]])
    rows = min((r1 - r0) * a.bs, max(a.shape[0] - r0 * a.bs, 0))
    cols = min((c1 - c0) * a.bs, max(a.shape[1] - c0 * a.bs, 0))
    return BSMatrix(
        shape=(rows, cols),
        bs=a.bs,
        coords=coords,
        data=a.data[jnp.asarray(idx)] if idx.size else a.data[:0],
    )


def assemble2x2(
    a00: BSMatrix, a01: BSMatrix, a10: BSMatrix, a11: BSMatrix, split: int
) -> BSMatrix:
    """Inverse of the quadtree split: glue four quadrants at block offset."""
    bs = a00.bs
    shape = (a00.shape[0] + a11.shape[0], a00.shape[1] + a11.shape[1])
    coords, datas = [], []
    for q, (dr, dc) in (
        (a00, (0, 0)),
        (a01, (0, split)),
        (a10, (split, 0)),
        (a11, (split, split)),
    ):
        if q.nnzb:
            coords.append(q.coords + np.array([[dr, dc]]))
            datas.append(q.data)
    if not coords:
        return BSMatrix.zeros(shape, bs, a00.dtype)
    return BSMatrix.from_blocks(
        shape, bs, np.concatenate(coords), jnp.concatenate(datas)
    )


def _dense_inv_chol(a: BSMatrix) -> BSMatrix:
    """Leaf: Z = L^{-T} where A = L L^T (dense lapack path)."""
    d = np.asarray(a.to_dense(), dtype=np.float64)
    L = np.linalg.cholesky(d)
    z = np.linalg.solve(L.T, np.eye(d.shape[0]))  # L^{-T}
    return BSMatrix.from_dense(z.astype(np.asarray(a.data).dtype), a.bs)


@dataclasses.dataclass
class RefineMonitor:
    """Convergence / divergence policy of the iterative refinement
    Z <- Z(I + delta/2), shared by the host and resident drivers.

    Tracks the most accurate iterate seen; ``update`` flags a stop on
    convergence (residual ||I - Z^T A Z||_F below tolerance), divergence
    (the residual grows 4x past the best seen), or stagnation (no new best
    for ``max_stall`` consecutive iterations — truncation / SpAMM error
    floors the residual above ``tol``, and iterating past the floor is pure
    waste).  On a non-convergence stop the caller returns the best iterate.
    """

    tol: float
    max_stall: int = 3
    best_r: float = float("inf")
    best_iter: int = -1
    stall: int = 0
    improved: bool = False  # whether the last update() set a new best
    # why the last update() returned True: "converged" / "diverged" /
    # "stalled"; None while the loop should continue.  The divergence trip
    # is the one the flight recorder dumps a postmortem on.
    stop_reason: str | None = None

    def update(self, it: int, r: float) -> bool:
        """Record iteration ``it``; return True when refinement should stop."""
        self.improved = r < self.best_r
        if self.improved:
            self.best_r, self.best_iter = r, it
            self.stall = 0
        else:
            self.stall += 1
        if r <= self.tol:
            self.stop_reason = "converged"
            return True
        if r > 4.0 * self.best_r:
            self.stop_reason = "diverged"
            return True
        if self.stall >= self.max_stall:
            self.stop_reason = "stalled"
            return True
        self.stop_reason = None
        return False


@dataclasses.dataclass
class InverseStats:
    """Metrics of one inverse-factorization run (mirrors PurifyStats).

    ``residual_history[i]`` is ``||I - Z_i^T A Z_i||_F`` before update ``i``;
    ``factorization_residual`` is the residual of the returned Z.  The
    symbolic-cache fields report the hit/miss behaviour of the refinement
    loop: once the iterate's sparsity pattern stabilizes under truncation,
    iterations are all hits (the symbolic phase is skipped entirely).
    """

    iterations: int
    residual_history: list
    factorization_residual: float
    nnzb_history: list
    symbolic_cache: dict | None = None
    cache_hits_history: list | None = None
    cache_misses_history: list | None = None


def inv_chol(
    a: BSMatrix,
    leaf_blocks: int = 1,
    *,
    impl: str = "auto",
    cache: SymbolicCache | None = None,
) -> BSMatrix:
    """Recursive inverse Cholesky.  Z upper triangular, Z^T A Z = I.

    Recursion: split A at the quadtree midpoint,
      Z00 = invchol(A00);  W = A01^T Z00;  S = A11 - W W^T;
      Z11 = invchol(S);    Z01 = -Z00 W^T Z11.

    ``cache`` memoizes every multiply's symbolic phase by structure —
    recursions over repeated quadrant structures (banded matrices, SCF-style
    repeated factorizations) skip the descent on the second encounter.
    """
    nbr = a.nblocks[0]
    if nbr <= leaf_blocks:
        return _dense_inv_chol(a)
    depth = int(np.ceil(np.log2(nbr)))
    split = 1 << (depth - 1)
    a00 = submatrix(a, 0, split, 0, split)
    a01 = submatrix(a, 0, split, split, nbr)
    a11 = submatrix(a, split, nbr, split, nbr)
    z00 = inv_chol(a00, leaf_blocks, impl=impl, cache=cache)
    w = multiply(a01.transpose(), z00, impl=impl, cache=cache)  # [n1, n0]
    s = add(a11, multiply(w, w.transpose(), impl=impl, cache=cache), 1.0, -1.0)
    z11 = inv_chol(s, leaf_blocks, impl=impl, cache=cache)
    z01 = multiply(
        multiply(z00, w.transpose(), impl=impl, cache=cache),
        z11,
        impl=impl,
        cache=cache,
    ).scale(-1.0)
    zero = BSMatrix.zeros((a11.shape[0], a00.shape[1]), a.bs, a.dtype)
    return assemble2x2(z00, z01, zero, z11, split)


def factorization_residual(
    a: BSMatrix,
    z: BSMatrix,
    *,
    impl: str = "auto",
    cache: SymbolicCache | None = None,
) -> float:
    """||I - Z^T A Z||_F."""
    zaz = multiply(
        multiply(z.transpose(), a, impl=impl, cache=cache), z, impl=impl, cache=cache
    )
    delta = add(identity(a.shape[0], a.bs, a.dtype), zaz, 1.0, -1.0)
    return delta.frobenius_norm()


def localized_inverse_factorization(
    a: BSMatrix,
    *,
    tol: float = 1e-10,
    max_iter: int = 100,
    trunc_tau: float = 0.0,
    leaf_blocks: int = 1,
    impl: str = "auto",
    cache: SymbolicCache | None = None,
) -> tuple[BSMatrix, InverseStats]:
    """Divide-and-conquer inverse factorization with iterative refinement.

    Factorize the two diagonal quadrants independently, then correct the
    coupling by Z <- Z(I + delta/2), delta = I - Z^T A Z, until
    :class:`RefineMonitor` stops the loop.  Every multiply's symbolic phase
    goes through ``cache`` (a :class:`~repro.core.cache.SymbolicCache`;
    created here when omitted), so iterations whose sparsity pattern is
    stable skip the symbolic phase entirely — hit/miss counts are reported
    per iteration in the returned :class:`InverseStats`.
    """
    cache = cache if cache is not None else SymbolicCache()
    nbr = a.nblocks[0]
    if nbr <= leaf_blocks:
        z = _dense_inv_chol(a)
        return z, InverseStats(
            0, [], factorization_residual(a, z, impl=impl, cache=cache), [z.nnzb],
            cache.stats(), [], [],
        )
    depth = int(np.ceil(np.log2(nbr)))
    split = 1 << (depth - 1)
    a00 = submatrix(a, 0, split, 0, split)
    a11 = submatrix(a, split, nbr, split, nbr)
    z00 = inv_chol(a00, leaf_blocks, impl=impl, cache=cache)
    z11 = inv_chol(a11, leaf_blocks, impl=impl, cache=cache)
    zero01 = BSMatrix.zeros((z00.shape[0], z11.shape[1]), a.bs, a.dtype)
    zero10 = BSMatrix.zeros((z11.shape[0], z00.shape[1]), a.bs, a.dtype)
    z = assemble2x2(z00, zero01, zero10, z11, split)

    eye = identity(a.shape[0], a.bs, a.dtype)
    monitor = RefineMonitor(tol)
    best = z
    history: list[float] = []
    nnzbs, hits_hist, miss_hist = [], [], []
    for it in range(max_iter):
        h0, m0 = cache.hits, cache.misses
        zaz = multiply(
            multiply(z.transpose(), a, impl=impl, cache=cache),
            z,
            impl=impl,
            cache=cache,
        )
        delta = add(eye, zaz, 1.0, -1.0)
        r = delta.frobenius_norm()
        history.append(r)
        nnzbs.append(z.nnzb)
        stop = monitor.update(it, r)
        if monitor.improved:
            best = z
        if not stop:
            step = add(eye, delta, 1.0, 0.5)  # I + delta/2
            z = multiply(z, step, impl=impl, cache=cache)
            if trunc_tau > 0:
                z = truncate(z, trunc_tau)
        hits_hist.append(cache.hits - h0)
        miss_hist.append(cache.misses - m0)
        if stop:
            break
    return best, InverseStats(
        len(history),
        history,
        monitor.best_r,
        nnzbs,
        cache.stats(),
        hits_hist,
        miss_hist,
    )
