"""Leaf matrix libraries (paper §2.1 ships three stand-alone leaf types).

On TPU every leaf is materially a dense ``bs x bs`` VMEM tile (that is what
the MXU consumes); the three paper leaf types survive as *structure policies*
that control (a) pruning when building leaves and (b) exact flop/nnz
accounting at sub-leaf granularity — which is how the paper's Table 1 Tflop
numbers are computed (block-sparse leaves with 64x64 internal blocks).

* ``dense``        — basic_matrix_lib: full leaf, no internal structure.
* ``block_sparse`` — block_sparse_matrix_lib: uniform internal blocks, zero
                     internal blocks neither stored nor counted.
* ``hierarchical`` — hierarchical_block_sparse_lib: quadtree inside the leaf;
                     for accounting identical to block_sparse with
                     power-of-two internal blocks.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .matrix import BSMatrix, block_frobenius_norms

__all__ = [
    "LeafSpec",
    "inner_masks",
    "inner_norms",
    "exact_spgemm_flops",
    "nnz_elements",
]


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    kind: str = "block_sparse"  # dense | block_sparse | hierarchical
    inner_bs: int = 64

    def __post_init__(self):
        assert self.kind in ("dense", "block_sparse", "hierarchical")


def inner_masks(a: BSMatrix, spec: LeafSpec) -> np.ndarray:
    """Bool [nnzb, bs/ibs, bs/ibs]: which internal blocks are nonzero."""
    ibs = a.bs if spec.kind == "dense" else spec.inner_bs
    assert a.bs % ibs == 0
    ni = a.bs // ibs
    data = np.asarray(a.data)
    blocks = data.reshape(a.nnzb, ni, ibs, ni, ibs)
    return np.any(blocks != 0, axis=(2, 4))


def inner_norms(a: BSMatrix, spec: LeafSpec) -> np.ndarray:
    """Float64 [nnzb, ni, ni]: Frobenius norm of each internal block.

    The leaf-policy view of the norm table: zero internal blocks (the ones a
    ``block_sparse`` / ``hierarchical`` policy neither stores nor counts) are
    exact zeros, so these matrices are simultaneously the inner sparsity mask
    and the ingredient of the tightened SpAMM leaf bound
    ``||Na @ Nb||_F <= ||A_leaf||_F * ||B_leaf||_F``
    (:func:`repro.core.spgemm.spamm` with ``leaf_spec=``).  Under
    ``kind="dense"`` the internal block IS the leaf (``ni == 1``) and the
    bound degenerates to the plain norm product.
    """
    ibs = a.bs if spec.kind == "dense" else spec.inner_bs
    assert a.bs % ibs == 0
    return np.asarray(block_frobenius_norms(a.data, inner=ibs), dtype=np.float64)


def nnz_elements(a: BSMatrix, spec: LeafSpec) -> int:
    """Stored elements under the leaf policy (zero internal blocks free)."""
    ibs = a.bs if spec.kind == "dense" else spec.inner_bs
    m = inner_masks(a, spec)
    return int(m.sum()) * ibs * ibs


def exact_spgemm_flops(
    a: BSMatrix, b: BSMatrix, tasks, spec: LeafSpec
) -> float:
    """Exact flops of the task list under the leaf policy.

    Counts 2*ibs^3 per internal (i,k)x(k,j) product with both internal blocks
    nonzero — the convention behind the paper's Table 1 Tflop column.
    """
    ibs = a.bs if spec.kind == "dense" else spec.inner_bs
    ma = inner_masks(a, spec).astype(np.int64)
    mb = inner_masks(b, spec).astype(np.int64)
    # triples per task = sum_ik ma[i,k] * (number of j with mb[k,j])
    mb_rowsum = mb.sum(axis=2)  # [nnzb_b, ni]
    triples = np.einsum(
        "tik,tk->t", ma[tasks.a_idx], mb_rowsum[tasks.b_idx]
    )
    return float(triples.sum()) * 2.0 * ibs**3
