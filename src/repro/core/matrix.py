"""Block-sparse matrix with quadtree (Morton) structure.

``BSMatrix`` is the Chunks-side object of the paper: the *structure* (which
leaf blocks are nonzero) lives on the host as Morton-sorted block coordinates,
the *values* live on device as one stacked array ``data[nnzb, bs, bs]``.
All structure decisions (symbolic multiply, truncation selection, scheduling)
are host-side and cheap; all flops run on device over the stacked blocks.

Leaf representation is delegated to :mod:`repro.core.leaf` (the paper ships
three leaf matrix libraries; see that module).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .quadtree import (
    QuadtreeIndex,
    build_quadtree_index,
    morton_encode,
    morton_sort,
    quadtree_depth,
    structure_fingerprint,
)

__all__ = ["BSMatrix", "block_frobenius_norms"]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class BSMatrix:
    """Block-sparse matrix.

    Attributes:
      shape:  logical (rows, cols); may be any size, blocks pad with zeros.
      bs:     leaf block size (uniform, square).
      coords: host numpy int64 [nnzb, 2] block (row, col), Morton sorted.
      data:   jnp [nnzb, bs, bs] leaf values.
    """

    shape: tuple[int, int]
    bs: int
    coords: np.ndarray
    data: jax.Array

    # -- invariants ---------------------------------------------------------
    def __post_init__(self):
        assert self.coords.ndim == 2 and self.coords.shape[1] == 2
        assert self.data.ndim == 3 and self.data.shape[0] == self.coords.shape[0]
        assert self.data.shape[1] == self.bs and self.data.shape[2] == self.bs

    @property
    def nnzb(self) -> int:
        return int(self.coords.shape[0])

    @property
    def nblocks(self) -> tuple[int, int]:
        return (_ceil_div(self.shape[0], self.bs), _ceil_div(self.shape[1], self.bs))

    @property
    def dtype(self):
        return self.data.dtype

    def codes(self) -> np.ndarray:
        return morton_encode(self.coords[:, 0], self.coords[:, 1])

    @property
    def structure_key(self) -> str:
        """Fingerprint of the sparsity structure (codes + grid + block size).

        The :class:`~repro.core.cache.SymbolicCache` key: value-independent,
        stable across processes.  Cached — the object is frozen, so the
        structure can never change under it.
        """
        key = self.__dict__.get("_structure_key")
        if key is None:
            key = structure_fingerprint(self.codes(), self.nblocks, self.bs)
            object.__setattr__(self, "_structure_key", key)
        return key

    def quadtree_index(
        self, depth: int | None = None, *, with_norms: bool = True
    ) -> QuadtreeIndex:
        """The hierarchical quadtree over this structure.

        ``with_norms=True`` includes subtree Frobenius norms (needed by SpAMM
        and hierarchical truncation; costs one device reduction + sync via
        :func:`block_frobenius_norms`); structure-only consumers (the plain
        multiply descent) pass ``with_norms=False`` and pay nothing.  Cached
        on the matrix per (depth, norms) — a norm-carrying index satisfies
        structure-only requests.  The object is frozen, so structure and
        values are immutable and the cache can never go stale
        (``dataclasses.replace`` produces a fresh object with an empty cache).
        """
        if depth is None:
            depth = quadtree_depth(*self.nblocks)
        cache = self.__dict__.get("_qt_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_qt_cache", cache)
        if (depth, True) in cache:
            return cache[(depth, True)]
        key = (depth, with_norms)
        if key not in cache:
            cache[key] = build_quadtree_index(
                self.coords,
                self.block_norms() if with_norms else None,
                depth=depth,
            )
        return cache[key]

    # -- constructors -------------------------------------------------------
    @staticmethod
    def zeros(shape: tuple[int, int], bs: int, dtype=jnp.float32) -> "BSMatrix":
        return BSMatrix(
            shape=tuple(shape),
            bs=bs,
            coords=np.zeros((0, 2), dtype=np.int64),
            data=jnp.zeros((0, bs, bs), dtype=dtype),
        )

    @staticmethod
    def from_dense(a, bs: int, prune_tol: float = 0.0) -> "BSMatrix":
        """Build from a dense array, pruning blocks with Frobenius norm <= tol."""
        a = np.asarray(a)
        m, n = a.shape
        nbr, nbc = _ceil_div(m, bs), _ceil_div(n, bs)
        pad = np.zeros((nbr * bs, nbc * bs), dtype=a.dtype)
        pad[:m, :n] = a
        blocks = pad.reshape(nbr, bs, nbc, bs).transpose(0, 2, 1, 3)
        norms = np.sqrt((blocks.astype(np.float64) ** 2).sum(axis=(2, 3)))
        rows, cols = np.nonzero(norms > prune_tol)
        coords = np.stack([rows, cols], axis=1).astype(np.int64)
        order = morton_sort(coords)
        coords = coords[order]
        data = jnp.asarray(blocks[coords[:, 0], coords[:, 1]])
        return BSMatrix(shape=(m, n), bs=bs, coords=coords, data=data)

    @staticmethod
    def from_blocks(
        shape: tuple[int, int], bs: int, coords: np.ndarray, data
    ) -> "BSMatrix":
        """Build from explicit block coords (deduplicated, Morton-sorted here)."""
        coords = np.asarray(coords, dtype=np.int64).reshape(-1, 2)
        data = jnp.asarray(data)
        if coords.shape[0] == 0:
            return BSMatrix.zeros(shape, bs, data.dtype)
        codes = morton_encode(coords[:, 0], coords[:, 1])
        order = np.argsort(codes, kind="stable")
        codes_s = codes[order]
        uniq, first = np.unique(codes_s, return_index=True)
        if uniq.size != codes_s.size:  # sum duplicates
            seg = np.zeros(codes_s.size, dtype=np.int64)
            seg[first] = 1
            seg = np.cumsum(seg) - 1
            data = jax.ops.segment_sum(
                data[order], jnp.asarray(seg), num_segments=int(uniq.size)
            )
            coords = coords[order][first]
        else:
            coords = coords[order]
            data = data[order]
        return BSMatrix(shape=tuple(shape), bs=bs, coords=coords, data=data)

    @staticmethod
    def from_coo(
        shape: tuple[int, int],
        bs: int,
        rows: Sequence[int],
        cols: Sequence[int],
        vals,
        dtype=jnp.float32,
    ) -> "BSMatrix":
        """Paper functionality: assignment from (row, col, value) vectors."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals)
        br, bc = rows // bs, cols // bs
        codes = morton_encode(br, bc)
        uniq, inv = np.unique(codes, return_inverse=True)
        nblk = int(uniq.size)
        if nblk == 0:
            return BSMatrix.zeros(shape, bs, dtype)
        # scatter element values into stacked blocks (host, then ship once)
        blocks = np.zeros((nblk, bs, bs), dtype=np.dtype(dtype))
        np.add.at(blocks, (inv, rows % bs, cols % bs), vals)
        order = np.argsort(uniq, kind="stable")  # already sorted by unique, but be safe
        from .quadtree import morton_decode

        r, c = morton_decode(uniq[order])
        coords = np.stack([r, c], axis=1)
        return BSMatrix(shape=tuple(shape), bs=bs, coords=coords, data=jnp.asarray(blocks[order]))

    # -- extraction ---------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        nbr, nbc = self.nblocks
        data = np.asarray(self.data)
        # vectorized scatter: stack -> (nbr, nbc, bs, bs) grid -> 2-D layout
        grid = np.zeros((nbr, nbc, self.bs, self.bs), dtype=data.dtype)
        if self.nnzb:
            grid[self.coords[:, 0], self.coords[:, 1]] = data
        out = grid.transpose(0, 2, 1, 3).reshape(nbr * self.bs, nbc * self.bs)
        return out[:m, :n]

    def get_elements(self, rows: Sequence[int], cols: Sequence[int]) -> np.ndarray:
        """Paper functionality: extract elements by (row, col) index vectors."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        codes = morton_encode(rows // self.bs, cols // self.bs)
        my = self.codes()
        out = np.zeros(rows.shape, dtype=np.asarray(self.data).dtype)
        if my.size == 0:
            return out
        pos = np.searchsorted(my, codes)
        hit = (pos < my.size) & (my[np.minimum(pos, my.size - 1)] == codes)
        if hit.any():
            data = np.asarray(self.data)
            out[hit] = data[pos[hit], rows[hit] % self.bs, cols[hit] % self.bs]
        return out

    # -- simple ops ---------------------------------------------------------
    def scale(self, alpha) -> "BSMatrix":
        return dataclasses.replace(self, data=self.data * jnp.asarray(alpha, self.dtype))

    def transpose(self) -> "BSMatrix":
        coords = self.coords[:, ::-1]
        order = morton_sort(coords)
        return BSMatrix(
            shape=(self.shape[1], self.shape[0]),
            bs=self.bs,
            coords=coords[order],
            data=jnp.transpose(self.data, (0, 2, 1))[jnp.asarray(order)]
            if self.nnzb
            else self.data,
        )

    def block_norms(self) -> np.ndarray:
        """Frobenius norm of each stored block (host numpy)."""
        if self.nnzb == 0:
            return np.zeros((0,), dtype=np.float64)
        return np.asarray(block_frobenius_norms(self.data))

    def frobenius_norm(self) -> float:
        n = self.block_norms()
        return float(np.sqrt((n.astype(np.float64) ** 2).sum()))

    def trace(self) -> float:
        diag = self.coords[:, 0] == self.coords[:, 1]
        if not diag.any():
            return 0.0
        d = self.data[jnp.asarray(np.nonzero(diag)[0])]
        return float(jnp.sum(jnp.trace(d, axis1=1, axis2=2)))

    def density(self) -> float:
        nbr, nbc = self.nblocks
        return self.nnzb / float(nbr * nbc)

    def astype(self, dtype) -> "BSMatrix":
        return dataclasses.replace(self, data=self.data.astype(dtype))


@functools.partial(jax.jit, static_argnames=("inner",))
def block_frobenius_norms(data: jax.Array, inner: int | None = None) -> jax.Array:
    """Frobenius norm over the trailing (bs, bs) axes; any leading batch shape.

    The single norm kernel shared by host block stacks ``[nnzb, bs, bs]`` and
    the resident per-device stores ``[P, cap, bs, bs]``
    (:func:`repro.dist.matrix.resident_block_norms`) — one accumulation dtype,
    so host and resident SpAMM/truncation prune decisions agree bit-for-bit.

    ``inner`` (a divisor of ``bs``) switches to the leaf-policy resolution of
    :class:`repro.core.leaf.LeafSpec`: the result gains trailing ``(ni, ni)``
    axes holding the Frobenius norm of each ``inner x inner`` internal block.
    Zero internal blocks — the ones a ``block_sparse`` leaf policy neither
    stores nor counts — come out as exact zeros, so the inner-norm matrices
    double as the leaf's inner sparsity mask and feed the tightened SpAMM
    product bound ``||Na @ Nb||_F <= ||A||_F ||B||_F``
    (:func:`repro.core.spgemm.spamm` with ``leaf_spec=``).  The default path
    (``inner=None``) is byte-for-byte the original kernel.
    """
    if inner is None:
        return jnp.sqrt(jnp.sum(jnp.square(data.astype(jnp.float32)), axis=(-2, -1)))
    bs = data.shape[-1]
    assert bs % inner == 0, (bs, inner)
    ni = bs // inner
    tiles = data.reshape(*data.shape[:-2], ni, inner, ni, inner)
    return jnp.sqrt(jnp.sum(jnp.square(tiles.astype(jnp.float32)), axis=(-3, -1)))
