"""Outer-product distributed SpGEMM — the paper's stated future work (§5).

The owner-of-C schedule fetches A/B operand blocks to the task site; with
poor data locality (the paper's random-blocks case at high worker counts)
those fetches grow.  The outer-product formulation partitions the
CONTRACTION index k instead:

  * A blocks live with the owner of their block-column k; B blocks with the
    owner of their block-row k — so every task (i,k,j) has BOTH operands
    local by construction: zero operand communication.
  * each device computes partial C blocks for its k-range, then ships each
    partial to the C owner, which reduces arriving contributions.

Communication = volume of partial-C spill (blocks whose contributions arise
on a device other than their owner) instead of operand fetches.  Which side
wins is structure-dependent: banded favours owner-computes (tiny operand
halo), heavy fill-in favours outer-product.  ``plan_outer_stats`` exposes the
comparison; ``choose_schedule`` picks the cheaper plan per structure — the
scheduler-level answer to the paper's "improve the scaling behavior in cases
with poor data locality".
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .quadtree import morton_encode
from .spgemm import Tasks, spgemm_symbolic
from .schedule import SpgemmPlan, make_spgemm_plan, partition_morton, plan_stats, _pad_ragged

__all__ = ["OuterPlan", "make_outer_plan", "plan_outer_stats", "choose_schedule"]


@dataclasses.dataclass(frozen=True)
class OuterPlan:
    """Static schedule for one outer-product multiply."""

    nparts: int
    bs: int
    # operand placement by contraction index
    a_owner: np.ndarray  # owner of A block = k_owner[col]
    b_owner: np.ndarray
    a_slot: np.ndarray
    b_slot: np.ndarray
    a_cap: int
    b_cap: int
    a_store_idx: np.ndarray
    b_store_idx: np.ndarray
    a_store_valid: np.ndarray
    b_store_valid: np.ndarray
    # local tasks (all-local operands): [P, t_cap]
    t_cap: int
    task_a: np.ndarray
    task_b: np.ndarray
    task_c: np.ndarray  # local partial-C slot, sorted
    task_count: np.ndarray
    # partial C: per device list of (global C block) it produces
    p_cap: int
    partial_c_global: np.ndarray  # [P, p_cap] global C idx per local partial slot
    partial_valid: np.ndarray
    # exchange of partials: offsets + send slot lists, and receive accumulate
    offsets: tuple[int, ...]
    send: dict[int, np.ndarray]  # [P, cap_d] local partial slots to send
    send_count: dict[int, np.ndarray]
    # destination accumulate indices: for [own partials | recv buffers] ->
    # local C slot (or c_cap trash for partials owned elsewhere)
    acc_idx: np.ndarray  # [P, acc_cap]
    acc_cap: int
    # output placement
    c_coords: np.ndarray
    c_owner: np.ndarray
    c_slot: np.ndarray
    c_cap: int
    c_store_idx: np.ndarray
    c_store_valid: np.ndarray
    tasks: Tasks


def make_outer_plan(
    a_coords: np.ndarray,
    b_coords: np.ndarray,
    nparts: int,
    bs: int,
    *,
    tasks: Tasks | None = None,
) -> OuterPlan:
    a_coords = np.asarray(a_coords)
    b_coords = np.asarray(b_coords)
    tasks = tasks if tasks is not None else spgemm_symbolic(a_coords, b_coords)
    nk = int(max(a_coords[:, 1].max(initial=0), b_coords[:, 0].max(initial=0))) + 1

    # partition the contraction index by task weight
    t_k = a_coords[tasks.a_idx, 1]
    kw = np.bincount(t_k, minlength=nk).astype(np.float64)
    k_owner = partition_morton(nk, nparts, kw)  # contiguous k ranges
    a_owner = k_owner[a_coords[:, 1]].astype(np.int32)
    b_owner = k_owner[b_coords[:, 0]].astype(np.int32)
    t_owner = k_owner[t_k]

    def owner_slots(owner):
        slot = np.zeros(owner.shape[0], dtype=np.int32)
        stores = []
        for p in range(nparts):
            idx = np.nonzero(owner == p)[0]
            slot[idx] = np.arange(idx.size, dtype=np.int32)
            stores.append(idx.astype(np.int32))
        return slot, stores

    a_slot, a_stores = owner_slots(a_owner)
    b_slot, b_stores = owner_slots(b_owner)
    a_cap = max(max((len(s) for s in a_stores), default=0), 1)
    b_cap = max(max((len(s) for s in b_stores), default=0), 1)

    def store_arrays(stores, cap, n):
        idx = np.zeros((nparts, cap), dtype=np.int32)
        valid = np.zeros((nparts, cap), dtype=bool)
        for p, s in enumerate(stores):
            idx[p, : len(s)] = s
            valid[p, : len(s)] = True
        return idx, valid

    a_store_idx, a_store_valid = store_arrays(a_stores, a_cap, len(a_coords))
    b_store_idx, b_store_valid = store_arrays(b_stores, b_cap, len(b_coords))

    # C ownership: Morton contiguous weighted by task count (same as p2p plan)
    nc = tasks.num_out
    cw = np.bincount(tasks.c_idx, minlength=nc).astype(np.float64)
    c_owner = partition_morton(nc, nparts, cw).astype(np.int32)
    c_slot, c_stores = owner_slots(c_owner)
    c_cap = max(max((len(s) for s in c_stores), default=0), 1)
    c_store_idx, c_store_valid = store_arrays(c_stores, c_cap, nc)

    # per-device: local partial-C index space + task lists
    task_a_l, task_b_l, task_c_l, partials = [], [], [], []
    for p in range(nparts):
        sel = np.nonzero(t_owner == p)[0]
        local_c_glob = np.unique(tasks.c_idx[sel])
        remap = {int(g): i for i, g in enumerate(local_c_glob)}
        tc = np.array([remap[int(g)] for g in tasks.c_idx[sel]], dtype=np.int32)
        order = np.argsort(tc, kind="stable")
        sel = sel[order]
        tc = tc[order]
        task_a_l.append(a_slot[tasks.a_idx[sel]])
        task_b_l.append(b_slot[tasks.b_idx[sel]])
        task_c_l.append(tc)
        partials.append(local_c_glob.astype(np.int32))

    t_cap = max(max((len(x) for x in task_a_l), default=0), 1)
    p_cap = max(max((len(x) for x in partials), default=0), 1)
    task_count = np.array([len(x) for x in task_a_l], dtype=np.int64)
    partial_c_global = _pad_ragged(partials, 0)
    partial_valid = np.zeros((nparts, p_cap), dtype=bool)
    for p, g in enumerate(partials):
        partial_valid[p, : len(g)] = True

    # exchange plan: device p sends partial slot s to owner of its C block
    send: dict[int, list] = {}
    recv_lists: dict[int, list] = {}  # dst -> list of (offset, src_order, global)
    for src in range(nparts):
        g = partials[src]
        dst_owner = c_owner[g]
        for dst in np.unique(dst_owner):
            if dst == src:
                continue
            d = int((dst - src) % nparts)
            slots = np.nonzero(dst_owner == dst)[0].astype(np.int32)
            send.setdefault(d, [np.zeros(0, np.int32)] * nparts)
            send[d][src] = slots
            recv_lists.setdefault(int(dst), []).append((d, g[slots]))
    offsets = tuple(sorted(send.keys()))
    send_pad = {d: _pad_ragged(send[d], 0) for d in offsets}
    send_cnt = {d: np.array([len(x) for x in send[d]], dtype=np.int64) for d in offsets}

    # accumulate layout on dst: [own partials (p_cap) | recv buffers per offset]
    acc_cap = p_cap + sum(send_pad[d].shape[1] for d in offsets)
    acc_idx = np.full((nparts, acc_cap), c_cap, dtype=np.int32)  # trash default
    for p in range(nparts):
        g = partials[p]
        own = c_owner[g] == p
        acc_idx[p, : len(g)][own] = c_slot[g[own]]
        base = p_cap
        for d in offsets:
            cap_d = send_pad[d].shape[1]
            src = (p - d) % nparts
            pairs = [x for x in recv_lists.get(p, []) if x[0] == d]
            if pairs:
                arriving = pairs[0][1]
                acc_idx[p, base : base + len(arriving)] = c_slot[arriving]
            base += cap_d

    return OuterPlan(
        nparts=nparts,
        bs=bs,
        a_owner=a_owner,
        b_owner=b_owner,
        a_slot=a_slot,
        b_slot=b_slot,
        a_cap=a_cap,
        b_cap=b_cap,
        a_store_idx=a_store_idx,
        b_store_idx=b_store_idx,
        a_store_valid=a_store_valid,
        b_store_valid=b_store_valid,
        t_cap=t_cap,
        task_a=_pad_ragged(task_a_l, 0),
        task_b=_pad_ragged(task_b_l, 0),
        task_c=_pad_ragged(task_c_l, p_cap),  # trash partial row
        task_count=task_count,
        p_cap=p_cap,
        partial_c_global=partial_c_global,
        partial_valid=partial_valid,
        offsets=offsets,
        send=send_pad,
        send_count=send_cnt,
        acc_idx=acc_idx,
        acc_cap=acc_cap,
        c_coords=tasks.c_coords,
        c_owner=c_owner,
        c_slot=c_slot,
        c_cap=c_cap,
        c_store_idx=c_store_idx,
        c_store_valid=c_store_valid,
        tasks=tasks,
    )


def plan_outer_stats(plan: OuterPlan) -> dict:
    P = plan.nparts
    blk = plan.bs * plan.bs * 4
    recv = np.zeros(P, dtype=np.float64)
    for d in plan.offsets:
        cnt = plan.send_count[d]
        for src in range(P):
            recv[(src + d) % P] += cnt[src] * blk
    tasks = plan.task_count.astype(np.float64)
    mean_t = max(tasks.mean(), 1e-12)
    return dict(
        nparts=P,
        tasks_total=int(tasks.sum()),
        task_balance=float(tasks.max() / mean_t),
        recv_bytes_mean=float(recv.mean()),
        recv_bytes_max=float(recv.max()),
        n_offsets=len(plan.offsets),
    )


def choose_schedule(a_coords, b_coords, nparts, bs, *, tasks=None):
    """Pick owner-computes vs outer-product by planned communication volume.

    Returns ("p2p"|"outer", plan, stats).  This is the structure-adaptive
    scheduler the paper's future-work section asks for.
    """
    tasks = tasks if tasks is not None else spgemm_symbolic(a_coords, b_coords)
    p2p = make_spgemm_plan(a_coords, b_coords, nparts, bs, tasks=tasks)
    outer = make_outer_plan(a_coords, b_coords, nparts, bs, tasks=tasks)
    s1 = plan_stats(p2p)
    s2 = plan_outer_stats(outer)
    if s1["recv_bytes_mean"] <= s2["recv_bytes_mean"]:
        return "p2p", p2p, s1
    return "outer", outer, s2
