"""Density matrix purification (SP2) — the paper's driving application.

Given a symmetric "Hamiltonian" F, eigenvalue bounds, and an occupation count
n_occ, compute the density matrix D = theta(mu*I - F) (projector onto the
n_occ lowest eigenstates) using only the library's multiply / add / trace /
truncate task types — the multiplication-heavy workload the library was built
for (paper refs 15, 3).
"""

from __future__ import annotations

import dataclasses

from .add import add, add_scaled_identity, identity
from .matrix import BSMatrix
from .spgemm import multiply
from .truncate import truncate

__all__ = ["sp2_purify", "PurifyStats"]


@dataclasses.dataclass
class PurifyStats:
    iterations: int
    trace_history: list
    idempotency_history: list
    nnzb_history: list


def sp2_purify(
    f: BSMatrix,
    n_occ: float,
    lmin: float,
    lmax: float,
    *,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    impl: str = "auto",
) -> tuple[BSMatrix, PurifyStats]:
    """SP2 (trace-correcting) purification.

    X0 = (lmax*I - F) / (lmax - lmin); then X <- X^2 when trace(X) > n_occ
    else X <- 2X - X^2, until idempotency ||X^2 - X|| is below tolerance.
    """
    span = lmax - lmin
    x = add_scaled_identity(f.scale(-1.0 / span), lmax / span)
    traces, idems, nnzbs = [], [], []
    best, best_idem = x, float("inf")
    for it in range(max_iter):
        x2 = multiply(x, x, impl=impl)
        idem = add(x2, x, 1.0, -1.0).frobenius_norm()
        tr = x.trace()
        traces.append(tr)
        idems.append(idem)
        nnzbs.append(x.nnzb)
        if idem < best_idem:
            best, best_idem = x, idem
        if idem <= idem_tol:
            break
        # divergence guard: in finite precision eigenvalues drift outside
        # [0, 1] and repeated squaring then blows up — return the most
        # idempotent iterate seen instead of iterating past the noise floor.
        if idem > 4.0 * best_idem:
            break
        if tr > n_occ:
            x = x2
        else:
            x = add(x, x2, 2.0, -1.0)
        if trunc_tau > 0:
            x = truncate(x, trunc_tau)
    return best, PurifyStats(len(traces), traces, idems, nnzbs)
