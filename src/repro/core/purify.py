"""Density matrix purification (SP2) — the paper's driving application.

Given a symmetric "Hamiltonian" F, eigenvalue bounds, and an occupation count
n_occ, compute the density matrix D = theta(mu*I - F) (projector onto the
n_occ lowest eigenstates) using only the library's multiply / add / trace /
truncate task types — the multiplication-heavy workload the library was built
for (paper refs 15, 3).

The SP2 *policy* (initial congruence coefficients, branch selection,
convergence / divergence tests) is factored out so the single-host driver
here and the device-resident distributed driver in
:mod:`repro.dist.purify` run the identical iteration on different matrix
backends.
"""

from __future__ import annotations

import dataclasses

from .add import add, add_scaled_identity, identity
from .cache import SymbolicCache
from .matrix import BSMatrix
from .spgemm import multiply
from .truncate import truncate

__all__ = [
    "sp2_purify",
    "PurifyStats",
    "sp2_init_coeffs",
    "sp2_should_square",
    "Sp2Monitor",
]


def sp2_init_coeffs(lmin: float, lmax: float) -> tuple[float, float]:
    """(scale, shift) with X0 = scale*F + shift*I = (lmax*I - F)/(lmax - lmin),
    mapping spec(F) in [lmin, lmax] onto [0, 1] reversed."""
    span = lmax - lmin
    return -1.0 / span, lmax / span


def sp2_should_square(trace: float, n_occ: float) -> bool:
    """Trace-correcting branch: X <- X^2 when trace(X) > n_occ, else 2X - X^2."""
    return trace > n_occ


@dataclasses.dataclass
class Sp2Monitor:
    """Convergence / divergence bookkeeping shared by both SP2 drivers.

    Tracks the most idempotent iterate seen; ``done`` flags convergence
    (idempotency below tolerance) or divergence (in finite precision
    eigenvalues drift outside [0, 1] and repeated squaring blows up — stop
    once idempotency regresses 4x past the best seen, and report the best
    iterate instead of iterating past the noise floor).
    """

    idem_tol: float
    best_idem: float = float("inf")
    best_iter: int = -1
    improved: bool = False  # whether the last update() set a new best
    # why the last update() returned True: "converged" / "diverged"; None
    # while the loop should continue (mirrors RefineMonitor.stop_reason)
    stop_reason: str | None = None

    def update(self, it: int, idem: float) -> bool:
        """Record iteration ``it``; return True when the loop should stop.

        ``improved`` afterwards tells the caller whether this iterate is the
        new most-idempotent one (so it can retain it as the result).
        """
        self.improved = idem < self.best_idem
        if self.improved:
            self.best_idem, self.best_iter = idem, it
        if idem <= self.idem_tol:
            self.stop_reason = "converged"
            return True
        if idem > 4.0 * self.best_idem:
            self.stop_reason = "diverged"
            return True
        self.stop_reason = None
        return False


@dataclasses.dataclass
class PurifyStats:
    iterations: int
    trace_history: list
    idempotency_history: list
    nnzb_history: list
    # symbolic-phase cache metrics: SymbolicCache.stats() at exit, plus the
    # per-iteration hit counts (an iteration on a stable sparsity pattern is
    # all hits — the symbolic phase is skipped entirely)
    symbolic_cache: dict | None = None
    cache_hits_history: list | None = None


def sp2_purify(
    f: BSMatrix,
    n_occ: float,
    lmin: float,
    lmax: float,
    *,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    impl: str = "auto",
    cache: SymbolicCache | None = None,
) -> tuple[BSMatrix, PurifyStats]:
    """SP2 (trace-correcting) purification.

    X0 = (lmax*I - F) / (lmax - lmin); then X <- X^2 when trace(X) > n_occ
    else X <- 2X - X^2, until idempotency ||X^2 - X|| is below tolerance.

    The multiply symbolic phase goes through a structure-keyed
    :class:`~repro.core.cache.SymbolicCache` (pass one to share across
    calls): iterations whose sparsity pattern is stable skip the symbolic
    phase entirely — the host-side mirror of
    :class:`repro.dist.PlanCache` on the distributed path.
    """
    cache = cache if cache is not None else SymbolicCache()
    scale, shift = sp2_init_coeffs(lmin, lmax)
    x = add_scaled_identity(f.scale(scale), shift)
    traces, idems, nnzbs, cache_hits = [], [], [], []
    monitor = Sp2Monitor(idem_tol)
    best = x
    for it in range(max_iter):
        h0 = cache.hits
        x2 = multiply(x, x, impl=impl, cache=cache)
        idem = add(x2, x, 1.0, -1.0).frobenius_norm()
        tr = x.trace()
        traces.append(tr)
        idems.append(idem)
        nnzbs.append(x.nnzb)
        cache_hits.append(cache.hits - h0)
        stop = monitor.update(it, idem)
        if monitor.improved:
            best = x
        if stop:
            break
        if sp2_should_square(tr, n_occ):
            x = x2
        else:
            x = add(x, x2, 2.0, -1.0)
        if trunc_tau > 0:
            x = truncate(x, trunc_tau)
    return best, PurifyStats(
        len(traces), traces, idems, nnzbs, cache.stats(), cache_hits
    )
