"""Sparse quadtree structure utilities (host side, numpy).

The paper represents a matrix as a sparse quaternary tree: a node is either
identically zero, a leaf matrix, or four recursively represented quadrants.
On TPU we keep the *data* in a flat device array of fixed-size leaf blocks and
the *structure* as host-side block coordinates.  The quadtree is implicit in
the Morton (Z-order) codes of the block coordinates: every quadtree node at
level L corresponds to a 2L-bit Morton prefix, and zero branches are exactly
the absent prefixes.  Morton order is the canonical block ordering throughout
the library — it is what gives the scheduler its locality (children of a
quadtree node are contiguous in Morton order, mirroring the paper's
"tasks operating on the same chunk execute on the same worker").
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "morton_encode",
    "morton_decode",
    "morton_sort",
    "quadtree_node_counts",
    "quadtree_depth",
    "expand_prefix",
]

_B = [
    0x5555555555555555,
    0x3333333333333333,
    0x0F0F0F0F0F0F0F0F,
    0x00FF00FF00FF00FF,
    0x0000FFFF0000FFFF,
]


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of x so there is a zero bit between each."""
    x = x.astype(np.uint64)
    x = (x | (x << np.uint64(16))) & np.uint64(_B[4])
    x = (x | (x << np.uint64(8))) & np.uint64(_B[3])
    x = (x | (x << np.uint64(4))) & np.uint64(_B[2])
    x = (x | (x << np.uint64(2))) & np.uint64(_B[1])
    x = (x | (x << np.uint64(1))) & np.uint64(_B[0])
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(_B[0])
    x = (x | (x >> np.uint64(1))) & np.uint64(_B[1])
    x = (x | (x >> np.uint64(2))) & np.uint64(_B[2])
    x = (x | (x >> np.uint64(4))) & np.uint64(_B[3])
    x = (x | (x >> np.uint64(8))) & np.uint64(_B[4])
    x = (x | (x >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return x


def morton_encode(row: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Interleave bits of (row, col) -> Z-order code.  row in even bits."""
    row = np.asarray(row)
    col = np.asarray(col)
    return (_part1by1(row) << np.uint64(1)) | _part1by1(col)


def morton_decode(code: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    code = np.asarray(code, dtype=np.uint64)
    row = _compact1by1(code >> np.uint64(1))
    col = _compact1by1(code)
    return row.astype(np.int64), col.astype(np.int64)


def morton_sort(coords: np.ndarray) -> np.ndarray:
    """Return the permutation that sorts (row, col) block coords in Z-order."""
    coords = np.asarray(coords)
    if coords.size == 0:
        return np.zeros((0,), dtype=np.int64)
    codes = morton_encode(coords[:, 0], coords[:, 1])
    return np.argsort(codes, kind="stable")


def quadtree_depth(nblocks_row: int, nblocks_col: int) -> int:
    """Number of quadtree levels above the leaves for a grid of blocks."""
    n = max(int(nblocks_row), int(nblocks_col), 1)
    return int(np.ceil(np.log2(n))) if n > 1 else 0


def quadtree_node_counts(coords: np.ndarray, depth: int | None = None) -> list[int]:
    """Number of *nonzero* quadtree nodes per level, root (level 0) to leaves.

    Level k nodes are the distinct 2k-bit Morton prefixes present in the
    structure.  This is the paper's "nonzero branches": everything absent is a
    nil chunk id and costs nothing.
    """
    coords = np.asarray(coords)
    if coords.size == 0:
        return [0]
    codes = morton_encode(coords[:, 0], coords[:, 1])
    if depth is None:
        depth = quadtree_depth(int(coords[:, 0].max()) + 1, int(coords[:, 1].max()) + 1)
    counts = []
    for level in range(depth + 1):
        shift = np.uint64(2 * (depth - level))
        counts.append(int(np.unique(codes >> shift).size))
    return counts


def expand_prefix(prefix: int, level: int, depth: int) -> tuple[int, int, int, int]:
    """Block-coordinate bounding box (r0, r1, c0, c1) of a Morton prefix node."""
    side = 1 << (depth - level)
    r, c = morton_decode(np.asarray([prefix << (2 * (depth - level))], dtype=np.uint64))
    return int(r[0]), int(r[0]) + side, int(c[0]), int(c[0]) + side
