"""Sparse quadtree structure utilities (host side, numpy).

The paper represents a matrix as a sparse quaternary tree: a node is either
identically zero, a leaf matrix, or four recursively represented quadrants.
On TPU we keep the *data* in a flat device array of fixed-size leaf blocks and
the *structure* as host-side block coordinates.  The quadtree lives in
the Morton (Z-order) codes of the block coordinates: every quadtree node at
level L corresponds to a 2L-bit Morton prefix, and zero branches are exactly
the absent prefixes.  Morton order is the canonical block ordering throughout
the library — it is what gives the scheduler its locality (children of a
quadtree node are contiguous in Morton order, mirroring the paper's
"tasks operating on the same chunk execute on the same worker").

:class:`QuadtreeIndex` makes the hierarchy first-class: per-level sorted
prefix arrays with CSR parent->child and node->leaf spans plus per-node
subtree Frobenius norms, built once per structure and cached on
:class:`~repro.core.matrix.BSMatrix`.  The symbolic phases in
:mod:`repro.core.spgemm` descend it level-by-level (vectorized), SpAMM and
:func:`repro.core.truncate.truncate_hierarchical` prune whole subtrees
against the norms, and :mod:`repro.core.schedule` snaps partition cuts to
its node boundaries.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = [
    "morton_encode",
    "morton_decode",
    "morton_sort",
    "quadtree_node_counts",
    "quadtree_depth",
    "expand_prefix",
    "structure_fingerprint",
    "QuadtreeIndex",
    "build_quadtree_index",
    "hierarchical_drop_mask",
]

_B = [
    0x5555555555555555,
    0x3333333333333333,
    0x0F0F0F0F0F0F0F0F,
    0x00FF00FF00FF00FF,
    0x0000FFFF0000FFFF,
]


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of x so there is a zero bit between each."""
    x = x.astype(np.uint64)
    x = (x | (x << np.uint64(16))) & np.uint64(_B[4])
    x = (x | (x << np.uint64(8))) & np.uint64(_B[3])
    x = (x | (x << np.uint64(4))) & np.uint64(_B[2])
    x = (x | (x << np.uint64(2))) & np.uint64(_B[1])
    x = (x | (x << np.uint64(1))) & np.uint64(_B[0])
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(_B[0])
    x = (x | (x >> np.uint64(1))) & np.uint64(_B[1])
    x = (x | (x >> np.uint64(2))) & np.uint64(_B[2])
    x = (x | (x >> np.uint64(4))) & np.uint64(_B[3])
    x = (x | (x >> np.uint64(8))) & np.uint64(_B[4])
    x = (x | (x >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return x


def morton_encode(row: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Interleave bits of (row, col) -> Z-order code.  row in even bits."""
    row = np.asarray(row)
    col = np.asarray(col)
    return (_part1by1(row) << np.uint64(1)) | _part1by1(col)


def morton_decode(code: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    code = np.asarray(code, dtype=np.uint64)
    row = _compact1by1(code >> np.uint64(1))
    col = _compact1by1(code)
    return row.astype(np.int64), col.astype(np.int64)


def morton_sort(coords: np.ndarray) -> np.ndarray:
    """Return the permutation that sorts (row, col) block coords in Z-order."""
    coords = np.asarray(coords)
    if coords.size == 0:
        return np.zeros((0,), dtype=np.int64)
    codes = morton_encode(coords[:, 0], coords[:, 1])
    return np.argsort(codes, kind="stable")


def quadtree_depth(nblocks_row: int, nblocks_col: int) -> int:
    """Number of quadtree levels above the leaves for a grid of blocks."""
    n = max(int(nblocks_row), int(nblocks_col), 1)
    return int(np.ceil(np.log2(n))) if n > 1 else 0


def quadtree_node_counts(coords: np.ndarray, depth: int | None = None) -> list[int]:
    """Number of *nonzero* quadtree nodes per level, root (level 0) to leaves.

    Level k nodes are the distinct 2k-bit Morton prefixes present in the
    structure.  This is the paper's "nonzero branches": everything absent is a
    nil chunk id and costs nothing.
    """
    coords = np.asarray(coords)
    if coords.size == 0:
        return [0]
    codes = morton_encode(coords[:, 0], coords[:, 1])
    if depth is None:
        depth = quadtree_depth(int(coords[:, 0].max()) + 1, int(coords[:, 1].max()) + 1)
    counts = []
    for level in range(depth + 1):
        shift = np.uint64(2 * (depth - level))
        counts.append(int(np.unique(codes >> shift).size))
    return counts


def expand_prefix(prefix: int, level: int, depth: int) -> tuple[int, int, int, int]:
    """Block-coordinate bounding box (r0, r1, c0, c1) of a Morton prefix node."""
    side = 1 << (depth - level)
    r, c = morton_decode(np.asarray([prefix << (2 * (depth - level))], dtype=np.uint64))
    return int(r[0]), int(r[0]) + side, int(c[0]), int(c[0]) + side


def structure_fingerprint(*parts) -> str:
    """Stable hex digest of a structure: arrays hashed by bytes, scalars by repr.

    The chunk-cache key analogue: two matrices with identical Morton codes
    (and two plans over identical structures) produce identical fingerprints
    across processes — ``hash()`` randomization and object identity play no
    role.  Used by :class:`repro.dist.PlanCache` and
    :class:`repro.core.cache.SymbolicCache`.
    """
    h = hashlib.blake2b(digest_size=16)
    for part in parts:
        if isinstance(part, np.ndarray):
            arr = np.ascontiguousarray(part)
            h.update(str(arr.dtype).encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        else:
            h.update(repr(part).encode())
        h.update(b"|")
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class QuadtreeIndex:
    """First-class hierarchical quadtree over a Morton-sorted block structure.

    The paper's sparse quaternary tree, materialized: level ``k`` holds the
    sorted ``2k``-bit Morton prefixes of the nonzero nodes, with CSR-style
    parent->child spans into level ``k+1`` (children of a node are contiguous
    because prefixes are sorted), leaf spans into the block stack, and —
    when built with leaf norms — per-node *subtree* Frobenius norms.  These
    are exactly the internal-node norms the paper's multiplication, SpAMM and
    truncation tasks use to prune whole subtrees without visiting them.

    Attributes:
      depth:        levels above the leaves (level 0 = root, level depth = leaves).
      prefixes:     per level, sorted uint64 Morton prefixes of nonzero nodes.
      child_start:  per level k < depth, int64 [n_k + 1] CSR spans: children of
                    node j at level k are prefixes[k+1][child_start[k][j] :
                    child_start[k][j+1]].
      leaf_start:   per level, int64 [n_k + 1] spans into the Morton-sorted
                    block stack covered by each node's subtree.
      norms:        per level, float64 [n_k] subtree Frobenius norms, or None
                    for a structure-only index.
      fingerprint:  structure fingerprint of (leaf codes, depth) — the cache
                    key shared with :class:`repro.core.cache.SymbolicCache`.
    """

    depth: int
    prefixes: tuple[np.ndarray, ...]
    child_start: tuple[np.ndarray, ...]
    leaf_start: tuple[np.ndarray, ...]
    norms: tuple[np.ndarray, ...] | None
    fingerprint: str

    @property
    def nnzb(self) -> int:
        return int(self.prefixes[-1].size)

    def num_nodes(self) -> int:
        """Total nonzero nodes across all levels."""
        return int(sum(p.size for p in self.prefixes))

    def node_counts(self) -> list[int]:
        return [int(p.size) for p in self.prefixes]

    def boundaries(self, level: int | None = None) -> np.ndarray:
        """Sorted unique leaf positions that start a quadtree node.

        ``level`` restricts to one level; default merges every level —
        the candidate cut positions for subtree-aligned Morton partitioning
        (:func:`repro.core.schedule.partition_morton` with ``align=``).
        """
        if level is not None:
            return np.unique(self.leaf_start[level])
        return np.unique(np.concatenate([ls for ls in self.leaf_start]))


def hierarchical_drop_mask(qt: QuadtreeIndex, tau: float) -> tuple[np.ndarray, int]:
    """Top-down greedy subtree-drop selection under a global Frobenius budget.

    The shared symbolic phase of hierarchical truncation (host
    :func:`repro.core.truncate.truncate_hierarchical` and the distributed
    ``dist_truncate_hierarchical``): at each level, the frontier nodes with
    smallest subtree norms are dropped while the *squared* budget allows (a
    subtree's squared Frobenius norm is exactly the sum of its leaf squares,
    so the accounting is exact); survivors descend.

    Returns ``(keep, nodes_visited)``: ``keep`` is a bool mask over the
    Morton-sorted leaf stack (False = the leaf lies under a dropped subtree)
    with ``sqrt(sum of dropped leaf norms^2) <= tau`` by construction, and
    ``nodes_visited`` counts the frontier nodes whose norms were examined —
    nodes (and leaves) below a dropped subtree are never visited.
    """
    assert qt.norms is not None, "hierarchical drop needs subtree norms"
    nnzb = qt.nnzb
    if nnzb == 0:
        return np.zeros((0,), dtype=bool), 0
    budget_sq = float(tau) ** 2
    drop_mark = np.zeros(nnzb + 1, dtype=np.int64)
    frontier = np.zeros(1, dtype=np.int64)  # root
    visited = 0
    for level in range(qt.depth + 1):
        visited += int(frontier.size)
        sq = qt.norms[level][frontier] ** 2
        order = np.argsort(sq)
        csum = np.cumsum(sq[order])
        ndrop = int(np.searchsorted(csum, budget_sq, side="right"))
        if ndrop:
            budget_sq -= float(csum[ndrop - 1])
            dropped = frontier[order[:ndrop]]
            ls = qt.leaf_start[level]
            np.add.at(drop_mark, ls[dropped], 1)
            np.add.at(drop_mark, ls[dropped + 1], -1)
            keep_nodes = np.ones(frontier.size, dtype=bool)
            keep_nodes[order[:ndrop]] = False
            frontier = frontier[keep_nodes]
        if frontier.size == 0 or level == qt.depth:
            break
        cs = qt.child_start[level]
        s0 = cs[frontier]
        counts = cs[frontier + 1] - s0
        local = np.arange(int(counts.sum())) - np.repeat(np.cumsum(counts) - counts, counts)
        frontier = np.repeat(s0, counts) + local
    keep = np.cumsum(drop_mark[:-1]) == 0
    return keep, visited


def build_quadtree_index(
    coords: np.ndarray,
    leaf_norms: np.ndarray | None = None,
    depth: int | None = None,
) -> QuadtreeIndex:
    """Build a :class:`QuadtreeIndex` from Morton-sorted block coords.

    ``leaf_norms`` (per-block Frobenius norms, stack order) enables the
    subtree-norm levels; omit for a structure-only index.  ``depth`` may be
    raised above the natural grid depth so two operands of a multiply share a
    common root (extra top levels are chains of single nodes).
    """
    coords = np.asarray(coords)
    n = coords.shape[0]
    if depth is None:
        top = int(max(coords.max(initial=0), 1))
        depth = 0
        while (1 << depth) <= top:
            depth += 1
    if n == 0:
        z = np.zeros((0,), dtype=np.uint64)
        s = np.zeros((1,), dtype=np.int64)
        return QuadtreeIndex(
            depth=depth,
            prefixes=tuple(z for _ in range(depth + 1)),
            child_start=tuple(s for _ in range(depth)),
            leaf_start=tuple(s for _ in range(depth + 1)),
            norms=None if leaf_norms is None else tuple(
                np.zeros((0,), dtype=np.float64) for _ in range(depth + 1)
            ),
            fingerprint=structure_fingerprint(z, depth),
        )
    codes = morton_encode(coords[:, 0], coords[:, 1])
    assert np.all(np.diff(codes.astype(np.int64)) > 0), "coords must be Morton-sorted, unique"
    prefixes = [codes >> np.uint64(2 * (depth - k)) for k in range(depth + 1)]
    prefixes = [np.unique(p) for p in prefixes[:-1]] + [prefixes[-1]]
    child_start = []
    for k in range(depth):
        parent = prefixes[k + 1] >> np.uint64(2)
        starts = np.searchsorted(parent, prefixes[k], side="left")
        child_start.append(
            np.concatenate([starts, [prefixes[k + 1].size]]).astype(np.int64)
        )
    leaf_start = [None] * (depth + 1)
    leaf_start[depth] = np.arange(n + 1, dtype=np.int64)
    for k in range(depth - 1, -1, -1):
        leaf_start[k] = leaf_start[k + 1][child_start[k]]
    norms = None
    if leaf_norms is not None:
        leaf_norms = np.asarray(leaf_norms, dtype=np.float64)
        assert leaf_norms.shape == (n,)
        sq = [None] * (depth + 1)
        sq[depth] = leaf_norms**2
        for k in range(depth - 1, -1, -1):
            sq[k] = np.add.reduceat(sq[k + 1], child_start[k][:-1])
        norms = tuple(np.sqrt(s) for s in sq)
    return QuadtreeIndex(
        depth=depth,
        prefixes=tuple(prefixes),
        child_start=tuple(child_start),
        leaf_start=tuple(leaf_start),
        norms=norms,
        fingerprint=structure_fingerprint(codes, depth),
    )
