"""Locality-aware static scheduling — the CHT runtime analogue on TPU.

CHT-MPI maps chunks and tasks to workers dynamically (decentralized data,
breadth-first work stealing).  An XLA SPMD program cannot migrate work
mid-step, so the equivalent decisions are made *here*, on the host, per
matrix structure:

* **Data placement** (= chunk placement): Morton-order contiguous range
  partition of the block stacks.  Children of a quadtree node are contiguous
  in Morton order, so this is precisely "blocks of the same subtree live on
  the same worker" — the locality CHT gets from hierarchical chunk identifiers.
* **Task placement** (= task scheduling): owner-of-C computes; the C
  partition is weighted by per-block task counts (flop cost model), which is
  the static equivalent of work stealing achieving flop balance.
* **Communication plan** (= chunk fetching/caching): for every task, its A/B
  operand blocks are either local or fetched from a peer; the full exchange
  is planned here as per-offset ``ppermute`` rounds, and only referenced
  blocks ever move (CHT's chunk cache pulls exactly the chunks tasks touch).

A ``random`` placement mode destroys locality on purpose — it reproduces the
random-permutation baseline family the paper argues against [5, 6, 8], and
the comparison (bytes moved per device) is the Fig 1c experiment.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..analysis.errors import PlanError
from .quadtree import build_quadtree_index, morton_encode, structure_fingerprint
from .spgemm import Tasks, spgemm_symbolic

__all__ = [
    "partition_morton",
    "partition_random",
    "SpgemmPlan",
    "make_spgemm_plan",
    "plan_stats",
    "plan_worker_bytes",
    "plan_byte_provenance",
    "structure_fingerprint",
    "plan_fetch",
    "local_fetch_index",
    "split_local_indices",
    "subtree_boundaries",
]


def subtree_boundaries(coords: np.ndarray) -> np.ndarray | None:
    """Candidate partition cuts: leaf positions starting a quadtree node.

    Returns None when ``coords`` is not Morton-sorted-unique (callers of the
    public planner may pass arbitrary coords; alignment is best-effort).
    """
    coords = np.asarray(coords)
    if coords.shape[0] == 0:
        return None
    codes = morton_encode(coords[:, 0], coords[:, 1]).astype(np.int64)
    if np.any(np.diff(codes) <= 0):
        return None
    return build_quadtree_index(coords).boundaries()


def partition_morton(
    nblocks: int,
    nparts: int,
    weights: np.ndarray | None = None,
    *,
    align: np.ndarray | None = None,
    slack: float = 0.15,
) -> np.ndarray:
    """Owner id per block: contiguous Morton ranges with ~equal total weight.

    Blocks are assumed Morton-sorted (BSMatrix canonical order).  Boundary
    placement is greedy on the weight prefix sum; this bounds the per-part
    overshoot by one block's weight, the static analogue of CHT's balance.

    ``align`` (sorted candidate cut positions, e.g. quadtree node boundaries
    from :func:`subtree_boundaries`) snaps each cut to the nearest candidate
    whose weight displacement stays within ``slack`` of a part's target
    weight — so partitions own whole subtrees where the balance budget
    allows, the locality CHT gets from hierarchical chunk identifiers.
    """
    if nblocks == 0:
        return np.zeros((0,), dtype=np.int32)
    w = np.ones(nblocks) if weights is None else np.asarray(weights, dtype=np.float64)
    w = np.maximum(w, 1e-12)
    csum = np.cumsum(w)
    total = csum[-1]
    # targets at equal weight quantiles
    targets = total * (np.arange(1, nparts) / nparts)
    bounds = np.searchsorted(csum, targets, side="left")
    if align is not None and len(align):
        align = np.unique(np.clip(np.asarray(align, dtype=np.int64), 0, nblocks))
        tol = slack * total / nparts
        w_before = np.concatenate([[0.0], csum])  # weight left of a cut position
        snapped = np.empty_like(bounds)
        for i, (t, b) in enumerate(zip(targets, bounds)):
            pos = np.searchsorted(align, b)
            cand = align[max(pos - 1, 0) : pos + 1]
            if cand.size:
                dist = np.abs(w_before[cand] - t)
                j = int(np.argmin(dist))
                if dist[j] <= tol:
                    b = int(cand[j])
            snapped[i] = b
        bounds = np.maximum.accumulate(snapped)
    owner = np.zeros(nblocks, dtype=np.int32)
    prev = 0
    for p, b in enumerate(np.concatenate([bounds, [nblocks]])):
        owner[prev:b] = p
        prev = b
    return owner


def partition_random(nblocks: int, nparts: int, seed: int = 0) -> np.ndarray:
    """Random-permutation placement (the locality-destroying baseline)."""
    rng = np.random.default_rng(seed)
    owner = np.arange(nblocks, dtype=np.int32) % nparts
    rng.shuffle(owner)
    return owner


def _pad_ragged(lists: list[np.ndarray], pad_val: int) -> np.ndarray:
    cap = max((len(x) for x in lists), default=0)
    cap = max(cap, 1)
    out = np.full((len(lists), cap), pad_val, dtype=np.int32)
    for i, x in enumerate(lists):
        out[i, : len(x)] = x
    return out


@dataclasses.dataclass(frozen=True)
class SpgemmPlan:
    """Host-side static schedule for one distributed multiply C = A @ B.

    All arrays with leading dim P are sharded over devices by shard_map.
    Device-local A buffer layout during execution:
      [ own A store (a_cap) | recv buffers per offset, in offset order ]
    and similarly for B.  Task operand indices point into that layout.
    """

    nparts: int
    bs: int
    exchange: str  # "p2p" (planned ppermute rounds) | "allgather" (baseline)
    # block placement: owner[i] and local slot of every global block
    a_owner: np.ndarray
    b_owner: np.ndarray
    a_slot: np.ndarray
    b_slot: np.ndarray
    a_cap: int
    b_cap: int
    a_store_idx: np.ndarray  # [P, a_cap] global A block idx per local slot (pad -> 0)
    b_store_idx: np.ndarray
    a_store_valid: np.ndarray  # [P, a_cap] bool
    b_store_valid: np.ndarray
    # exchange: per offset d, send slot lists  [P, cap_d]
    a_offsets: tuple[int, ...]
    b_offsets: tuple[int, ...]
    a_send: dict[int, np.ndarray]
    b_send: dict[int, np.ndarray]
    a_send_count: dict[int, np.ndarray]  # true counts per device (stats)
    b_send_count: dict[int, np.ndarray]
    # tasks per device (padded): operand idx into device-local buffer layout
    t_cap: int
    task_a: np.ndarray  # [P, t_cap]
    task_b: np.ndarray
    task_c: np.ndarray  # [P, t_cap] local C slot, sorted; pad -> c_cap (trash row)
    task_count: np.ndarray  # [P]
    # output
    c_coords: np.ndarray
    c_owner: np.ndarray
    c_slot: np.ndarray
    c_cap: int
    c_store_idx: np.ndarray  # [P, c_cap] global C block idx (pad -> 0)
    c_store_valid: np.ndarray
    tasks: Tasks
    # [P, t_cap] global task index (into the tasks arrays) per padded device
    # slot (pad -> 0; mask with task_count) — lets a per-call prune pattern
    # over the global task list be relaid into the device task layout without
    # re-planning (delta-plan SpAMM, repro.dist.multiply)
    task_gidx: np.ndarray | None = None
    # fused-engine operand addressing (p2p plans only; None for allgather):
    # task_a == (src == 0 ? off : a_cap + sum(round caps before src-1) + off),
    # decomposed so the fused kernel can gather tiles from the own store
    # (src == 0) or receive buffer src-1 without the concatenated buffer —
    # see repro.kernels.fused_leaf
    task_a_src: np.ndarray | None = None  # [P, t_cap] int32
    task_a_off: np.ndarray | None = None
    task_b_src: np.ndarray | None = None
    task_b_off: np.ndarray | None = None

    @property
    def shapes(self):
        return dict(
            a_cap=self.a_cap, b_cap=self.b_cap, c_cap=self.c_cap, t_cap=self.t_cap
        )


def plan_fetch(x_owner: np.ndarray, x_slot: np.ndarray, needs: list, nparts: int):
    """Plan ppermute rounds delivering, to each device, the blocks it needs.

    ``needs[dst]`` is a sorted-unique array of global block indices device
    ``dst`` must end up holding (its own blocks are skipped — they are already
    resident).  Remote blocks arrive via one ``ppermute`` per ring offset
    ``d = (dst - src) mod nparts``; the receive layout on ``dst`` is blocks
    sorted by global index, per offset.  Returns ``(offsets, send_pad,
    send_cnt, recv_pos)`` where ``recv_pos[(dst, g)] = (offset, position)``.

    This is the chunk-fetch planner shared by the multiply schedule and the
    device-resident collectives in :mod:`repro.dist`.
    """
    send: dict[int, list] = {}
    recv_pos = {}  # (dst, global block) -> (offset, position)
    for dst in range(nparts):
        need = np.asarray(needs[dst], dtype=np.int64)
        remote = need[x_owner[need] != dst] if need.size else need
        for src in np.unique(x_owner[remote]) if remote.size else []:
            d = int((dst - src) % nparts)
            blocks = remote[x_owner[remote] == src]  # sorted (np.unique)
            send.setdefault(d, [np.zeros(0, np.int32)] * nparts)
            send[d][src] = x_slot[blocks].astype(np.int32)
            for pos, g in enumerate(blocks):
                recv_pos[(dst, int(g))] = (d, pos)
    offsets = tuple(sorted(send.keys()))
    send_pad = {d: _pad_ragged(send[d], 0) for d in offsets}
    send_cnt = {
        d: np.array([len(x) for x in send[d]], dtype=np.int64) for d in offsets
    }
    return offsets, send_pad, send_cnt, recv_pos


def local_fetch_index(
    x_owner, x_slot, offsets, send_pad, recv_pos, cap: int, g: int, dev: int
) -> int:
    """Index of global block ``g`` in device ``dev``'s local p2p buffer.

    Buffer layout during execution: ``[ own store (cap) | recv buffers per
    offset, in offset order ]`` — matches :func:`plan_fetch`'s receive layout.
    """
    if x_owner[g] == dev:
        return int(x_slot[g])
    d, pos = recv_pos[(dev, int(g))]
    base = cap
    for dd in offsets:
        if dd == d:
            break
        base += send_pad[dd].shape[1]
    return base + pos


def split_local_indices(
    idx: np.ndarray, cap: int, round_caps: list[int]
) -> tuple[np.ndarray, np.ndarray]:
    """Decompose p2p buffer indices into fused-engine ``(src, off)`` pairs.

    The staged layout is ``[own store (cap) | recv per offset, in offset
    order]``; ``src == 0`` addresses the own store at row ``off`` and
    ``src == r+1`` addresses receive buffer ``r`` (padded round capacity
    ``round_caps[r]``) at row ``off``.  Vectorized over any index array.
    """
    idx = np.asarray(idx, dtype=np.int64)
    bounds = np.concatenate([[cap], cap + np.cumsum(round_caps)]).astype(np.int64)
    src = np.searchsorted(bounds, idx, side="right").astype(np.int32)
    starts = np.concatenate([[0], bounds[:-1]]).astype(np.int64)
    off = (idx - starts[src]).astype(np.int32)
    return src, off


def _owner_slots(owner: np.ndarray, nparts: int):
    """Local slot per block + per-part store index lists."""
    slot = np.zeros(owner.shape[0], dtype=np.int32)
    stores = []
    for p in range(nparts):
        idx = np.nonzero(owner == p)[0]
        slot[idx] = np.arange(idx.size, dtype=np.int32)
        stores.append(idx.astype(np.int32))
    return slot, stores


def make_spgemm_plan(
    a_coords: np.ndarray,
    b_coords: np.ndarray,
    nparts: int,
    bs: int,
    *,
    placement: str = "morton",  # morton | random
    exchange: str = "p2p",  # p2p | allgather
    tasks: Tasks | None = None,
    seed: int = 0,
    a_owner: np.ndarray | None = None,
    b_owner: np.ndarray | None = None,
    align_subtrees: bool = True,
) -> SpgemmPlan:
    """Plan a distributed multiply: placement, task schedule, exchange.

    ``a_owner`` / ``b_owner`` pin the operand placements to externally-fixed
    maps (device-resident operands — :class:`repro.dist.DistBSMatrix` — whose
    stores must not be reshuffled); when omitted they are chosen here.
    ``tasks`` pins a precomputed (possibly SpAMM-pruned) task list so the
    symbolic phase is not redone.  ``align_subtrees`` snaps Morton partition
    cuts to quadtree node boundaries within the balance slack.
    """
    tasks = tasks if tasks is not None else spgemm_symbolic(a_coords, b_coords)
    na, nb, nc = a_coords.shape[0], b_coords.shape[0], tasks.num_out

    # -- placement (chunk -> worker) ---------------------------------------
    if placement == "morton":
        # weight C blocks by task count (flops); A/B by uniform block weight
        cw = np.bincount(tasks.c_idx, minlength=nc).astype(np.float64)
        c_owner = partition_morton(
            nc,
            nparts,
            cw,
            align=subtree_boundaries(tasks.c_coords) if align_subtrees else None,
        )
        if a_owner is None:
            a_owner = partition_morton(
                na,
                nparts,
                align=subtree_boundaries(a_coords) if align_subtrees else None,
            )
        if b_owner is None:
            b_owner = partition_morton(
                nb,
                nparts,
                align=subtree_boundaries(b_coords) if align_subtrees else None,
            )
    elif placement == "random":
        c_owner = partition_random(nc, nparts, seed)
        if a_owner is None:
            a_owner = partition_random(na, nparts, seed + 1)
        if b_owner is None:
            b_owner = partition_random(nb, nparts, seed + 2)
    else:
        raise ValueError(placement)
    a_owner = np.asarray(a_owner, dtype=np.int32)
    b_owner = np.asarray(b_owner, dtype=np.int32)
    # typed (not assert) so `python -O` keeps the guard: a pinned owner map
    # of the wrong shape or range would silently scramble every store slot
    if a_owner.shape != (na,) or b_owner.shape != (nb,):
        raise PlanError(
            f"pinned owner maps do not match the operand structures: "
            f"a_owner {a_owner.shape} for {na} A blocks, "
            f"b_owner {b_owner.shape} for {nb} B blocks")
    for name, owner, n in (("a", a_owner, na), ("b", b_owner, nb)):
        if n and (int(owner.min()) < 0 or int(owner.max()) >= nparts):
            raise PlanError(
                f"{name}_owner assigns blocks outside the mesh of {nparts} "
                f"(owner range [{int(owner.min())}, {int(owner.max())}])")

    a_slot, a_stores = _owner_slots(a_owner, nparts)
    b_slot, b_stores = _owner_slots(b_owner, nparts)
    c_slot, c_stores = _owner_slots(c_owner, nparts)
    a_cap = max(max((len(s) for s in a_stores), default=0), 1)
    b_cap = max(max((len(s) for s in b_stores), default=0), 1)
    c_cap = max(max((len(s) for s in c_stores), default=0), 1)

    def store_arrays(stores, cap):
        idx = np.zeros((nparts, cap), dtype=np.int32)
        valid = np.zeros((nparts, cap), dtype=bool)
        for p, s in enumerate(stores):
            idx[p, : len(s)] = s
            valid[p, : len(s)] = True
        return idx, valid

    a_store_idx, a_store_valid = store_arrays(a_stores, a_cap)
    b_store_idx, b_store_valid = store_arrays(b_stores, b_cap)
    c_store_idx, c_store_valid = store_arrays(c_stores, c_cap)

    # -- task -> owner of C -------------------------------------------------
    t_owner = c_owner[tasks.c_idx]

    # -- exchange plan (chunk fetches) ---------------------------------------
    # For matrix X in {A, B}: device p needs the distinct X blocks referenced
    # by its tasks; those owned elsewhere arrive via the rounds planned by
    # plan_fetch.
    def _exchange(x_owner, x_slot, ref_idx):
        needs = [
            np.unique(ref_idx[t_owner == p]) if np.any(t_owner == p) else np.zeros(0, np.int64)
            for p in range(nparts)
        ]
        return plan_fetch(x_owner, x_slot, needs, nparts)

    if exchange == "p2p":
        a_offsets, a_send, a_send_cnt, a_recv_pos = _exchange(a_owner, a_slot, tasks.a_idx)
        b_offsets, b_send, b_send_cnt, b_recv_pos = _exchange(b_owner, b_slot, tasks.b_idx)
    else:  # allgather baseline: no planned exchange, full replication
        a_offsets = b_offsets = ()
        a_send = b_send = {}
        a_send_cnt = b_send_cnt = {}
        a_recv_pos = b_recv_pos = {}

    # -- device-local operand indices ----------------------------------------
    # local buffer layout: [store (cap) | offset buffers in tuple order]
    def local_index(x_owner, x_slot, offsets, send_pad, recv_pos, cap, g, dev):
        if exchange == "allgather":
            # gathered layout: [owner0 store | owner1 store | ...]
            return int(x_owner[g]) * cap + int(x_slot[g])
        return local_fetch_index(
            x_owner, x_slot, offsets, send_pad, recv_pos, cap, g, dev
        )

    task_a_l, task_b_l, task_c_l, task_g_l = [], [], [], []
    for p in range(nparts):
        sel = np.nonzero(t_owner == p)[0]
        # keep tasks sorted by local C slot for kernel-friendly accumulation;
        # the stable sort keeps global (symbolic) task order within a C
        # block, so fp32 accumulation order — and hence the result bits —
        # is invariant under owner re-layout (rebalancing stays bit-exact)
        order = np.argsort(c_slot[tasks.c_idx[sel]], kind="stable")
        sel = sel[order]
        task_g_l.append(sel.astype(np.int32))
        ta = np.array(
            [
                local_index(a_owner, a_slot, a_offsets, a_send, a_recv_pos, a_cap, g, p)
                for g in tasks.a_idx[sel]
            ],
            dtype=np.int32,
        )
        tb = np.array(
            [
                local_index(b_owner, b_slot, b_offsets, b_send, b_recv_pos, b_cap, g, p)
                for g in tasks.b_idx[sel]
            ],
            dtype=np.int32,
        )
        tc = c_slot[tasks.c_idx[sel]].astype(np.int32)
        task_a_l.append(ta)
        task_b_l.append(tb)
        task_c_l.append(tc)
    t_cap = max(max((len(x) for x in task_a_l), default=0), 1)
    task_count = np.array([len(x) for x in task_a_l], dtype=np.int64)
    task_a = _pad_ragged(task_a_l, 0)
    task_b = _pad_ragged(task_b_l, 0)
    task_c = _pad_ragged(task_c_l, c_cap)  # trash row
    task_gidx = _pad_ragged(task_g_l, 0)
    # fused-engine addressing (padded slots decompose to (0, 0): store row 0,
    # discarded via the trash row)
    task_a_src = task_a_off = task_b_src = task_b_off = None
    if exchange == "p2p":
        task_a_src, task_a_off = split_local_indices(
            task_a, a_cap, [a_send[d].shape[1] for d in a_offsets]
        )
        task_b_src, task_b_off = split_local_indices(
            task_b, b_cap, [b_send[d].shape[1] for d in b_offsets]
        )

    return SpgemmPlan(
        nparts=nparts,
        bs=bs,
        exchange=exchange,
        a_owner=a_owner,
        b_owner=b_owner,
        a_slot=a_slot,
        b_slot=b_slot,
        a_cap=a_cap,
        b_cap=b_cap,
        a_store_idx=a_store_idx,
        b_store_idx=b_store_idx,
        a_store_valid=a_store_valid,
        b_store_valid=b_store_valid,
        a_offsets=a_offsets,
        b_offsets=b_offsets,
        a_send=a_send,
        b_send=b_send,
        a_send_count=a_send_cnt,
        b_send_count=b_send_cnt,
        t_cap=t_cap,
        task_a=task_a,
        task_b=task_b,
        task_c=task_c,
        task_count=task_count,
        c_coords=tasks.c_coords,
        c_owner=c_owner,
        c_slot=c_slot,
        c_cap=c_cap,
        c_store_idx=c_store_idx,
        c_store_valid=c_store_valid,
        tasks=tasks,
        task_gidx=task_gidx,
        task_a_src=task_a_src,
        task_a_off=task_a_off,
        task_b_src=task_b_src,
        task_b_off=task_b_off,
    )


def plan_worker_bytes(plan: SpgemmPlan) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-worker exchange bytes of a plan: (recv_actual, send_actual, recv_padded).

    ``recv_actual`` / ``send_actual`` count the true (unpadded) operand blocks
    each worker receives / ships during the planned exchange rounds;
    ``recv_padded`` is what the SPMD program physically moves (uniform padded
    payloads per ``ppermute`` round).  This is the per-worker breakdown the
    dynamic load-balancing cost model (:mod:`repro.dist.balance`) consumes —
    a skewed operand layout shows up as one worker shipping everything.
    """
    P = plan.nparts
    itemsize = 4
    blk = plan.bs * plan.bs * itemsize
    recv_actual = np.zeros(P, dtype=np.float64)
    send_actual = np.zeros(P, dtype=np.float64)
    recv_padded = np.zeros(P, dtype=np.float64)
    if plan.exchange == "allgather":
        # every device receives everyone else's full (padded) store and ships
        # its own store to the other P-1 devices
        per_dev = (P - 1) * (plan.a_cap + plan.b_cap) * blk
        recv_padded[:] = per_dev
        a_counts = np.bincount(plan.a_owner, minlength=P)
        b_counts = np.bincount(plan.b_owner, minlength=P)
        recv_actual[:] = (a_counts.sum() + b_counts.sum()) * blk  # upper: full matrices
        for p in range(P):
            recv_actual[p] -= (a_counts[p] + b_counts[p]) * blk
            send_actual[p] = (P - 1) * (a_counts[p] + b_counts[p]) * blk
    else:
        for offs, send_cnt, send_pad in (
            (plan.a_offsets, plan.a_send_count, plan.a_send),
            (plan.b_offsets, plan.b_send_count, plan.b_send),
        ):
            for d in offs:
                cnt = send_cnt[d]  # indexed by src; dst = (src + d) % P
                for src in range(P):
                    dst = (src + d) % P
                    recv_actual[dst] += cnt[src] * blk
                    send_actual[src] += cnt[src] * blk
                    recv_padded[dst] += send_pad[d].shape[1] * blk
    return recv_actual, send_actual, recv_padded


def plan_byte_provenance(plan: SpgemmPlan) -> dict:
    """Per-task, per-round provenance of every operand byte a plan touches.

    Extends :func:`plan_worker_bytes` (per-worker exchange totals) down to
    the level the locality ledger (:mod:`repro.obs.locality`) meters:

    * ``referenced`` / ``local`` / ``shipped`` — per-worker bytes of the
      *distinct* operand blocks each worker's task list reads, split by
      whether the block is resident (owned) or fetched.  Counted at fp32
      itemsize so ``local + shipped == referenced`` holds exactly and, for
      p2p plans, ``shipped`` equals ``plan_worker_bytes``'s ``recv_actual``
      bit-for-bit (the planned exchange delivers precisely the distinct
      remote references).
    * ``task_local`` — ``[P, t_cap]`` bool, True where *both* operands of a
      padded task slot are locally owned (padding is False); ``local_tasks``
      is its per-worker row sum — the locally-satisfied flop count.
    * ``rounds`` — one record per planned ``ppermute`` round (execution
      order: A rounds then B rounds) with per-worker actual/padded
      block counts, for the executed-task-graph analyzer.
    * ``fetch_a`` / ``fetch_b`` — flat ``(gids, src, dst)`` arrays: global
      block index, owning worker, fetching worker for every planned remote
      reference — the per-block movement-lineage feed.

    All quantities are static plan properties; delta-mask pruning and bf16
    wire halving are applied by the ledger at dispatch time.
    """
    P = plan.nparts
    blk = plan.bs * plan.bs * 4
    tasks = plan.tasks
    t_owner = plan.c_owner[tasks.c_idx] if tasks.c_idx.size else np.zeros(0, np.int32)
    referenced = np.zeros(P, dtype=np.float64)
    local = np.zeros(P, dtype=np.float64)
    shipped = np.zeros(P, dtype=np.float64)
    fetch = {}
    for name, owner, ref_idx in (
        ("a", plan.a_owner, tasks.a_idx),
        ("b", plan.b_owner, tasks.b_idx),
    ):
        gids_l, src_l, dst_l = [], [], []
        for p in range(P):
            refs = np.unique(ref_idx[t_owner == p]) if ref_idx.size else np.zeros(0, np.int64)
            own = int((owner[refs] == p).sum()) if refs.size else 0
            referenced[p] += refs.size * blk
            local[p] += own * blk
            shipped[p] += (refs.size - own) * blk
            remote = refs[owner[refs] != p] if refs.size else refs
            if remote.size:
                gids_l.append(remote.astype(np.int64))
                src_l.append(owner[remote].astype(np.int32))
                dst_l.append(np.full(remote.size, p, dtype=np.int32))
        fetch[name] = (
            np.concatenate(gids_l) if gids_l else np.zeros(0, np.int64),
            np.concatenate(src_l) if src_l else np.zeros(0, np.int32),
            np.concatenate(dst_l) if dst_l else np.zeros(0, np.int32),
        )

    # per-task locality from the global task map (exchange-independent):
    # a padded slot repeats global task 0, so mask with task_count
    valid = np.arange(plan.task_c.shape[1])[None, :] < plan.task_count[:, None]
    if plan.task_gidx is not None and tasks.a_idx.size:
        ga = tasks.a_idx[plan.task_gidx]
        gb = tasks.b_idx[plan.task_gidx]
        me = np.arange(P, dtype=np.int32)[:, None]
        task_local = (
            (plan.a_owner[ga] == me) & (plan.b_owner[gb] == me) & valid
        )
    else:
        task_local = np.zeros_like(valid)
    local_tasks = task_local.sum(axis=1).astype(np.int64)

    # per-round wire records, in execution order (A rounds then B rounds)
    rounds = []
    if plan.exchange == "p2p":
        for name, offs, send_pad, send_cnt in (
            ("a", plan.a_offsets, plan.a_send, plan.a_send_count),
            ("b", plan.b_offsets, plan.b_send, plan.b_send_count),
        ):
            for r, d in enumerate(offs):
                cnt = send_cnt[d].astype(np.int64)  # by src; dst = (src+d)%P
                recv = np.zeros(P, dtype=np.int64)
                recv[(np.arange(P) + d) % P] = cnt
                rounds.append(dict(
                    operand=name, offset=int(d), round=r,
                    cap=int(send_pad[d].shape[1]),
                    send_blocks=cnt, recv_blocks=recv,
                ))
    else:  # allgather: one logical round replicating both padded stores
        a_counts = np.bincount(plan.a_owner, minlength=P).astype(np.int64)
        b_counts = np.bincount(plan.b_owner, minlength=P).astype(np.int64)
        total = a_counts + b_counts
        rounds.append(dict(
            operand="ab", offset=-1, round=0,
            cap=int(plan.a_cap + plan.b_cap),
            send_blocks=(P - 1) * total,
            recv_blocks=int(total.sum()) - total,
        ))
    wire_recv, wire_send, wire_padded = plan_worker_bytes(plan)
    return dict(
        itemsize=4,
        block_bytes=blk,
        referenced=referenced,
        local=local,
        shipped=shipped,
        task_local=task_local,
        local_tasks=local_tasks,
        rounds=rounds,
        fetch_a=fetch["a"],
        fetch_b=fetch["b"],
        wire_recv=wire_recv,
        wire_send=wire_send,
        wire_padded=wire_padded,
    )


def plan_stats(plan: SpgemmPlan) -> dict:
    """Schedule quality metrics — the paper's Fig 1 quantities.

    * flop balance: max/mean tasks per device (CHT's load balancing claim)
    * recv bytes per device: actual (true counts) and padded (what the SPMD
      program moves) — Fig 1c 'data received per worker process'.
    * per-worker breakdown (``tasks_per_worker`` / ``recv_bytes_per_worker``
      / ``send_bytes_per_worker``) — the raw vectors the dynamic
      load-balancing cost model (:mod:`repro.dist.balance`) weighs.
    """
    P = plan.nparts
    recv_actual, send_actual, recv_padded = plan_worker_bytes(plan)
    tasks = plan.task_count.astype(np.float64)
    mean_t = max(tasks.mean(), 1e-12)
    return dict(
        nparts=P,
        tasks_total=int(tasks.sum()),
        task_balance=float(tasks.max() / mean_t),
        flops_per_dev_mean=2.0 * mean_t * plan.bs**3,
        recv_bytes_mean=float(recv_actual.mean()),
        recv_bytes_max=float(recv_actual.max()),
        recv_bytes_padded_mean=float(recv_padded.mean()),
        n_offsets=len(plan.a_offsets) + len(plan.b_offsets),
        tasks_per_worker=plan.task_count.astype(np.int64).tolist(),
        recv_bytes_per_worker=recv_actual.tolist(),
        send_bytes_per_worker=send_actual.tolist(),
    )
