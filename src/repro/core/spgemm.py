"""Sparse matrix-matrix multiplication over quadtree block structures.

Mirrors the paper's multiplication task templates in two phases:

* **Symbolic** (host, structure only): enumerate the leaf-level block products
  ``C[c] += A[a] @ B[b]``.  Three implementations, all producing identical
  task sets (tested): the *production path* :func:`spgemm_symbolic_tree`, a
  vectorized level-by-level descent over cached
  :class:`~repro.core.quadtree.QuadtreeIndex` structures; a flat hash/merge
  join (:func:`spgemm_symbolic`, used where only raw coords are available);
  and a literal Python-recursive quadtree descent
  (:func:`spgemm_symbolic_recursive`) kept as the paper-faithful reference.
* **Numeric** (device): grouped block matmul over the stacked leaf data —
  either the pure-jnp reference (segment_sum) or the Pallas TPU kernel in
  :mod:`repro.kernels.block_spmm`.

Also provides symmetric multiply (syrk), and SpAMM — the paper's sparse
approximate multiply with norm-based pruning applied *during* the descent
(:func:`spamm_symbolic`): subtree pairs whose ``||A||_F * ||B||_F`` bound
fits the greedy budget are dropped before their leaves are ever enumerated,
with a returned error bound <= tau.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .cache import SymbolicCache
from .matrix import BSMatrix, block_frobenius_norms
from .quadtree import QuadtreeIndex, morton_encode, morton_decode, quadtree_depth

__all__ = [
    "Tasks",
    "spgemm_symbolic",
    "spgemm_symbolic_tree",
    "spgemm_symbolic_recursive",
    "spgemm_numeric",
    "multiply",
    "syrk",
    "spamm",
    "spamm_symbolic",
    "task_flops",
]


@dataclasses.dataclass(frozen=True)
class Tasks:
    """Leaf-level multiply task list: C[c_idx[t]] += A[a_idx[t]] @ B[b_idx[t]].

    Tasks are sorted by (c_idx, a_idx); c_coords is Morton-sorted so that
    ``c_idx`` ascending == Morton order of output blocks.
    """

    a_idx: np.ndarray  # [T] int64 into A block stack
    b_idx: np.ndarray  # [T] int64 into B block stack
    c_idx: np.ndarray  # [T] int64 into c_coords
    c_coords: np.ndarray  # [nnzb_c, 2] block coords of the output

    @property
    def num_tasks(self) -> int:
        return int(self.a_idx.shape[0])

    @property
    def num_out(self) -> int:
        return int(self.c_coords.shape[0])


def _empty_tasks() -> Tasks:
    z = np.zeros((0,), dtype=np.int64)
    return Tasks(z, z, z, np.zeros((0, 2), dtype=np.int64))


def _finalize_tasks(a_idx: np.ndarray, b_idx: np.ndarray, ci: np.ndarray, cj: np.ndarray) -> Tasks:
    """Canonical Tasks from raw (a, b, out-row, out-col) pair lists.

    Shared tail of every symbolic phase, so all of them are bit-identical:
    dedupe output codes into Morton-sorted c_coords, lexsort by (c_idx, a_idx)
    — which uniquely orders tasks since b is determined by (a, c).
    """
    codes = morton_encode(ci, cj)
    uniq, c_idx = np.unique(codes, return_inverse=True)
    r, c = morton_decode(uniq)
    c_coords = np.stack([r, c], axis=1)
    order = np.lexsort((a_idx, c_idx))
    return Tasks(
        a_idx=a_idx[order].astype(np.int64),
        b_idx=b_idx[order].astype(np.int64),
        c_idx=c_idx[order].astype(np.int64),
        c_coords=c_coords,
    )


def spgemm_symbolic(a_coords: np.ndarray, b_coords: np.ndarray) -> Tasks:
    """Vectorized symbolic phase: join A's block-cols against B's block-rows."""
    a_coords = np.asarray(a_coords)
    b_coords = np.asarray(b_coords)
    if a_coords.shape[0] == 0 or b_coords.shape[0] == 0:
        return _empty_tasks()

    # group A by k = col, B by k = row
    a_ord = np.argsort(a_coords[:, 1], kind="stable")
    b_ord = np.argsort(b_coords[:, 0], kind="stable")
    ak = a_coords[a_ord, 1]
    bk = b_coords[b_ord, 0]
    a_uk, a_start, a_cnt = np.unique(ak, return_index=True, return_counts=True)
    b_uk, b_start, b_cnt = np.unique(bk, return_index=True, return_counts=True)
    common, ia, ib = np.intersect1d(a_uk, b_uk, assume_unique=True, return_indices=True)
    if common.size == 0:
        return _empty_tasks()
    ca, cb = a_cnt[ia], b_cnt[ib]  # per-k group sizes
    sa, sb = a_start[ia], b_start[ib]  # per-k group starts
    pairs = ca * cb
    total = int(pairs.sum())
    # expand: for group g, a index repeats cb[g] times each; b index tiles ca[g] times
    goff = np.concatenate([[0], np.cumsum(pairs)])[:-1]
    gid = np.repeat(np.arange(common.size), pairs)
    local = np.arange(total) - goff[gid]  # 0..pairs[g)-1 within each group
    a_local = local // cb[gid]
    b_local = local % cb[gid]
    a_idx = a_ord[sa[gid] + a_local]
    b_idx = b_ord[sb[gid] + b_local]

    ci = a_coords[a_idx, 0]
    cj = b_coords[b_idx, 1]
    return _finalize_tasks(a_idx, b_idx, ci, cj)


def _tree_descend(
    ia: QuadtreeIndex,
    ib: QuadtreeIndex,
    tau: float | None,
    *,
    upper_only: bool = False,
) -> tuple[np.ndarray, np.ndarray, float, int]:
    """Vectorized level-synchronous quadtree descent for C = A @ B.

    Expands the frontier of matching (A node, B node) pairs one level at a
    time (the paper's multiplication task recursion, whole levels at once):
    children pairs must agree on the inner quadrant bit, nil children are
    pruned for free by the CSR child spans.  With ``tau`` set, additionally
    applies the SpAMM bound during descent — at each level the smallest
    ``||A_node|| * ||B_node||`` products are greedily dropped while their sum
    fits the remaining budget, so pruned subtrees are *never enumerated*.

    ``upper_only`` restricts the descent to the upper triangle of C (the
    paper's symmetric task types): a node pair whose output node lies
    strictly below the diagonal — A-node row prefix > B-node col prefix —
    can only produce c_row > c_col leaves, so the whole pair is dropped
    mid-descent and its subtree is never expanded; diagonal-straddling pairs
    keep descending and the leaf level applies the exact c_row <= c_col cut.

    Returns ``(leaf_a, leaf_b, err_bound, pairs_visited)``: leaf pairs as
    block-stack indices, the accumulated pruned-bound sum (<= tau), and the
    number of candidate node pairs visited across all levels.
    """
    assert ia.depth == ib.depth, (ia.depth, ib.depth)
    if ia.nnzb == 0 or ib.nnzb == 0:
        z = np.zeros((0,), dtype=np.int64)
        return z, z, 0.0, 0
    if tau is not None and tau > 0:
        assert ia.norms is not None and ib.norms is not None, "SpAMM needs subtree norms"
    ai = np.zeros(1, dtype=np.int64)  # root pair
    bi = np.zeros(1, dtype=np.int64)
    visited = 1
    err = 0.0
    budget = float(tau) if tau is not None else 0.0
    one = np.uint64(1)
    for level in range(ia.depth):
        sa = ia.child_start[level]
        sb = ib.child_start[level]
        sa0, ca = sa[ai], sa[ai + 1] - sa[ai]
        sb0, cb = sb[bi], sb[bi + 1] - sb[bi]
        pairs = ca * cb
        total = int(pairs.sum())
        goff = np.concatenate([[0], np.cumsum(pairs)])[:-1]
        gid = np.repeat(np.arange(pairs.size), pairs)
        local = np.arange(total) - goff[gid]
        ach = sa0[gid] + local // cb[gid]
        bch = sb0[gid] + local % cb[gid]
        # inner-index match: A child quadrant (qi, qk), B child (qk, qj)
        pa = ia.prefixes[level + 1][ach]
        pb = ib.prefixes[level + 1][bch]
        match = (pa & one) == ((pb >> one) & one)
        if upper_only:
            # output node (i, j): i from the A child prefix, j from the B
            # child prefix; strictly-lower nodes cannot reach c_row <= c_col
            i_node, _ = morton_decode(pa)
            _, j_node = morton_decode(pb)
            match &= i_node <= j_node
        ai, bi = ach[match], bch[match]
        visited += int(ai.size)
        if budget > 0.0 and ai.size:
            bound = ia.norms[level + 1][ai] * ib.norms[level + 1][bi]
            order = np.argsort(bound)
            csum = np.cumsum(bound[order])
            ndrop = int(np.searchsorted(csum, budget, side="right"))
            if ndrop:
                pruned = float(csum[ndrop - 1])
                err += pruned
                budget -= pruned
                keep = np.ones(ai.size, dtype=bool)
                keep[order[:ndrop]] = False
                ai, bi = ai[keep], bi[keep]
        if ai.size == 0:
            break
    return ai, bi, err, visited


def _tasks_from_leaf_pairs(ia: QuadtreeIndex, ib: QuadtreeIndex, ai, bi) -> Tasks:
    if ai.size == 0:
        return _empty_tasks()
    ar, _ = morton_decode(ia.prefixes[-1][ai])
    _, bc = morton_decode(ib.prefixes[-1][bi])
    return _finalize_tasks(ai, bi, ar, bc)


def spgemm_symbolic_tree(
    ia: QuadtreeIndex, ib: QuadtreeIndex, *, upper_only: bool = False
) -> Tasks:
    """Symbolic phase via vectorized quadtree descent — the production path.

    Identical output to :func:`spgemm_symbolic` (tested bit-for-bit), but
    structured as the paper's hierarchy walk over cached
    :class:`~repro.core.quadtree.QuadtreeIndex` structures, which is what
    lets SpAMM (:func:`spamm_symbolic`) prune whole subtrees mid-descent.

    ``upper_only`` keeps only tasks with ``c_row <= c_col``, pruned *during*
    the descent (strictly-lower node pairs are never expanded) — the
    symmetric task types (:func:`syrk` / :func:`symm_square`) use it to
    roughly halve their symbolic cost versus enumerate-then-filter, with a
    bit-identical task list (tested).
    """
    ai, bi, _, _ = _tree_descend(ia, ib, tau=None, upper_only=upper_only)
    return _tasks_from_leaf_pairs(ia, ib, ai, bi)


def spamm_symbolic(
    ia: QuadtreeIndex, ib: QuadtreeIndex, tau: float
) -> tuple[Tasks, float, int]:
    """Hierarchical SpAMM symbolic phase.

    Applies the ``||A_node||_F * ||B_node||_F <= remaining-budget`` bound at
    every level of the descent, so a subtree pair pruned at level L never
    expands its up-to-4^(depth-L) leaf tasks.  Returns ``(tasks, err_bound,
    pairs_visited)`` with the guarantee ``||A@B - C||_F <= err_bound <= tau``
    (triangle inequality over the pruned node-pair products).
    """
    ai, bi, err, visited = _tree_descend(ia, ib, tau=tau)
    return _tasks_from_leaf_pairs(ia, ib, ai, bi), err, visited


def spgemm_symbolic_recursive(a_coords: np.ndarray, b_coords: np.ndarray) -> Tasks:
    """Literal quadtree-descent symbolic phase (the paper's task recursion).

    A multiply task at level L on nodes (A_ik, B_kj) registers child tasks for
    every pair of nonzero child quadrants with matching inner index; nil
    children (absent Morton prefixes) are pruned — the fallback execute
    function of the paper.  Equivalent to :func:`spgemm_symbolic` (tested);
    kept as the faithful reference and used by the scheduler's cost model.
    """
    a_coords = np.asarray(a_coords)
    b_coords = np.asarray(b_coords)
    if a_coords.shape[0] == 0 or b_coords.shape[0] == 0:
        return _empty_tasks()
    depth = 0
    top = int(
        max(
            a_coords.max(initial=0),
            b_coords.max(initial=0),
            1,
        )
    )
    while (1 << depth) <= top:
        depth += 1
    # per-level sets of (node codes) plus leaf code -> stack index maps
    a_codes = morton_encode(a_coords[:, 0], a_coords[:, 1])
    b_codes = morton_encode(b_coords[:, 0], b_coords[:, 1])
    a_pos = {int(c): i for i, c in enumerate(a_codes)}
    b_pos = {int(c): i for i, c in enumerate(b_codes)}
    a_levels = [set((a_codes >> np.uint64(2 * (depth - l))).tolist()) for l in range(depth + 1)]
    b_levels = [set((b_codes >> np.uint64(2 * (depth - l))).tolist()) for l in range(depth + 1)]

    out_a, out_b, out_ci, out_cj = [], [], [], []

    def child(prefix: int, qr: int, qc: int) -> int:
        return (prefix << 2) | (qr << 1) | qc

    def descend(an: int, bn: int, level: int) -> None:
        # an encodes (i,k) interleaved; bn encodes (k,j).  Children quadrants
        # are indexed by (qi,qk) for A and (qk,qj) for B.
        if level == depth:
            ar, ac = morton_decode(np.asarray([an], dtype=np.uint64))
            br, bc = morton_decode(np.asarray([bn], dtype=np.uint64))
            out_a.append(a_pos[an])
            out_b.append(b_pos[bn])
            out_ci.append(int(ar[0]))
            out_cj.append(int(bc[0]))
            return
        nl = level + 1
        for qi in range(2):
            for qk in range(2):
                ac = child(an, qi, qk)
                if ac not in a_levels[nl]:
                    continue  # nil chunk id: zero branch pruned
                for qj in range(2):
                    bc = child(bn, qk, qj)
                    if bc in b_levels[nl]:
                        descend(ac, bc, nl)

    descend(0, 0, 0)
    if not out_a:
        return _empty_tasks()
    a_idx = np.asarray(out_a, dtype=np.int64)
    b_idx = np.asarray(out_b, dtype=np.int64)
    codes = morton_encode(np.asarray(out_ci), np.asarray(out_cj))
    uniq, c_idx = np.unique(codes, return_inverse=True)
    r, c = morton_decode(uniq)
    order = np.lexsort((a_idx, c_idx))
    return Tasks(a_idx[order], b_idx[order], c_idx[order].astype(np.int64), np.stack([r, c], axis=1))


def task_flops(tasks: Tasks, bs: int) -> float:
    """Dense-leaf flop count: 2 * bs^3 per task (mul+add)."""
    return 2.0 * float(tasks.num_tasks) * bs**3


def _prune_tasks(tasks: Tasks, keep: np.ndarray) -> Tasks:
    """Restrict a task list to a bool keep mask, dropping orphaned C blocks."""
    kept_out = np.unique(tasks.c_idx[keep])
    remap = -np.ones(tasks.num_out, dtype=np.int64)
    remap[kept_out] = np.arange(kept_out.size)
    return Tasks(
        a_idx=tasks.a_idx[keep],
        b_idx=tasks.b_idx[keep],
        c_idx=remap[tasks.c_idx[keep]],
        c_coords=tasks.c_coords[kept_out],
    )


def _refine_leaf_spamm(
    a: BSMatrix, b: BSMatrix, tasks: Tasks, tau: float, err: float, leaf_spec
) -> tuple[Tasks, float]:
    """Leaf-policy SpAMM refinement: inner-norm product bounds per kept task.

    The hierarchical descent prunes with the leaf bound
    ``||A_leaf||_F * ||B_leaf||_F``; for leaves carrying internal sparsity
    (:class:`repro.core.leaf.LeafSpec` ``block_sparse`` / ``hierarchical``)
    the tighter ``||Na @ Nb||_F`` holds, where ``Na[i, k] = ||A_ik||_F`` over
    the internal blocks: per internal output block,
    ``||(AB)_ij||_F <= sum_k ||A_ik||_F ||B_kj||_F = (Na Nb)_ij``, and by
    Cauchy-Schwarz ``||Na Nb||_F <= ||Na||_F ||Nb||_F`` — so tasks whose
    internal structures barely overlap (disjoint inner masks bound to ~0) are
    dropped within the remaining ``tau`` budget even though their full-leaf
    norm product survived the descent.  Under ``kind="dense"`` the internal
    block is the whole leaf and the bound degenerates to the descent's own,
    so nothing extra can be pruned: the task list is returned untouched,
    bit-identical to the plain path (regression-tested).
    """
    from .leaf import inner_norms

    ibs = a.bs if leaf_spec.kind == "dense" else leaf_spec.inner_bs
    if a.bs // ibs <= 1 or tasks.num_tasks == 0:
        return tasks, err
    na = inner_norms(a, leaf_spec)  # [nnzb_a, ni, ni]
    nb = inner_norms(b, leaf_spec)
    prod = np.einsum("tik,tkj->tij", na[tasks.a_idx], nb[tasks.b_idx])
    bound = np.sqrt(np.sum(prod**2, axis=(1, 2)))
    order = np.argsort(bound)
    csum = np.cumsum(bound[order])
    ndrop = int(np.searchsorted(csum, tau - err, side="right"))
    if ndrop == 0:
        return tasks, err
    keep = np.ones(tasks.num_tasks, dtype=bool)
    keep[order[:ndrop]] = False
    return _prune_tasks(tasks, keep), err + float(csum[ndrop - 1])


def spgemm_numeric(
    a_data: jax.Array,
    b_data: jax.Array,
    tasks: Tasks,
    *,
    impl: str = "auto",
    out_dtype=None,
) -> jax.Array:
    """Numeric phase: grouped block matmul C[c] += A[a] @ B[b].

    impl: 'ref' (pure jnp segment_sum), 'kernel' (Pallas), or 'auto'.
    """
    out_dtype = out_dtype or a_data.dtype
    bs = a_data.shape[-2]
    if tasks.num_tasks == 0:
        return jnp.zeros((0, bs, b_data.shape[-1]), dtype=out_dtype)
    if impl == "auto":
        impl = "kernel" if bs % 8 == 0 and bs >= 8 else "ref"
    if impl == "kernel":
        from repro.kernels import ops as kops

        return kops.block_spmm(
            a_data,
            b_data,
            jnp.asarray(tasks.a_idx, jnp.int32),
            jnp.asarray(tasks.b_idx, jnp.int32),
            jnp.asarray(tasks.c_idx, jnp.int32),
            tasks.num_out,
        ).astype(out_dtype)
    from repro.kernels import ref as kref

    return kref.block_spmm_ref(
        a_data,
        b_data,
        jnp.asarray(tasks.a_idx),
        jnp.asarray(tasks.b_idx),
        jnp.asarray(tasks.c_idx),
        tasks.num_out,
    ).astype(out_dtype)


def _common_depth(a: BSMatrix, b: BSMatrix) -> int:
    """Shared quadtree depth so both operands hang off one root."""
    return max(quadtree_depth(*a.nblocks), quadtree_depth(*b.nblocks))


def multiply(
    a: BSMatrix, b: BSMatrix, *, impl: str = "auto", cache: SymbolicCache | None = None
) -> BSMatrix:
    """C = A @ B (regular multiplication task type).

    The symbolic phase is the vectorized quadtree descent over the operands'
    cached :class:`~repro.core.quadtree.QuadtreeIndex` structures; pass a
    :class:`~repro.core.cache.SymbolicCache` to skip it entirely whenever the
    pair of sparsity patterns has been seen before (iterative algorithms —
    see :func:`repro.core.purify.sp2_purify`).
    """
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    assert a.bs == b.bs

    def build() -> Tasks:
        depth = _common_depth(a, b)
        return spgemm_symbolic_tree(
            a.quadtree_index(depth, with_norms=False),
            b.quadtree_index(depth, with_norms=False),
        )

    if cache is None:
        tasks = build()
    else:
        tasks = cache.get_or_build(
            ("spgemm", a.structure_key, b.structure_key), build
        )
    data = spgemm_numeric(a.data, b.data, tasks, impl=impl)
    return BSMatrix(
        shape=(a.shape[0], b.shape[1]), bs=a.bs, coords=tasks.c_coords, data=data
    )


def syrk(a: BSMatrix, *, impl: str = "auto") -> BSMatrix:
    """Symmetric rank-k construction: C = A @ A^T, exploiting symmetry.

    Only tasks with c_row <= c_col are computed — via the ``upper_only``
    hierarchy descent, so strictly-lower subtree pairs are pruned before
    their leaves are ever enumerated — and the mirror is materialized by
    transposing the strictly-upper blocks (paper: symmetric square / rank-k
    task types).
    """
    at = a.transpose()
    depth = _common_depth(a, at)
    upper = spgemm_symbolic_tree(
        a.quadtree_index(depth, with_norms=False),
        at.quadtree_index(depth, with_norms=False),
        upper_only=True,
    )
    data = spgemm_numeric(a.data, at.data, upper, impl=impl)
    upper_m = BSMatrix(shape=(a.shape[0], a.shape[0]), bs=a.bs, coords=upper.c_coords, data=data)
    strict = upper.c_coords[:, 0] < upper.c_coords[:, 1]
    if not strict.any():
        return upper_m
    mirror_coords = upper.c_coords[strict][:, ::-1]
    mirror_data = jnp.transpose(data[jnp.asarray(np.nonzero(strict)[0])], (0, 2, 1))
    return BSMatrix.from_blocks(
        (a.shape[0], a.shape[0]),
        a.bs,
        np.concatenate([upper.c_coords, mirror_coords]),
        jnp.concatenate([data, mirror_data]),
    )


def symm_square(a: BSMatrix, *, impl: str = "auto") -> BSMatrix:
    """Symmetric matrix square (paper task type): for symmetric A,
    A^2 = A A^T, so only the upper triangle is computed and mirrored."""
    return syrk(a, impl=impl)


def spamm(
    a: BSMatrix,
    b: BSMatrix,
    tau: float,
    *,
    impl: str = "auto",
    method: str = "hierarchical",
    leaf_spec=None,
):
    """Sparse approximate multiply (paper: SpAMM task type).

    Skips work whose contribution bound ||A_node||_F * ||B_node||_F fits a
    greedy budget so the *total* skipped bound <= tau.  Returns
    (C, error_bound) with ||AB - C||_F <= error_bound <= tau.

    ``method="hierarchical"`` (default) prunes during the quadtree descent
    (:func:`spamm_symbolic`): a dropped subtree pair is never enumerated, so
    the symbolic cost shrinks with the dropped work.  ``method="leaf"`` is
    the flat reference: enumerate every leaf task, then prune.

    ``leaf_spec`` (a :class:`repro.core.leaf.LeafSpec`) extends either
    method's pruning below leaf granularity: surviving tasks are re-bounded
    with the inner-norm product ``||Na @ Nb||_F`` (tighter than the leaf
    norm product for block-sparse leaves; identical to it for
    ``kind="dense"``) and further pruned within the remaining budget — see
    :func:`_refine_leaf_spamm`.
    """
    if method == "hierarchical":
        depth = _common_depth(a, b)
        tasks, err, _ = spamm_symbolic(
            a.quadtree_index(depth), b.quadtree_index(depth), tau
        )
        if leaf_spec is not None:
            tasks, err = _refine_leaf_spamm(a, b, tasks, tau, err, leaf_spec)
        if tasks.num_tasks == 0:
            return BSMatrix.zeros((a.shape[0], b.shape[1]), a.bs, a.dtype), err
        data = spgemm_numeric(a.data, b.data, tasks, impl=impl)
        return (
            BSMatrix(
                shape=(a.shape[0], b.shape[1]),
                bs=a.bs,
                coords=tasks.c_coords,
                data=data,
            ),
            err,
        )
    assert method == "leaf", method
    tasks = spgemm_symbolic(a.coords, b.coords)
    if tasks.num_tasks == 0:
        return BSMatrix.zeros((a.shape[0], b.shape[1]), a.bs, a.dtype), 0.0
    na = np.asarray(block_frobenius_norms(a.data), dtype=np.float64)
    nb = np.asarray(block_frobenius_norms(b.data), dtype=np.float64)
    bound = na[tasks.a_idx] * nb[tasks.b_idx]
    order = np.argsort(bound)
    csum = np.cumsum(bound[order])
    ndrop = int(np.searchsorted(csum, tau, side="right"))
    drop = np.zeros(tasks.num_tasks, dtype=bool)
    drop[order[:ndrop]] = True
    err = float(csum[ndrop - 1]) if ndrop else 0.0
    kept = _prune_tasks(tasks, ~drop)
    if leaf_spec is not None:
        kept, err = _refine_leaf_spamm(a, b, kept, tau, err, leaf_spec)
    if kept.num_tasks == 0:
        return BSMatrix.zeros((a.shape[0], b.shape[1]), a.bs, a.dtype), err
    data = spgemm_numeric(a.data, b.data, kept, impl=impl)
    return (
        BSMatrix(shape=(a.shape[0], b.shape[1]), bs=a.bs, coords=kept.c_coords, data=data),
        err,
    )
