"""Truncation task types: removal of small matrix elements with error control.

The paper ships several error-control variants; we implement:

* :func:`truncate` — block-level truncation with a *global* Frobenius-norm
  guarantee: the blocks with smallest norms are removed greedily such that
  ``||A - truncate(A, tau)||_F <= tau`` (tight by construction).
* :func:`truncate_hierarchical` — the same global guarantee, decided on the
  quadtree: whole subtrees with small subtree norms are dropped first during
  a top-down descent, so a dropped subtree's leaves are never visited.
* :func:`truncate_elementwise` — zero every element with ``|a_ij| <= eps``
  and drop blocks that become empty (the classic drop-tolerance variant).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .matrix import BSMatrix
from .quadtree import hierarchical_drop_mask

__all__ = ["truncate", "truncate_hierarchical", "truncate_elementwise"]


def truncate(a: BSMatrix, tau: float) -> BSMatrix:
    """Remove smallest-norm blocks while sqrt(sum of removed norms^2) <= tau."""
    if a.nnzb == 0 or tau <= 0:
        return a
    norms = a.block_norms().astype(np.float64)
    order = np.argsort(norms)
    csum = np.sqrt(np.cumsum(norms[order] ** 2))
    ndrop = int(np.searchsorted(csum, tau, side="right"))
    if ndrop == 0:
        return a
    keep = np.ones(a.nnzb, dtype=bool)
    keep[order[:ndrop]] = False
    idx = np.nonzero(keep)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=a.data[jnp.asarray(idx)]
    )


def truncate_hierarchical(a: BSMatrix, tau: float) -> BSMatrix:
    """Truncate by dropping whole quadtree subtrees first, then leaves.

    Top-down greedy over the cached :class:`~repro.core.quadtree.QuadtreeIndex`
    subtree norms via :func:`repro.core.quadtree.hierarchical_drop_mask` —
    the same descent the distributed path
    (``repro.dist.collectives.dist_truncate_hierarchical``) runs against the
    resident norm table.  The global guarantee
    ``||A - truncate_hierarchical(A, tau)||_F <= tau`` is preserved; the
    dropped set may differ from :func:`truncate`'s leaf-greedy optimum, but a
    subtree dropped at level L is removed without its leaves ever being
    enumerated — the paper's hierarchical error-control task.
    """
    if a.nnzb == 0 or tau <= 0:
        return a
    keep, _ = hierarchical_drop_mask(a.quadtree_index(), tau)
    if keep.all():
        return a
    idx = np.nonzero(keep)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=a.data[jnp.asarray(idx)]
    )


def truncate_elementwise(a: BSMatrix, eps: float) -> BSMatrix:
    """Zero elements with |a_ij| <= eps; drop blocks that become all-zero."""
    if a.nnzb == 0:
        return a
    data = jnp.where(jnp.abs(a.data) > eps, a.data, jnp.zeros_like(a.data))
    alive = np.asarray(jnp.any(data != 0, axis=(1, 2)))
    idx = np.nonzero(alive)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=data[jnp.asarray(idx)]
    )
