"""Truncation task types: removal of small matrix elements with error control.

The paper ships several error-control variants; we implement:

* :func:`truncate` — block-level truncation with a *global* Frobenius-norm
  guarantee: the blocks with smallest norms are removed greedily such that
  ``||A - truncate(A, tau)||_F <= tau`` (tight by construction).
* :func:`truncate_hierarchical` — the same global guarantee, decided on the
  quadtree: whole subtrees with small subtree norms are dropped first during
  a top-down descent, so a dropped subtree's leaves are never visited.
* :func:`truncate_elementwise` — zero every element with ``|a_ij| <= eps``
  and drop blocks that become empty (the classic drop-tolerance variant).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .matrix import BSMatrix

__all__ = ["truncate", "truncate_hierarchical", "truncate_elementwise"]


def truncate(a: BSMatrix, tau: float) -> BSMatrix:
    """Remove smallest-norm blocks while sqrt(sum of removed norms^2) <= tau."""
    if a.nnzb == 0 or tau <= 0:
        return a
    norms = a.block_norms().astype(np.float64)
    order = np.argsort(norms)
    csum = np.sqrt(np.cumsum(norms[order] ** 2))
    ndrop = int(np.searchsorted(csum, tau, side="right"))
    if ndrop == 0:
        return a
    keep = np.ones(a.nnzb, dtype=bool)
    keep[order[:ndrop]] = False
    idx = np.nonzero(keep)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=a.data[jnp.asarray(idx)]
    )


def truncate_hierarchical(a: BSMatrix, tau: float) -> BSMatrix:
    """Truncate by dropping whole quadtree subtrees first, then leaves.

    Top-down greedy over the cached :class:`~repro.core.quadtree.QuadtreeIndex`
    subtree norms: at each level, the frontier nodes with smallest subtree
    norms are dropped while the *squared* budget allows (a subtree's squared
    Frobenius norm is exactly the sum of its leaf squares, so the accounting
    is exact); survivors descend.  The global guarantee
    ``||A - truncate_hierarchical(A, tau)||_F <= tau`` is preserved; the
    dropped set may differ from :func:`truncate`'s leaf-greedy optimum, but a
    subtree dropped at level L is removed without its leaves ever being
    enumerated — the paper's hierarchical error-control task.
    """
    if a.nnzb == 0 or tau <= 0:
        return a
    qt = a.quadtree_index()
    budget_sq = float(tau) ** 2
    drop_mark = np.zeros(a.nnzb + 1, dtype=np.int64)
    frontier = np.zeros(1, dtype=np.int64)  # root
    for level in range(qt.depth + 1):
        sq = qt.norms[level][frontier] ** 2
        order = np.argsort(sq)
        csum = np.cumsum(sq[order])
        ndrop = int(np.searchsorted(csum, budget_sq, side="right"))
        if ndrop:
            budget_sq -= float(csum[ndrop - 1])
            dropped = frontier[order[:ndrop]]
            ls = qt.leaf_start[level]
            np.add.at(drop_mark, ls[dropped], 1)
            np.add.at(drop_mark, ls[dropped + 1], -1)
            keep_nodes = np.ones(frontier.size, dtype=bool)
            keep_nodes[order[:ndrop]] = False
            frontier = frontier[keep_nodes]
        if frontier.size == 0 or level == qt.depth:
            break
        cs = qt.child_start[level]
        s0 = cs[frontier]
        counts = cs[frontier + 1] - s0
        local = np.arange(int(counts.sum())) - np.repeat(np.cumsum(counts) - counts, counts)
        frontier = np.repeat(s0, counts) + local
    keep = np.cumsum(drop_mark[:-1]) == 0
    if keep.all():
        return a
    idx = np.nonzero(keep)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=a.data[jnp.asarray(idx)]
    )


def truncate_elementwise(a: BSMatrix, eps: float) -> BSMatrix:
    """Zero elements with |a_ij| <= eps; drop blocks that become all-zero."""
    if a.nnzb == 0:
        return a
    data = jnp.where(jnp.abs(a.data) > eps, a.data, jnp.zeros_like(a.data))
    alive = np.asarray(jnp.any(data != 0, axis=(1, 2)))
    idx = np.nonzero(alive)[0]
    return BSMatrix(
        shape=a.shape, bs=a.bs, coords=a.coords[idx], data=data[jnp.asarray(idx)]
    )
