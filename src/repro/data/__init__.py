from .pipeline import TokenPipeline

__all__ = ["TokenPipeline"]
