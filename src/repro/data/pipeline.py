"""Deterministic, stateless, shardable synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` via a counter-based RNG,
which gives the fault-tolerance properties the runtime needs for free:

* **skip-ahead resume**: restarting at step N just asks for batch N — no
  iterator state to checkpoint, bitwise-identical continuation (tested).
* **host sharding**: each host materializes only its slice of the global
  batch (``host_slice``); slices are disjoint by construction.
* **elasticity**: a different host count re-slices the same global batch.

Token streams are Zipf-distributed (realistic embedding-gather skew);
modality stubs (audio frames / vision patches) are seeded Gaussians.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2

    def _rng(self, step: int, stream: int = 0) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, int(stream), int(step)])
        )

    def _tokens(self, rng, shape) -> np.ndarray:
        z = rng.zipf(self.zipf_a, size=shape)
        return ((z - 1) % self.cfg.vocab_size).astype(np.int32)

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            rng = self._rng(step)
            return {
                "frames": rng.standard_normal(
                    (self.batch, self.seq, cfg.frontend_dim), dtype=np.float32
                ),
                "labels": self._tokens(self._rng(step, 1), (self.batch, self.seq)),
            }
        if cfg.frontend == "vision_stub":
            rng = self._rng(step)
            return {
                "patches": rng.standard_normal(
                    (self.batch, cfg.num_patches, cfg.d_model), dtype=np.float32
                ).astype(np.float32),
                "tokens": self._tokens(
                    self._rng(step, 1), (self.batch, self.seq - cfg.num_patches)
                ),
            }
        return {"tokens": self._tokens(self._rng(step), (self.batch, self.seq))}

    def host_slice(
        self, step: int, host_id: int, num_hosts: int
    ) -> dict[str, np.ndarray]:
        """This host's rows of the global batch (disjoint, covering)."""
        assert self.batch % num_hosts == 0, (self.batch, num_hosts)
        per = self.batch // num_hosts
        g = self.global_batch(step)
        return {k: v[host_id * per : (host_id + 1) * per] for k, v in g.items()}
