"""Device-resident distributed matrix runtime — the CHT worker-storage layer.

The paper's CHT-MPI runtime keeps chunks resident in worker storage and
caches the chunks tasks touch, so iterative algorithms never re-ship
operands between operations.  This package is that layer for the XLA mesh:

* :class:`DistBSMatrix` (:mod:`repro.dist.matrix`) — a sharded block-sparse
  matrix whose padded per-device stores live on the worker mesh *across*
  operations; host-side structure (coords, owner, slot maps); enters via
  :func:`scatter`, leaves via :meth:`DistBSMatrix.gather`.
* :class:`PlanCache` (:mod:`repro.dist.cache`) — structure-keyed cache of
  symbolic plans, device-resident plan arrays, and jitted shard_map
  executables, with hit/miss metrics.
* resident collectives (:mod:`repro.dist.collectives`) — ``dist_add``
  (structure union, owner-aligned re-slotting), ``dist_scale``,
  ``dist_trace`` / ``dist_frobenius_norm`` (psum reductions),
  ``dist_transpose`` (re-slot to the transposed owner layout via planned
  ppermute rounds, no host gather), ``dist_submatrix`` /
  ``dist_assemble2x2`` (quadrant slice and glue — owner-local, zero
  inter-device motion), ``dist_truncate`` / ``dist_truncate_hierarchical``
  (host symbolic selection — flat greedy or quadtree subtree-drop over the
  resident norm table — then device compaction).
* :func:`dist_multiply` / :func:`dist_spamm` (:mod:`repro.dist.multiply`) —
  C = A @ B on resident operands through the cached schedule; SpAMM prunes
  hierarchically with an error bound <= tau, by default as a *delta plan*:
  a task mask against the cached full-multiply executable, so fluctuating
  prune patterns never miss the plan cache.
* :func:`dist_inv_chol` / :func:`dist_localized_inverse_factorization`
  (:mod:`repro.dist.inverse`) — inverse factorization (Z^T A Z = I) with
  the refinement loop running entirely through delta-plan SpAMM and
  hierarchical truncation, sharing one norm-table fetch per iteration.
* :func:`dist_sp2_purify` / :func:`dist_sqrt_inv_pipeline`
  (:mod:`repro.dist.purify`) — the full SP2 loop on resident matrices with
  per-iteration cache/comm stats, and the end-to-end SPD pipeline
  S -> Z -> Z^T H Z -> SP2 -> Z D Z^T that never leaves the devices.
* dynamic load balancing (:mod:`repro.dist.balance`) — a measured
  per-worker cost model (:class:`WorkerLoad`: executed tasks, exchange
  bytes, owned leaves), a :class:`RebalancePolicy` / :class:`LoadMonitor`
  feedback loop, and the resident re-layout collective
  :func:`dist_repartition` (planned ``ppermute`` rounds, block payloads
  only); the iterative drivers take ``rebalance=`` and re-lay iterates out
  between iterations when the measured imbalance crosses the threshold.
"""

from .balance import (
    LoadMonitor,
    RebalancePolicy,
    WorkerLoad,
    owner_imbalance,
    rebalanced_owner,
    worker_load,
)
from .cache import PlanCache
from .collectives import (
    dist_add,
    dist_assemble2x2,
    dist_frobenius_norm,
    dist_repartition,
    dist_scale,
    dist_submatrix,
    dist_trace,
    dist_transpose,
    dist_truncate,
    dist_truncate_hierarchical,
    transpose_permutation,
)
from .inverse import (
    DistInverseStats,
    dist_inv_chol,
    dist_localized_inverse_factorization,
)
from .matrix import DistBSMatrix, dist_zeros, resident_block_norms, scatter
from .multiply import (
    dist_multiply,
    dist_spamm,
    multiply_plan_key,
    spamm_delta_plan_key,
)
from .purify import (
    DistPurifyStats,
    SqrtInvPipelineStats,
    dist_lanczos_bounds,
    dist_sp2_purify,
    dist_sqrt_inv_pipeline,
)

__all__ = [
    "DistBSMatrix",
    "scatter",
    "dist_zeros",
    "resident_block_norms",
    "PlanCache",
    "dist_add",
    "dist_scale",
    "dist_trace",
    "dist_frobenius_norm",
    "dist_transpose",
    "dist_repartition",
    "dist_submatrix",
    "dist_assemble2x2",
    "transpose_permutation",
    "dist_truncate",
    "dist_truncate_hierarchical",
    "dist_multiply",
    "dist_spamm",
    "multiply_plan_key",
    "spamm_delta_plan_key",
    "dist_inv_chol",
    "dist_localized_inverse_factorization",
    "DistInverseStats",
    "dist_sp2_purify",
    "DistPurifyStats",
    "dist_lanczos_bounds",
    "dist_sqrt_inv_pipeline",
    "SqrtInvPipelineStats",
    "RebalancePolicy",
    "LoadMonitor",
    "WorkerLoad",
    "worker_load",
    "owner_imbalance",
    "rebalanced_owner",
]
