"""Dynamic load balancing for the device-resident runtime.

The paper's CHT-MPI runtime "succeeds to dynamically load balance the
calculation regardless of the sparsity structure" via decentralized work
stealing.  An XLA SPMD program cannot steal work mid-step, so the equivalent
feedback loop runs between steps, on the host, from quantities the runtime
already materializes:

* **Measured cost model** (:func:`worker_load` / :class:`WorkerLoad`): per
  worker, the multiply tasks it actually executed (the delta-plan SpAMM mask
  is honoured — masked-off tasks cost nothing), the flops they imply, the
  true operand bytes it received *and shipped* during the planned
  ``ppermute`` rounds (:func:`repro.core.schedule.plan_worker_bytes`), and
  the resident leaf blocks it owns, optionally weighted by the norm table so
  structurally-present-but-zero leaves count for nothing.
* **Policy** (:class:`RebalancePolicy` / :class:`LoadMonitor`): the combined
  per-worker cost (tasks + comm + ownership, in task-equivalent units) is
  summarized as ``imbalance = max / mean``; when it exceeds the threshold,
  a new owner map is proposed — a weighted, subtree-aligned
  :func:`repro.core.schedule.partition_morton` cut over per-block weights
  measured from the executed task list — and adopted only when it improves
  the predicted imbalance by ``min_gain`` (so a stabilized layout is never
  churned and the plan cache stays all-hit).
* **Re-layout** (:func:`repro.dist.collectives.dist_repartition`): blocks
  migrate to the new owners entirely on device via planned ``ppermute``
  rounds; values, coordinates and Morton stack order are untouched, so the
  algorithm cannot observe the move — only the schedule can.

The iterative drivers (``dist_sp2_purify``, the inverse refinement loop, and
``dist_sqrt_inv_pipeline``) accept ``rebalance=RebalancePolicy(...)`` and run
this loop between iterations, reporting per-iteration imbalance and migrated
bytes in their stats rows.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.quadtree import morton_encode
from repro.core.schedule import (
    SpgemmPlan,
    partition_morton,
    plan_worker_bytes,
    subtree_boundaries,
)
from repro.core.spgemm import Tasks

from .collectives import RepartitionExecutable, dist_repartition  # noqa: F401
from .matrix import DistBSMatrix

__all__ = [
    "RebalancePolicy",
    "WorkerLoad",
    "LoadMonitor",
    "worker_load",
    "calibrate_policy",
    "measure_iteration_load",
    "peek_last_plan",
    "block_reference_weights",
    "map_block_weights",
    "owner_imbalance",
    "rebalanced_owner",
    "dist_repartition",
    "RepartitionExecutable",
]


@dataclasses.dataclass(frozen=True)
class RebalancePolicy:
    """Knobs of the rebalancing feedback loop.

    Cost coefficients express everything in task-equivalent units (one unit =
    one leaf multiply task, 2*bs^3 flops): moving one operand block over the
    interconnect is charged ``recv_cost`` (receiver) + ``send_cost``
    (shipper) tasks, and owning one resident leaf block — its share of norm
    reductions, additions, truncation compactions and store memory — is
    charged ``block_cost`` tasks.  ``threshold`` is the combined max/mean
    imbalance above which a re-layout is considered; ``min_gain`` is the
    predicted-improvement factor a proposed owner map must deliver before it
    is adopted (the hysteresis that keeps a stabilized layout, and therefore
    the plan cache, untouched).  ``align_subtrees`` / ``slack`` are forwarded
    to :func:`repro.core.schedule.partition_morton` so the new cuts keep
    snapping to quadtree node boundaries.
    """

    threshold: float = 1.25
    min_gain: float = 1.1
    recv_cost: float = 0.5
    send_cost: float = 0.5
    block_cost: float = 0.25
    align_subtrees: bool = True
    slack: float = 0.15

    def __post_init__(self):
        assert self.threshold >= 1.0 and self.min_gain >= 1.0


@dataclasses.dataclass(frozen=True)
class WorkerLoad:
    """Measured per-worker cost of one executed distributed multiply.

    All arrays are ``[nparts]``.  ``tasks`` counts the leaf multiply tasks
    the worker actually ran (under delta-plan SpAMM: after the runtime task
    mask); ``recv_bytes`` / ``send_bytes`` are the true (unpadded) operand
    bytes of the planned exchange rounds; ``blocks`` is the (optionally
    norm-weighted) count of resident operand leaves the worker owns.

    ``wall_s``, when set (the drivers thread the measured iteration span
    duration in via :meth:`LoadMonitor.note_wall`), is the wall-clock
    seconds of the step this load was measured from — the feedback signal
    :func:`calibrate_policy` fits the policy's cost coefficients against.
    """

    nparts: int
    bs: int
    tasks: np.ndarray
    recv_bytes: np.ndarray
    send_bytes: np.ndarray
    blocks: np.ndarray
    wall_s: float | None = None

    def flops(self) -> np.ndarray:
        return 2.0 * self.tasks * float(self.bs) ** 3

    def __add__(self, other: "WorkerLoad") -> "WorkerLoad":
        """Accumulate loads of several multiplies (one driver iteration)."""
        assert self.nparts == other.nparts and self.bs == other.bs
        wall = (
            None
            if self.wall_s is None and other.wall_s is None
            else (self.wall_s or 0.0) + (other.wall_s or 0.0)
        )
        return WorkerLoad(
            nparts=self.nparts,
            bs=self.bs,
            tasks=self.tasks + other.tasks,
            recv_bytes=self.recv_bytes + other.recv_bytes,
            send_bytes=self.send_bytes + other.send_bytes,
            blocks=self.blocks + other.blocks,
            wall_s=wall,
        )

    def combined(self, policy: RebalancePolicy) -> np.ndarray:
        """Per-worker cost in task-equivalent units under the policy."""
        blk = float(self.bs * self.bs * 4)
        return (
            self.tasks
            + policy.recv_cost * self.recv_bytes / blk
            + policy.send_cost * self.send_bytes / blk
            + policy.block_cost * self.blocks
        )

    def imbalance(self, policy: RebalancePolicy | None = None) -> float:
        """max/mean of the combined per-worker cost (1.0 = perfect balance)."""
        c = self.combined(policy if policy is not None else RebalancePolicy())
        mean = c.mean()
        return float(c.max() / mean) if mean > 0 else 1.0


def worker_load(
    plan: SpgemmPlan,
    *,
    task_count: np.ndarray | None = None,
    a_weights: np.ndarray | None = None,
    b_weights: np.ndarray | None = None,
) -> WorkerLoad:
    """Measured :class:`WorkerLoad` of one executed multiply plan.

    ``task_count`` overrides the plan's static per-worker task counts with
    what actually ran (the drivers pass the delta-plan SpAMM masked counts
    surfaced on ``cache.last_task_count``).  ``a_weights`` / ``b_weights``
    are per-block ownership weights in operand stack order — the drivers
    pass ``norms != 0`` from the resident norm table so numerically-zero
    leaves cost nothing (leaf-nnz weighting); default is one per block.
    """
    P = plan.nparts
    tasks = np.asarray(
        plan.task_count if task_count is None else task_count, dtype=np.float64
    )
    assert tasks.shape == (P,)
    recv, send, _ = plan_worker_bytes(plan)
    wa = np.ones(plan.a_owner.shape[0]) if a_weights is None else np.asarray(
        a_weights, dtype=np.float64
    )
    wb = np.ones(plan.b_owner.shape[0]) if b_weights is None else np.asarray(
        b_weights, dtype=np.float64
    )
    blocks = np.bincount(plan.a_owner, weights=wa, minlength=P) + np.bincount(
        plan.b_owner, weights=wb, minlength=P
    )
    return WorkerLoad(
        nparts=P,
        bs=plan.bs,
        tasks=tasks,
        recv_bytes=recv,
        send_bytes=send,
        blocks=blocks.astype(np.float64),
    )


def calibrate_policy(
    loads: list[WorkerLoad], base: RebalancePolicy | None = None
) -> tuple[RebalancePolicy, dict]:
    """Fit the policy's cost coefficients from measured wall-clock feedback.

    An SPMD step's wall time is set by its slowest worker, so each observed
    load with a :attr:`WorkerLoad.wall_s` contributes one sample of

        wall  ~=  k_t * max(tasks) + k_r * max(recv)/blk
                + k_s * max(send)/blk + k_b * max(blocks)

    solved by least squares (coefficients clipped at zero).  ``k_t`` is the
    seconds-per-task unit; the returned policy carries the measured ratios
    ``recv_cost = k_r / k_t`` etc. in the usual task-equivalent units —
    closing the loop the static defaults (0.5 / 0.5 / 0.25) only guessed at.
    Falls back to ``base`` unchanged (``fitted=False`` in the report) when
    there are fewer samples than coefficients or the fit degenerates.
    """
    base = base if base is not None else RebalancePolicy()
    samples = [ld for ld in loads if ld.wall_s is not None and ld.wall_s > 0]
    report = dict(
        samples=len(samples),
        fitted=False,
        task_s=None,
        recv_cost=base.recv_cost,
        send_cost=base.send_cost,
        block_cost=base.block_cost,
        rms_resid_s=None,
    )
    if len(samples) < 4:
        return base, report
    blk = float(samples[0].bs * samples[0].bs * 4)
    X = np.array(
        [
            [
                ld.tasks.max(),
                ld.recv_bytes.max() / blk,
                ld.send_bytes.max() / blk,
                ld.blocks.max(),
            ]
            for ld in samples
        ],
        dtype=np.float64,
    )
    y = np.array([ld.wall_s for ld in samples], dtype=np.float64)
    k, *_ = np.linalg.lstsq(X, y, rcond=None)
    k = np.clip(k, 0.0, None)
    if k[0] <= 0.0:
        return base, report
    policy = dataclasses.replace(
        base,
        recv_cost=float(k[1] / k[0]),
        send_cost=float(k[2] / k[0]),
        block_cost=float(k[3] / k[0]),
    )
    report.update(
        fitted=True,
        task_s=float(k[0]),
        recv_cost=policy.recv_cost,
        send_cost=policy.send_cost,
        block_cost=policy.block_cost,
        rms_resid_s=float(np.sqrt(np.mean((X @ k - y) ** 2))),
    )
    return policy, report


def peek_last_plan(cache) -> SpgemmPlan | None:
    """The plan behind the most recent multiply-family call, or None.

    Reads ``cache.last_plan_key`` without touching hit/miss counters or LRU
    order — the drivers call this right after each multiply to measure the
    plan that actually executed (exact, SpAMM-replan or SpAMM-delta alike).
    """
    if cache is None or cache.last_plan_key is None:
        return None
    entry = cache.peek(cache.last_plan_key)
    plan = entry[0] if entry is not None else None
    assert plan is None or isinstance(plan, SpgemmPlan)
    return plan


def measure_iteration_load(
    cache,
    plan: SpgemmPlan | None,
    a_leaf_weights: np.ndarray | None = None,
    b_leaf_weights: np.ndarray | None = None,
) -> WorkerLoad | None:
    """Measured :class:`WorkerLoad` of the multiply a driver just executed.

    ``plan`` is the peeked plan behind ``cache.last_plan_key``;
    ``cache.last_task_count`` carries the per-worker tasks that actually ran
    (delta-plan SpAMM masks tasks at runtime, so the plan's static counts
    overstate the work).  The leaf-weight vectors are the operands'
    stack-order leaf-nnz weights (``norms != 0``) when the driver holds a
    norm table; each is ignored when its length no longer matches the
    operand the plan was built for.  Returns ``None`` when no plan ran this
    iteration.
    """
    if plan is None:
        return None
    tcount = getattr(cache, "last_task_count", None)
    if tcount is None or len(tcount) != plan.nparts:
        tcount = plan.task_count
    wa, wb = a_leaf_weights, b_leaf_weights
    if wa is not None and wa.shape[0] != plan.a_owner.shape[0]:
        wa = None  # structure drifted from the table the caller holds
    if wb is not None and wb.shape[0] != plan.b_owner.shape[0]:
        wb = None
    return worker_load(plan, task_count=tcount, a_weights=wa, b_weights=wb)


def block_reference_weights(
    tasks: Tasks, na: int, nb: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-block task-reference counts (wa [na], wb [nb]) of a task list.

    ``wa[i]`` counts the multiply tasks reading A block ``i`` — the measured
    per-block flop weight the re-layout cut optimizes.  Structural (derived
    from the full task list, not the per-call prune mask), so the proposed
    owner map is deterministic per structure and the plan cache converges.
    """
    wa = np.bincount(tasks.a_idx, minlength=na).astype(np.float64)
    wb = np.bincount(tasks.b_idx, minlength=nb).astype(np.float64)
    return wa, wb


def map_block_weights(
    src_coords: np.ndarray,
    src_weights: np.ndarray,
    dst_coords: np.ndarray,
    default: float = 1.0,
) -> np.ndarray:
    """Carry per-block weights from one structure to another by coordinates.

    The cost model measures weights on the structure that was multiplied; by
    re-layout time the iterate has been updated (squaring fill-in,
    truncation), so weights are joined on Morton codes: blocks present in
    both keep their measured weight, new blocks get ``default``.
    """
    dst = np.asarray(dst_coords)
    if dst.shape[0] == 0:
        return np.zeros((0,), dtype=np.float64)
    out = np.full(dst.shape[0], float(default), dtype=np.float64)
    src = np.asarray(src_coords)
    if src.shape[0] == 0:
        return out
    src_codes = morton_encode(src[:, 0], src[:, 1])
    dst_codes = morton_encode(dst[:, 0], dst[:, 1])
    pos = np.searchsorted(src_codes, dst_codes)
    pos_c = np.minimum(pos, src_codes.size - 1)
    hit = src_codes[pos_c] == dst_codes
    out[hit] = np.asarray(src_weights, dtype=np.float64)[pos_c[hit]]
    return out


def owner_imbalance(
    owner: np.ndarray, weights: np.ndarray, nparts: int
) -> float:
    """max/mean weighted load of an owner map (1.0 = perfect balance)."""
    loads = np.bincount(
        np.asarray(owner, dtype=np.int64),
        weights=np.asarray(weights, dtype=np.float64),
        minlength=nparts,
    )
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


def rebalanced_owner(
    coords: np.ndarray,
    weights: np.ndarray,
    nparts: int,
    policy: RebalancePolicy | None = None,
) -> np.ndarray:
    """Weighted, subtree-aligned Morton re-partition for a block structure.

    The proposal side of the feedback loop: the same
    :func:`repro.core.schedule.partition_morton` cut the static scheduler
    uses, but over *measured* per-block weights — contiguous Morton ranges
    (locality preserved), cuts snapped to quadtree node boundaries within the
    policy's balance slack.
    """
    policy = policy if policy is not None else RebalancePolicy()
    coords = np.asarray(coords)
    align = subtree_boundaries(coords) if policy.align_subtrees else None
    return partition_morton(
        coords.shape[0], nparts, weights, align=align, slack=policy.slack
    )


class LoadMonitor:
    """Tracks measured worker loads and decides when a re-layout pays.

    ``observe`` records a :class:`WorkerLoad` and returns its combined
    imbalance; ``should_rebalance`` applies the policy threshold;
    ``propose`` turns measured per-block weights into a candidate owner map
    and vets it — identical maps and maps that do not improve the predicted
    weighted imbalance by ``min_gain`` are rejected (returning ``None``), so
    once the layout has converged the monitor goes quiet and every
    downstream plan stays cached.
    """

    def __init__(self, nparts: int, policy: RebalancePolicy | None = None):
        self.nparts = int(nparts)
        self.policy = policy if policy is not None else RebalancePolicy()
        self.loads: list[WorkerLoad] = []
        self.rebalances = 0

    def observe(self, load: WorkerLoad) -> float:
        self.loads.append(load)
        return load.imbalance(self.policy)

    def note_wall(self, wall_s: float) -> None:
        """Attach a measured step wall time to the latest observed load.

        The drivers call this with the iteration span's duration right after
        :meth:`observe` — the wall-clock feedback :func:`calibrate_policy`
        fits the policy coefficients against.
        """
        if self.loads and wall_s > 0:
            self.loads[-1] = dataclasses.replace(
                self.loads[-1], wall_s=float(wall_s)
            )

    def calibration(self) -> tuple[RebalancePolicy, dict]:
        """Wall-clock-calibrated policy + fit report from the observed loads."""
        return calibrate_policy(self.loads, self.policy)

    def should_rebalance(self, load: WorkerLoad) -> bool:
        return load.imbalance(self.policy) > self.policy.threshold

    def propose(
        self, x: DistBSMatrix, weights: np.ndarray
    ) -> np.ndarray | None:
        """Candidate owner map for ``x`` under measured block weights, or
        ``None`` when a re-layout would not pay."""
        if x.nnzb == 0:
            return None
        new_owner = rebalanced_owner(x.coords, weights, self.nparts, self.policy)
        if np.array_equal(new_owner, x.owner):
            return None
        before = owner_imbalance(x.owner, weights, self.nparts)
        after = owner_imbalance(new_owner, weights, self.nparts)
        if before < after * self.policy.min_gain:
            return None
        return new_owner

    def migrate(
        self, x: DistBSMatrix, weights: np.ndarray, cache=None
    ) -> tuple[DistBSMatrix, int, float | None]:
        """Propose-and-apply a re-layout of ``x`` under measured weights.

        The shared tail of every driver's rebalance step: vet a candidate
        owner map (:meth:`propose`), re-slot on device when it pays, and
        account the move.  Returns ``(x, migrated_bytes,
        predicted_imbalance_after)`` — the last two are ``0`` / ``None``
        when no re-layout happened.
        """
        new_owner = self.propose(x, weights)
        if new_owner is None:
            return x, 0, None
        before = owner_imbalance(x.owner, weights, self.nparts)
        info: dict = {}
        x = dist_repartition(x, new_owner, cache, stats=info)
        self.rebalances += 1
        after = owner_imbalance(new_owner, weights, self.nparts)
        from repro.obs.log import log_of

        lg = log_of(cache)
        if lg.enabled:
            lg.info(
                "rebalance", migrated_bytes=int(info["migrated_bytes"]),
                imbalance=float(before), imbalance_after=float(after),
                rebalances=self.rebalances, nnzb=int(x.nnzb),
            )
        return x, info["migrated_bytes"], after

    def relayout_if_skewed(
        self, x: DistBSMatrix, cache=None, weights: np.ndarray | None = None
    ) -> tuple[DistBSMatrix, int]:
        """Up-front re-layout of a skewed matrix; returns (x, migrated bytes).

        The entry-point fix for layouts the iteration itself never revisits —
        a skewed initial iterate, or a pinned operand (the SPD matrix of the
        inverse refinement) whose placement would otherwise stay skewed for
        every remaining multiply.  Block-ownership weights only (``weights``
        defaults to one per block); gated by the policy threshold and
        ``propose``'s gain vetting like every other re-layout.
        """
        if x.nnzb == 0:
            return x, 0
        w = np.ones(x.nnzb, dtype=np.float64) if weights is None else weights
        if owner_imbalance(x.owner, w, self.nparts) <= self.policy.threshold:
            return x, 0
        x, migrated, _ = self.migrate(x, w, cache)
        return x, migrated
