"""Structure-keyed plan cache — the chunk-cache analogue of the CHT runtime.

CHT workers cache the chunks tasks touch so iterative algorithms stop paying
for re-fetches once their access pattern stabilizes.  The XLA-side
equivalents of those re-fetches are (a) host-side symbolic planning, (b)
shipping plan index arrays to devices and (c) tracing + compiling the
``shard_map`` program.  :class:`PlanCache` memoizes all three behind a key
derived from :func:`repro.core.quadtree.structure_fingerprint` of the operand
structures (Morton codes + owner maps) plus the schedule knobs (nparts,
placement/exchange mode, impl).  Every purification iteration after the
sparsity pattern stabilizes under truncation is a pure cache hit: no
planning, no recompilation, no host->device transfer.

The generic LRU + hit/miss machinery lives in
:class:`repro.core.cache.SymbolicCache`, which the single-host symbolic
phases share; ``PlanCache`` is its distributed-plan face.
"""

from __future__ import annotations

from repro.core.cache import SymbolicCache

__all__ = ["PlanCache"]


class PlanCache(SymbolicCache):
    """LRU cache from structure keys to built plans/executables.

    Keys are hashable tuples (callers prefix them with a kind tag:
    ``"spgemm"`` / ``"spamm"`` / ``"spamm-delta"`` / ``"spgemm-tasks"`` /
    ``"add"`` / ``"transpose"`` / ``"repartition"`` / ``"slice"`` /
    ``"assemble"`` / ``"truncate"`` / ``"trace"`` / ``"fro"`` / ``"norms"``
    — the full resident vocabulary; per-kind hit/miss counts surface in
    :meth:`stats`).  Values are whatever the builder returns — typically a
    (plan, executable) pair whose executable holds device-resident index
    arrays and a jitted shard_map program.  Every key fingerprints the
    operand owner maps, so a dynamic re-layout
    (:func:`repro.dist.collectives.dist_repartition`) re-keys downstream
    plans automatically and a stabilized layout returns to all-hit.

    Admission runs the static verifier (:mod:`repro.analysis`) per the
    ``verify=`` policy inherited from :class:`SymbolicCache`: the default
    ``"cached-once"`` re-proves every plan / relayout / norm-table value
    once, on the miss path — a zero-miss replay (the stabilized SCF steady
    state) never verifies and pays nothing — while ``"always"`` re-verifies
    on every hit and ``"off"`` disables the hook.  Violations raise
    :class:`repro.analysis.PlanError` before the bad plan is cached and
    surface through the tracer as ``plan_verify_violation`` instants.
    """
