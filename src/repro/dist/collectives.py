"""Distributed linear-algebra collectives over resident stores.

Everything here follows the same shape as the multiply schedule: a host-side
symbolic phase per *structure* (cached in :class:`~repro.dist.cache.PlanCache`)
producing small index arrays and a jitted ``shard_map`` program, and a device
phase that only ever touches the resident stores:

* :func:`dist_add` — C = alpha*A + beta*B, structure union with owner-aligned
  re-slotting: union blocks inherit A's owner where present, else B's, so
  only B-copies of overlapping blocks ever cross a device boundary (planned
  as ``ppermute`` rounds via :func:`repro.core.schedule.plan_fetch`).
* :func:`dist_trace` / :func:`dist_frobenius_norm` — local masked reductions
  followed by a ``psum`` over the worker axis.
* :func:`dist_truncate` — device-computed block norms, host symbolic
  selection (identical error control to :func:`repro.core.truncate.truncate`),
  device-side compaction gather; blocks keep their owners so no data moves.
* :func:`dist_truncate_hierarchical` — the same compaction, but the symbolic
  selection is the quadtree subtree-drop descent
  (:func:`repro.core.quadtree.hierarchical_drop_mask`) over a
  :class:`~repro.core.quadtree.QuadtreeIndex` built from the resident norm
  table: dropped subtrees' leaves are never enumerated, and only the tiny
  [P, cap] norm table ever crosses device->host.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import AXIS, _exchange_bufs
from repro.core.quadtree import (
    build_quadtree_index,
    hierarchical_drop_mask,
    morton_sort,
    quadtree_depth,
)
from repro.core.schedule import (
    _owner_slots,
    local_fetch_index,
    plan_fetch,
    structure_fingerprint,
)
from repro.jax_compat import shard_map
from repro.obs.timing import timed_into
from repro.obs.tracer import tracer_of

from .cache import PlanCache
from .matrix import DistBSMatrix, mesh_key, resident_block_norms

__all__ = [
    "dist_add",
    "dist_scale",
    "dist_trace",
    "dist_frobenius_norm",
    "dist_transpose",
    "dist_repartition",
    "RepartitionExecutable",
    "dist_submatrix",
    "dist_assemble2x2",
    "dist_truncate",
    "dist_truncate_hierarchical",
    "transpose_permutation",
]


def _put(mesh, x):
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(AXIS)))


def _structure_key(a: DistBSMatrix) -> tuple:
    return (
        structure_fingerprint(a.codes(), a.owner, a.nparts, a.bs),
        mesh_key(a.mesh),
    )


# --------------------------------------------------------------------------
# add
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _AddSpec:
    nparts: int
    a_offsets: tuple
    b_offsets: tuple


def _acc_dtype(*dtypes):
    """Accumulate in at least float32, wider if the stores are wider."""
    out = jnp.float32
    for dt in dtypes:
        out = jnp.promote_types(out, dt)
    return out


def _mapped_add(
    a_store, b_store, idx_a, idx_b, val_a, val_b, alpha, beta, *sends, spec
):
    na = len(spec.a_offsets)
    acc = _acc_dtype(a_store.dtype, b_store.dtype)
    a_all = _exchange_bufs(a_store[0], spec.a_offsets, sends[:na], spec.nparts)
    b_all = _exchange_bufs(b_store[0], spec.b_offsets, sends[na:], spec.nparts)
    c = alpha.astype(acc) * a_all[idx_a[0]].astype(acc) * val_a[0][:, None, None].astype(acc)
    c += beta.astype(acc) * b_all[idx_b[0]].astype(acc) * val_b[0][:, None, None].astype(acc)
    return c[None]


class AddExecutable:
    """Planned structure-union add bound to a mesh; alpha/beta are runtime
    scalars so one executable serves every coefficient pair."""

    def __init__(self, a: DistBSMatrix, b: DistBSMatrix):
        nparts, mesh = a.nparts, a.mesh
        a_codes, b_codes = a.codes(), b.codes()
        c_codes = np.union1d(a_codes, b_codes)  # sorted == Morton order
        nc = int(c_codes.size)
        pos_a = np.searchsorted(c_codes, a_codes)
        pos_b = np.searchsorted(c_codes, b_codes)
        # owner-aligned re-slotting: A's owner wins on overlap -> A blocks
        # never move; B-only blocks inherit B's owner and never move either.
        c_owner = np.zeros(nc, dtype=np.int32)
        c_owner[pos_b] = b.owner
        c_owner[pos_a] = a.owner
        c_slot, c_stores = _owner_slots(c_owner, nparts)
        c_cap = max(max((len(s) for s in c_stores), default=0), 1)

        # which A/B blocks each device needs: the source blocks of the union
        # entries it owns (ascending by construction; plan_fetch skips the
        # ones whose source copy is already local)
        def needs(x_pos):
            dst_of = c_owner[x_pos]
            return [
                np.nonzero(dst_of == p)[0].astype(np.int64) for p in range(nparts)
            ]

        a_offsets, a_send, a_send_cnt, a_recv = plan_fetch(
            a.owner, a.slot, needs(pos_a), nparts
        )
        b_offsets, b_send, b_send_cnt, b_recv = plan_fetch(
            b.owner, b.slot, needs(pos_b), nparts
        )

        # union position -> source block index (or -1)
        from_a = -np.ones(nc, dtype=np.int64)
        from_b = -np.ones(nc, dtype=np.int64)
        from_a[pos_a] = np.arange(a.nnzb)
        from_b[pos_b] = np.arange(b.nnzb)

        idx_a = np.zeros((nparts, c_cap), dtype=np.int32)
        idx_b = np.zeros((nparts, c_cap), dtype=np.int32)
        val_a = np.zeros((nparts, c_cap), dtype=np.float32)
        val_b = np.zeros((nparts, c_cap), dtype=np.float32)
        for p, s in enumerate(c_stores):
            for local, u in enumerate(s):
                ga, gb = from_a[u], from_b[u]
                if ga >= 0:
                    idx_a[p, local] = local_fetch_index(
                        a.owner, a.slot, a_offsets, a_send, a_recv, a.cap, ga, p
                    )
                    val_a[p, local] = 1.0
                if gb >= 0:
                    idx_b[p, local] = local_fetch_index(
                        b.owner, b.slot, b_offsets, b_send, b_recv, b.cap, gb, p
                    )
                    val_b[p, local] = 1.0

        # host-side plan copy retained for static verification at plan-cache
        # admission (repro.analysis.verify, kind="add") — the device arrays
        # are unverifiable post-put
        self._verify_plan = dict(
            kind="add", nparts=nparts,
            a_owner=np.asarray(a.owner), a_slot=np.asarray(a.slot),
            a_cap=a.cap,
            b_owner=np.asarray(b.owner), b_slot=np.asarray(b.slot),
            b_cap=b.cap,
            pos_a=pos_a, pos_b=pos_b, from_a=from_a, from_b=from_b,
            c_owner=c_owner, c_slot=c_slot, c_cap=c_cap,
            a_offsets=a_offsets, a_send=a_send, a_send_cnt=a_send_cnt,
            b_offsets=b_offsets, b_send=b_send, b_send_cnt=b_send_cnt,
            idx_a=idx_a, idx_b=idx_b, val_a=val_a, val_b=val_b,
        )

        from repro.core.quadtree import morton_decode

        r, c = morton_decode(c_codes)
        self.c_coords = np.stack([r, c], axis=1)
        self.c_owner = c_owner
        self.c_slot = c_slot
        self.c_cap = c_cap
        self.mesh = mesh
        spec = _AddSpec(nparts, a_offsets, b_offsets)
        self._plan_args = [
            _put(mesh, idx_a),
            _put(mesh, idx_b),
            _put(mesh, val_a),
            _put(mesh, val_b),
        ]
        self._sends = [_put(mesh, a_send[d]) for d in a_offsets] + [
            _put(mesh, b_send[d]) for d in b_offsets
        ]
        nargs = 2 + len(self._plan_args)
        self._mapped = jax.jit(
            shard_map(
                functools.partial(_mapped_add, spec=spec),
                mesh=mesh,
                in_specs=tuple(P(AXIS) for _ in range(nargs))
                + (P(), P())
                + tuple(P(AXIS) for _ in self._sends),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, a_store, b_store, alpha, beta):
        return self._mapped(
            a_store,
            b_store,
            *self._plan_args,
            jnp.float32(alpha),
            jnp.float32(beta),
            *self._sends,
        )


def dist_add(
    a: DistBSMatrix,
    b: DistBSMatrix,
    alpha=1.0,
    beta=1.0,
    cache: PlanCache | None = None,
) -> DistBSMatrix:
    """C = alpha*A + beta*B on resident stores; structure-union plan cached."""
    assert a.shape == b.shape and a.bs == b.bs, (a.shape, b.shape, a.bs, b.bs)
    tr = tracer_of(cache)
    key = ("add", _structure_key(a), _structure_key(b))
    build = lambda: AddExecutable(a, b)
    with tr.span("dist_add", cat="collective", nnzb_a=a.nnzb, nnzb_b=b.nnzb):
        exe = cache.get_or_build(key, build) if cache is not None else build()
        with tr.span("dispatch", cat="kernel", op="add") as sp:
            store = tr.sync(
                exe(a.store, b.store, alpha, beta).astype(
                    jnp.result_type(a.dtype, b.dtype)
                )
            )
            if tr.enabled:
                sp.worker_costs = np.bincount(
                    exe.c_owner, minlength=a.nparts
                ).astype(np.float64)
    return DistBSMatrix(
        shape=tuple(a.shape),
        bs=a.bs,
        coords=exe.c_coords,
        owner=exe.c_owner,
        slot=exe.c_slot,
        cap=exe.c_cap,
        store=store,
        mesh=a.mesh,
    )


def dist_scale(a: DistBSMatrix, alpha) -> DistBSMatrix:
    """alpha * A; purely local, no plan needed."""
    return a.scale(alpha)


# --------------------------------------------------------------------------
# reductions
# --------------------------------------------------------------------------


def _mapped_masked_trace(store, mask):
    acc = _acc_dtype(store.dtype)
    tr = jnp.einsum("cii->c", store[0].astype(acc))
    return jax.lax.psum(jnp.sum(tr * mask[0].astype(acc)), AXIS)


def _mapped_masked_sumsq(store, mask):
    acc = _acc_dtype(store.dtype)
    sq = jnp.sum(store[0].astype(acc) ** 2, axis=(1, 2))
    return jax.lax.psum(jnp.sum(sq * mask[0].astype(acc)), AXIS)


class _ReduceExecutable:
    def __init__(self, a: DistBSMatrix, body, mask: np.ndarray):
        self._mask = _put(a.mesh, mask)
        self._mapped = jax.jit(
            shard_map(
                body,
                mesh=a.mesh,
                in_specs=(P(AXIS), P(AXIS)),
                out_specs=P(),
                check_vma=False,
            )
        )

    def __call__(self, store):
        return self._mapped(store, self._mask)


def _valid_mask(a: DistBSMatrix) -> np.ndarray:
    _, valid = a.store_maps()
    return valid.astype(np.float32)


def dist_trace(a: DistBSMatrix, cache: PlanCache | None = None) -> float:
    """trace(A): psum of masked per-device diagonal-block traces."""
    def build():
        mask = np.zeros((a.nparts, a.cap), dtype=np.float32)
        diag = a.coords[:, 0] == a.coords[:, 1]
        mask[a.owner[diag], a.slot[diag]] = 1.0
        return _ReduceExecutable(a, _mapped_masked_trace, mask)

    tr = tracer_of(cache)
    with tr.span("dist_trace", cat="collective", nnzb=a.nnzb):
        key = ("trace", _structure_key(a))
        exe = cache.get_or_build(key, build) if cache is not None else build()
        return float(exe(a.store))


def dist_frobenius_norm(a: DistBSMatrix, cache: PlanCache | None = None) -> float:
    """||A||_F: psum of per-device masked block sum-of-squares."""
    def build():
        return _ReduceExecutable(a, _mapped_masked_sumsq, _valid_mask(a))

    tr = tracer_of(cache)
    with tr.span("dist_fro", cat="collective", nnzb=a.nnzb):
        key = ("fro", _structure_key(a))
        exe = cache.get_or_build(key, build) if cache is not None else build()
        return float(np.sqrt(exe(a.store)))


# --------------------------------------------------------------------------
# truncation
# --------------------------------------------------------------------------


@jax.jit
def _block_norms_sq(store):
    return jnp.sum(store.astype(_acc_dtype(store.dtype)) ** 2, axis=(2, 3))


def _mapped_compact(store, gidx, gval):
    return (store[0][gidx[0]] * gval[0][:, None, None].astype(store.dtype))[None]


class _CompactExecutable:
    def __init__(self, a: DistBSMatrix, gidx: np.ndarray, gval: np.ndarray):
        self._args = [_put(a.mesh, gidx), _put(a.mesh, gval)]
        self._mapped = jax.jit(
            shard_map(
                _mapped_compact,
                mesh=a.mesh,
                in_specs=(P(AXIS), P(AXIS), P(AXIS)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, store):
        return self._mapped(store, *self._args)


def _compact_to_kept(
    a: DistBSMatrix,
    kept: np.ndarray,
    cache: PlanCache | None,
    *,
    coords: np.ndarray | None = None,
    shape: tuple[int, int] | None = None,
    kind: str = "truncate",
) -> DistBSMatrix:
    """Device-side compaction onto a kept subset of the block stack.

    Shared tail of both truncation variants and of the resident quadrant
    slice (:func:`dist_submatrix`): blocks keep their owners (slots just
    close ranks within each device), so compaction never moves block data
    between devices; the gather executable is cached per
    (structure, kept-set).  ``kept`` may carry any order — slots follow its
    order per owner, so slicers that re-sort shifted coordinates into Morton
    order preserve the store layout invariant.  ``coords`` / ``shape``
    override the result structure (slices shift coordinates and shrink the
    logical shape; the executable itself depends only on the kept set).
    """
    new_owner = a.owner[kept]
    new_slot, new_stores = _owner_slots(new_owner, a.nparts)
    new_cap = max(max((len(s) for s in new_stores), default=0), 1)
    gidx = np.zeros((a.nparts, new_cap), dtype=np.int32)
    gval = np.zeros((a.nparts, new_cap), dtype=np.float32)
    for p, s in enumerate(new_stores):
        old = a.slot[kept[s]]
        gidx[p, : len(s)] = old
        gval[p, : len(s)] = 1.0

    key = (kind, _structure_key(a), structure_fingerprint(kept))

    def build():
        exe = _CompactExecutable(a, gidx, gval)
        # host-side plan copy for static verification at cache admission
        # (repro.analysis.verify, kind="compact")
        exe._verify_plan = dict(
            kind="compact", label=kind, nparts=a.nparts,
            a_owner=np.asarray(a.owner), a_slot=np.asarray(a.slot),
            a_cap=a.cap, kept=np.asarray(kept, dtype=np.int64),
            new_owner=new_owner, new_slot=new_slot, new_cap=new_cap,
            gidx=gidx, gval=gval,
        )
        return exe

    exe = cache.get_or_build(key, build) if cache is not None else build()
    return DistBSMatrix(
        shape=tuple(a.shape) if shape is None else tuple(shape),
        bs=a.bs,
        coords=a.coords[kept] if coords is None else coords,
        owner=new_owner,
        slot=new_slot,
        cap=new_cap,
        store=exe(a.store),
        mesh=a.mesh,
    )


def dist_truncate(
    a: DistBSMatrix, tau: float, cache: PlanCache | None = None
) -> DistBSMatrix:
    """Drop smallest-norm blocks with sqrt(sum of dropped norms^2) <= tau.

    Block norms are computed on device (only the tiny [P, cap] norm table
    crosses to the host); the greedy global selection is the same error
    control as :func:`repro.core.truncate.truncate`; surviving blocks are
    compacted device-side and keep their owners, so truncation moves no
    block data between devices.
    """
    if a.nnzb == 0 or tau <= 0:
        return a
    tr = tracer_of(cache)
    # device fetch stays OUTSIDE the symbolic account (same rule as the
    # hierarchical path, which times only the descent)
    norms_sq = np.asarray(_block_norms_sq(a.store))  # [P, cap] -> host (small)
    if tr.enabled:
        tr.counter("norm_fetch_bytes").add(a.nnzb * 4)
    with timed_into(cache, "symbolic_s", tr, "truncate_select",
                    cat="symbolic", nnzb=a.nnzb):
        n_sq = norms_sq[a.owner, a.slot].astype(np.float64)
        order = np.argsort(n_sq)
        csum = np.sqrt(np.cumsum(n_sq[order]))
        ndrop = int(np.searchsorted(csum, tau, side="right"))
    if ndrop == 0:
        return a
    keep = np.ones(a.nnzb, dtype=bool)
    keep[order[:ndrop]] = False
    return _compact_to_kept(a, np.nonzero(keep)[0], cache)


# --------------------------------------------------------------------------
# transpose
# --------------------------------------------------------------------------


def transpose_permutation(coords: np.ndarray) -> np.ndarray:
    """``perm`` with ``perm[i]`` = source stack index of transposed block i.

    Pure structure: the transposed stack in Morton order pulls block ``i``
    from position ``perm[i]`` of the original stack.  Block Frobenius norms
    are transpose-invariant, so ``norms[perm]`` is the transposed matrix's
    norm table — callers holding a current table (the refinement loop in
    :mod:`repro.dist.inverse`) reuse it without a fresh device fetch.
    """
    return morton_sort(np.asarray(coords)[:, ::-1])


@dataclasses.dataclass(frozen=True)
class _TransposeSpec:
    nparts: int
    offsets: tuple


def _mapped_transpose(store, gidx, gval, *sends, spec):
    allb = _exchange_bufs(store[0], spec.offsets, sends, spec.nparts)
    out = allb[gidx[0]] * gval[0][:, None, None].astype(store.dtype)
    return jnp.transpose(out, (0, 2, 1))[None]


def _relayout_gather_plan(x: DistBSMatrix, out_owner: np.ndarray, src: np.ndarray):
    """Shared exchange-plan assembly of the owner re-layout collectives.

    Output stack position ``o`` lives on device ``out_owner[o]`` and pulls
    source block ``src[o]`` out of A's resident layout: blocks already local
    gather from the store, the rest travel via planned ``ppermute`` rounds
    (:func:`repro.core.schedule.plan_fetch`).  Transpose (``src`` = the
    transpose permutation) and repartition (``src`` = identity) both build
    their executables from this.  Returns ``(out_slot, out_cap, offsets,
    send, send_cnt, gidx, gval)``.
    """
    nparts = x.nparts
    out_slot, out_stores = _owner_slots(out_owner, nparts)
    out_cap = max(max((len(s) for s in out_stores), default=0), 1)
    needs = [
        np.unique(src[out_owner == p]) if np.any(out_owner == p)
        else np.zeros(0, np.int64)
        for p in range(nparts)
    ]
    offsets, send, send_cnt, recv = plan_fetch(x.owner, x.slot, needs, nparts)
    gidx = np.zeros((nparts, out_cap), dtype=np.int32)
    gval = np.zeros((nparts, out_cap), dtype=np.float32)
    for p, s in enumerate(out_stores):
        for local, o in enumerate(s):
            gidx[p, local] = local_fetch_index(
                x.owner, x.slot, offsets, send, recv, x.cap, src[o], p
            )
            gval[p, local] = 1.0
    return out_slot, out_cap, offsets, send, send_cnt, gidx, gval


def _relayout_verify_payload(x, src, out_owner, out_slot, out_cap, offsets,
                             send, send_cnt, gidx, gval, label):
    """Host-side copy of the relayout plan arrays, retained on executables
    so :func:`repro.analysis.verify.verify_value` can re-prove the gather
    at plan-cache admission (the device arrays are unverifiable post-put)."""
    return dict(
        kind="relayout", label=label, nparts=x.nparts,
        x_owner=np.asarray(x.owner), x_slot=np.asarray(x.slot), x_cap=x.cap,
        src=np.asarray(src), out_owner=np.asarray(out_owner),
        out_slot=np.asarray(out_slot), out_cap=out_cap, offsets=offsets,
        send=send, send_cnt=send_cnt, gidx=gidx, gval=gval,
    )


class TransposeExecutable:
    """Planned resident transpose bound to a mesh.

    Every transposed block *inherits its source block's owner* — the cut the
    operand currently has, uniform Morton or dynamically rebalanced, carries
    through unchanged.  That makes the transpose communication-free by
    construction (every gather is local; the planned ``ppermute`` machinery
    degenerates to zero rounds) and, after a rebalance, keeps the balancer's
    weighted cut instead of re-slotting back to the uniform Morton partition
    — which would both pay phantom migration bytes on every transpose and
    silently undo the migration the balancer just paid for.  Block data is
    transposed in the mapped body on gather.
    """

    def __init__(self, a: DistBSMatrix):
        nparts, mesh = a.nparts, a.mesh
        src = transpose_permutation(a.coords)  # out stack pos -> a stack idx
        out_owner = a.owner[src]  # inherit the operand's cut (zero movement)
        out_slot, out_cap, offsets, send, send_cnt, gidx, gval = (
            _relayout_gather_plan(a, out_owner, src)
        )
        self._verify_plan = _relayout_verify_payload(
            a, src, out_owner, out_slot, out_cap, offsets, send, send_cnt,
            gidx, gval, "transpose")
        # per-source true send counts (stats/trace attribution)
        self.sent_blocks = np.zeros(nparts, dtype=np.int64)
        for d in offsets:
            self.sent_blocks += send_cnt[d]

        self.src = src
        self.out_coords = a.coords[src][:, ::-1]
        self.out_owner = out_owner
        self.out_slot = out_slot
        self.out_cap = out_cap
        self.mesh = mesh
        spec = _TransposeSpec(nparts, offsets)
        self._args = [_put(mesh, gidx), _put(mesh, gval)]
        self._sends = [_put(mesh, send[d]) for d in offsets]
        nargs = 1 + len(self._args) + len(self._sends)
        self._mapped = jax.jit(
            shard_map(
                functools.partial(_mapped_transpose, spec=spec),
                mesh=mesh,
                in_specs=tuple(P(AXIS) for _ in range(nargs)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, store):
        return self._mapped(store, *self._args, *self._sends)


def dist_transpose(
    a: DistBSMatrix, cache: PlanCache | None = None
) -> DistBSMatrix:
    """A^T on the resident store; structure-keyed plan, no host gather.

    The result's owner layout inherits A's (each transposed block stays on
    the device that owns its source block), so the transpose is
    communication-free and a rebalanced cut survives it; downstream plan
    keys fingerprint the owner map, so plans re-key automatically.
    """
    tr = tracer_of(cache)
    key = ("transpose", _structure_key(a))
    build = lambda: TransposeExecutable(a)
    with tr.span("dist_transpose", cat="collective", nnzb=a.nnzb):
        exe = cache.get_or_build(key, build) if cache is not None else build()
        with tr.span("dispatch", cat="kernel", op="transpose") as sp:
            store = tr.sync(exe(a.store))
            if tr.enabled:
                blk = a.bs * a.bs * a.store.dtype.itemsize
                shipped = int(exe.sent_blocks.sum())
                sp.args.update(sent_blocks=shipped)
                tr.counter("send_bytes").add(shipped * blk)
                tr.counter("recv_bytes").add(shipped * blk)
                # cost share: blocks each source ships, plus the local gather
                sp.worker_costs = exe.sent_blocks.astype(np.float64) + 1.0
    return DistBSMatrix(
        shape=(a.shape[1], a.shape[0]),
        bs=a.bs,
        coords=exe.out_coords,
        owner=exe.out_owner,
        slot=exe.out_slot,
        cap=exe.out_cap,
        store=store,
        mesh=a.mesh,
    )


# --------------------------------------------------------------------------
# repartition (owner re-layout)
# --------------------------------------------------------------------------


def _mapped_relayout(store, gidx, gval, *sends, spec):
    allb = _exchange_bufs(store[0], spec.offsets, sends, spec.nparts)
    return (allb[gidx[0]] * gval[0][:, None, None].astype(store.dtype))[None]


class RepartitionExecutable:
    """Planned owner re-layout bound to a mesh — the dynamic load balancer's
    data-motion primitive (:mod:`repro.dist.balance`).

    Re-slots every block to a caller-supplied new owner map using the same
    planned ``ppermute``-round machinery as :class:`TransposeExecutable`:
    blocks whose owner is unchanged are gathered from the local store, blocks
    that migrate travel device-to-device in the planned rounds — block
    payloads only, no host round-trip.  Coordinates and stack (Morton) order
    are untouched; slots are reassigned in ascending Morton order within each
    new owner, preserving the layout invariant every planner relies on.
    Downstream plans re-key automatically: every plan-cache key fingerprints
    the owner map, so the first operation after a re-layout plans fresh and
    the cache returns to all-hit once the layout stabilizes.
    """

    def __init__(self, x: DistBSMatrix, new_owner: np.ndarray):
        nparts, mesh = x.nparts, x.mesh
        new_owner = np.asarray(new_owner, dtype=np.int32)
        assert new_owner.shape == (x.nnzb,)
        assert new_owner.size == 0 or (
            new_owner.min() >= 0 and new_owner.max() < nparts
        ), "owner map must assign every block a device id < mesh size"
        src = np.arange(x.nnzb, dtype=np.int64)  # re-layout, not a permutation
        new_slot, new_cap, offsets, send, send_cnt, gidx, gval = (
            _relayout_gather_plan(x, new_owner, src)
        )
        self._verify_plan = _relayout_verify_payload(
            x, src, new_owner, new_slot, new_cap, offsets, send, send_cnt,
            gidx, gval, "repartition")

        self.new_owner = new_owner
        self.new_slot = new_slot
        self.new_cap = new_cap
        self.migrated_blocks = int(np.count_nonzero(new_owner != x.owner))
        # per-source true send counts (stats): only migrating blocks ship
        self.sent_blocks = np.zeros(nparts, dtype=np.int64)
        for d in offsets:
            self.sent_blocks += send_cnt[d]
        self.mesh = mesh
        spec = _TransposeSpec(nparts, offsets)
        self._args = [_put(mesh, gidx), _put(mesh, gval)]
        self._sends = [_put(mesh, send[d]) for d in offsets]
        nargs = 1 + len(self._args) + len(self._sends)
        self._mapped = jax.jit(
            shard_map(
                functools.partial(_mapped_relayout, spec=spec),
                mesh=mesh,
                in_specs=tuple(P(AXIS) for _ in range(nargs)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, store):
        return self._mapped(store, *self._args, *self._sends)


def dist_repartition(
    x: DistBSMatrix,
    new_owner: np.ndarray,
    cache: PlanCache | None = None,
    *,
    stats: dict | None = None,
) -> DistBSMatrix:
    """Re-slot A's blocks to ``new_owner`` entirely on device.

    The resident re-layout collective of the dynamic load-balancing
    subsystem (:mod:`repro.dist.balance`): structure, values and Morton stack
    order are preserved bit-for-bit (``gather()`` before and after are
    identical, and so is the stack-order norm table — block values never
    change, only which device holds them), so a re-layout between iterations
    is invisible to the algorithm and only visible to the schedule.  The
    executable is cached per (structure + old owner, new owner); a no-op map
    (``new_owner == x.owner``) returns ``x`` unchanged without touching the
    cache.

    ``stats``, when a dict, receives ``migrated_blocks`` / ``migrated_bytes``
    (blocks that actually changed owner — the planned rounds ship nothing
    else) and ``sent_blocks_per_worker``.
    """
    new_owner = np.asarray(new_owner, dtype=np.int32)
    if x.nnzb == 0 or np.array_equal(new_owner, x.owner):
        if stats is not None:
            stats["migrated_blocks"] = 0
            stats["migrated_bytes"] = 0
            stats["sent_blocks_per_worker"] = np.zeros(x.nparts, dtype=np.int64)
        return x
    tr = tracer_of(cache)
    key = (
        "repartition",
        _structure_key(x),
        structure_fingerprint(new_owner),
    )
    build = lambda: RepartitionExecutable(x, new_owner)
    blk = x.bs * x.bs * x.store.dtype.itemsize
    with tr.span("dist_repartition", cat="migration", nnzb=x.nnzb) as msp:
        exe = cache.get_or_build(key, build) if cache is not None else build()
        if stats is not None:
            stats["migrated_blocks"] = exe.migrated_blocks
            stats["migrated_bytes"] = exe.migrated_blocks * blk
            stats["sent_blocks_per_worker"] = exe.sent_blocks.copy()
        with tr.span("dispatch", cat="kernel", op="repartition") as sp:
            store = tr.sync(exe(x.store))
            if tr.enabled:
                msp.args.update(migrated_blocks=exe.migrated_blocks)
                tr.counter("migrated_bytes").add(exe.migrated_blocks * blk)
                # cost share: blocks each source ships, plus the local gather
                sp.worker_costs = exe.sent_blocks.astype(np.float64) + 1.0
    return DistBSMatrix(
        shape=tuple(x.shape),
        bs=x.bs,
        coords=x.coords,
        owner=exe.new_owner,
        slot=exe.new_slot,
        cap=exe.new_cap,
        store=store,
        mesh=x.mesh,
    )


# --------------------------------------------------------------------------
# quadrant slice / assemble
# --------------------------------------------------------------------------


def dist_submatrix(
    a: DistBSMatrix,
    r0: int,
    r1: int,
    c0: int,
    c1: int,
    cache: PlanCache | None = None,
) -> DistBSMatrix:
    """Block-range slice a[r0:r1, c0:c1] on the resident store.

    The resident counterpart of :func:`repro.core.inverse.submatrix`: the
    kept set is an owner-local coordinate mask decided on the host, the data
    motion is the shared device-side compaction (:func:`_compact_to_kept`) —
    blocks keep their owners, so slicing moves nothing between devices.
    """
    m = (
        (a.coords[:, 0] >= r0)
        & (a.coords[:, 0] < r1)
        & (a.coords[:, 1] >= c0)
        & (a.coords[:, 1] < c1)
    )
    kept = np.nonzero(m)[0]
    new_coords = a.coords[kept] - np.array([[r0, c0]])
    # quadrant offsets strip a shared Morton prefix, which preserves relative
    # order; re-sort anyway so arbitrary ranges keep the layout invariant
    order = morton_sort(new_coords)
    kept, new_coords = kept[order], new_coords[order]
    rows = min((r1 - r0) * a.bs, max(a.shape[0] - r0 * a.bs, 0))
    cols = min((c1 - c0) * a.bs, max(a.shape[1] - c0 * a.bs, 0))
    return _compact_to_kept(
        a, kept, cache, coords=new_coords, shape=(rows, cols), kind="slice"
    )


def _mapped_assemble(s0, s1, s2, s3, gidx, gval):
    allb = jnp.concatenate([s0[0], s1[0], s2[0], s3[0]], axis=0)
    return (allb[gidx[0]] * gval[0][:, None, None].astype(allb.dtype))[None]


class AssembleExecutable:
    """Planned 2x2 quadrant glue bound to a mesh.

    Every output block is one quadrant's block on the device that already
    owns it — the local buffer is just the four quadrant stores concatenated
    — so assembly performs zero inter-device communication; only the merged
    slot maps are rebuilt on the host.
    """

    def __init__(self, quads, offsets_rc, mesh):
        nparts = int(mesh.devices.size)
        coords, owner, src_q, src_i = [], [], [], []
        for qi, (q, (dr, dc)) in enumerate(zip(quads, offsets_rc)):
            if q.nnzb:
                coords.append(q.coords + np.array([[dr, dc]]))
                owner.append(q.owner)
                src_q.append(np.full(q.nnzb, qi, dtype=np.int64))
                src_i.append(np.arange(q.nnzb, dtype=np.int64))
        if coords:
            coords = np.concatenate(coords)
            owner = np.concatenate(owner)
            src_q = np.concatenate(src_q)
            src_i = np.concatenate(src_i)
        else:
            coords = np.zeros((0, 2), dtype=np.int64)
            owner = np.zeros((0,), dtype=np.int32)
            src_q = src_i = np.zeros((0,), dtype=np.int64)
        order = morton_sort(coords)
        coords, owner = coords[order], owner[order]
        src_q, src_i = src_q[order], src_i[order]
        out_slot, out_stores = _owner_slots(owner, nparts)
        out_cap = max(max((len(s) for s in out_stores), default=0), 1)

        base = np.concatenate([[0], np.cumsum([q.cap for q in quads])])[:-1]
        gidx = np.zeros((nparts, out_cap), dtype=np.int32)
        gval = np.zeros((nparts, out_cap), dtype=np.float32)
        for p, s in enumerate(out_stores):
            for local, o in enumerate(s):
                q = quads[src_q[o]]
                gidx[p, local] = base[src_q[o]] + q.slot[src_i[o]]
                gval[p, local] = 1.0

        self.out_coords = coords
        self.out_owner = owner
        self.out_slot = out_slot
        self.out_cap = out_cap
        self._args = [_put(mesh, gidx), _put(mesh, gval)]
        self._mapped = jax.jit(
            shard_map(
                _mapped_assemble,
                mesh=mesh,
                in_specs=tuple(P(AXIS) for _ in range(6)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        )

    def __call__(self, *stores):
        return self._mapped(*stores, *self._args)


def dist_assemble2x2(
    a00: DistBSMatrix,
    a01: DistBSMatrix,
    a10: DistBSMatrix,
    a11: DistBSMatrix,
    split: int,
    cache: PlanCache | None = None,
) -> DistBSMatrix:
    """Glue four resident quadrants at block offset ``split``.

    Inverse of :func:`dist_submatrix` over a quadtree split; blocks keep
    their owners, so nothing moves between devices (empty quadrants — the
    zero branches of the factorization — contribute padding only).
    """
    quads = (a00, a01, a10, a11)
    bs = a00.bs
    assert all(q.bs == bs for q in quads)
    shape = (a00.shape[0] + a11.shape[0], a00.shape[1] + a11.shape[1])
    offsets_rc = ((0, 0), (0, split), (split, 0), (split, split))
    key = (
        "assemble",
        tuple(_structure_key(q) for q in quads),
        tuple(tuple(q.shape) for q in quads),
        int(split),
    )
    build = lambda: AssembleExecutable(quads, offsets_rc, a00.mesh)
    exe = cache.get_or_build(key, build) if cache is not None else build()
    dtype = jnp.result_type(*(q.dtype for q in quads))
    store = exe(*(q.store.astype(dtype) for q in quads))
    return DistBSMatrix(
        shape=shape,
        bs=bs,
        coords=exe.out_coords,
        owner=exe.out_owner,
        slot=exe.out_slot,
        cap=exe.out_cap,
        store=store,
        mesh=a00.mesh,
    )


def dist_truncate_hierarchical(
    a: DistBSMatrix,
    tau: float,
    cache: PlanCache | None = None,
    *,
    norms: np.ndarray | None = None,
    stats: dict | None = None,
) -> DistBSMatrix:
    """Truncate by dropping whole quadtree subtrees first — resident variant.

    Builds a :class:`~repro.core.quadtree.QuadtreeIndex` from the resident
    per-block norm table (one tiny [P, cap] device->host transfer, or zero
    when ``norms`` is supplied by a caller that already fetched it) and runs
    the same top-down subtree-drop descent as
    :func:`repro.core.truncate.truncate_hierarchical` — identical kept set on
    identical inputs, same global guarantee ``||A - T(A)||_F <= tau``, and a
    subtree dropped at level L is removed without its leaves ever being
    enumerated.  Survivors are compacted device-side keeping their owners, so
    no block data moves between devices.

    ``stats``, when a dict, receives ``nodes_visited`` (frontier nodes whose
    norms the descent examined) and ``kept`` (surviving stack indices) — the
    SP2 driver uses ``kept`` to carry the norm table forward to the next
    iteration's SpAMM without a fresh fetch.
    """
    if stats is not None:
        stats["nodes_visited"] = 0
        stats["kept"] = np.arange(a.nnzb, dtype=np.int64)
    if a.nnzb == 0 or tau <= 0:
        return a
    if norms is None:
        # outside the symbolic timer: a miss on the fused norm executable is
        # timed into cache.build_s by get_or_build
        norms = resident_block_norms(a, cache)
    with timed_into(cache, "symbolic_s", tracer_of(cache), "hierarchical_drop",
                    cat="symbolic", nnzb=a.nnzb):
        depth = quadtree_depth(-(-a.shape[0] // a.bs), -(-a.shape[1] // a.bs))
        qt = build_quadtree_index(a.coords, norms, depth=depth)
        keep, visited = hierarchical_drop_mask(qt, tau)
    if stats is not None:
        stats["nodes_visited"] = visited
    if keep.all():
        return a
    kept = np.nonzero(keep)[0]
    if stats is not None:
        stats["kept"] = kept
    return _compact_to_kept(a, kept, cache)
