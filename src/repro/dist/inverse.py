"""Device-resident inverse factorization (paper §2.2) — repro.dist.inverse.

The multiplication-heavy workload that motivates the whole quadtree design,
run end-to-end on the resident runtime: find Z with Z^T A Z = I for SPD A
without the iterates ever leaving the worker mesh.

* :func:`dist_inv_chol` — recursive inverse Cholesky over the quadtree
  split.  Quadrants are carved out of the resident store with
  :func:`~repro.dist.collectives.dist_submatrix` (owner-local masks, no
  inter-device motion), every Schur step is a resident
  transpose/multiply/add, and the recursion bottoms out in a dense lapack
  factorization of the tiny leaf (the one boundary crossing, exactly like
  the host path's leaf).
* :func:`dist_localized_inverse_factorization` — divide-and-conquer:
  factorize the two diagonal quadrants independently, glue them with
  :func:`~repro.dist.collectives.dist_assemble2x2`, then correct the
  coupling by iterative refinement Z <- Z(I + delta/2), delta = I - Z^T A Z.
  The refinement loop is the hot path and runs entirely through the cached
  planners: ``dist_spamm(method="delta")`` multiplies and
  ``dist_truncate_hierarchical`` error control share one norm-table fetch
  per iteration (the transposed iterate's norms are a host-side permutation
  of the same table — block norms are transpose-invariant), and once the
  sparsity pattern stabilizes an iteration incurs *zero* plan-cache misses —
  the same discipline as ``dist_sp2_purify``.

Convergence policy (:class:`repro.core.inverse.RefineMonitor`) is shared
with the host driver, so both stop on the identical criterion.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.add import identity
from repro.core.inverse import (
    RefineMonitor,
    _dense_inv_chol,
    assemble2x2,
    factorization_residual,
    submatrix,
)
from repro.core.matrix import BSMatrix
from repro.core.schedule import plan_stats
from repro.kernels.precision import Precision
from repro.obs.health import HealthMonitor, HealthPolicy
from repro.obs.locality import locality_iteration, locality_snapshot
from repro.obs.log import log_of
from repro.obs.timing import IterationScope
from repro.obs.tracer import run_metrics, tracer_of

from .balance import (
    LoadMonitor,
    RebalancePolicy,
    block_reference_weights,
    map_block_weights,
    measure_iteration_load,
    peek_last_plan,
)
from .cache import PlanCache
from .collectives import (
    dist_add,
    dist_assemble2x2,
    dist_frobenius_norm,
    dist_submatrix,
    dist_transpose,
    dist_truncate_hierarchical,
    transpose_permutation,
)
from .matrix import DistBSMatrix, dist_zeros, resident_block_norms, scatter
from .multiply import dist_multiply, dist_spamm

__all__ = [
    "dist_inv_chol",
    "dist_localized_inverse_factorization",
    "DistInverseStats",
]


@dataclasses.dataclass
class DistInverseStats:
    """Per-run and per-iteration metrics of the resident refinement loop.

    Mirrors :class:`~repro.dist.purify.DistPurifyStats`: ``per_iter`` rows
    carry the plan-cache hit/miss deltas, planning/symbolic seconds, the
    executed multiply plan's mean received bytes per worker, the bytes of
    the shared norm-table fetch, and the SpAMM error bound of that
    iteration's multiplies.  ``factorization_residual`` is the residual of
    the returned (best) iterate.
    """

    iterations: int
    residual_history: list
    factorization_residual: float
    nnzb_history: list
    cache: dict  # run_metrics(cache) at exit: PlanCache.stats() keys plus
    # every tracer counter/gauge when tracing was enabled
    per_iter: list  # shared-schema rows (repro.obs.timing.SHARED_ITER_KEYS
    # plus the refinement residual)
    rebalances: int = 0  # re-layouts performed by the rebalance= policy
    # wall-clock calibration of the rebalance policy's cost coefficients
    # (repro.dist.balance.calibrate_policy report); None without rebalance=
    calibration: dict | None = None
    # HealthMonitor.summary() when health monitoring was on; None otherwise
    health: dict | None = None


def _leaf_ranges(nbr: int, leaf_blocks: int, base: int = 0) -> list[tuple[int, int]]:
    """Block-row ranges the inv_chol recursion's leaves cover, in descent
    order (power-of-2 split, same as the recursion itself)."""
    if nbr <= leaf_blocks:
        return [(base, base + nbr)]
    split = 1 << (int(np.ceil(np.log2(nbr))) - 1)
    return _leaf_ranges(split, leaf_blocks, base) + _leaf_ranges(
        nbr - split, leaf_blocks, base + split
    )


def _leaf_block_diagonal(coords: np.ndarray, ranges: list[tuple[int, int]]) -> bool:
    """True when every nonzero block lies inside some diagonal leaf square —
    then all inv_chol leaves are independent and can factorize as one batch."""
    if coords.shape[0] == 0:
        return True
    starts = np.array([lo for lo, _ in ranges] + [ranges[-1][1]], dtype=np.int64)
    leaf = np.searchsorted(starts, coords[:, 0], side="right") - 1
    return bool(
        np.all(
            (coords[:, 1] >= starts[leaf]) & (coords[:, 1] < starts[leaf + 1])
        )
    )


def _batched_leaf_inv_chol(
    a: DistBSMatrix, ranges: list[tuple[int, int]], leaf_blocks: int, cache
) -> DistBSMatrix:
    """All leaves independent: ONE gather, size-grouped batched dense
    factorizations, ONE scatter — instead of the recursion's per-leaf
    gather/factorize/scatter Python loop.

    numpy's stacked ``cholesky`` / ``solve`` run the same lapack routine per
    matrix in the batch, so each leaf's factor is bit-identical to what the
    per-leaf :func:`~repro.core.inverse._dense_inv_chol` produces.
    """
    host = a.gather()
    out_dtype = np.asarray(host.data).dtype if host.nnzb else np.float32
    leaves = [submatrix(host, lo, hi, lo, hi) for lo, hi in ranges]
    denses = [np.asarray(lf.to_dense(), dtype=np.float64) for lf in leaves]
    z_dense: list[np.ndarray | None] = [None] * len(leaves)
    by_shape: dict[tuple, list[int]] = {}
    for i, d in enumerate(denses):
        by_shape.setdefault(d.shape, []).append(i)
    for shape, idxs in by_shape.items():
        stack = np.stack([denses[i] for i in idxs])
        L = np.linalg.cholesky(stack)
        eye = np.broadcast_to(np.eye(shape[0]), stack.shape)
        z = np.linalg.solve(np.swapaxes(L, -1, -2), eye)  # L^{-T}, batched
        for j, i in enumerate(idxs):
            z_dense[i] = z[j]
    leaf_z = [
        BSMatrix.from_dense(z.astype(out_dtype), a.bs) for z in z_dense
    ]
    # rebuild the recursion's assemble2x2 nesting over the precomputed
    # leaves so the result's block structure matches the unbatched path
    ptr = [0]

    def nest(lo: int, hi: int) -> BSMatrix:
        nbr = hi - lo
        if nbr <= leaf_blocks:
            z = leaf_z[ptr[0]]
            ptr[0] += 1
            return z
        split = 1 << (int(np.ceil(np.log2(nbr))) - 1)
        z00 = nest(lo, lo + split)
        z11 = nest(lo + split, hi)
        zero01 = BSMatrix.zeros((z00.shape[0], z11.shape[1]), a.bs, out_dtype)
        zero10 = BSMatrix.zeros((z11.shape[0], z00.shape[1]), a.bs, out_dtype)
        return assemble2x2(z00, zero01, zero10, z11, split)

    return scatter(nest(0, -(-a.shape[0] // a.bs)), a.mesh)


def dist_inv_chol(
    a: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    leaf_blocks: int = 1,
    exchange: str = "p2p",
    impl: str = "fused",
    precision: Precision | None = None,
    batch_leaves: bool = True,
) -> DistBSMatrix:
    """Recursive inverse Cholesky on the resident store.  Z^T A Z = I.

    Identical recursion (and identical block structure — tested) to
    :func:`repro.core.inverse.inv_chol`:
      Z00 = invchol(A00);  W = A01^T Z00;  S = A11 - W W^T;
      Z11 = invchol(S);    Z01 = -Z00 W^T Z11,
    with every step a resident collective.  Leaves (<= ``leaf_blocks`` block
    rows) gather to the host for the dense lapack factorization and scatter
    straight back — the only boundary crossings, same as the host path.

    Two structural fast paths (both value-preserving):

    * an empty coupling quadrant A01 skips the W / Schur multiplies outright
      (S = A11, Z01 = 0) instead of multiplying empty structures;
    * ``batch_leaves`` (default on): when every nonzero block of the current
      submatrix lies inside a diagonal leaf square, the remaining descent
      is pure bookkeeping — the leaves gather in ONE boundary crossing,
      factorize as size-grouped *batched* dense cholesky/solve calls, and
      scatter back in one crossing, replacing the per-leaf Python loop.
    """
    nbr = -(-a.shape[0] // a.bs)
    if nbr <= leaf_blocks:
        return scatter(_dense_inv_chol(a.gather()), a.mesh)
    if batch_leaves:
        ranges = _leaf_ranges(nbr, leaf_blocks)
        if len(ranges) > 1 and _leaf_block_diagonal(a.coords, ranges):
            with tracer_of(cache).span(
                "inv_chol_batched_leaves", cat="collective",
                nbr=int(nbr), leaves=len(ranges),
            ):
                return _batched_leaf_inv_chol(a, ranges, leaf_blocks, cache)
    kw = dict(
        leaf_blocks=leaf_blocks, exchange=exchange, impl=impl,
        precision=precision, batch_leaves=batch_leaves,
    )
    mkw = dict(exchange=exchange, impl=impl, precision=precision)
    with tracer_of(cache).span("inv_chol", cat="collective", nbr=int(nbr)):
        depth = int(np.ceil(np.log2(nbr)))
        split = 1 << (depth - 1)
        a00 = dist_submatrix(a, 0, split, 0, split, cache)
        a01 = dist_submatrix(a, 0, split, split, nbr, cache)
        a11 = dist_submatrix(a, split, nbr, split, nbr, cache)
        z00 = dist_inv_chol(a00, cache, **kw)
        if a01.nnzb == 0:
            # no coupling between the quadrants: S = A11 and Z01 = 0 exactly
            z11 = dist_inv_chol(a11, cache, **kw)
            zero01 = dist_zeros(
                (a00.shape[0], a11.shape[1]), a.bs, a.mesh, a.dtype
            )
            zero10 = dist_zeros(
                (a11.shape[0], a00.shape[1]), a.bs, a.mesh, a.dtype
            )
            return dist_assemble2x2(z00, zero01, zero10, z11, split, cache)
        w = dist_multiply(
            dist_transpose(a01, cache), z00, cache, **mkw
        )  # [n1, n0]
        wt = dist_transpose(w, cache)  # shared by Schur and coupling steps
        s = dist_add(
            a11, dist_multiply(w, wt, cache, **mkw), 1.0, -1.0, cache,
        )
        z11 = dist_inv_chol(s, cache, **kw)
        z01 = dist_multiply(
            dist_multiply(z00, wt, cache, **mkw), z11, cache, **mkw
        ).scale(-1.0)
        zero = dist_zeros((a11.shape[0], a00.shape[1]), a.bs, a.mesh, a.dtype)
        return dist_assemble2x2(z00, z01, zero, z11, split, cache)


def dist_localized_inverse_factorization(
    a: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    tol: float = 1e-8,
    max_iter: int = 100,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    spamm_method: str = "delta",
    leaf_blocks: int = 1,
    exchange: str = "p2p",
    impl: str = "fused",
    precision: Precision | None = None,
    batch_leaves: bool = True,
    rebalance: RebalancePolicy | None = None,
    tracer=None,
    log=None,
    health: HealthPolicy | None = None,
) -> tuple[DistBSMatrix, DistInverseStats]:
    """Divide-and-conquer inverse factorization, resident end to end.

    The two diagonal quadrants factorize independently
    (:func:`dist_inv_chol`), the block-diagonal Z is glued resident, and the
    refinement Z <- Z(I + delta/2) runs through the cached planners:

    * ``spamm_tau > 0`` routes every refinement multiply through
      ``dist_spamm(method="delta")`` — the prune pattern is a task mask over
      the structure-keyed full plan, so a fluctuating ``tau``-prune never
      misses the plan cache;
    * ``trunc_tau > 0`` truncates the iterate with the hierarchical
      subtree-drop descent, and its norm table is carried into the next
      iteration's SpAMM (the transposed operand reuses the same table via
      :func:`~repro.dist.collectives.transpose_permutation` — block norms
      are transpose-invariant), so one fetch serves the whole iteration.

    Convergence/divergence policy is the shared
    :class:`~repro.core.inverse.RefineMonitor`; the best iterate is
    returned resident with :class:`DistInverseStats`.

    ``rebalance`` (a :class:`~repro.dist.balance.RebalancePolicy`) turns on
    dynamic load balancing.  The pinned SPD operand ``a`` is the classic
    skew trap — its layout never changes, so a skewed scatter makes one
    worker ship its blocks every refinement multiply forever; when its
    ownership imbalance exceeds the threshold it is re-laid out once,
    up-front, on device.  The iterate Z is then measured and re-laid out
    between iterations exactly like the SP2 driver, with ``imbalance`` /
    ``imbalance_after`` / ``migrated_bytes`` per-iteration rows.
    """
    cache = cache if cache is not None else PlanCache()
    if tracer is not None:
        cache.tracer = tracer
    if log is not None:
        cache.event_log = log
    trc = tracer_of(cache)
    lg = log_of(cache)
    hm = HealthMonitor(health, cache=cache) if health is not None else None
    rec = getattr(cache, "flight_recorder", None)
    if lg.enabled:
        lg.info(
            "run_start", driver="inverse_factorization", n=int(a.shape[0]),
            max_iter=int(max_iter), tol=float(tol),
            trunc_tau=float(trunc_tau), spamm_tau=float(spamm_tau),
        )
    with trc.span("inverse_factorization", cat="phase", n=int(a.shape[0])):
        lb = LoadMonitor(a.nparts, rebalance) if rebalance is not None else None
        upfront_migrated = 0
        if lb is not None:
            # the pinned operand's layout is never revisited by the
            # iteration: a skewed scatter would make one worker ship its
            # store every refinement multiply forever — fix it once,
            # up-front, on device (its bytes land in iteration 0's row)
            a, upfront_migrated = lb.relayout_if_skewed(a, cache)
        nbr = -(-a.shape[0] // a.bs)
        if nbr <= leaf_blocks:
            host_a = a.gather()
            z_host = _dense_inv_chol(host_a)
            return scatter(z_host, a.mesh), DistInverseStats(
                0, [], factorization_residual(host_a, z_host, impl="ref"),
                [z_host.nnzb], run_metrics(cache), [],
            )
        depth = int(np.ceil(np.log2(nbr)))
        split = 1 << (depth - 1)
        a00 = dist_submatrix(a, 0, split, 0, split, cache)
        a11 = dist_submatrix(a, split, nbr, split, nbr, cache)
        kw = dict(
            leaf_blocks=leaf_blocks, exchange=exchange, impl=impl,
            precision=precision, batch_leaves=batch_leaves,
        )
        z00 = dist_inv_chol(a00, cache, **kw)
        z11 = dist_inv_chol(a11, cache, **kw)
        zero01 = dist_zeros((z00.shape[0], z11.shape[1]), a.bs, a.mesh, a.dtype)
        zero10 = dist_zeros((z11.shape[0], z00.shape[1]), a.bs, a.mesh, a.dtype)
        z = dist_assemble2x2(z00, zero01, zero10, z11, split, cache)

        eye = scatter(identity(a.shape[0], a.bs, a.dtype), a.mesh)
        # the SPD operand's norms never change: one fetch serves all
        # iterations
        a_norms = resident_block_norms(a, cache) if spamm_tau > 0 else None
        monitor = RefineMonitor(tol)
        best = z
        history: list[float] = []
        nnzbs: list[int] = []
        per_iter: list[dict] = []
        z_norms = None  # stack-order norm table of z, carried from truncation
        for it in range(max_iter):
            if rec is not None:
                rec.mark(cache)
            with IterationScope(cache, it, trc, name="inv_iteration") as scope:
                lsnap = locality_snapshot(cache)
                z_op = z  # the iterate the refinement multiplies read
                mult_err = 0.0
                norm_fetch_bytes = 0
                # measured per-worker cost accumulates over BOTH residual
                # multiplies — the (zt)a plan is where a pinned skewed
                # operand shows up
                leaf_w = (
                    (z_norms != 0.0).astype(np.float64)
                    if z_norms is not None
                    else None
                )
                a_leaf_w = (
                    (a_norms != 0.0).astype(np.float64)
                    if a_norms is not None
                    else None
                )
                if spamm_tau > 0:
                    zt = dist_transpose(z, cache)
                    zt_norms = (
                        z_norms[transpose_permutation(z.coords)]
                        if z_norms is not None
                        else None
                    )
                    za, e1 = dist_spamm(
                        zt, a, spamm_tau, cache, exchange=exchange, impl=impl,
                        method=spamm_method, precision=precision,
                        a_norms=zt_norms, b_norms=a_norms,
                    )
                    load_zta = measure_iteration_load(
                        cache, peek_last_plan(cache), None, a_leaf_w
                    )
                    zaz, e2 = dist_spamm(
                        za, z, spamm_tau, cache, exchange=exchange, impl=impl,
                        method=spamm_method, precision=precision,
                        b_norms=z_norms,
                    )
                    mult_err = max(e1, e2)
                else:
                    zt = dist_transpose(z, cache)
                    za = dist_multiply(
                        zt, a, cache, exchange=exchange, impl=impl,
                        precision=precision,
                    )
                    load_zta = measure_iteration_load(
                        cache, peek_last_plan(cache), None, a_leaf_w
                    )
                    zaz = dist_multiply(
                        za, z, cache, exchange=exchange, impl=impl,
                        precision=precision,
                    )
                plan = peek_last_plan(cache)  # (za)z plan: recv stats + z weights
                load = measure_iteration_load(cache, plan, None, leaf_w)
                if load is None:
                    # the (za)z multiply built no plan (e.g. its full task
                    # list is empty): the (zt)a measurement still counts — a
                    # skewed pinned operand must not go unreported
                    load = load_zta
                elif load_zta is not None:
                    load = load + load_zta
                imb = None
                if load is not None:
                    imb = lb.observe(load) if lb is not None else load.imbalance()
                delta = dist_add(eye, zaz, 1.0, -1.0, cache)
                r = dist_frobenius_norm(delta, cache)
                history.append(r)
                nnzbs.append(z.nnzb)
                nnzb_it = z.nnzb
                stop = monitor.update(it, r)
                if stop and monitor.stop_reason == "diverged":
                    if lg.enabled:
                        lg.warn(
                            "refine_divergence", iteration=it,
                            residual=float(r), best_r=float(monitor.best_r),
                            best_iter=int(monitor.best_iter),
                        )
                    if trc.enabled:
                        trc.instant(
                            "refine_divergence", cat="health", iteration=it,
                            residual=float(r), best_r=float(monitor.best_r),
                        )
                    if rec is not None:
                        rec.dump(
                            "refine_divergence", cache, iteration=it,
                            residual=float(r), best_r=float(monitor.best_r),
                            best_iter=int(monitor.best_iter),
                        )
                if monitor.improved:
                    best = z
                if not stop:
                    step = dist_add(eye, delta, 1.0, 0.5, cache)  # I + delta/2
                    if spamm_tau > 0:
                        z, e3 = dist_spamm(
                            z, step, spamm_tau, cache,
                            exchange=exchange, impl=impl,
                            method=spamm_method, precision=precision,
                            a_norms=z_norms,
                        )
                        mult_err = max(mult_err, e3)
                    else:
                        z = dist_multiply(
                            z, step, cache, exchange=exchange, impl=impl,
                            precision=precision,
                        )
                    z_norms = None
                    if trunc_tau > 0:
                        # one norm-table fetch serves the truncation descent
                        # and the next iteration's SpAMM (both orientations
                        # of Z)
                        pre_norms = resident_block_norms(z, cache)
                        norm_fetch_bytes = pre_norms.shape[0] * 4
                        info: dict = {}
                        z = dist_truncate_hierarchical(
                            z, trunc_tau, cache, norms=pre_norms, stats=info
                        )
                        z_norms = pre_norms[info["kept"]]
                imb_after, migrated = None, upfront_migrated
                upfront_migrated = 0
                if (
                    lb is not None
                    and not stop
                    and load is not None
                    and lb.should_rebalance(load)
                    and plan is not None
                ):
                    # measured per-block weights for the iterate: its
                    # reference counts as the b operand of the executed (za)z
                    # plan plus one unit of ownership, mapped onto the
                    # updated structure
                    _, wb = block_reference_weights(
                        plan.tasks, plan.a_owner.shape[0], z_op.nnzb
                    )
                    w = map_block_weights(
                        z_op.coords, wb + 1.0, z.coords, default=1.0
                    )
                    # z_norms is stack-ordered, so it survives the re-layout
                    z, moved, imb_after = lb.migrate(z, w, cache)
                    migrated += moved
                row = scope.row(
                    nnzb=nnzb_it,
                    residual=r,
                    spamm_err=mult_err,
                    recv_bytes_mean=(
                        plan_stats(plan)["recv_bytes_mean"]
                        if plan is not None
                        else 0.0
                    ),
                    norm_fetch_bytes=norm_fetch_bytes,
                    imbalance=imb,
                    imbalance_after=imb_after,
                    migrated_bytes=migrated,
                    **locality_iteration(cache, scope, lsnap,
                                         iteration=it, driver="inverse"),
                )
                per_iter.append(row)
                if lb is not None and load is not None:
                    # wall-clock feedback: the measured iteration time
                    # calibrates the policy's cost coefficients
                    lb.note_wall(row["wall_s"])
                if lg.debug_enabled:
                    lg.debug(
                        "iteration", driver="inverse",
                        **{k: row[k] for k in (
                            "iteration", "nnzb", "residual", "wall_s",
                            "cache_hits", "cache_misses", "recv_bytes_mean",
                        )},
                    )
                if hm is not None:
                    hm.observe(row, load)
                    hm.maybe_refit(lb)
            if stop:
                break
    if lg.enabled:
        lg.info(
            "run_end", driver="inverse_factorization",
            iterations=len(history), stop_reason=monitor.stop_reason,
            best_r=float(monitor.best_r), nnzb=int(best.nnzb),
        )
    return best, DistInverseStats(
        len(history), history, monitor.best_r, nnzbs, run_metrics(cache),
        per_iter,
        rebalances=lb.rebalances if lb is not None else 0,
        calibration=lb.calibration()[1] if lb is not None else None,
        health=hm.summary() if hm is not None else None,
    )
