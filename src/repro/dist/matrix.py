"""Device-resident distributed block-sparse matrix.

:class:`DistBSMatrix` is the persistent distributed object the CHT runtime
keeps in worker chunk storage: the *values* live sharded across a 1-D worker
mesh as one padded per-device store ``[P, cap, bs, bs]`` and STAY there
across operations; the *structure* (Morton-sorted block coords plus the
owner / slot placement maps) lives on the host where all symbolic decisions
are made.  A matrix enters the mesh once via :func:`scatter` and leaves only
at the algorithm boundary via :meth:`DistBSMatrix.gather` — iterative
algorithms (``repro.dist.purify``) never ship operand blocks from the host
between operations.

Layout invariants (relied on by every planner in this package):

* ``owner[g]`` is the device holding global block ``g``; ``slot[g]`` is its
  row in that device's store, and slots are assigned in ascending global
  (Morton) order within each owner — exactly
  :func:`repro.core.schedule._owner_slots`.
* ``cap == max(blocks per device, 1)``; store rows past a device's last
  valid slot are padding with UNSPECIFIED content (kernel trash rows) — every
  consumer masks by validity rather than assuming zeros.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import AXIS, make_worker_mesh
from repro.core.matrix import BSMatrix, block_frobenius_norms
from repro.core.quadtree import morton_encode
from repro.core.schedule import _owner_slots, partition_morton

__all__ = ["DistBSMatrix", "scatter", "mesh_key", "resident_block_norms"]


def mesh_key(mesh: Mesh) -> tuple:
    """Device identity of a mesh — part of every plan-cache key, so a shared
    PlanCache never replays an executable jitted for a different mesh."""
    return tuple(int(d.id) for d in mesh.devices.flat)


def _store_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(AXIS))


@dataclasses.dataclass(frozen=True)
class DistBSMatrix:
    """Sharded block-sparse matrix resident on a worker mesh.

    Attributes:
      shape:  logical (rows, cols).
      bs:     leaf block size.
      coords: host [nnzb, 2] block (row, col), Morton sorted.
      owner:  host [nnzb] int32 — device holding each block.
      slot:   host [nnzb] int32 — row within the owner's store.
      cap:    store rows per device (max blocks on any device, >= 1).
      store:  device [P, cap, bs, bs], sharded over the mesh's worker axis;
              rows past a device's valid count are unspecified padding.
      mesh:   the worker mesh the store lives on.
    """

    shape: tuple[int, int]
    bs: int
    coords: np.ndarray
    owner: np.ndarray
    slot: np.ndarray
    cap: int
    store: jax.Array
    mesh: Mesh

    def __post_init__(self):
        assert self.coords.ndim == 2 and self.coords.shape[1] == 2
        assert self.owner.shape == self.slot.shape == (self.coords.shape[0],)
        assert self.store.shape == (
            self.nparts,
            self.cap,
            self.bs,
            self.bs,
        ), (self.store.shape, self.nparts, self.cap, self.bs)

    @property
    def nnzb(self) -> int:
        return int(self.coords.shape[0])

    @property
    def nparts(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def dtype(self):
        return self.store.dtype

    def codes(self) -> np.ndarray:
        return morton_encode(self.coords[:, 0], self.coords[:, 1])

    def store_maps(self) -> tuple[np.ndarray, np.ndarray]:
        """(store_idx [P, cap] global block per slot, store_valid [P, cap])."""
        idx = np.zeros((self.nparts, self.cap), dtype=np.int32)
        valid = np.zeros((self.nparts, self.cap), dtype=bool)
        idx[self.owner, self.slot] = np.arange(self.nnzb, dtype=np.int32)
        valid[self.owner, self.slot] = True
        return idx, valid

    # -- boundary conversions ----------------------------------------------
    def gather(self) -> BSMatrix:
        """Pull the matrix back to a host-structured BSMatrix (boundary op)."""
        host = np.asarray(self.store)
        data = host[self.owner, self.slot] if self.nnzb else host[:0, 0]
        return BSMatrix(
            shape=tuple(self.shape),
            bs=self.bs,
            coords=self.coords,
            data=jnp.asarray(data),
        )

    # -- device-local ops ---------------------------------------------------
    def scale(self, alpha) -> "DistBSMatrix":
        """alpha * A; elementwise on the resident store, stays sharded."""
        return dataclasses.replace(
            self, store=self.store * jnp.asarray(alpha, self.dtype)
        )

    def astype(self, dtype) -> "DistBSMatrix":
        return dataclasses.replace(self, store=self.store.astype(dtype))


def resident_block_norms(x: DistBSMatrix) -> np.ndarray:
    """Per-block Frobenius norms in stack order from the resident store.

    Runs :func:`repro.core.matrix.block_frobenius_norms` — the exact kernel
    the host path uses, same accumulation dtype — on the ``[P, cap, bs, bs]``
    store; only the tiny ``[P, cap]`` norm table crosses device->host (the
    block data stays resident).  Host and resident SpAMM / hierarchical
    truncation therefore make identical prune decisions near ``tau``.
    """
    if x.nnzb == 0:
        return np.zeros((0,), dtype=np.float64)
    table = np.asarray(block_frobenius_norms(x.store))  # [P, cap] -> host
    return table[x.owner, x.slot].astype(np.float64)


def scatter(
    a: BSMatrix,
    mesh: Mesh | None = None,
    *,
    owner: np.ndarray | None = None,
) -> DistBSMatrix:
    """Ship a host BSMatrix onto the mesh once; default Morton placement.

    The inverse of :meth:`DistBSMatrix.gather`.  ``owner`` pins an explicit
    placement (must assign every block a device id < mesh size).
    """
    mesh = mesh or make_worker_mesh()
    nparts = int(mesh.devices.size)
    if owner is None:
        owner = partition_morton(a.nnzb, nparts)
    owner = np.asarray(owner, dtype=np.int32)
    assert owner.shape == (a.nnzb,)
    slot, stores = _owner_slots(owner, nparts)
    cap = max(max((len(s) for s in stores), default=0), 1)
    host = np.zeros((nparts, cap, a.bs, a.bs), dtype=np.asarray(a.data).dtype)
    data = np.asarray(a.data)
    for p, s in enumerate(stores):
        host[p, : len(s)] = data[s]
    store = jax.device_put(jnp.asarray(host), _store_sharding(mesh))
    return DistBSMatrix(
        shape=tuple(a.shape),
        bs=a.bs,
        coords=a.coords,
        owner=owner,
        slot=slot,
        cap=cap,
        store=store,
        mesh=mesh,
    )
