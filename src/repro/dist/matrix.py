"""Device-resident distributed block-sparse matrix.

:class:`DistBSMatrix` is the persistent distributed object the CHT runtime
keeps in worker chunk storage: the *values* live sharded across a 1-D worker
mesh as one padded per-device store ``[P, cap, bs, bs]`` and STAY there
across operations; the *structure* (Morton-sorted block coords plus the
owner / slot placement maps) lives on the host where all symbolic decisions
are made.  A matrix enters the mesh once via :func:`scatter` and leaves only
at the algorithm boundary via :meth:`DistBSMatrix.gather` — iterative
algorithms (``repro.dist.purify``) never ship operand blocks from the host
between operations.

Layout invariants (relied on by every planner in this package):

* ``owner[g]`` is the device holding global block ``g``; ``slot[g]`` is its
  row in that device's store, and slots are assigned in ascending global
  (Morton) order within each owner — exactly
  :func:`repro.core.schedule._owner_slots`.
* ``cap == max(blocks per device, 1)``; store rows past a device's last
  valid slot are padding with UNSPECIFIED content (kernel trash rows) — every
  consumer masks by validity rather than assuming zeros.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import AXIS, make_worker_mesh
from repro.core.matrix import BSMatrix, block_frobenius_norms
from repro.core.quadtree import morton_encode, structure_fingerprint
from repro.core.schedule import _owner_slots, partition_morton
from repro.jax_compat import shard_map

__all__ = [
    "DistBSMatrix",
    "scatter",
    "dist_zeros",
    "mesh_key",
    "resident_block_norms",
]


def mesh_key(mesh: Mesh) -> tuple:
    """Device identity of a mesh — part of every plan-cache key, so a shared
    PlanCache never replays an executable jitted for a different mesh."""
    return tuple(int(d.id) for d in mesh.devices.flat)


def _store_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(AXIS))


@dataclasses.dataclass(frozen=True)
class DistBSMatrix:
    """Sharded block-sparse matrix resident on a worker mesh.

    Attributes:
      shape:  logical (rows, cols).
      bs:     leaf block size.
      coords: host [nnzb, 2] block (row, col), Morton sorted.
      owner:  host [nnzb] int32 — device holding each block.
      slot:   host [nnzb] int32 — row within the owner's store.
      cap:    store rows per device (max blocks on any device, >= 1).
      store:  device [P, cap, bs, bs], sharded over the mesh's worker axis;
              rows past a device's valid count are unspecified padding.
      mesh:   the worker mesh the store lives on.
    """

    shape: tuple[int, int]
    bs: int
    coords: np.ndarray
    owner: np.ndarray
    slot: np.ndarray
    cap: int
    store: jax.Array
    mesh: Mesh

    def __post_init__(self):
        assert self.coords.ndim == 2 and self.coords.shape[1] == 2
        assert self.owner.shape == self.slot.shape == (self.coords.shape[0],)
        assert self.store.shape == (
            self.nparts,
            self.cap,
            self.bs,
            self.bs,
        ), (self.store.shape, self.nparts, self.cap, self.bs)

    @property
    def nnzb(self) -> int:
        return int(self.coords.shape[0])

    @property
    def nparts(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def dtype(self):
        return self.store.dtype

    def codes(self) -> np.ndarray:
        return morton_encode(self.coords[:, 0], self.coords[:, 1])

    def store_maps(self) -> tuple[np.ndarray, np.ndarray]:
        """(store_idx [P, cap] global block per slot, store_valid [P, cap])."""
        idx = np.zeros((self.nparts, self.cap), dtype=np.int32)
        valid = np.zeros((self.nparts, self.cap), dtype=bool)
        idx[self.owner, self.slot] = np.arange(self.nnzb, dtype=np.int32)
        valid[self.owner, self.slot] = True
        return idx, valid

    # -- boundary conversions ----------------------------------------------
    def gather(self) -> BSMatrix:
        """Pull the matrix back to a host-structured BSMatrix (boundary op)."""
        host = np.asarray(self.store)
        data = host[self.owner, self.slot] if self.nnzb else host[:0, 0]
        return BSMatrix(
            shape=tuple(self.shape),
            bs=self.bs,
            coords=self.coords,
            data=jnp.asarray(data),
        )

    # -- device-local ops ---------------------------------------------------
    def scale(self, alpha) -> "DistBSMatrix":
        """alpha * A; elementwise on the resident store, stays sharded."""
        return dataclasses.replace(
            self, store=self.store * jnp.asarray(alpha, self.dtype)
        )

    def astype(self, dtype) -> "DistBSMatrix":
        return dataclasses.replace(self, store=self.store.astype(dtype))


def _mapped_norms_psum(store, gpos, *, nnzb: int):
    """Per-device block norms scattered to global stack positions, psum'd.

    Each stack position receives its value from exactly one device (its
    owner) plus zeros from the rest — float addition with +0.0 is exact, so
    the result is bit-identical to fetching the padded table and indexing on
    the host.  Padding rows scatter into the trash position ``nnzb``.
    """
    norms = block_frobenius_norms(store[0])  # [cap], float32
    out = jnp.zeros((nnzb + 1,), norms.dtype).at[gpos[0]].add(norms)
    return jax.lax.psum(out[:nnzb], AXIS)


class NormTableExecutable:
    """Fused device-side norm reduction + compaction for one structure.

    The legacy path fetches the padded ``[P, cap]`` norm table and compacts
    on the host; this executable scatters each device's valid block norms
    into their global stack positions and ``psum``s over the worker axis, so
    only the dense ``[nnzb]`` stack-order vector — the exact leaf bounds the
    hierarchical descents consume — ever crosses device->host.
    """

    def __init__(self, x: DistBSMatrix):
        gpos = np.full((x.nparts, x.cap), x.nnzb, dtype=np.int32)  # trash
        gpos[x.owner, x.slot] = np.arange(x.nnzb, dtype=np.int32)
        # host copy retained for repro.analysis plan-cache verification
        self._verify_plan = dict(
            kind="norm-table", gpos=gpos, owner=np.asarray(x.owner),
            slot=np.asarray(x.slot), nnzb=x.nnzb, nparts=x.nparts, cap=x.cap)
        self._gpos = jax.device_put(
            jnp.asarray(gpos), NamedSharding(x.mesh, P(AXIS))
        )
        self._mapped = jax.jit(
            shard_map(
                functools.partial(_mapped_norms_psum, nnzb=x.nnzb),
                mesh=x.mesh,
                in_specs=(P(AXIS), P(AXIS)),
                out_specs=P(),
                check_vma=False,
            )
        )

    def __call__(self, store) -> np.ndarray:
        return np.asarray(self._mapped(store, self._gpos))  # [nnzb] -> host


def resident_block_norms(x: DistBSMatrix, cache=None) -> np.ndarray:
    """Per-block Frobenius norms in stack order from the resident store.

    Runs :func:`repro.core.matrix.block_frobenius_norms` — the exact kernel
    the host path uses, same accumulation dtype — on the ``[P, cap, bs, bs]``
    store, so host and resident SpAMM / hierarchical truncation make
    identical prune decisions near ``tau``.  With a
    :class:`~repro.dist.cache.PlanCache`, the reduction and the compaction
    are fused on device (:class:`NormTableExecutable`, cached per structure):
    only the ``[nnzb]`` stack-order vector crosses device->host instead of
    the padded ``[P, cap]`` table, with bit-identical values (tested).
    """
    if x.nnzb == 0:
        return np.zeros((0,), dtype=np.float64)
    from repro.obs.tracer import tracer_of

    tr = tracer_of(cache)
    with tr.span("norm_fetch", cat="collective", nnzb=x.nnzb):
        if tr.enabled:
            tr.counter("norm_fetch_bytes").add(x.nnzb * 4)
        mm = getattr(cache, "memory_meter", None) if cache is not None else None
        if mm is not None:
            # the [P, cap] norm table the fused reduction materializes
            per_worker = np.full(x.nparts, x.cap * 4, dtype=np.int64)
            mm.note_bytes("norm_table", per_worker, cache=cache)
        if cache is not None:
            key = (
                "norms",
                structure_fingerprint(x.codes(), x.owner, x.nparts, x.bs),
                mesh_key(x.mesh),
            )
            exe = cache.get_or_build(key, lambda: NormTableExecutable(x))
            return exe(x.store).astype(np.float64)
        table = np.asarray(block_frobenius_norms(x.store))  # [P, cap] -> host
        return table[x.owner, x.slot].astype(np.float64)


def dist_zeros(
    shape: tuple[int, int], bs: int, mesh: Mesh, dtype=jnp.float32
) -> DistBSMatrix:
    """Structurally-empty resident matrix (cap-1 padding store, no blocks)."""
    store = jax.device_put(
        jnp.zeros((int(mesh.devices.size), 1, bs, bs), dtype=dtype),
        _store_sharding(mesh),
    )
    return DistBSMatrix(
        shape=tuple(shape),
        bs=bs,
        coords=np.zeros((0, 2), dtype=np.int64),
        owner=np.zeros((0,), dtype=np.int32),
        slot=np.zeros((0,), dtype=np.int32),
        cap=1,
        store=store,
        mesh=mesh,
    )


def scatter(
    a: BSMatrix,
    mesh: Mesh | None = None,
    *,
    owner: np.ndarray | None = None,
) -> DistBSMatrix:
    """Ship a host BSMatrix onto the mesh once; default Morton placement.

    The inverse of :meth:`DistBSMatrix.gather`.  ``owner`` pins an explicit
    placement (must assign every block a device id < mesh size).
    """
    mesh = mesh or make_worker_mesh()
    nparts = int(mesh.devices.size)
    if owner is None:
        owner = partition_morton(a.nnzb, nparts)
    owner = np.asarray(owner, dtype=np.int32)
    assert owner.shape == (a.nnzb,)
    slot, stores = _owner_slots(owner, nparts)
    cap = max(max((len(s) for s in stores), default=0), 1)
    host = np.zeros((nparts, cap, a.bs, a.bs), dtype=np.asarray(a.data).dtype)
    data = np.asarray(a.data)
    for p, s in enumerate(stores):
        host[p, : len(s)] = data[s]
    store = jax.device_put(jnp.asarray(host), _store_sharding(mesh))
    return DistBSMatrix(
        shape=tuple(a.shape),
        bs=a.bs,
        coords=a.coords,
        owner=owner,
        slot=slot,
        cap=cap,
        store=store,
        mesh=mesh,
    )
