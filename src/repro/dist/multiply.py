"""Distributed multiply on resident operands, planned through the cache.

``dist_multiply`` is the hot-path operation the subsystem exists for: both
operands are :class:`~repro.dist.matrix.DistBSMatrix` stores already living
on the mesh, the schedule comes from the structure-keyed
:class:`~repro.dist.cache.PlanCache` (symbolic phase + shard_map executable
+ device-resident plan arrays, built once per distinct structure), and the
result store is produced sharded — it never visits the host.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import make_spgemm_executable
from repro.core.quadtree import build_quadtree_index, quadtree_depth
from repro.core.schedule import make_spgemm_plan, structure_fingerprint
from repro.core.spgemm import spamm_symbolic

from .cache import PlanCache
from .matrix import DistBSMatrix, _store_sharding, mesh_key

__all__ = ["dist_multiply", "dist_spamm", "multiply_plan_key"]


def multiply_plan_key(
    a: DistBSMatrix, b: DistBSMatrix, *, exchange: str, impl: str
) -> tuple:
    """Cache key: A/B Morton codes + owner maps + mesh + mode knobs."""
    return (
        "spgemm",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
    )


def dist_multiply(
    a: DistBSMatrix,
    b: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    exchange: str = "p2p",
    impl: str = "ref",
) -> DistBSMatrix:
    """C = A @ B with A, B, C device-resident.  Plan + executable cached."""
    assert a.mesh is b.mesh or list(a.mesh.devices.flat) == list(
        b.mesh.devices.flat
    ), "operands must live on the same worker mesh"
    assert a.shape[1] == b.shape[0] and a.bs == b.bs, (a.shape, b.shape)

    def build():
        plan = make_spgemm_plan(
            a.coords,
            b.coords,
            a.nparts,
            a.bs,
            exchange=exchange,
            a_owner=a.owner,
            b_owner=b.owner,
        )
        # the pinned placements must reproduce the operands' resident layout
        assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
            plan.a_cap,
            a.cap,
            plan.b_cap,
            b.cap,
        )
        exe = make_spgemm_executable(plan, a.mesh, impl=impl)
        return plan, exe

    if cache is None:
        plan, exe = build()
    else:
        plan, exe = cache.get_or_build(
            multiply_plan_key(a, b, exchange=exchange, impl=impl), build
        )
    c_store = exe(a.store, b.store)
    return DistBSMatrix(
        shape=(a.shape[0], b.shape[1]),
        bs=a.bs,
        coords=plan.c_coords,
        owner=np.asarray(plan.c_owner, dtype=np.int32),
        slot=np.asarray(plan.c_slot, dtype=np.int32),
        cap=plan.c_cap,
        store=c_store,
        mesh=a.mesh,
    )


def _resident_block_norms(x: DistBSMatrix) -> np.ndarray:
    """Per-block Frobenius norms in stack order; only the tiny [P, cap] norm
    table crosses device->host (the block data stays resident).  Matches
    :func:`repro.core.matrix.block_frobenius_norms` bit-for-bit so the
    hierarchical prune decisions agree with the host path."""
    norms = np.asarray(
        jnp.sqrt(jnp.sum(jnp.square(x.store.astype(jnp.float32)), axis=(2, 3)))
    )
    return (
        norms[x.owner, x.slot].astype(np.float64)
        if x.nnzb
        else np.zeros((0,), np.float64)
    )


def dist_spamm(
    a: DistBSMatrix,
    b: DistBSMatrix,
    tau: float,
    cache: PlanCache | None = None,
    *,
    exchange: str = "p2p",
    impl: str = "ref",
) -> tuple[DistBSMatrix, float]:
    """Sparse approximate multiply on resident operands: C ~= A @ B.

    The hierarchical SpAMM symbolic phase (:func:`repro.core.spgemm.spamm_symbolic`)
    runs on the host against quadtree indexes carrying subtree norms — norms
    depend on current values, so it runs every call, but it is cheap and
    shrinks with the pruned work.  The *pruned task list* is then threaded
    into :func:`make_spgemm_plan(tasks=...)`; the plan + executable are cached
    keyed by the pruned structure, so a stable prune pattern (e.g. SP2
    iterations past pattern stabilization) reuses the compiled program.

    Returns ``(C, err_bound)`` with ``||A@B - C||_F <= err_bound <= tau``.
    """
    assert a.mesh is b.mesh or list(a.mesh.devices.flat) == list(
        b.mesh.devices.flat
    ), "operands must live on the same worker mesh"
    assert a.shape[1] == b.shape[0] and a.bs == b.bs, (a.shape, b.shape)
    depth = max(
        quadtree_depth(-(-a.shape[0] // a.bs), -(-a.shape[1] // a.bs)),
        quadtree_depth(-(-b.shape[0] // b.bs), -(-b.shape[1] // b.bs)),
    )
    ia = build_quadtree_index(a.coords, _resident_block_norms(a), depth=depth)
    ib = build_quadtree_index(b.coords, _resident_block_norms(b), depth=depth)
    tasks, err, _ = spamm_symbolic(ia, ib, tau)
    if tasks.num_tasks == 0:
        store = jax.device_put(
            jnp.zeros((a.nparts, 1, a.bs, a.bs), dtype=a.dtype),
            _store_sharding(a.mesh),
        )
        empty = DistBSMatrix(
            shape=(a.shape[0], b.shape[1]),
            bs=a.bs,
            coords=np.zeros((0, 2), dtype=np.int64),
            owner=np.zeros((0,), dtype=np.int32),
            slot=np.zeros((0,), dtype=np.int32),
            cap=1,
            store=store,
            mesh=a.mesh,
        )
        return empty, err

    key = (
        "spamm",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs,
            tasks.a_idx, tasks.b_idx, tasks.c_idx,
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
    )

    def build():
        plan = make_spgemm_plan(
            a.coords,
            b.coords,
            a.nparts,
            a.bs,
            exchange=exchange,
            tasks=tasks,
            a_owner=a.owner,
            b_owner=b.owner,
        )
        assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
            plan.a_cap, a.cap, plan.b_cap, b.cap,
        )
        exe = make_spgemm_executable(plan, a.mesh, impl=impl)
        return plan, exe

    if cache is None:
        plan, exe = build()
    else:
        plan, exe = cache.get_or_build(key, build)
    c_store = exe(a.store, b.store)
    return (
        DistBSMatrix(
            shape=(a.shape[0], b.shape[1]),
            bs=a.bs,
            coords=plan.c_coords,
            owner=np.asarray(plan.c_owner, dtype=np.int32),
            slot=np.asarray(plan.c_slot, dtype=np.int32),
            cap=plan.c_cap,
            store=c_store,
            mesh=a.mesh,
        ),
        err,
    )
