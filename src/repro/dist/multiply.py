"""Distributed multiply on resident operands, planned through the cache.

``dist_multiply`` is the hot-path operation the subsystem exists for: both
operands are :class:`~repro.dist.matrix.DistBSMatrix` stores already living
on the mesh, the schedule comes from the structure-keyed
:class:`~repro.dist.cache.PlanCache` (symbolic phase + shard_map executable
+ device-resident plan arrays, built once per distinct structure), and the
result store is produced sharded — it never visits the host.

``dist_spamm`` adds error-controlled approximate multiply in two modes:

* ``method="delta"`` (default) — the *delta-plan* path: the full-multiply
  plan and a :class:`~repro.core.distributed.MaskedSpgemmExecutable` are
  cached once per structure; each call runs the hierarchical SpAMM descent
  on the host and ships only a tiny per-task on/off mask (``gval``-style
  zeroing via trash-row redirect).  A fluctuating ``tau``-prune pattern
  therefore never causes a plan-cache miss — the SP2 inner loop stays pure
  device work.
* ``method="replan"`` — the pruned task list is threaded into
  :func:`make_spgemm_plan(tasks=...)` and the plan is keyed by the pruned
  structure: cheaper flops/exchange per call, but any wiggle in the prune
  pattern re-plans and re-jits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (
    make_fused_spgemm_executable,
    make_masked_fused_spgemm_executable,
    make_masked_spgemm_executable,
    make_spgemm_executable,
)
from repro.core.quadtree import build_quadtree_index, quadtree_depth
from repro.core.schedule import (
    make_spgemm_plan,
    structure_fingerprint,
)
from repro.core.spgemm import spamm_symbolic, spgemm_symbolic
from repro.kernels.precision import FP32, Precision, low_precision_task_mask
from repro.obs.timing import timed_into
from repro.obs.tracer import tracer_of

from .cache import PlanCache
from .collectives import dist_repartition
from .matrix import (
    DistBSMatrix,
    _store_sharding,
    mesh_key,
    resident_block_norms,
)

__all__ = [
    "dist_multiply",
    "dist_spamm",
    "multiply_plan_key",
    "spamm_delta_plan_key",
]

# backward-compatible private name; the implementation now lives next to the
# store layout it reads (repro.dist.matrix)
_resident_block_norms = resident_block_norms


_FUSED_IMPLS = ("fused", "fused-interpret")


def multiply_plan_key(
    a: DistBSMatrix,
    b: DistBSMatrix,
    *,
    exchange: str,
    impl: str,
    precision: Precision = FP32,
) -> tuple:
    """Cache key: A/B Morton codes + owner maps + mesh + mode knobs.

    Operand dtypes and the precision policy are part of the key — a bf16 or
    adaptive program is a different compiled artifact than the fp32 one.
    """
    return (
        "spgemm",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
        str(a.dtype),
        str(b.dtype),
        precision.key(),
    )


def spamm_delta_plan_key(
    a: DistBSMatrix,
    b: DistBSMatrix,
    *,
    exchange: str,
    impl: str,
    precision: Precision = FP32,
) -> tuple:
    """Delta-plan SpAMM cache key — structure only, independent of the per-call
    prune pattern, so every call on a stable structure is a hit."""
    return (
        "spamm-delta",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
        str(a.dtype),
        str(b.dtype),
        precision.key(),
    )


def _plan_obs_static(plan) -> dict:
    """Per-plan static annotation payload, memoized on the plan object.

    Everything here depends only on the plan (exchange bytes, ownership
    terms of the cost model, per-round byte totals) — a warm-cache run
    replays the same plan hundreds of times, so recomputing it per dispatch
    is what pushed tracing overhead past the benchmark cap.
    """
    st = getattr(plan, "_obs_static", None)
    if st is None:
        from .balance import RebalancePolicy, worker_load

        load = worker_load(plan)
        pol = RebalancePolicy()
        blk = plan.bs * plan.bs * 4
        rounds = []
        if plan.exchange != "allgather":
            for operand, offs, cnts in (
                ("a", plan.a_offsets, plan.a_send_count),
                ("b", plan.b_offsets, plan.b_send_count),
            ):
                for rnd, d in enumerate(offs):
                    rounds.append((operand, rnd, int(d),
                                   float(np.asarray(cnts[d]).sum()) * blk))
        base = (pol.recv_cost * load.recv_bytes / blk
                + pol.send_cost * load.send_bytes / blk
                + pol.block_cost * load.blocks)
        st = dict(
            # the task-independent terms of the rebalancer's combined cost
            base=base,
            # full (unmasked) dispatch cost vector, precomputed: most warm
            # dispatches run the whole task list
            full_costs=np.asarray(plan.task_count, np.float64) + base,
            full_tasks=int(np.asarray(plan.task_count).sum()),
            recv_sum=float(load.recv_bytes.sum()),
            send_sum=float(load.send_bytes.sum()),
            rounds=rounds,
            tiles={},            # per-dtype pick_tiles memo
            rounds_tracer=None,  # exchange_round instants once per tracer
        )
        st["full_costs"].setflags(write=False)  # shared across spans
        object.__setattr__(plan, "_obs_static", st)  # plan is frozen
    return st


def _annotate_spgemm_dispatch(
    tr, sp, plan, task_count, precision: Precision | None = None, exe=None
) -> None:
    """Per-worker attribution + byte/task counters on an executed multiply
    dispatch span.  Callers guard on ``tr.enabled`` — this does real work
    (plan byte accounting, cost-model evaluation) that must cost nothing
    with tracing off.
    """
    st = _plan_obs_static(plan)
    if precision is not None:
        dtype = "bfloat16" if precision.mode == "bf16" else "float32"
        tiles = st["tiles"].get(dtype)
        if tiles is None:
            from repro.kernels.autotune import pick_tiles

            tiles = st["tiles"][dtype] = list(
                pick_tiles(plan.bs, plan.bs, plan.bs, dtype))
        sp.args.update(precision=precision.mode, dtype=dtype, tiles=tiles)
    ex = getattr(exe, "last_exchange", None)
    if ex is not None:
        sp.args.update(
            send_blocks=ex["send_blocks"],
            kept_send_blocks=ex["kept_blocks"],
            dropped_rounds=ex["dropped_rounds"],
        )
        tr.counter("pruned_send_blocks").add(
            float(ex["send_blocks"] - ex["kept_blocks"])
        )
    # the same combined task-equivalent cost the rebalancer weighs, so the
    # trace's utilization tracks match BENCH_balance's imbalance numbers
    if task_count is None or task_count is plan.task_count:
        sp.worker_costs = st["full_costs"]
        tasks = st["full_tasks"]
    else:
        tc = np.asarray(task_count)
        sp.worker_costs = tc.astype(np.float64) + st["base"]
        tasks = int(tc.sum())
    sp.args.update(tasks=tasks, recv_bytes=st["recv_sum"],
                   send_bytes=st["send_sum"])
    tr.counter("tasks_executed").add(float(tasks))
    tr.counter("recv_bytes").add(st["recv_sum"])
    tr.counter("send_bytes").add(st["send_sum"])
    # exchange rounds run fused inside the jitted dispatch — emit honest
    # per-round markers carrying planned bytes, not fabricated durations.
    # They are plan-static, so each plan emits them on its first dispatch
    # observed by a given tracer; warm replays add no duplicate markers.
    if st["rounds_tracer"] is not tr:
        st["rounds_tracer"] = tr
        for operand, rnd, d, nbytes in st["rounds"]:
            tr.instant("exchange_round", cat="exchange", operand=operand,
                       round=rnd, offset=d, bytes=nbytes)


def _note_dispatch_memory(cache, plan, precision, c) -> None:
    """Account an executed multiply against the installed
    :class:`~repro.obs.memory.MemoryMeter` (no-op when none is installed):
    the plan's receive buffers at wire precision plus the result store.

    A repeat dispatch of the same cached plan over the same owner layout
    yields byte-identical account vectors, so those are deduped by token —
    peak watermarks cannot move and warm iteration loops pay one set
    lookup instead of recomputing the per-worker bincounts."""
    mm = getattr(cache, "memory_meter", None) if cache is not None else None
    if mm is None:
        return
    tok = (id(plan), id(c.owner), c.nnzb, c.cap,
           getattr(precision, "mode", None))
    seen = getattr(mm, "_dispatch_seen", None)
    if seen is None:
        seen = mm._dispatch_seen = set()
    if tok in seen:
        return
    seen.add(tok)
    mm.note_plan(plan, precision, cache=cache)
    mm.note_matrix(c, "store", cache=cache)


def _note_dispatch_locality(
    cache, tr, plan, precision, a, b, *, task_on=None, exe=None
) -> None:
    """Meter an executed multiply against the installed
    :class:`~repro.obs.locality.LocalityLedger` (no-op when none is
    installed, costing one getattr): static local/shipped residency split,
    wire bytes with delta-mask pruning and the wire itemsize applied, and
    per-block movement lineage keyed by the operands' Morton codes.
    Independent of the tracer — the ledger meters even with tracing off —
    but feeds the locality counters when a tracer listens."""
    lld = getattr(cache, "locality_ledger", None) if cache is not None else None
    if lld is None:
        return
    wire = 2 if getattr(precision, "mode", "fp32") != "fp32" else 4
    out = lld.note_dispatch(
        plan,
        wire_itemsize=wire,
        task_on=task_on,
        keeps=getattr(exe, "last_keeps", None),
        a_codes=a.codes(),
        b_codes=b.codes(),
    )
    if tr.enabled:
        tr.counter("local_bytes").add(out["local_bytes"])
        tr.counter("shipped_bytes").add(out["shipped_bytes"])
        tr.counter("wire_recv_bytes").add(out["wire_recv_bytes"])
        tr.counter("local_flops").add(out["local_flops"])


def _check_operands(a: DistBSMatrix, b: DistBSMatrix) -> None:
    assert a.mesh is b.mesh or list(a.mesh.devices.flat) == list(
        b.mesh.devices.flat
    ), "operands must live on the same worker mesh"
    assert a.shape[1] == b.shape[0] and a.bs == b.bs, (a.shape, b.shape)


def _rebalance_operands(
    a: DistBSMatrix, b: DistBSMatrix, cache: PlanCache | None, policy
) -> tuple[DistBSMatrix, DistBSMatrix]:
    """Opt-in operand re-layout before planning a multiply.

    Weighs each operand's current owner map against its task-reference
    counts in this multiply (plus one unit of ownership weight per block) —
    the :mod:`repro.dist.balance` cost model at single-op granularity — and
    re-slots skewed operands through :func:`~repro.dist.collectives.
    dist_repartition` before the plan is built.  Everything is structural,
    so the decision is deterministic per structure pair and repeated calls
    are pure cache hits; iterative callers should instead hold the
    repartitioned handle (the drivers' ``rebalance=`` loop does).
    """
    from .balance import LoadMonitor, block_reference_weights, owner_imbalance

    key = (
        "spgemm-tasks",
        structure_fingerprint(a.codes(), b.codes(), a.bs),
    )
    build = lambda: spgemm_symbolic(a.coords, b.coords)
    tasks = cache.get_or_build(key, build) if cache is not None else build()
    wa, wb = block_reference_weights(tasks, a.nnzb, b.nnzb)
    wa += 1.0
    wb += 1.0
    mon = LoadMonitor(a.nparts, policy)
    same = b is a

    def relayout(x, w):
        if owner_imbalance(x.owner, w, x.nparts) <= policy.threshold:
            return x
        new_owner = mon.propose(x, w)
        return x if new_owner is None else dist_repartition(x, new_owner, cache)

    a = relayout(a, wa)
    return (a, a) if same else (a, relayout(b, wb))


def _precision_of(precision, impl: str, exchange: str = "p2p") -> Precision:
    precision = FP32 if precision is None else precision
    if precision.is_mixed:
        assert impl in _FUSED_IMPLS, (
            "mixed precision needs the fused leaf engine (impl='fused')"
        )
        assert exchange == "p2p", (
            "mixed precision needs the p2p exchange (allgather plans have no "
            "(src, off) task decomposition)"
        )
    return precision


def _use_fused(impl: str, exchange: str) -> bool:
    """Fused engine needs the p2p (src, off) decomposition; an allgather
    plan falls back to the staged reference path."""
    return impl in _FUSED_IMPLS and exchange == "p2p"


def _valid_task_slots(plan) -> np.ndarray:
    return (
        np.arange(plan.task_gidx.shape[1])[None, :] < plan.task_count[:, None]
    )


def _adaptive_low_table(plan, low_task: np.ndarray) -> np.ndarray:
    """Map a global per-task low-precision mask onto [P, t_cap] int32."""
    if low_task.shape[0] == 0:  # no tasks: gidx pads with 0, don't index
        return np.zeros(plan.task_gidx.shape, np.int32)
    valid = _valid_task_slots(plan)
    return (low_task[plan.task_gidx] & valid).astype(np.int32)


def dist_multiply(
    a: DistBSMatrix,
    b: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    exchange: str = "p2p",
    impl: str = "ref",
    precision: Precision | None = None,
    rebalance=None,
) -> DistBSMatrix:
    """C = A @ B with A, B, C device-resident.  Plan + executable cached.

    ``impl="fused"`` routes through the fused leaf engine (one
    unpack+GEMM+accumulate dispatch, no concatenated operand buffer);
    ``precision`` selects its dtype policy (:class:`Precision` — ``fp32`` |
    ``bf16`` | ``adaptive``; adaptive spends a rounding-error budget of
    ``precision.tau`` using the resident norm tables).  Staged impls
    (``ref`` / ``kernel``) are fp32-only.

    ``rebalance`` (a :class:`repro.dist.balance.RebalancePolicy`) re-slots
    skewed operand layouts on device before planning — see
    :func:`_rebalance_operands`.
    """
    _check_operands(a, b)
    precision = _precision_of(precision, impl, exchange)
    fused = _use_fused(impl, exchange)
    adaptive = precision.mode == "adaptive"
    tr = tracer_of(cache)
    with tr.span("dist_multiply", cat="collective",
                 nnzb_a=a.nnzb, nnzb_b=b.nnzb):
        if rebalance is not None:
            a, b = _rebalance_operands(a, b, cache, rebalance)

        def build():
            plan = make_spgemm_plan(
                a.coords,
                b.coords,
                a.nparts,
                a.bs,
                exchange=exchange,
                a_owner=a.owner,
                b_owner=b.owner,
            )
            # the pinned placements must reproduce the operands' resident
            # layout
            assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
                plan.a_cap,
                a.cap,
                plan.b_cap,
                b.cap,
            )
            if fused and adaptive:
                # adaptive needs the per-task low mask -> masked executable;
                # no pruning here (all tasks run), so keep the exchange full
                exe = make_masked_fused_spgemm_executable(
                    plan, a.mesh, impl=impl, precision=precision,
                    prune_exchange=False,
                )
            elif fused:
                exe = make_fused_spgemm_executable(
                    plan, a.mesh, impl=impl, precision=precision
                )
            else:
                staged = "ref" if impl in _FUSED_IMPLS else impl
                exe = make_spgemm_executable(plan, a.mesh, impl=staged)
            return plan, exe

        key = multiply_plan_key(
            a, b, exchange=exchange, impl=impl, precision=precision
        )
        if cache is None:
            plan, exe = build()
        else:
            plan, exe = cache.get_or_build(key, build)
            cache.last_plan_key = key
            cache.last_task_count = plan.task_count
        if adaptive:
            a_norms = resident_block_norms(a, cache)
            b_norms = a_norms if b is a else resident_block_norms(b, cache)
            full = plan.tasks
            low_task, _ = low_precision_task_mask(
                a_norms, b_norms, full.a_idx, full.b_idx, precision.tau
            )
            task_on = _valid_task_slots(plan)
            task_low = _adaptive_low_table(plan, low_task)
        with tr.span("dispatch", cat="kernel", op="spgemm") as sp:
            if adaptive:
                c_store = tr.sync(exe(a.store, b.store, task_on, task_low))
            else:
                c_store = tr.sync(exe(a.store, b.store))
            if tr.enabled:
                _annotate_spgemm_dispatch(
                    tr, sp, plan, plan.task_count, precision, exe
                )
    c = DistBSMatrix(
        shape=(a.shape[0], b.shape[1]),
        bs=a.bs,
        coords=plan.c_coords,
        owner=np.asarray(plan.c_owner, dtype=np.int32),
        slot=np.asarray(plan.c_slot, dtype=np.int32),
        cap=plan.c_cap,
        store=c_store,
        mesh=a.mesh,
    )
    _note_dispatch_memory(cache, plan, precision, c)
    _note_dispatch_locality(cache, tr, plan, precision, a, b, exe=exe)
    return c


def _spamm_pruned_tasks(
    a: DistBSMatrix,
    b: DistBSMatrix,
    tau: float,
    a_norms: np.ndarray,
    b_norms: np.ndarray,
):
    """Hierarchical SpAMM descent on the resident structures.

    ``a_norms`` / ``b_norms`` are stack-order per-block norms the caller
    already holds — :func:`dist_spamm` prefetches them through the fused
    psum path (:func:`resident_block_norms` with the cache) outside the
    symbolic timer, or reuses a table carried over from truncation.
    Returns ``(tasks, err_bound)``.
    """
    depth = max(
        quadtree_depth(-(-a.shape[0] // a.bs), -(-a.shape[1] // a.bs)),
        quadtree_depth(-(-b.shape[0] // b.bs), -(-b.shape[1] // b.bs)),
    )
    na, nb = a_norms, b_norms
    ia = build_quadtree_index(a.coords, na, depth=depth)
    ib = ia if b is a else build_quadtree_index(b.coords, nb, depth=depth)
    tasks, err, _ = spamm_symbolic(ia, ib, tau)
    return tasks, err


def _empty_dist_result(a: DistBSMatrix, b: DistBSMatrix) -> DistBSMatrix:
    store = jax.device_put(
        jnp.zeros((a.nparts, 1, a.bs, a.bs), dtype=a.dtype),
        _store_sharding(a.mesh),
    )
    return DistBSMatrix(
        shape=(a.shape[0], b.shape[1]),
        bs=a.bs,
        coords=np.zeros((0, 2), dtype=np.int64),
        owner=np.zeros((0,), dtype=np.int32),
        slot=np.zeros((0,), dtype=np.int32),
        cap=1,
        store=store,
        mesh=a.mesh,
    )


def dist_spamm(
    a: DistBSMatrix,
    b: DistBSMatrix,
    tau: float,
    cache: PlanCache | None = None,
    *,
    exchange: str = "p2p",
    impl: str = "ref",
    method: str = "delta",
    precision: Precision | None = None,
    a_norms: np.ndarray | None = None,
    b_norms: np.ndarray | None = None,
    rebalance=None,
) -> tuple[DistBSMatrix, float]:
    """Sparse approximate multiply on resident operands: C ~= A @ B.

    The hierarchical SpAMM symbolic phase (:func:`repro.core.spgemm.spamm_symbolic`)
    runs on the host against quadtree indexes carrying subtree norms — norms
    depend on current values, so it runs every call, but it is cheap and
    shrinks with the pruned work.  ``a_norms`` / ``b_norms`` (stack-order
    per-block norms, as returned by :func:`resident_block_norms`) let callers
    share one norm-table fetch across operations.

    ``method="delta"`` applies the prune pattern as a task mask against the
    cached full-multiply plan (see module docstring): the plan cache is keyed
    by structure alone, so prune-pattern fluctuation never misses.
    ``method="replan"`` threads the pruned task list into a per-pattern plan.

    ``rebalance`` (a :class:`repro.dist.balance.RebalancePolicy`) re-slots
    skewed operand layouts on device before planning
    (:func:`_rebalance_operands`); note the stack-order norm tables are
    layout-invariant, so prefetched ``a_norms`` / ``b_norms`` stay valid
    across the re-layout.

    ``precision`` (fused impl only) selects the leaf engine's dtype policy;
    ``adaptive`` rounds the smallest-bound kept tasks to bf16 under a budget
    of ``precision.budget(tau)`` — the returned bound then includes the
    rounding spend, so ``||A@B - C||_F <= err_bound`` still holds.

    Returns ``(C, err_bound)`` with ``||A@B - C||_F <= err_bound``; for pure
    pruning (fp32/bf16 storage aside) the bound is ``<= tau``.
    """
    _check_operands(a, b)
    precision = _precision_of(precision, impl, exchange)
    if precision.mode == "adaptive":
        assert method == "delta", "adaptive precision rides the delta plan"
    tr = tracer_of(cache)
    with tr.span("dist_spamm", cat="collective",
                 nnzb_a=a.nnzb, nnzb_b=b.nnzb, tau=float(tau)):
        return _dist_spamm_impl(
            a, b, tau, cache, tr,
            exchange=exchange, impl=impl, method=method, precision=precision,
            a_norms=a_norms, b_norms=b_norms, rebalance=rebalance,
        )


def _dist_spamm_impl(
    a, b, tau, cache, tr, *, exchange, impl, method, precision, a_norms,
    b_norms, rebalance
):
    fused = _use_fused(impl, exchange)
    if rebalance is not None:
        a, b = _rebalance_operands(a, b, cache, rebalance)
    # norm fetches stay outside the symbolic timer: a miss on the fused norm
    # executable is timed into cache.build_s by get_or_build
    if a_norms is None:
        a_norms = resident_block_norms(a, cache)
    if b_norms is None:
        b_norms = a_norms if b is a else resident_block_norms(b, cache)
    # descent time only — miss builders are timed into cache.build_s by
    # get_or_build, and must not be double-counted as symbolic work
    with timed_into(cache, "symbolic_s", tr, "spamm_descent",
                    cat="symbolic", tau=float(tau)):
        tasks, err = _spamm_pruned_tasks(a, b, tau, a_norms, b_norms)

    if method == "delta":
        key = spamm_delta_plan_key(
            a, b, exchange=exchange, impl=impl, precision=precision
        )

        def build():
            # the delta plan IS the exact-multiply plan; reuse one already
            # cached for dist_multiply on this structure instead of redoing
            # the symbolic phase (only the executable differs)
            exact = (
                cache.peek(multiply_plan_key(
                    a, b, exchange=exchange, impl=impl, precision=precision
                ))
                if cache is not None
                else None
            )
            plan = exact[0] if exact is not None else make_spgemm_plan(
                a.coords,
                b.coords,
                a.nparts,
                a.bs,
                exchange=exchange,
                a_owner=a.owner,
                b_owner=b.owner,
            )
            assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
                plan.a_cap, a.cap, plan.b_cap, b.cap,
            )
            if fused:
                exe = make_masked_fused_spgemm_executable(
                    plan, a.mesh, impl=impl, precision=precision
                )
            else:
                staged = "ref" if impl in _FUSED_IMPLS else impl
                exe = make_masked_spgemm_executable(plan, a.mesh, impl=staged)
            return plan, exe

        if cache is None:
            plan, exe = build()
        else:
            plan, exe = cache.get_or_build(key, build)
            cache.last_plan_key = key
        # relay the kept (a, b) pairs onto the full task list: a task is
        # uniquely (a_idx, b_idx) — the output block is determined by the pair
        with timed_into(cache, "symbolic_s", tr, "delta_mask", cat="symbolic"):
            full = plan.tasks
            if full.num_tasks == 0:
                # no structural overlap: every padded slot is already masked
                # off (task_gidx pads with 0, which must not index an empty
                # task list)
                task_on = np.zeros(plan.task_gidx.shape, dtype=bool)
            else:
                keep_task = np.zeros(full.num_tasks, dtype=bool)
                if tasks.num_tasks:
                    nb_blocks = np.int64(max(b.nnzb, 1))
                    keep_task = np.isin(
                        full.a_idx * nb_blocks + full.b_idx,
                        tasks.a_idx * nb_blocks + tasks.b_idx,
                    )
                valid = (
                    np.arange(plan.task_gidx.shape[1])[None, :]
                    < plan.task_count[:, None]
                )
                task_on = keep_task[plan.task_gidx] & valid
        # adaptive mixed precision: spend the rounding budget on the kept
        # tasks with the smallest ||A_t||·||B_t|| bound (a pruned task
        # contributes no error and must not consume budget)
        task_low = None
        if precision.mode == "adaptive":
            full = plan.tasks
            keep_task_g = np.zeros(max(full.num_tasks, 1), dtype=bool)
            if full.num_tasks:
                keep_task_g[plan.task_gidx[task_on]] = True
            low_task, spent = low_precision_task_mask(
                a_norms, b_norms, full.a_idx, full.b_idx,
                precision.budget(tau), eligible=keep_task_g[: full.num_tasks],
            )
            task_low = _adaptive_low_table(plan, low_task)
            err = float(err) + spent
        # measured per-worker flop load: only unmasked tasks cost work
        masked_count = task_on.sum(axis=1).astype(np.int64)
        if cache is not None:
            cache.last_task_count = masked_count
        with tr.span("dispatch", cat="kernel", op="spamm-delta") as sp:
            if fused:
                c_store = tr.sync(exe(a.store, b.store, task_on, task_low))
            else:
                c_store = tr.sync(exe(a.store, b.store, task_on))
            if tr.enabled:
                _annotate_spgemm_dispatch(
                    tr, sp, plan, masked_count, precision, exe
                )
        c = DistBSMatrix(
            shape=(a.shape[0], b.shape[1]),
            bs=a.bs,
            coords=plan.c_coords,
            owner=np.asarray(plan.c_owner, dtype=np.int32),
            slot=np.asarray(plan.c_slot, dtype=np.int32),
            cap=plan.c_cap,
            store=c_store,
            mesh=a.mesh,
        )
        _note_dispatch_memory(cache, plan, precision, c)
        _note_dispatch_locality(
            cache, tr, plan, precision, a, b, task_on=task_on, exe=exe
        )
        return c, err

    assert method == "replan", method
    if tasks.num_tasks == 0:
        if cache is not None:
            cache.last_plan_key = None  # no plan ran; nothing to peek
            cache.last_task_count = None
        return _empty_dist_result(a, b), err

    key = (
        "spamm",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs,
            tasks.a_idx, tasks.b_idx, tasks.c_idx,
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
        str(a.dtype),
        str(b.dtype),
        precision.key(),
    )

    def build():
        plan = make_spgemm_plan(
            a.coords,
            b.coords,
            a.nparts,
            a.bs,
            exchange=exchange,
            tasks=tasks,
            a_owner=a.owner,
            b_owner=b.owner,
        )
        assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
            plan.a_cap, a.cap, plan.b_cap, b.cap,
        )
        if fused:
            exe = make_fused_spgemm_executable(
                plan, a.mesh, impl=impl, precision=precision
            )
        else:
            staged = "ref" if impl in _FUSED_IMPLS else impl
            exe = make_spgemm_executable(plan, a.mesh, impl=staged)
        return plan, exe

    if cache is None:
        plan, exe = build()
    else:
        plan, exe = cache.get_or_build(key, build)
        cache.last_plan_key = key
        cache.last_task_count = plan.task_count
    with tr.span("dispatch", cat="kernel", op="spamm-replan") as sp:
        c_store = tr.sync(exe(a.store, b.store))
        if tr.enabled:
            _annotate_spgemm_dispatch(
                tr, sp, plan, plan.task_count, precision, exe
            )
    c = DistBSMatrix(
        shape=(a.shape[0], b.shape[1]),
        bs=a.bs,
        coords=plan.c_coords,
        owner=np.asarray(plan.c_owner, dtype=np.int32),
        slot=np.asarray(plan.c_slot, dtype=np.int32),
        cap=plan.c_cap,
        store=c_store,
        mesh=a.mesh,
    )
    _note_dispatch_memory(cache, plan, precision, c)
    _note_dispatch_locality(cache, tr, plan, precision, a, b, exe=exe)
    return c, err
