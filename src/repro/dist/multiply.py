"""Distributed multiply on resident operands, planned through the cache.

``dist_multiply`` is the hot-path operation the subsystem exists for: both
operands are :class:`~repro.dist.matrix.DistBSMatrix` stores already living
on the mesh, the schedule comes from the structure-keyed
:class:`~repro.dist.cache.PlanCache` (symbolic phase + shard_map executable
+ device-resident plan arrays, built once per distinct structure), and the
result store is produced sharded — it never visits the host.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.distributed import make_spgemm_executable
from repro.core.schedule import make_spgemm_plan, structure_fingerprint

from .cache import PlanCache
from .matrix import DistBSMatrix, mesh_key

__all__ = ["dist_multiply", "multiply_plan_key"]


def multiply_plan_key(
    a: DistBSMatrix, b: DistBSMatrix, *, exchange: str, impl: str
) -> tuple:
    """Cache key: A/B Morton codes + owner maps + mesh + mode knobs."""
    return (
        "spgemm",
        structure_fingerprint(
            a.codes(), b.codes(), a.owner, b.owner, a.nparts, a.bs
        ),
        mesh_key(a.mesh),
        exchange,
        impl,
    )


def dist_multiply(
    a: DistBSMatrix,
    b: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    exchange: str = "p2p",
    impl: str = "ref",
) -> DistBSMatrix:
    """C = A @ B with A, B, C device-resident.  Plan + executable cached."""
    assert a.mesh is b.mesh or list(a.mesh.devices.flat) == list(
        b.mesh.devices.flat
    ), "operands must live on the same worker mesh"
    assert a.shape[1] == b.shape[0] and a.bs == b.bs, (a.shape, b.shape)

    def build():
        plan = make_spgemm_plan(
            a.coords,
            b.coords,
            a.nparts,
            a.bs,
            exchange=exchange,
            a_owner=a.owner,
            b_owner=b.owner,
        )
        # the pinned placements must reproduce the operands' resident layout
        assert plan.a_cap == a.cap and plan.b_cap == b.cap, (
            plan.a_cap,
            a.cap,
            plan.b_cap,
            b.cap,
        )
        exe = make_spgemm_executable(plan, a.mesh, impl=impl)
        return plan, exe

    if cache is None:
        plan, exe = build()
    else:
        plan, exe = cache.get_or_build(
            multiply_plan_key(a, b, exchange=exchange, impl=impl), build
        )
    c_store = exe(a.store, b.store)
    return DistBSMatrix(
        shape=(a.shape[0], b.shape[1]),
        bs=a.bs,
        coords=plan.c_coords,
        owner=np.asarray(plan.c_owner, dtype=np.int32),
        slot=np.asarray(plan.c_slot, dtype=np.int32),
        cap=plan.c_cap,
        store=c_store,
        mesh=a.mesh,
    )
