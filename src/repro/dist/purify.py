"""End-to-end distributed SP2 purification on resident matrices.

The full iterative loop — multiply via a cached plan, add / trace /
Frobenius norm / truncate via the resident collectives — runs on
:class:`~repro.dist.matrix.DistBSMatrix` stores that never leave the worker
mesh.  The host only sees scalars (trace, idempotency) and tiny index
tables each iteration; after the sparsity pattern stabilizes under
truncation every planning step is a :class:`~repro.dist.cache.PlanCache`
hit, so an iteration is pure device work: the CHT chunk-cache behaviour the
paper measures, reproduced on an XLA mesh.

Shares the SP2 *policy* (initial congruence, trace-correcting branch,
convergence / divergence monitor) with the single-host driver via
:mod:`repro.core.purify`, so both produce the same iterates.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.core.add import add_scaled_identity, identity
from repro.core.distributed import make_worker_mesh
from repro.core.matrix import BSMatrix
from repro.core.purify import PurifyStats, Sp2Monitor, sp2_init_coeffs, sp2_should_square
from repro.kernels.precision import Precision
from repro.core.schedule import SpgemmPlan, plan_stats
from repro.obs.health import HealthMonitor, HealthPolicy
from repro.obs.locality import locality_iteration, locality_snapshot
from repro.obs.log import log_of
from repro.obs.timing import IterationScope
from repro.obs.tracer import run_metrics, tracer_of

from .balance import (
    LoadMonitor,
    RebalancePolicy,
    block_reference_weights,
    map_block_weights,
    measure_iteration_load,
)
from .cache import PlanCache
from .collectives import (
    dist_add,
    dist_frobenius_norm,
    dist_trace,
    dist_transpose,
    dist_truncate,
    dist_truncate_hierarchical,
)
from .matrix import DistBSMatrix, resident_block_norms, scatter
from .multiply import dist_multiply, dist_spamm

__all__ = [
    "dist_sp2_purify",
    "DistPurifyStats",
    "dist_lanczos_bounds",
    "LanczosDivergence",
    "dist_sqrt_inv_pipeline",
    "SqrtInvPipelineStats",
]


@dataclasses.dataclass
class DistPurifyStats:
    """Per-run and per-iteration metrics of the distributed SP2 loop."""

    iterations: int
    trace_history: list
    idempotency_history: list
    nnzb_history: list
    cache: dict  # run_metrics(cache) at exit: PlanCache.stats() keys plus
    # every tracer counter/gauge when tracing was enabled
    per_iter: list  # shared-schema rows (repro.obs.timing.SHARED_ITER_KEYS
    # plus SP2 extras): plan-cache hits/misses, recv bytes, nnzb, measured
    # worker-load imbalance (always) and imbalance_after / migrated_bytes
    # when a rebalance= policy re-laid the iterate out
    rebalances: int = 0  # re-layouts performed by the rebalance= policy
    # wall-clock calibration of the rebalance policy's cost coefficients
    # (repro.dist.balance.calibrate_policy report); None without rebalance=
    calibration: dict | None = None
    # HealthMonitor.summary() (alerts, live-policy refits); None without
    # health= monitoring
    health: dict | None = None

    def as_purify_stats(self) -> PurifyStats:
        return PurifyStats(
            self.iterations,
            self.trace_history,
            self.idempotency_history,
            self.nnzb_history,
        )


def dist_sp2_purify(
    f: BSMatrix | DistBSMatrix,
    n_occ: float,
    lmin: float,
    lmax: float,
    mesh: Mesh | None = None,
    *,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    trunc_method: str = "hierarchical",
    spamm_method: str = "delta",
    impl: str = "fused",
    exchange: str = "p2p",
    precision: Precision | None = None,
    cache: PlanCache | None = None,
    return_resident: bool = False,
    rebalance: RebalancePolicy | None = None,
    tracer=None,
    log=None,
    health: HealthPolicy | None = None,
) -> tuple[BSMatrix | DistBSMatrix, DistPurifyStats]:
    """SP2 purification with every iterate resident on the worker mesh.

    Accepts a host ``BSMatrix`` (scattered once) or an already-resident
    ``DistBSMatrix``.  Returns the gathered density matrix and stats; pass a
    ``cache`` to share plans across calls (e.g. repeated SCF-style solves on
    a fixed sparsity pattern).  ``spamm_tau > 0`` replaces the exact multiply
    with hierarchical SpAMM (:func:`repro.dist.multiply.dist_spamm`): each
    square carries an error bound <= spamm_tau.

    Error control is hierarchical end to end by default:
    ``trunc_method="hierarchical"`` truncates via the quadtree subtree-drop
    descent on the resident norm table
    (:func:`repro.dist.collectives.dist_truncate_hierarchical`; ``"leaf"``
    selects the flat greedy :func:`~repro.dist.collectives.dist_truncate`),
    and ``spamm_method="delta"`` applies the per-iteration prune pattern as a
    task mask against the cached full-multiply plan (``"replan"`` builds a
    plan per pruned pattern).  With the defaults, one [P, cap] norm-table
    fetch per iteration is shared between truncation and the next SpAMM, and
    once the sparsity pattern stabilizes an iteration incurs *zero*
    plan-cache misses even while the ``tau``-prune pattern fluctuates — the
    inner loop is pure device work.

    ``return_resident=True`` skips the boundary gather and returns the best
    iterate as a :class:`~repro.dist.matrix.DistBSMatrix` — pipeline callers
    (:func:`dist_sqrt_inv_pipeline`) keep chaining resident operations on it.

    ``rebalance`` (a :class:`~repro.dist.balance.RebalancePolicy`) turns on
    dynamic load balancing: each iteration's multiply is measured into a
    per-worker cost model (executed tasks, exchange bytes, owned leaves —
    :func:`repro.dist.balance.worker_load`); when the combined max/mean
    imbalance exceeds the policy threshold the iterate is re-laid out on
    device (:func:`~repro.dist.collectives.dist_repartition`) along a
    weighted, subtree-aligned Morton cut before the next iteration.  Every
    per-iteration stats row carries the measured ``imbalance`` (also with
    ``rebalance=None``, so static runs are comparable), plus
    ``imbalance_after`` and ``migrated_bytes`` when a re-layout happened.
    Values are bit-identical to the static run — only the schedule changes.

    ``tracer`` (a :class:`repro.obs.Tracer`) turns on span tracing for the
    whole run: it is attached to the plan cache, so every collective,
    kernel dispatch and plan build records nested spans under one
    ``sp2_purify`` phase.  Tracing never touches numerics — results are
    bit-identical with it on, off, or NULL.

    ``log`` (a :class:`repro.obs.EventLog`) attaches the structured event
    log to the cache the same way: run start/end, per-iteration debug
    events, plan builds, rebalances and health alerts all land in it.
    ``health`` (a :class:`repro.obs.HealthPolicy`) turns on the online
    :class:`~repro.obs.health.HealthMonitor` — straggler / miss-storm /
    blowup / stall alerts, plus live calibration of the rebalance policy
    when ``rebalance`` is also on.  Like tracing, both are schedule- and
    report-only: results stay bit-identical.
    """
    cache = cache if cache is not None else PlanCache()
    if tracer is not None:
        cache.tracer = tracer
    if log is not None:
        cache.event_log = log
    trc = tracer_of(cache)
    lg = log_of(cache)
    hm = HealthMonitor(health, cache=cache) if health is not None else None
    rec = getattr(cache, "flight_recorder", None)
    if lg.enabled:
        lg.info("run_start", driver="sp2_purify", n=int(f.shape[0]),
                n_occ=float(n_occ), max_iter=max_iter, idem_tol=idem_tol,
                trunc_tau=trunc_tau, spamm_tau=spamm_tau)
    with trc.span("sp2_purify", cat="phase", n=int(f.shape[0])):
        scale, shift = sp2_init_coeffs(lmin, lmax)
        if isinstance(f, DistBSMatrix):
            assert mesh is None or mesh is f.mesh, (
                "resident F already lives on a mesh; drop the mesh argument "
                "or pass the one it was scattered onto"
            )
            mesh = f.mesh
            # X0 = scale*F + shift*I, built resident: only the diagonal
            # identity enters through scatter; F's store never leaves the mesh
            eye = scatter(identity(f.shape[0], f.bs, f.dtype), mesh)
            x = dist_add(f, eye, scale, shift, cache)
        else:
            mesh = mesh or make_worker_mesh()
            x0 = add_scaled_identity(f.scale(scale), shift)
            x = scatter(x0, mesh)

        traces, idems, nnzbs, per_iter = [], [], [], []
        monitor = Sp2Monitor(idem_tol)
        lb = LoadMonitor(x.nparts, rebalance) if rebalance is not None else None
        upfront_migrated = 0
        if lb is not None:
            # a skewed X0 (inherited from F's scatter) would pay one fully
            # imbalanced iteration before the first measured re-layout; fix
            # the ownership skew up-front (its bytes land in iteration 0's
            # row)
            x, upfront_migrated = lb.relayout_if_skewed(x, cache)
        best = x
        x_norms = None  # stack-order norm table of x, carried from truncation
        for it in range(max_iter):
            if rec is not None:
                rec.mark(cache)  # postmortem deltas cover the last iteration
            with IterationScope(cache, it, trc, name="sp2_iteration") as scope:
                lsnap = locality_snapshot(cache)
                x_op = x  # multiply operand: measured weights refer to it
                if spamm_tau > 0:
                    x2, mult_err = dist_spamm(
                        x, x, spamm_tau, cache,
                        exchange=exchange, impl=impl,
                        method=spamm_method, precision=precision,
                        a_norms=x_norms,
                    )
                else:
                    x2 = dist_multiply(
                        x, x, cache, exchange=exchange, impl=impl,
                        precision=precision,
                    )
                    mult_err = 0.0
                # peek the plan the multiply actually used (exact,
                # SpAMM-replan or SpAMM-delta — last_plan_key tracks all
                # three), so recv-bytes stats stay truthful for every mode
                entry = (
                    cache.peek(cache.last_plan_key)
                    if cache.last_plan_key is not None
                    else None
                )
                plan = entry[0] if entry is not None else None
                assert plan is None or isinstance(plan, SpgemmPlan)
                # measured per-worker cost of the multiply just executed
                # (reported in static runs too, so rebalanced and static
                # trajectories compare)
                leaf_w = (
                    (x_norms != 0.0).astype(np.float64)
                    if x_norms is not None
                    else None
                )
                load = measure_iteration_load(cache, plan, leaf_w, leaf_w)
                imb = None
                if load is not None:
                    imb = lb.observe(load) if lb is not None else load.imbalance()
                idem = dist_frobenius_norm(dist_add(x2, x, 1.0, -1.0, cache), cache)
                tr = dist_trace(x, cache)
                traces.append(tr)
                idems.append(idem)
                nnzbs.append(x.nnzb)
                nnzb_it = x.nnzb
                stop = monitor.update(it, idem)
                if stop and monitor.stop_reason == "diverged":
                    if lg.enabled:
                        lg.warn("sp2_divergence", iteration=it, idem=idem,
                                best_idem=monitor.best_idem,
                                best_iter=monitor.best_iter)
                    if trc.enabled:
                        trc.instant("sp2_divergence", cat="health",
                                    iteration=it, idem=idem)
                    if rec is not None:
                        rec.dump("sp2_divergence", cache, iteration=it,
                                 idem=float(idem),
                                 best_idem=float(monitor.best_idem),
                                 best_iter=monitor.best_iter)
                if monitor.improved:
                    best = x
                nfb = 0
                if not stop:
                    if sp2_should_square(tr, n_occ):
                        x = x2
                    else:
                        x = dist_add(x, x2, 2.0, -1.0, cache)
                    x_norms = None
                    if trunc_tau > 0:
                        if trunc_method == "hierarchical":
                            # one norm-table fetch serves both the truncation
                            # descent and the next iteration's SpAMM:
                            # compaction keeps block values, so the kept
                            # subset of the table is the truncated matrix's
                            pre_norms = resident_block_norms(x, cache)
                            nfb = pre_norms.shape[0] * 4
                            info: dict = {}
                            x = dist_truncate_hierarchical(
                                x, trunc_tau, cache, norms=pre_norms, stats=info
                            )
                            x_norms = pre_norms[info["kept"]]
                        else:
                            assert trunc_method == "leaf", trunc_method
                            x = dist_truncate(x, trunc_tau, cache)
                imb_after, migrated = None, upfront_migrated
                upfront_migrated = 0
                if (
                    lb is not None
                    and not stop
                    and load is not None
                    and lb.should_rebalance(load)
                    and plan is not None
                ):
                    # measured per-block weights: reads of each operand block
                    # in the executed task list plus one unit of ownership,
                    # mapped onto the updated iterate's structure by Morton
                    # code
                    wa, wb = block_reference_weights(
                        plan.tasks, x_op.nnzb, x_op.nnzb
                    )
                    w = map_block_weights(
                        x_op.coords, wa + wb + 1.0, x.coords, default=1.0
                    )
                    # x_norms is stack-ordered, so it survives the re-layout
                    x, moved, imb_after = lb.migrate(x, w, cache)
                    migrated += moved
                # built after the update + truncation so each row carries its
                # own iteration's full cache/timing deltas (truncation
                # included)
                row = scope.row(
                    nnzb=nnzb_it,
                    idem=idem,
                    trace=tr,
                    spamm_err=mult_err,
                    recv_bytes_mean=(
                        plan_stats(plan)["recv_bytes_mean"]
                        if plan is not None
                        else 0.0
                    ),
                    norm_fetch_bytes=nfb,
                    imbalance=imb,
                    imbalance_after=imb_after,
                    migrated_bytes=migrated,
                    **locality_iteration(cache, scope, lsnap,
                                         iteration=it, driver="sp2"),
                )
                per_iter.append(row)
                if lb is not None and load is not None:
                    # wall-clock feedback: the measured iteration time
                    # calibrates the policy's cost coefficients
                    lb.note_wall(row["wall_s"])
                if lg.debug_enabled:
                    lg.debug("iteration", driver="sp2", **{
                        k: row[k] for k in ("iteration", "nnzb", "idem",
                                            "wall_s", "cache_hits",
                                            "cache_misses",
                                            "recv_bytes_mean")})
                if hm is not None:
                    hm.observe(row, load)
                    hm.maybe_refit(lb)
            if stop:
                break
    if lg.enabled:
        lg.info("run_end", driver="sp2_purify", iterations=len(traces),
                stop_reason=monitor.stop_reason,
                best_idem=monitor.best_idem, nnzb=best.nnzb)
    return (best if return_resident else best.gather()), DistPurifyStats(
        len(traces), traces, idems, nnzbs, run_metrics(cache), per_iter,
        rebalances=lb.rebalances if lb is not None else 0,
        calibration=lb.calibration()[1] if lb is not None else None,
        health=hm.summary() if hm is not None else None,
    )


# --------------------------------------------------------------------------
# end-to-end SPD pipeline: S -> Z -> Z^T H Z -> SP2 (-> Z D Z^T)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SqrtInvPipelineStats:
    """Per-stage metrics of :func:`dist_sqrt_inv_pipeline`.

    ``inverse`` / ``purify`` are the stage drivers' own stats objects
    (refinement iterations, per-iteration plan hit/miss rows, bytes moved);
    ``congruence`` and ``back_transform`` carry the cache deltas and wall
    time of the two multiply pairs; ``bounds`` records the (lmin, lmax) the
    SP2 stage ran with (estimated from the resident norm table when the
    caller supplied none); ``cache`` is the shared PlanCache at exit.
    """

    inverse: object  # DistInverseStats
    purify: DistPurifyStats
    congruence: dict
    back_transform: dict | None
    bounds: tuple
    cache: dict


def _spectral_bounds_from_norms(coords, norms) -> tuple[float, float]:
    """Symmetric spectral enclosure from the resident block-norm table.

    ``||F||_2 <= max_i sum_j ||F_ij||_2 <= max_i sum_j ||F_ij||_F`` — a
    block row-sum (Gershgorin-style) bound computed from the tiny norm
    table, so estimating SP2's eigenvalue interval costs no extra block
    data transfer.  Loose bounds cost SP2 iterations, never correctness.
    """
    rows = np.asarray(coords)[:, 0]
    sums = np.zeros(int(rows.max()) + 1 if rows.size else 1, dtype=np.float64)
    np.add.at(sums, rows, np.asarray(norms, dtype=np.float64))
    b = float(sums.max()) if rows.size else 0.0
    if b == 0.0:
        return -1.0, 1.0  # F == 0: any nondegenerate enclosure of {0} works
    return -b, b


class LanczosDivergence(RuntimeError):
    """The Lanczos recurrence left the finite regime (non-finite alpha /
    beta, or the tridiagonal eigensolve failed) — the caller falls back to
    the block-Gershgorin enclosure."""


def _lanczos_ritz(
    f: DistBSMatrix, cache, steps: int, seed: int
) -> tuple[float, float]:
    """The raw Lanczos sweep; raises :class:`LanczosDivergence` on any
    non-finite recurrence coefficient or eigensolve failure."""
    n, bs = f.shape[0], f.bs
    rng = np.random.default_rng(seed)
    v0 = rng.standard_normal(n)
    v0 /= np.linalg.norm(v0)
    col = np.zeros((n, bs), dtype=f.dtype)
    col[:, 0] = v0
    vcur = scatter(BSMatrix.from_dense(col, bs), f.mesh)
    vprev = None
    beta = 0.0
    alphas: list[float] = []
    betas: list[float] = []
    for _ in range(max(int(steps), 1)):
        w = dist_multiply(f, vcur, cache)
        vt = dist_transpose(vcur, cache)
        alpha = dist_trace(dist_multiply(vt, w, cache), cache)
        if not np.isfinite(alpha):
            raise LanczosDivergence(f"non-finite alpha {alpha!r}")
        w = dist_add(w, vcur, 1.0, -alpha, cache)
        if vprev is not None:
            w = dist_add(w, vprev, 1.0, -beta, cache)
        alphas.append(alpha)
        beta = dist_frobenius_norm(w, cache)
        if not np.isfinite(beta):
            raise LanczosDivergence(f"non-finite beta {beta!r}")
        betas.append(beta)
        if beta <= 1e-12 * max(abs(alpha), 1.0):
            break  # invariant subspace: Ritz values are exact eigenvalues
        vprev, vcur = vcur, w.scale(1.0 / beta)
    k = len(alphas)
    t = np.diag(np.asarray(alphas, dtype=np.float64))
    for i in range(k - 1):
        t[i, i + 1] = t[i + 1, i] = betas[i]
    try:
        theta, s = np.linalg.eigh(t)
    except np.linalg.LinAlgError as e:
        raise LanczosDivergence(f"tridiagonal eigensolve failed: {e}") from e
    eta = abs(betas[k - 1]) * np.abs(s[k - 1, :])
    lo, hi = float((theta - eta).min()), float((theta + eta).max())
    if not (np.isfinite(lo) and np.isfinite(hi)):
        raise LanczosDivergence(f"non-finite Ritz bounds ({lo}, {hi})")
    return lo, hi


def dist_lanczos_bounds(
    f: DistBSMatrix,
    cache: PlanCache | None = None,
    *,
    steps: int = 10,
    seed: int = 0,
) -> tuple[float, float]:
    """Ritz-value estimate of spec(F) from a few resident Lanczos steps.

    Tightens the block-Gershgorin enclosure
    (:func:`_spectral_bounds_from_norms`) without gathering F: the Lanczos
    vector lives on the mesh as an ``(n, bs)`` block-column matrix whose
    first column carries the vector, so every step is existing resident
    collectives — ``dist_multiply`` for F@v, transpose+multiply+``dist_trace``
    for the dot products, ``dist_add`` for the three-term recurrence and
    ``dist_frobenius_norm`` for the normalization.  All structures repeat
    across steps, so after the first step the plan cache is all-hit.

    Returns ``(lo, hi)`` — the extreme Ritz values widened by each pair's
    residual bound ``beta_k * |s_k|`` (the exact residual norm of the Ritz
    pair).  This is a sharp *estimate*, not a rigorous enclosure of the full
    spectrum; callers intersect it with the Gershgorin interval (so bounds
    never widen) and rely on SP2's divergence monitor as the backstop for a
    rare under-estimate.

    **Hardened** (the ROADMAP "Lanczos enclosure hardening" item): a
    divergence trip inside the sweep — non-finite recurrence coefficient or
    a failed tridiagonal eigensolve — falls back to the block-Gershgorin
    enclosure from the resident norm table instead of propagating NaNs into
    SP2's interval, and the trip is logged as a ``lanczos_fallback`` health
    event through the cache's :class:`~repro.obs.log.EventLog` + a tracer
    instant.  This is what lets ``lanczos_steps`` default on in
    :func:`dist_sqrt_inv_pipeline`.
    """
    assert f.shape[0] == f.shape[1], "spectral bounds need a square operand"
    try:
        return _lanczos_ritz(f, cache, steps, seed)
    except LanczosDivergence as e:
        lo, hi = _spectral_bounds_from_norms(
            f.coords, resident_block_norms(f, cache))
        lg = log_of(cache)
        if lg.enabled:
            lg.warn("lanczos_fallback", reason=str(e), steps=int(steps),
                    gershgorin_lo=lo, gershgorin_hi=hi)
        tr = tracer_of(cache)
        if tr.enabled:
            tr.instant("lanczos_fallback", cat="health", reason=str(e))
        return lo, hi


def dist_sqrt_inv_pipeline(
    s: BSMatrix | DistBSMatrix,
    h: BSMatrix | DistBSMatrix,
    n_occ: float,
    mesh: Mesh | None = None,
    *,
    lmin: float | None = None,
    lmax: float | None = None,
    tol: float = 1e-8,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    leaf_blocks: int = 1,
    impl: str = "fused",
    exchange: str = "p2p",
    precision: Precision | None = None,
    cache: PlanCache | None = None,
    transform_back: bool = True,
    rebalance: RebalancePolicy | None = None,
    lanczos_steps: int = 8,
    tracer=None,
    log=None,
    health: HealthPolicy | None = None,
) -> tuple[BSMatrix, SqrtInvPipelineStats]:
    """The paper's full electronic-structure workflow, resident end to end.

    Overlap matrix S -> inverse factor Z (localized inverse factorization,
    Z^T S Z = I) -> congruence transform F = Z^T H Z into the orthonormal
    basis -> SP2 purification of F -> density matrix back in the original
    basis, D = Z D_ortho Z^T (skipped with ``transform_back=False``).  S and
    H enter the mesh once (or arrive already resident); every intermediate
    stays sharded; the returned density matrix is the single boundary
    gather.  All stages share one :class:`~repro.dist.cache.PlanCache`, so
    structures recurring across stages (Z, its transpose, the stabilized
    SP2 iterate) are planned and compiled exactly once.

    When ``lmin`` / ``lmax`` are omitted, the SP2 eigenvalue interval is
    estimated from F's resident norm table (block Gershgorin row sums — no
    block data leaves the mesh for it); ``lanczos_steps > 0`` (**default
    on** now that :func:`dist_lanczos_bounds` falls back to Gershgorin on a
    divergence trip) refines that interval with a few resident Lanczos
    steps, intersected with the Gershgorin enclosure so it can only
    tighten — a loose row-sum bound costs SP2 iterations, and the
    refinement buys them back without gathering F.  Pass
    ``lanczos_steps=0`` for the pure Gershgorin interval.

    ``rebalance`` (a :class:`~repro.dist.balance.RebalancePolicy`) enables
    dynamic load balancing in both iterative stages — the inverse refinement
    loop and SP2 — re-laying iterates out on device when the measured
    per-worker cost model reports imbalance above the policy threshold.

    ``tracer`` (a :class:`repro.obs.Tracer`) records the whole workflow as
    one span timeline: inverse / congruence / spectral-bounds / SP2 /
    back-transform phases with every collective, plan build and kernel
    dispatch nested beneath — export with
    :func:`repro.obs.write_chrome_trace`.
    """
    from .inverse import dist_localized_inverse_factorization

    cache = cache if cache is not None else PlanCache()
    if tracer is not None:
        cache.tracer = tracer
    if log is not None:
        cache.event_log = log
    trc = tracer_of(cache)
    if isinstance(s, DistBSMatrix):
        assert mesh is None or list(mesh.devices.flat) == list(
            s.mesh.devices.flat
        ), "resident S lives on a different device set than the given mesh"
        mesh = s.mesh
        ds = s
    else:
        mesh = mesh or make_worker_mesh()
        ds = scatter(s, mesh)
    if isinstance(h, DistBSMatrix):
        assert list(h.mesh.devices.flat) == list(mesh.devices.flat), (
            "resident H lives on a different device set than S's mesh"
        )
        dh = h
    else:
        dh = scatter(h, mesh)
    assert ds.shape == dh.shape and ds.bs == dh.bs, (ds.shape, dh.shape)

    z, inv_stats = dist_localized_inverse_factorization(
        ds, cache, tol=tol, max_iter=max_iter, trunc_tau=trunc_tau,
        spamm_tau=spamm_tau, leaf_blocks=leaf_blocks, exchange=exchange,
        impl=impl, precision=precision, rebalance=rebalance, health=health,
    )

    with IterationScope(cache, None, trc, name="congruence", cat="phase") as sc:
        zt = dist_transpose(z, cache)
        f_ortho = dist_multiply(
            dist_multiply(
                zt, dh, cache, exchange=exchange, impl=impl,
                precision=precision,
            ),
            z, cache, exchange=exchange, impl=impl, precision=precision,
        )
        congruence = sc.delta()

    if lmin is None or lmax is None:
        with trc.span("spectral_bounds", cat="phase", lanczos=lanczos_steps):
            lo, hi = _spectral_bounds_from_norms(
                f_ortho.coords, resident_block_norms(f_ortho, cache)
            )
            if lanczos_steps > 0:
                llo, lhi = dist_lanczos_bounds(
                    f_ortho, cache, steps=lanczos_steps
                )
                # intersect with the Gershgorin enclosure: refinement can
                # only tighten the interval, never widen it
                if max(lo, llo) < min(hi, lhi):
                    lo, hi = max(lo, llo), min(hi, lhi)
        lmin = lo if lmin is None else lmin
        lmax = hi if lmax is None else lmax

    d_ortho, purify_stats = dist_sp2_purify(
        f_ortho, n_occ, lmin, lmax, max_iter=max_iter, idem_tol=idem_tol,
        trunc_tau=trunc_tau, spamm_tau=spamm_tau, impl=impl,
        exchange=exchange, precision=precision, cache=cache,
        return_resident=True, rebalance=rebalance, health=health,
    )

    back = None
    if transform_back:
        with IterationScope(
            cache, None, trc, name="back_transform", cat="phase"
        ) as sb:
            d = dist_multiply(
                dist_multiply(
                    z, d_ortho, cache, exchange=exchange, impl=impl,
                    precision=precision,
                ),
                zt, cache, exchange=exchange, impl=impl, precision=precision,
            )
            back = sb.delta()
        result = d.gather()
    else:
        result = d_ortho.gather()
    return result, SqrtInvPipelineStats(
        inv_stats, purify_stats, congruence, back, (lmin, lmax),
        run_metrics(cache),
    )
