"""End-to-end distributed SP2 purification on resident matrices.

The full iterative loop — multiply via a cached plan, add / trace /
Frobenius norm / truncate via the resident collectives — runs on
:class:`~repro.dist.matrix.DistBSMatrix` stores that never leave the worker
mesh.  The host only sees scalars (trace, idempotency) and tiny index
tables each iteration; after the sparsity pattern stabilizes under
truncation every planning step is a :class:`~repro.dist.cache.PlanCache`
hit, so an iteration is pure device work: the CHT chunk-cache behaviour the
paper measures, reproduced on an XLA mesh.

Shares the SP2 *policy* (initial congruence, trace-correcting branch,
convergence / divergence monitor) with the single-host driver via
:mod:`repro.core.purify`, so both produce the same iterates.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
from jax.sharding import Mesh

from repro.core.add import add_scaled_identity, identity
from repro.core.distributed import make_worker_mesh
from repro.core.matrix import BSMatrix
from repro.core.purify import PurifyStats, Sp2Monitor, sp2_init_coeffs, sp2_should_square
from repro.core.schedule import SpgemmPlan, plan_stats

from .cache import PlanCache
from .collectives import (
    dist_add,
    dist_frobenius_norm,
    dist_trace,
    dist_transpose,
    dist_truncate,
    dist_truncate_hierarchical,
)
from .matrix import DistBSMatrix, resident_block_norms, scatter
from .multiply import dist_multiply, dist_spamm

__all__ = [
    "dist_sp2_purify",
    "DistPurifyStats",
    "dist_sqrt_inv_pipeline",
    "SqrtInvPipelineStats",
]


@dataclasses.dataclass
class DistPurifyStats:
    """Per-run and per-iteration metrics of the distributed SP2 loop."""

    iterations: int
    trace_history: list
    idempotency_history: list
    nnzb_history: list
    cache: dict  # PlanCache.stats() at exit
    per_iter: list  # dicts: plan-cache hits/misses, recv bytes, nnzb

    def as_purify_stats(self) -> PurifyStats:
        return PurifyStats(
            self.iterations,
            self.trace_history,
            self.idempotency_history,
            self.nnzb_history,
        )


def dist_sp2_purify(
    f: BSMatrix | DistBSMatrix,
    n_occ: float,
    lmin: float,
    lmax: float,
    mesh: Mesh | None = None,
    *,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    trunc_method: str = "hierarchical",
    spamm_method: str = "delta",
    impl: str = "ref",
    exchange: str = "p2p",
    cache: PlanCache | None = None,
    return_resident: bool = False,
) -> tuple[BSMatrix | DistBSMatrix, DistPurifyStats]:
    """SP2 purification with every iterate resident on the worker mesh.

    Accepts a host ``BSMatrix`` (scattered once) or an already-resident
    ``DistBSMatrix``.  Returns the gathered density matrix and stats; pass a
    ``cache`` to share plans across calls (e.g. repeated SCF-style solves on
    a fixed sparsity pattern).  ``spamm_tau > 0`` replaces the exact multiply
    with hierarchical SpAMM (:func:`repro.dist.multiply.dist_spamm`): each
    square carries an error bound <= spamm_tau.

    Error control is hierarchical end to end by default:
    ``trunc_method="hierarchical"`` truncates via the quadtree subtree-drop
    descent on the resident norm table
    (:func:`repro.dist.collectives.dist_truncate_hierarchical`; ``"leaf"``
    selects the flat greedy :func:`~repro.dist.collectives.dist_truncate`),
    and ``spamm_method="delta"`` applies the per-iteration prune pattern as a
    task mask against the cached full-multiply plan (``"replan"`` builds a
    plan per pruned pattern).  With the defaults, one [P, cap] norm-table
    fetch per iteration is shared between truncation and the next SpAMM, and
    once the sparsity pattern stabilizes an iteration incurs *zero*
    plan-cache misses even while the ``tau``-prune pattern fluctuates — the
    inner loop is pure device work.

    ``return_resident=True`` skips the boundary gather and returns the best
    iterate as a :class:`~repro.dist.matrix.DistBSMatrix` — pipeline callers
    (:func:`dist_sqrt_inv_pipeline`) keep chaining resident operations on it.
    """
    cache = cache if cache is not None else PlanCache()
    scale, shift = sp2_init_coeffs(lmin, lmax)
    if isinstance(f, DistBSMatrix):
        assert mesh is None or mesh is f.mesh, (
            "resident F already lives on a mesh; drop the mesh argument or "
            "pass the one it was scattered onto"
        )
        mesh = f.mesh
        # X0 = scale*F + shift*I, built resident: only the diagonal identity
        # enters through scatter; F's store never leaves the mesh
        eye = scatter(identity(f.shape[0], f.bs, f.dtype), mesh)
        x = dist_add(f, eye, scale, shift, cache)
    else:
        mesh = mesh or make_worker_mesh()
        x0 = add_scaled_identity(f.scale(scale), shift)
        x = scatter(x0, mesh)

    traces, idems, nnzbs, per_iter = [], [], [], []
    monitor = Sp2Monitor(idem_tol)
    best = x
    x_norms = None  # stack-order norm table of x, carried over from truncation
    for it in range(max_iter):
        snap, t0 = cache.snapshot(), time.perf_counter()
        if spamm_tau > 0:
            x2, mult_err = dist_spamm(
                x, x, spamm_tau, cache,
                exchange=exchange, impl=impl,
                method=spamm_method, a_norms=x_norms,
            )
        else:
            x2 = dist_multiply(x, x, cache, exchange=exchange, impl=impl)
            mult_err = 0.0
        # peek the plan the multiply actually used (exact, SpAMM-replan or
        # SpAMM-delta — last_plan_key tracks all three), so recv-bytes stats
        # stay truthful for every multiply mode
        entry = (
            cache.peek(cache.last_plan_key)
            if cache.last_plan_key is not None
            else None
        )
        plan = entry[0] if entry is not None else None
        assert plan is None or isinstance(plan, SpgemmPlan)
        idem = dist_frobenius_norm(dist_add(x2, x, 1.0, -1.0, cache), cache)
        tr = dist_trace(x, cache)
        traces.append(tr)
        idems.append(idem)
        nnzbs.append(x.nnzb)
        nnzb_it = x.nnzb
        stop = monitor.update(it, idem)
        if monitor.improved:
            best = x
        if not stop:
            if sp2_should_square(tr, n_occ):
                x = x2
            else:
                x = dist_add(x, x2, 2.0, -1.0, cache)
            x_norms = None
            if trunc_tau > 0:
                if trunc_method == "hierarchical":
                    # one norm-table fetch serves both the truncation descent
                    # and the next iteration's SpAMM: compaction keeps block
                    # values, so the kept subset of the table is the
                    # truncated matrix's
                    pre_norms = resident_block_norms(x, cache)
                    info: dict = {}
                    x = dist_truncate_hierarchical(
                        x, trunc_tau, cache, norms=pre_norms, stats=info
                    )
                    x_norms = pre_norms[info["kept"]]
                else:
                    assert trunc_method == "leaf", trunc_method
                    x = dist_truncate(x, trunc_tau, cache)
        # appended after the update + truncation so each row carries its own
        # iteration's full cache/timing deltas (truncation included)
        per_iter.append(
            dict(
                iteration=it,
                nnzb=nnzb_it,
                idem=idem,
                trace=tr,
                spamm_err=mult_err,
                recv_bytes_mean=(
                    plan_stats(plan)["recv_bytes_mean"] if plan is not None else 0.0
                ),
                wall_s=time.perf_counter() - t0,
                **cache.delta(snap),
            )
        )
        if stop:
            break
    return (best if return_resident else best.gather()), DistPurifyStats(
        len(traces), traces, idems, nnzbs, cache.stats(), per_iter
    )


# --------------------------------------------------------------------------
# end-to-end SPD pipeline: S -> Z -> Z^T H Z -> SP2 (-> Z D Z^T)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SqrtInvPipelineStats:
    """Per-stage metrics of :func:`dist_sqrt_inv_pipeline`.

    ``inverse`` / ``purify`` are the stage drivers' own stats objects
    (refinement iterations, per-iteration plan hit/miss rows, bytes moved);
    ``congruence`` and ``back_transform`` carry the cache deltas and wall
    time of the two multiply pairs; ``bounds`` records the (lmin, lmax) the
    SP2 stage ran with (estimated from the resident norm table when the
    caller supplied none); ``cache`` is the shared PlanCache at exit.
    """

    inverse: object  # DistInverseStats
    purify: DistPurifyStats
    congruence: dict
    back_transform: dict | None
    bounds: tuple
    cache: dict


def _spectral_bounds_from_norms(coords, norms) -> tuple[float, float]:
    """Symmetric spectral enclosure from the resident block-norm table.

    ``||F||_2 <= max_i sum_j ||F_ij||_2 <= max_i sum_j ||F_ij||_F`` — a
    block row-sum (Gershgorin-style) bound computed from the tiny norm
    table, so estimating SP2's eigenvalue interval costs no extra block
    data transfer.  Loose bounds cost SP2 iterations, never correctness.
    """
    rows = np.asarray(coords)[:, 0]
    sums = np.zeros(int(rows.max()) + 1 if rows.size else 1, dtype=np.float64)
    np.add.at(sums, rows, np.asarray(norms, dtype=np.float64))
    b = float(sums.max()) if rows.size else 0.0
    if b == 0.0:
        return -1.0, 1.0  # F == 0: any nondegenerate enclosure of {0} works
    return -b, b


def dist_sqrt_inv_pipeline(
    s: BSMatrix | DistBSMatrix,
    h: BSMatrix | DistBSMatrix,
    n_occ: float,
    mesh: Mesh | None = None,
    *,
    lmin: float | None = None,
    lmax: float | None = None,
    tol: float = 1e-8,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    leaf_blocks: int = 1,
    impl: str = "ref",
    exchange: str = "p2p",
    cache: PlanCache | None = None,
    transform_back: bool = True,
) -> tuple[BSMatrix, SqrtInvPipelineStats]:
    """The paper's full electronic-structure workflow, resident end to end.

    Overlap matrix S -> inverse factor Z (localized inverse factorization,
    Z^T S Z = I) -> congruence transform F = Z^T H Z into the orthonormal
    basis -> SP2 purification of F -> density matrix back in the original
    basis, D = Z D_ortho Z^T (skipped with ``transform_back=False``).  S and
    H enter the mesh once (or arrive already resident); every intermediate
    stays sharded; the returned density matrix is the single boundary
    gather.  All stages share one :class:`~repro.dist.cache.PlanCache`, so
    structures recurring across stages (Z, its transpose, the stabilized
    SP2 iterate) are planned and compiled exactly once.

    When ``lmin`` / ``lmax`` are omitted, the SP2 eigenvalue interval is
    estimated from F's resident norm table (block Gershgorin row sums — no
    block data leaves the mesh for it).
    """
    from .inverse import dist_localized_inverse_factorization

    cache = cache if cache is not None else PlanCache()
    if isinstance(s, DistBSMatrix):
        assert mesh is None or list(mesh.devices.flat) == list(
            s.mesh.devices.flat
        ), "resident S lives on a different device set than the given mesh"
        mesh = s.mesh
        ds = s
    else:
        mesh = mesh or make_worker_mesh()
        ds = scatter(s, mesh)
    if isinstance(h, DistBSMatrix):
        assert list(h.mesh.devices.flat) == list(mesh.devices.flat), (
            "resident H lives on a different device set than S's mesh"
        )
        dh = h
    else:
        dh = scatter(h, mesh)
    assert ds.shape == dh.shape and ds.bs == dh.bs, (ds.shape, dh.shape)

    z, inv_stats = dist_localized_inverse_factorization(
        ds, cache, tol=tol, max_iter=max_iter, trunc_tau=trunc_tau,
        spamm_tau=spamm_tau, leaf_blocks=leaf_blocks, exchange=exchange,
        impl=impl,
    )

    snap, t0 = cache.snapshot(), time.perf_counter()
    zt = dist_transpose(z, cache)
    f_ortho = dist_multiply(
        dist_multiply(zt, dh, cache, exchange=exchange, impl=impl),
        z, cache, exchange=exchange, impl=impl,
    )
    congruence = dict(wall_s=time.perf_counter() - t0, **cache.delta(snap))

    if lmin is None or lmax is None:
        lo, hi = _spectral_bounds_from_norms(
            f_ortho.coords, resident_block_norms(f_ortho, cache)
        )
        lmin = lo if lmin is None else lmin
        lmax = hi if lmax is None else lmax

    d_ortho, purify_stats = dist_sp2_purify(
        f_ortho, n_occ, lmin, lmax, max_iter=max_iter, idem_tol=idem_tol,
        trunc_tau=trunc_tau, spamm_tau=spamm_tau, impl=impl,
        exchange=exchange, cache=cache, return_resident=True,
    )

    back = None
    if transform_back:
        snap, t0 = cache.snapshot(), time.perf_counter()
        d = dist_multiply(
            dist_multiply(z, d_ortho, cache, exchange=exchange, impl=impl),
            zt, cache, exchange=exchange, impl=impl,
        )
        back = dict(wall_s=time.perf_counter() - t0, **cache.delta(snap))
        result = d.gather()
    else:
        result = d_ortho.gather()
    return result, SqrtInvPipelineStats(
        inv_stats, purify_stats, congruence, back, (lmin, lmax), cache.stats()
    )
