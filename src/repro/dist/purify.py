"""End-to-end distributed SP2 purification on resident matrices.

The full iterative loop — multiply via a cached plan, add / trace /
Frobenius norm / truncate via the resident collectives — runs on
:class:`~repro.dist.matrix.DistBSMatrix` stores that never leave the worker
mesh.  The host only sees scalars (trace, idempotency) and tiny index
tables each iteration; after the sparsity pattern stabilizes under
truncation every planning step is a :class:`~repro.dist.cache.PlanCache`
hit, so an iteration is pure device work: the CHT chunk-cache behaviour the
paper measures, reproduced on an XLA mesh.

Shares the SP2 *policy* (initial congruence, trace-correcting branch,
convergence / divergence monitor) with the single-host driver via
:mod:`repro.core.purify`, so both produce the same iterates.
"""

from __future__ import annotations

import dataclasses
import time

from jax.sharding import Mesh

from repro.core.add import add_scaled_identity, identity
from repro.core.distributed import make_worker_mesh
from repro.core.matrix import BSMatrix
from repro.core.purify import PurifyStats, Sp2Monitor, sp2_init_coeffs, sp2_should_square
from repro.core.schedule import plan_stats

from .cache import PlanCache
from .collectives import dist_add, dist_frobenius_norm, dist_trace, dist_truncate
from .matrix import DistBSMatrix, scatter
from .multiply import dist_multiply, dist_spamm, multiply_plan_key

__all__ = ["dist_sp2_purify", "DistPurifyStats"]


@dataclasses.dataclass
class DistPurifyStats:
    """Per-run and per-iteration metrics of the distributed SP2 loop."""

    iterations: int
    trace_history: list
    idempotency_history: list
    nnzb_history: list
    cache: dict  # PlanCache.stats() at exit
    per_iter: list  # dicts: plan-cache hits/misses, recv bytes, nnzb

    def as_purify_stats(self) -> PurifyStats:
        return PurifyStats(
            self.iterations,
            self.trace_history,
            self.idempotency_history,
            self.nnzb_history,
        )


def dist_sp2_purify(
    f: BSMatrix | DistBSMatrix,
    n_occ: float,
    lmin: float,
    lmax: float,
    mesh: Mesh | None = None,
    *,
    max_iter: int = 100,
    idem_tol: float = 1e-8,
    trunc_tau: float = 0.0,
    spamm_tau: float = 0.0,
    impl: str = "ref",
    exchange: str = "p2p",
    cache: PlanCache | None = None,
) -> tuple[BSMatrix, DistPurifyStats]:
    """SP2 purification with every iterate resident on the worker mesh.

    Accepts a host ``BSMatrix`` (scattered once) or an already-resident
    ``DistBSMatrix``.  Returns the gathered density matrix and stats; pass a
    ``cache`` to share plans across calls (e.g. repeated SCF-style solves on
    a fixed sparsity pattern).  ``spamm_tau > 0`` replaces the exact multiply
    with hierarchical SpAMM (:func:`repro.dist.multiply.dist_spamm`): each
    square carries an error bound <= spamm_tau, and the pruned task list is
    threaded into the cached plan.
    """
    cache = cache if cache is not None else PlanCache()
    scale, shift = sp2_init_coeffs(lmin, lmax)
    if isinstance(f, DistBSMatrix):
        assert mesh is None or mesh is f.mesh, (
            "resident F already lives on a mesh; drop the mesh argument or "
            "pass the one it was scattered onto"
        )
        mesh = f.mesh
        # X0 = scale*F + shift*I, built resident: only the diagonal identity
        # enters through scatter; F's store never leaves the mesh
        eye = scatter(identity(f.shape[0], f.bs, f.dtype), mesh)
        x = dist_add(f, eye, scale, shift, cache)
    else:
        mesh = mesh or make_worker_mesh()
        x0 = add_scaled_identity(f.scale(scale), shift)
        x = scatter(x0, mesh)

    traces, idems, nnzbs, per_iter = [], [], [], []
    monitor = Sp2Monitor(idem_tol)
    best = x
    for it in range(max_iter):
        h0, m0, t0 = cache.hits, cache.misses, time.perf_counter()
        if spamm_tau > 0:
            x2, mult_err = dist_spamm(x, x, spamm_tau, cache, exchange=exchange, impl=impl)
        else:
            x2 = dist_multiply(x, x, cache, exchange=exchange, impl=impl)
            mult_err = 0.0
        idem = dist_frobenius_norm(dist_add(x2, x, 1.0, -1.0, cache), cache)
        tr = dist_trace(x, cache)
        traces.append(tr)
        idems.append(idem)
        nnzbs.append(x.nnzb)
        entry = (
            cache.peek(multiply_plan_key(x, x, exchange=exchange, impl=impl))
            if spamm_tau <= 0
            else None
        )
        plan = entry[0] if entry is not None else None
        per_iter.append(
            dict(
                iteration=it,
                nnzb=x.nnzb,
                idem=idem,
                trace=tr,
                cache_hits=cache.hits - h0,
                cache_misses=cache.misses - m0,
                spamm_err=mult_err,
                recv_bytes_mean=(
                    plan_stats(plan)["recv_bytes_mean"] if plan is not None else 0.0
                ),
                wall_s=time.perf_counter() - t0,
            )
        )
        stop = monitor.update(it, idem)
        if monitor.improved:
            best = x
        if stop:
            break
        if sp2_should_square(tr, n_occ):
            x = x2
        else:
            x = dist_add(x, x2, 2.0, -1.0, cache)
        if trunc_tau > 0:
            x = dist_truncate(x, trunc_tau, cache)
    return best.gather(), DistPurifyStats(
        len(traces), traces, idems, nnzbs, cache.stats(), per_iter
    )
