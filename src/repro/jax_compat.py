"""Version-tolerant jax API lookups shared across the library.

``shard_map`` moved from :mod:`jax.experimental.shard_map` (jax 0.4.x, where
the replication-check kwarg is ``check_rep``) to the top-level :mod:`jax`
namespace (newer releases, kwarg ``check_vma``).  All call sites go through
:func:`shard_map` here so the same code runs on both.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
