"""On-disk tile autotuner for the grouped block-matmul kernels.

Replaces the static ``_pick_tile`` heuristic: winners measured per
``(platform, bm, bk, bn, dtype)`` are persisted in a small JSON cache (keyed
like the plan cache: structure-independent knobs only) and looked up by
:func:`pick_tiles` before every kernel dispatch.  Untuned shapes — and any
unreadable/corrupt cache file — fall back to the heuristic, so the tuner is
strictly opt-in: correctness never depends on the cache.

The timing machinery (:func:`time_call`) is shared with
``benchmarks/kernel_micro.py`` so benchmark numbers and autotune decisions
come from one stopwatch.
"""

from __future__ import annotations

import json
import os
import tempfile

from ..obs.timing import Stopwatch

__all__ = [
    "time_call",
    "heuristic_tiles",
    "tile_key",
    "default_cache_path",
    "load_tile_cache",
    "save_tile_entry",
    "pick_tiles",
    "autotune_tiles",
    "clear_memo",
]

CACHE_VERSION = 1
_ENV_VAR = "REPRO_AUTOTUNE_CACHE"

# in-process memo of loaded cache files: path -> (mtime, entries dict)
_memo: dict[str, tuple[float, dict]] = {}


def time_call(fn, reps: int = 5) -> float:
    """Mean wall seconds per call after one warmup (compile) call."""
    fn()
    sw = Stopwatch()
    for _ in range(reps):
        fn()
    return sw.elapsed() / max(reps, 1)


def _pick_tile(n: int, cap: int = 512) -> int:
    """Largest divisor of n that is <= cap, preferring MXU-aligned sizes."""
    if n <= cap:
        return n
    for cand in (512, 384, 256, 128):
        if cand <= cap and n % cand == 0:
            return cand
    t = cap
    while n % t:
        t -= 1
    return t


def heuristic_tiles(bm: int, bk: int, bn: int, cap: int = 512) -> tuple[int, int, int]:
    """The pre-autotune static choice — the fallback for untuned shapes."""
    return _pick_tile(bm, cap), _pick_tile(bn, cap), _pick_tile(bk, cap)


def default_platform() -> str:
    import jax

    return jax.default_backend()


def tile_key(platform: str, bm: int, bk: int, bn: int, dtype) -> str:
    return f"{platform}|{int(bm)}x{int(bk)}x{int(bn)}|{str(dtype)}"


def default_cache_path() -> str:
    path = os.environ.get(_ENV_VAR)
    if path:
        return path
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )


def load_tile_cache(path: str | None = None) -> dict:
    """Entries from the on-disk cache; {} when missing or corrupt.

    A malformed file (truncated write, wrong schema version, junk) must
    never break a kernel dispatch — it reads as empty and the heuristic
    takes over.
    """
    path = path or default_cache_path()
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    memo = _memo.get(path)
    if memo is not None and memo[0] == mtime:
        return memo[1]
    try:
        with open(path) as fh:
            raw = json.load(fh)
        assert raw.get("version") == CACHE_VERSION
        entries = raw["entries"]
        assert isinstance(entries, dict)
        entries = {
            k: tuple(int(t) for t in v)
            for k, v in entries.items()
            if isinstance(v, (list, tuple)) and len(v) == 3
        }
    except Exception:
        entries = {}
    _memo[path] = (mtime, entries)
    return entries


def save_tile_entry(
    key: str, tiles: tuple[int, int, int], path: str | None = None
) -> None:
    """Merge one winner into the cache file (atomic replace)."""
    path = path or default_cache_path()
    entries = dict(load_tile_cache(path))
    entries[key] = tuple(int(t) for t in tiles)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(path) or ".", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(
                {"version": CACHE_VERSION, "entries": entries}, fh, indent=1
            )
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _memo.pop(path, None)


def clear_memo() -> None:
    """Drop the in-process cache-file memo (tests poking at the file)."""
    _memo.clear()


def pick_tiles(
    bm: int,
    bk: int,
    bn: int,
    dtype="float32",
    *,
    platform: str | None = None,
    path: str | None = None,
) -> tuple[int, int, int]:
    """(tm, tn, tk) for a block shape: tuned winner if cached, else heuristic.

    A cached entry that no longer divides the block shape (stale file,
    hand-edited) is ignored rather than trusted.
    """
    platform = platform or default_platform()
    entry = load_tile_cache(path).get(tile_key(platform, bm, bk, bn, dtype))
    if entry is not None:
        tm, tn, tk = entry
        if tm >= 1 and tn >= 1 and tk >= 1 and bm % tm == 0 and bn % tn == 0 and bk % tk == 0:
            return tm, tn, tk
    return heuristic_tiles(bm, bk, bn)


def candidate_tiles(bm: int, bk: int, bn: int, per_dim: int = 3) -> list[tuple[int, int, int]]:
    """Small candidate grid: lane-aligned divisors of each block dim."""

    def divisors(n):
        cands = [
            d
            for d in (512, 384, 256, 128, 64, 32, 16, 8)
            if d <= n and n % d == 0
        ]
        if n not in cands:
            cands.insert(0, n)
        return cands[:per_dim]

    out = []
    for tm in divisors(bm):
        for tn in divisors(bn):
            for tk in divisors(bk):
                out.append((tm, tn, tk))
    return out


def autotune_tiles(
    bm: int,
    bk: int,
    bn: int,
    dtype="float32",
    *,
    bench=None,
    candidates=None,
    reps: int = 3,
    platform: str | None = None,
    path: str | None = None,
    persist: bool = True,
) -> tuple[tuple[int, int, int], list[dict]]:
    """Benchmark candidate tilings for one block shape and persist the winner.

    ``bench(tm, tn, tk)`` must return a zero-arg callable that runs the
    kernel to completion with that tiling (``benchmarks/kernel_micro.py``
    provides one; the default builds a tiny random task list over
    ``repro.kernels.block_spmm``).  Candidates that fail to run (tiling
    rejected by the compiler) are skipped.  Returns the winning tiling and
    the per-candidate timing rows.
    """
    platform = platform or default_platform()
    if bench is None:
        bench = _default_bench(bm, bk, bn, dtype)
    candidates = candidates or candidate_tiles(bm, bk, bn)
    rows = []
    best, best_t = None, float("inf")
    for tm, tn, tk in candidates:
        try:
            fn = bench(tm, tn, tk)
            t = time_call(fn, reps=reps)
        except Exception as e:
            rows.append(dict(tiles=(tm, tn, tk), us=None, error=str(e)))
            continue
        rows.append(dict(tiles=(tm, tn, tk), us=t * 1e6))
        if t < best_t:
            best, best_t = (tm, tn, tk), t
    if best is None:
        best = heuristic_tiles(bm, bk, bn)
    elif persist:
        save_tile_entry(tile_key(platform, bm, bk, bn, dtype), best, path)
    return best, rows


def _default_bench(bm: int, bk: int, bn: int, dtype):
    import jax.numpy as jnp
    import numpy as np

    from .block_spmm import block_spmm_kernel_call

    rng = np.random.default_rng(0)
    T, n_in, n_out = 16, 8, 4
    a = jnp.asarray(rng.standard_normal((n_in, bm, bk)), dtype)
    b = jnp.asarray(rng.standard_normal((n_in, bk, bn)), dtype)
    ai = jnp.asarray(rng.integers(0, n_in, T), jnp.int32)
    bi = jnp.asarray(rng.integers(0, n_in, T), jnp.int32)
    ci = jnp.asarray(np.sort(rng.integers(0, n_out, T)), jnp.int32)
    interpret = default_platform() != "tpu"

    def bench(tm, tn, tk):
        return lambda: block_spmm_kernel_call(
            a, b, ai, bi, ci, num_out=n_out, tm=tm, tn=tn, tk=tk,
            interpret=interpret,
        ).block_until_ready()

    return bench
