"""Pallas TPU kernel: grouped block-sparse matmul (the paper's numeric phase).

Computes ``C[c[t]] += A[a[t]] @ B[b[t]]`` for a host-computed task list with
``c`` sorted ascending (the symbolic phase guarantees this).  This one kernel
is the leaf-level engine for every multiplication task type in the library
(regular / symmetric / SpAMM) *and* for MegaBlocks-style MoE expert GEMMs.

TPU mapping
-----------
* Task indices are **scalar-prefetched** (SMEM) so BlockSpec index maps can
  gather A/B tiles straight from HBM into VMEM double-buffered pipelines —
  no [T, bs, bs] gather is ever materialized (unlike the jnp reference).
* Grid is ``(nm, nn, T, nk)``; the innermost two dims iterate tasks and the
  contraction.  For a fixed output tile (m, n), consecutive grid steps with
  the same ``c[t]`` revisit the same output block, so the accumulator lives
  in VMEM across both k-steps and same-output tasks; it is zero-initialised
  exactly at ``(k == 0) & (t == 0 | c[t] != c[t-1])``.
* MXU: tiles are (tm, tk) x (tk, tn) with fp32 accumulation via
  ``preferred_element_type``; tile sizes are multiples of 128 when the block
  size allows (bs >= 128), otherwise the full block is one tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import pick_tiles
from .compat import tpu_compiler_params

__all__ = ["block_spmm_kernel_call"]


def _kernel(a_idx_ref, b_idx_ref, c_idx_ref, a_ref, b_ref, o_ref, *, nk: int):
    t = pl.program_id(2)
    k = pl.program_id(3)
    prev = c_idx_ref[jnp.maximum(t - 1, 0)]
    first_task_for_block = jnp.logical_or(t == 0, c_idx_ref[t] != prev)

    @pl.when(jnp.logical_and(k == 0, first_task_for_block))
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0]
    b = b_ref[0]
    o_ref[0] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("num_out", "tm", "tn", "tk", "interpret")
)
def block_spmm_kernel_call(
    a_data: jax.Array,
    b_data: jax.Array,
    a_idx: jax.Array,
    b_idx: jax.Array,
    c_idx: jax.Array,
    *,
    num_out: int,
    tm: int | None = None,
    tn: int | None = None,
    tk: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper. Prefer repro.kernels.ops.block_spmm."""
    T = a_idx.shape[0]
    bm, bk = a_data.shape[1], a_data.shape[2]
    bn = b_data.shape[2]
    assert b_data.shape[1] == bk, (a_data.shape, b_data.shape)
    # tile selection: autotuned winner when the on-disk cache has this
    # (platform, block shape, dtype), the old static heuristic otherwise
    dtm, dtn, dtk = pick_tiles(bm, bk, bn, a_data.dtype)
    tm, tn, tk = tm or dtm, tn or dtn, tk or dtk
    nm, nn, nk = bm // tm, bn // tn, bk // tk

    grid = (nm, nn, T, nk)

    def a_map(m, n, t, k, a_idx_ref, b_idx_ref, c_idx_ref):
        del n
        return (a_idx_ref[t], m, k)

    def b_map(m, n, t, k, a_idx_ref, b_idx_ref, c_idx_ref):
        del m
        return (b_idx_ref[t], k, n)

    def o_map(m, n, t, k, a_idx_ref, b_idx_ref, c_idx_ref):
        del k
        return (c_idx_ref[t], m, n)

    flops = 2 * T * bm * bn * bk
    bytes_accessed = int(
        T * (tm * bk * a_data.dtype.itemsize + bk * tn * b_data.dtype.itemsize)
        + num_out * bm * bn * 4
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tm, tk), a_map),
                pl.BlockSpec((1, tk, tn), b_map),
            ],
            out_specs=pl.BlockSpec((1, tm, tn), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((num_out, bm, bn), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=flops, bytes_accessed=bytes_accessed, transcendentals=0
        ),
        interpret=interpret,
    )(a_idx, b_idx, c_idx, a_data, b_data)
    return out
