"""Version-tolerant lookups for :mod:`jax.experimental.pallas.tpu` API drift.

The TPU compiler-params dataclass was renamed across jax releases
(``TPUCompilerParams`` on 0.4.x, ``CompilerParams`` on newer versions).
Kernels go through :func:`tpu_compiler_params` so they work on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:  # pragma: no cover - unknown future rename
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams"
        )
    return cls(**kwargs)
