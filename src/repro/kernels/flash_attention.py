"""Pallas TPU flash attention (online softmax), causal + sliding-window.

The prefill/training attention hot spot.  GQA-aware: K/V may have fewer heads
than Q; the kv head is selected in the BlockSpec index map (h // rep), so K/V
are never materially repeated.

Grid: (batch, q_heads, q_tiles, kv_tiles), kv innermost.  Softmax state
(m, l, acc) lives in VMEM scratch across kv steps; fully-masked kv tiles are
skipped (causal: tiles entirely above the diagonal; window: tiles entirely
outside the band) — for sliding-window attention this makes the kernel
O(seq * window) instead of O(seq^2), which is what lets recurrentgemma-style
local attention run at 500k context.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params

__all__ = ["flash_attention_call"]

_NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bkv: int,
    nkv: int,
    sq: int,
    sk: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions; q positions are aligned to the END of the kv axis so
    # the same kernel serves decode-style suffix queries.
    q_lo = iq * bq + (sk - sq)
    k_lo = ikv * bkv
    # tile-level skip tests (static shapes, dynamic predicates)
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_lo + bkv - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s *= scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=bool)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(jnp.float32),
            v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ikv == nkv - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bkv", "interpret")
)
def flash_attention_call(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 256,
    bkv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, HK, Sk, D] with H % HK == 0."""
    B, H, Sq, D = q.shape
    _, HK, Sk, _ = k.shape
    assert H % HK == 0, (H, HK)
    rep = H // HK
    bq = min(bq, Sq)
    bkv = min(bkv, Sk)
    assert Sq % bq == 0 and Sk % bkv == 0, (Sq, bq, Sk, bkv)
    nq, nkv = Sq // bq, Sk // bkv
    scale = D**-0.5

    def q_map(b, h, iq, ikv):
        del ikv
        return (b, h, iq, 0)

    def kv_map(b, h, iq, ikv):
        del iq
        return (b, h // rep, ikv, 0)

    def o_map(b, h, iq, ikv):
        del ikv
        return (b, h, iq, 0)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bkv=bkv,
        nkv=nkv,
        sq=Sq,
        sk=Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), q_map),
            pl.BlockSpec((1, 1, bkv, D), kv_map),
            pl.BlockSpec((1, 1, bkv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), o_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
