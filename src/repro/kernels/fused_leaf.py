"""Fused leaf engine: unpack + batched block GEMM + C-accumulate in one call.

The staged numeric phase (``core/distributed.py`` historically) materialized
a concatenated device-local operand buffer ``[own store | recv_0 | recv_1 |
...]`` after the ppermute rounds, then ran ``kernels/block_spmm.py`` as a
separate dispatch over it.  The fused engine removes that intermediate: the
plan's task operand indices are decomposed host-side into ``(src, off)``
pairs — ``src == 0`` reads the device's own store at row ``off``; ``src ==
r+1`` reads receive buffer ``r`` at row ``off`` — and the kernel gathers
tiles straight out of the store and the stacked receive buffers via
scalar-prefetched index maps.  No ``[sum(cap), bs, bs]`` concatenate is ever
built, on TPU or on CPU.

Grid and accumulation contract are identical to ``block_spmm``: grid
``(nm, nn, T, nk)``, output rows revisited across same-``c`` tasks with the
accumulator zero-initialised at ``(k == 0) & (t == 0 | c[t] != c[t-1])``,
fp32 accumulation, trailing trash row for padded/masked tasks.

Mixed precision: operand stores may arrive bfloat16 (the ``bf16`` policy
casts before the exchange, halving payload bytes); accumulation stays fp32.
In ``adaptive`` mode a scalar-prefetched per-task ``low`` mask rounds that
task's fp32 operand tiles to bf16 before the MXU — the SpAMM norm bound
selected those tasks, so the rounding error is budgeted by construction
(see :mod:`repro.kernels.precision`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import pick_tiles
from .compat import tpu_compiler_params

__all__ = [
    "fused_block_spmm_kernel_call",
    "fused_block_spmm_ref",
    "first_accumulation_hazard",
]


def first_accumulation_hazard(c_idx) -> int | None:
    """First task index violating the kernel's accumulation contract, else
    ``None``.

    The grid zeroes the accumulator at ``(k == 0) & (t == 0 | c[t] !=
    c[t-1])``: each output row must therefore be visited by one contiguous
    ascending run of tasks.  A ``c_idx`` that revisits an earlier row
    re-zeroes it — a write race between grid segments that silently drops
    the first chain's contributions.  Host-side (numpy) so the static
    verifier (:mod:`repro.analysis.verify`) and tests share one definition
    of the contract with the kernel that relies on it.
    """
    import numpy as np

    c = np.asarray(c_idx).reshape(-1)
    if c.size < 2:
        return None
    dec = np.nonzero(np.diff(c) < 0)[0]
    return int(dec[0]) + 1 if dec.size else None


def _round_bf16(x):
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _kernel(
    a_src_ref,
    a_off_ref,
    b_src_ref,
    b_off_ref,
    c_idx_ref,
    low_ref,
    a_store_ref,
    a_recv_ref,
    b_store_ref,
    b_recv_ref,
    o_ref,
    *,
    nk: int,
    adaptive: bool,
):
    t = pl.program_id(2)
    k = pl.program_id(3)
    prev = c_idx_ref[jnp.maximum(t - 1, 0)]
    first_task_for_block = jnp.logical_or(t == 0, c_idx_ref[t] != prev)

    @pl.when(jnp.logical_and(k == 0, first_task_for_block))
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # unpack: the index maps already steered the pipeline to the right row of
    # the store (src == 0) or of receive buffer src-1; the discarded branch
    # fetched a dummy row 0 tile
    a = jnp.where(a_src_ref[t] == 0, a_store_ref[0], a_recv_ref[0, 0])
    b = jnp.where(b_src_ref[t] == 0, b_store_ref[0], b_recv_ref[0, 0])
    if adaptive:
        lo = low_ref[t] != 0
        a = jnp.where(lo, _round_bf16(a), a)
        b = jnp.where(lo, _round_bf16(b), b)
    o_ref[0] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("num_out", "adaptive", "tm", "tn", "tk", "interpret"),
)
def fused_block_spmm_kernel_call(
    a_store: jax.Array,  # [capA, bm, bk] own store
    a_recv: jax.Array,  # [Ra, capU_a, bm, bk] stacked receive buffers
    b_store: jax.Array,  # [capB, bk, bn]
    b_recv: jax.Array,  # [Rb, capU_b, bk, bn]
    a_src: jax.Array,  # [T] int32: 0 -> own store, r+1 -> recv buffer r
    a_off: jax.Array,  # [T] int32 row within the selected source
    b_src: jax.Array,
    b_off: jax.Array,
    c_idx: jax.Array,  # [T] int32 output row, sorted ascending
    low: jax.Array,  # [T] int32: 1 -> round this task's tiles to bf16
    *,
    num_out: int,
    adaptive: bool = False,
    tm: int | None = None,
    tn: int | None = None,
    tk: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw fused pallas_call. Prefer repro.kernels.ops.fused_block_spmm.

    With no exchange rounds pass a dummy ``[1, 1, bm, bk]`` receive stack and
    all-zero ``src`` — the recv branch then prefetches the dummy row and the
    select discards it.
    """
    T = a_src.shape[0]
    bm, bk = a_store.shape[1], a_store.shape[2]
    bn = b_store.shape[2]
    assert b_store.shape[1] == bk, (a_store.shape, b_store.shape)
    assert a_recv.shape[-2:] == (bm, bk), (a_recv.shape, (bm, bk))
    assert b_recv.shape[-2:] == (bk, bn), (b_recv.shape, (bk, bn))
    dtm, dtn, dtk = pick_tiles(bm, bk, bn, a_store.dtype)
    tm, tn, tk = tm or dtm, tn or dtn, tk or dtk
    nm, nn, nk = bm // tm, bn // tn, bk // tk

    grid = (nm, nn, T, nk)

    def a_store_map(m, n, t, k, a_src, a_off, b_src, b_off, c_idx, low):
        return (jnp.where(a_src[t] == 0, a_off[t], 0), m, k)

    def a_recv_map(m, n, t, k, a_src, a_off, b_src, b_off, c_idx, low):
        return (
            jnp.maximum(a_src[t] - 1, 0),
            jnp.where(a_src[t] == 0, 0, a_off[t]),
            m,
            k,
        )

    def b_store_map(m, n, t, k, a_src, a_off, b_src, b_off, c_idx, low):
        return (jnp.where(b_src[t] == 0, b_off[t], 0), k, n)

    def b_recv_map(m, n, t, k, a_src, a_off, b_src, b_off, c_idx, low):
        return (
            jnp.maximum(b_src[t] - 1, 0),
            jnp.where(b_src[t] == 0, 0, b_off[t]),
            k,
            n,
        )

    def o_map(m, n, t, k, a_src, a_off, b_src, b_off, c_idx, low):
        return (c_idx[t], m, n)

    isz = a_store.dtype.itemsize
    flops = 2 * T * bm * bn * bk
    bytes_accessed = int(
        T * (tm * bk * isz + bk * tn * isz) + num_out * bm * bn * 4
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, adaptive=adaptive),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tm, tk), a_store_map),
                pl.BlockSpec((1, 1, tm, tk), a_recv_map),
                pl.BlockSpec((1, tk, tn), b_store_map),
                pl.BlockSpec((1, 1, tk, tn), b_recv_map),
            ],
            out_specs=pl.BlockSpec((1, tm, tn), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((num_out, bm, bn), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=flops, bytes_accessed=bytes_accessed, transcendentals=0
        ),
        interpret=interpret,
    )(a_src, a_off, b_src, b_off, c_idx, low, a_store, a_recv, b_store, b_recv)
    return out


@functools.partial(jax.jit, static_argnames=("num_out", "adaptive"))
def fused_block_spmm_ref(
    a_store: jax.Array,
    a_recv: jax.Array,
    b_store: jax.Array,
    b_recv: jax.Array,
    a_src: jax.Array,
    a_off: jax.Array,
    b_src: jax.Array,
    b_off: jax.Array,
    c_idx: jax.Array,
    low: jax.Array | None = None,
    *,
    num_out: int,
    adaptive: bool = False,
) -> jax.Array:
    """jnp/segment-sum reference of the fused engine (CPU + interpret parity).

    Gathers each task's operand tiles from (store | recv stack) by the same
    ``(src, off)`` decomposition the kernel prefetches, then runs the exact
    einsum + ``segment_sum`` of :func:`repro.kernels.ref.block_spmm_ref` —
    in fp32 the result is bit-identical to the staged path gathering from
    the concatenated operand buffer, because the gathered tile values and
    the accumulation order are the same.
    """

    def gather(store, recv, src, off):
        local = src == 0
        own = store[jnp.where(local, off, 0)]
        rem = recv[jnp.maximum(src - 1, 0), jnp.where(local, 0, off)]
        return jnp.where(local[:, None, None], own, rem)

    lhs = gather(a_store, a_recv, a_src, a_off).astype(jnp.float32)
    rhs = gather(b_store, b_recv, b_src, b_off).astype(jnp.float32)
    if adaptive:
        assert low is not None
        lo = (low != 0)[:, None, None]
        lhs = jnp.where(lo, _round_bf16(lhs), lhs)
        rhs = jnp.where(lo, _round_bf16(rhs), rhs)
    prods = jnp.einsum("tij,tjk->tik", lhs, rhs)
    return jax.ops.segment_sum(prods, c_idx, num_segments=num_out)
