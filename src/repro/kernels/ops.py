"""Jit'd public wrappers around the Pallas kernels.

Selects interpret mode automatically off-TPU so the same call sites work in
CPU tests (interpret=True) and on real hardware (compiled Mosaic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .block_spmm import block_spmm_kernel_call
from .fused_leaf import fused_block_spmm_kernel_call, fused_block_spmm_ref

__all__ = ["block_spmm", "fused_block_spmm", "flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_spmm(
    a_data: jax.Array,
    b_data: jax.Array,
    a_idx: jax.Array,
    b_idx: jax.Array,
    c_idx: jax.Array,
    num_out: int,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Grouped block matmul: C[c[t]] += A[a[t]] @ B[b[t]], c sorted ascending.

    Contract: every output row in [0, num_out) must receive at least one
    task, except an optional TRAILING trash region (padded tasks), whose
    content is unspecified — callers slice it off.  The symbolic phase and
    the distributed scheduler both satisfy this by construction.

    Returns fp32 [num_out, bm, bn].
    """
    if a_idx.shape[0] == 0:
        return jnp.zeros((num_out, a_data.shape[1], b_data.shape[2]), jnp.float32)
    interpret = (not _on_tpu()) if interpret is None else interpret
    # Tiny/odd blocks (tests, partial leaves) go through the oracle — the
    # kernel wants lane-aligned tiles.
    bm, bk, bn = a_data.shape[1], a_data.shape[2], b_data.shape[2]
    if min(bm, bk, bn) < 8 or bm % 8 or bk % 8 or bn % 8:
        return ref.block_spmm_ref(a_data, b_data, a_idx, b_idx, c_idx, num_out)
    return block_spmm_kernel_call(
        a_data,
        b_data,
        jnp.asarray(a_idx, jnp.int32),
        jnp.asarray(b_idx, jnp.int32),
        jnp.asarray(c_idx, jnp.int32),
        num_out=num_out,
        interpret=interpret,
    )


def fused_block_spmm(
    a_store: jax.Array,
    a_recv: jax.Array,
    b_store: jax.Array,
    b_recv: jax.Array,
    a_src: jax.Array,
    a_off: jax.Array,
    b_src: jax.Array,
    b_off: jax.Array,
    c_idx: jax.Array,
    num_out: int,
    *,
    low: jax.Array | None = None,
    adaptive: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused unpack + grouped block matmul + accumulate (the leaf engine).

    Task operands are addressed as ``(src, off)`` pairs over the device's
    own store and the stacked receive buffers — see
    :mod:`repro.kernels.fused_leaf` for the layout and the accumulation
    contract (same as :func:`block_spmm`, trailing trash row included).

    Dispatch: compiled Mosaic on TPU, the fused jnp/segment-sum reference
    elsewhere (pass ``interpret=True`` to force the Pallas interpreter —
    tests do, production CPU paths should not: interpret mode is orders of
    magnitude slower than the reference).  Tiny/odd block sizes fall back
    to the reference like :func:`block_spmm`.  Returns fp32
    ``[num_out, bm, bn]``.
    """
    bm, bk, bn = a_store.shape[1], a_store.shape[2], b_store.shape[2]
    if a_src.shape[0] == 0:
        return jnp.zeros((num_out, bm, bn), jnp.float32)
    i32 = lambda x: jnp.asarray(x, jnp.int32)
    if low is None:
        low = jnp.zeros(a_src.shape, jnp.int32)
    use_kernel = _on_tpu() if interpret is None else True
    if (
        not use_kernel
        or min(bm, bk, bn) < 8
        or bm % 8
        or bk % 8
        or bn % 8
    ):
        return fused_block_spmm_ref(
            a_store, a_recv, b_store, b_recv,
            i32(a_src), i32(a_off), i32(b_src), i32(b_off), i32(c_idx),
            i32(low), num_out=num_out, adaptive=adaptive,
        )
    return fused_block_spmm_kernel_call(
        a_store, a_recv, b_store, b_recv,
        i32(a_src), i32(a_off), i32(b_src), i32(b_off), i32(c_idx), i32(low),
        num_out=num_out, adaptive=adaptive,
        interpret=bool(interpret) if interpret is not None else False,
    )


def grouped_gemm_varsize(
    x: jax.Array,
    group_sizes,
    w: jax.Array,
    *,
    tile_m: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """MegaBlocks-style dropless grouped GEMM through the paper's kernel.

    x: [T, K] rows sorted by group (tokens sorted by expert);
    group_sizes: host list/array, sum == T; w: [G, K, N] per-group weights.
    Returns [T, N] with row t multiplied by its group's weight.

    The variable group boundaries become a *block-sparse task list*: x is
    tiled into [T/tile_m, tile_m, K] row blocks and each tile is paired with
    the weight(s) of the group(s) it spans — exactly the symbolic/numeric
    split of the sparse matrix library, with tokens as block rows.  Tiles
    spanning a group boundary are handled by masking each (tile, group) pair
    to the rows owned by that group — so no token is ever dropped and no
    capacity padding is computed (vs the capacity-factor path in
    repro.models.moe).
    """
    import numpy as np

    group_sizes = np.asarray(group_sizes)
    T, K = x.shape
    G, _, N = w.shape
    assert group_sizes.sum() == T, (group_sizes.sum(), T)
    pad = (-T) % tile_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nt = (T + pad) // tile_m
    # host symbolic phase: one task per (row-tile, group) pair it overlaps
    starts = np.concatenate([[0], np.cumsum(group_sizes)])
    row_group = np.repeat(np.arange(G), group_sizes)
    row_group = np.concatenate([row_group, np.full(pad, G - 1)])
    a_idx, b_idx, c_idx, mask_lo, mask_hi = [], [], [], [], []
    for t in range(nt):
        lo, hi = t * tile_m, (t + 1) * tile_m
        for g in np.unique(row_group[lo:hi]):
            a_idx.append(t)
            b_idx.append(int(g))
            c_idx.append(t)
            g_lo = int(starts[g])
            g_hi = int(starts[g + 1]) if g < G - 1 else T + pad
            mask_lo.append(max(g_lo - lo, 0))
            mask_hi.append(min(g_hi - lo, tile_m))
    xt = x.reshape(nt, tile_m, K)
    # mask each task's tile to its group's rows (numeric phase stays a pure
    # grouped block matmul; boundary tiles appear once per group)
    rows = jnp.arange(tile_m)
    sel = (rows[None, :] >= jnp.asarray(mask_lo)[:, None]) & (
        rows[None, :] < jnp.asarray(mask_hi)[:, None]
    )
    a_data = xt[jnp.asarray(a_idx)] * sel[:, :, None].astype(x.dtype)
    out = block_spmm(
        a_data,
        w.astype(x.dtype),
        jnp.arange(len(a_idx), dtype=jnp.int32),
        jnp.asarray(b_idx, jnp.int32),
        jnp.asarray(c_idx, jnp.int32),
        nt,
        interpret=interpret,
    )
    return out.reshape(nt * tile_m, N)[:T].astype(x.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Online-softmax attention (Pallas on TPU, oracle fallback elsewhere)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    from .flash_attention import flash_attention_call

    return flash_attention_call(q, k, v, causal=causal, window=window, interpret=interpret)
