"""Norm-aware mixed-precision policy for the fused leaf engine.

SpAMM's error analysis already ranks every task by ``||A_t||_F ||B_t||_F``
— the same bound that controls what pruning may drop also controls what
*rounding* may perturb: storing a task's operand tiles in bfloat16 changes
the product by at most ``(2u + u^2) ||A_t||_F ||B_t||_F`` with ``u`` the
bf16 unit roundoff, so tasks with small norm products tolerate low
precision *by construction*.  :class:`Precision` names the three modes the
drivers thread through (``precision=`` on ``dist_multiply`` /
``dist_spamm`` / the SP2 and inverse drivers):

* ``fp32``   — everything exact single precision (the default).
* ``bf16``   — operand blocks are cast to bfloat16 *before* the exchange
  (halving ppermute payload bytes) and multiplied with fp32 accumulation.
* ``adaptive`` — operands stay fp32 on the wire; per task, the fused kernel
  rounds the operand tiles to bf16 when the task was selected by
  :func:`low_precision_task_mask` under the ``tau`` error budget.

Accumulation is always fp32 (``preferred_element_type``), matching the
paper's dtype discipline of 32-bit defaults with selectively relaxed
storage.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Precision",
    "FP32",
    "BF16",
    "low_precision_task_mask",
    "EPS_BF16",
]

# bfloat16 unit roundoff: 8 significand bits (incl. hidden) -> u = 2^-8.
# Used pessimistically; round-to-nearest actually gives 2^-9.
EPS_BF16 = 2.0**-8
# first-order bound on || fl(A)fl(B) - AB ||_F / (||A||_F ||B||_F) when both
# operands are rounded once: (1+u)^2 - 1 = 2u + u^2
ROUND2_BOUND = 2.0 * EPS_BF16 + EPS_BF16 * EPS_BF16


@dataclasses.dataclass(frozen=True)
class Precision:
    """Mixed-precision policy threaded through the distributed drivers.

    ``tau`` is the adaptive mode's Frobenius error budget per multiply; with
    ``tau == 0`` the drivers substitute their SpAMM tau, so one knob bounds
    prune + rounding error together.  ``fp32`` / ``bf16`` ignore ``tau``.
    """

    mode: str = "fp32"  # fp32 | bf16 | adaptive
    tau: float = 0.0

    def __post_init__(self):
        assert self.mode in ("fp32", "bf16", "adaptive"), self.mode
        assert self.tau >= 0.0, self.tau

    def key(self) -> tuple:
        """Plan-cache key component — the compiled program differs per mode."""
        return (self.mode, float(self.tau) if self.mode == "adaptive" else 0.0)

    @property
    def is_mixed(self) -> bool:
        return self.mode != "fp32"

    def budget(self, fallback_tau: float = 0.0) -> float:
        """Adaptive error budget: own tau, else the caller's SpAMM tau."""
        return self.tau if self.tau > 0.0 else float(fallback_tau)


FP32 = Precision("fp32")
BF16 = Precision("bf16")


def low_precision_task_mask(
    a_norms: np.ndarray,
    b_norms: np.ndarray,
    a_idx: np.ndarray,
    b_idx: np.ndarray,
    budget: float,
    *,
    eligible: np.ndarray | None = None,
) -> tuple[np.ndarray, float]:
    """Select the tasks whose bf16 rounding error fits inside ``budget``.

    Per-task bound: ``ROUND2_BOUND * ||A_t||_F * ||B_t||_F``.  Greedy
    smallest-bound-first selection keeps the summed bound <= budget (the
    triangle inequality makes the per-task bounds additive), which is the
    same budget-spending rule hierarchical SpAMM uses for pruning.

    ``eligible`` masks tasks that may be selected (delta-plan callers pass
    the kept-task mask: a pruned task contributes zero error and must not
    consume budget).  Returns ``(mask [T] bool, spent_bound)``.
    """
    a_idx = np.asarray(a_idx)
    b_idx = np.asarray(b_idx)
    T = a_idx.shape[0]
    mask = np.zeros(T, dtype=bool)
    if T == 0 or budget <= 0.0:
        return mask, 0.0
    per = ROUND2_BOUND * np.asarray(a_norms, np.float64)[a_idx] * np.asarray(
        b_norms, np.float64
    )[b_idx]
    if eligible is not None:
        cand = np.nonzero(np.asarray(eligible, dtype=bool))[0]
    else:
        cand = np.arange(T)
    if cand.size == 0:
        return mask, 0.0
    order = cand[np.argsort(per[cand], kind="stable")]
    csum = np.cumsum(per[order])
    k = int(np.searchsorted(csum, budget, side="right"))
    mask[order[:k]] = True
    return mask, float(csum[k - 1]) if k else 0.0
