"""Pure-jnp oracles for the Pallas kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["block_spmm_ref", "flash_attention_ref"]


@functools.partial(jax.jit, static_argnames=("num_out",))
def block_spmm_ref(
    a_data: jax.Array,
    b_data: jax.Array,
    a_idx: jax.Array,
    b_idx: jax.Array,
    c_idx: jax.Array,
    num_out: int,
) -> jax.Array:
    """Grouped block matmul oracle: C[c[t]] += A[a[t]] @ B[b[t]].

    fp32 accumulation regardless of input dtype (matches the kernel).
    """
    lhs = a_data[a_idx].astype(jnp.float32)
    rhs = b_data[b_idx].astype(jnp.float32)
    prods = jnp.einsum("tij,tjk->tik", lhs, rhs)
    return jax.ops.segment_sum(prods, c_idx, num_segments=num_out)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference attention. q,k,v: [batch, heads, seq, head_dim] (kv may have
    fewer heads — GQA — broadcast here). Optional sliding window."""
    bq, hq, sq, d = q.shape
    hk = k.shape[1]
    if hk != hq:
        rep = hq // hk
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else d**-0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits *= scale
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (decode-style)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
