import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module must fit, and
its cost/memory/collective analyses feed the roofline (EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun   (every cell)
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.hlo_analysis import analyze_collectives, op_census
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_state_and_shardings,
    make_ctx,
    serve_input_shardings,
    serve_input_specs,
    train_input_shardings,
    train_input_specs,
)
from repro.models import model as model_mod
from repro.models import transformer
from repro.runtime.elastic import state_shardings  # noqa: F401  (docs)
from repro.sharding.rules import spec_tree

# TPU v5e-ish hardware model (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link


def _params_shardings(ctx, cfg, dtype=None):
    from jax.sharding import NamedSharding

    params, axes = transformer.abstract_params(cfg)
    if dtype is not None:  # serving stores bf16 weights
        params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, dtype)
            if jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            params,
        )
    specs = spec_tree(ctx, params, axes)
    return params, jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs)


def _lower_one(cfg, shape, mesh, ctx, *, attn_impl, unroll, kv_dtype=None, train_opts=None):
    """Lower + compile one variant of a cell; returns (compiled, t_lower, t_compile)."""
    t0 = time.time()
    if shape.kind == "train":
        topts = dict(train_opts or {})
        state, st_sh = abstract_state_and_shardings(
            ctx, cfg, param_dtype=topts.get("param_dtype", jnp.float32)
        )
        batch = train_input_specs(cfg, shape)
        b_sh = train_input_shardings(ctx, cfg, shape)
        step = model_mod.make_train_step(
            cfg, ctx, attn_impl=attn_impl, unroll=unroll, **topts
        )
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state, batch)
    elif shape.kind == "prefill":
        params, p_sh = _params_shardings(ctx, cfg, dtype=jnp.bfloat16)
        batch = train_input_specs(cfg, shape)
        b_sh = train_input_shardings(ctx, cfg, shape)

        def prefill_step(params, batch):
            c = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else p,
                params,
            )
            cb = {
                k: v.astype(jnp.bfloat16) if jnp.issubdtype(v.dtype, jnp.floating) else v
                for k, v in batch.items()
            }
            return transformer.apply(c, cfg, ctx, cb, attn_impl=attn_impl, unroll=unroll)

        lowered = jax.jit(prefill_step, in_shardings=(p_sh, b_sh)).lower(params, batch)
    else:  # decode
        params, p_sh = _params_shardings(ctx, cfg, dtype=jnp.bfloat16)
        cache, tokens, pos = serve_input_specs(cfg, shape, kv_dtype=kv_dtype)
        c_sh, t_sh, pos_sh = serve_input_shardings(ctx, cfg, shape, kv_dtype=kv_dtype)
        serve = model_mod.make_serve_step(cfg, ctx, unroll=unroll)
        jitted = jax.jit(
            serve,
            in_shardings=(p_sh, c_sh, t_sh, pos_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params, cache, tokens, pos)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = analyze_collectives(compiled.as_text())
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "operand_bytes": coll["operand_bytes"],
        "wire_bytes": coll["wire_bytes"],
    }
    for k, v in coll["by_kind"].items():
        out[f"kind/{k}/count"] = float(v["count"])
        out[f"kind/{k}/wire_bytes"] = float(v["wire_bytes"])
    return out


def lower_cell(
    arch_id: str,
    shape_name: str,
    multi_pod: bool,
    *,
    attn_impl="chunked",
    unroll=True,
    rules_override=None,
    grad_accum=None,
    cfg_overrides=None,
    kv_dtype=None,
    train_opts=None,
):
    """One cell: production (scan) lowering for memory + compile proof, and a
    1-period/2-period unrolled pair to extrapolate exact per-device costs
    (XLA's HloCostAnalysis counts while-loop bodies once, so the scan
    module's totals would undercount by the trip count)."""
    import dataclasses as _dc

    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports(shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped", "why": why}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, cfg, shape)
    if rules_override:
        ctx = ctx.with_rules(**rules_override)
    if grad_accum is not None:
        cfg = _dc.replace(cfg, grad_accum=grad_accum)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)

    # 1) production lowering: the deployable program (scan over periods)
    compiled, t_lower, t_compile = _lower_one(
        cfg, shape, mesh, ctx, attn_impl=attn_impl, unroll=False, kv_dtype=kv_dtype,
        train_opts=train_opts,
    )
    mem = compiled.memory_analysis()
    census = op_census(compiled.as_text())

    # 2) cost extrapolation
    pat = len(cfg.block_pattern)
    periods = cfg.num_layers // pat
    rem = cfg.num_layers % pat
    if periods <= 2 and unroll:
        cu, _, _ = _lower_one(
            cfg, shape, mesh, ctx, attn_impl=attn_impl, unroll=True, kv_dtype=kv_dtype,
            train_opts=train_opts,
        )
        costs = _costs(cu)
        extrap = "exact-unrolled"
    elif unroll:
        cfg1 = _dc.replace(cfg, num_layers=1 * pat + rem)
        cfg2 = _dc.replace(cfg, num_layers=2 * pat + rem)
        c1, _, _ = _lower_one(
            cfg1, shape, mesh, ctx, attn_impl=attn_impl, unroll=True, kv_dtype=kv_dtype,
            train_opts=train_opts,
        )
        f1 = _costs(c1)
        c2, _, _ = _lower_one(
            cfg2, shape, mesh, ctx, attn_impl=attn_impl, unroll=True, kv_dtype=kv_dtype,
            train_opts=train_opts,
        )
        f2 = _costs(c2)
        keys = set(f1) | set(f2)
        costs = {
            k: f1.get(k, 0.0) + (periods - 1) * (f2.get(k, 0.0) - f1.get(k, 0.0))
            for k in keys
        }
        extrap = "per-period"
    else:
        costs = _costs(compiled)
        extrap = "scan-raw (body counted once)"

    chips = mesh.devices.size
    flops = costs["flops"]
    bytes_acc = costs["bytes"]
    model_flops = _model_flops(cfg, shape)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "extrapolation": extrap,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "collective_operand_bytes_per_dev": costs["operand_bytes"],
        "collective_wire_bytes_per_dev": costs["wire_bytes"],
        "collectives_by_kind": {
            k.split("/")[1]: {
                "count": costs.get(f"kind/{k.split('/')[1]}/count", 0.0),
                "wire_bytes": costs.get(f"kind/{k.split('/')[1]}/wire_bytes", 0.0),
            }
            for k in costs
            if k.startswith("kind/")
        },
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": costs["wire_bytes"] / LINK_BW,
        "model_flops_global": model_flops,
        "model_flops_per_dev": model_flops / chips,
        "useful_flops_ratio": (model_flops / chips) / flops if flops else 0.0,
        "memory_analysis": _mem_dict(mem),
        "op_census": census,
    }
    terms = {
        "compute": rec["compute_term_s"],
        "memory": rec["memory_term_s"],
        "collective": rec["collective_term_s"],
    }
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["roofline_fraction"] = (
        rec["compute_term_s"] * rec["useful_flops_ratio"] / max(terms.values())
        if max(terms.values()) > 0
        else 0.0
    )
    return rec, compiled


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = matmul params."""
    n = cfg.flops_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _mem_dict(mem) -> dict:
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(mem)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--no-unroll", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s, args.mesh == "multi"))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.mesh == "multi"))

    for arch, shp, multi in cells:
        key = f"{arch}|{shp}|{'multi' if multi else 'single'}"
        try:
            rec, compiled = lower_cell(
                arch, shp, multi, attn_impl=args.attn_impl, unroll=not args.no_unroll
            )
        except Exception as e:  # a failing cell is a bug: report loudly
            rec = {
                "arch": arch,
                "shape": shp,
                "mesh": "2x16x16" if multi else "16x16",
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:],
            }
        if rec["status"] == "ok":
            print(
                f"[ok] {key}: compile={rec['compile_s']}s "
                f"flops/dev={rec['hlo_flops_per_dev']:.3e} "
                f"bytes/dev={rec['hlo_bytes_per_dev']:.3e} "
                f"wire/dev={rec['collective_wire_bytes_per_dev']:.3e} "
                f"bottleneck={rec['bottleneck']} frac={rec['roofline_fraction']:.3f}"
            )
            print("  memory_analysis:", rec["memory_analysis"])
        else:
            print(f"[{rec['status']}] {key}: {rec.get('why', rec.get('error'))}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = f"{arch}__{shp}__{'multi' if multi else 'single'}.json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
