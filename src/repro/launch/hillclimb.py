import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing: re-lower a dry-run cell under a named variant and diff
the roofline terms against baseline.  Each variant encodes one hypothesis
(EXPERIMENTS.md §Perf records hypothesis -> change -> before -> after).

  python -m repro.launch.hillclimb --arch qwen2-72b --shape train_4k \
      --variant accum4 --out results/hillclimb
"""

import argparse
import json

import jax.numpy as jnp

from repro.launch.dryrun import lower_cell

VARIANTS = {
    # H-accum: FSDP re-gathers weights once per microbatch; collective term
    # should scale ~linearly with grad_accum.
    "baseline": {},
    "accum8": dict(grad_accum=8),
    "accum4": dict(grad_accum=4),
    "accum2": dict(grad_accum=2),
    "accum1": dict(grad_accum=1),
    # H-remat: 'dots' keeps matmul outputs, removing recompute flops at the
    # cost of activation memory (useful_flops_ratio up, memory term up).
    "remat_dots": dict(cfg_overrides={"remat": "dots"}),
    "remat_none": dict(cfg_overrides={"remat": "none"}),
    # H-sp: Megatron-style sequence parallelism — residual stream sharded
    # over the model axis between blocks; TP psums become (scattered) partial
    # exchanges, activations 16x smaller on the model axis.
    "sp": dict(rules_override={"seq": ("model",)}),
    # H-kv8: int8 KV cache halves decode cache bytes; scales applied to
    # logits, never to the cache.
    "kv_int8": dict(kv_dtype=jnp.int8),
    # H-cf: MoE capacity factor (dispatch padding waste vs drop rate).
    "moe_cf1": dict(cfg_overrides={"moe_capacity_factor": 1.0}),
    "moe_cf2": dict(cfg_overrides={"moe_capacity_factor": 2.0}),
    # H-bf16: bf16 params + fp32 master -> bf16 weight-grad reductions.
    "bf16master": dict(train_opts={"param_dtype": "bf16"}),
    # H-rs: pin grads to param sharding -> reduce-scatter instead of AR.
    "gradrs": dict(train_opts={"grad_reshard": True}),
    "bf16_rs": dict(train_opts={"param_dtype": "bf16", "grad_reshard": True}),
    "bf16_rs_accum4": dict(
        train_opts={"param_dtype": "bf16", "grad_reshard": True}, grad_accum=4
    ),
    "bf16_rs_accum1": dict(
        train_opts={"param_dtype": "bf16", "grad_reshard": True}, grad_accum=1
    ),
    # H-dispatch: decode MoE moves tokens, not expert weights (now default
    # in the decode path; re-lower to measure vs the pre-dispatch baseline).
    "token_dispatch": dict(),
    # combos
    "sp_accum4": dict(grad_accum=4, rules_override={"seq": ("model",)}),
    "sp_accum1": dict(grad_accum=1, rules_override={"seq": ("model",)}),
    "sp_accum2": dict(grad_accum=2, rules_override={"seq": ("model",)}),
    "sp_accum4_dots": dict(
        grad_accum=4,
        rules_override={"seq": ("model",)},
        cfg_overrides={"remat": "dots"},
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    kw = dict(VARIANTS[args.variant])
    if "train_opts" in kw:
        topts = dict(kw["train_opts"])
        if topts.get("param_dtype") == "bf16":
            topts["param_dtype"] = jnp.bfloat16
        kw["train_opts"] = topts
    rec, _ = lower_cell(args.arch, args.shape, args.mesh == "multi", **kw)
    rec["variant"] = args.variant
    os.makedirs(args.out, exist_ok=True)
    fn = f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}.json"
    with open(os.path.join(args.out, fn), "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        print(
            f"[{args.variant}] {args.arch}|{args.shape}: "
            f"compute={rec['compute_term_s']:.3f}s memory={rec['memory_term_s']:.3f}s "
            f"collective={rec['collective_term_s']:.3f}s useful={rec['useful_flops_ratio']:.2f} "
            f"frac={rec['roofline_fraction']:.3f} temp={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.1f}GiB"
        )
    else:
        print(f"[{args.variant}] {rec['status']}: {rec.get('error', rec.get('why'))}")


if __name__ == "__main__":
    main()
