"""Parse compiled HLO text: collective traffic + op census for the roofline.

cost_analysis() has no collective bytes, so we extract them from the
partitioned module.  Two conventions are reported:

* ``operand_bytes`` — literal sum of operand sizes per collective (the spec's
  definition of collective_bytes);
* ``wire_bytes``    — per-device link traffic under ring algorithms:
  all-gather -> result bytes (receives everyone's shard),
  all-reduce -> 2x operand, reduce-scatter / all-to-all / collective-permute
  -> operand bytes.  The roofline's collective term uses wire_bytes (it is
  the one proportional to time on the busiest link).
"""

from __future__ import annotations

import collections
import re

__all__ = ["analyze_collectives", "op_census", "dtype_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)


def dtype_bytes(dt: str) -> float:
    return _DTYPE_BYTES.get(dt, 4)


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES and not dt[0].isalpha():
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * dtype_bytes(dt)
    return total


def analyze_collectives(hlo_text: str) -> dict:
    """Returns totals + per-op-kind breakdown from partitioned HLO."""
    defs: dict[str, str] = {}
    lines = hlo_text.splitlines()
    parsed = []
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        defs[name] = type_str
        parsed.append((name, type_str, opcode, ln))

    per_kind_operand = collections.Counter()
    per_kind_wire = collections.Counter()
    per_kind_count = collections.Counter()
    for name, type_str, opcode, ln in parsed:
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                base = c
                break
        if base is None:
            continue
        # operand names: inside the call parens, %refs only
        call = ln.split(opcode + "(", 1)[1]
        depth, args, cur = 1, [], []
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        if cur:
            args.append("".join(cur).strip())
        operand_bytes = 0.0
        for a in args:
            a = a.strip().lstrip("%")
            if a in defs:
                operand_bytes += _type_bytes(defs[a])
        result_bytes = _type_bytes(type_str)
        if opcode.endswith("-start"):
            # start-op result tuple repeats operand + result; halve it
            result_bytes = result_bytes / 2.0
        if base == "all-gather":
            wire = result_bytes
        elif base == "all-reduce":
            wire = 2.0 * operand_bytes
        else:
            wire = operand_bytes
        per_kind_operand[base] += operand_bytes
        per_kind_wire[base] += wire
        per_kind_count[base] += 1

    return {
        "operand_bytes": float(sum(per_kind_operand.values())),
        "wire_bytes": float(sum(per_kind_wire.values())),
        "by_kind": {
            k: {
                "count": per_kind_count[k],
                "operand_bytes": float(per_kind_operand[k]),
                "wire_bytes": float(per_kind_wire[k]),
            }
            for k in per_kind_count
        },
    }


def op_census(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Opcode frequency (duplicate fusions/remat show up here)."""
    counts = collections.Counter()
    for ln in hlo_text.splitlines():
        m = _DEF_RE.match(ln)
        if m:
            counts[m.group(3)] += 1
    return counts.most_common(top)
