"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
forces 512 host platform devices).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """jax.make_mesh, tolerant of a device pool larger than the mesh."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n]).reshape(shape), axes)
