"""Batched decode driver: greedy generation over a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 8 --gen 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as model_mod
from repro.models import transformer
from repro.obs.timing import Stopwatch


def generate(cfg, params, prompts: np.ndarray, gen: int, *, dtype=jnp.float32):
    """prompts: [B, P] int32. Greedy decode; prompt fed token by token."""
    B, P = prompts.shape
    max_len = P + gen
    cache = transformer.init_cache(cfg, B, max_len, dtype)
    serve = jax.jit(model_mod.make_serve_step(cfg, None, compute_dtype=dtype))
    tok = jnp.asarray(prompts[:, :1])
    out = [np.asarray(tok)]
    logits = None
    for pos in range(max_len - 1):
        logits, cache = serve(params, cache, tok, jnp.int32(pos))
        if pos + 1 < P:
            tok = jnp.asarray(prompts[:, pos + 1 : pos + 2])  # teacher-force prompt
        else:
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]  # greedy
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.kind != "encoder", "encoder archs have no decode step"
    params, _ = transformer.init_params(jax.random.key(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    sw = Stopwatch()
    seqs = generate(cfg, params, prompts, args.gen)
    dt = sw.elapsed()
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"arch={cfg.name} generated {seqs.shape} in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
