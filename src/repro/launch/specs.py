"""Input ShapeDtypeStructs + shardings for every (arch x shape) cell.

``input_specs()`` returns weak-type-correct stand-ins (no allocation) for
every model input; the shardings come from the same logical-axis rules the
params use.  Shape-specific rule overrides:

* ``long_500k`` (batch=1): activations can't shard on batch -> KV cache
  shards its *sequence* dim over the data axis (sequence parallelism), and
  batch falls back to replicated via the divisibility rule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.models import transformer
from repro.sharding.rules import MeshCtx, logical_to_spec, spec_tree

__all__ = [
    "make_ctx",
    "train_input_specs",
    "train_input_shardings",
    "serve_input_specs",
    "serve_input_shardings",
    "abstract_state_and_shardings",
]


def make_ctx(mesh, cfg: ArchConfig, shape: ShapeSpec) -> MeshCtx:
    ctx = MeshCtx(mesh=mesh)
    if shape.kind in ("prefill", "decode"):
        # Inference: no optimizer state, so dense params fit TP-only —
        # FSDP-gathering weights every step would be pure collective waste.
        # Expert weights keep an FSDP axis: MoE volume never fits TP-only
        # (kimi-k2 = 1T params).  Prefill keeps it on d_model ("embed_e",
        # gather amortized over ~1M tokens); decode moves it to the expert
        # d_ff dim ("moe_ff") so weights stay resident and the (tiny) token
        # batch is dispatched instead (models/moe.py token_dispatch).
        ctx = ctx.with_rules(embed=())
    if shape.kind == "decode" and cfg.is_moe:
        ctx = ctx.with_rules(embed_e=(), moe_ff=("data",))
    if shape.name == "long_500k":
        ctx = ctx.with_rules(seq_kv=("data",))
    # NOTE: decode_32k keeps KV caches batch-sharded only.  Sharding the
    # cache seq dim looks attractive memory-wise but the per-token
    # dynamic-update-slice then crosses a sharded dim and the SPMD
    # partitioner falls back to full rematerialization of the cache
    # (measured: +36GB temp, +10x flops).  See EXPERIMENTS.md §Perf.
    return ctx


def _token_specs(cfg: ArchConfig, batch: int, seq: int):
    specs, axes = {}, {}
    if cfg.frontend == "audio_stub":
        specs["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), jnp.float32)
        axes["frames"] = ("batch", "seq", None)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        axes["labels"] = ("batch", "seq")
    elif cfg.frontend == "vision_stub":
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), jnp.float32
        )
        axes["patches"] = ("batch", None, None)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq - cfg.num_patches), jnp.int32)
        axes["tokens"] = ("batch", None)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    return specs, axes


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    return _token_specs(cfg, shape.global_batch, shape.seq_len)[0]


def train_input_shardings(ctx: MeshCtx, cfg: ArchConfig, shape: ShapeSpec):
    specs, axes = _token_specs(cfg, shape.global_batch, shape.seq_len)
    return {
        k: NamedSharding(ctx.mesh, logical_to_spec(ctx, specs[k].shape, axes[k]))
        for k in specs
    }


def serve_input_specs(cfg: ArchConfig, shape: ShapeSpec, kv_dtype=None):
    """(cache, tokens, pos) abstract values for decode_step lowering."""
    dt = kv_dtype if kv_dtype is not None else jnp.bfloat16
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len, dt)
    )
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def serve_input_shardings(ctx: MeshCtx, cfg: ArchConfig, shape: ShapeSpec, kv_dtype=None):
    cache, tokens, pos = serve_input_specs(cfg, shape, kv_dtype=kv_dtype)
    c_axes = transformer.cache_axes(cfg, int8=kv_dtype == jnp.int8)
    cache_sh = jax.tree.map(
        lambda x, s: NamedSharding(ctx.mesh, s),
        cache,
        spec_tree(ctx, cache, c_axes),
    )
    tok_sh = NamedSharding(ctx.mesh, logical_to_spec(ctx, tokens.shape, ("batch", None)))
    pos_sh = NamedSharding(ctx.mesh, PartitionSpec())
    return cache_sh, tok_sh, pos_sh


def abstract_state_and_shardings(ctx: MeshCtx, cfg: ArchConfig, param_dtype=jnp.float32):
    """Abstract train state + its NamedSharding tree."""
    from repro.models import model as model_mod
    from repro.runtime.elastic import state_shardings

    state = model_mod.abstract_train_state(cfg, param_dtype=param_dtype)
    axes = transformer.param_axes(cfg)
    shardings = state_shardings(ctx, state, axes)
    return state, shardings
