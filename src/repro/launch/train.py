"""End-to-end training driver: checkpointed, restartable, straggler-aware.

Runs any of the 10 architectures (reduced or full config) on whatever devices
exist.  Example (CPU, reduced config, a few hundred steps):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Restart the same command after killing it: it resumes from the last
committed checkpoint, bitwise identically (stateless data pipeline).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import TokenPipeline
from repro.models import model as model_mod
from repro.runtime.fault_tolerance import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32", choices=["float32", "bfloat16"])
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    pipe = TokenPipeline(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    step_fn = jax.jit(
        model_mod.make_train_step(
            cfg,
            None,
            compute_dtype=dtype,
            lr_peak=args.lr,
            warmup=max(args.steps // 10, 1),
            total_steps=args.steps,
            grad_accum=args.grad_accum,
        )
    )

    loop = TrainLoop(
        step_fn, pipe, args.ckpt_dir, ckpt_every=args.ckpt_every
    )
    init = model_mod.init_train_state(jax.random.key(args.seed), cfg)
    state, start = loop.resume_or_init(init)
    if start:
        print(f"resumed from checkpoint at step {start}")
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M steps {start}..{start + args.steps}")
    state, hist = loop.run(state, start, args.steps)
    print(
        f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
        f"retries={loop.retries} stragglers={loop.straggler.events}"
    )


if __name__ == "__main__":
    main()
