"""LM substrate: layers, attention, MoE, recurrent blocks, model assembly."""
