"""Attention: GQA full/causal/prefix/local variants + KV-cache decode.

Layouts: activations are [B, S, H, hd].  Three implementations:

* ``direct``  — materialized logits (small shapes, oracle).
* ``chunked`` — lax.scan over KV chunks with online softmax ("flash in HLO"):
  memory stays O(S * chunk) regardless of sequence length; this is the
  CPU-compilable stand-in whose HLO memory profile tracks the Pallas kernel.
* ``flash``   — the Pallas kernel (repro.kernels.flash_attention), TPU target.

Local (sliding-window) attention uses banded chunking — q chunk i attends kv
chunks {i-1, i} with an exact in-window mask — so HLO flops are O(S * 2W),
not O(S^2); this is what makes recurrentgemma's 500k-context shapes
sub-quadratic (DESIGN.md §Arch-applicability: the band is the paper's banded
test case at the attention level).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention", "decode_attention"]

_NEG = -1e30


def _repeat_kv(k: jax.Array, heads: int) -> jax.Array:
    hk = k.shape[2]
    if hk == heads:
        return k
    return jnp.repeat(k, heads // hk, axis=2)


def _mask(qpos, kpos, *, causal, window, prefix_len):
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    if prefix_len is not None:
        m |= kp < prefix_len  # prefix-LM: everything sees the prefix
    return m


def _direct(q, k, v, qpos, kpos, *, causal, window, prefix_len, scale):
    k = _repeat_kv(k, q.shape[2])
    v = _repeat_kv(v, q.shape[2])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    m = _mask(qpos, kpos, causal=causal, window=window, prefix_len=prefix_len)
    logits = jnp.where(m[:, None] if m.ndim == 3 else m[None, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def _chunked(q, k, v, qpos, kpos, *, causal, window, prefix_len, scale, chunk):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    if Sk % chunk:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-(10**9))
        Sk = Sk + pad
    nk = Sk // chunk
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    kc = k.reshape(B, nk, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk, H, D).transpose(1, 0, 2, 3, 4)
    kpc = kpos.reshape(nk, chunk)

    def step(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, kp = xs
        logits = jnp.einsum(
            "bqhd,bkhd->bhqk", q.astype(jnp.float32), kb.astype(jnp.float32)
        )
        logits *= scale
        msk = _mask(qpos, kp, causal=causal, window=window, prefix_len=prefix_len)
        logits = jnp.where(msk[None, None], logits, _NEG)
        m_new = jnp.maximum(m_run, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kpc))
    l_f = jnp.where(l_f == 0.0, 1.0, l_f)
    out = (acc / l_f[..., None]).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def _local_banded(q, k, v, *, window, causal, scale):
    """Sliding-window attention via banded chunking: O(S * 2W) flops."""
    B, S, H, D = q.shape
    W = window
    pad = (-S) % W
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    n = Sp // W
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    qb = q.reshape(B, n, W, H, D)
    # kv context for chunk i = chunks [i-1, i] -> width 2W
    kb = k.reshape(B, n, W, H, D)
    vb = v.reshape(B, n, W, H, D)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kctx = jnp.concatenate([k_prev, kb], axis=2)  # [B, n, 2W, H, D]
    vctx = jnp.concatenate([v_prev, vb], axis=2)
    logits = jnp.einsum(
        "bnqhd,bnkhd->bnhqk", qb.astype(jnp.float32), kctx.astype(jnp.float32)
    )
    logits *= scale
    qpos = jnp.arange(n * W).reshape(n, W)
    # positions of the 2W context for chunk i: (i-1)*W ... (i+1)*W - 1
    ctx = (jnp.arange(n)[:, None] - 1) * W + jnp.arange(2 * W)[None, :]
    qp = qpos[:, :, None]
    kp = ctx[:, None, :]
    m = (kp >= 0) & (kp > qp - W)
    if causal:
        m &= kp <= qp
    logits = jnp.where(m[None, :, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p, vctx.astype(jnp.float32))
    out = out.reshape(B, Sp, H, D)[:, :S]
    return out.astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int | None = None,
    impl: str = "chunked",
    chunk: int = 512,
) -> jax.Array:
    """q: [B, Sq, H, hd]; k, v: [B, Sk, HK, hd] (HK divides H)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = D**-0.5
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    if window is not None and prefix_len is None and Sq == Sk and impl != "direct":
        return _local_banded(q, k, v, window=window, causal=causal, scale=scale)
    if impl == "flash" and prefix_len is None:
        from repro.kernels import ops as kops

        qt = q.transpose(0, 2, 1, 3)
        out = kops.flash_attention(
            qt, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), causal=causal, window=window
        )
        return out.transpose(0, 2, 1, 3)
    if impl == "direct" or Sq * Sk <= 256 * 256:
        return _direct(
            q, k, v, qpos, kpos, causal=causal, window=window, prefix_len=prefix_len, scale=scale
        )
    return _chunked(
        q,
        k,
        v,
        qpos,
        kpos,
        causal=causal,
        window=window,
        prefix_len=prefix_len,
        scale=scale,
        chunk=chunk,
    )


def decode_attention(
    q: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    window: int | None = None,
    kpos: jax.Array | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """One-token attention against a KV cache.

    q: [B, 1, H, hd]; cache_k/v: [B, S, HK, hd]; pos: current index (scalar).
    kpos optionally gives the true position held by each cache slot (ring
    buffers); negative kpos = never written.  Positions > pos are masked;
    with window, positions <= pos - window too.

    int8 caches: pass per-(b, s, h) absmax scales; they are applied to the
    (tiny) logits / probs, never to the (huge) cache, so quantized serving
    halves cache bytes with no large dequantized temporary.
    """
    B, _, H, D = q.shape
    S = cache_k.shape[1]
    HK = cache_k.shape[2]
    G = H // HK
    # GQA without materializing repeated K/V: group q heads by kv head.
    # preferred_element_type gives fp32 accumulation without materializing
    # an fp32 copy of the (huge) cache.
    qg = q.reshape(B, HK, G, D)
    kq = cache_k.astype(jnp.bfloat16) if cache_k.dtype == jnp.int8 else cache_k
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg.astype(kq.dtype), kq, preferred_element_type=jnp.float32
    )
    if k_scale is not None:  # [B, S, HK] -> scale logits rows
        logits = logits * jnp.transpose(k_scale, (0, 2, 1))[:, :, None, :] / 127.0
    logits *= D**-0.5
    kpos = jnp.arange(S) if kpos is None else kpos
    m = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        m &= kpos > pos - window
    logits = jnp.where(m[None, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        p = p * jnp.transpose(v_scale, (0, 2, 1))[:, :, None, :] / 127.0
    vq = cache_v.astype(jnp.bfloat16) if cache_v.dtype == jnp.int8 else cache_v
    out = jnp.einsum(
        "bhgs,bshd->bhgd",
        p.astype(vq.dtype),
        vq,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)
