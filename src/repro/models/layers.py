"""Shared transformer layers: norms, rotary, MLPs, embeddings.

Parameters are plain nested dicts.  Every init returns ``(params, axes)``
where ``axes`` mirrors the params tree with a tuple of logical axis names per
array dim (None = unsharded/replicated).  :mod:`repro.sharding.rules` turns
logical axes into mesh PartitionSpecs with divisibility fallback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "norm_init",
    "apply_norm",
    "embed_init",
    "mlp_init",
    "mlp_apply",
    "rope",
]


def _normal(key, shape, scale, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def dense_init(key, d_in: int, d_out: int, axes, *, bias: bool = False, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": _normal(key, (d_in, d_out), scale)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
        a["b"] = (axes[1],)
    return p, a


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def proj_in_init(key, d: int, heads: int, hd: int, head_axis: str, *, bias=False):
    """Attention in-projection with explicit head dim: w [d, heads, hd].

    Keeping heads as a real tensor dim lets the sharding rules decide at the
    HEAD COUNT granularity (e.g. qwen2-0.5b's 14 heads correctly replicate on
    a 16-way model axis instead of splitting head_dim)."""
    p = {"w": _normal(key, (d, heads, hd), d**-0.5)}
    a = {"w": ("embed", head_axis, None)}
    if bias:
        p["b"] = jnp.zeros((heads, hd), jnp.float32)
        a["b"] = (head_axis, None)
    return p, a


def proj_in(p, x):
    """[..., d] @ [d, H, hd] -> [..., H, hd]."""
    y = jnp.einsum("...d,dhk->...hk", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def proj_out_init(key, heads: int, hd: int, d: int, head_axis: str):
    p = {"w": _normal(key, (heads, hd, d), (heads * hd) ** -0.5)}
    a = {"w": (head_axis, None, "embed")}
    return p, a


def proj_out(p, x):
    """[..., H, hd] @ [H, hd, d] -> [..., d]."""
    return jnp.einsum("...hk,hkd->...d", x, p["w"].astype(x.dtype))


def norm_init(kind: str, d: int):
    """kind: rmsnorm | layernorm | nonparam_ln (OLMo: no learned params)."""
    if kind == "nonparam_ln":
        return {}, {}
    p = {"scale": jnp.ones((d,), jnp.float32)}
    a = {"scale": ("embed",)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
        a["bias"] = ("embed",)
    return p, a


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        return (y * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def embed_init(key, vocab: int, d: int):
    p = {"table": _normal(key, (vocab, d), 1.0)}
    a = {"table": ("vocab", "embed")}
    return p, a


def mlp_init(key, d: int, d_ff: int, act: str):
    """act: silu (SwiGLU), geglu (gated GELU), gelu (plain 2-matrix MLP)."""
    ks = jax.random.split(key, 3)
    gated = act in ("silu", "geglu")
    p, a = {}, {}
    p["wi"], a["wi"] = _normal(ks[0], (d, d_ff), d**-0.5), ("embed", "mlp")
    if gated:
        p["wg"], a["wg"] = _normal(ks[1], (d, d_ff), d**-0.5), ("embed", "mlp")
    p["wo"], a["wo"] = _normal(ks[2], (d_ff, d), d_ff**-0.5), ("mlp", "embed")
    return p, a


def mlp_apply(p, x, act: str):
    h = x @ p["wi"].astype(x.dtype)
    if act == "silu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(x.dtype))
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ p["wg"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., seq, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
