"""Train / serve step factories: loss, grad accumulation, optimizer wiring."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.optim import adamw_update, clip_by_global_norm, cosine_lr
from repro.sharding.rules import constrain

from . import transformer

__all__ = ["make_loss_fn", "make_train_step", "make_serve_step", "init_train_state"]


def _cast_inputs(batch, dtype):
    return {
        k: (v.astype(dtype) if jnp.issubdtype(v.dtype, jnp.floating) else v)
        for k, v in batch.items()
    }


def _ce(logits, labels):
    """Cross entropy via logsumexp - one_hot contraction.

    Sharding-friendly: with vocab TP-sharded, both the logsumexp reduction
    and the one_hot contraction stay sharded (tiny psums) — no full-logits
    all-gather, unlike take_along_axis.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    correct = jnp.einsum("...v,...v->...", logits, oh)
    return (lse - correct).mean()


def make_loss_fn(
    cfg: ArchConfig, ctx, *, attn_impl="chunked", compute_dtype=jnp.bfloat16, unroll=False
):
    def loss_fn(params, batch):
        cparams = jax.tree.map(
            lambda p: p.astype(compute_dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        b = _cast_inputs(batch, compute_dtype)
        logits = transformer.apply(cparams, cfg, ctx, b, attn_impl=attn_impl, unroll=unroll)
        if cfg.kind == "encoder":
            loss = _ce(logits, batch["labels"])
        elif cfg.frontend == "vision_stub":
            # prefix-LM: text logits start after the patch prefix
            loss = _ce(logits[:, cfg.num_patches : -1], batch["tokens"][:, 1:])
        else:
            loss = _ce(logits[:, :-1], batch["tokens"][:, 1:])
        return loss, {"loss": loss}

    return loss_fn


def init_train_state(key, cfg: ArchConfig, *, param_dtype=jnp.float32):
    """param_dtype=bf16 stores bf16 weights + an fp32 master copy in the
    optimizer (classic mixed precision): gradients and their cross-device
    reductions then run at bf16 — half the all-reduce wire bytes."""
    from repro.optim import adamw_init

    params, _ = transformer.init_params(key, cfg)
    state = {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}
    if param_dtype == jnp.bfloat16:
        state["opt"]["master"] = params  # fp32 master copy
        state["params"] = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
    return state


def abstract_train_state(cfg: ArchConfig, *, param_dtype=jnp.float32):
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, param_dtype=param_dtype), jax.random.key(0)
    )


def make_train_step(
    cfg: ArchConfig,
    ctx,
    *,
    attn_impl: str = "chunked",
    compute_dtype=jnp.bfloat16,
    lr_peak: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    grad_clip: float = 1.0,
    grad_accum: int | None = None,
    weight_decay: float = 0.1,
    unroll: bool = False,
    param_dtype=jnp.float32,
    grad_reshard: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_accum > 1 loops over microbatches (leading batch dim split) — the
    activation-memory lever for the biggest models.  unroll=True uses a
    python loop (dry-run cost accounting); otherwise lax.scan.

    grad_reshard=True pins gradients to the parameter sharding before the
    optimizer, turning the partitioner's weight-grad all-reduce into a
    reduce-scatter (the FSDP-correct reduction: each device only needs its
    shard of the gradient).
    """
    loss_fn = make_loss_fn(
        cfg, ctx, attn_impl=attn_impl, compute_dtype=compute_dtype, unroll=unroll
    )
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    bf16_params = param_dtype == jnp.bfloat16

    grad_shardings = None
    if grad_reshard and ctx is not None:
        from jax.sharding import NamedSharding

        from repro.sharding.rules import spec_tree

        ps, axes = transformer.abstract_params(cfg)
        grad_shardings = jax.tree.map(
            lambda s: NamedSharding(ctx.mesh, s), spec_tree(ctx, ps, axes)
        )

    def train_step(state, batch):
        params = state["params"]
        gdtype = jnp.bfloat16 if bf16_params else jnp.float32

        def grads_of(mb):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            if grad_shardings is not None:
                g = jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)
            return loss, g

        if accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if unroll:
                grads, loss_sum = g0, 0.0
                for i in range(accum):
                    mb = jax.tree.map(lambda x: x[i], micro)
                    loss, g = grads_of(mb)
                    grads = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), grads, g)
                    loss_sum = loss_sum + loss
            else:

                def body(carry, mb):
                    gacc, lacc = carry
                    loss, g = grads_of(mb)
                    gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + loss), None

                (grads, loss_sum), _ = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        else:
            loss, grads = grads_of(batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = cosine_lr(state["step"], peak=lr_peak, warmup=warmup, total=total_steps)
        master = state["opt"].get("master", params)
        opt_in = {k: v for k, v in state["opt"].items() if k != "master"}
        new_master, new_opt = adamw_update(
            grads, opt_in, master, lr=lr, weight_decay=weight_decay
        )
        if bf16_params:
            new_params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else p,
                new_master,
            )
            new_opt["master"] = new_master
        else:
            new_params = new_master
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


def make_serve_step(cfg: ArchConfig, ctx, *, compute_dtype=jnp.bfloat16, unroll=False):
    """Returns serve_step(params, cache, tokens, pos) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        cparams = jax.tree.map(
            lambda p: p.astype(compute_dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        return transformer.decode_step(cparams, cfg, ctx, cache, tokens, pos, unroll=unroll)

    return serve_step
