"""Mixture-of-Experts with expert parallelism — the paper's technique in LMs.

A dropless-ish MoE FFN *is* a block-sparse matrix multiply (MegaBlocks): the
token-by-expert dispatch pattern is exactly a quadtree-style block structure
known only at run time, and the expert GEMMs are the grouped block products
our Pallas kernel executes.  Mapping onto the mesh:

* activations are data-parallel over (pod, data) and **replicated along the
  model axis**; experts are sharded over the model axis (EP).
* the layer runs under shard_map: each device routes its local tokens,
  selects the pairs destined to *its* experts (sort-based, capacity-bounded,
  static shapes), runs the expert FFN, and psums partial outputs over the
  model axis — the same all-reduce a TP MLP would pay, so EP costs no extra
  collective class.
* expert GEMM path: batched einsum (XLA) or the grouped block_spmm kernel
  with trivially-grouped tasks (one per expert) — ``gemm_impl``.

Capacity: Ce = ceil(T_local * top_k * capacity_factor / E).  Overflowing
pairs are dropped (standard capacity-factor semantics); the combine step
renormalizes surviving gates.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.jax_compat import shard_map

from .layers import _normal

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d: int, d_ff: int, num_experts: int, act: str):
    ks = jax.random.split(key, 4)
    gated = act in ("silu", "geglu")
    p = {
        "router": _normal(ks[0], (d, num_experts), d**-0.5),
        "w1": _normal(ks[1], (num_experts, d, d_ff), d**-0.5),
        "w2": _normal(ks[3], (num_experts, d_ff, d), d_ff**-0.5),
    }
    a = {
        "router": ("embed", None),
        "w1": ("expert", "embed_e", "moe_ff"),
        "w2": ("expert", "moe_ff", "embed_e"),
    }
    if gated:
        p["wg"] = _normal(ks[2], (num_experts, d, d_ff), d**-0.5)
        a["wg"] = ("expert", "embed_e", "moe_ff")
    return p, a


def _expert_ffn(xe, w1, wg, w2, act: str, gemm_impl: str):
    """xe: [E_l, Ce, D]; w1: [E_l, D, F].  Batched expert GEMMs."""
    mm = functools.partial(_grouped_mm, gemm_impl=gemm_impl)
    h = mm(xe, w1)
    if act == "silu":
        h = jax.nn.silu(h) * mm(xe, wg)
    elif act == "geglu":
        h = jax.nn.gelu(h) * mm(xe, wg)
    else:
        h = jax.nn.gelu(h)
    return mm(h.astype(xe.dtype), w2)


def _grouped_mm(x, w, *, gemm_impl: str):
    """[E, M, K] x [E, K, N] -> [E, M, N] via einsum or the paper's kernel."""
    if gemm_impl == "block_spmm":
        from repro.kernels import ops as kops

        E = x.shape[0]
        idx = jnp.arange(E, dtype=jnp.int32)
        return kops.block_spmm(x, w.astype(x.dtype), idx, idx, idx, E).astype(x.dtype)
    return jnp.einsum("emk,ekn->emn", x, w.astype(x.dtype))


def _moe_local(
    x,
    router,
    w1,
    wg,
    w2,
    *,
    e_base,
    num_experts,
    top_k,
    capacity,
    act,
    gemm_impl,
):
    """Per-device MoE over local tokens and local experts.

    x: [B_l, S, D]; w1: [E_l, D, F].  Returns the partial output from local
    experts (to be psum'd over the model axis).
    """
    B, S, D = x.shape
    E_l = w1.shape[0]
    T = B * S
    xf = x.reshape(T, D)
    logits = (xf @ router.astype(xf.dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)  # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    pe = eidx.reshape(-1)  # [T*k] expert id per pair
    pt = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    pg = gates.reshape(-1)

    # rank of each pair within its expert (stable arrival order)
    order = jnp.argsort(pe, stable=True)
    sorted_e = pe[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(T * top_k, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros((T * top_k,), jnp.int32).at[order].set(rank_sorted)

    mine = (pe >= e_base) & (pe < e_base + E_l)
    valid = mine & (rank < capacity)
    slot = jnp.where(valid, (pe - e_base) * capacity + rank, E_l * capacity)

    # dispatch: slot -> token index (pad rows read a zero token)
    disp = jnp.full((E_l * capacity + 1,), T, jnp.int32).at[slot].set(
        jnp.where(valid, pt, T)
    )[:-1]
    comb_gate = jnp.zeros((E_l * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, pg, 0.0)
    )[:-1]
    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = x_pad[disp].reshape(E_l, capacity, D)

    ye = _expert_ffn(xe, w1, wg, w2, act, gemm_impl)  # [E_l, Ce, D]

    ye_flat = ye.reshape(E_l * capacity, D) * comb_gate[:, None].astype(ye.dtype)
    out = jax.ops.segment_sum(ye_flat, disp, num_segments=T + 1)[:T]
    return out.reshape(B, S, D).astype(x.dtype)


def moe_apply(
    p,
    x,
    ctx,
    *,
    num_experts: int,
    top_k: int,
    act: str,
    capacity_factor: float = 1.25,
    gemm_impl: str = "einsum",
    dropless: bool = False,
    token_dispatch: bool = False,
):
    """x: [B, S, D] (dp-sharded, replicated over model axis).

    dropless=True sets capacity to the worst case (every token's top-k hits
    the same expert => cap = local token count): no pair is ever dropped.
    Used at decode time, where token counts are tiny and drops would skew
    generation; training keeps the classic capacity factor.
    """
    wg = p.get("wg")

    def _cap(Tl):
        if dropless:
            return Tl
        return max(1, math.ceil(Tl * top_k * capacity_factor / num_experts))

    if ctx is None or ctx.tp_axis is None or num_experts % ctx.tp_size() != 0:
        # single-device / no-EP fallback: all experts local
        Tl = x.shape[0] * x.shape[1]
        cap = _cap(Tl)
        return _moe_local(
            x,
            p["router"],
            p["w1"],
            wg if wg is not None else p["w1"],
            p["w2"],
            e_base=0,
            num_experts=num_experts,
            top_k=top_k,
            capacity=cap,
            act=act,
            gemm_impl=gemm_impl,
        )

    tp = ctx.tp_axis
    tp_size = ctx.tp_size()
    dp = ctx.dp_axes
    E_l = num_experts // tp_size
    dp_size = int(np.prod([ctx.axis_sizes[a] for a in dp])) if dp else 1
    B = x.shape[0]
    wg_in = wg if wg is not None else p["w1"][:, :, :0]

    if (
        token_dispatch
        and dp
        and B % dp_size == 0
        and p["w1"].shape[-1] % dp_size == 0
    ):
        # ---- decode dispatch mode: move tokens (KB), not weights (GB) ----
        # Expert weights stay fully resident, F-dim sharded over the data
        # axes; the (tiny) decode batch is all-gathered so every device
        # serves its own experts' F-slice, then one psum over the whole mesh
        # recombines.  Replaces the per-token FSDP gather of expert weights.
        B_l = B // dp_size
        T_full = B * x.shape[1]

        def body_dispatch(x_l, router, w1_l, wg_l, w2_l):
            xg = jax.lax.all_gather(x_l, dp, axis=0, tiled=True)  # [B, 1, D]
            e_base = jax.lax.axis_index(tp) * E_l
            out = _moe_local(
                xg,
                router,
                w1_l,
                wg_l,
                w2_l,
                e_base=e_base,
                num_experts=num_experts,
                top_k=top_k,
                capacity=T_full,  # dropless at decode scale
                act=act,
                gemm_impl=gemm_impl,
            )
            out = jax.lax.psum(out, (tp, *dp))
            # slice back this device's batch rows
            idx = jax.lax.axis_index(dp[0])
            for a in dp[1:]:
                idx = idx * ctx.axis_sizes[a] + jax.lax.axis_index(a)
            return jax.lax.dynamic_slice_in_dim(out, idx * B_l, B_l, axis=0)

        return shard_map(
            body_dispatch,
            mesh=ctx.mesh,
            in_specs=(
                P(dp, None, None),
                P(None, None),
                P(tp, None, dp),
                P(tp, None, dp),
                P(tp, dp, None),
            ),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(x, p["router"], p["w1"], wg_in, p["w2"])

    Tl = (B // max(dp_size, 1)) * x.shape[1]
    cap = _cap(Tl)

    def body(x_l, router, w1, wg_l, w2):
        e_base = jax.lax.axis_index(tp) * E_l
        out = _moe_local(
            x_l,
            router,
            w1,
            wg_l,
            w2,
            e_base=e_base,
            num_experts=num_experts,
            top_k=top_k,
            capacity=cap,
            act=act,
            gemm_impl=gemm_impl,
        )
        return jax.lax.psum(out, tp)

    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(dp, None, None),
            P(None, None),
            P(tp, None, None),
            P(tp, None, None),
            P(tp, None, None),
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, p["router"], p["w1"], wg_in, p["w2"])
