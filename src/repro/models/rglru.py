"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence:  r_t = sigmoid(W_r u_t);  i_t = sigmoid(W_i u_t)
             a_t = exp(-c * softplus(Lambda) * r_t)        (c = 8)
             h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t u_t)

Training/prefill uses a parallel associative scan over time (TPU-friendly:
log-depth, no sequential loop); decode keeps h as O(1) state.  Gate weights
are block-diagonal per head, as in Griffin.  The surrounding recurrent block
is: linear-in (2 branches) -> causal depthwise conv (w=4) -> RG-LRU ->
gated merge -> linear-out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _normal

__all__ = ["rglru_block_init", "rglru_block_apply", "rglru_decode_step", "rglru_init_state"]

_C = 8.0
_CONV_W = 4


def rglru_block_init(key, d: int, heads: int):
    ks = jax.random.split(key, 7)
    dh = d // heads
    p = {
        "w_in_x": _normal(ks[0], (d, d), d**-0.5),
        "w_in_g": _normal(ks[1], (d, d), d**-0.5),
        "conv": _normal(ks[2], (_CONV_W, d), 0.1),
        "w_r": _normal(ks[3], (heads, dh, dh), dh**-0.5),
        "w_i": _normal(ks[4], (heads, dh, dh), dh**-0.5),
        # Lambda parametrized so a ~ U(0.9, 0.999) at r = 1
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, d)) / _C)).astype(
            jnp.float32
        ),
        "w_out": _normal(ks[5], (d, d), d**-0.5),
    }
    a = {
        "w_in_x": ("embed", "rnn"),
        "w_in_g": ("embed", "rnn"),
        "conv": (None, "rnn"),
        "w_r": ("heads", None, None),
        "w_i": ("heads", None, None),
        "lam": ("rnn",),
        "w_out": ("rnn", "embed"),
    }
    return p, a


def _gates(p, u, heads):
    B, S, D = u.shape
    dh = D // heads
    uh = u.reshape(B, S, heads, dh)
    r = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", uh, p["w_r"].astype(u.dtype)))
    i = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", uh, p["w_i"].astype(u.dtype)))
    return r.reshape(B, S, D), i.reshape(B, S, D)


def _conv_causal(w, x, tail=None):
    """Depthwise causal conv, width 4.  tail: [B, 3, D] previous inputs."""
    if tail is None:
        shifted = [x]
        for j in range(1, _CONV_W):
            shifted.append(jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]])
    else:
        ctx = jnp.concatenate([tail, x], axis=1)  # [B, 3 + S, D]
        shifted = [ctx[:, _CONV_W - 1 - j : ctx.shape[1] - j] for j in range(_CONV_W)]
        shifted[0] = x
    out = sum(w[j].astype(x.dtype) * s for j, s in enumerate(shifted))
    return out


def _rglru_scan(p, u, heads, h0=None):
    """Parallel scan over time. u: [B, S, D] -> y, h_last."""
    r, i = _gates(p, u, heads)
    log_a = -_C * jax.nn.softplus(p["lam"]).astype(jnp.float32) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (
        i.astype(jnp.float32) * u.astype(jnp.float32)
    )
    if h0 is not None:  # fold initial state into step 0
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_block_apply(p, x, *, heads: int):
    """Full block: [B, S, D] -> [B, S, D]."""
    u = x @ p["w_in_x"].astype(x.dtype)
    g = jax.nn.gelu(x @ p["w_in_g"].astype(x.dtype))
    u = _conv_causal(p["conv"], u)
    y, _ = _rglru_scan(p, u, heads)
    return (y * g) @ p["w_out"].astype(x.dtype)


def rglru_init_state(batch: int, d: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, d), dtype),
    }


def rglru_decode_step(p, x, state, *, heads: int):
    """One-token step. x: [B, 1, D] -> (y, new_state)."""
    u = x @ p["w_in_x"].astype(x.dtype)
    g = jax.nn.gelu(x @ p["w_in_g"].astype(x.dtype))
    conv_tail = state["conv"]
    u_c = _conv_causal(p["conv"], u, tail=conv_tail)
    new_tail = jnp.concatenate([conv_tail[:, 1:], u], axis=1)
    r, i = _gates(p, u_c, heads)
    a = jnp.exp(-_C * jax.nn.softplus(p["lam"]) * r[:, 0].astype(jnp.float32))
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1 - a**2, 1e-12)) * (
        i[:, 0].astype(jnp.float32) * u_c[:, 0].astype(jnp.float32)
    )
    y = (h.astype(x.dtype)[:, None] * g) @ p["w_out"].astype(x.dtype)
    return y, {"h": h, "conv": new_tail}
