"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

The SSD chunked algorithm is itself a block-banded matrix computation (a
semiseparable matrix product): intra-chunk terms are dense bs x bs blocks on
the diagonal band, inter-chunk terms flow through a rank-N state — the same
"exploit block structure, skip zero blocks" insight the paper applies to
quadtrees (DESIGN.md §Arch-applicability).

Block layout (mamba2): in_proj -> [z (gate), x, B, C, dt]; causal depthwise
conv (w=4) on (x, B, C); SSD; gated RMSNorm; out_proj.  Decode carries the
[B, H, P, N] state plus the conv tail: O(1) per token, which is what makes
the 500k-context decode shape runnable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _normal, apply_norm

__all__ = ["ssd_block_init", "ssd_block_apply", "ssd_decode_step", "ssd_init_state"]

_CONV_W = 4


def ssd_block_init(key, d: int, *, d_inner: int, heads: int, d_state: int):
    ks = jax.random.split(key, 5)
    hp = d_inner // heads  # head dim P
    conv_dim = d_inner + 2 * d_state
    p = {
        "in_proj": _normal(
            ks[0], (d, 2 * d_inner + 2 * d_state + heads), d**-0.5
        ),
        "conv": _normal(ks[1], (_CONV_W, conv_dim), 0.1),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _normal(ks[2], (d_inner, d), d_inner**-0.5),
    }
    a = {
        "in_proj": ("embed", "rnn"),
        "conv": (None, None),
        "a_log": ("heads",),
        "d_skip": ("heads",),
        "dt_bias": ("heads",),
        "norm_scale": ("rnn",),
        "out_proj": ("rnn", "embed"),
    }
    return p, a


def _split_proj(p, x, d_inner, d_state, heads):
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xs, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state], -1
    )
    return z, xs, B, C, dt


def _conv(w, u, tail=None):
    if tail is None:
        shifted = [u] + [
            jnp.pad(u, ((0, 0), (j, 0), (0, 0)))[:, : u.shape[1]] for j in range(1, _CONV_W)
        ]
    else:
        ctx = jnp.concatenate([tail, u], axis=1)
        shifted = [ctx[:, _CONV_W - 1 - j : ctx.shape[1] - j] for j in range(_CONV_W)]
        shifted[0] = u
    return jax.nn.silu(sum(w[j].astype(u.dtype) * s for j, s in enumerate(shifted)))


def _segsum(x):
    """log-space cumulative segment sums: out[..., i, j] = sum_{j<k<=i} x[k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh, dt, a_log, Bm, Cm, *, chunk: int):
    """SSD over chunks.  xh: [B, S, H, P]; dt: [B, S, H]; Bm/Cm: [B, S, N].

    Returns y [B, S, H, P] and final state [B, H, P, N].
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    A = -jnp.exp(a_log)  # [H] negative
    dtA = dt * A  # [B, S, H]
    xt = (xh * dt[..., None]).reshape(Bsz, nc, Q, H, Pd)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    dA = dtA.reshape(Bsz, nc, Q, H).transpose(0, 3, 1, 2)  # [B, H, nc, Q]
    dA_cs = jnp.cumsum(dA, -1)

    # intra-chunk (block-diagonal band): L = exp(segsum(dA))
    L = jnp.exp(_segsum(dA))  # [B, H, nc, Q, Q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xt)

    # chunk states: decay to end of chunk
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)  # [B, H, nc, Q]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xt)

    # inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cs[..., -1])  # [B, H, nc]

    def step(h, inp):
        dec, s = inp  # dec: [B, H]; s: [B, H, P, N]
        h_new = h * dec[..., None, None] + s
        return h_new, h

    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        step,
        h0,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)),
    )
    # prev_states[c] = state entering chunk c
    final_state, _ = step(
        prev_states[-1], (chunk_decay[..., -1], states[:, -1].astype(jnp.float32))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B, nc, H, P, N]

    decay_out = jnp.exp(dA_cs)  # [B, H, nc, Q]
    y_off = jnp.einsum(
        "bcln,bhcl,bchpn->bclhp", Cc, decay_out, prev_states.astype(Cc.dtype)
    )
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, final_state


def ssd_block_apply(p, x, *, d_inner: int, heads: int, d_state: int, chunk: int = 128):
    B, S, D = x.shape
    Pd = d_inner // heads
    z, xs, Bm, Cm, dt = _split_proj(p, x, d_inner, d_state, heads)
    conv_in = jnp.concatenate([xs, Bm, Cm], -1)
    conv_out = _conv(p["conv"], conv_in)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, S, H]
    xh = xs.reshape(B, S, heads, Pd)
    y, _ = ssd_chunked(xh.astype(jnp.float32), dt, p["a_log"], Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk=chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = apply_norm("rmsnorm", {"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype)


def ssd_init_state(batch: int, *, d_inner: int, heads: int, d_state: int, dtype=jnp.float32):
    Pd = d_inner // heads
    return {
        "h": jnp.zeros((batch, heads, Pd, d_state), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, d_inner + 2 * d_state), dtype),
    }


def ssd_decode_step(p, x, state, *, d_inner: int, heads: int, d_state: int):
    """One-token recurrent step.  x: [B, 1, D]."""
    B = x.shape[0]
    Pd = d_inner // heads
    z, xs, Bm, Cm, dt = _split_proj(p, x, d_inner, d_state, heads)
    conv_in = jnp.concatenate([xs, Bm, Cm], -1)
    conv_out = _conv(p["conv"], conv_in, tail=state["conv"])
    new_tail = jnp.concatenate([state["conv"][:, 1:], conv_in], axis=1)
    xs, Bm, Cm = jnp.split(conv_out[:, 0], [d_inner, d_inner + d_state], -1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B, H]
    A = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * A)  # [B, H]
    xh = xs.reshape(B, heads, Pd).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    h = state["h"] * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = apply_norm("rmsnorm", {"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype), {"h": h, "conv": new_tail}
