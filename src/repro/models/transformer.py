"""Model assembly: decoder / encoder / VLM / hybrid / SSM from ArchConfig.

Layers are grouped by the repeating block pattern and scanned over pattern
periods (stacked params, compact HLO — SPMD partitions one period body).
Pattern remainder layers are unrolled at the end.  Decode carries a cache
pytree with the same period structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.sharding.rules import constrain

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rec_mod
from . import ssd as ssm_mod
from .layers import (
    apply_norm,
    dense,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
    proj_in,
    proj_in_init,
    proj_out,
    proj_out_init,
    rope,
)

__all__ = ["init_params", "param_axes", "apply", "init_cache", "decode_step"]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _block_init(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["ln1"], a["ln1"] = norm_init(cfg.norm, cfg.d_model)
    if kind in ("attn", "local"):
        hd = cfg.hd
        p["q"], a["q"] = proj_in_init(
            ks[0], cfg.d_model, cfg.num_heads, hd, "heads", bias=cfg.qkv_bias
        )
        p["k"], a["k"] = proj_in_init(
            ks[1], cfg.d_model, cfg.num_kv_heads, hd, "kv_heads", bias=cfg.qkv_bias
        )
        p["v"], a["v"] = proj_in_init(
            ks[2], cfg.d_model, cfg.num_kv_heads, hd, "kv_heads", bias=cfg.qkv_bias
        )
        p["o"], a["o"] = proj_out_init(ks[3], cfg.num_heads, hd, cfg.d_model, "heads")
    elif kind == "rec":
        p["mix"], a["mix"] = rec_mod.rglru_block_init(ks[0], cfg.d_model, cfg.num_heads)
    elif kind == "ssm":
        p["mix"], a["mix"] = ssm_mod.ssd_block_init(
            ks[0], cfg.d_model, d_inner=cfg.d_inner, heads=cfg.ssm_heads, d_state=cfg.ssm_state
        )
        return p, a  # mamba block: norm + mixer only, no separate MLP
    p["ln2"], a["ln2"] = norm_init(cfg.norm, cfg.d_model)
    if cfg.is_moe and kind in ("attn", "local"):
        p["moe"], a["moe"] = moe_mod.moe_init(
            ks[4], cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.mlp_act
        )
    else:
        p["mlp"], a["mlp"] = mlp_init(ks[4], cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p, a


def _prepend_layers_axis(axes):
    return jax.tree.map(
        lambda ax: ("layers", *ax), axes, is_leaf=lambda x: isinstance(x, tuple)
    )


def _pattern_layout(cfg: ArchConfig):
    pattern = cfg.block_pattern
    periods = cfg.num_layers // len(pattern)
    rem = cfg.num_layers % len(pattern)
    return pattern, periods, rem


def init_params(key, cfg: ArchConfig):
    """Returns (params, axes) — axes mirrors params with logical axis names."""
    pattern, periods, rem = _pattern_layout(cfg)
    keys = jax.random.split(key, 8)
    p, a = {}, {}
    p["embed"], a["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model)
    if cfg.frontend == "audio_stub":
        p["frontend"], a["frontend"] = dense_init(
            keys[1], cfg.frontend_dim, cfg.d_model, ("embed", None)
        )
    blocks_p, blocks_a = {}, {}
    for pos, kind in enumerate(pattern):
        kpos = jax.random.fold_in(keys[2], pos)
        ks = jax.random.split(kpos, periods)
        blocks_p[f"p{pos}"] = jax.vmap(lambda k: _block_init(k, cfg, kind)[0])(ks)
        blocks_a[f"p{pos}"] = _prepend_layers_axis(_block_init(kpos, cfg, kind)[1])
    p["blocks"], a["blocks"] = blocks_p, blocks_a
    tail_p, tail_a = [], []
    for i in range(rem):
        kind = pattern[i % len(pattern)]
        tp, ta = _block_init(jax.random.fold_in(keys[3], i), cfg, kind)
        tail_p.append(tp)
        tail_a.append(ta)
    if tail_p:
        p["tail"], a["tail"] = tail_p, tail_a
    p["final_norm"], a["final_norm"] = norm_init(
        cfg.norm if cfg.norm != "nonparam_ln" else "rmsnorm", cfg.d_model
    )
    if not cfg.tie_embeddings:
        p["head"], a["head"] = dense_init(
            keys[4], cfg.d_model, cfg.vocab_size, ("embed", "vocab")
        )
    return p, a


def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct params, axes) without materializing any array.

    init runs under eval_shape (tracers, no flops); the axes tree — pure
    python, key-independent — is captured via a side channel.
    """
    box = {}

    def f(key):
        p, a = init_params(key, cfg)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.key(0))
    return shapes, box["axes"]


def param_axes(cfg: ArchConfig):
    return abstract_params(cfg)[1]


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _sinusoidal(S: int, D: int, dtype):
    pos = np.arange(S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * i / D))
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(pe, dtype)


def _apply_block(
    p,
    x,
    cfg: ArchConfig,
    ctx,
    kind: str,
    *,
    positions,
    prefix_len=None,
    attn_impl="chunked",
):
    B, S, D = x.shape
    h = apply_norm(cfg.norm, p["ln1"], x)
    if kind in ("attn", "local"):
        q = proj_in(p["q"], h)  # [B, S, H, hd]
        k = proj_in(p["k"], h)
        v = proj_in(p["v"], h)
        if cfg.positions == "rope":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        q = constrain(ctx, q, "batch", "seq", "heads", None)
        out = attn_mod.attention(
            q,
            k,
            v,
            causal=cfg.kind != "encoder",
            window=cfg.window if kind == "local" else None,
            prefix_len=prefix_len,
            impl=attn_impl,
        )
        mixed = proj_out(p["o"], out)
    elif kind == "rec":
        mixed = rec_mod.rglru_block_apply(p["mix"], h, heads=cfg.num_heads)
    elif kind == "ssm":
        mixed = ssm_mod.ssd_block_apply(
            p["mix"], h, d_inner=cfg.d_inner, heads=cfg.ssm_heads, d_state=cfg.ssm_state
        )
        return x + mixed  # mamba block has no separate MLP
    x = x + mixed
    x = constrain(ctx, x, "batch", "seq", None)
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    if cfg.is_moe and kind in ("attn", "local"):
        ff = moe_mod.moe_apply(
            p["moe"],
            h2,
            ctx,
            num_experts=cfg.num_experts,
            top_k=cfg.top_k,
            act=cfg.mlp_act,
            capacity_factor=cfg.moe_capacity_factor,
        )
    else:
        ff = mlp_apply(p["mlp"], h2, cfg.mlp_act)
    return x + ff


def _embed_inputs(p, cfg: ArchConfig, inputs, ctx):
    table = p["embed"]["table"]
    if cfg.frontend == "audio_stub":
        x = dense(p["frontend"], inputs["frames"])
    elif cfg.frontend == "vision_stub":
        tok = table[inputs["tokens"]]
        x = jnp.concatenate([inputs["patches"].astype(tok.dtype), tok], axis=1)
    else:
        x = table[inputs["tokens"]]
    if cfg.positions == "sinusoidal":
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    return constrain(ctx, x, "batch", "seq", None)


def _head(p, cfg: ArchConfig, x, ctx):
    if cfg.tie_embeddings and "head" not in p:
        logits = x @ p["embed"]["table"].T.astype(x.dtype)
    else:
        logits = dense(p["head"], x)
    return constrain(ctx, logits, "batch", "seq", "vocab")


def apply(
    params,
    cfg: ArchConfig,
    ctx,
    inputs,
    *,
    attn_impl: str = "chunked",
    unroll: bool = False,
):
    """Full forward -> logits [B, S, vocab].

    unroll=True replaces the layer scan with a python loop (identical math;
    used by the dry-run so cost_analysis counts every period, since XLA's
    HloCostAnalysis does not multiply while-loop bodies by trip count).
    """
    pattern, periods, rem = _pattern_layout(cfg)
    x = _embed_inputs(params, cfg, inputs, ctx)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    prefix_len = cfg.num_patches if cfg.frontend == "vision_stub" else None

    def period_body(x, pslice):
        for pos, kind in enumerate(pattern):
            x = _apply_block(
                pslice[f"p{pos}"],
                x,
                cfg,
                ctx,
                kind,
                positions=positions,
                prefix_len=prefix_len,
                attn_impl=attn_impl,
            )
        return x

    body = period_body
    if cfg.remat == "full":
        body = jax.checkpoint(period_body, policy=None)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    if periods > 0 and unroll:
        for i in range(periods):
            x = body(x, jax.tree.map(lambda a: a[i], params["blocks"]))
    elif periods > 0:
        x, _ = jax.lax.scan(
            lambda c, ps: (body(c, ps), None), x, params["blocks"]
        )
    for i in range(rem):
        kind = pattern[i % len(pattern)]
        x = _apply_block(
            params["tail"][i],
            x,
            cfg,
            ctx,
            kind,
            positions=positions,
            prefix_len=prefix_len,
            attn_impl=attn_impl,
        )
    x = apply_norm(
        cfg.norm if cfg.norm != "nonparam_ln" else "rmsnorm", params["final_norm"], x
    )
    return _head(params, cfg, x, ctx)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _cache_for_kind(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    hd = cfg.hd
    if kind in ("attn", "local"):
        w = max_len if kind == "attn" else min(cfg.window, max_len)
        shape = (batch, w, cfg.num_kv_heads, hd)
        c = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if dtype == jnp.int8:  # quantized serving: per-(b, s, h) absmax scales
            c["k_scale"] = jnp.zeros(shape[:3], jnp.bfloat16)
            c["v_scale"] = jnp.zeros(shape[:3], jnp.bfloat16)
        return c
    sdt = jnp.bfloat16 if dtype == jnp.int8 else dtype
    if kind == "rec":
        return rec_mod.rglru_init_state(batch, cfg.d_model, sdt)
    if kind == "ssm":
        return ssm_mod.ssd_init_state(
            batch, d_inner=cfg.d_inner, heads=cfg.ssm_heads, d_state=cfg.ssm_state, dtype=sdt
        )
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache pytree: {"blocks": {pN: stacked [periods, ...]}, "tail": [...]}."""
    pattern, periods, rem = _pattern_layout(cfg)
    blocks = {}
    for pos, kind in enumerate(pattern):
        one = _cache_for_kind(cfg, kind, batch, max_len, dtype)
        blocks[f"p{pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (periods, *x.shape)).copy(), one
        )
    cache = {"blocks": blocks}
    tail = [
        _cache_for_kind(cfg, pattern[i % len(pattern)], batch, max_len, dtype)
        for i in range(rem)
    ]
    if tail:
        cache["tail"] = tail
    return cache


def cache_axes(cfg: ArchConfig, int8: bool = False):
    """Logical axes tree mirroring init_cache (for serve-step shardings)."""
    pattern, periods, rem = _pattern_layout(cfg)

    def kind_axes(kind: str, layered: bool):
        pre = ("layers",) if layered else ()
        if kind in ("attn", "local"):
            # kv_heads shards when divisible; otherwise head_dim picks up
            # the model axis (cache updates are along seq: no cross-shard
            # scatter, unlike seq sharding which triggers full remat).
            kv = pre + ("batch", "seq_kv", "kv_heads", "head_dim")
            d = {"k": kv, "v": kv}
            if int8:
                sc = pre + ("batch", "seq_kv", "kv_heads")
                d["k_scale"] = sc
                d["v_scale"] = sc
            return d
        if kind == "rec":
            return {"h": pre + ("batch", "rnn"), "conv": pre + ("batch", None, "rnn")}
        if kind == "ssm":
            return {
                "h": pre + ("batch", "heads", None, None),
                "conv": pre + ("batch", None, "rnn"),
            }
        raise ValueError(kind)

    axes = {"blocks": {f"p{i}": kind_axes(k, True) for i, k in enumerate(pattern)}}
    if rem:
        axes["tail"] = [kind_axes(pattern[i % len(pattern)], False) for i in range(rem)]
    return axes


def _decode_block(p, c, x, cfg: ArchConfig, ctx, kind: str, pos):
    B = x.shape[0]
    h = apply_norm(cfg.norm, p["ln1"], x)
    if kind in ("attn", "local"):
        q = proj_in(p["q"], h)  # [B, 1, H, hd]
        k = proj_in(p["k"], h)
        v = proj_in(p["v"], h)
        if cfg.positions == "rope":
            pp = jnp.full((B, 1), pos)
            q = rope(q, pp, cfg.rope_theta)
            k = rope(k, pp, cfg.rope_theta)
        int8kv = c["k"].dtype == jnp.int8
        slot = pos if kind == "attn" else pos % c["k"].shape[1]
        if int8kv:
            ks = jnp.max(jnp.abs(k[:, 0]).astype(jnp.float32), axis=-1)  # [B, KH]
            vs = jnp.max(jnp.abs(v[:, 0]).astype(jnp.float32), axis=-1)
            k8 = jnp.round(
                k[:, 0].astype(jnp.float32) / jnp.maximum(ks, 1e-6)[..., None] * 127.0
            ).astype(jnp.int8)
            v8 = jnp.round(
                v[:, 0].astype(jnp.float32) / jnp.maximum(vs, 1e-6)[..., None] * 127.0
            ).astype(jnp.int8)
            ck = c["k"].at[:, slot].set(k8)
            cv = c["v"].at[:, slot].set(v8)
            newc = {
                "k": ck,
                "v": cv,
                "k_scale": c["k_scale"].at[:, slot].set(ks.astype(jnp.bfloat16)),
                "v_scale": c["v_scale"].at[:, slot].set(vs.astype(jnp.bfloat16)),
            }
        else:
            ck = c["k"].at[:, slot].set(k[:, 0].astype(c["k"].dtype))
            cv = c["v"].at[:, slot].set(v[:, 0].astype(c["v"].dtype))
            newc = {"k": ck, "v": cv}
        if kind == "attn":
            kpos = jnp.arange(ck.shape[1])
        else:  # ring buffer of size window
            w = c["k"].shape[1]
            s = jnp.arange(w)
            kpos = pos - ((pos - s) % w)
        out = attn_mod.decode_attention(
            q,
            ck,
            cv,
            pos,
            window=cfg.window if kind == "local" else None,
            kpos=kpos,
            k_scale=newc.get("k_scale"),
            v_scale=newc.get("v_scale"),
        )
        mixed = proj_out(p["o"], out)
    elif kind == "rec":
        mixed, newc = rec_mod.rglru_decode_step(p["mix"], h, c, heads=cfg.num_heads)
    elif kind == "ssm":
        mixed, newc = ssm_mod.ssd_decode_step(
            p["mix"], h, c, d_inner=cfg.d_inner, heads=cfg.ssm_heads, d_state=cfg.ssm_state
        )
        return x + mixed, newc
    x = x + mixed
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    if cfg.is_moe and kind in ("attn", "local"):
        ff = moe_mod.moe_apply(
            p["moe"],
            h2,
            ctx,
            num_experts=cfg.num_experts,
            top_k=cfg.top_k,
            act=cfg.mlp_act,
            dropless=True,  # decode: never drop a generation token
            token_dispatch=True,  # decode: move tokens (KB), not weights (GB)
        )
    else:
        ff = mlp_apply(p["mlp"], h2, cfg.mlp_act)
    return x + ff, newc


def decode_step(params, cfg: ArchConfig, ctx, cache, tokens, pos, *, unroll: bool = False):
    """One decode step.  tokens: [B, 1] int32; pos: scalar position index."""
    pattern, periods, rem = _pattern_layout(cfg)
    x = params["embed"]["table"][tokens]
    x = constrain(ctx, x, "batch", None, None)

    def body(x, xs):
        pslice, cslice = xs
        newc = {}
        for p_i, kind in enumerate(pattern):
            x, newc[f"p{p_i}"] = _decode_block(
                pslice[f"p{p_i}"], cslice[f"p{p_i}"], x, cfg, ctx, kind, pos
            )
        return x, newc

    new_cache = {}
    if periods > 0 and unroll:
        ys = []
        for i in range(periods):
            x, nc = body(
                x,
                (
                    jax.tree.map(lambda a: a[i], params["blocks"]),
                    jax.tree.map(lambda a: a[i], cache["blocks"]),
                ),
            )
            ys.append(nc)
        new_cache["blocks"] = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    elif periods > 0:
        x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = new_blocks
    else:
        new_cache["blocks"] = cache["blocks"]
    if rem:
        new_tail = []
        for i in range(rem):
            kind = pattern[i % len(pattern)]
            x, nc = _decode_block(
                params["tail"][i], cache["tail"][i], x, cfg, ctx, kind, pos
            )
            new_tail.append(nc)
        new_cache["tail"] = new_tail
    x = apply_norm(
        cfg.norm if cfg.norm != "nonparam_ln" else "rmsnorm", params["final_norm"], x
    )
    logits = _head(params, cfg, x, ctx)
    return logits, new_cache
