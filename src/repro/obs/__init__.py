"""Runtime observability — span tracing, metrics, Perfetto export, reports.

The telemetry layer of the resident runtime (the CHT papers' task/chunk
accounting and per-process execution timelines, reproduced as a runtime
service):

* :class:`Tracer` / :data:`NULL_TRACER` (:mod:`repro.obs.tracer`) — nested
  spans with per-worker cost attribution, plus counters/gauges registered
  once; the disabled tracer is an allocation-free no-op.  The tracer rides
  on the plan cache (``PlanCache(tracer=...)``), which is already threaded
  through every resident collective and driver.
* :mod:`repro.obs.timing` — the shared timing idioms (``timed_into``,
  ``IterationScope``) that replace the scattered ``perf_counter`` pairs and
  give both iterative drivers one per-iteration row schema.
* :mod:`repro.obs.export` — Chrome trace-event JSON loadable in Perfetto:
  a host track with the full span tree and one utilization track per
  worker; :func:`validate_chrome_trace` is the CI schema check.
* :mod:`repro.obs.report` — per-worker busy/idle utilization summary from a
  live tracer or a written trace file (``python -m repro.obs.report``).
* :func:`run_metrics` — the flat metrics dict (cache + tracer counters) the
  driver stats dataclasses wrap.

The **runtime health observatory** (this PR's online half):

* :mod:`repro.obs.log` — :class:`EventLog` leveled structured JSONL log +
  :data:`NULL_LOG`, riding on the plan cache like the tracer
  (:func:`log_of`), and :class:`FlightRecorder`, which dumps a postmortem
  (last spans, counter deltas, recent events, plan-cache state) when plan
  admission raises ``PlanError`` or a driver divergence trip fires.
* :mod:`repro.obs.memory` — :class:`MemoryMeter` per-worker device-memory
  accounting from plan capacities / store shapes / receive buffers, with
  peak watermarks per collective and a memory column in the report.
* :mod:`repro.obs.health` — :class:`HealthMonitor` online anomaly detection
  (stragglers, plan-cache miss storms, exchange blowups, convergence
  stalls) + live `calibrate_policy` feedback into the load balancer.
* :mod:`repro.obs.regress` — the benchmark trajectory store
  (``BENCH_HISTORY.jsonl``) and ``python -m repro.obs.regress --check``
  regression gate.

The **locality & task-graph analytics** layer:

* :mod:`repro.obs.locality` — :class:`LocalityLedger`, riding on the plan
  cache like the tracer (:func:`ledger_of`): per-dispatch decomposition of
  operand reads into locally-owned vs shipped bytes, wire metering with
  delta-mask pruning and bf16 halving applied, per-block movement lineage,
  and the per-iteration driver emission pair
  (:func:`locality_snapshot` / :func:`locality_iteration`).
* :mod:`repro.obs.taskgraph` — executed-task-graph analytics over a plan's
  index arrays: critical path, per-worker slack, and what-if projections
  (:func:`analyze_plan`, :func:`whatif_rebalanced`,
  :func:`project_seconds`); ``python -m repro.obs.report --locality``
  renders the benchmark output.
"""

from .export import chrome_trace_events, validate_chrome_trace, write_chrome_trace
from .health import HealthAlert, HealthMonitor, HealthPolicy
from .log import (
    EVENT_KEYS,
    NULL_LOG,
    POSTMORTEM_KEYS,
    EventLog,
    FlightRecorder,
    NullEventLog,
    load_events,
    log_of,
)
from .locality import (
    LOCALITY_ITER_KEYS,
    LocalityLedger,
    ledger_of,
    locality_iteration,
    locality_snapshot,
    plan_provenance,
)
from .memory import MemoryMeter, jax_memory_stats, meter_of, plan_memory_bytes
from .report import (
    locality_from_file,
    locality_table,
    memory_from_file,
    utilization_from_file,
    utilization_table,
    worker_utilization,
)
from .taskgraph import (
    TaskGraphAnalysis,
    analyze_plan,
    project_seconds,
    whatif_rebalanced,
)
from .timing import SHARED_ITER_KEYS, IterationScope, timed_into
from .tracer import (
    NULL_TRACER,
    Counter,
    Gauge,
    NullTracer,
    Span,
    Tracer,
    run_metrics,
    tracer_of,
)

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "Span",
    "Counter",
    "Gauge",
    "tracer_of",
    "run_metrics",
    "timed_into",
    "IterationScope",
    "SHARED_ITER_KEYS",
    "chrome_trace_events",
    "write_chrome_trace",
    "validate_chrome_trace",
    "worker_utilization",
    "utilization_from_file",
    "memory_from_file",
    "utilization_table",
    "EventLog",
    "NullEventLog",
    "NULL_LOG",
    "log_of",
    "load_events",
    "FlightRecorder",
    "EVENT_KEYS",
    "POSTMORTEM_KEYS",
    "MemoryMeter",
    "meter_of",
    "plan_memory_bytes",
    "jax_memory_stats",
    "HealthPolicy",
    "HealthAlert",
    "HealthMonitor",
    "LocalityLedger",
    "LOCALITY_ITER_KEYS",
    "ledger_of",
    "plan_provenance",
    "locality_snapshot",
    "locality_iteration",
    "locality_table",
    "locality_from_file",
    "TaskGraphAnalysis",
    "analyze_plan",
    "whatif_rebalanced",
    "project_seconds",
]
