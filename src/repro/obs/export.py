"""Trace exporters: Chrome trace-event JSON (Perfetto-loadable) + validation.

The emitted file follows the Chrome trace-event format (``traceEvents`` with
``B``/``E`` duration pairs, ``i`` instants, ``C`` counters and ``M``
metadata), which Perfetto and ``chrome://tracing`` both load directly:

* **host track** (pid 0) — the full nested span tree exactly as recorded:
  phases, iterations, collectives, plan builds, symbolic descents, kernel
  dispatches, rebalance migrations, with per-span args.
* **worker tracks** (pid 1, one tid per worker) — the paper-style
  utilization timeline: every leaf span carrying a measured
  :attr:`~repro.obs.tracer.Span.worker_costs` vector contributes a busy
  interval on worker ``p`` of length ``dur * cost_p / max_q cost_q``
  (an SPMD step ends when its slowest worker does, so the heaviest worker
  is busy for the whole span and the rest idle in proportion to their
  measured share).  Gaps between busy intervals read as idle time.
* **counter track** — every registered counter/gauge as Chrome ``C``
  events, so byte/task counters plot over the same timeline.

:func:`validate_chrome_trace` is the schema check shared by the tests and
the CI trace-smoke job: monotonic non-negative timestamps per track,
strictly matched and properly nested ``B``/``E`` pairs, and exactly one
track per worker.
"""

from __future__ import annotations

import json

import numpy as np

from .tracer import Tracer

__all__ = [
    "chrome_trace_events",
    "write_chrome_trace",
    "validate_chrome_trace",
    "HOST_PID",
    "WORKER_PID",
]

HOST_PID = 0
WORKER_PID = 1


def _span_tree(tracer: Tracer):
    kids: list[list[int]] = [[] for _ in tracer.spans]
    roots: list[int] = []
    for i, sp in enumerate(tracer.spans):
        (roots if sp.parent < 0 else kids[sp.parent]).append(i)
    return kids, roots


def _attributed_leaves(tracer: Tracer) -> list[int]:
    """Spans carrying worker_costs with no attributed ancestor (so their
    busy intervals never nest on a worker track)."""
    has = [sp.worker_costs is not None for sp in tracer.spans]
    out = []
    for i, sp in enumerate(tracer.spans):
        if not has[i]:
            continue
        p, shadowed = sp.parent, False
        while p >= 0:
            if has[p]:
                shadowed = True
                break
            p = tracer.spans[p].parent
        if not shadowed:
            out.append(i)
    return out


def _json_safe(args: dict) -> dict:
    out = {}
    for k, v in args.items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, np.ndarray):
            v = v.tolist()
        out[k] = v
    return out


def chrome_trace_events(tracer: Tracer) -> list[dict]:
    """Chrome trace-event list: metadata, host B/E tree, worker tracks,
    instants and counter series.  Timestamps are microseconds from the
    tracer's origin."""
    org = tracer.origin
    us = lambda t: (t - org) * 1e6
    ev: list[dict] = [
        dict(ph="M", name="process_name", pid=HOST_PID, tid=0,
             args=dict(name="host runtime")),
        dict(ph="M", name="thread_name", pid=HOST_PID, tid=0,
             args=dict(name="driver")),
    ]

    # worker count from the attributed spans (0 tracks when none recorded)
    leaves = _attributed_leaves(tracer)
    nparts = max((len(tracer.spans[i].worker_costs) for i in leaves), default=0)
    if nparts:
        ev.append(dict(ph="M", name="process_name", pid=WORKER_PID, tid=0,
                       args=dict(name="workers")))
        for p in range(nparts):
            ev.append(dict(ph="M", name="thread_name", pid=WORKER_PID, tid=p,
                           args=dict(name=f"worker {p}")))

    # host track: DFS over the span tree keeps B/E properly nested even for
    # zero-duration spans sharing timestamps
    kids, roots = _span_tree(tracer)

    def emit(i: int) -> None:
        sp = tracer.spans[i]
        ev.append(dict(ph="B", name=sp.name, cat=sp.cat or "span",
                       ts=us(sp.t0), pid=HOST_PID, tid=0,
                       args=_json_safe(sp.args)))
        for c in kids[i]:
            emit(c)
        ev.append(dict(ph="E", name=sp.name, cat=sp.cat or "span",
                       ts=us(sp.t1), pid=HOST_PID, tid=0))

    for r in roots:
        emit(r)

    for name, cat, t, _parent, args in tracer.instants:
        ev.append(dict(ph="i", name=name, cat=cat or "instant", ts=us(t),
                       pid=HOST_PID, tid=0, s="t", args=_json_safe(args)))

    # worker utilization tracks: per attributed leaf span, worker p is busy
    # for its measured cost share of the step
    for i in leaves:
        sp = tracer.spans[i]
        costs = np.asarray(sp.worker_costs, dtype=np.float64)
        cmax = costs.max() if costs.size else 0.0
        if cmax <= 0.0:
            continue
        for p in range(costs.shape[0]):
            frac = costs[p] / cmax
            if frac <= 0.0:
                continue
            ev.append(dict(ph="B", name=sp.name, cat=sp.cat or "span",
                           ts=us(sp.t0), pid=WORKER_PID, tid=p,
                           args=dict(cost_share=float(frac))))
            ev.append(dict(ph="E", name=sp.name, cat=sp.cat or "span",
                           ts=us(sp.t0 + sp.dur * frac), pid=WORKER_PID,
                           tid=p))

    for t, name, value in tracer._counter_events:
        ev.append(dict(ph="C", name=name, ts=us(t), pid=HOST_PID, tid=0,
                       args={name: value}))

    return ev


def write_chrome_trace(tracer: Tracer, path: str) -> dict:
    """Write the Perfetto-loadable trace file; returns a small summary."""
    events = chrome_trace_events(tracer)
    with open(path, "w") as fh:
        json.dump(dict(traceEvents=events, displayTimeUnit="ms"), fh)
        fh.write("\n")
    return validate_chrome_trace(events)


def validate_chrome_trace(trace) -> dict:
    """Schema check for an emitted trace (events list, trace dict, or path).

    Raises ``AssertionError`` on: non-monotonic or negative timestamps
    within a track, unmatched or mis-nested ``B``/``E`` pairs, or worker
    thread-name metadata not covering tids 0..P-1 exactly once.  Returns
    summary counts (spans per track, workers, counters).
    """
    if isinstance(trace, str):
        with open(trace) as fh:
            trace = json.load(fh)
    events = trace["traceEvents"] if isinstance(trace, dict) else trace

    per_track: dict[tuple, list] = {}
    worker_names: dict[int, str] = {}
    counters = set()
    for e in events:
        ph = e["ph"]
        if ph == "M":
            if e["name"] == "thread_name" and e["pid"] == WORKER_PID:
                tid = e["tid"]
                assert tid not in worker_names, f"duplicate worker track {tid}"
                worker_names[tid] = e["args"]["name"]
            continue
        if ph == "C":
            counters.add(e["name"])
            continue
        assert e["ts"] >= 0.0, f"negative timestamp: {e}"
        if ph in ("B", "E"):
            per_track.setdefault((e["pid"], e["tid"]), []).append(e)

    span_counts: dict[str, int] = {}
    for (pid, tid), evs in sorted(per_track.items()):
        # emission order is authoritative; timestamps must not go backwards
        last = 0.0
        stack: list[str] = []
        n = 0
        for e in evs:
            assert e["ts"] >= last - 1e-9, (
                f"non-monotonic ts on track {(pid, tid)}: {e['ts']} < {last}")
            last = max(last, e["ts"])
            if e["ph"] == "B":
                stack.append(e["name"])
                n += 1
            else:
                assert stack, f"E without B on track {(pid, tid)}: {e}"
                top = stack.pop()
                assert top == e["name"], (
                    f"mis-nested span on track {(pid, tid)}: "
                    f"E {e['name']!r} closes B {top!r}")
        assert not stack, f"unclosed spans on track {(pid, tid)}: {stack}"
        span_counts[f"{pid}/{tid}"] = n

    nworkers = len(worker_names)
    assert set(worker_names) == set(range(nworkers)), (
        f"worker tracks must be tids 0..{nworkers - 1}: {sorted(worker_names)}")
    for tid, name in worker_names.items():
        assert name == f"worker {tid}", (tid, name)
    # the worker timeline as a whole carries busy intervals (a single fully
    # idle worker is legal — its track just reads as idle)
    if nworkers:
        assert any(span_counts.get(f"{WORKER_PID}/{t}", 0) > 0
                   for t in worker_names), "no busy spans on any worker track"

    return dict(
        events=len(events),
        host_spans=span_counts.get(f"{HOST_PID}/0", 0),
        workers=nworkers,
        worker_spans={t: n for t, n in span_counts.items()
                      if t.startswith(f"{WORKER_PID}/")},
        counters=sorted(counters),
    )
