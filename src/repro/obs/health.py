"""Online health monitoring over per-iteration driver rows.

The CHT runtime observes its own behavior continuously and feeds the
observations back into scheduling; :class:`HealthMonitor` is that loop's
anomaly detector for the XLA-mesh reproduction.  The iterative drivers feed
it the same :data:`~repro.obs.timing.SHARED_ITER_KEYS` row they already
emit per iteration (plus the measured :class:`~repro.dist.balance.WorkerLoad`
when load balancing is on), and it detects:

* **stragglers** — one worker's combined cost drifting past
  ``straggler_factor`` times the mesh median for ``straggler_patience``
  consecutive iterations (a persistently slow/overloaded worker, not a
  one-iteration blip);
* **plan-cache miss storms** — misses on ``miss_storm_window`` consecutive
  iterations after the warmup, i.e. the sparsity pattern never stabilizes
  and every iteration replans (the zero-miss steady state is the runtime's
  whole performance model);
* **exchange-byte blowups** — mean receive bytes jumping past
  ``exchange_blowup`` times the running median (fill-in explosion or a
  degenerate re-layout);
* **convergence stalls** — the driver's residual/idempotency making no
  progress for ``stall_window`` iterations (beyond the monitors' own
  divergence trips, which fire harder and dump a postmortem).

Alerts append to :attr:`HealthMonitor.alerts`, emit ``health_alert`` warn
events into the :class:`~repro.obs.log.EventLog` and ``health_alert``
tracer instants (category ``"health"``), so they land in postmortems and
Chrome traces alike.

**Live policy refit** (closing the ROADMAP follow-on "apply the fitted
policy live"): every ``refit_every`` iterations :meth:`maybe_refit` runs
the wall-clock calibration already collected by the
:class:`~repro.dist.balance.LoadMonitor` and, when the fit converged,
replaces ``LoadMonitor.policy`` mid-run — subsequent rebalance decisions
use measured cost coefficients instead of the defaults.  This is a
schedule-only change: re-layouts are bit-identical by construction, so
results with health monitoring on equal results with it off.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .log import log_of
from .tracer import tracer_of

__all__ = ["HealthPolicy", "HealthAlert", "HealthMonitor"]


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Detection thresholds; the defaults are deliberately conservative so
    alerts mean something."""

    straggler_factor: float = 1.5
    straggler_patience: int = 3
    miss_warmup: int = 3
    miss_storm_window: int = 3
    exchange_blowup: float = 4.0
    stall_window: int = 6
    refit_every: int = 8
    live_policy: bool = True


@dataclasses.dataclass(frozen=True)
class HealthAlert:
    kind: str
    iteration: int
    message: str
    data: dict = dataclasses.field(default_factory=dict)


class HealthMonitor:
    """Feed :meth:`observe` one driver row per iteration; read
    :attr:`alerts` / :meth:`summary` at run end."""

    def __init__(self, policy: HealthPolicy | None = None, *, cache=None):
        self.policy = policy if policy is not None else HealthPolicy()
        self.cache = cache
        self.alerts: list[HealthAlert] = []
        self.refits = 0
        self.iterations = 0
        self._straggler_streak: np.ndarray | None = None
        self._miss_streak = 0
        self._cost_policy = None  # cached cost-model coefficients
        self._recv_hist: list[float] = []
        self._best_resid = float("inf")
        self._stall = 0

    # -- emission ------------------------------------------------------------
    def _emit(self, kind: str, iteration: int, message: str,
              **data: Any) -> HealthAlert:
        alert = HealthAlert(kind=kind, iteration=int(iteration),
                            message=message, data=dict(data))
        self.alerts.append(alert)
        lg = log_of(self.cache)
        if lg.enabled:
            lg.warn("health_alert", kind=kind, iteration=int(iteration),
                    message=message, **data)
        tr = tracer_of(self.cache)
        if tr.enabled:
            tr.instant("health_alert", cat="health", kind=kind,
                       iteration=int(iteration), **data)
        return alert

    # -- detectors -----------------------------------------------------------
    def observe(self, row: dict, load=None) -> list[HealthAlert]:
        """Run every detector over one iteration row; returns new alerts."""
        p = self.policy
        self.iterations += 1
        it = int(row.get("iteration") or 0)
        new: list[HealthAlert] = []

        # stragglers: per-worker combined cost vs the mesh median
        if load is not None:
            if self._cost_policy is None:
                from repro.dist.balance import RebalancePolicy

                self._cost_policy = RebalancePolicy()
            cost = np.asarray(load.combined(self._cost_policy), np.float64)
            if self._straggler_streak is None or (
                    self._straggler_streak.shape != cost.shape):
                self._straggler_streak = np.zeros(cost.shape, np.int64)
            med = float(np.median(cost))
            if med > 0.0:
                over = cost > p.straggler_factor * med
                self._straggler_streak = np.where(
                    over, self._straggler_streak + 1, 0)
                tripped = np.nonzero(
                    self._straggler_streak >= p.straggler_patience)[0]
                for w in tripped:
                    new.append(self._emit(
                        "straggler", it,
                        f"worker {int(w)} cost {cost[w]:.0f} > "
                        f"{p.straggler_factor:g}x mesh median {med:.0f} for "
                        f"{p.straggler_patience} consecutive iterations",
                        worker=int(w), cost=float(cost[w]), median=med))
                    self._straggler_streak[w] = 0  # re-arm, don't spam

        # plan-cache miss storm: replanning every iteration past warmup
        if self.iterations > p.miss_warmup:
            if int(row.get("cache_misses") or 0) > 0:
                self._miss_streak += 1
                if self._miss_streak == p.miss_storm_window:
                    new.append(self._emit(
                        "miss_storm", it,
                        f"plan-cache misses on {self._miss_streak} "
                        "consecutive iterations past warmup — the sparsity "
                        "pattern is not stabilizing",
                        streak=self._miss_streak,
                        misses=int(row.get("cache_misses") or 0)))
            else:
                self._miss_streak = 0

        # exchange-byte blowup vs the running median (last 64 iterations,
        # so the scan stays O(1) per iteration on long runs)
        recv = float(row.get("recv_bytes_mean") or 0.0)
        if self._recv_hist:
            med = float(np.median(self._recv_hist))
            if med > 0.0 and recv > p.exchange_blowup * med:
                new.append(self._emit(
                    "exchange_blowup", it,
                    f"mean recv bytes {recv:.3g} > {p.exchange_blowup:g}x "
                    f"running median {med:.3g}",
                    recv_bytes_mean=recv, median=med))
        self._recv_hist.append(recv)
        if len(self._recv_hist) > 64:
            del self._recv_hist[0]

        # convergence stall: the driver's own progress metric going flat
        resid = row.get("residual", row.get("idem"))
        if resid is not None:
            resid = float(resid)
            if resid < self._best_resid:
                self._best_resid = resid
                self._stall = 0
            else:
                self._stall += 1
                if self._stall == p.stall_window:
                    new.append(self._emit(
                        "convergence_stall", it,
                        f"no residual improvement for {self._stall} "
                        f"iterations (best {self._best_resid:.3e})",
                        stall=self._stall, best=self._best_resid,
                        residual=resid))
        return new

    # -- live policy feedback ------------------------------------------------
    def maybe_refit(self, lb) -> Any:
        """Feed the wall-clock-calibrated cost coefficients live into the
        :class:`~repro.dist.balance.LoadMonitor` policy every
        ``refit_every`` iterations; returns the new policy when applied."""
        p = self.policy
        if lb is None or not p.live_policy or p.refit_every <= 0:
            return None
        if self.iterations == 0 or self.iterations % p.refit_every:
            return None
        fitted, report = lb.calibration()
        if not report.get("fitted"):
            return None
        if fitted == lb.policy:
            return None
        lb.policy = fitted
        self.refits += 1
        lg = log_of(self.cache)
        if lg.enabled:
            lg.info("policy_refit", iteration=self.iterations,
                    recv_cost=fitted.recv_cost, send_cost=fitted.send_cost,
                    block_cost=fitted.block_cost,
                    rms_resid_s=report.get("rms_resid_s"))
        tr = tracer_of(self.cache)
        if tr.enabled:
            tr.instant("policy_refit", cat="health",
                       iteration=self.iterations,
                       recv_cost=fitted.recv_cost,
                       send_cost=fitted.send_cost,
                       block_cost=fitted.block_cost)
        return fitted

    def summary(self) -> dict:
        """JSON-safe run summary for driver stats / BENCH files."""
        return dict(
            iterations=int(self.iterations),
            refits=int(self.refits),
            alerts=[dict(kind=a.kind, iteration=a.iteration,
                         message=a.message, **a.data) for a in self.alerts],
            alerts_by_kind={
                k: sum(1 for a in self.alerts if a.kind == k)
                for k in sorted({a.kind for a in self.alerts})},
        )
