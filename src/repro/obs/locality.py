"""Data-locality ledger — who owned each operand byte, who fetched it, how often.

The paper's central empirical claim is that the runtime "dynamically
exploit[s] data locality to avoid movement of data".  The tracer measures
*time* and the memory meter measures *bytes resident*, but neither
attributes movement to *placement decisions*.  This module closes that gap:

* :class:`LocalityLedger` — rides on the plan cache like the tracer and
  event log (``cache.locality_ledger``, installed via :meth:`install`,
  read back with ``getattr`` so un-instrumented dispatches pay nothing).
  Every multiply-family dispatch feeds it one :meth:`note_dispatch` call;
  the ledger decomposes the executed plan's operand reads into
  locally-owned vs shipped bytes (static residency split, from
  :func:`repro.core.schedule.plan_byte_provenance`), meters what actually
  crossed the wire (delta-mask pruning and bf16 wire halving applied), and
  accumulates per-block movement lineage — who owned a block, who fetched
  it, and how many times across the run.  A block re-fetched every
  iteration is the cache-opportunity signal a future exchange cache would
  exploit.
* :func:`locality_snapshot` / :func:`locality_iteration` — the driver-side
  per-iteration emission pair: fraction fields into the stats row, span
  attrs on the iteration span, tracer gauges, and one ``locality``
  :class:`~repro.obs.log.EventLog` record.

Accounting invariants (tested in ``tests/test_locality.py``):

* ``local_bytes + shipped_bytes == referenced_bytes`` exactly — the static
  residency split conserves, per worker and in total.
* ``local_bytes`` is a placement property, not a mask property: delta-mask
  pruning shrinks ``wire_recv_bytes`` but never ``local_bytes`` (a locally
  owned block is resident whether or not this dispatch's mask reads it).
* For p2p plans the static ``shipped`` decomposition equals
  ``plan_worker_bytes``'s ``recv_actual`` bit-for-bit (hypothesis-tested
  in the analysis CI job).

The ledger only ever meters *verified* plans: :meth:`install` refuses a
cache whose static-verification policy is ``"off"``.
"""

from __future__ import annotations

import json
import typing

import numpy as np

from .log import log_of
from .tracer import tracer_of

if typing.TYPE_CHECKING:  # core.cache imports obs.log: keep obs<->core lazy
    from ..core.schedule import SpgemmPlan

__all__ = [
    "LocalityLedger",
    "ledger_of",
    "plan_provenance",
    "locality_snapshot",
    "locality_iteration",
    "LOCALITY_ITER_KEYS",
]

#: rider attribute memoizing a plan's static byte provenance (computed once
#: per plan, like the dispatch annotations' ``_obs_static`` rider)
_PROV_ATTR = "_obs_locality"

#: the per-iteration fields locality_iteration() appends to driver rows —
#: schema-stable like SHARED_ITER_KEYS
LOCALITY_ITER_KEYS = (
    "locality_flops",
    "locality_bytes",
    "local_bytes",
    "shipped_bytes",
    "wire_recv_bytes",
    "wire_send_bytes",
)


def plan_provenance(plan: SpgemmPlan) -> dict:
    """Memoized :func:`~repro.core.schedule.plan_byte_provenance` of a plan.

    The provenance is a pure structural property, so it rides on the frozen
    plan (``object.__setattr__``) and every later dispatch of the same plan
    reuses it — steady-state dispatch cost is a few vector adds.
    """
    prov = getattr(plan, _PROV_ATTR, None)
    if prov is None:
        from ..core.schedule import plan_byte_provenance  # lazy: import cycle

        prov = plan_byte_provenance(plan)
        object.__setattr__(plan, _PROV_ATTR, prov)
    return prov


def _frac(num: float, den: float) -> float:
    return float(num / den) if den > 0 else 1.0


class LocalityLedger:
    """Cumulative locality account of every verified multiply dispatch.

    Scalar totals are mirrored by per-worker vectors (lazily sized to the
    first dispatched plan's ``nparts``).  Movement lineage is appended as
    raw per-dispatch arrays and aggregated only in :meth:`moved_blocks` /
    :meth:`summary`, keeping the dispatch-path cost flat.
    """

    def __init__(self, *, top_k: int = 10):
        self.top_k = int(top_k)
        self.nparts: int | None = None
        self.dispatches = 0
        # static residency split, fp32 itemsize (conserving: local + shipped
        # == referenced, per worker)
        self.referenced_bytes = 0.0
        self.local_bytes = 0.0
        self.shipped_bytes = 0.0
        # what actually crossed the wire: delta-mask pruning drops whole
        # blocks, reduced precision halves the per-block payload
        self.wire_recv_bytes = 0.0
        self.wire_send_bytes = 0.0
        # locally-satisfied flops (both operands resident on the task's
        # worker) vs total executed flops — runtime task masks honored
        self.local_flops = 0.0
        self.total_flops = 0.0
        self._pw: dict[str, np.ndarray] | None = None
        # movement lineage: per-dispatch (operand, code, src, dst) arrays
        self._lineage: list[tuple[str, np.ndarray, np.ndarray, np.ndarray]] = []

    # -- wiring ---------------------------------------------------------------
    def install(self, cache) -> "LocalityLedger":
        """Attach as ``cache.locality_ledger``.

        Refuses a cache with static verification off: the ledger's numbers
        are placement claims about executed plans, and an unverified plan
        could mis-attribute every byte.
        """
        if getattr(cache, "verify", "off") == "off":
            raise ValueError(
                "locality ledger only meters verified plans: set "
                "cache.verify to 'cached-once' or 'always', not 'off'")
        cache.locality_ledger = self
        return self

    # -- dispatch-side metering ----------------------------------------------
    def note_dispatch(self, plan: SpgemmPlan, *, wire_itemsize: int = 4,
                      task_on: np.ndarray | None = None,
                      keeps: tuple | None = None,
                      a_codes: np.ndarray | None = None,
                      b_codes: np.ndarray | None = None) -> dict:
        """Meter one executed plan; returns this dispatch's scalar deltas.

        ``task_on`` is the delta-plan runtime task mask (``[P, t_cap]``
        bool) when the dispatch masked tasks; ``keeps`` is the per-round
        exchange keep-mask pair ``(a_keeps, b_keeps)`` when the fused
        masked engine also pruned the wire.  ``a_codes`` / ``b_codes`` are
        the operands' Morton codes — the structure-stable block identity
        lineage is keyed by (falls back to global indices, which are only
        stable within one structure).
        """
        prov = plan_provenance(plan)
        P = plan.nparts
        if self._pw is None:
            self.nparts = P
            self._pw = {k: np.zeros(P, dtype=np.float64) for k in (
                "referenced", "local", "shipped", "wire_recv", "wire_send",
                "local_flops", "total_flops")}
        pw = self._pw

        pw["referenced"] += prov["referenced"]
        pw["local"] += prov["local"]
        pw["shipped"] += prov["shipped"]

        flop = 2.0 * float(plan.bs) ** 3
        if task_on is None:
            counts = plan.task_count.astype(np.float64)
            lcounts = prov["local_tasks"].astype(np.float64)
        else:
            counts = task_on.sum(axis=1).astype(np.float64)
            lcounts = (prov["task_local"] & task_on).sum(axis=1).astype(np.float64)
        pw["total_flops"] += counts * flop
        pw["local_flops"] += lcounts * flop

        scale = wire_itemsize / 4.0
        if keeps is None:
            wrecv = prov["wire_recv"] * scale
            wsend = prov["wire_send"] * scale
        else:
            wrecv, wsend = _kept_wire(plan, keeps, wire_itemsize)
        pw["wire_recv"] += wrecv
        pw["wire_send"] += wsend

        self._note_lineage(plan, prov, keeps, a_codes, b_codes)

        self.dispatches += 1
        out = dict(
            referenced_bytes=float(prov["referenced"].sum()),
            local_bytes=float(prov["local"].sum()),
            shipped_bytes=float(prov["shipped"].sum()),
            wire_recv_bytes=float(wrecv.sum()),
            wire_send_bytes=float(wsend.sum()),
            local_flops=float(lcounts.sum() * flop),
            total_flops=float(counts.sum() * flop),
        )
        self.referenced_bytes += out["referenced_bytes"]
        self.local_bytes += out["local_bytes"]
        self.shipped_bytes += out["shipped_bytes"]
        self.wire_recv_bytes += out["wire_recv_bytes"]
        self.wire_send_bytes += out["wire_send_bytes"]
        self.local_flops += out["local_flops"]
        self.total_flops += out["total_flops"]
        return out

    def _note_lineage(self, plan, prov, keeps, a_codes, b_codes) -> None:
        for name, codes, keep_i in (("a", a_codes, 0), ("b", b_codes, 1)):
            if keeps is None:
                gids, src, dst = prov[f"fetch_{name}"]
            else:
                gids, src, dst = _kept_fetches(plan, name, keeps[keep_i])
            if not gids.size:
                continue
            key = codes[gids] if codes is not None else gids
            self._lineage.append((name, np.asarray(key, dtype=np.int64),
                                  src, dst))

    # -- per-iteration deltas -------------------------------------------------
    def snapshot(self) -> tuple:
        """Scalar snapshot for per-iteration deltas (see :meth:`delta`)."""
        return (self.local_flops, self.total_flops, self.local_bytes,
                self.shipped_bytes, self.referenced_bytes,
                self.wire_recv_bytes, self.wire_send_bytes)

    def delta(self, snap: tuple) -> dict:
        """Locality accumulated since ``snap``: the per-iteration fields
        (:data:`LOCALITY_ITER_KEYS`) the drivers append to stats rows."""
        lf, tf, lb, sb, rb, wr, ws = snap
        d_lf = self.local_flops - lf
        d_tf = self.total_flops - tf
        d_lb = self.local_bytes - lb
        d_rb = self.referenced_bytes - rb
        return dict(
            locality_flops=_frac(d_lf, d_tf),
            locality_bytes=_frac(d_lb, d_rb),
            local_bytes=d_lb,
            shipped_bytes=self.shipped_bytes - sb,
            wire_recv_bytes=self.wire_recv_bytes - wr,
            wire_send_bytes=self.wire_send_bytes - ws,
        )

    # -- aggregation ----------------------------------------------------------
    def moved_blocks(self, top_k: int | None = None) -> list[dict]:
        """The most-fetched blocks across the run, most-moved first.

        One record per (operand, block): fetch count (re-fetch across
        iterations counts every time — the cache-opportunity signal),
        distinct fetching workers, and the owning worker(s) observed.
        """
        top_k = self.top_k if top_k is None else int(top_k)
        out = []
        for op in ("a", "b"):
            chunks = [(c, s, d) for (o, c, s, d) in self._lineage if o == op]
            if not chunks:
                continue
            codes = np.concatenate([c for c, _, _ in chunks])
            src = np.concatenate([s for _, s, _ in chunks])
            dst = np.concatenate([d for _, _, d in chunks])
            uniq, inv, cnts = np.unique(codes, return_inverse=True,
                                        return_counts=True)
            for i in np.argsort(-cnts, kind="stable")[:top_k]:
                sel = inv == i
                out.append(dict(
                    operand=op,
                    code=int(uniq[i]),
                    fetches=int(cnts[i]),
                    fetchers=np.unique(dst[sel]).astype(int).tolist(),
                    owners=np.unique(src[sel]).astype(int).tolist(),
                ))
        out.sort(key=lambda r: -r["fetches"])
        return out[:top_k]

    def summary(self) -> dict:
        """JSON-safe run totals: fractions, per-worker table, moved blocks."""
        pw = self._pw
        per_worker = []
        if pw is not None:
            for p in range(self.nparts):
                per_worker.append(dict(
                    worker=p,
                    referenced_bytes=float(pw["referenced"][p]),
                    local_bytes=float(pw["local"][p]),
                    shipped_bytes=float(pw["shipped"][p]),
                    wire_recv_bytes=float(pw["wire_recv"][p]),
                    wire_send_bytes=float(pw["wire_send"][p]),
                    locality_bytes=_frac(pw["local"][p], pw["referenced"][p]),
                    locality_flops=_frac(pw["local_flops"][p],
                                         pw["total_flops"][p]),
                ))
        return dict(
            dispatches=self.dispatches,
            nparts=self.nparts,
            locality_flops=_frac(self.local_flops, self.total_flops),
            locality_bytes=_frac(self.local_bytes, self.referenced_bytes),
            referenced_bytes=self.referenced_bytes,
            local_bytes=self.local_bytes,
            shipped_bytes=self.shipped_bytes,
            wire_recv_bytes=self.wire_recv_bytes,
            wire_send_bytes=self.wire_send_bytes,
            local_flops=self.local_flops,
            total_flops=self.total_flops,
            per_worker=per_worker,
            moved_blocks=self.moved_blocks(),
        )

    def write(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.summary(), fh, indent=2)
            fh.write("\n")
        return path


def _kept_wire(plan: SpgemmPlan, keeps: tuple, wire_itemsize: int):
    """Per-worker wire bytes of a keep-mask-pruned exchange."""
    P = plan.nparts
    wblk = plan.bs * plan.bs * wire_itemsize
    wrecv = np.zeros(P, dtype=np.float64)
    wsend = np.zeros(P, dtype=np.float64)
    for (offs, send_cnt), keep in zip(
        ((plan.a_offsets, plan.a_send_count), (plan.b_offsets, plan.b_send_count)),
        keeps,
    ):
        for r, d in enumerate(offs):
            cnt = send_cnt[d]
            k = np.asarray(keep[r], dtype=bool)
            in_cnt = np.arange(k.shape[1])[None, :] < cnt[:, None]
            kept = (k & in_cnt).sum(axis=1).astype(np.float64)
            wsend += kept * wblk
            wrecv[(np.arange(P) + d) % P] += kept * wblk
    return wrecv, wsend


def _kept_fetches(plan: SpgemmPlan, name: str, keep: list):
    """(gids, src, dst) of the blocks a pruned exchange actually delivered."""
    offs = plan.a_offsets if name == "a" else plan.b_offsets
    send = plan.a_send if name == "a" else plan.b_send
    send_cnt = plan.a_send_count if name == "a" else plan.b_send_count
    store_idx = plan.a_store_idx if name == "a" else plan.b_store_idx
    P = plan.nparts
    gids_l, src_l, dst_l = [], [], []
    for r, d in enumerate(offs):
        cnt = send_cnt[d]
        k = np.asarray(keep[r], dtype=bool)
        for src in range(P):
            c = int(cnt[src])
            if not c:
                continue
            slots = send[d][src, :c][k[src, :c]]
            if not slots.size:
                continue
            gids_l.append(store_idx[src, slots].astype(np.int64))
            src_l.append(np.full(slots.size, src, dtype=np.int32))
            dst_l.append(np.full(slots.size, (src + d) % P, dtype=np.int32))
    if not gids_l:
        z = np.zeros(0, np.int64)
        return z, np.zeros(0, np.int32), np.zeros(0, np.int32)
    return (np.concatenate(gids_l), np.concatenate(src_l),
            np.concatenate(dst_l))


def ledger_of(cache) -> LocalityLedger | None:
    """The ledger riding on the plan cache, or None when not installed."""
    if cache is None:
        return None
    return getattr(cache, "locality_ledger", None)


def locality_snapshot(cache) -> tuple | None:
    """Iteration-top ledger snapshot; None when no ledger is installed."""
    lld = ledger_of(cache)
    return lld.snapshot() if lld is not None else None


def locality_iteration(cache, scope, snap: tuple | None, *,
                       iteration, driver: str) -> dict:
    """Per-iteration locality emission: returns the row-extra fields and
    lands the same numbers as span attrs, tracer gauges and an EventLog
    ``locality`` record.  A cheap no-op dict when no ledger is installed,
    so un-instrumented drivers pay a getattr and nothing else."""
    lld = ledger_of(cache)
    if lld is None or snap is None:
        return {}
    fields = lld.delta(snap)
    scope.annotate(**fields)
    tr = tracer_of(cache)
    if tr.enabled:
        tr.gauge("locality_flops").set(fields["locality_flops"])
        tr.gauge("locality_bytes").set(fields["locality_bytes"])
    lg = log_of(cache)
    if lg.enabled:
        lg.info("locality", driver=driver, iteration=iteration, **fields)
    return fields
