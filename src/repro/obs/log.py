"""Structured event log + flight recorder — the greppable half of obs.

The tracer (:mod:`repro.obs.tracer`) answers "where did the time go" after a
*successful* run; this module answers "what happened" after a *failed* one:

* :class:`EventLog` — a leveled, schema-stable structured log.  Every event
  is one flat JSON-safe dict carrying the stable envelope
  :data:`EVENT_KEYS` (``ts``/``seq``/``level``/``event``) followed by
  free-form payload fields.  Events stream to a JSONL file when a path is
  given and always land in a bounded in-memory ring buffer (``recent``) —
  the flight recorder's source.  Like the tracer, the log rides on the plan
  cache (``cache.event_log``) so the drivers, collectives and balancer all
  reach it via :func:`log_of` without new plumbing; :data:`NULL_LOG` is the
  disabled log every un-instrumented path sees — falsy, allocation-free,
  records nothing, so logging off cannot perturb numerics.
* :class:`FlightRecorder` — a postmortem dumper.  ``install(cache)`` hooks
  it onto the cache; when a :class:`~repro.analysis.PlanError` is raised at
  plan admission, or a :class:`~repro.core.inverse.RefineMonitor` /
  :class:`~repro.core.purify.Sp2Monitor` divergence trip fires, the
  instrumented site calls :meth:`FlightRecorder.dump` and the recorder
  writes one JSON file with the stable envelope :data:`POSTMORTEM_KEYS`:
  the last N closed spans and instants, counter totals and deltas since the
  last :meth:`mark`, the ring buffer of recent log events, the plan-cache
  stats and the last plan key — everything needed to reconstruct the final
  iterations of a run that died.

Timestamps are epoch seconds (``time.time``), not the tracer's monotonic
clock: log lines are correlated with *external* systems (CI logs, other
processes), where the span timeline is correlated with itself.
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, IO

from .export import _json_safe
from .tracer import tracer_of

__all__ = [
    "EVENT_KEYS",
    "POSTMORTEM_KEYS",
    "LEVELS",
    "EventLog",
    "NullEventLog",
    "NULL_LOG",
    "log_of",
    "FlightRecorder",
    "load_events",
]

#: severity vocabulary, in increasing order
LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}

#: the stable envelope every event record starts with, in order — schema
#: stability is tested like SHARED_ITER_KEYS
EVENT_KEYS = ("ts", "seq", "level", "event")

#: the stable top-level schema of a flight-recorder postmortem file
POSTMORTEM_KEYS = (
    "reason",
    "ts",
    "detail",
    "spans",
    "instants",
    "counters",
    "counter_deltas",
    "events",
    "cache",
    "last_plan_key",
)


class EventLog:
    """Leveled structured log: JSONL stream + bounded ring buffer.

    ``path`` may be a filesystem path (opened line-buffered in append mode)
    or an open file-like object; ``None`` keeps events in memory only.
    ``level`` filters at emit time — events below it cost one dict lookup
    and nothing else.  ``capacity`` bounds ``recent``, the ring buffer the
    flight recorder snapshots.  ``clock`` is injectable for deterministic
    tests and defaults to epoch seconds.
    """

    enabled = True

    def __init__(self, path: str | IO | None = None, *, level: str = "info",
                 capacity: int = 512, clock=time.time):
        if level not in LEVELS:
            raise ValueError(f"level={level!r} not in {sorted(LEVELS)}")
        self.level = level
        self._threshold = LEVELS[level]
        self._clock = clock
        self.seq = 0
        self.recent: deque = deque(maxlen=int(capacity))
        if isinstance(path, str):
            self._fh: IO | None = open(path, "a", buffering=1)
            self._own_fh = True
        else:
            self._fh = path
            self._own_fh = False
        self.path = path if isinstance(path, str) else None

    def __bool__(self) -> bool:
        return True

    @property
    def debug_enabled(self) -> bool:
        """True when debug-level events survive the filter — per-iteration
        call sites guard on this so building the field dict costs nothing
        at ``info`` and above."""
        return self._threshold <= LEVELS["debug"]

    def emit(self, level: str, event: str, **fields: Any) -> dict | None:
        """Record one event; returns the record, or None when filtered."""
        if LEVELS[level] < self._threshold:
            return None
        rec = dict(ts=float(self._clock()), seq=self.seq, level=level,
                   event=str(event))
        rec.update(_json_safe(fields))
        self.seq += 1
        self.recent.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
        return rec

    # -- convenience levels --------------------------------------------------
    def debug(self, event: str, **fields: Any) -> dict | None:
        return self.emit("debug", event, **fields)

    def info(self, event: str, **fields: Any) -> dict | None:
        return self.emit("info", event, **fields)

    def warn(self, event: str, **fields: Any) -> dict | None:
        return self.emit("warn", event, **fields)

    def error(self, event: str, **fields: Any) -> dict | None:
        return self.emit("error", event, **fields)

    def events_of(self, event: str, level: str | None = None) -> list[dict]:
        """Matching records still in the ring buffer, in emit order."""
        return [r for r in self.recent
                if r["event"] == event and (level is None or r["level"] == level)]

    def close(self) -> None:
        if self._own_fh and self._fh is not None:
            self._fh.close()
            self._fh = None


class NullEventLog:
    """The disabled log: falsy, allocation-free, records nothing."""

    enabled = False
    debug_enabled = False
    level = "off"
    seq = 0
    recent: tuple = ()

    def __bool__(self) -> bool:
        return False

    def emit(self, level: str, event: str, **fields: Any) -> None:
        return None

    def debug(self, event: str, **fields: Any) -> None:
        return None

    def info(self, event: str, **fields: Any) -> None:
        return None

    def warn(self, event: str, **fields: Any) -> None:
        return None

    def error(self, event: str, **fields: Any) -> None:
        return None

    def events_of(self, event: str, level: str | None = None) -> list:
        return []

    def close(self) -> None:
        pass


NULL_LOG = NullEventLog()


def log_of(cache) -> EventLog | NullEventLog:
    """The event log threaded through the runtime rides on the plan cache."""
    if cache is None:
        return NULL_LOG
    lg = getattr(cache, "event_log", None)
    return lg if lg is not None else NULL_LOG


def load_events(path: str) -> list[dict]:
    """Parse a JSONL event-log file back into records (postmortem grepping)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _span_record(sp) -> dict:
    rec = dict(name=sp.name, cat=sp.cat, t0=float(sp.t0), dur=float(sp.dur),
               parent=int(sp.parent), args=_json_safe(sp.args))
    if sp.worker_costs is not None:
        rec["worker_costs"] = _json_safe(dict(c=sp.worker_costs))["c"]
    return rec


class FlightRecorder:
    """Bounded postmortem recorder riding on the plan cache.

    ``install(cache)`` attaches the recorder as ``cache.flight_recorder``;
    the plan-cache admission hook and the drivers' divergence trips then
    find it via ``getattr`` and call :meth:`dump` with a reason.  Drivers
    call :meth:`mark` once per iteration so a dump carries counter *deltas*
    over the final iteration, not just totals since run start.
    """

    def __init__(self, path: str = "postmortem.json", *,
                 last_spans: int = 64, last_events: int = 128,
                 clock=time.time):
        self.path = path
        self.last_spans = int(last_spans)
        self.last_events = int(last_events)
        self._clock = clock
        self._marked: dict = {}
        self.dumps = 0

    def install(self, cache) -> "FlightRecorder":
        cache.flight_recorder = self
        return self

    def mark(self, cache) -> None:
        """Snapshot counter totals; the next dump reports deltas vs here."""
        tr = tracer_of(cache)
        self._marked = dict(tr.metrics_flat()) if tr.enabled else {}

    def snapshot(self, reason: str, cache=None, **detail: Any) -> dict:
        """Assemble (but do not write) a postmortem record."""
        tr = tracer_of(cache)
        lg = log_of(cache)
        spans = [_span_record(sp) for sp in list(tr.spans)[-self.last_spans:]]
        instants = [
            dict(name=n, cat=c, ts=float(t), args=_json_safe(a))
            for (n, c, t, _p, a) in list(tr.instants)[-self.last_spans:]
        ]
        counters = dict(tr.metrics_flat()) if tr.enabled else {}
        deltas = {k: v - self._marked.get(k, 0.0)
                  for k, v in counters.items()
                  if isinstance(v, (int, float))}
        events = list(lg.recent)[-self.last_events:] if lg.enabled else []
        return dict(
            reason=str(reason),
            ts=float(self._clock()),
            detail=_json_safe(detail),
            spans=spans,
            instants=instants,
            counters=counters,
            counter_deltas=deltas,
            events=events,
            cache=cache.stats() if cache is not None else None,
            last_plan_key=(
                str(cache.last_plan_key)
                if cache is not None and getattr(cache, "last_plan_key", None)
                is not None else None),
        )

    def dump(self, reason: str, cache=None, **detail: Any) -> str:
        """Write the postmortem file; returns its path.

        Never raises: the recorder fires on the failure path, and a broken
        postmortem write must not mask the original error.
        """
        post = self.snapshot(reason, cache, **detail)
        self.dumps += 1
        try:
            with open(self.path, "w") as fh:
                json.dump(post, fh, indent=2, default=str)
                fh.write("\n")
        except OSError:
            return self.path
        lg = log_of(cache)
        if lg.enabled:
            lg.error("postmortem", reason=str(reason), path=self.path)
        tr = tracer_of(cache)
        if tr.enabled:
            tr.instant("postmortem", cat="health", reason=str(reason),
                       path=self.path)
        return self.path
