"""Per-worker device-memory accounting for the resident runtime.

The runtime's device footprint is fully determined by host-side symbolic
state: block stores are padded ``[P, cap, bs, bs]`` arrays, exchange receive
buffers are sized by the plan's padded per-round send capacities, norm
tables are one float per block.  :class:`MemoryMeter` folds those into
per-worker byte accounts *without touching the device*:

* :func:`matrix_worker_bytes` — physical store bytes per worker (uniform:
  the padded store allocates ``cap`` rows on every device) plus the actual
  (unpadded) resident block bytes per worker, which *do* skew with the
  owner map and are what a re-layout changes.
* :func:`plan_memory_bytes` — the transient footprint of one planned
  multiply dispatch: operand stores, padded receive buffers per ppermute
  round (or the full allgather payload), the output store, and the task
  index arrays.  Memoized on the plan (``plan._obs_mem``) like the
  balancer's ``_obs_static`` so zero-miss replays pay one getattr.
* The meter keeps **peak watermarks per account kind** ("collective") and a
  per-worker peak vector, surfaces them as tracer gauges
  (``mem_<kind>_peak_bytes`` plus per-worker ``mem_peak_w<p>_bytes`` on
  :meth:`MemoryMeter.flush`), so the memory column of
  ``python -m repro.obs.report`` can be reconstructed from a written trace
  file alone.
* :func:`jax_memory_stats` — best-effort ``device.memory_stats()`` where
  the backend exposes it (TPU/GPU; CPU fake devices typically return
  nothing) so the symbolic account can be cross-checked against the
  allocator on real hardware.

The meter rides on the plan cache (``cache.memory_meter``, default None);
the multiply dispatch sites and collectives note into it behind a cheap
``getattr`` so accounting off costs nothing and cannot perturb numerics.
"""

from __future__ import annotations

import numpy as np

from .tracer import tracer_of

__all__ = [
    "MemoryMeter",
    "meter_of",
    "matrix_worker_bytes",
    "plan_memory_bytes",
    "jax_memory_stats",
]

#: index arrays shipped per task slot (task_a, task_b, task_c, task_gidx,
#: and the four fused (src, off) address arrays), int32 each
_TASK_INDEX_ARRAYS = 8

_ITEMSIZES: dict = {}


def _itemsize(dtype) -> int:
    v = _ITEMSIZES.get(dtype)
    if v is None:
        v = int(np.dtype(str(dtype)).itemsize)
        _ITEMSIZES[dtype] = v
    return v


def matrix_worker_bytes(x) -> dict:
    """Store bytes of a :class:`~repro.dist.matrix.DistBSMatrix`.

    ``physical`` is what XLA allocates per worker — the padded store row
    count times the block size, identical on every device by construction.
    ``actual`` is the per-worker bytes of *valid* resident blocks (the
    quantity an owner re-layout moves).
    """
    itemsize = _itemsize(x.dtype) if x.nnzb else 4
    blk = x.bs * x.bs * itemsize
    physical = np.full(x.nparts, float(x.cap * blk))
    actual = np.bincount(x.owner, minlength=x.nparts).astype(np.float64) * blk
    return dict(physical=physical, actual=actual, blk=blk)


def plan_memory_bytes(plan, precision=None) -> dict:
    """Per-worker transient device bytes of one planned multiply dispatch.

    Operand stores are always fp32; the *wire* (receive buffers) honors the
    precision policy's storage dtype (bf16 halves them).  Memoized on the
    plan keyed by wire itemsize, so per-iteration accounting on a cached
    plan is a dict lookup.
    """
    wire_itemsize = 4
    if precision is not None and getattr(precision, "mode", "fp32") != "fp32":
        wire_itemsize = 2
    memo = getattr(plan, "_obs_mem", None)
    if memo is not None and wire_itemsize in memo:
        return memo[wire_itemsize]

    P = plan.nparts
    blk_store = plan.bs * plan.bs * 4
    blk_wire = plan.bs * plan.bs * wire_itemsize
    own = float((plan.a_cap + plan.b_cap) * blk_store)
    out = float(plan.c_cap * blk_store)
    if plan.exchange == "allgather":
        recv = float((P - 1) * (plan.a_cap + plan.b_cap) * blk_wire)
    else:
        recv = 0.0
        for offs, send_pad in ((plan.a_offsets, plan.a_send),
                               (plan.b_offsets, plan.b_send)):
            for d in offs:
                recv += float(send_pad[d].shape[1] * blk_wire)
    index = float(plan.t_cap * 4 * _TASK_INDEX_ARRAYS)
    per_worker = np.full(P, own + recv + out + index)
    result = dict(
        own_bytes=own,
        recv_buffer_bytes=recv,
        out_bytes=out,
        index_bytes=index,
        total_bytes=own + recv + out + index,
        per_worker=per_worker,
    )
    memo = dict(memo) if memo else {}
    memo[wire_itemsize] = result
    try:
        object.__setattr__(plan, "_obs_mem", memo)
    except AttributeError:
        pass
    return result


def jax_memory_stats() -> list[dict] | None:
    """Allocator stats per device where the backend exposes them.

    Returns one dict per device with whatever keys ``device.memory_stats()``
    reports (``bytes_in_use`` / ``peak_bytes_in_use`` on TPU/GPU), or None
    when jax is absent or no device reports (the CPU fake-device mesh)."""
    try:
        import jax
    except ImportError:
        return None
    out = []
    try:
        for d in jax.devices():
            stats = None
            try:
                stats = d.memory_stats()
            except Exception:
                stats = None
            if stats:
                out.append(dict(device=int(d.id), **{
                    k: v for k, v in stats.items()
                    if isinstance(v, (int, float))}))
    except Exception:
        return None
    return out or None


class MemoryMeter:
    """Peak-watermark device-memory accounts, per kind and per worker.

    ``current[kind]`` / ``peak[kind]`` are ``[P]`` byte vectors; the
    per-worker total watermark (:meth:`worker_peak`) sums the per-kind
    peaks — an upper bound on concurrent residency (stores persist across
    dispatches, receive buffers do not overlap between collectives).
    """

    enabled = True

    def __init__(self):
        self.nparts = 0
        self.current: dict[str, np.ndarray] = {}
        self.peak: dict[str, np.ndarray] = {}
        self.notes = 0

    def install(self, cache) -> "MemoryMeter":
        cache.memory_meter = self
        return self

    def _bump(self, kind: str, per_worker: np.ndarray, tracer=None) -> None:
        per_worker = np.asarray(per_worker, dtype=np.float64)
        self.nparts = max(self.nparts, per_worker.shape[0])
        self.current[kind] = per_worker
        prev = self.peak.get(kind)
        if prev is None or prev.shape != per_worker.shape:
            self.peak[kind] = per_worker.copy()
        else:
            np.maximum(prev, per_worker, out=prev)
        self.notes += 1
        if tracer is not None and tracer.enabled:
            tracer.gauge(f"mem_{kind}_peak_bytes").set(
                float(self.peak[kind].max()))

    # -- accounting entry points (all host-side symbolic math) ---------------
    def note_matrix(self, x, kind: str = "store", cache=None) -> None:
        """Account a resident matrix's physical store bytes per worker."""
        b = matrix_worker_bytes(x)
        self._bump(kind, b["physical"], tracer_of(cache))
        self._bump(kind + "_actual", b["actual"])

    def note_plan(self, plan, precision=None, kind: str = "multiply",
                  cache=None) -> None:
        """Account one planned dispatch's transient footprint per worker."""
        m = plan_memory_bytes(plan, precision)
        self._bump(kind, m["per_worker"], tracer_of(cache))

    def note_bytes(self, kind: str, per_worker, cache=None) -> None:
        """Account an arbitrary per-worker byte vector (norm tables, ...)."""
        self._bump(kind, np.asarray(per_worker, dtype=np.float64),
                   tracer_of(cache))

    # -- readout -------------------------------------------------------------
    def worker_peak(self) -> np.ndarray:
        """Per-worker peak-watermark bytes: sum of per-kind peaks (upper
        bound on concurrent residency); excludes the ``*_actual`` accounts,
        which alias the physical stores."""
        out = np.zeros(max(self.nparts, 1))
        for kind, peak in self.peak.items():
            if kind.endswith("_actual"):
                continue
            v = np.zeros_like(out)
            v[: peak.shape[0]] = peak
            out += v
        return out

    def flush(self, tracer) -> None:
        """Emit per-worker peak gauges so a written Chrome trace carries the
        memory column (``mem_peak_w<p>_bytes`` counter events)."""
        if tracer is None or not tracer.enabled:
            return
        wp = self.worker_peak()
        for p in range(wp.shape[0]):
            tracer.gauge(f"mem_peak_w{p}_bytes").set(float(wp[p]))

    def summary(self) -> dict:
        """JSON-safe account summary (driver stats / BENCH files)."""
        wp = self.worker_peak()
        return dict(
            nparts=int(self.nparts),
            notes=int(self.notes),
            worker_peak_bytes=wp.tolist(),
            peak_bytes_max=float(wp.max()) if wp.size else 0.0,
            per_kind={k: dict(peak_bytes_max=float(v.max()),
                              peak_bytes=v.tolist())
                      for k, v in sorted(self.peak.items())},
            jax=jax_memory_stats(),
        )


def meter_of(cache):
    """The memory meter riding on the plan cache, or None when accounting
    is off (mirrors :func:`repro.obs.tracer.tracer_of`)."""
    return getattr(cache, "memory_meter", None) if cache is not None else None
