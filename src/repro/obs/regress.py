"""Benchmark trajectory store + regression gate.

Three ``BENCH_*.json`` files live at the repo root with no history behind
them: a regression there is invisible until someone rereads old CI logs.
This module defines the one-schema-for-all-benches trajectory store
(``BENCH_HISTORY.jsonl``) and the CI gate over it:

* Every history entry is one JSON line with the stable envelope
  :data:`ENTRY_KEYS`: ``ts`` (epoch seconds), ``commit`` (short git hash or
  "unknown"), ``bench`` ("trace" / "balance" / "kernel" / "purify" / ...),
  ``config`` ("smoke" / "full" / structure name), ``metrics`` (flat
  str->float dict) and free-form ``meta``.  ``benchmarks/history.py``
  extracts entries from the written BENCH files and appends them.
* :func:`check_history` groups entries by ``(bench, config, metric)``,
  takes the **median of all prior entries** in each group as the baseline
  (robust to one noisy CI run) and fails the latest entry when it is worse
  than baseline beyond the metric's tolerance.  Metric direction and
  tolerances live in :data:`TOLERANCES`; unknown metrics get
  :data:`DEFAULT_SPEC` (lower-is-better, 100% relative slack — wall-clock
  noise on shared CI runners is real).  Single-entry groups pass: the first
  recorded run *is* the baseline.
* CLI: ``python -m repro.obs.regress --check`` exits nonzero on any
  regression; ``--list`` prints the trajectory table.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

__all__ = [
    "ENTRY_KEYS",
    "HISTORY_FILENAME",
    "MetricSpec",
    "TOLERANCES",
    "DEFAULT_SPEC",
    "load_history",
    "append_history",
    "check_history",
    "trajectory_table",
    "main",
]

#: the stable envelope of one history entry, in order
ENTRY_KEYS = ("ts", "commit", "bench", "config", "metrics", "meta")

HISTORY_FILENAME = "BENCH_HISTORY.jsonl"


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """Tolerance of one metric: ``direction`` is "lower" or "higher"
    (which way is better); the latest value regresses when it is worse than
    baseline by more than ``abs_tol + rel_tol * |baseline|``."""

    direction: str = "lower"
    rel_tol: float = 1.0
    abs_tol: float = 0.0


DEFAULT_SPEC = MetricSpec()

#: per-bench metric tolerances.  Wall-clock metrics get loose relative
#: slack (CI runners are noisy); structural metrics (bit identity, overhead
#: cap, error bounds) are tight — those are the ones a code change moves.
TOLERANCES: dict[str, dict[str, MetricSpec]] = {
    "trace": {
        # the bench's own gate is 2%; the history gate allows the same
        # absolute drift from the recorded baseline
        "overhead_pct": MetricSpec("lower", rel_tol=0.0, abs_tol=2.0),
        "overhead_sync_pct": MetricSpec("lower", rel_tol=1.0, abs_tol=10.0),
        "bit_identical": MetricSpec("higher", rel_tol=0.0, abs_tol=0.0),
        "min_untraced_s": MetricSpec("lower", rel_tol=1.0),
        "min_traced_s": MetricSpec("lower", rel_tol=1.0),
    },
    "balance": {
        "peak_imbalance_reduction": MetricSpec("higher", rel_tol=0.5),
        "bit_identical": MetricSpec("higher", rel_tol=0.0, abs_tol=0.0),
        "imbalance_tail": MetricSpec("lower", rel_tol=0.5),
        "wall_s_per_iter": MetricSpec("lower", rel_tol=1.0),
    },
    "kernel": {
        "fused_speedup": MetricSpec("higher", rel_tol=0.5),
        "bit_identical": MetricSpec("higher", rel_tol=0.0, abs_tol=0.0),
        "bf16_fro_err": MetricSpec("lower", rel_tol=0.5),
        "within_bounds": MetricSpec("higher", rel_tol=0.0, abs_tol=0.0),
    },
    "locality": {
        # locality fractions are structural (placement + plan), not
        # wall-clock: regressions here mean a planning/placement change
        # started moving bytes it didn't need to
        "locality_flops_static": MetricSpec("higher", rel_tol=0.25),
        "locality_flops_rebalanced": MetricSpec("higher", rel_tol=0.25),
        "locality_bytes_rebalanced": MetricSpec("higher", rel_tol=0.25),
        # rebalanced must beat static on the skewed layout (the bench
        # asserts > 1.0; history-gate drift beyond 25% is a regression)
        "rebalanced_locality_gain": MetricSpec("higher", rel_tol=0.25),
        "wire_mb_rebalanced": MetricSpec("lower", rel_tol=0.5),
        # what-if critical-path ratio (rebalanced cut / executed plan):
        # lower is better, and it is a pure re-plan property
        "critical_path_ratio": MetricSpec("lower", rel_tol=0.25),
    },
}


def _spec_for(bench: str, metric: str) -> MetricSpec:
    return TOLERANCES.get(bench, {}).get(metric, DEFAULT_SPEC)


def load_history(path: str) -> list[dict]:
    """Parse the JSONL history; missing file is an empty history."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            entry = json.loads(line)
            missing = set(ENTRY_KEYS) - entry.keys()
            if missing:
                raise ValueError(
                    f"{path}:{i + 1}: entry missing keys {sorted(missing)}")
            out.append(entry)
    return out


def append_history(path: str, entry: dict) -> dict:
    """Validate the envelope and append one JSONL line."""
    missing = set(ENTRY_KEYS) - entry.keys()
    if missing:
        raise ValueError(f"history entry missing keys {sorted(missing)}")
    for k, v in entry["metrics"].items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            raise ValueError(f"metric {k!r} must be numeric, got {v!r}")
    with open(path, "a") as fh:
        fh.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def _median(values: list[float]) -> float:
    s = sorted(values)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def check_history(entries: list[dict],
                  tolerances: dict | None = None) -> list[dict]:
    """Regressions of each group's latest entry vs the median of its prior
    entries.  Returns one violation dict per regressing metric."""
    groups: dict[tuple, list[tuple[int, dict]]] = {}
    for i, e in enumerate(entries):
        groups.setdefault((e["bench"], e["config"]), []).append((i, e))

    violations = []
    for (bench, config), members in sorted(groups.items()):
        if len(members) < 2:
            continue  # first recorded run is the baseline
        *prior, (_, latest) = members
        for metric, value in sorted(latest["metrics"].items()):
            history = [e["metrics"][metric] for _, e in prior
                       if metric in e["metrics"]]
            if not history:
                continue
            spec = (tolerances or {}).get(bench, {}).get(metric) \
                if tolerances else None
            spec = spec or _spec_for(bench, metric)
            baseline = _median(history)
            slack = spec.abs_tol + spec.rel_tol * abs(baseline)
            if spec.direction == "lower":
                bad = value > baseline + slack
            else:
                bad = value < baseline - slack
            if bad:
                violations.append(dict(
                    bench=bench, config=config, metric=metric,
                    value=float(value), baseline=float(baseline),
                    slack=float(slack), direction=spec.direction,
                    samples=len(history), commit=latest.get("commit"),
                ))
    return violations


def trajectory_table(entries: list[dict]) -> str:
    """Human-readable trajectory: one line per entry."""
    lines = [f"{'bench':10s} {'config':16s} {'commit':10s} metrics"]
    for e in entries:
        metrics = "  ".join(f"{k}={v:.4g}"
                            for k, v in sorted(e["metrics"].items()))
        lines.append(f"{e['bench']:10s} {e['config']:16s} "
                     f"{str(e['commit']):10s} {metrics}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="benchmark history regression gate")
    ap.add_argument("--history", default=HISTORY_FILENAME,
                    help=f"history file (default ./{HISTORY_FILENAME})")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on tolerance-violating regressions")
    ap.add_argument("--list", action="store_true",
                    help="print the trajectory table")
    args = ap.parse_args(argv)

    entries = load_history(args.history)
    if args.list or not args.check:
        print(trajectory_table(entries) if entries
              else f"{args.history}: no entries")
    if not args.check:
        return 0
    violations = check_history(entries)
    if violations:
        print(f"regress: {len(violations)} regression(s) vs baseline "
              f"in {args.history}:")
        for v in violations:
            arrow = ">" if v["direction"] == "lower" else "<"
            print(f"  {v['bench']}/{v['config']} {v['metric']}: "
                  f"{v['value']:.4g} {arrow} baseline {v['baseline']:.4g} "
                  f"± {v['slack']:.4g} ({v['samples']} prior sample(s), "
                  f"commit {v['commit']})")
        return 1
    n = len(entries)
    print(f"regress: clean ({n} entr{'y' if n == 1 else 'ies'} "
          f"in {args.history})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
