"""Per-worker utilization report — the measured counterpart of the load
balancer's imbalance numbers.

Derives, from a live :class:`~repro.obs.tracer.Tracer` or from a written
Chrome trace file, each worker's busy seconds (sum of its attributed busy
intervals), busy/idle fractions of the traced window, and the timeline
imbalance ``max busy / mean busy`` — directly comparable to the
``max/mean`` combined-cost imbalance the rebalancing cost model reports
(``BENCH_balance.json``): for a single step both reduce to the same ratio,
and across a run the timeline number is the duration-weighted aggregate.

Render with :func:`utilization_table`, or from a trace file::

    python -m repro.obs.report trace_sqrt_inv.json

The locality/task-graph side (``benchmarks/locality.py`` output)::

    python -m repro.obs.report --locality [BENCH_locality.json]

renders, per structure: static vs rebalanced locality fractions, the
per-worker locality table, the most-moved blocks, and the critical-path
breakdown with its what-if projections.
"""

from __future__ import annotations

import json

import numpy as np

from .export import WORKER_PID, _attributed_leaves
from .tracer import Tracer

__all__ = [
    "worker_utilization",
    "utilization_from_file",
    "memory_from_file",
    "utilization_table",
    "locality_table",
    "locality_from_file",
]


def _summarize(busy: np.ndarray, window: float) -> dict:
    window = max(window, 1e-12)
    frac = busy / window
    mean_busy = busy.mean() if busy.size else 0.0
    return dict(
        nparts=int(busy.size),
        window_s=float(window),
        busy_s=[float(b) for b in busy],
        busy_frac=[float(f) for f in frac],
        idle_frac=[float(1.0 - f) for f in frac],
        mean_busy_frac=float(frac.mean()) if busy.size else 0.0,
        min_busy_frac=float(frac.min()) if busy.size else 0.0,
        max_busy_frac=float(frac.max()) if busy.size else 0.0,
        timeline_imbalance=(
            float(busy.max() / mean_busy) if mean_busy > 0 else 1.0
        ),
    )


def worker_utilization(tracer: Tracer) -> dict:
    """Busy/idle fractions per worker from a live tracer's attributed spans.

    The window is the total duration of attributed steps (an SPMD step's
    wall time is its slowest worker's time, so the heaviest worker per step
    is busy for the whole step); worker ``p`` is busy for
    ``dur * cost_p / max_q cost_q`` of each step.
    """
    leaves = _attributed_leaves(tracer)
    nparts = max((len(tracer.spans[i].worker_costs) for i in leaves), default=0)
    busy = np.zeros(nparts, dtype=np.float64)
    window = 0.0
    for i in leaves:
        sp = tracer.spans[i]
        costs = np.asarray(sp.worker_costs, dtype=np.float64)
        cmax = costs.max() if costs.size else 0.0
        if cmax <= 0.0:
            continue
        window += sp.dur
        busy[: costs.shape[0]] += sp.dur * costs / cmax
    return _summarize(busy, window)


def utilization_from_file(path: str) -> dict:
    """Same report computed back from a written Chrome trace file.

    Reads the worker tracks' ``B``/``E`` pairs, so it validates that the
    exported file carries the full utilization picture on its own.
    """
    with open(path) as fh:
        trace = json.load(fh)
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    tids = set()
    opens: dict[tuple, float] = {}
    busy: dict[int, float] = {}
    intervals: list[tuple[float, float]] = []
    for e in events:
        if e.get("pid") != WORKER_PID:
            continue
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                tids.add(e["tid"])
            continue
        if e["ph"] == "B":
            opens[(e["tid"], e["name"], e["ts"])] = e["ts"]
        elif e["ph"] == "E":
            # match the oldest open B on this tid (pairs are emitted B,E)
            key = next(k for k in opens if k[0] == e["tid"])
            t0 = opens.pop(key)
            busy[e["tid"]] = busy.get(e["tid"], 0.0) + (e["ts"] - t0) * 1e-6
            intervals.append((t0 * 1e-6, e["ts"] * 1e-6))
    nparts = (max(tids) + 1) if tids else 0
    busy_v = np.array([busy.get(p, 0.0) for p in range(nparts)])
    # window: union length of the busiest worker's view is not recoverable
    # exactly; use the per-step convention — the heaviest worker spans the
    # whole step — i.e. the maximum single-track busy time per step summed,
    # which equals the merged interval length of all busy intervals
    window, end = 0.0, None
    for lo, hi in sorted(intervals):
        if end is None or lo >= end:
            window += hi - lo
            end = hi
        elif hi > end:
            window += hi - end
            end = hi
    return _summarize(busy_v, window)


def memory_from_file(path: str) -> list[float] | None:
    """Per-worker peak device-memory bytes recovered from a written trace.

    :meth:`~repro.obs.memory.MemoryMeter.flush` emits one
    ``mem_peak_w{p}_bytes`` gauge per worker; these land in the Chrome trace
    as ``C`` counter events, so the memory column of the report — like the
    utilization numbers — needs nothing but the trace file.  Returns
    ``None`` when the trace carries no memory gauges.
    """
    with open(path) as fh:
        trace = json.load(fh)
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    peaks: dict[int, float] = {}
    for e in events:
        if e.get("ph") != "C" or not e["name"].startswith("mem_peak_w"):
            continue
        p = int(e["name"][len("mem_peak_w"):-len("_bytes")])
        # gauges re-emit on every flush: the last value is the run peak
        peaks[p] = float(e["args"][e["name"]])
    if not peaks:
        return None
    return [peaks.get(p, 0.0) for p in range(max(peaks) + 1)]


def utilization_table(util: dict, memory: list[float] | None = None) -> str:
    """Human-readable per-worker utilization summary table.

    ``memory`` (per-worker peak bytes, e.g. from :func:`memory_from_file`
    or ``MemoryMeter.worker_peak()``) adds a peak-MB column.
    """
    mem_col = memory is not None and len(memory) >= util["nparts"]
    header = f"{'worker':>6}  {'busy ms':>10}  {'busy %':>7}  {'idle %':>7}"
    if mem_col:
        header += f"  {'peak MB':>9}"
    lines = [
        f"traced window: {util['window_s'] * 1e3:.1f} ms over "
        f"{util['nparts']} workers   "
        f"timeline imbalance (max/mean busy): "
        f"{util['timeline_imbalance']:.2f}",
        header,
    ]
    for p in range(util["nparts"]):
        row = (
            f"{p:>6}  {util['busy_s'][p] * 1e3:>10.1f}  "
            f"{util['busy_frac'][p] * 100:>6.1f}%  "
            f"{util['idle_frac'][p] * 100:>6.1f}%"
        )
        if mem_col:
            row += f"  {memory[p] / 1e6:>9.2f}"
        lines.append(row)
    tail = (
        f"{'mean':>6}  {np.mean(util['busy_s']) * 1e3:>10.1f}  "
        f"{util['mean_busy_frac'] * 100:>6.1f}%  "
        f"{(1 - util['mean_busy_frac']) * 100:>6.1f}%"
    )
    if mem_col:
        tail += f"  {np.mean(memory[: util['nparts']]) / 1e6:>9.2f}"
    lines.append(tail)
    return "\n".join(lines)


def _locality_mode_line(mode: str, s: dict) -> str:
    return (f"  [{mode:10s}] locality {s['locality_flops'] * 100:5.1f}% of "
            f"flops / {s['locality_bytes'] * 100:5.1f}% of bytes   "
            f"shipped {s['shipped_bytes'] / 1e6:7.2f} MB   "
            f"wire {s['wire_recv_bytes'] / 1e6:7.2f} MB   "
            f"({s['dispatches']} dispatches)")


def locality_table(data: dict) -> str:
    """Human-readable render of one ``BENCH_locality.json`` payload.

    Per structure: static vs rebalanced locality fractions, the rebalanced
    run's per-worker locality split, its most-moved blocks, and the
    task-graph critical-path breakdown with what-if projections.
    """
    meta = data.get("meta", {})
    lines = [
        f"locality report: n={meta.get('n')} bs={meta.get('bs')} "
        f"workers={meta.get('workers')} "
        f"initial layout: {meta.get('initial_layout', '?')}"
    ]
    for name, row in sorted(data["locality"].items()):
        lines.append(f"\n== {name} ==")
        for mode in ("static", "rebalanced"):
            if mode in row:
                lines.append(_locality_mode_line(mode, row[mode]))
        detail = row.get("rebalanced") or row.get("static")
        if detail and detail.get("per_worker"):
            lines.append(
                f"  {'worker':>8}  {'local MB':>9}  {'shipped MB':>10}  "
                f"{'wire MB':>8}  {'loc flops':>9}  {'loc bytes':>9}")
            for w in detail["per_worker"]:
                lines.append(
                    f"  {w['worker']:>8}  {w['local_bytes'] / 1e6:>9.2f}  "
                    f"{w['shipped_bytes'] / 1e6:>10.2f}  "
                    f"{w['wire_recv_bytes'] / 1e6:>8.2f}  "
                    f"{w['locality_flops'] * 100:>8.1f}%  "
                    f"{w['locality_bytes'] * 100:>8.1f}%")
        if detail and detail.get("moved_blocks"):
            lines.append("  most-moved blocks (operand, Morton code, "
                         "fetches, owners -> fetchers):")
            for b in detail["moved_blocks"]:
                lines.append(
                    f"    {b['operand']}  code={b['code']:<8d} "
                    f"fetched {b['fetches']:>4d}x   "
                    f"owners {b['owners']} -> workers {b['fetchers']}")
        tg = row.get("taskgraph")
        if tg:
            before, after = tg["before"], tg.get("after")
            lines.append(
                f"  critical path (task-equivalents): "
                f"{before['critical_path']:.1f} = exchange "
                f"{before['cp_exchange']:.1f} + compute "
                f"{before['cp_compute']:.1f}   max busy "
                f"{max(before['busy']):.1f}   mean slack "
                f"{sum(before['slack']) / max(len(before['slack']), 1):.1f}")
            lines.append(
                f"  what-if: perfect balance "
                f"{before['whatif_perfect_balance']:.1f}   zero exchange "
                f"{before['whatif_zero_exchange']:.1f}"
                + (f"   rebalanced cut {after['critical_path']:.1f} "
                   f"(predicted gain {tg['predicted_gain']:.2f}x)"
                   if after else ""))
            rounds = sorted(before.get("rounds", []),
                            key=lambda r: -r["max_cost"])[:4]
            if rounds:
                lines.append("  heaviest exchange rounds: " + "   ".join(
                    f"{r['operand']}@+{r['offset']} {r['max_cost']:.1f}"
                    for r in rounds))
    return "\n".join(lines)


def locality_from_file(path: str) -> str:
    with open(path) as fh:
        return locality_table(json.load(fh))


def main(argv=None) -> int:
    import sys

    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "--locality":
        path = argv[1] if len(argv) > 1 else "BENCH_locality.json"
        print(locality_from_file(path))
        return 0
    if len(argv) != 1:
        print("usage: python -m repro.obs.report <chrome-trace.json> | "
              "--locality [BENCH_locality.json]")
        return 2
    util = utilization_from_file(argv[0])
    print(utilization_table(util, memory=memory_from_file(argv[0])))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
