"""Executed-task-graph analytics: critical path, slack, what-if projections.

The tracer's span timeline says how long a run took; this module says how
long it *had to* take.  From a :class:`~repro.core.schedule.SpgemmPlan`'s
index arrays (exchange round -> task -> output-slot accumulation chain) it
reconstructs the dependency structure the SPMD program actually executes —
each planned ``ppermute`` round is a barrier, then every worker runs its
task list — and computes:

* the **critical path**: the sum over rounds of the most-loaded worker's
  round cost, plus the most-loaded worker's compute — a lower bound on the
  step's wall time under the executed schedule;
* per-worker **busy time** and **slack** (critical path minus busy time;
  non-negative by construction since the critical path takes the per-round
  and compute maxima);
* **what-if projections**: predicted critical path under perfect flop
  balance, under zero exchange, and under the measured rebalanced cut
  (:func:`whatif_rebalanced` re-plans with the weights the dynamic load
  balancer would use and analyzes the resulting plan) — validating
  :class:`~repro.dist.balance.RebalancePolicy` gains analytically before
  paying a migration.

Costs are expressed in **task-equivalent units** using the same per-block
coefficients as the load balancer's cost model
(:meth:`~repro.dist.balance.WorkerLoad.combined`): one unit is one leaf
task's flops, a received or sent block costs ``recv_cost`` / ``send_cost``
units.  :func:`project_seconds` converts units to seconds by calibrating
against a measured wall time.
"""

from __future__ import annotations

import dataclasses
import typing

import numpy as np

from .locality import plan_provenance

if typing.TYPE_CHECKING:  # core.cache imports obs.log: keep obs<->core lazy
    from ..core.schedule import SpgemmPlan

__all__ = [
    "TaskGraphAnalysis",
    "analyze_plan",
    "whatif_rebalanced",
    "project_seconds",
]


@dataclasses.dataclass(frozen=True)
class TaskGraphAnalysis:
    """Critical-path decomposition of one executed plan, in task units."""

    nparts: int
    compute: np.ndarray  # [P] task-equivalent compute per worker
    exchange: np.ndarray  # [P] summed per-round exchange cost per worker
    busy: np.ndarray  # [P] = exchange + compute
    slack: np.ndarray  # [P] = critical_path - busy  (>= 0)
    critical_path: float  # cp_exchange + cp_compute
    cp_exchange: float  # sum over rounds of the per-round maximum
    cp_compute: float  # max over workers of compute
    rounds: list  # per-round detail records (operand, offset, max_cost, cap)
    whatif_perfect_balance: float  # cp_exchange + mean compute
    whatif_zero_exchange: float  # compute-only critical path

    def as_dict(self) -> dict:
        """JSON-safe record (benchmarks, reports)."""
        return dict(
            nparts=self.nparts,
            units="task-equivalents",
            critical_path=float(self.critical_path),
            cp_exchange=float(self.cp_exchange),
            cp_compute=float(self.cp_compute),
            compute=self.compute.tolist(),
            exchange=self.exchange.tolist(),
            busy=self.busy.tolist(),
            slack=self.slack.tolist(),
            rounds=[dict(r) for r in self.rounds],
            whatif_perfect_balance=float(self.whatif_perfect_balance),
            whatif_zero_exchange=float(self.whatif_zero_exchange),
        )


def analyze_plan(plan: SpgemmPlan, *, task_count: np.ndarray | None = None,
                 policy=None) -> TaskGraphAnalysis:
    """Analyze the executed dependency DAG of one plan.

    ``task_count`` overrides the plan's static per-worker task counts with
    measured ones (delta-plan SpAMM masks tasks at runtime — pass
    ``cache.last_task_count``); ``policy`` supplies the byte-cost
    coefficients and defaults to :class:`~repro.dist.balance.RebalancePolicy`.
    """
    from ..dist.balance import RebalancePolicy  # lazy: avoids obs<->dist cycle

    policy = policy if policy is not None else RebalancePolicy()
    P = plan.nparts
    prov = plan_provenance(plan)
    compute = np.asarray(
        plan.task_count if task_count is None else task_count,
        dtype=np.float64)
    if compute.shape != (P,):
        raise ValueError(
            f"task_count shape {compute.shape} does not match nparts={P}")

    exchange = np.zeros(P, dtype=np.float64)
    cp_exchange = 0.0
    round_detail = []
    for rec in prov["rounds"]:
        recv = np.asarray(rec["recv_blocks"], dtype=np.float64)
        send = np.asarray(rec["send_blocks"], dtype=np.float64)
        cost = policy.recv_cost * recv + policy.send_cost * send
        exchange += cost
        cp_exchange += float(cost.max()) if cost.size else 0.0
        round_detail.append(dict(
            operand=rec["operand"], offset=rec["offset"], cap=rec["cap"],
            max_cost=float(cost.max()) if cost.size else 0.0,
        ))
    cp_compute = float(compute.max()) if compute.size else 0.0
    busy = exchange + compute
    critical_path = cp_exchange + cp_compute
    slack = critical_path - busy
    return TaskGraphAnalysis(
        nparts=P,
        compute=compute,
        exchange=exchange,
        busy=busy,
        slack=slack,
        critical_path=critical_path,
        cp_exchange=cp_exchange,
        cp_compute=cp_compute,
        rounds=round_detail,
        whatif_perfect_balance=cp_exchange + float(compute.mean()),
        whatif_zero_exchange=cp_compute,
    )


def whatif_rebalanced(plan: SpgemmPlan, a_coords: np.ndarray,
                      b_coords: np.ndarray | None = None, *,
                      policy=None) -> dict:
    """Project the critical path under the measured rebalanced cut.

    Re-plans the same task list with the owner map the dynamic load
    balancer would migrate to (reference-count weights over the executed
    tasks, exactly :meth:`~repro.dist.balance.LoadMonitor.migrate`'s
    weighting) and analyzes the re-plan — the analytic preview of a
    migration's gain, before paying its bytes.  ``b_coords`` defaults to
    ``a_coords`` (the X·X case, where one migration moves both operands).

    Returns ``{"before", "after"}`` analyses plus ``predicted_gain``
    (before/after critical-path ratio) and the proposed owner map.
    """
    from ..core.schedule import make_spgemm_plan
    from ..dist.balance import (RebalancePolicy, block_reference_weights,
                                rebalanced_owner)

    policy = policy if policy is not None else RebalancePolicy()
    same = b_coords is None or b_coords is a_coords
    b_coords = a_coords if b_coords is None else b_coords
    na, nb = a_coords.shape[0], b_coords.shape[0]
    wa, wb = block_reference_weights(plan.tasks, na, nb)
    if same:
        owner = rebalanced_owner(a_coords, wa + wb + 1.0, plan.nparts, policy)
        a_owner = b_owner = owner
    else:
        a_owner = rebalanced_owner(a_coords, wa + 1.0, plan.nparts, policy)
        b_owner = rebalanced_owner(b_coords, wb + 1.0, plan.nparts, policy)
    replanned = make_spgemm_plan(
        a_coords, b_coords, plan.nparts, plan.bs,
        exchange=plan.exchange, tasks=plan.tasks,
        a_owner=a_owner, b_owner=b_owner,
    )
    before = analyze_plan(plan, policy=policy)
    after = analyze_plan(replanned, policy=policy)
    gain = (before.critical_path / after.critical_path
            if after.critical_path > 0 else 1.0)
    return dict(
        before=before,
        after=after,
        predicted_gain=float(gain),
        a_owner=a_owner,
        b_owner=b_owner,
        plan=replanned,
    )


def project_seconds(analysis: TaskGraphAnalysis,
                    measured_wall_s: float) -> dict:
    """Convert a unit-space analysis into seconds against a measured wall.

    One measured step wall time calibrates seconds-per-unit on the critical
    path; the what-if projections then read directly in seconds.
    """
    cp = analysis.critical_path
    spu = measured_wall_s / cp if cp > 0 else 0.0
    return dict(
        measured_s=float(measured_wall_s),
        seconds_per_unit=float(spu),
        critical_path_s=float(cp * spu),
        perfect_balance_s=float(analysis.whatif_perfect_balance * spu),
        zero_exchange_s=float(analysis.whatif_zero_exchange * spu),
    )
