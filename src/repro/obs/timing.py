"""Shared timing idioms of the resident runtime — one utility, one account.

Before this module, ``time.perf_counter()`` pairs were scattered across
``dist/collectives.py``, ``dist/multiply.py``, ``dist/inverse.py``,
``dist/purify.py`` and ``core/cache.py``, and the drivers disagreed on what
each accumulator included (``dist_truncate`` timed the device norm fetch
into ``symbolic_s``; the hierarchical path did not).  Everything now goes
through two context managers:

* :class:`timed_into` — time a block into a named accumulator attribute
  (``cache.build_s`` / ``cache.symbolic_s``) and emit one tracer span.  The
  accounting rule is uniform by construction: *device fetches stay outside,
  host-side symbolic/planning work goes inside.*
* :class:`IterationScope` — the per-iteration scope every iterative driver
  shares: one tracer span, one cache counter snapshot, one wall clock, and
  a uniform per-iteration stats row (:data:`SHARED_ITER_KEYS`) so the SP2
  and inverse-refinement drivers emit schema-compatible rows.
"""

from __future__ import annotations

from time import perf_counter

from .tracer import NULL_TRACER, tracer_of

__all__ = ["timed_into", "IterationScope", "SHARED_ITER_KEYS",
           "wall_clock", "Stopwatch"]


def wall_clock() -> float:
    """The runtime's one wall clock (monotonic seconds).

    Every module outside this one measures time through here (or through
    :class:`Stopwatch` / :class:`timed_into`) — enforced by the
    ``perf-counter`` rule of :mod:`repro.analysis.lint` — so all timing
    accounts share one clock source and stay comparable.
    """
    return perf_counter()


class Stopwatch:
    """Minimal elapsed-seconds helper over :func:`wall_clock`.

    ``elapsed()`` reads without resetting; ``lap()`` reads and restarts —
    the two idioms the training loop, the autotuner and the serving CLI
    previously open-coded with raw ``perf_counter`` pairs.
    """

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0 = perf_counter()

    def restart(self) -> None:
        self._t0 = perf_counter()

    def elapsed(self) -> float:
        return perf_counter() - self._t0

    def lap(self) -> float:
        now = perf_counter()
        dt = now - self._t0
        self._t0 = now
        return dt


class timed_into:
    """``with timed_into(cache, "symbolic_s", tracer, "spamm_descent"): ...``

    Accumulates the body's wall time onto ``obj.attr`` (skipped when ``obj``
    is None) and records a tracer span (skipped when ``name`` is None or the
    tracer is disabled).  ``elapsed`` holds the measured seconds after exit.
    """

    __slots__ = ("_obj", "_attr", "_tracer", "_name", "_cat", "_args",
                 "_handle", "_t0", "elapsed")

    def __init__(self, obj, attr: str, tracer=None, name: str | None = None,
                 cat: str = "symbolic", **args):
        self._obj = obj
        self._attr = attr
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._name = name
        self._cat = cat
        self._args = args
        self._handle = None
        self.elapsed = 0.0

    def __enter__(self):
        if self._name is not None and self._tracer.enabled:
            self._handle = self._tracer.span(self._name, cat=self._cat,
                                             **self._args)
            self._handle.__enter__()
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = perf_counter() - self._t0
        if self._handle is not None:
            self._handle.__exit__(*exc)
        obj = self._obj
        if obj is not None:
            setattr(obj, self._attr, getattr(obj, self._attr) + self.elapsed)
        return None


# the per-iteration row keys BOTH iterative drivers (dist_sp2_purify,
# dist_localized_inverse_factorization) emit — tested for schema stability
SHARED_ITER_KEYS = (
    "iteration",
    "nnzb",
    "spamm_err",
    "recv_bytes_mean",
    "norm_fetch_bytes",
    "imbalance",
    "imbalance_after",
    "migrated_bytes",
    "wall_s",
    "cache_hits",
    "cache_misses",
    "plan_build_s",
    "symbolic_s",
)

_ROW_DEFAULTS = dict(
    nnzb=0,
    spamm_err=0.0,
    recv_bytes_mean=0.0,
    norm_fetch_bytes=0,
    imbalance=None,
    imbalance_after=None,
    migrated_bytes=0,
)


class IterationScope:
    """One driver iteration (or named stage): span + cache snapshot + clock.

    ``delta()`` returns the wall/cache-counter deltas accumulated so far
    (the stage rows of :func:`~repro.dist.purify.dist_sqrt_inv_pipeline`);
    ``row(**fields)`` additionally fills the shared per-iteration schema
    (:data:`SHARED_ITER_KEYS`) with uniform defaults so every driver's rows
    carry the same keys for the same meanings.
    """

    __slots__ = ("_cache", "_tracer", "_name", "_cat", "_args", "_handle",
                 "_snap", "_t0", "iteration")

    def __init__(self, cache, iteration=None, tracer=None,
                 name: str = "iteration", cat: str = "iteration", **args):
        self._cache = cache
        self._tracer = tracer if tracer is not None else tracer_of(cache)
        self._name = name
        self._cat = cat
        self._args = args
        self._handle = None
        self.iteration = iteration

    def __enter__(self):
        if self._tracer.enabled:
            args = dict(self._args)
            if self.iteration is not None:
                args["i"] = self.iteration
            self._handle = self._tracer.span(self._name, cat=self._cat, **args)
            self._handle.__enter__()
        self._snap = self._cache.snapshot() if self._cache is not None else None
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        if self._handle is not None:
            self._handle.__exit__(*exc)
            self._handle = None
        return None

    def annotate(self, **args) -> None:
        """Attach extra attrs to the iteration span (before scope exit).

        The span handle is released on ``__exit__``, so per-iteration
        annotations (e.g. the locality ledger's fraction fields) must land
        while the scope is still open; a no-op when tracing is disabled.
        """
        if self._handle is not None:
            self._handle._span.args.update(args)

    def delta(self) -> dict:
        """wall seconds + cache counter deltas accumulated in this scope."""
        out = dict(wall_s=perf_counter() - self._t0)
        if self._snap is not None:
            out.update(self._cache.delta(self._snap))
        else:
            out.update(cache_hits=0, cache_misses=0,
                       plan_build_s=0.0, symbolic_s=0.0)
        return out

    def row(self, **fields) -> dict:
        """The shared per-iteration stats row, driver extras appended."""
        out = dict(iteration=self.iteration, **_ROW_DEFAULTS)
        out.update(self.delta())
        out.update(fields)
        missing = set(SHARED_ITER_KEYS) - out.keys()
        assert not missing, f"iteration row missing shared keys: {missing}"
        return out
