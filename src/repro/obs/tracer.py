"""Span-based tracer + metrics registry for the resident runtime.

The CHT-MPI paper demonstrates its load-balancing claims with per-process
execution timelines and work/communication statistics gathered by the
runtime itself; the original Chunks-and-Tasks programming-model paper makes
task/chunk accounting a first-class runtime service.  This module is that
service for the XLA-mesh reproduction:

* :class:`Tracer` records **nested spans** (phase -> iteration -> collective
  -> kernel dispatch / plan build / symbolic descent / rebalance migration)
  on one host timeline, each with a wall-clock interval, a category, free
  args, and — on leaf dispatch spans — a **per-worker cost attribution**
  vector (:attr:`Span.worker_costs`) measured from the executed plan.  An
  SPMD step's wall time is set by its slowest worker, so the exporters
  derive one *track per worker* whose busy interval inside each step is the
  worker's measured share of the step cost — the paper's utilization
  timeline, reproduced from runtime measurements.
* **Counters and gauges** are registered once on the tracer's metrics
  registry (``plan_hits`` / ``plan_misses`` / ``tasks_executed`` /
  ``recv_bytes`` / ``send_bytes`` / ``migrated_bytes`` /
  ``norm_fetch_bytes``, plus ``plans_verified`` / ``verify_violations``
  from the static verifier at plan-cache admission) and emitted uniformly:
  live as Chrome counter events, and at run end as the flat dict
  (:func:`run_metrics`) the driver stats dataclasses wrap.
* **Structured analysis events**: the plan verifier
  (:mod:`repro.analysis`) reports each violation as a
  ``plan_verify_violation`` instant in category ``"analysis"`` carrying
  the check id and task/round provenance — query them with
  :meth:`Tracer.instants_of`.
* :data:`NULL_TRACER` is the disabled tracer every un-instrumented call
  path sees: all methods are allocation-free no-ops, it is falsy, and it
  records nothing — tracing off costs a few attribute lookups per
  operation and cannot perturb numerics.

The tracer rides on the plan cache (``SymbolicCache.tracer``), which is
already threaded through every resident collective and driver — enable
tracing by constructing ``PlanCache(tracer=Tracer())`` or by passing
``tracer=`` to a driver, and read it back anywhere via :func:`tracer_of`.
"""

from __future__ import annotations

import time
from typing import Any

__all__ = [
    "Counter",
    "Gauge",
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "tracer_of",
    "run_metrics",
]


class Counter:
    """Monotonic counter registered once on a tracer's metrics registry."""

    __slots__ = ("name", "value", "_tracer")

    def __init__(self, name: str, tracer: "Tracer | None" = None):
        self.name = name
        self.value = 0.0
        self._tracer = tracer

    def add(self, v: float = 1.0) -> None:
        self.value += v
        tr = self._tracer
        if tr is not None and tr.enabled:
            tr._counter_events.append((tr._clock(), self.name, self.value))


class Gauge:
    """Last-value gauge registered once on a tracer's metrics registry."""

    __slots__ = ("name", "value", "_tracer")

    def __init__(self, name: str, tracer: "Tracer | None" = None):
        self.name = name
        self.value = 0.0
        self._tracer = tracer

    def set(self, v: float) -> None:
        self.value = float(v)
        tr = self._tracer
        if tr is not None and tr.enabled:
            tr._counter_events.append((tr._clock(), self.name, self.value))


class Span:
    """One recorded interval on the host timeline.

    ``parent`` is the index of the enclosing span in ``tracer.spans`` (or
    -1); ``worker_costs``, when set by the instrumentation, is a ``[P]``
    non-negative vector of measured per-worker cost shares of this span
    (executed tasks + exchange bytes in task-equivalent units) — the
    exporters turn it into per-worker busy intervals.
    """

    __slots__ = ("name", "cat", "t0", "t1", "parent", "args", "worker_costs")

    def __init__(self, name: str, cat: str, t0: float, parent: int, args: dict):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t0
        self.parent = parent
        self.args = args
        self.worker_costs = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _SpanHandle:
    """Context manager closing one span; yields the span for annotation."""

    __slots__ = ("_tracer", "_span", "_jax_scope")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span
        self._jax_scope = None

    def __enter__(self) -> Span:
        if self._tracer._jax_scopes:
            try:
                from jax.profiler import TraceAnnotation

                self._jax_scope = TraceAnnotation(self._span.name)
                self._jax_scope.__enter__()
            except Exception:  # jax absent or profiler unavailable
                self._jax_scope = None
        return self._span

    def __exit__(self, *exc) -> None:
        if self._jax_scope is not None:
            self._jax_scope.__exit__(*exc)
        tr = self._tracer
        self._span.t1 = tr._clock()
        tr._stack.pop()
        return None


class Tracer:
    """Records nested spans, instants, and registered counters/gauges.

    ``sync`` makes :meth:`sync` block on device values inside kernel-dispatch
    spans so span durations measure execution rather than async dispatch
    (numerics are untouched either way).  ``jax_scopes`` additionally opens a
    ``jax.profiler.TraceAnnotation`` named scope per span, so a concurrent
    ``jax.profiler.trace`` capture carries the same labels.
    """

    enabled = True

    def __init__(
        self,
        *,
        clock=time.perf_counter,
        sync: bool = True,
        jax_scopes: bool = False,
    ):
        self._clock = clock
        self._sync = sync
        self._jax_scopes = jax_scopes
        self.origin = clock()
        self.spans: list[Span] = []
        self.instants: list[tuple[str, str, float, int, dict]] = []
        self._stack: list[int] = []
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._counter_events: list[tuple[float, str, float]] = []

    def __bool__(self) -> bool:
        return True

    # -- spans ---------------------------------------------------------------
    def span(self, name: str, cat: str = "", **args: Any) -> _SpanHandle:
        """Open a nested span; use as ``with tracer.span(...) as sp``."""
        parent = self._stack[-1] if self._stack else -1
        sp = Span(name, cat, self._clock(), parent, args)
        self._stack.append(len(self.spans))
        self.spans.append(sp)
        return _SpanHandle(self, sp)

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        """Zero-duration marker attached to the current span."""
        parent = self._stack[-1] if self._stack else -1
        self.instants.append((name, cat, self._clock(), parent, args))

    def instants_of(self, name: str, cat: str | None = None) -> list[dict]:
        """The recorded args dicts of matching instants, in record order
        (e.g. the verifier's ``plan_verify_violation`` analysis events)."""
        return [args for (n, c, _, _, args) in self.instants
                if n == name and (cat is None or c == cat)]

    # -- metrics registry ----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, self)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, self)
        return g

    def metrics_flat(self) -> dict:
        """Flat dict of every registered counter/gauge plus span counts."""
        out: dict = {name: c.value for name, c in sorted(self._counters.items())}
        out.update({name: g.value for name, g in sorted(self._gauges.items())})
        out["spans_recorded"] = len(self.spans)
        return out

    # -- device sync ---------------------------------------------------------
    def sync(self, x: Any) -> Any:
        """Block on a device value so the enclosing span measures execution.

        No-op when the tracer was built with ``sync=False`` (and always on
        :data:`NULL_TRACER`), so tracing off never forces synchronization.
        """
        if self._sync:
            try:
                import jax

                jax.block_until_ready(x)
            except ImportError:
                pass
        return x


class _NullHandle:
    """Reusable no-op span context; also quacks like a Span for annotation."""

    __slots__ = ()
    worker_costs = None

    @property
    def args(self) -> dict:  # a fresh throwaway dict: mutations vanish
        return {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def __setattr__(self, name, value):  # annotations on a null span vanish
        pass


class _NullMetric:
    __slots__ = ()
    value = 0.0

    def add(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass


_NULL_HANDLE = _NullHandle()
_NULL_METRIC = _NullMetric()


class NullTracer:
    """The disabled tracer: falsy, allocation-free, records nothing."""

    enabled = False
    spans: tuple = ()
    instants: tuple = ()

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, cat: str = "", **args: Any) -> _NullHandle:
        return _NULL_HANDLE

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        pass

    def instants_of(self, name: str, cat: str | None = None) -> list:
        return []

    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def metrics_flat(self) -> dict:
        return {}

    def sync(self, x: Any) -> Any:
        return x


NULL_TRACER = NullTracer()


def tracer_of(cache) -> Tracer | NullTracer:
    """The tracer threaded through the runtime rides on the plan cache."""
    if cache is None:
        return NULL_TRACER
    tr = getattr(cache, "tracer", None)
    return tr if tr is not None else NULL_TRACER


def run_metrics(cache=None, tracer=None) -> dict:
    """The unified flat metrics dict the driver stats dataclasses wrap.

    Cache counters (hits / misses / hit_rate / build_s / symbolic_s /
    by_kind) merged with every counter and gauge registered on the tracer
    (tasks_executed, recv/send bytes, migrated bytes, norm-fetch bytes, span
    counts).  With tracing disabled this is exactly ``cache.stats()`` — the
    pre-tracer behaviour — so existing consumers keep working unchanged.
    """
    tr = tracer if tracer is not None else tracer_of(cache)
    out: dict = dict(cache.stats()) if cache is not None else {}
    out.update(tr.metrics_flat())
    return out
