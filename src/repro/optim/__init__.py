from .adamw import adamw_init, adamw_update, clip_by_global_norm, cosine_lr
from .compress import compress_grads, decompress_grads, error_feedback_update

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_lr",
    "compress_grads",
    "decompress_grads",
    "error_feedback_update",
]
