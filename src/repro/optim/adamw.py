"""AdamW with global-norm clipping and cosine schedule.

Optimizer state mirrors the param tree (same logical axes -> same sharding:
ZeRO-style for FSDP-sharded params comes for free).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm", "cosine_lr"]


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros), "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    grads,
    state,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1**cf
    bc2 = 1.0 - b2**cf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        p32 = p.astype(jnp.float32)
        newp = p32 - lr * (step + weight_decay * p32)
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": count}


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
