"""Int8 gradient compression with error feedback (cross-pod all-reduce aid).

At multi-pod scale the pod axis rides the slow inter-pod links; quantizing
gradients to int8 with per-tensor scale cuts that all-reduce volume 4x.
Error feedback accumulates the quantization residual locally and re-injects
it next step, preserving convergence (Karimireddy et al., 2019).

Usage inside train_step:
    q, scales = compress_grads(add_error(grads, err))
    grads_hat = decompress_grads(q, scales)       # what actually gets reduced
    err = error_feedback_update(grads_plus_err, grads_hat)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_grads", "decompress_grads", "error_feedback_update"]


def _q_one(g):
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads):
    flat, treedef = jax.tree.flatten(grads)
    qs, scales = zip(*[_q_one(g) for g in flat]) if flat else ((), ())
    return jax.tree.unflatten(treedef, list(qs)), jax.tree.unflatten(treedef, list(scales))


def decompress_grads(q, scales):
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def error_feedback_update(intended, transmitted):
    """New residual = what we wanted to send - what the wire carried."""
    return jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), intended, transmitted
    )
