from .fault_tolerance import StragglerDetector, run_with_retries, TrainLoop
from .elastic import reshard_state

__all__ = ["StragglerDetector", "run_with_retries", "TrainLoop", "reshard_state"]
