"""Elastic scaling: re-shard a checkpointed state onto a different mesh.

Checkpoints store unsharded arrays + the model's logical axes; placement is
purely a function of (mesh, rules).  Growing or shrinking the cluster is
therefore: restore -> device_put with the new mesh's NamedShardings.  The
dry-run proves alternative mesh shapes compile (launch/dryrun.py --mesh).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding.rules import MeshCtx, spec_tree

__all__ = ["reshard_state", "state_shardings"]


def state_shardings(ctx: MeshCtx, state_abstract, params_axes):
    """NamedSharding tree for a train state {params, opt{...}, step}.

    Optimizer slots mirroring the param tree (mu, nu, optional fp32 master)
    share the params' shardings; scalars replicate."""
    p_specs = spec_tree(ctx, state_abstract["params"], params_axes)
    mk = lambda spec: NamedSharding(ctx.mesh, spec)
    p_sh = jax.tree.map(mk, p_specs)
    opt = {}
    for k, v in state_abstract["opt"].items():
        opt[k] = jax.tree.map(mk, p_specs) if isinstance(v, dict) else mk(PartitionSpec())
    return {"params": p_sh, "opt": opt, "step": mk(PartitionSpec())}


def reshard_state(state, old_ctx: MeshCtx | None, new_ctx: MeshCtx, params_axes):
    """Move a state pytree onto ``new_ctx.mesh`` (elastic grow/shrink)."""
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    shardings = state_shardings(new_ctx, abstract, params_axes)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
