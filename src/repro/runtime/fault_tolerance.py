"""Fault tolerance & straggler mitigation for the training runtime.

At 1000+ nodes, failures are the steady state.  Mechanisms here:

* **step retry** (:func:`run_with_retries`): transient device/runtime errors
  (preempted host, flaky link) retry the step; the stateless data pipeline
  makes the retried step deterministic.
* **checkpoint/restart** (:class:`TrainLoop`): periodic async checkpoints +
  resume from the latest manifest; a restarted run continues bitwise
  identically (tested in tests/test_fault_tolerance.py).
* **straggler detection** (:class:`StragglerDetector`): step-time EWMA with
  a multiplicative threshold.  On real pods the response is re-scheduling the
  slow host's shard (the CHT work-stealing analogue at step granularity);
  here we surface the signal and count events.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.obs.timing import Stopwatch

__all__ = ["run_with_retries", "StragglerDetector", "TrainLoop"]


def run_with_retries(fn: Callable, *args, max_retries: int = 3, on_failure=None):
    """Run fn; retry on transient failure (deterministic step => safe)."""
    for attempt in range(max_retries + 1):
        try:
            return fn(*args)
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:  # transient class
            if attempt == max_retries:
                raise
            if on_failure is not None:
                on_failure(attempt, e)
    raise AssertionError("unreachable")


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor: flags steps slower than ``threshold`` x EWMA."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float | None = None
    events: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.events += 1
        # don't poison the EWMA with the straggler sample
        self.ewma = self.ewma if slow else (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class TrainLoop:
    """Checkpointed, restartable, straggler-aware outer loop."""

    def __init__(
        self,
        train_step,
        pipeline,
        ckpt_dir: str,
        *,
        ckpt_every: int = 50,
        keep: int = 3,
        max_retries: int = 3,
    ):
        self.train_step = train_step
        self.pipeline = pipeline
        self.manager = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.straggler = StragglerDetector()
        self.retries = 0

    def resume_or_init(self, state_like_or_init):
        from repro.checkpoint import latest_step

        step = latest_step(self.manager.directory)
        if step is not None:
            state, step = self.manager.restore_latest(state_like_or_init)
            return jax.tree.map(jax.numpy.asarray, state), step
        return state_like_or_init, 0

    def run(self, state, start_step: int, num_steps: int, log_every: int = 10, log=print):
        metrics_hist = []
        for step in range(start_step, start_step + num_steps):
            batch = self.pipeline.global_batch(step)
            sw = Stopwatch()

            def attempt():
                return self.train_step(state, batch)

            def on_failure(k, e):
                self.retries += 1
                log(f"[retry {k}] step {step}: {e}")

            state, metrics = run_with_retries(
                attempt, max_retries=self.max_retries, on_failure=on_failure
            )
            jax.block_until_ready(metrics["loss"])
            dt = sw.elapsed()
            if self.straggler.observe(dt):
                log(f"[straggler] step {step} took {dt:.3f}s (ewma {self.straggler.ewma:.3f}s)")
            metrics_hist.append({k: float(v) for k, v in metrics.items()})
            if step % log_every == 0:
                log(f"step {step} loss {float(metrics['loss']):.4f} ({dt*1e3:.0f} ms)")
            if (step + 1) % self.ckpt_every == 0:
                self.manager.save(step + 1, state)
        self.manager.save(start_step + num_steps, state)
        self.manager.wait()
        return state, metrics_hist
