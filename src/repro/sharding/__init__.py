from .rules import MeshCtx, logical_to_spec, spec_tree, constrain

__all__ = ["MeshCtx", "logical_to_spec", "spec_tree", "constrain"]
