"""Logical-axis -> mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

Every parameter is annotated at init time with logical axis names per dim
(e.g. ("embed", "heads")).  A rule table maps logical names to mesh axes;
:func:`logical_to_spec` applies it with a **divisibility fallback**: if a
dim's size does not divide by the mapped mesh axes, that dim is replicated
instead (e.g. qwen2-0.5b's 14 heads on a 16-way model axis).  This keeps one
rule table valid across all 10 architectures.

Default rule table (mesh axes: pod, data, model):
  embed   -> data          (FSDP: params sharded over the data axis)
  heads/kv_heads/mlp/vocab/expert/rnn -> model  (TP / EP)
  layers  -> None          (stacked scan axis)
Batch is data-parallel over (pod, data); `long_500k` overrides activations
to sequence-parallel over data (see launch/specs.py).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["MeshCtx", "logical_to_spec", "spec_tree", "constrain", "DEFAULT_RULES"]

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "embed": ("data",),  # FSDP
    "embed_e": ("data",),  # expert-weight d_model dim: FSDP even at inference
    # (MoE param volume never fits TP-only; dense params do)
    "moe_ff": (),  # expert d_ff dim; decode overrides to ("data",) so expert
    # weights stay fully resident (tokens are dispatched instead)
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "rnn": ("model",),
    "head_dim": ("model",),  # KV-cache fallback when kv_heads can't shard
    "state": (),
    "layers": (),
    "batch": ("pod", "data"),
    "seq": (),
    "seq_sp": ("data",),  # sequence parallelism (long-context override)
}


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Mesh + axis-name context threaded through model apply functions."""

    mesh: Mesh
    rules: tuple[tuple[str, tuple[str, ...]], ...] = tuple(
        (k, v) for k, v in DEFAULT_RULES.items()
    )

    @property
    def rule_map(self) -> dict[str, tuple[str, ...]]:
        return dict(self.rules)

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def present(self, axes: tuple[str, ...]) -> tuple[str, ...]:
        return tuple(a for a in axes if a in self.mesh.axis_names)

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return self.present(("pod", "data"))

    @property
    def tp_axis(self) -> str | None:
        return "model" if "model" in self.mesh.axis_names else None

    def tp_size(self) -> int:
        return self.axis_sizes.get("model", 1)

    def with_rules(self, **overrides) -> "MeshCtx":
        r = self.rule_map
        r.update(overrides)
        return dataclasses.replace(self, rules=tuple(r.items()))


def logical_to_spec(
    ctx: MeshCtx, shape: tuple[int, ...], axes: tuple[str | None, ...]
) -> PartitionSpec:
    """Map logical axes to a PartitionSpec, replicating non-divisible dims."""
    assert len(shape) == len(axes), (shape, axes)
    rule_map = ctx.rule_map
    sizes = ctx.axis_sizes
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        if name is None:
            entries.append(None)
            continue
        mesh_axes = tuple(
            a for a in rule_map.get(name, ()) if a in sizes and a not in used
        )
        total = int(np.prod([sizes[a] for a in mesh_axes])) if mesh_axes else 1
        if mesh_axes and dim % total == 0:
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def spec_tree(ctx: MeshCtx, params, axes_tree):
    """PartitionSpec tree for a params tree + parallel logical-axes tree."""
    leaves, treedef = jax.tree.flatten(params)
    ax_leaves = treedef.flatten_up_to(axes_tree)

    def one(p, ax):
        shape = p.shape if hasattr(p, "shape") else np.shape(p)
        return logical_to_spec(ctx, tuple(shape), tuple(ax))

    return jax.tree.unflatten(treedef, [one(p, ax) for p, ax in zip(leaves, ax_leaves)])


def constrain(ctx: MeshCtx | None, x, *entries):
    """with_sharding_constraint with divisibility fallback; no-op without ctx."""
    if ctx is None:
        return x
    spec = logical_to_spec(ctx, x.shape, tuple(entries))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
