"""Minimal stand-in for the `hypothesis` API used by this test suite.

Installed into ``sys.modules`` by ``conftest.py`` ONLY when the real
`hypothesis` package is unavailable (it is an optional dev dependency; see
``pyproject.toml``'s ``dev`` extra).  It implements the narrow surface the
tests use — ``given``, ``settings`` and the ``integers`` / ``floats`` /
``sampled_from`` / ``tuples`` / ``lists`` strategies — as deterministic
seeded random sampling with one extra lower-boundary probe per test.  It
does no shrinking; with real hypothesis installed it is never imported.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample, boundary):
        self._sample = sample  # rng -> value
        self._boundary = boundary  # () -> lower-edge value

    def sample(self, rng):
        return self._sample(rng)

    def boundary(self):
        return self._boundary()


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        lambda: int(min_value),
    )


def floats(min_value, max_value, **_kw):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        lambda: float(min_value),
    )


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        lambda rng: elements[int(rng.integers(len(elements)))],
        lambda: elements[0],
    )


def tuples(*strategies):
    return _Strategy(
        lambda rng: tuple(s.sample(rng) for s in strategies),
        lambda: tuple(s.boundary() for s in strategies),
    )


def lists(elements, min_size=0, max_size=10, **_kw):
    return _Strategy(
        lambda rng: [
            elements.sample(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))
        ],
        lambda: [elements.boundary() for _ in range(min_size)],
    )


class settings:
    """Decorator recording max_examples; other kwargs are accepted, ignored."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_max_examples = self.max_examples
        return fn


def given(*arg_strats, **kw_strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            nex = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for ex in range(nex):
                if ex == 0:  # probe the lower boundary once
                    pos = [s.boundary() for s in arg_strats]
                    kws = {k: s.boundary() for k, s in kw_strats.items()}
                else:
                    pos = [s.sample(rng) for s in arg_strats]
                    kws = {k: s.sample(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *pos, **{**kwargs, **kws})
                except Exception:
                    print(
                        f"Falsifying example (fallback hypothesis shim): "
                        f"args={pos} kwargs={kws}",
                        file=sys.stderr,
                    )
                    raise

        # propagate settings applied outside @given onto the wrapper
        if hasattr(fn, "_fallback_max_examples"):
            wrapper._fallback_max_examples = fn._fallback_max_examples
        # hide the strategy-supplied parameters from pytest's fixture
        # resolution (it follows __wrapped__ to the original signature)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


def install() -> None:
    """Register this module as `hypothesis` if the real one is missing."""
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "tuples", "lists"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
