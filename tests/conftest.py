import os
import sys

# src layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis (dev extra); fall back to a seeded random
# sampler when it is not installed so the suite still collects and runs.
sys.path.insert(0, os.path.dirname(__file__))
import _hypothesis_fallback  # noqa: E402

_hypothesis_fallback.install()

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dry-run sets 512 itself, in subprocesses).
