"""Shared test utilities."""

import numpy as np

from repro.core import BSMatrix


def banded_matrix(n: int, halfwidth: int, bs: int, seed: int = 0) -> BSMatrix:
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - halfwidth), min(n, i + halfwidth + 1)
        a[i, lo:hi] = rng.standard_normal(hi - lo)
    return BSMatrix.from_dense(a, bs)


def random_block_matrix(
    n: int, bs: int, density: float, seed: int = 0
) -> BSMatrix:
    """Random block sparsity pattern with given block density."""
    rng = np.random.default_rng(seed)
    nb = -(-n // bs)
    mask = rng.random((nb, nb)) < density
    a = np.zeros((nb * bs, nb * bs), dtype=np.float32)
    for i, j in zip(*np.nonzero(mask)):
        a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = rng.standard_normal((bs, bs))
    return BSMatrix.from_dense(a[:n, :n], bs)


def spd_banded(n: int, halfwidth: int, bs: int, seed: int = 0) -> BSMatrix:
    m = banded_matrix(n, halfwidth, bs, seed)
    d = m.to_dense()
    return BSMatrix.from_dense(d @ d.T + n * np.eye(n, dtype=np.float32), bs)
