import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BSMatrix,
    add,
    add_scaled_identity,
    identity,
    truncate,
    truncate_elementwise,
    truncate_hierarchical,
)

from helpers import banded_matrix, random_block_matrix


@given(
    n=st.integers(8, 60),
    bs=st.sampled_from([4, 8]),
    alpha=st.floats(-3, 3),
    beta=st.floats(-3, 3),
    seed=st.integers(0, 5),
)
@settings(max_examples=20, deadline=None)
def test_add(n, bs, alpha, beta, seed):
    a = random_block_matrix(n, bs, 0.4, seed)
    b = random_block_matrix(n, bs, 0.4, seed + 9)
    c = add(a, b, alpha, beta)
    assert np.allclose(
        c.to_dense(), alpha * a.to_dense() + beta * b.to_dense(), atol=1e-4
    )


def test_identity_partial_block():
    i = identity(10, 4)
    assert np.allclose(i.to_dense(), np.eye(10))


def test_add_scaled_identity():
    a = banded_matrix(30, 3, 8)
    c = add_scaled_identity(a, -2.5)
    assert np.allclose(c.to_dense(), a.to_dense() - 2.5 * np.eye(30), atol=1e-5)


@given(tau=st.floats(0.0, 100.0), seed=st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_truncate_error_control(tau, seed):
    a = random_block_matrix(48, 8, 0.6, seed)
    t = truncate(a, tau)
    err = np.linalg.norm(a.to_dense() - t.to_dense())
    assert err <= tau + 1e-5
    assert t.nnzb <= a.nnzb


def test_truncate_greedy_maximal():
    # dropping any additional block must exceed tau
    a = random_block_matrix(32, 8, 0.8, 3)
    tau = 0.5 * a.frobenius_norm()
    t = truncate(a, tau)
    if t.nnzb:
        dropped_sq = a.frobenius_norm() ** 2 - t.frobenius_norm() ** 2
        smallest_kept = t.block_norms().min()
        assert np.sqrt(max(dropped_sq, 0) + smallest_kept**2) > tau - 1e-4


def test_truncate_elementwise():
    a = banded_matrix(40, 4, 8)
    t = truncate_elementwise(a, 0.5)
    d = t.to_dense()
    assert ((np.abs(d) > 0.5) | (d == 0)).all()


@given(tau=st.floats(0.0, 100.0), seed=st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_truncate_hierarchical_error_control(tau, seed):
    a = random_block_matrix(48, 8, 0.6, seed)
    t = truncate_hierarchical(a, tau)
    err = np.linalg.norm(a.to_dense() - t.to_dense())
    assert err <= tau + 1e-5
    assert t.nnzb <= a.nnzb


def test_truncate_hierarchical_drops_whole_subtrees():
    # a matrix with one tiny quadrant: the whole subtree goes in one decision
    rng = np.random.default_rng(0)
    n, bs = 64, 8
    d = rng.standard_normal((n, n)).astype(np.float32)
    d[n // 2 :, n // 2 :] *= 1e-6  # bottom-right quadrant is negligible
    a = BSMatrix.from_dense(d, bs)
    tau = 1e-3
    t = truncate_hierarchical(a, tau)
    # the negligible quadrant's blocks are gone, the rest survives
    gone = (t.coords[:, 0] >= n // (2 * bs)) & (t.coords[:, 1] >= n // (2 * bs))
    assert not gone.any()
    assert np.linalg.norm(a.to_dense() - t.to_dense()) <= tau + 1e-6


def test_hierarchical_drop_mask_skips_dropped_subtrees():
    # the shared descent (core + distributed truncation) must never visit
    # nodes under a dropped subtree: with one negligible quadrant the visit
    # count stays well below the total node count
    from repro.core.quadtree import hierarchical_drop_mask

    rng = np.random.default_rng(1)
    n, bs = 64, 8
    d = rng.standard_normal((n, n)).astype(np.float32)
    d[n // 2 :, n // 2 :] *= 1e-6
    a = BSMatrix.from_dense(d, bs)
    qt = a.quadtree_index()
    keep, visited = hierarchical_drop_mask(qt, 1e-3)
    assert 0 < visited < qt.num_nodes()
    # the mask agrees with the public truncation entry point
    t = truncate_hierarchical(a, 1e-3)
    assert int(keep.sum()) == t.nnzb
    # no drops: every level's frontier is visited in full
    keep_all, visited_all = hierarchical_drop_mask(qt, 0.0)
    assert keep_all.all() and visited_all == qt.num_nodes()


def test_truncate_hierarchical_edge_cases():
    z = BSMatrix.zeros((32, 32), 8)
    assert truncate_hierarchical(z, 1.0) is z
    a = random_block_matrix(32, 8, 0.5, 4)
    assert truncate_hierarchical(a, 0.0) is a  # tau=0: no-op
    # all-dropped: budget above the full norm empties the matrix
    t = truncate_hierarchical(a, a.frobenius_norm() * 2)
    assert t.nnzb == 0 and np.allclose(t.to_dense(), 0.0)


@pytest.mark.parametrize("n,bs", [(40, 8), (56, 16)])
def test_truncate_elementwise_non_power_of_two_grid(n, bs):
    a = random_block_matrix(n, bs, 0.6, seed=n)
    eps = float(np.median(np.abs(np.asarray(a.data)))) if a.nnzb else 0.1
    t = truncate_elementwise(a, eps)
    d, ref = t.to_dense(), a.to_dense()
    assert np.array_equal(d != 0, np.abs(ref) > eps)
    assert np.allclose(d[d != 0], ref[np.abs(ref) > eps])


def test_truncate_elementwise_all_dropped_and_empty():
    z = BSMatrix.zeros((24, 24), 8)
    assert truncate_elementwise(z, 0.5) is z
    a = random_block_matrix(24, 8, 0.5, 7)
    t = truncate_elementwise(a, float(np.abs(np.asarray(a.data)).max()) + 1.0)
    assert t.nnzb == 0
    assert np.allclose(t.to_dense(), 0.0)
