"""Static-analysis layer: plan verifier, mutation suite, lint, cache policy.

The mutation suite is the verifier's proof of detection: every seeded
corruption must be caught by its named check, with provenance, while the
clean plan it was derived from verifies empty.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import banded_matrix, random_block_matrix

from repro.analysis import PlanError, Violation
from repro.analysis.lint import (
    Finding,
    fix_perf_counter_source,
    lint_paths,
    load_baseline,
)
from repro.analysis.mutate import CORRUPTIONS, NotApplicable, clone_plan
from repro.analysis.verify import (
    verify_add_plan,
    verify_compact_plan,
    verify_payload,
    verify_spgemm_plan,
    verify_task_mask,
    verify_value,
)
from repro.core.cache import SymbolicCache
from repro.core.schedule import (
    make_spgemm_plan,
    plan_byte_provenance,
    plan_worker_bytes,
)

BS = 16


def _plan(matrix=None, nparts=4, exchange="p2p", **kw):
    m = matrix if matrix is not None else random_block_matrix(256, BS, 0.25, seed=3)
    return make_spgemm_plan(m.coords, m.coords, nparts, BS,
                            exchange=exchange, **kw)


# ---------------------------------------------------------------------------
# clean plans verify; seeded corruptions are caught
# ---------------------------------------------------------------------------


def test_clean_plan_verifies():
    plan = _plan()
    assert plan.tasks.num_tasks > 0
    assert verify_spgemm_plan(plan) == []


@pytest.mark.parametrize("name", sorted(CORRUPTIONS))
def test_mutation_caught_with_provenance(name):
    plan = _plan()
    fn, expected = CORRUPTIONS[name]
    bad, kwargs = fn(plan)
    report = verify_spgemm_plan(bad, **kwargs)
    checks = {v.check for v in report}
    assert expected in checks, (name, sorted(checks))
    caught = [v for v in report if v.check == expected]
    assert all(isinstance(v, Violation) and v.provenance for v in caught)
    assert all(v.message for v in caught)
    # the corruption never leaked into the original plan
    assert verify_spgemm_plan(plan) == []


def test_mutation_suite_covers_required_corruptions():
    # the acceptance list from the issue, each a distinct corruption
    assert len(CORRUPTIONS) >= 8
    required = {"send-conflict", "src-off-oob", "round-permutation",
                "use-before-receive", "c-slot-race", "owner-fingerprint",
                "mask-redirect", "capacity-mismatch"}
    assert required <= {exp for _, exp in CORRUPTIONS.values()}


# ---------------------------------------------------------------------------
# edge cases: each clean and mutated
# ---------------------------------------------------------------------------


def test_edge_empty_task_list():
    # A is diagonal, B is empty: the symbolic phase yields zero tasks
    nb = 8
    a_coords = np.stack([np.arange(nb), np.arange(nb)], axis=1)
    plan = make_spgemm_plan(a_coords, np.zeros((0, 2), np.int64), 4, BS)
    assert plan.tasks.num_tasks == 0
    assert verify_spgemm_plan(plan) == []
    bad = clone_plan(plan)
    bad.task_c[0, 0] = 0  # padded slot aimed at a live row, not the trash
    assert {"mask-redirect"} <= {v.check for v in verify_spgemm_plan(bad)}


def test_edge_single_worker_zero_rounds():
    plan = _plan(nparts=1)
    assert plan.a_offsets == () and plan.b_offsets == ()
    assert verify_spgemm_plan(plan) == []
    fn, expected = CORRUPTIONS["accumulation_order"]
    bad, kwargs = fn(plan)
    assert expected in {v.check for v in verify_spgemm_plan(bad, **kwargs)}
    # exchange corruptions are structurally inapplicable here
    with pytest.raises(NotApplicable):
        CORRUPTIONS["send_conflict"][0](plan)


def test_edge_more_parts_than_blocks():
    m = banded_matrix(64, 2, BS)  # 4x4 block rows, few blocks
    plan = _plan(matrix=m, nparts=8)
    assert plan.a_owner.shape[0] < 8 * 2  # some devices own nothing
    assert verify_spgemm_plan(plan) == []
    fn, expected = CORRUPTIONS["owner_fingerprint"]
    bad, kwargs = fn(plan)
    assert expected in {v.check for v in verify_spgemm_plan(bad, **kwargs)}


def test_edge_non_power_of_two_blocks():
    m = random_block_matrix(120, 24, 0.4, seed=5)  # 5x5 blocks of 24
    plan = make_spgemm_plan(m.coords, m.coords, 3, 24)
    assert verify_spgemm_plan(plan) == []
    fn, expected = CORRUPTIONS["capacity_mismatch"]
    bad, kwargs = fn(plan)
    assert expected in {v.check for v in verify_spgemm_plan(bad, **kwargs)}


def test_edge_fully_masked_delta_all_rounds_dropped():
    from repro.core.distributed import _exchange_keep_masks

    plan = _plan()
    nrounds = len(plan.a_offsets) + len(plan.b_offsets)
    assert nrounds > 0
    off = np.zeros(plan.tasks.num_tasks, bool)
    _, _, live_a, live_b, stats = _exchange_keep_masks(plan, off)
    assert live_a == () and live_b == ()
    assert stats["dropped_rounds"] == nrounds and stats["kept_blocks"] == 0
    assert verify_task_mask(plan, off) == []  # no kept task starves
    # a partial mask over a corrupted span memo is caught
    on = np.ones(plan.tasks.num_tasks, bool)
    assert verify_task_mask(plan, on) == []
    from repro.core.distributed import _send_task_spans

    bad = clone_plan(plan)
    maps = {k: (s.copy(), c.copy()) for k, (s, c) in
            _send_task_spans(bad).items()}
    (name, d) = next(iter(maps))
    starts, cat = maps[(name, d)]
    maps[(name, d)] = (np.zeros_like(starts), cat)  # every span empty
    object.__setattr__(bad, "_send_task_spans", maps)
    assert {"exchange-starvation"} <= {v.check for v in verify_task_mask(bad, on)}
    assert {"exchange-starvation"} <= {v.check for v in verify_spgemm_plan(bad)}


# ---------------------------------------------------------------------------
# planner guards survive -O (typed PlanError, not assert)
# ---------------------------------------------------------------------------


def test_mismatched_owner_shapes_raise_plan_error():
    m = random_block_matrix(128, BS, 0.3)
    with pytest.raises(PlanError, match="owner maps do not match"):
        make_spgemm_plan(m.coords, m.coords, 4, BS,
                         a_owner=np.zeros(m.coords.shape[0] + 1, np.int32))
    with pytest.raises(PlanError, match="outside the mesh"):
        make_spgemm_plan(m.coords, m.coords, 4, BS,
                         b_owner=np.full(m.coords.shape[0], 7, np.int32))


# ---------------------------------------------------------------------------
# cache admission policy
# ---------------------------------------------------------------------------


def test_cache_rejects_corrupt_plan_and_traces_violations():
    from repro.obs.tracer import Tracer

    plan = _plan()
    bad, _ = CORRUPTIONS["send_conflict"][0](plan)
    tr = Tracer(sync=False)
    cache = SymbolicCache(tracer=tr)
    with pytest.raises(PlanError) as exc:
        cache.get_or_build(("spgemm", "k1"), lambda: (bad, None))
    assert exc.value.violations and exc.value.violations[0].provenance
    assert ("spgemm", "k1") not in cache  # bad plans are never admitted
    events = tr.instants_of("plan_verify_violation", "analysis")
    assert events and events[0]["check"] == "send-conflict"
    assert cache.verify_violations >= 1
    assert tr.counter("verify_violations").value >= 1


def test_cached_once_pays_nothing_on_hits():
    plan = _plan()
    cache = SymbolicCache()  # default verify="cached-once"
    cache.get_or_build(("spgemm", "k"), lambda: (plan, None))
    assert cache.plans_verified == 1 and cache.verify_s > 0.0
    verified, spent = cache.plans_verified, cache.verify_s
    for _ in range(5):  # zero-miss replay: no verification work at all
        cache.get_or_build(("spgemm", "k"), lambda: (plan, None))
    assert cache.hits == 5
    assert cache.plans_verified == verified
    assert cache.verify_s == spent  # exact: the hook never ran

    always = SymbolicCache(verify="always")
    always.get_or_build(("spgemm", "k"), lambda: (plan, None))
    always.get_or_build(("spgemm", "k"), lambda: (plan, None))
    assert always.plans_verified == 2  # re-proved on the hit too

    off = SymbolicCache(verify="off")
    off.get_or_build(("spgemm", "k"), lambda: (plan, None))
    assert off.plans_verified == 0 and off.verify_s == 0.0

    with pytest.raises(ValueError):
        SymbolicCache(verify="sometimes")


def test_unverifiable_values_pass_through():
    cache = SymbolicCache()
    assert cache.get_or_build(("trace", "k"), lambda: 42.0) == 42.0
    assert cache.plans_verified == 0  # nothing verifiable: no counter tick
    assert verify_value(("trace", "k"), 42.0) is None


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------


def test_lint_repo_clean():
    findings, waived = lint_paths()
    assert findings == [], "\n".join(str(f) for f in findings)
    # the baseline waives exactly the tracer's default clock
    assert {f.key for f in waived} <= load_baseline()
    assert any(f.key == "obs/tracer.py::perf-counter" for f in waived)


def test_lint_rules_fire(tmp_path):
    bad = tmp_path / "offender.py"
    bad.write_text(textwrap.dedent("""
        import time
        from time import perf_counter

        def slow():
            return time.perf_counter()

        class Exe:
            def _build_program(self):
                import numpy as np
                x = np.asarray(self.dev)
                x.block_until_ready()
                return jax.device_get(x)

        def _mapped_body(store):
            return np.asarray(store)

        def key(a, b, mesh, precision):
            return ("spamm-delta", mesh_key(mesh), str(a.dtype))
    """))
    findings, _ = lint_paths([bad], baseline=set())
    rules = sorted({f.rule for f in findings})
    assert rules == ["host-sync", "perf-counter", "plan-key-fields"]
    sync = [f for f in findings if f.rule == "host-sync"]
    assert len(sync) == 4  # asarray + block_until_ready + device_get + mapped
    assert all(isinstance(f, Finding) and f.line > 0 for f in findings)
    # the baseline waives by path::rule key
    waiveall = {f.key for f in findings}
    clean, waived = lint_paths([bad], baseline=waiveall)
    assert clean == [] and len(waived) == len(findings)


def test_lint_allows_clean_key_and_timing_home(tmp_path):
    home = tmp_path / "obs"
    home.mkdir()
    (home / "timing.py").write_text("from time import perf_counter\n")
    good = tmp_path / "good.py"
    good.write_text(textwrap.dedent("""
        def key(a, b, mesh, precision):
            return ("spamm", mesh_key(mesh), str(a.dtype), str(b.dtype),
                    precision.key())

        def host_key(a, b):
            return ("spgemm", a.structure_key, b.structure_key)
    """))
    findings, _ = lint_paths([tmp_path], baseline=set())
    assert findings == []


# ---------------------------------------------------------------------------
# CLI + verification on real executables over a multi-device mesh
# ---------------------------------------------------------------------------


def test_cli_selftest_clean():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--selftest"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "analysis: clean" in proc.stdout


_MESH_SCRIPT = """
import json
import numpy as np
from repro.core import BSMatrix
from repro.core.distributed import make_worker_mesh
from repro.dist import PlanCache, scatter
from repro.dist.multiply import dist_multiply
from repro.dist.collectives import dist_transpose
from repro.dist.matrix import resident_block_norms

rng = np.random.default_rng(0)
nb, bs = 12, 16
mask = (np.abs(np.arange(nb)[:, None] - np.arange(nb)[None]) <= 2)
a = np.zeros((nb * bs, nb * bs), np.float32)
for i, j in zip(*np.nonzero(mask)):
    a[i*bs:(i+1)*bs, j*bs:(j+1)*bs] = rng.standard_normal((bs, bs))
A = BSMatrix.from_dense(a, bs)
mesh = make_worker_mesh(4)
cache = PlanCache(verify="always")
dA = scatter(A, mesh)
c1 = dist_multiply(dA, dA, cache=cache)
c2 = dist_multiply(dA, dA, cache=cache)  # hit path re-verifies
t = dist_transpose(dA, cache=cache)
norms = resident_block_norms(dA, cache=cache)
st = cache.stats()
print("RESULT " + json.dumps(dict(
    verified=st["plans_verified"], violations=st["verify_violations"],
    verify_s=st["verify_s"], hits=st["hits"])))
"""


def test_verify_always_on_real_mesh_executables():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # multiply plan (miss + re-verified hit), transpose, norm table all proved
    assert out["verified"] >= 4
    assert out["violations"] == 0
    assert out["verify_s"] > 0.0
    assert out["hits"] >= 1

# ---------------------------------------------------------------------------
# property-based plan fuzzing: random structures x random owner pins
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    nparts=st.sampled_from([1, 2, 3, 4, 8]),
    density=st.floats(0.05, 0.6),
    pin=st.sampled_from(["morton", "skew", "random"]),
    exchange=st.sampled_from(["p2p", "allgather"]),
)
def test_fuzz_pinned_plans_verify_and_ledger_matches_worker_bytes(
        seed, nparts, density, pin, exchange):
    m = random_block_matrix(128, BS, density, seed=seed)
    nb = m.coords.shape[0]
    if pin == "skew":
        owner = np.zeros(nb, np.int32)
    elif pin == "random":
        owner = np.random.default_rng(seed).integers(
            0, nparts, nb).astype(np.int32)
    else:
        owner = None
    plan = make_spgemm_plan(m.coords, m.coords, nparts, BS,
                            exchange=exchange, a_owner=owner, b_owner=owner)
    assert verify_spgemm_plan(plan) == []
    # the ledger's per-task byte decomposition conserves and sums to the
    # load balancer's plan_worker_bytes totals exactly
    prov = plan_byte_provenance(plan)
    assert np.array_equal(prov["local"] + prov["shipped"], prov["referenced"])
    recv, send, _ = plan_worker_bytes(plan)
    assert np.array_equal(prov["wire_recv"], recv)
    assert np.array_equal(prov["wire_send"], send)
    if exchange == "p2p":
        assert np.array_equal(prov["shipped"], recv)
    assert prov["task_local"].shape == (nparts, plan.task_gidx.shape[1])
    assert np.array_equal(prov["task_local"].sum(axis=1), prov["local_tasks"])


# ---------------------------------------------------------------------------
# add / compact verifiers
# ---------------------------------------------------------------------------


def _add_payload():
    """A real AddExecutable's host-side plan copy (single-device mesh)."""
    from repro.core import BSMatrix
    from repro.core.distributed import make_worker_mesh
    from repro.dist import scatter
    from repro.dist.collectives import AddExecutable

    rng = np.random.default_rng(0)
    n, bs = 32, 8
    da = np.zeros((n, n), np.float32)
    da[:16, :16] = rng.standard_normal((16, 16))
    db = np.zeros((n, n), np.float32)
    db[8:24, 8:24] = rng.standard_normal((16, 16))
    mesh = make_worker_mesh(1)
    exe = AddExecutable(scatter(BSMatrix.from_dense(da, bs), mesh),
                        scatter(BSMatrix.from_dense(db, bs), mesh))
    return exe._verify_plan


def test_add_plan_clean_and_dispatched():
    payload = _add_payload()
    assert payload["kind"] == "add"
    assert verify_add_plan(payload) == []
    assert verify_payload(payload) == []  # kind-dispatch reaches it


def test_add_plan_catches_union_and_gather_corruption():
    payload = _add_payload()
    live = np.nonzero(payload["from_a"] >= 0)[0]
    assert live.size >= 2

    # duplicate a source: one A block dropped, another double-counted
    bad = dict(payload)
    bad["from_a"] = payload["from_a"].copy()
    bad["from_a"][live[1]] = bad["from_a"][live[0]]
    assert "add-union" in {v.check for v in verify_add_plan(bad)}

    # zero the gather weight of a live operand: contribution silently lost
    bad = dict(payload)
    bad["val_a"] = payload["val_a"].copy()
    p, slot = np.argwhere(bad["val_a"] == 1.0)[0]
    bad["val_a"][p, slot] = 0.0
    assert "operand-mismatch" in {v.check for v in verify_add_plan(bad)}

    # weight on a padding slot: garbage accumulated into a live block
    bad = dict(payload)
    bad["val_b"] = payload["val_b"].copy()
    pad = np.argwhere(payload["val_b"] == 0.0)
    if pad.size:
        bad["val_b"][pad[0][0], pad[0][1]] = 1.0
        assert "mask-redirect" in {v.check for v in verify_add_plan(bad)}


def _compact_payload():
    a_owner = np.array([0, 1, 0, 1], np.int32)
    a_slot = np.array([0, 0, 1, 1], np.int32)
    kept = np.array([0, 3], np.int64)
    return dict(
        kind="compact", label="truncate", nparts=2,
        a_owner=a_owner, a_slot=a_slot, a_cap=2, kept=kept,
        new_owner=a_owner[kept], new_slot=np.array([0, 0], np.int32),
        new_cap=1,
        gidx=np.array([[0], [1]], np.int32),
        gval=np.ones((2, 1), np.float32),
    )


def test_compact_plan_clean_and_dispatched():
    payload = _compact_payload()
    assert verify_compact_plan(payload) == []
    assert verify_payload(payload) == []


def test_compact_plan_catches_corruption():
    # a kept block changing owners: compaction must be communication-free
    bad = _compact_payload()
    bad["new_owner"] = np.array([1, 0], np.int32)
    bad["new_slot"] = np.array([0, 0], np.int32)
    assert "owner-fingerprint" in {v.check for v in verify_compact_plan(bad)}

    # gather aimed at the wrong source slot
    bad = _compact_payload()
    bad["gidx"] = np.array([[1], [1]], np.int32)
    assert "operand-mismatch" in {v.check for v in verify_compact_plan(bad)}

    # kept index outside the block stack
    bad = _compact_payload()
    bad["kept"] = np.array([0, 9], np.int64)
    assert "owner-map" in {v.check for v in verify_compact_plan(bad)}


# ---------------------------------------------------------------------------
# lint --fix: mechanical perf-counter rewrites, idempotent
# ---------------------------------------------------------------------------

_FIXABLE = textwrap.dedent("""\
    import time
    from time import perf_counter

    def work(busy):
        t0 = perf_counter()
        now = time.perf_counter()
        busy(now)
        dt = time.perf_counter() - t0
        return dt
""")


def test_lint_fix_rewrites_and_is_idempotent(tmp_path):
    import ast

    fixed, n = fix_perf_counter_source(_FIXABLE)
    assert n > 0
    ast.parse(fixed)  # still valid python
    # paired names become stopwatches, unpaired reads become wall clock
    assert "t0 = Stopwatch()" in fixed
    assert "dt = t0.elapsed()" in fixed
    assert "now = wall_clock()" in fixed
    assert "perf_counter" not in fixed
    assert "from repro.obs.timing import Stopwatch, wall_clock" in fixed
    # idempotent: a second pass finds nothing to do
    again, n2 = fix_perf_counter_source(fixed)
    assert n2 == 0 and again == fixed
    # and the fixed module lints clean of the perf-counter rule
    mod = tmp_path / "mod.py"
    mod.write_text(fixed)
    findings, _ = lint_paths([mod], baseline=set())
    assert not [f for f in findings if f.rule == "perf-counter"]


def test_lint_fix_cli_flag(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(_FIXABLE)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--lint-only", "--fix",
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "FIX" in proc.stdout, proc.stdout + proc.stderr
    fixed = mod.read_text()
    assert "perf_counter" not in fixed and "Stopwatch()" in fixed
