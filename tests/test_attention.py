import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention, decode_attention


def _qkv(rng, B, Sq, Sk, H, HK, D):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, HK, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, HK, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("H,HK", [(4, 4), (4, 1), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_direct(H, HK, causal):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 320, 320, H, HK, 16)
    a = attention(q, k, v, causal=causal, impl="chunked", chunk=64)
    b = attention(q, k, v, causal=causal, impl="direct")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_prefix_lm_mask():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 320, 320, 2, 2, 16)
    a = attention(q, k, v, causal=True, prefix_len=64, impl="chunked", chunk=64)
    b = attention(q, k, v, causal=True, prefix_len=64, impl="direct")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
    # prefix tokens attend bidirectionally: output differs from pure causal
    c = attention(q, k, v, causal=True, impl="direct")
    assert np.abs(np.asarray(b)[:, :64] - np.asarray(c)[:, :64]).max() > 1e-3


def test_local_banded_matches_direct_window():
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 2, 256, 256, 2, 1, 16)
    a = attention(q, k, v, causal=True, window=64, impl="chunked")  # banded path
    b = attention(q, k, v, causal=True, window=64, impl="direct")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_local_banded_nondivisible_seq():
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 1, 200, 200, 2, 2, 8)
    a = attention(q, k, v, causal=True, window=64, impl="chunked")
    b = attention(q, k, v, causal=True, window=64, impl="direct")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_decode_matches_full_attention():
    rng = np.random.default_rng(4)
    B, S, H, HK, D = 2, 32, 4, 2, 16
    q_all, k_all, v_all = _qkv(rng, B, S, S, H, HK, D)
    full = attention(q_all, k_all, v_all, causal=True, impl="direct")
    # decode position by position against a growing cache
    ck = jnp.zeros((B, S, HK, D))
    cv = jnp.zeros((B, S, HK, D))
    for pos in range(S):
        ck = ck.at[:, pos].set(k_all[:, pos])
        cv = cv.at[:, pos].set(v_all[:, pos])
        out = decode_attention(q_all[:, pos : pos + 1], ck, cv, pos)
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, pos]), rtol=2e-4, atol=2e-4
        )


def test_decode_ring_buffer_window():
    rng = np.random.default_rng(5)
    B, S, W, H, D = 1, 48, 16, 2, 8
    q_all, k_all, v_all = _qkv(rng, B, S, S, H, H, D)
    full = attention(q_all, k_all, v_all, causal=True, window=W, impl="direct")
    ck = jnp.zeros((B, W, H, D))
    cv = jnp.zeros((B, W, H, D))
    s = jnp.arange(W)
    for pos in range(S):
        slot = pos % W
        ck = ck.at[:, slot].set(k_all[:, pos])
        cv = cv.at[:, slot].set(v_all[:, pos])
        kpos = pos - ((pos - s) % W)
        out = decode_attention(q_all[:, pos : pos + 1], ck, cv, pos, window=W, kpos=kpos)
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, pos]), rtol=2e-4, atol=2e-4
        )
