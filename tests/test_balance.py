"""Dynamic load-balancing subsystem tests (repro.dist.balance).

Property tests for the weighted Morton partitioner and the cost-model
helpers run in-process (no devices).  The resident behaviour — repartition
round-trip, the measured-imbalance acceptance criterion on the
random-offdiag sequence, bit-identical results, zero-miss steady state —
runs in a subprocess with 8 fake CPU devices, mirroring tests/test_dist.py.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schedule import partition_morton, subtree_boundaries
from repro.dist.balance import (
    RebalancePolicy,
    WorkerLoad,
    map_block_weights,
    owner_imbalance,
)

from helpers import random_block_matrix


# -- partition_morton(weights=...) properties --------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partition_morton_weighted_overshoot_bound(nblocks, nparts, seed):
    # greedy prefix-sum placement: every part's weight stays within one
    # block's weight of the ideal target (the static balance bound)
    rng = np.random.default_rng(seed)
    w = rng.random(nblocks) * rng.choice([1.0, 10.0, 100.0], size=nblocks)
    owner = partition_morton(nblocks, nparts, w)
    assert owner.shape == (nblocks,)
    assert np.all(np.diff(owner) >= 0)  # contiguous Morton ranges
    assert owner.min() >= 0 and owner.max() < nparts
    w_eff = np.maximum(w, 1e-12)  # the partitioner's zero-weight clamp
    loads = np.bincount(owner, weights=w_eff, minlength=nparts)
    assert loads.max() <= w_eff.sum() / nparts + w_eff.max() + 1e-9


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=100),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partition_morton_zero_weight_blocks(nblocks, nparts, seed):
    # zero (and all-zero) weights must not divide by zero or stall a cut;
    # the owner map stays a valid contiguous range partition
    rng = np.random.default_rng(seed)
    w = rng.random(nblocks)
    w[rng.random(nblocks) < 0.5] = 0.0
    for weights in (w, np.zeros(nblocks)):
        owner = partition_morton(nblocks, nparts, weights)
        assert owner.shape == (nblocks,)
        assert np.all(np.diff(owner) >= 0)
        assert owner.min() >= 0 and owner.max() < nparts


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=7, max_value=32),
)
def test_partition_morton_more_parts_than_blocks(nblocks, nparts):
    owner = partition_morton(nblocks, nparts)
    assert owner.shape == (nblocks,)
    assert np.all(np.diff(owner) >= 0)
    assert owner.max() < nparts
    # at most one block per part when parts outnumber blocks
    assert np.bincount(owner, minlength=nparts).max() <= 1 + (nblocks > nparts)


def test_partition_morton_empty():
    assert partition_morton(0, 4).shape == (0,)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=2, max_value=6),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partition_morton_align_snapping_edges(nparts, seed):
    # snapping must keep cuts monotone and inside [0, nblocks] even with
    # pathological candidate sets (duplicates, out-of-range, endpoints only)
    rng = np.random.default_rng(seed)
    nblocks = 64
    w = rng.random(nblocks) * 10
    for align in (
        np.array([0, 0, 64, 64, 200, -3]),  # duplicates + out of range
        np.array([32]),  # single interior candidate
        np.arange(0, 65),  # every position: cuts snap freely within slack
    ):
        owner = partition_morton(nblocks, nparts, w, align=align, slack=0.25)
        assert np.all(np.diff(owner) >= 0)
        assert owner.min() >= 0 and owner.max() < nparts
        loads = np.bincount(owner, weights=np.maximum(w, 1e-12), minlength=nparts)
        # slack-bounded: each part within target + slack budget + one block
        target = w.sum() / nparts
        assert loads.max() <= target + 0.25 * target + w.max() + 1e-9


def test_partition_morton_aligned_cuts_land_on_boundaries():
    a = random_block_matrix(64, 8, 1.0, 0)
    align = subtree_boundaries(a.coords)
    w = np.random.default_rng(3).random(a.nnzb) + 0.5
    owner = partition_morton(a.nnzb, 4, w, align=align)
    cuts = np.nonzero(np.diff(owner))[0] + 1
    assert np.all(np.isin(cuts, align))


# -- cost-model helpers ------------------------------------------------------


def test_worker_load_imbalance_uniform_is_one():
    P = 4
    ld = WorkerLoad(
        nparts=P,
        bs=16,
        tasks=np.full(P, 10.0),
        recv_bytes=np.full(P, 1024.0),
        send_bytes=np.full(P, 1024.0),
        blocks=np.full(P, 5.0),
    )
    assert ld.imbalance() == pytest.approx(1.0)
    skewed = WorkerLoad(
        nparts=P,
        bs=16,
        tasks=np.array([40.0, 0.0, 0.0, 0.0]),
        recv_bytes=np.zeros(P),
        send_bytes=np.zeros(P),
        blocks=np.zeros(P),
    )
    assert skewed.imbalance() == pytest.approx(4.0)
    both = ld + skewed
    assert both.tasks[0] == 50.0 and both.tasks[1] == 10.0


def test_owner_imbalance_and_policy_gating():
    owner = np.zeros(8, dtype=np.int32)
    assert owner_imbalance(owner, np.ones(8), 4) == pytest.approx(4.0)
    balanced = np.repeat(np.arange(4), 2).astype(np.int32)
    assert owner_imbalance(balanced, np.ones(8), 4) == pytest.approx(1.0)
    with pytest.raises(AssertionError):
        RebalancePolicy(threshold=0.5)


def test_map_block_weights_join_semantics():
    src = np.array([[0, 0], [1, 1], [2, 2]])
    dst = np.array([[0, 0], [2, 2], [3, 3]])
    w = map_block_weights(src, np.array([5.0, 7.0, 9.0]), dst, default=1.5)
    assert w.tolist() == [5.0, 9.0, 1.5]
    assert map_block_weights(src, np.ones(3), np.zeros((0, 2), np.int64)).shape == (0,)
    assert map_block_weights(
        np.zeros((0, 2), np.int64), np.zeros(0), dst, default=2.0
    ).tolist() == [2.0, 2.0, 2.0]


# -- resident behaviour (8-device subprocess) --------------------------------

_SCRIPT = r"""
import numpy as np, jax, json
from repro.core import BSMatrix
from repro.core.distributed import make_worker_mesh
from repro.dist import (scatter, PlanCache, dist_repartition, dist_multiply,
                        dist_sp2_purify, dist_localized_inverse_factorization,
                        resident_block_norms, rebalanced_owner, RebalancePolicy,
                        owner_imbalance)
from repro.dist.collectives import RepartitionExecutable

assert jax.device_count() == 8, jax.device_count()
out = {}

def random_offdiag(n, density, bs, seed=2):
    # the paper-style random-offdiag sequence (benchmarks/spamm_sequences.py):
    # strong diagonal + sparse off-diagonal blocks of widely varying size
    rng = np.random.default_rng(seed)
    nb = n // bs
    a = np.zeros((n, n), dtype=np.float32)
    for b in range(nb):
        a[b*bs:(b+1)*bs, b*bs:(b+1)*bs] = rng.standard_normal((bs, bs))
    mask = rng.random((nb, nb)) < density
    np.fill_diagonal(mask, False)
    for i, j in zip(*np.nonzero(mask)):
        scale = 10.0 ** rng.uniform(-4, 0)
        a[i*bs:(i+1)*bs, j*bs:(j+1)*bs] = scale * rng.standard_normal((bs, bs))
    return a

mesh = make_worker_mesh(8)
n, bs, nocc = 256, 16, 80
h = random_offdiag(n, 0.08, bs)
h = 0.2 * (h + h.T) / 2 + np.diag(np.linspace(-1, 1, n))
f = BSMatrix.from_dense(h.astype(np.float32), bs)
w = np.linalg.eigvalsh(h.astype(np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
skew = np.zeros(f.nnzb, dtype=np.int32)  # skewed initial layout: all on worker 0

# --- dist_repartition round-trip on the skewed layout -----------------------
cache = PlanCache()
dA = scatter(f, mesh, owner=skew)
new_owner = rebalanced_owner(dA.coords, np.ones(dA.nnzb), 8)
info = {}
dB = dist_repartition(dA, new_owner, cache, stats=info)
out["rp_owner_honored"] = bool(np.array_equal(dB.owner, new_owner))
out["rp_coords_same"] = bool(np.array_equal(dB.coords, dA.coords))
out["rp_gather_identical"] = bool(np.array_equal(
    np.asarray(dA.gather().data), np.asarray(dB.gather().data)))
out["rp_norms_invariant"] = bool(np.array_equal(
    resident_block_norms(dA), resident_block_norms(dB)))
# only migrating block payloads are planned into the rounds: blocks whose
# owner is unchanged are never in any send list (no host round-trip either —
# the executable's mapped body is the only data motion)
exe = RepartitionExecutable(dA, new_owner)
out["rp_migrated"] = [int(info["migrated_blocks"]),
                      int(np.count_nonzero(new_owner != dA.owner))]
out["rp_sent_total"] = [int(exe.sent_blocks.sum()), int(exe.migrated_blocks)]
out["rp_bytes"] = int(info["migrated_bytes"])
# round-trip back to the original layout: stores bit-identical
dC = dist_repartition(dB, dA.owner, cache)
out["rp_roundtrip_store"] = bool(np.array_equal(
    np.asarray(dC.store), np.asarray(dA.store)))
# no-op map returns the same object without touching the cache
h0, m0 = cache.hits, cache.misses
dD = dist_repartition(dB, dB.owner, cache)
out["rp_noop"] = [dD is dB, cache.hits - h0, cache.misses - m0]

# --- acceptance: SP2 on random-offdiag, skewed layout, static vs rebalanced -
runs = {}
for name, pol in (("static", None), ("rebalanced", RebalancePolicy())):
    df = scatter(f, mesh, owner=skew)
    d, st = dist_sp2_purify(df, nocc, lmin, lmax, idem_tol=1e-5,
                            trunc_tau=1e-5, spamm_tau=1e-6,
                            cache=PlanCache(), rebalance=pol)
    imbs = [pi["imbalance"] for pi in st.per_iter if pi["imbalance"] is not None]
    runs[name] = (d, st, imbs)
d_s, st_s, imb_s = runs["static"]
d_r, st_r, imb_r = runs["rebalanced"]
out["sp2_iters"] = [st_s.iterations, st_r.iterations]
out["sp2_rebalances"] = st_r.rebalances
out["sp2_imb_static"] = imb_s
out["sp2_imb_rebalanced"] = imb_r
out["sp2_bit_identical"] = bool(np.array_equal(
    np.asarray(d_s.to_dense()), np.asarray(d_r.to_dense())))
out["sp2_migrated"] = [int(pi["migrated_bytes"]) for pi in st_r.per_iter]
out["sp2_tail_misses"] = [pi["cache_misses"] for pi in st_r.per_iter[-3:]]
out["sp2_tail_hits"] = [pi["cache_hits"] for pi in st_r.per_iter[-3:]]

# --- inverse refinement: skewed pinned SPD operand --------------------------
spd = random_offdiag(n, 0.08, bs, seed=5)
spd = (spd + spd.T) / 2 * 0.05
spd += np.diag(1.0 + 0.5 * np.random.default_rng(7).random(n))
A = BSMatrix.from_dense(spd.astype(np.float32), bs)
inv_runs = {}
for name, pol in (("static", None), ("rebalanced", RebalancePolicy())):
    da = scatter(A, mesh, owner=np.zeros(A.nnzb, dtype=np.int32))
    z, st = dist_localized_inverse_factorization(
        da, PlanCache(), tol=1e-7, trunc_tau=1e-7, spamm_tau=1e-8,
        rebalance=pol)
    imbs = [pi["imbalance"] for pi in st.per_iter if pi["imbalance"] is not None]
    inv_runs[name] = (z, st, imbs)
z_s, ist_s, iimb_s = inv_runs["static"]
z_r, ist_r, iimb_r = inv_runs["rebalanced"]
out["inv_iters"] = [ist_s.iterations, ist_r.iterations]
out["inv_rebalances"] = ist_r.rebalances
out["inv_imb_static"] = iimb_s
out["inv_imb_rebalanced"] = iimb_r
out["inv_bit_identical"] = bool(np.array_equal(
    np.asarray(z_s.gather().to_dense()), np.asarray(z_r.gather().to_dense())))
out["inv_tail_misses"] = [pi["cache_misses"] for pi in ist_r.per_iter[-3:]]
out["inv_residuals"] = [ist_s.factorization_residual, ist_r.factorization_residual]

# --- dist_multiply / dist_spamm rebalance knob ------------------------------
cache2 = PlanCache()
dskew = scatter(f, mesh, owner=skew)
c_static = dist_multiply(dskew, dskew, cache2)
c_reb = dist_multiply(dskew, dskew, cache2, rebalance=RebalancePolicy())
out["knob_bit_identical"] = bool(np.array_equal(
    np.asarray(c_static.gather().to_dense()), np.asarray(c_reb.gather().to_dense())))
# second rebalanced call: repartition + plan are pure cache hits
h0, m0 = cache2.hits, cache2.misses
dist_multiply(dskew, dskew, cache2, rebalance=RebalancePolicy())
out["knob_second_call"] = [cache2.hits - h0, cache2.misses - m0]

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def balance_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT ") :])


def test_repartition_owner_map_honored(balance_results):
    assert balance_results["rp_owner_honored"]
    assert balance_results["rp_coords_same"]  # Morton stack order preserved


def test_repartition_gather_bit_identical(balance_results):
    assert balance_results["rp_gather_identical"]
    assert balance_results["rp_roundtrip_store"]


def test_repartition_norm_table_invariant(balance_results):
    assert balance_results["rp_norms_invariant"]


def test_repartition_moves_only_migrating_blocks(balance_results):
    migrated, expected = balance_results["rp_migrated"]
    assert migrated == expected > 0
    sent_total, migrated_blocks = balance_results["rp_sent_total"]
    # the planned rounds ship exactly the blocks that change owner
    assert sent_total == migrated_blocks
    assert balance_results["rp_bytes"] == migrated_blocks * 16 * 16 * 4
    is_same, hits, misses = balance_results["rp_noop"]
    assert is_same and hits == 0 and misses == 0


def test_sp2_rebalancing_reduces_imbalance_2x(balance_results):
    # the acceptance criterion: on the random-offdiag sequence with a skewed
    # initial layout, the measured max/mean worker-load imbalance drops by
    # >= 2x versus static partitioning
    imb_s = balance_results["sp2_imb_static"]
    imb_r = balance_results["sp2_imb_rebalanced"]
    assert max(imb_s) >= 2.0 * max(imb_r)
    assert balance_results["sp2_rebalances"] >= 1


def test_sp2_rebalanced_results_bit_identical(balance_results):
    # re-layouts change the schedule, never the math
    assert balance_results["sp2_bit_identical"]
    it_s, it_r = balance_results["sp2_iters"]
    assert it_s == it_r


def test_sp2_rebalanced_zero_miss_steady_state(balance_results):
    # once the layout (and sparsity pattern) stabilizes, iterations return
    # to pure cache hits despite the re-layouts earlier in the run
    assert all(m == 0 for m in balance_results["sp2_tail_misses"])
    assert all(h > 0 for h in balance_results["sp2_tail_hits"])


def test_sp2_migrated_bytes_reported(balance_results):
    # the up-front re-layout of the skewed X0 moved real payload, and its
    # bytes are accounted in the rebalanced run's stats rows
    assert sum(balance_results["sp2_migrated"]) > 0
    assert len(balance_results["sp2_imb_rebalanced"]) > 0


def test_inverse_rebalanced_pinned_operand(balance_results):
    # the pinned SPD operand's skew is fixed up-front; the refinement
    # trajectory is measurably more balanced and bit-identical
    assert balance_results["inv_rebalances"] >= 1
    assert balance_results["inv_bit_identical"]
    imb_s = balance_results["inv_imb_static"]
    imb_r = balance_results["inv_imb_rebalanced"]
    assert np.mean(imb_r) <= np.mean(imb_s)
    assert all(m == 0 for m in balance_results["inv_tail_misses"])
    r_s, r_r = balance_results["inv_residuals"]
    assert r_s == r_r


def test_multiply_rebalance_knob(balance_results):
    assert balance_results["knob_bit_identical"]
    hits, misses = balance_results["knob_second_call"]
    # repeated call on the same skewed operands: repartition executable and
    # the rebalanced plan are both cache hits, nothing re-plans
    assert misses == 0 and hits >= 2
