import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import reduced_config
from repro.data import TokenPipeline
from repro.models import model as model_mod


def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)},
        "opt": {"mu": {"w": jnp.zeros((3, 4)), "b": jnp.zeros(4)}, "count": jnp.int32(7)},
        "step": jnp.int32(42),
    }


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 42, s)
    restored, step = restore_checkpoint(str(tmp_path), s)
    assert step == 42
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_write_ignored(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    # simulate a crash mid-save: step dir without manifest
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1
    restored, step = restore_checkpoint(str(tmp_path), s)
    assert step == 1


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    s = _state()
    for step in [1, 2, 3, 4]:
        mgr.save(step, s)
    mgr.wait()
    mgr._gc()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_restart_is_bitwise_identical(tmp_path):
    """Train 6 steps straight vs train 3 + checkpoint + restore + 3 more."""
    cfg = reduced_config("qwen2-0.5b")
    pipe = TokenPipeline(cfg, batch=4, seq=16, seed=0)
    step_fn = jax.jit(model_mod.make_train_step(cfg, None, compute_dtype=jnp.float32))

    def run(state, start, n):
        for i in range(start, start + n):
            state, _ = step_fn(state, pipe.global_batch(i))
        return state

    s0 = model_mod.init_train_state(jax.random.key(0), cfg)
    straight = run(s0, 0, 6)

    s1 = model_mod.init_train_state(jax.random.key(0), cfg)
    s1 = run(s1, 0, 3)
    save_checkpoint(str(tmp_path), 3, s1)
    restored, st = restore_checkpoint(str(tmp_path), jax.tree.map(np.asarray, s1))
    restored = jax.tree.map(jnp.asarray, restored)
    resumed = run(restored, 3, 3)

    for a, b in zip(jax.tree.leaves(straight["params"]), jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
