import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import TokenPipeline


def test_deterministic_and_distinct():
    cfg = reduced_config("olmo-1b")
    p = TokenPipeline(cfg, batch=8, seq=32, seed=1)
    a = p.global_batch(5)
    b = p.global_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # same step = same data
    c = p.global_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])  # steps differ


def test_skip_ahead_equals_sequential():
    cfg = reduced_config("olmo-1b")
    p = TokenPipeline(cfg, batch=4, seq=16, seed=2)
    seq = [p.global_batch(i)["tokens"] for i in range(5)]
    # "resume at 3" without replaying 0..2
    np.testing.assert_array_equal(p.global_batch(3)["tokens"], seq[3])


def test_host_slices_partition_global_batch():
    cfg = reduced_config("olmo-1b")
    p = TokenPipeline(cfg, batch=8, seq=16, seed=3)
    g = p.global_batch(0)["tokens"]
    parts = [p.host_slice(0, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g)


def test_tokens_in_vocab():
    for arch in ["olmo-1b", "hubert-xlarge", "paligemma-3b"]:
        cfg = reduced_config(arch)
        p = TokenPipeline(cfg, batch=4, seq=32, seed=0)
        b = p.global_batch(0)
        for k, v in b.items():
            if v.dtype == np.int32:
                assert v.min() >= 0 and v.max() < cfg.vocab_size


def test_modality_stubs():
    cfg = reduced_config("hubert-xlarge")
    b = TokenPipeline(cfg, batch=2, seq=16, seed=0).global_batch(0)
    assert b["frames"].shape == (2, 16, cfg.frontend_dim)
    cfg = reduced_config("paligemma-3b")
    b = TokenPipeline(cfg, batch=2, seq=16, seed=0).global_batch(0)
    assert b["patches"].shape == (2, cfg.num_patches, cfg.d_model)
    assert b["tokens"].shape == (2, 16 - cfg.num_patches)
