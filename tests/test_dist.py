"""Device-resident distributed runtime tests (repro.dist).

SPMD behaviour runs in a subprocess with 4 fake CPU devices (the main test
process must keep seeing 1 device); PlanCache semantics and structure
fingerprints are cheap enough to test in-process on a 1-device mesh.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = r"""
import numpy as np, jax, json, sys
from repro.core import BSMatrix, multiply, add, truncate, sp2_purify, spamm
from repro.core.truncate import truncate_hierarchical
from repro.core.quadtree import build_quadtree_index
from repro.core.distributed import make_worker_mesh
from repro.dist import (scatter, PlanCache, dist_multiply, dist_spamm, dist_add,
                        dist_trace, dist_frobenius_norm, dist_truncate,
                        dist_truncate_hierarchical, dist_sp2_purify,
                        resident_block_norms)

assert jax.device_count() == 4, jax.device_count()
rng = np.random.default_rng(0)

def banded(n, h, bs, seed=0):
    r = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i-h), min(n, i+h+1)
        a[i, lo:hi] = r.standard_normal(hi-lo)
    return BSMatrix.from_dense(a, bs)

mesh = make_worker_mesh(4)
out = {}

A = banded(192, 12, 16, seed=1)
B = banded(192, 5, 16, seed=2)
dA, dB = scatter(A, mesh), scatter(B, mesh)
out["roundtrip_err"] = float(np.abs(dA.gather().to_dense() - A.to_dense()).max())

cache = PlanCache()
C = dist_multiply(dA, dA, cache)
out["mult_err"] = float(np.abs(C.gather().to_dense() - multiply(A, A).to_dense()).max())
dist_multiply(dA, dA, cache)  # same structure again
out["mult_cache"] = cache.stats()

S = dist_add(dA, dB, 2.0, -0.5, cache)
out["add_err"] = float(np.abs(S.gather().to_dense() - add(A, B, 2.0, -0.5).to_dense()).max())
# second call with different coefficients reuses the cached executable
S2 = dist_add(dA, dB, -1.0, 3.0, cache)
out["add_err2"] = float(np.abs(S2.gather().to_dense() - add(A, B, -1.0, 3.0).to_dense()).max())

out["trace_err"] = abs(dist_trace(dA, cache) - A.trace())
out["fro_err"] = abs(dist_frobenius_norm(dA, cache) - A.frobenius_norm())

tau = float(np.median(A.block_norms()) * 2)
T = dist_truncate(dA, tau, cache)
refT = truncate(A, tau)
out["trunc_nnzb"] = [T.nnzb, refT.nnzb, A.nnzb]
out["trunc_err"] = float(np.abs(T.gather().to_dense() - refT.to_dense()).max())

# resident norm table is the exact host computation (same kernel, same
# accumulation dtype) so prune decisions agree bit-for-bit near tau
out["norms_bitwise_equal"] = bool(
    np.array_equal(resident_block_norms(dA), np.asarray(A.block_norms()))
)

# dist_truncate edge cases mirroring the core-path tests
tau_all = A.frobenius_norm() * 1.01  # tau >= ||A||_F: every block dropped
T_all = dist_truncate(dA, tau_all, cache)
out["trunc_all_dropped"] = [T_all.nnzb, truncate(A, tau_all).nnzb]
single = BSMatrix.from_dense(np.full((16, 16), 0.5, np.float32), 16)
dsingle = scatter(single, mesh)
out["trunc_single"] = [
    dist_truncate(dsingle, 1e-6, cache).nnzb,   # tau below the block norm: kept
    dist_truncate(dsingle, 1e6, cache).nnzb,    # tau above: dropped
]
out["trunc_kept_set_equal"] = bool(
    np.array_equal(dist_truncate(dA, tau, cache).coords, truncate(A, tau).coords)
)

# hierarchical resident truncation: same kept set as the core descent, global
# Frobenius guarantee, and dropped subtrees' leaves never enumerated
tau_h = float(np.median(A.block_norms()) * 3)
info = {}
Th = dist_truncate_hierarchical(dA, tau_h, cache, stats=info)
refTh = truncate_hierarchical(A, tau_h)
out["htrunc_nnzb"] = [Th.nnzb, refTh.nnzb, A.nnzb]
out["htrunc_coords_equal"] = bool(np.array_equal(Th.coords, refTh.coords))
out["htrunc_err"] = float(np.abs(Th.gather().to_dense() - refTh.to_dense()).max())
out["htrunc_guarantee"] = [
    float(np.linalg.norm(A.to_dense() - np.asarray(Th.gather().to_dense(), np.float64))),
    tau_h,
]
qt_full = build_quadtree_index(A.coords, np.asarray(A.block_norms(), np.float64))
out["htrunc_visited"] = [int(info["nodes_visited"]), int(qt_full.num_nodes())]
out["htrunc_kept_len"] = [int(len(info["kept"])), Th.nnzb]

# SP2 purification on an SPD-shifted banded Hamiltonian
n, bs, nocc = 128, 16, 40
r = np.random.default_rng(3)
h = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - 3), min(n, i + 4)
    h[i, lo:hi] = 0.2 * r.standard_normal(hi - lo)
h = (h + h.T) / 2 + np.diag(np.linspace(-1, 1, n))
f = BSMatrix.from_dense(h, bs)
w = np.linalg.eigvalsh(h.astype(np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
d_ref, st_ref = sp2_purify(f, nocc, lmin, lmax, idem_tol=1e-5, trunc_tau=1e-5, impl="ref")
# leaf truncation runs the identical selection to the core driver -> exact parity
pc = PlanCache()
d_dist, st = dist_sp2_purify(f, nocc, lmin, lmax, mesh, idem_tol=1e-5,
                             trunc_tau=1e-5, trunc_method="leaf", cache=pc)
out["purify_err"] = float(np.abs(d_dist.to_dense() - d_ref.to_dense()).max())
# resident-input branch: already-scattered F, X0 built on the mesh
d_res, _ = dist_sp2_purify(scatter(f, mesh), nocc, lmin, lmax, idem_tol=1e-5,
                           trunc_tau=1e-5, trunc_method="leaf")
out["purify_resident_err"] = float(np.abs(d_res.to_dense() - d_ref.to_dense()).max())
out["purify_trace"] = float(d_dist.trace())
out["nocc"] = nocc
out["iters"] = [st.iterations, st_ref.iterations]
out["cache"] = st.cache
out["tail_hits"] = [pi["cache_hits"] for pi in st.per_iter[-3:]]
out["tail_misses"] = [pi["cache_misses"] for pi in st.per_iter[-3:]]

# hierarchical SpAMM on resident operands: bound holds, matches host path,
# repeated calls with a stable prune pattern hit the plan cache
tau_s = 20.0  # large enough that the descent actually prunes subtrees
sc = PlanCache()
Cs, err_s = dist_spamm(dA, dB, tau_s, sc)
host_c, host_err = spamm(A, B, tau_s)
out["spamm_bound_ok"] = bool(err_s <= tau_s + 1e-9)
out["spamm_true_err"] = float(
    np.linalg.norm(Cs.gather().to_dense() - A.to_dense() @ B.to_dense())
)
out["spamm_err_bound"] = float(err_s)
out["spamm_host_agree"] = float(
    np.abs(Cs.gather().to_dense() - host_c.to_dense()).max()
)
dist_spamm(dA, dB, tau_s, sc)  # same values -> same pruned tasks -> hit
out["spamm_cache"] = sc.stats()

# delta-plan SpAMM: a *different* tau (different prune pattern) still hits the
# structure-keyed plan; the replan path must re-plan for the new pattern
h0, m0 = sc.hits, sc.misses
Cs2, err_s2 = dist_spamm(dA, dB, tau_s * 3, sc)
out["spamm_delta_other_tau"] = [sc.hits - h0, sc.misses - m0]
host_c2, _ = spamm(A, B, tau_s * 3)
out["spamm_delta_other_tau_agree"] = float(
    np.abs(Cs2.gather().to_dense() - host_c2.to_dense()).max()
)
Cr, err_r = dist_spamm(dA, dB, tau_s, sc, method="replan")
out["spamm_replan_agree"] = float(
    np.abs(Cr.gather().to_dense() - host_c.to_dense()).max()
)
out["spamm_replan_bound"] = [float(err_r), float(err_s)]
# delta path with an empty full task list (no structural overlap): the mask
# relay must not index into the zero-length task array
E = BSMatrix.from_blocks((32, 32), 16, np.array([[0, 1]]),
                         np.ones((1, 16, 16), np.float32))
Ce, _ = dist_spamm(scatter(E, mesh), scatter(E, mesh), 0.5, sc)
out["spamm_delta_empty_nnzb"] = Ce.nnzb

# SP2 with SpAMM multiplies (leaf parity run): density still correct
d_spamm, st_sp = dist_sp2_purify(f, nocc, lmin, lmax, mesh, idem_tol=1e-5,
                                 trunc_tau=1e-5, trunc_method="leaf",
                                 spamm_tau=1e-6)
out["purify_spamm_err"] = float(np.abs(d_spamm.to_dense() - d_ref.to_dense()).max())
out["purify_spamm_trace"] = float(d_spamm.trace())
out["purify_spamm_errs_bounded"] = bool(
    all(pi["spamm_err"] <= 1e-6 + 1e-12 for pi in st_sp.per_iter)
)

# the default end-to-end path: hierarchical truncation + delta SpAMM.  Once
# the sparsity pattern stabilizes an iteration incurs ZERO plan-cache misses
# even though the tau-prune pattern still fluctuates, recv bytes are reported
# from the plan actually executed (regression: used to read the exact-multiply
# key and report 0.0 whenever spamm_tau > 0), and the density is still right.
d_hier, st_h = dist_sp2_purify(f, nocc, lmin, lmax, mesh, idem_tol=1e-5,
                               trunc_tau=1e-5, spamm_tau=1e-6)
out["purify_hier_err"] = float(np.abs(d_hier.to_dense() - d_ref.to_dense()).max())
out["purify_hier_trace"] = float(d_hier.trace())
out["purify_hier_tail_misses"] = [pi["cache_misses"] for pi in st_h.per_iter[-3:]]
out["purify_hier_tail_hits"] = [pi["cache_hits"] for pi in st_h.per_iter[-3:]]
out["purify_spamm_recv_bytes"] = [pi["recv_bytes_mean"] for pi in st_h.per_iter]
out["purify_hier_errs_bounded"] = bool(
    all(pi["spamm_err"] <= 1e-6 + 1e-12 for pi in st_h.per_iter)
)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT ") :])


def test_scatter_gather_roundtrip(dist_results):
    assert dist_results["roundtrip_err"] == 0.0


def test_dist_multiply_matches_host(dist_results):
    assert dist_results["mult_err"] < 1e-4
    st = dist_results["mult_cache"]
    assert st["hits"] >= 1 and st["misses"] >= 1


def test_dist_add_matches_host(dist_results):
    assert dist_results["add_err"] < 1e-4
    assert dist_results["add_err2"] < 1e-4


def test_dist_reductions_match_host(dist_results):
    assert dist_results["trace_err"] < 1e-3
    assert dist_results["fro_err"] < 1e-3


def test_dist_truncate_matches_host(dist_results):
    t, ref, orig = dist_results["trunc_nnzb"]
    assert t == ref < orig  # actually dropped blocks, same selection
    assert dist_results["trunc_err"] == 0.0


def test_dist_purify_matches_single_host(dist_results):
    assert dist_results["purify_err"] < 1e-4
    assert dist_results["purify_resident_err"] < 1e-4
    assert abs(dist_results["purify_trace"] - dist_results["nocc"]) < 0.05


def test_dist_spamm(dist_results):
    assert dist_results["spamm_bound_ok"]
    assert dist_results["spamm_true_err"] <= dist_results["spamm_err_bound"] + 1e-2
    # identical norms -> identical hierarchical prune as the host path
    assert dist_results["spamm_host_agree"] < 1e-5
    st = dist_results["spamm_cache"]
    assert st["hits"] >= 1  # stable prune pattern reuses plan + executable


def test_dist_purify_with_spamm(dist_results):
    assert dist_results["purify_spamm_err"] < 1e-3
    assert abs(dist_results["purify_spamm_trace"] - dist_results["nocc"]) < 0.05
    assert dist_results["purify_spamm_errs_bounded"]
    it_dist, it_ref = dist_results["iters"]
    assert it_dist == it_ref


def test_resident_norms_match_host_bitwise(dist_results):
    # same kernel, same accumulation dtype: host and resident SpAMM /
    # truncation prune decisions can never disagree near tau
    assert dist_results["norms_bitwise_equal"]


def test_dist_truncate_edge_cases(dist_results):
    assert dist_results["trunc_all_dropped"] == [0, 0]  # tau >= ||A||_F
    assert dist_results["trunc_single"] == [1, 0]  # single-block keep / drop
    assert dist_results["trunc_kept_set_equal"]  # same kept set as core


def test_dist_truncate_hierarchical(dist_results):
    t, ref, orig = dist_results["htrunc_nnzb"]
    assert t == ref < orig  # dropped blocks, identical set to the core descent
    assert dist_results["htrunc_coords_equal"]
    assert dist_results["htrunc_err"] == 0.0
    err, tau = dist_results["htrunc_guarantee"]
    assert err <= tau * (1 + 1e-6) + 1e-6  # global Frobenius guarantee
    visited, total = dist_results["htrunc_visited"]
    assert 0 < visited < total  # dropped subtrees' leaves never enumerated
    kept_reported, kept_actual = dist_results["htrunc_kept_len"]
    assert kept_reported == kept_actual


def test_dist_spamm_delta_plan(dist_results):
    # a different tau (different prune pattern) must NOT miss the plan cache
    hits, misses = dist_results["spamm_delta_other_tau"]
    assert misses == 0 and hits >= 1
    assert dist_results["spamm_delta_other_tau_agree"] < 1e-5
    # replan mode computes the same result and the same bound
    assert dist_results["spamm_replan_agree"] < 1e-5
    r, d = dist_results["spamm_replan_bound"]
    assert abs(r - d) < 1e-9
    assert dist_results["spamm_delta_empty_nnzb"] == 0


def test_dist_purify_hierarchical_delta_zero_misses(dist_results):
    # the issue's acceptance criterion: with spamm_tau > 0 and hierarchical
    # trunc_tau > 0, a stabilized-pattern iteration incurs zero plan-cache
    # misses even while the tau-prune pattern fluctuates
    assert dist_results["purify_hier_err"] < 1e-3
    assert abs(dist_results["purify_hier_trace"] - dist_results["nocc"]) < 0.05
    assert dist_results["purify_hier_errs_bounded"]
    assert all(m == 0 for m in dist_results["purify_hier_tail_misses"])
    assert all(h > 0 for h in dist_results["purify_hier_tail_hits"])


def test_dist_purify_spamm_recv_bytes_reported(dist_results):
    # regression: recv_bytes_mean read the exact-multiply key and reported
    # 0.0 for every iteration whenever spamm_tau > 0
    rb = dist_results["purify_spamm_recv_bytes"]
    assert rb and all(b > 0 for b in rb)


def test_dist_purify_plan_cache_hits(dist_results):
    # once truncation stabilizes the sparsity pattern, iterations are pure
    # cache hits: no symbolic planning, no recompilation
    assert dist_results["cache"]["hits"] > 0
    assert all(h > 0 for h in dist_results["tail_hits"])
    assert all(m == 0 for m in dist_results["tail_misses"])


# -- in-process (1-device mesh): cache key semantics and fingerprints --------


def test_plan_cache_hit_miss_semantics():
    import jax

    from repro.core.distributed import make_worker_mesh
    from repro.dist import PlanCache, dist_multiply, scatter

    sys.path.insert(0, os.path.dirname(__file__))
    from helpers import banded_matrix

    assert jax.device_count() == 1
    mesh = make_worker_mesh(1)
    a = banded_matrix(64, 6, 16, seed=0)
    da = scatter(a, mesh)
    cache = PlanCache()
    dist_multiply(da, da, cache)
    assert (cache.hits, cache.misses) == (0, 1)
    dist_multiply(da, da, cache)  # identical structure -> hit
    assert (cache.hits, cache.misses) == (1, 1)

    # perturb the structure: one extra block -> different key -> miss
    import jax.numpy as jnp
    from repro.core import BSMatrix

    coords = np.concatenate([a.coords, [[3, 0]]])
    data = jnp.concatenate([a.data, jnp.ones((1, 16, 16), a.dtype)])
    b = BSMatrix.from_blocks(a.shape, a.bs, coords, data)
    assert b.nnzb == a.nnzb + 1
    db = scatter(b, mesh)
    dist_multiply(db, db, cache)
    assert (cache.hits, cache.misses) == (1, 2)


def test_structure_fingerprint_stability():
    from repro.core.schedule import structure_fingerprint

    x = np.arange(10, dtype=np.int64)
    assert structure_fingerprint(x, 4) == structure_fingerprint(x.copy(), 4)
    assert structure_fingerprint(x, 4) != structure_fingerprint(x, 8)
    y = x.copy()
    y[3] += 1
    assert structure_fingerprint(x, 4) != structure_fingerprint(y, 4)
    # dtype matters (same bytes, different meaning)
    assert structure_fingerprint(x) != structure_fingerprint(x.view(np.uint64))
