"""Device-resident inverse factorization tests (repro.dist.inverse).

Same harness as test_dist.py: SPMD behaviour runs in a subprocess with 4
fake CPU devices; the main process keeps seeing 1 device.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np, jax, json
from repro.core import (BSMatrix, add, identity, inv_chol,
                        localized_inverse_factorization, multiply, sp2_purify,
                        submatrix)
from repro.core.distributed import make_worker_mesh
from repro.dist import (PlanCache, dist_assemble2x2, dist_inv_chol,
                        dist_lanczos_bounds,
                        dist_localized_inverse_factorization, dist_spamm,
                        dist_sqrt_inv_pipeline, dist_submatrix, dist_transpose,
                        resident_block_norms, scatter)

assert jax.device_count() == 4, jax.device_count()


def banded(n, h, bs, seed=0):
    r = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - h), min(n, i + h + 1)
        a[i, lo:hi] = r.standard_normal(hi - lo)
    return BSMatrix.from_dense(a, bs)


def spd(n, h, bs, seed=0):
    d = banded(n, h, bs, seed).to_dense()
    return BSMatrix.from_dense(d @ d.T + n * np.eye(n, dtype=np.float32), bs)


mesh = make_worker_mesh(4)
out = {}
cache = PlanCache()

# -- transpose: values, round-trip, owner layout, cache behaviour ------------
A = banded(96, 8, 8, seed=1)
dA = scatter(A, mesh)
dT = dist_transpose(dA, cache)
out["t_coords_equal"] = bool(np.array_equal(dT.coords, A.transpose().coords))
out["t_err"] = float(np.abs(dT.gather().to_dense() - A.to_dense().T).max())
dTT = dist_transpose(dT, cache)
out["tt_coords_equal"] = bool(np.array_equal(dTT.coords, dA.coords))
out["tt_owner_equal"] = bool(np.array_equal(dTT.owner, dA.owner))
out["tt_slot_equal"] = bool(np.array_equal(dTT.slot, dA.slot))
out["tt_err"] = float(np.abs(dTT.gather().to_dense() - A.to_dense()).max())
h0, m0 = cache.hits, cache.misses
dist_transpose(dA, cache)  # same structure -> pure hit
out["t_cache"] = [cache.hits - h0, cache.misses - m0]

# -- fused norm-table psum: bit-identical to the legacy padded-table path ----
out["norms_bitwise_equal"] = bool(
    np.array_equal(resident_block_norms(dA), resident_block_norms(dA, cache))
)
out["norms_host_equal"] = bool(
    np.array_equal(resident_block_norms(dA, cache), np.asarray(A.block_norms()))
)
h0, m0 = cache.hits, cache.misses
resident_block_norms(dA, cache)
out["norms_cache"] = [cache.hits - h0, cache.misses - m0]

# -- quadrant slice / assemble: identity, owner preservation -----------------
S = spd(64, 4, 8, seed=2)
dS = scatter(S, mesh)
quads = [dist_submatrix(dS, r0, r1, c0, c1, cache)
         for (r0, r1, c0, c1) in [(0, 4, 0, 4), (0, 4, 4, 8),
                                  (4, 8, 0, 4), (4, 8, 4, 8)]]
refs = [submatrix(S, r0, r1, c0, c1)
        for (r0, r1, c0, c1) in [(0, 4, 0, 4), (0, 4, 4, 8),
                                 (4, 8, 0, 4), (4, 8, 4, 8)]]
out["slice_coords_equal"] = bool(all(
    np.array_equal(q.coords, r.coords) for q, r in zip(quads, refs)))
out["slice_err"] = float(max(
    np.abs(q.gather().to_dense() - r.to_dense()).max() for q, r in zip(quads, refs)))
R = dist_assemble2x2(*quads, 4, cache)
out["asm_coords_equal"] = bool(np.array_equal(R.coords, dS.coords))
out["asm_owner_equal"] = bool(np.array_equal(R.owner, dS.owner))
out["asm_err"] = float(np.abs(R.gather().to_dense() - S.to_dense()).max())

# -- dist_inv_chol vs core: kept set + values, pow2 / non-pow2 / single ------
cases = {"pow2": spd(64, 4, 8, seed=3), "nonpow2": spd(56, 5, 8, seed=4),
         "single": spd(16, 3, 16, seed=5)}
for name, a in cases.items():
    z_ref = inv_chol(a, impl="ref")
    dz = dist_inv_chol(scatter(a, mesh), cache)
    out[f"invchol_{name}_coords_equal"] = bool(
        np.array_equal(dz.coords, z_ref.coords))
    out[f"invchol_{name}_err"] = float(
        np.abs(dz.gather().to_dense() - z_ref.to_dense()).max())
    zg = dz.gather()
    zaz = multiply(multiply(zg.transpose(), a, impl="ref"), zg, impl="ref")
    out[f"invchol_{name}_residual"] = float(
        add(identity(a.shape[0], a.bs, a.dtype), zaz, 1.0, -1.0).frobenius_norm())

# -- refinement on an ill-conditioned SPD matrix -----------------------------
n = 64
b = banded(n, 3, 8, seed=6).to_dense()
ill = BSMatrix.from_dense(b @ b.T + 1e-3 * np.eye(n, dtype=np.float32), 8)
out["ill_cond"] = float(np.linalg.cond(np.asarray(ill.to_dense(), np.float64)))
z_ill, st_ill = dist_localized_inverse_factorization(
    scatter(ill, mesh), cache, tol=1e-5, max_iter=60)
out["ill_history"] = [float(r) for r in st_ill.residual_history]
out["ill_final"] = float(st_ill.factorization_residual)

# -- zero plan-cache misses once the pattern stabilizes ----------------------
fresh = PlanCache()
dS2 = scatter(S, mesh)
z1, st1 = dist_localized_inverse_factorization(
    dS2, fresh, tol=1e-7, max_iter=40, trunc_tau=1e-6, spamm_tau=1e-7)
z2, st2 = dist_localized_inverse_factorization(
    dS2, fresh, tol=1e-7, max_iter=40, trunc_tau=1e-6, spamm_tau=1e-7)
out["refine_iters"] = [st1.iterations, st2.iterations]
out["refine_final"] = [st1.factorization_residual, st2.factorization_residual]
out["refine_run1_misses"] = [pi["cache_misses"] for pi in st1.per_iter]
out["refine_run2_misses"] = [pi["cache_misses"] for pi in st2.per_iter]
out["refine_run2_hits"] = [pi["cache_hits"] for pi in st2.per_iter]
out["refine_nnzb"] = [st1.nnzb_history[-1], S.nblocks[0] ** 2]
# host driver agreement under the shared RefineMonitor policy
z_host, st_host = localized_inverse_factorization(S, tol=1e-7, max_iter=40, impl="ref")
z_res, st_res = dist_localized_inverse_factorization(dS2, fresh, tol=1e-7, max_iter=40)
out["refine_host_agree"] = float(
    np.abs(z_res.gather().to_dense() - z_host.to_dense()).max())
out["refine_host_iters"] = [st_res.iterations, st_host.iterations]

# -- end-to-end pipeline: S -> Z -> Z^T H Z -> SP2 -> Z D Z^T ---------------
rng = np.random.default_rng(7)
hm = 0.2 * rng.standard_normal((64, 64)).astype(np.float32)
H = BSMatrix.from_dense(
    (hm + hm.T) / 2 + np.diag(np.linspace(-1, 1, 64)).astype(np.float32), 8)
nocc = 20
pc = PlanCache()
D, pst = dist_sqrt_inv_pipeline(
    S, H, nocc, mesh, tol=1e-6, idem_tol=1e-5, trunc_tau=1e-6, spamm_tau=1e-7,
    cache=pc)
# host reference pipeline with the same error-control knobs
zh, _ = localized_inverse_factorization(S, tol=1e-6, trunc_tau=1e-6, impl="ref")
f_o = multiply(multiply(zh.transpose(), H, impl="ref"), zh, impl="ref")
w = np.linalg.eigvalsh(np.asarray(f_o.to_dense(), np.float64))
d_o, _ = sp2_purify(f_o, nocc, float(w.min()) - 0.05, float(w.max()) + 0.05,
                    idem_tol=1e-5, trunc_tau=1e-6, impl="ref")
d_host = multiply(multiply(zh, d_o, impl="ref"), zh.transpose(), impl="ref")
out["pipe_err"] = float(np.abs(D.to_dense() - d_host.to_dense()).max())
out["pipe_trace_ds"] = float(multiply(D, S, impl="ref").trace())
out["pipe_nocc"] = nocc
out["pipe_bounds"] = list(pst.bounds)
out["pipe_fo_norm_bound_ok"] = bool(
    float(np.abs(w).max()) <= pst.bounds[1] + 1e-9)
out["pipe_purify_tail_misses"] = [
    pi["cache_misses"] for pi in pst.purify.per_iter[-3:]]
out["pipe_purify_iters"] = pst.purify.iterations
out["pipe_back_misses_second"] = None
# second pipeline call on identical structures: refinement + congruence +
# back-transform replay entirely from the cache
snap_m = pc.misses
D2, pst2 = dist_sqrt_inv_pipeline(
    S, H, nocc, mesh, tol=1e-6, idem_tol=1e-5, trunc_tau=1e-6, spamm_tau=1e-7,
    cache=pc)
out["pipe_second_inv_misses"] = [
    pi["cache_misses"] for pi in pst2.inverse.per_iter]
out["pipe_second_congruence_misses"] = pst2.congruence["cache_misses"]
out["pipe_second_err"] = float(np.abs(D2.to_dense() - D.to_dense()).max())

# -- satellite: resident Lanczos eigenbound refinement -----------------------
# directly: a few resident Lanczos steps estimate the spectrum of the
# ill-conditioned matrix through existing collectives only
wi = np.linalg.eigvalsh(np.asarray(ill.to_dense(), np.float64))
lz_lo, lz_hi = dist_lanczos_bounds(scatter(ill, mesh), cache, steps=15)
out["lz_direct"] = [lz_lo, lz_hi, float(wi.min()), float(wi.max())]
# in the pipeline: the refined interval intersects the Gershgorin enclosure
# (never widens) and buys back the SP2 iterations the loose row-sum bound
# costs on the ill-conditioned overlap matrix
lzc = PlanCache()
D0, pst0 = dist_sqrt_inv_pipeline(
    ill, H, nocc, mesh, tol=1e-5, idem_tol=1e-5, trunc_tau=1e-6,
    spamm_tau=1e-7, cache=lzc, lanczos_steps=0)
DL, pstL = dist_sqrt_inv_pipeline(
    ill, H, nocc, mesh, tol=1e-5, idem_tol=1e-5, trunc_tau=1e-6,
    spamm_tau=1e-7, cache=lzc, lanczos_steps=12)
out["lz_bounds0"] = list(pst0.bounds)
out["lz_boundsL"] = list(pstL.bounds)
out["lz_iters"] = [pst0.purify.iterations, pstL.purify.iterations]
out["lz_err"] = float(np.abs(DL.to_dense() - D0.to_dense()).max())
out["lz_trace"] = [float(multiply(D0, ill, impl="ref").trace()),
                   float(multiply(DL, ill, impl="ref").trace())]

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def inv_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT ") :])


def test_dist_transpose_roundtrip(inv_results):
    assert inv_results["t_coords_equal"]
    assert inv_results["t_err"] == 0.0
    # T(T(A)) == A including the owner layout (Morton partition of A's codes)
    assert inv_results["tt_coords_equal"]
    assert inv_results["tt_owner_equal"]
    assert inv_results["tt_slot_equal"]
    assert inv_results["tt_err"] == 0.0


def test_dist_transpose_plan_cached(inv_results):
    hits, misses = inv_results["t_cache"]
    assert misses == 0 and hits >= 1


def test_resident_norm_psum_bitwise(inv_results):
    # the fused device-side reduction ([nnzb] psum) returns exactly what the
    # padded-table fetch and the host kernel return — prune decisions near
    # tau can never diverge between the paths
    assert inv_results["norms_bitwise_equal"]
    assert inv_results["norms_host_equal"]
    hits, misses = inv_results["norms_cache"]
    assert misses == 0 and hits >= 1


def test_dist_quadrant_slice_assemble_identity(inv_results):
    assert inv_results["slice_coords_equal"]
    assert inv_results["slice_err"] == 0.0
    # reassembly restores structure, values AND placement: slice/assemble
    # moved no block between devices
    assert inv_results["asm_coords_equal"]
    assert inv_results["asm_owner_equal"]
    assert inv_results["asm_err"] == 0.0


@pytest.mark.parametrize("case", ["pow2", "nonpow2", "single"])
def test_dist_inv_chol_matches_core(inv_results, case):
    assert inv_results[f"invchol_{case}_coords_equal"]  # identical kept set
    assert inv_results[f"invchol_{case}_err"] < 1e-5
    assert inv_results[f"invchol_{case}_residual"] < 1e-4


def test_dist_refinement_ill_conditioned(inv_results):
    hist = inv_results["ill_history"]
    assert inv_results["ill_cond"] > 1e4  # genuinely ill-conditioned
    assert hist[0] > hist[-1]  # refinement reduced the residual
    assert inv_results["ill_final"] < 2e-4  # near the float32 floor


def test_dist_refinement_zero_misses_on_stable_pattern(inv_results):
    # acceptance criterion: once the sparsity pattern stabilizes, refinement
    # iterations incur zero plan-cache misses — the repeated solve replays
    # every iteration (including the first) from the structure-keyed cache
    assert all(m == 0 for m in inv_results["refine_run2_misses"])
    assert all(h > 0 for h in inv_results["refine_run2_hits"])
    # within the first run the stabilized tail is also all-hit
    assert inv_results["refine_run1_misses"][-1] == 0
    assert inv_results["refine_final"][0] < 1e-4
    nnzb, full = inv_results["refine_nnzb"]
    assert nnzb <= full


def test_dist_refinement_matches_host_policy(inv_results):
    # shared RefineMonitor: both drivers stop on the identical criterion
    it_res, it_host = inv_results["refine_host_iters"]
    assert it_res == it_host
    assert inv_results["refine_host_agree"] < 1e-4


def test_dist_sqrt_inv_pipeline_matches_host(inv_results):
    # within truncation tolerance of the host pipeline (core localized
    # inverse factorization + congruence + sp2_purify + back transform)
    assert inv_results["pipe_err"] < 1e-3
    assert abs(inv_results["pipe_trace_ds"] - inv_results["pipe_nocc"]) < 0.05
    # norm-table Gershgorin interval really encloses the spectrum
    assert inv_results["pipe_fo_norm_bound_ok"]
    assert inv_results["pipe_bounds"][0] < 0 < inv_results["pipe_bounds"][1]
    # stabilized SP2 tail inside the pipeline is all-hit
    assert all(m == 0 for m in inv_results["pipe_purify_tail_misses"])


def test_dist_sqrt_inv_pipeline_replays_from_cache(inv_results):
    # a second solve on identical structures does zero re-planning anywhere:
    # refinement iterations and the congruence transform are pure hits
    assert all(m == 0 for m in inv_results["pipe_second_inv_misses"])
    assert inv_results["pipe_second_congruence_misses"] == 0
    assert inv_results["pipe_second_err"] < 1e-6


def test_dist_lanczos_bounds_estimate(inv_results):
    lo, hi, wmin, wmax = inv_results["lz_direct"]
    spread = wmax - wmin
    # the Ritz +- residual interval tracks the true spectrum closely after a
    # few steps (the Krylov space converges to the extremes first)
    assert hi >= wmax - 0.05 * spread
    assert lo <= wmin + 0.05 * spread
    assert hi <= wmax + spread  # and stays in the right ballpark
    assert lo >= wmin - spread


def test_pipeline_lanczos_never_widens_interval(inv_results):
    b0, bl = inv_results["lz_bounds0"], inv_results["lz_boundsL"]
    # the refined interval is the intersection with Gershgorin: a subset
    assert bl[0] >= b0[0] - 1e-12
    assert bl[1] <= b0[1] + 1e-12
    assert (bl[1] - bl[0]) < (b0[1] - b0[0])  # and strictly tighter here


def test_pipeline_lanczos_reduces_sp2_iterations(inv_results):
    it0, itl = inv_results["lz_iters"]
    assert itl < it0  # tighter interval -> fewer SP2 iterations
    # density matrix unchanged within error-control tolerance
    assert inv_results["lz_err"] < 1e-3
    tr0, trl = inv_results["lz_trace"]
    assert abs(tr0 - trl) < 0.05
