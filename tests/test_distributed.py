"""SPMD execution tests (subprocess with 8 fake CPU devices — the main test
process must keep seeing 1 device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, json
from repro.core import BSMatrix, multiply
from repro.core.schedule import make_spgemm_plan, plan_stats
from repro.core.distributed import make_worker_mesh, dist_spgemm, unshard_result

rng = np.random.default_rng(0)
def banded(n, h, bs):
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i-h), min(n, i+h+1)
        a[i, lo:hi] = rng.standard_normal(hi-lo)
    return BSMatrix.from_dense(a, bs)

assert jax.device_count() == 8, jax.device_count()
A = banded(256, 20, 16)
ref = multiply(A, A).to_dense()
out = {}
for placement, exchange, impl in [
    ("morton", "p2p", "ref"),
    ("random", "p2p", "ref"),
    ("morton", "allgather", "ref"),
    ("morton", "p2p", "kernel"),
]:
    plan = make_spgemm_plan(A.coords, A.coords, 8, 16, placement=placement, exchange=exchange)
    res = dist_spgemm(plan, A.data, A.data, make_worker_mesh(8), impl=impl)
    C = unshard_result(plan, res, (256, 256), 16)
    err = float(np.abs(C.to_dense() - ref).max())
    st = plan_stats(plan)
    out[f"{placement}/{exchange}/{impl}"] = {"err": err, "recv": st["recv_bytes_mean"]}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def spmd_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT ") :])


def test_all_modes_match_dense(spmd_results):
    for key, r in spmd_results.items():
        assert r["err"] < 1e-3, (key, r)


def test_kernel_impl_matches(spmd_results):
    assert spmd_results["morton/p2p/kernel"]["err"] < 1e-3


def test_locality_comm_ordering(spmd_results):
    morton = spmd_results["morton/p2p/ref"]["recv"]
    random = spmd_results["random/p2p/ref"]["recv"]
    ag = spmd_results["morton/allgather/ref"]["recv"]
    assert morton < random < ag
