"""Elastic scaling: re-shard a training state across different mesh shapes."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import Mesh
from repro.configs import reduced_config
from repro.models import model as model_mod, transformer
from repro.runtime.elastic import reshard_state, state_shardings
from repro.sharding.rules import MeshCtx

assert jax.device_count() == 8
cfg = reduced_config("olmo-1b")
state = model_mod.init_train_state(jax.random.key(0), cfg)
axes = transformer.param_axes(cfg)
ref = jax.tree.map(np.asarray, state)

results = {}
prev = state
for shape, names in [((2, 4), ("data", "model")), ((4, 2), ("data", "model")), ((8,), ("data",))]:
    mesh = Mesh(np.array(jax.devices()).reshape(shape), names)
    ctx = MeshCtx(mesh=mesh)
    prev = reshard_state(prev, None, ctx, axes)
    # values preserved across elastic transitions
    err = max(
        float(np.abs(np.asarray(a) - b).max())
        for a, b in zip(jax.tree.leaves(prev), jax.tree.leaves(ref))
    )
    # params actually sharded on the fsdp axis where divisible
    results[str(shape)] = err
print("RESULT " + json.dumps(results))
"""


def test_elastic_reshard_preserves_values():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    results = json.loads(line[7:])
    assert all(v == 0.0 for v in results.values()), results
    assert len(results) == 3
