import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import TokenPipeline
from repro.models import model as model_mod
from repro.runtime.fault_tolerance import StragglerDetector, TrainLoop, run_with_retries


def test_run_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42

    failures = []
    out = run_with_retries(flaky, max_retries=5, on_failure=lambda k, e: failures.append(k))
    assert out == 42 and len(failures) == 2


def test_run_with_retries_exhausts():
    def dead():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_with_retries(dead, max_retries=2)


def test_straggler_detector():
    det = StragglerDetector(alpha=0.5, threshold=2.0)
    for _ in range(5):
        assert not det.observe(1.0)
    assert det.observe(5.0)  # 5x ewma
    assert det.events == 1
    # ewma not poisoned by the straggler
    assert det.ewma == pytest.approx(1.0)


def test_train_loop_with_injected_failures(tmp_path):
    cfg = reduced_config("qwen2-0.5b")
    pipe = TokenPipeline(cfg, batch=4, seq=16, seed=0)
    inner = jax.jit(model_mod.make_train_step(cfg, None, compute_dtype=jnp.float32))
    fail_at = {"steps": {2, 5}}

    def flaky_step(state, batch):
        step = int(state["step"])
        if step in fail_at["steps"]:
            fail_at["steps"].discard(step)
            raise RuntimeError(f"injected failure at step {step}")
        return inner(state, batch)

    loop = TrainLoop(flaky_step, pipe, str(tmp_path), ckpt_every=4, max_retries=2)
    state = model_mod.init_train_state(jax.random.key(0), cfg)
    state, hist = loop.run(state, 0, 8, log_every=100, log=lambda *_: None)
    assert loop.retries == 2
    assert len(hist) == 8
    # continuation after retries matches failure-free run exactly
    clean = model_mod.init_train_state(jax.random.key(0), cfg)
    for i in range(8):
        clean, _ = inner(clean, pipe.global_batch(i))
    for a, b in zip(jax.tree.leaves(clean["params"]), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_loop_restart_from_checkpoint(tmp_path):
    cfg = reduced_config("olmo-1b")
    pipe = TokenPipeline(cfg, batch=4, seq=16, seed=1)
    step = jax.jit(model_mod.make_train_step(cfg, None, compute_dtype=jnp.float32))
    # first run: 6 steps, checkpoint every 3
    loop1 = TrainLoop(step, pipe, str(tmp_path), ckpt_every=3)
    s0 = model_mod.init_train_state(jax.random.key(0), cfg)
    s1, _ = loop1.run(s0, 0, 6, log_every=100, log=lambda *_: None)
    # "crash" and restart: a new loop resumes from the committed checkpoint
    loop2 = TrainLoop(step, pipe, str(tmp_path), ckpt_every=3)
    s2, start = loop2.resume_or_init(model_mod.init_train_state(jax.random.key(0), cfg))
    assert start == 6
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
