"""Fused leaf engine: kernel/ref parity, mixed precision, autotune cache,
and 8-worker distributed bit-identity (subprocess, like test_distributed)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ref as kref  # noqa: E402
from repro.kernels.autotune import (  # noqa: E402
    clear_memo,
    heuristic_tiles,
    load_tile_cache,
    pick_tiles,
    save_tile_entry,
    tile_key,
)
from repro.kernels.fused_leaf import (  # noqa: E402
    fused_block_spmm_kernel_call,
    fused_block_spmm_ref,
)
from repro.kernels.ops import fused_block_spmm  # noqa: E402
from repro.kernels.precision import (  # noqa: E402
    BF16,
    FP32,
    ROUND2_BOUND,
    Precision,
    low_precision_task_mask,
)

rng = np.random.default_rng(7)


def _problem(T=24, n_store=6, rounds=2, cap_u=5, bm=16, bk=16, bn=16, dtype=np.float32):
    """Random fused-engine operand set + the equivalent staged concatenation."""
    a_store = rng.standard_normal((n_store, bm, bk)).astype(dtype)
    b_store = rng.standard_normal((n_store, bk, bn)).astype(dtype)
    a_recv = rng.standard_normal((rounds, cap_u, bm, bk)).astype(dtype)
    b_recv = rng.standard_normal((rounds, cap_u, bk, bn)).astype(dtype)
    a_src = rng.integers(0, rounds + 1, T).astype(np.int32)
    a_off = np.where(
        a_src == 0, rng.integers(0, n_store, T), rng.integers(0, cap_u, T)
    ).astype(np.int32)
    b_src = rng.integers(0, rounds + 1, T).astype(np.int32)
    b_off = np.where(
        b_src == 0, rng.integers(0, n_store, T), rng.integers(0, cap_u, T)
    ).astype(np.int32)
    num_out = 5
    c_idx = np.sort(rng.integers(0, num_out, T)).astype(np.int32)
    # staged layout: [own store | recv round 0 | recv round 1 | ...]
    a_cat = np.concatenate([a_store, a_recv.reshape(-1, bm, bk)])
    b_cat = np.concatenate([b_store, b_recv.reshape(-1, bk, bn)])
    a_lin = np.where(a_src == 0, a_off, n_store + (a_src - 1) * cap_u + a_off)
    b_lin = np.where(b_src == 0, b_off, n_store + (b_src - 1) * cap_u + b_off)
    return dict(
        a_store=a_store, a_recv=a_recv, b_store=b_store, b_recv=b_recv,
        a_src=a_src, a_off=a_off, b_src=b_src, b_off=b_off, c_idx=c_idx,
        num_out=num_out, a_cat=a_cat, b_cat=b_cat, a_lin=a_lin, b_lin=b_lin,
    )


def _ref(p, **kw):
    return np.asarray(
        fused_block_spmm_ref(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            jnp.asarray(p["a_src"]), jnp.asarray(p["a_off"]),
            jnp.asarray(p["b_src"]), jnp.asarray(p["b_off"]),
            jnp.asarray(p["c_idx"]), num_out=p["num_out"], **kw,
        )
    )


def test_fused_ref_bit_identical_to_staged_fp32():
    p = _problem()
    staged = np.asarray(
        kref.block_spmm_ref(
            p["a_cat"], p["b_cat"],
            jnp.asarray(p["a_lin"], jnp.int32), jnp.asarray(p["b_lin"], jnp.int32),
            jnp.asarray(p["c_idx"]), p["num_out"],
        )
    )
    fused = _ref(p)
    assert (staged == fused).all()


def test_fused_kernel_interpret_matches_ref_full_tile():
    p = _problem(bm=16, bk=16, bn=16)
    got = np.asarray(
        fused_block_spmm_kernel_call(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            jnp.asarray(p["a_src"]), jnp.asarray(p["a_off"]),
            jnp.asarray(p["b_src"]), jnp.asarray(p["b_off"]),
            jnp.asarray(p["c_idx"]), jnp.zeros(p["a_src"].shape, jnp.int32),
            num_out=p["num_out"], interpret=True,
        )
    )
    # full-block tiles: one dot per task, same accumulation order as the ref
    assert (got == _ref(p)).all()


def test_fused_kernel_interpret_tiled():
    p = _problem(bm=16, bk=16, bn=16)
    got = np.asarray(
        fused_block_spmm_kernel_call(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            jnp.asarray(p["a_src"]), jnp.asarray(p["a_off"]),
            jnp.asarray(p["b_src"]), jnp.asarray(p["b_off"]),
            jnp.asarray(p["c_idx"]), jnp.zeros(p["a_src"].shape, jnp.int32),
            num_out=p["num_out"], tm=8, tn=8, tk=8, interpret=True,
        )
    )
    # k-split changes the fp32 summation tree: allclose, not bit-equal
    np.testing.assert_allclose(got, _ref(p), rtol=1e-5, atol=1e-5)


def test_fused_nonpow2_block_sizes():
    # 24 is lane-aligned (divisible by 8) -> interpret kernel path
    p = _problem(bm=24, bk=24, bn=24)
    got = np.asarray(
        fused_block_spmm(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            p["num_out"], interpret=True,
        )
    )
    assert (got == _ref(p)).all()
    # 10 is not lane-aligned -> ops dispatch falls back to the fused ref
    q = _problem(bm=10, bk=10, bn=10)
    got = np.asarray(
        fused_block_spmm(
            q["a_store"], q["a_recv"], q["b_store"], q["b_recv"],
            q["a_src"], q["a_off"], q["b_src"], q["b_off"], q["c_idx"],
            q["num_out"], interpret=True,
        )
    )
    assert (got == _ref(q)).all()


def test_fused_empty_task_list():
    p = _problem(T=0)
    got = np.asarray(
        fused_block_spmm(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            p["num_out"],
        )
    )
    assert got.shape == (p["num_out"], 16, 16)
    assert (got == 0).all()


def test_fused_adaptive_low_mask_bound():
    p = _problem(T=32)
    exact = _ref(p)
    a_n = np.linalg.norm(p["a_cat"].astype(np.float64), axis=(1, 2))
    b_n = np.linalg.norm(p["b_cat"].astype(np.float64), axis=(1, 2))
    budget = 0.5 * float(ROUND2_BOUND * (a_n[p["a_lin"]] * b_n[p["b_lin"]]).sum())
    low, spent = low_precision_task_mask(a_n, b_n, p["a_lin"], p["b_lin"], budget)
    assert 0 < low.sum() < low.shape[0]
    assert spent <= budget
    got = np.asarray(
        fused_block_spmm(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            p["num_out"], low=jnp.asarray(low.astype(np.int32)), adaptive=True,
        )
    )
    err = float(np.linalg.norm((got - exact).ravel()))
    assert err <= spent + 1e-12, (err, spent)
    # all-off mask is exactly fp32
    got0 = np.asarray(
        fused_block_spmm(
            p["a_store"], p["a_recv"], p["b_store"], p["b_recv"],
            p["a_src"], p["a_off"], p["b_src"], p["b_off"], p["c_idx"],
            p["num_out"], low=jnp.zeros(32, jnp.int32), adaptive=True,
        )
    )
    assert (got0 == exact).all()


def test_fused_bf16_storage_bound():
    p = _problem(T=32)
    exact = _ref(p)
    q = dict(p)
    for k in ("a_store", "a_recv", "b_store", "b_recv"):
        q[k] = jnp.asarray(p[k], jnp.bfloat16)
    got = _ref(q)
    a_n = np.linalg.norm(p["a_cat"].astype(np.float64), axis=(1, 2))
    b_n = np.linalg.norm(p["b_cat"].astype(np.float64), axis=(1, 2))
    bound = float(ROUND2_BOUND * (a_n[p["a_lin"]] * b_n[p["b_lin"]]).sum())
    err = float(np.linalg.norm((got - exact).ravel()))
    assert 0 < err <= bound, (err, bound)


def test_low_precision_mask_properties():
    a_n = np.array([1.0, 2.0, 3.0, 4.0])
    b_n = np.array([1.0, 1.0, 1.0, 1.0])
    idx = np.arange(4)
    per = ROUND2_BOUND * a_n
    # budget for the two cheapest tasks only
    m, spent = low_precision_task_mask(a_n, b_n, idx, idx, per[0] + per[1])
    assert m.tolist() == [True, True, False, False]
    assert np.isclose(spent, per[0] + per[1])
    # eligibility excludes a task even if it fits
    m, _ = low_precision_task_mask(
        a_n, b_n, idx, idx, 100.0, eligible=np.array([True, False, True, True])
    )
    assert m.tolist() == [True, False, True, True]
    # zero budget / empty task list select nothing
    m, spent = low_precision_task_mask(a_n, b_n, idx, idx, 0.0)
    assert not m.any() and spent == 0.0
    m, spent = low_precision_task_mask(a_n, b_n, idx[:0], idx[:0], 1.0)
    assert m.shape == (0,) and spent == 0.0


def test_precision_policy():
    assert FP32.key() != BF16.key()
    assert Precision("adaptive", 1e-3).key() != Precision("adaptive", 1e-4).key()
    assert Precision("adaptive", 0.0).budget(1e-5) == 1e-5
    assert Precision("adaptive", 1e-3).budget(1e-5) == 1e-3
    assert not FP32.is_mixed and BF16.is_mixed
    with pytest.raises(AssertionError):
        Precision("fp64")


# --- autotune cache ---------------------------------------------------------


def test_autotune_roundtrip_and_pick(tmp_path):
    path = str(tmp_path / "autotune.json")
    clear_memo()
    key = tile_key("cpu", 32, 32, 32, "float32")
    assert pick_tiles(32, 32, 32, "float32", platform="cpu", path=path) == \
        heuristic_tiles(32, 32, 32)
    save_tile_entry(key, (8, 16, 32), path=path)
    assert pick_tiles(32, 32, 32, "float32", platform="cpu", path=path) == (8, 16, 32)
    # other dtype / shape still miss
    assert pick_tiles(32, 32, 32, "bfloat16", platform="cpu", path=path) == \
        heuristic_tiles(32, 32, 32)
    assert pick_tiles(64, 32, 32, "float32", platform="cpu", path=path) == \
        heuristic_tiles(64, 32, 32)


def test_autotune_corrupt_file_falls_back(tmp_path):
    path = str(tmp_path / "autotune.json")
    with open(path, "w") as fh:
        fh.write("{not json")
    clear_memo()
    assert load_tile_cache(path) == {}
    assert pick_tiles(32, 32, 32, platform="cpu", path=path) == \
        heuristic_tiles(32, 32, 32)
    # wrong schema version also reads as empty
    with open(path, "w") as fh:
        json.dump({"version": 999, "entries": {"x": [1, 1, 1]}}, fh)
    clear_memo()
    assert load_tile_cache(path) == {}


def test_autotune_stale_entry_ignored(tmp_path):
    path = str(tmp_path / "autotune.json")
    clear_memo()
    # 24 does not divide 32: the entry must be ignored, not trusted
    save_tile_entry(tile_key("cpu", 32, 32, 32, "float32"), (24, 24, 24), path=path)
    assert pick_tiles(32, 32, 32, "float32", platform="cpu", path=path) == \
        heuristic_tiles(32, 32, 32)


def test_autotune_tiles_picks_fastest(tmp_path):
    from repro.kernels.autotune import autotune_tiles

    path = str(tmp_path / "autotune.json")
    clear_memo()

    def bench(tm, tn, tk):
        if (tm, tn, tk) == (4, 4, 4):
            raise RuntimeError("tiling rejected")
        return lambda: None

    best, rows = autotune_tiles(
        16, 16, 16, "float32", bench=bench,
        candidates=[(16, 16, 16), (8, 8, 8), (4, 4, 4)],
        reps=1, platform="cpu", path=path,
    )
    assert best in ((16, 16, 16), (8, 8, 8))
    assert any(r["us"] is None for r in rows)  # rejected candidate recorded
    clear_memo()
    assert pick_tiles(16, 16, 16, "float32", platform="cpu", path=path) == best


# --- 8-worker distributed parity (subprocess) -------------------------------

_DIST_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import BSMatrix, multiply
from repro.core.schedule import make_spgemm_plan
from repro.core.distributed import (
    make_worker_mesh, shard_stores, unshard_result, AXIS,
    SpgemmExecutable, MaskedSpgemmExecutable,
    FusedSpgemmExecutable, MaskedFusedSpgemmExecutable,
)
from repro.core.inverse import inv_chol
from repro.dist.cache import PlanCache
from repro.dist.matrix import scatter
from repro.dist.multiply import dist_multiply, dist_spamm
from repro.dist.purify import dist_sqrt_inv_pipeline
from repro.dist.inverse import dist_inv_chol
from repro.kernels.precision import BF16, Precision

assert jax.device_count() == 8, jax.device_count()
out = {}
rng = np.random.default_rng(0)
def banded(n, h, bs):
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i-h), min(n, i+h+1)
        a[i, lo:hi] = rng.standard_normal(hi-lo)
    return BSMatrix.from_dense(a, bs)

# --- executable level: fused vs staged, pruning, masking --------------------
A = banded(256, 20, 16)
mesh = make_worker_mesh(8)
sh = NamedSharding(mesh, P(AXIS))
plan = make_spgemm_plan(A.coords, A.coords, 8, 16, placement="morton", exchange="p2p")
a_store, b_store = shard_stores(plan, A.data, A.data)
a_store = jax.device_put(jnp.asarray(a_store), sh)
b_store = jax.device_put(jnp.asarray(b_store), sh)

c_staged = np.asarray(SpgemmExecutable(plan, mesh, impl="ref")(a_store, b_store))
c_fused = np.asarray(FusedSpgemmExecutable(plan, mesh, impl="fused")(a_store, b_store))
out["fused_eq_staged"] = bool((c_staged == c_fused).all())
C = unshard_result(plan, c_fused, (256, 256), 16)
out["fused_vs_dense_err"] = float(np.abs(C.to_dense() - multiply(A, A).to_dense()).max())

T = plan.tasks.num_tasks
valid = np.arange(plan.t_cap)[None, :] < plan.task_count[:, None]
all_on = np.broadcast_to(valid, (plan.nparts, plan.t_cap))
mf = MaskedFusedSpgemmExecutable(plan, mesh, impl="fused", prune_exchange=True)
mf_off = MaskedFusedSpgemmExecutable(plan, mesh, impl="fused", prune_exchange=False)
out["masked_allon_eq_fused"] = bool(
    (np.asarray(mf(a_store, b_store, all_on)) == c_fused).all())

keep_task = rng.random(T) < 0.4
task_on = keep_task[plan.task_gidx] & valid
c_ms = np.asarray(MaskedSpgemmExecutable(plan, mesh, impl="ref")(a_store, b_store, task_on))
c_mfp = np.asarray(mf(a_store, b_store, task_on))
c_mfn = np.asarray(mf_off(a_store, b_store, task_on))
out["pruned_eq_staged"] = bool((c_ms == c_mfp).all())
out["pruned_eq_unpruned"] = bool((c_mfp == c_mfn).all())
out["pruned_stats"] = dict(mf.last_exchange)

none_on = np.zeros_like(task_on)
out["all_masked_zero"] = bool((np.asarray(mf(a_store, b_store, none_on)) == 0).all())
out["all_masked_stats"] = dict(mf.last_exchange)

# --- driver level: fused default pipeline, adaptive spamm, leaf batching ----
def spd_banded(n, h, bs):
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i-h), min(n, i+h+1)
        a[i, lo:hi] = rng.standard_normal(hi-lo) * 0.1
    a = (a + a.T) / 2 + np.eye(n, dtype=np.float32) * 2.0
    return a

n, bs = 128, 16
s = spd_banded(n, 6, bs); h = spd_banded(n, 6, bs)
S, H = BSMatrix.from_dense(s, bs), BSMatrix.from_dense(h, bs)
d_ref, _ = dist_sqrt_inv_pipeline(S, H, n // 2, mesh, impl="ref", cache=PlanCache())
d_fused, _ = dist_sqrt_inv_pipeline(S, H, n // 2, mesh, cache=PlanCache())
out["pipeline_fused_eq_ref"] = bool(
    (np.asarray(d_ref.to_dense()) == np.asarray(d_fused.to_dense())).all())

d_b, _ = dist_sqrt_inv_pipeline(S, H, n // 2, mesh, precision=BF16, cache=PlanCache())
out["pipeline_bf16_diff"] = float(np.abs(
    np.asarray(d_ref.to_dense()) - np.asarray(d_b.to_dense())).max())

dA = scatter(S, mesh)
c_exact = dist_multiply(dA, dA, PlanCache())
c_ad, bound = dist_spamm(dA, dA, 1e-2, PlanCache(), impl="fused",
                         precision=Precision("adaptive"), method="delta")
err = float(np.linalg.norm(
    np.asarray(c_exact.gather().to_dense()) - np.asarray(c_ad.gather().to_dense())))
out["adaptive_err_le_bound"] = [err, float(bound)]

bd = np.zeros((n, n), dtype=np.float32)
for k in range(0, n, 32):
    bd[k:k+32, k:k+32] = spd_banded(32, 16, bs)
BD = BSMatrix.from_dense(bd, bs)
dbd = scatter(BD, mesh)
zb = np.asarray(dist_inv_chol(dbd, PlanCache(), leaf_blocks=2).gather().to_dense())
zl = np.asarray(dist_inv_chol(dbd, PlanCache(), leaf_blocks=2,
                              batch_leaves=False).gather().to_dense())
zh = np.asarray(inv_chol(BD, leaf_blocks=2).to_dense())
out["leafbatch_eq_loop"] = bool((zb == zl).all())
out["leafbatch_vs_host_maxdiff"] = float(np.abs(zb - zh).max())

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fused_dist_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT ") :])


def test_dist_fused_bit_identical_to_staged(fused_dist_results):
    r = fused_dist_results
    assert r["fused_eq_staged"]
    assert r["fused_vs_dense_err"] < 1e-3


def test_dist_exchange_pruning(fused_dist_results):
    r = fused_dist_results
    assert r["masked_allon_eq_fused"]
    assert r["pruned_eq_staged"]
    assert r["pruned_eq_unpruned"]
    st = r["pruned_stats"]
    assert 0 < st["kept_blocks"] < st["send_blocks"]
    am = r["all_masked_stats"]
    assert r["all_masked_zero"]
    assert am["kept_blocks"] == 0 and am["dropped_rounds"] > 0


def test_dist_pipeline_fused_default_bit_identical(fused_dist_results):
    assert fused_dist_results["pipeline_fused_eq_ref"]


def test_dist_pipeline_bf16_close(fused_dist_results):
    d = fused_dist_results["pipeline_bf16_diff"]
    assert 0 <= d < 0.5, d


def test_dist_adaptive_error_within_bound(fused_dist_results):
    err, bound = fused_dist_results["adaptive_err_le_bound"]
    assert err <= bound + 1e-12, (err, bound)


def test_dist_leaf_batching_bit_identical(fused_dist_results):
    r = fused_dist_results
    assert r["leafbatch_eq_loop"]
    assert r["leafbatch_vs_host_maxdiff"] == 0.0
