"""Runtime health observatory: event log, flight recorder, memory accounting,
health monitors, regression gate, device-transfer lint.

Schema stability is golden-keyed like the shared iteration rows
(``SHARED_ITER_KEYS``): ``EVENT_KEYS`` pins the event-log envelope and
``POSTMORTEM_KEYS`` the flight-recorder dump.  The SPMD half (observability
off/on bit-identity, Lanczos fallback, refine-divergence postmortem) runs in
a subprocess with 4 fake CPU devices, same harness as test_obs.py.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from helpers import random_block_matrix

from repro.analysis import PlanError
from repro.analysis.lint import lint_paths
from repro.analysis.mutate import CORRUPTIONS
from repro.core.cache import SymbolicCache
from repro.core.inverse import RefineMonitor
from repro.core.purify import Sp2Monitor
from repro.core.schedule import make_spgemm_plan
from repro.obs import Tracer
from repro.obs.log import (
    EVENT_KEYS,
    NULL_LOG,
    POSTMORTEM_KEYS,
    EventLog,
    FlightRecorder,
    load_events,
    log_of,
)
from repro.obs.memory import MemoryMeter, plan_memory_bytes
from repro.obs.regress import (
    ENTRY_KEYS,
    append_history,
    check_history,
    load_history,
)
from repro.obs.regress import main as regress_main

BS = 16


def _plan(exchange="p2p"):
    m = random_block_matrix(256, BS, 0.25, seed=3)
    return make_spgemm_plan(m.coords, m.coords, 4, BS, exchange=exchange)


# ---------------------------------------------------------------------------
# event log: golden envelope, level filter, JSONL round-trip, ring buffer
# ---------------------------------------------------------------------------


def test_event_record_golden_keys():
    lg = EventLog()
    rec = lg.info("run_start", driver="sp2", n=64)
    # the envelope keys come first, in pinned order; payload follows
    assert tuple(rec)[: len(EVENT_KEYS)] == EVENT_KEYS
    assert EVENT_KEYS == ("ts", "seq", "level", "event")
    assert rec["event"] == "run_start" and rec["level"] == "info"
    assert rec["driver"] == "sp2" and rec["n"] == 64


def test_level_filter_and_sequencing():
    lg = EventLog(level="warn")
    assert lg.info("quiet") is None and lg.debug("quiet") is None
    a, b = lg.warn("first"), lg.error("second")
    assert [r["event"] for r in lg.recent] == ["first", "second"]
    assert b["seq"] == a["seq"] + 1


def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    lg = EventLog(path, level="debug")
    lg.debug("plan_build", kind="spgemm", build_s=0.25)
    lg.warn("health_alert", kind="straggler", worker=2)
    lg.close()
    back = load_events(path)
    assert [r["event"] for r in back] == ["plan_build", "health_alert"]
    assert back[0]["kind"] == "spgemm" and back[1]["worker"] == 2
    assert all(tuple(r)[: len(EVENT_KEYS)] == EVENT_KEYS for r in back)


def test_ring_buffer_capacity():
    lg = EventLog(capacity=4)
    for i in range(10):
        lg.info("tick", i=i)
    assert [r["i"] for r in lg.recent] == [6, 7, 8, 9]


def test_null_log_is_inert():
    assert not NULL_LOG and not NULL_LOG.enabled
    assert NULL_LOG.info("anything", x=1) is None
    assert NULL_LOG.events_of("anything") == []
    assert log_of(None) is NULL_LOG
    cache = SymbolicCache()
    assert log_of(cache) is NULL_LOG  # default off
    lg = EventLog()
    cache.event_log = lg
    assert log_of(cache) is lg
    cache.event_log = None
    assert log_of(cache) is NULL_LOG


def test_events_filter_by_name_and_level():
    lg = EventLog(level="debug")
    lg.debug("iteration", i=0)
    lg.warn("health_alert", kind="stall")
    lg.debug("iteration", i=1)
    assert [r["i"] for r in lg.events_of("iteration")] == [0, 1]
    assert len(lg.events_of("health_alert", level="warn")) == 1
    assert lg.events_of("iteration", level="warn") == []


# ---------------------------------------------------------------------------
# flight recorder: golden postmortem schema, counter deltas, PlanError hook
# ---------------------------------------------------------------------------


def test_postmortem_golden_keys(tmp_path):
    tr = Tracer(sync=False)
    cache = SymbolicCache(tracer=tr, event_log=EventLog())
    rec = FlightRecorder(str(tmp_path / "pm.json")).install(cache)
    assert cache.flight_recorder is rec
    with tr.span("step", cat="phase"):
        tr.counter("tasks_executed").add(7.0)
    pm = rec.snapshot("unit_test", cache, extra="detail")
    assert tuple(pm) == POSTMORTEM_KEYS
    assert pm["reason"] == "unit_test" and pm["detail"]["extra"] == "detail"
    assert [sp["name"] for sp in pm["spans"]] == ["step"]


def test_postmortem_counter_deltas_vs_mark(tmp_path):
    tr = Tracer(sync=False)
    cache = SymbolicCache(tracer=tr)
    rec = FlightRecorder(str(tmp_path / "pm.json")).install(cache)
    tr.counter("tasks_executed").add(10.0)
    rec.mark(cache)
    tr.counter("tasks_executed").add(3.0)
    pm = rec.snapshot("delta_test", cache)
    assert pm["counters"]["tasks_executed"] == pytest.approx(13.0)
    assert pm["counter_deltas"]["tasks_executed"] == pytest.approx(3.0)


def test_plan_error_dumps_postmortem(tmp_path):
    """An injected plan corruption rejected at admission leaves a complete
    postmortem behind — the debugging workflow the flight recorder exists
    for."""
    plan = _plan()
    bad, _ = CORRUPTIONS["send_conflict"][0](plan)
    tr = Tracer(sync=False)
    lg = EventLog(level="debug")
    cache = SymbolicCache(tracer=tr, event_log=lg)
    pm_path = str(tmp_path / "postmortem.json")
    rec = FlightRecorder(pm_path).install(cache)
    with pytest.raises(PlanError):
        cache.get_or_build(("spgemm", "k1"), lambda: (bad, None))
    assert rec.dumps == 1 and os.path.exists(pm_path)
    with open(pm_path) as fh:
        pm = json.load(fh)
    assert tuple(pm) == POSTMORTEM_KEYS
    assert pm["reason"] == "plan_error"
    assert pm["detail"]["violations"]
    assert pm["detail"]["violations"][0]["check"] == "send-conflict"
    assert pm["cache"]["entries"] == 0  # the bad plan was never admitted
    # the error also landed in the event log and the tracer's instants
    assert lg.events_of("plan_error", level="error")
    assert tr.instants_of("postmortem", "health")


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("exchange", ["p2p", "allgather"])
def test_plan_memory_bytes_math(exchange):
    plan = _plan(exchange)
    mem = plan_memory_bytes(plan)
    blk = BS * BS * 4
    assert mem["own_bytes"] == (plan.a_cap + plan.b_cap) * blk
    assert mem["out_bytes"] == plan.c_cap * blk
    if exchange == "allgather":
        expected = (plan.nparts - 1) * (plan.a_cap + plan.b_cap) * blk
    else:
        expected = sum(
            send[d].shape[1] * blk
            for offs, send in ((plan.a_offsets, plan.a_send),
                               (plan.b_offsets, plan.b_send))
            for d in offs
        )
    assert mem["recv_buffer_bytes"] == expected
    assert mem["total_bytes"] == pytest.approx(
        mem["own_bytes"] + mem["recv_buffer_bytes"] + mem["out_bytes"]
        + mem["index_bytes"]
    )
    assert np.allclose(mem["per_worker"], mem["total_bytes"])
    # memoized on the plan: the second call is the same dict
    assert plan_memory_bytes(plan) is mem


def test_plan_memory_bf16_wire():
    plan = _plan()

    class Bf16:
        mode = "bf16"

    full = plan_memory_bytes(plan)
    half = plan_memory_bytes(plan, Bf16())
    assert half["recv_buffer_bytes"] == pytest.approx(
        full["recv_buffer_bytes"] / 2)
    assert half["own_bytes"] == full["own_bytes"]  # stores stay fp32


def test_memory_meter_peaks_and_flush():
    mm = MemoryMeter()
    mm.note_bytes("norm_table", np.array([100.0, 300.0, 200.0, 100.0]))
    mm.note_bytes("norm_table", np.array([50.0, 50.0, 50.0, 50.0]))
    # the peak watermark keeps the high tide, not the last note
    assert np.array_equal(mm.peak["norm_table"], [100.0, 300.0, 200.0, 100.0])
    mm.note_bytes("recv", np.full(4, 10.0))
    assert np.array_equal(mm.worker_peak(), [110.0, 310.0, 210.0, 110.0])
    tr = Tracer(sync=False)
    mm.flush(tr)
    assert tr.gauge("mem_peak_w1_bytes").value == pytest.approx(310.0)
    summary = mm.summary()
    assert summary["nparts"] == 4
    assert summary["peak_bytes_max"] == pytest.approx(310.0)
    assert set(summary["per_kind"]) == {"norm_table", "recv"}


# ---------------------------------------------------------------------------
# health monitor detectors (synthetic rows/loads, no mesh needed)
# ---------------------------------------------------------------------------


def _row(it, misses=0, recv=1000.0, residual=None):
    row = dict(iteration=it, cache_misses=misses, recv_bytes_mean=recv)
    if residual is not None:
        row["residual"] = residual
    return row


def _load(tasks):
    from repro.dist.balance import WorkerLoad

    tasks = np.asarray(tasks, dtype=np.float64)
    z = np.zeros_like(tasks)
    return WorkerLoad(nparts=tasks.shape[0], bs=BS, tasks=tasks,
                      recv_bytes=z, send_bytes=z, blocks=z)


def test_straggler_detector_needs_patience():
    from repro.obs.health import HealthMonitor, HealthPolicy

    hm = HealthMonitor(HealthPolicy(straggler_factor=1.5,
                                    straggler_patience=3))
    slow = _load([100.0, 100.0, 100.0, 400.0])
    assert hm.observe(_row(0), slow) == []  # streak 1
    assert hm.observe(_row(1), slow) == []  # streak 2
    alerts = hm.observe(_row(2), slow)      # streak 3: trips
    assert [a.kind for a in alerts] == ["straggler"]
    assert alerts[0].data["worker"] == 3
    # re-armed after the alert: no immediate repeat
    assert hm.observe(_row(3), slow) == []
    # a one-iteration blip never trips
    hm2 = HealthMonitor(HealthPolicy())
    assert hm2.observe(_row(0), slow) == []
    assert hm2.observe(_row(1), _load([100.0] * 4)) == []
    assert hm2.observe(_row(2), slow) == []


def test_miss_storm_detector_past_warmup():
    from repro.obs.health import HealthMonitor, HealthPolicy

    hm = HealthMonitor(HealthPolicy(miss_warmup=2, miss_storm_window=3))
    alerts = []
    for it in range(8):
        alerts += hm.observe(_row(it, misses=2))
    assert [a.kind for a in alerts] == ["miss_storm"]
    # warmup misses alone never trip
    hm2 = HealthMonitor(HealthPolicy(miss_warmup=4, miss_storm_window=3))
    for it in range(4):
        assert hm2.observe(_row(it, misses=5)) == []


def test_exchange_blowup_detector():
    from repro.obs.health import HealthMonitor, HealthPolicy

    hm = HealthMonitor(HealthPolicy(exchange_blowup=4.0))
    for it in range(4):
        assert hm.observe(_row(it, recv=1000.0)) == []
    alerts = hm.observe(_row(4, recv=8000.0))
    assert [a.kind for a in alerts] == ["exchange_blowup"]
    assert alerts[0].data["recv_bytes_mean"] == pytest.approx(8000.0)


def test_convergence_stall_detector():
    from repro.obs.health import HealthMonitor, HealthPolicy

    hm = HealthMonitor(HealthPolicy(stall_window=3))
    assert hm.observe(_row(0, residual=1.0)) == []
    alerts = []
    for it in range(1, 6):
        alerts += hm.observe(_row(it, residual=1.0))  # flat forever
    assert [a.kind for a in alerts] == ["convergence_stall"]
    # improvement resets the stall counter
    hm2 = HealthMonitor(HealthPolicy(stall_window=3))
    r = 1.0
    for it in range(8):
        r *= 0.5
        assert hm2.observe(_row(it, residual=r)) == []


def test_alerts_land_in_log_and_trace():
    from repro.obs.health import HealthMonitor, HealthPolicy

    tr = Tracer(sync=False)
    cache = SymbolicCache(tracer=tr, event_log=EventLog())
    hm = HealthMonitor(HealthPolicy(stall_window=2), cache=cache)
    for it in range(5):
        hm.observe(_row(it, residual=1.0))
    assert hm.alerts
    assert cache.event_log.events_of("health_alert", level="warn")
    assert tr.instants_of("health_alert", "health")
    summary = hm.summary()
    assert summary["alerts_by_kind"] == {"convergence_stall": 1}


def test_maybe_refit_applies_fitted_policy():
    from repro.dist.balance import RebalancePolicy
    from repro.obs.health import HealthMonitor, HealthPolicy

    fitted = RebalancePolicy(recv_cost=0.9, send_cost=0.1, block_cost=0.4)

    class FakeLB:
        policy = RebalancePolicy()

        def calibration(self):
            return fitted, dict(fitted=True, rms_resid_s=0.01)

    lb = FakeLB()
    hm = HealthMonitor(HealthPolicy(refit_every=4))
    for it in range(3):
        hm.observe(_row(it))
        assert hm.maybe_refit(lb) is None
    hm.observe(_row(3))
    assert hm.maybe_refit(lb) == fitted  # iteration 4: refit applied live
    assert lb.policy == fitted and hm.refits == 1
    # same fit again: no-op, not another refit
    for it in range(4, 8):
        hm.observe(_row(it))
    assert hm.maybe_refit(lb) is None and hm.refits == 1

    class NotFitted:
        policy = RebalancePolicy()

        def calibration(self):
            return fitted, dict(fitted=False)

    hm2 = HealthMonitor(HealthPolicy(refit_every=1))
    hm2.observe(_row(0))
    nf = NotFitted()
    assert hm2.maybe_refit(nf) is None and nf.policy == RebalancePolicy()
    # live_policy=False is a hard off switch
    hm3 = HealthMonitor(HealthPolicy(refit_every=1, live_policy=False))
    hm3.observe(_row(0))
    assert hm3.maybe_refit(lb) is None


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------


def _entry(bench="trace", config="smoke", commit="abc1234", **metrics):
    return dict(ts=1e9, commit=commit, bench=bench, config=config,
                metrics=metrics, meta={})


def test_history_round_trip_and_envelope(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    assert load_history(path) == []  # missing file is an empty history
    append_history(path, _entry(overhead_pct=1.0))
    append_history(path, _entry(overhead_pct=1.2))
    back = load_history(path)
    assert len(back) == 2 and set(ENTRY_KEYS) <= back[0].keys()
    with pytest.raises(ValueError):
        append_history(path, dict(ts=1.0, commit="x"))  # missing keys
    with pytest.raises(ValueError):
        append_history(path, _entry(bit_identical=True))  # bool metric


def test_check_history_pass_and_fail():
    base = [_entry(overhead_pct=1.0), _entry(overhead_pct=1.1)]
    assert check_history(base) == []  # within abs_tol=2.0
    # seeded regression: overhead jumps past baseline + 2% absolute slack
    bad = base + [_entry(overhead_pct=4.0, commit="bad9999")]
    violations = check_history(bad)
    assert len(violations) == 1
    v = violations[0]
    assert (v["bench"], v["metric"], v["commit"]) == (
        "trace", "overhead_pct", "bad9999")
    # higher-is-better direction: a dropped bit_identical gate fails exactly
    flip = [_entry(bit_identical=1.0), _entry(bit_identical=1.0),
            _entry(bit_identical=0.0)]
    assert [v["metric"] for v in check_history(flip)] == ["bit_identical"]
    # single-entry groups are their own baseline
    assert check_history([_entry(overhead_pct=99.0)]) == []
    # baseline is the median of priors: one noisy run doesn't poison it
    noisy = [_entry(overhead_pct=1.0), _entry(overhead_pct=50.0),
             _entry(overhead_pct=1.0), _entry(overhead_pct=1.2)]
    assert check_history(noisy) == []


def test_regress_cli_exit_codes(tmp_path, capsys):
    path = str(tmp_path / "hist.jsonl")
    append_history(path, _entry(overhead_pct=1.0, bit_identical=1.0))
    append_history(path, _entry(overhead_pct=1.1, bit_identical=1.0))
    assert regress_main(["--history", path, "--check"]) == 0
    assert "clean" in capsys.readouterr().out
    append_history(path, _entry(overhead_pct=9.9, commit="bad9999",
                                bit_identical=1.0))
    assert regress_main(["--history", path, "--check"]) == 1
    out = capsys.readouterr().out
    assert "overhead_pct" in out and "bad9999" in out
    assert regress_main(["--history", path, "--list"]) == 0


def test_history_extractor_from_bench_files(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from history import entries_from_bench_json
    finally:
        sys.path.pop(0)
    trace = dict(
        meta=dict(smoke=True, n=128, workers=8, observatory=True),
        overhead=dict(overhead_pct=0.5, overhead_sync_pct=2.0,
                      min_untraced_s=1.0, min_traced_s=1.005,
                      bit_identical=True),
    )
    path = str(tmp_path / "BENCH_trace.json")
    with open(path, "w") as fh:
        json.dump(trace, fh)
    entries = entries_from_bench_json(path, ts=1e9, commit="abc1234")
    assert len(entries) == 1
    e = entries[0]
    assert (e["bench"], e["config"]) == ("trace", "smoke")
    assert e["metrics"]["bit_identical"] == 1.0  # bool became 0/1
    assert e["meta"]["observatory"] is True
    # the extracted entry passes the envelope validation on append
    hist = str(tmp_path / "hist.jsonl")
    append_history(hist, e)
    assert check_history(load_history(hist)) == []
    with open(str(tmp_path / "junk.json"), "w") as fh:
        json.dump(dict(nonsense=1), fh)
    with pytest.raises(ValueError):
        entries_from_bench_json(str(tmp_path / "junk.json"))


# ---------------------------------------------------------------------------
# monitors expose why they stopped
# ---------------------------------------------------------------------------


def test_monitor_stop_reasons():
    m = RefineMonitor(1e-8)
    assert not m.update(0, 1.0) and m.stop_reason is None
    assert m.update(1, 1e-9) and m.stop_reason == "converged"
    d = RefineMonitor(1e-12)
    d.update(0, 1.0)
    assert d.update(1, 5.0) and d.stop_reason == "diverged"
    s = RefineMonitor(1e-12, max_stall=2)
    s.update(0, 1.0)
    assert not s.update(1, 1.5) and s.stop_reason is None
    assert s.update(2, 1.5) and s.stop_reason == "stalled"
    p = Sp2Monitor(1e-8)
    assert not p.update(0, 1.0) and p.stop_reason is None
    assert p.update(1, 1e-9) and p.stop_reason == "converged"
    pd = Sp2Monitor(1e-12)
    pd.update(0, 1.0)
    assert pd.update(1, 5.0) and pd.stop_reason == "diverged"


# ---------------------------------------------------------------------------
# device-transfer lint rule
# ---------------------------------------------------------------------------


def test_device_transfer_lint_fires(tmp_path):
    offender = tmp_path / "offender.py"
    offender.write_text(
        "import jax\n"
        "def dist_bad_collective(x, sh):\n"
        "    y = jax.device_put(x, sh)\n"
        "    return jax.device_get(y)\n"
        "def innocent_helper(x, sh):\n"
        "    return jax.device_put(x, sh)\n"
    )
    findings, _ = lint_paths([offender], baseline=set())
    hits = [f for f in findings if f.rule == "device-transfer"]
    assert len(hits) == 2  # put + get inside dist_*; the helper is clean
    assert all("dist_bad_collective" in f.message for f in hits)
    assert {f.line for f in hits} == {3, 4}
    # the waiver key works like every other rule's
    waived_findings, waived = lint_paths(
        [offender], baseline={"offender.py::device-transfer"})
    assert [f for f in waived_findings if f.rule == "device-transfer"] == []
    assert len([f for f in waived if f.rule == "device-transfer"]) == 2


def test_repo_is_device_transfer_clean():
    findings, _ = lint_paths()
    assert [str(f) for f in findings if f.rule == "device-transfer"] == []


# ---------------------------------------------------------------------------
# SPMD half: bit-identity with observability on, Lanczos fallback,
# refine-divergence postmortem (subprocess, 4 fake devices)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import json, os, tempfile
import numpy as np, jax
from repro.core import BSMatrix
from repro.core.distributed import make_worker_mesh
from repro.dist import (PlanCache, RebalancePolicy, dist_sp2_purify,
                        dist_localized_inverse_factorization, scatter)
import repro.dist.purify as pur
import repro.dist.inverse as inv
from repro.obs import (EventLog, FlightRecorder, HealthPolicy, MemoryMeter,
                       POSTMORTEM_KEYS, Tracer)

assert jax.device_count() == 4, jax.device_count()
mesh = make_worker_mesh(4)
tmp = tempfile.mkdtemp()
out = {}

rng = np.random.default_rng(0)
n, bs = 64, 8
b = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - 5), min(n, i + 6)
    b[i, lo:hi] = rng.standard_normal(hi - lo)
S = BSMatrix.from_dense(b @ b.T / n + np.eye(n, dtype=np.float32), bs)
hm = 0.2 * rng.standard_normal((n, n)).astype(np.float32)
F = BSMatrix.from_dense(
    (hm + hm.T) / 2 + np.diag(np.linspace(-1, 1, n)).astype(np.float32), bs)
w = np.linalg.eigvalsh(np.asarray(F.to_dense(), np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
nocc = 20
kw = dict(idem_tol=1e-5, trunc_tau=1e-6, spamm_tau=1e-7, max_iter=40)

# -- full observatory on vs everything off: bit-identical results ------------
skew = np.zeros(F.nnzb, dtype=np.int32)
dFs = scatter(F, mesh, owner=skew)
d0, st0 = dist_sp2_purify(dFs, nocc, lmin, lmax, cache=PlanCache(),
                          rebalance=RebalancePolicy(), **kw)
cache = PlanCache(tracer=Tracer(sync=False),
                  event_log=EventLog(os.path.join(tmp, "ev.jsonl"),
                                     level="debug"))
mm = MemoryMeter().install(cache)
rec = FlightRecorder(os.path.join(tmp, "pm.json")).install(cache)
d1, st1 = dist_sp2_purify(dFs, nocc, lmin, lmax, cache=cache,
                          rebalance=RebalancePolicy(),
                          health=HealthPolicy(), **kw)
out["obs_bit_identical"] = bool(np.array_equal(
    np.asarray(d0.to_dense()), np.asarray(d1.to_dense())))
out["health_summary_present"] = st1.health is not None
out["health_off_is_none"] = st0.health is None
evs = [r["event"] for r in cache.event_log.recent]
out["driver_events"] = sorted({e for e in evs
                               if e in ("run_start", "run_end", "iteration",
                                        "plan_build", "rebalance")})
out["memory_accounted"] = bool(mm.notes > 0
                               and float(mm.worker_peak().max()) > 0)
out["no_spurious_postmortem"] = rec.dumps == 0
cache.event_log.close()

# -- Lanczos divergence falls back to block Gershgorin -----------------------
dS = scatter(S, mesh)
cache2 = PlanCache(event_log=EventLog(level="debug"))
lo_ref, hi_ref = pur._spectral_bounds_from_norms(
    dS.coords, pur.resident_block_norms(dS, cache2))
real_ritz = pur._lanczos_ritz
def broken_ritz(f, cache, steps, seed):
    raise pur.LanczosDivergence("injected non-finite beta")
pur._lanczos_ritz = broken_ritz
lo, hi = pur.dist_lanczos_bounds(dS, cache2, steps=8)
pur._lanczos_ritz = real_ritz
out["lanczos_fallback_matches_gershgorin"] = bool(
    abs(lo - lo_ref) < 1e-12 and abs(hi - hi_ref) < 1e-12)
fb = cache2.event_log.events_of("lanczos_fallback", level="warn")
out["lanczos_fallback_logged"] = bool(
    fb and "injected" in fb[0]["reason"])
lo2, hi2 = pur.dist_lanczos_bounds(dS, cache2, steps=8)
out["lanczos_healthy_sane"] = bool(
    np.isfinite(lo2) and np.isfinite(hi2) and lo2 < hi2)

# -- refine divergence trips the flight recorder -----------------------------
class DivergeNow(inv.RefineMonitor):
    def update(self, it, r):
        super().update(it, r)
        if it >= 1:
            self.stop_reason = "diverged"
            return True
        return False
real_mon = inv.RefineMonitor
inv.RefineMonitor = DivergeNow
cache3 = PlanCache(tracer=Tracer(sync=False),
                   event_log=EventLog(level="debug"))
pm_path = os.path.join(tmp, "pm_refine.json")
rec3 = FlightRecorder(pm_path, last_spans=32).install(cache3)
z, ist = dist_localized_inverse_factorization(
    dS, cache3, tol=1e-9, max_iter=10, trunc_tau=1e-6, spamm_tau=1e-7)
inv.RefineMonitor = real_mon
out["refine_dump_count"] = rec3.dumps
with open(pm_path) as fh:
    pm = json.load(fh)
out["refine_pm_keys_golden"] = list(pm) == list(POSTMORTEM_KEYS)
out["refine_pm_reason"] = pm["reason"]
out["refine_pm_iteration"] = pm["detail"].get("iteration")
out["refine_pm_has_spans"] = bool(pm["spans"])
out["refine_pm_cache_state"] = bool(pm["cache"].get("hits", 0) > 0
                                    or pm["cache"].get("misses", 0) > 0)
out["refine_warned"] = bool(
    cache3.event_log.events_of("refine_divergence", level="warn"))

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def spmd_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_observatory_on_is_bit_identical(spmd_results):
    assert spmd_results["obs_bit_identical"]
    assert spmd_results["health_summary_present"]
    assert spmd_results["health_off_is_none"]
    assert spmd_results["no_spurious_postmortem"]


def test_driver_threads_event_log(spmd_results):
    assert set(spmd_results["driver_events"]) >= {
        "run_start", "run_end", "iteration", "plan_build"}


def test_memory_meter_rides_the_drivers(spmd_results):
    assert spmd_results["memory_accounted"]


def test_lanczos_divergence_falls_back_to_gershgorin(spmd_results):
    assert spmd_results["lanczos_fallback_matches_gershgorin"]
    assert spmd_results["lanczos_fallback_logged"]
    assert spmd_results["lanczos_healthy_sane"]


def test_refine_divergence_dumps_postmortem(spmd_results):
    assert spmd_results["refine_dump_count"] == 1
    assert spmd_results["refine_pm_keys_golden"]
    assert spmd_results["refine_pm_reason"] == "refine_divergence"
    assert spmd_results["refine_pm_iteration"] == 1
    assert spmd_results["refine_pm_has_spans"]
    assert spmd_results["refine_pm_cache_state"]
    assert spmd_results["refine_warned"]
