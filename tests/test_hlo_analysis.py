"""HLO collective-parser unit tests (synthetic HLO text)."""

from repro.launch.hlo_analysis import analyze_collectives, op_census

_HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,4096,8192]{2,1,0} parameter(0)
  %p1 = f32[8192,1848]{1,0} parameter(1)
  %ar = bf16[16,4096,8192]{2,1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[8192,29568]{1,0} all-gather(%p1), dimensions={1}
  %rs = bf16[16,4096,512]{2,1,0} reduce-scatter(%ar), dimensions={2}
  %cp = bf16[16,4096,8192]{2,1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  ROOT %out = bf16[16,4096,512]{2,1,0} copy(%rs)
}
"""


def test_collective_totals():
    r = analyze_collectives(_HLO)
    bf16 = 16 * 4096 * 8192 * 2
    f32_in = 8192 * 1848 * 4
    f32_out = 8192 * 29568 * 4
    k = r["by_kind"]
    # all-reduce: operand bytes; wire 2x
    assert k["all-reduce"]["operand_bytes"] == bf16
    assert k["all-reduce"]["wire_bytes"] == 2 * bf16
    # all-gather: wire = result bytes (receives everyone's shard)
    assert k["all-gather"]["operand_bytes"] == f32_in
    assert k["all-gather"]["wire_bytes"] == f32_out
    # reduce-scatter / permute: operand bytes
    assert k["reduce-scatter"]["wire_bytes"] == bf16
    assert k["collective-permute"]["wire_bytes"] == bf16
    assert r["operand_bytes"] == bf16 * 3 + f32_in


def test_op_census():
    census = dict(op_census(_HLO))
    assert census["all-reduce"] == 1
    assert census["parameter"] == 2
