import numpy as np
import pytest

from repro.core import (
    BSMatrix,
    SymbolicCache,
    factorization_residual,
    inv_chol,
    localized_inverse_factorization,
    sp2_purify,
    submatrix,
)

from helpers import spd_banded


def test_submatrix():
    m = spd_banded(64, 4, 8)
    s = submatrix(m, 2, 6, 1, 5)
    assert np.allclose(s.to_dense(), m.to_dense()[16:48, 8:40])


def test_inv_chol_identity_residual():
    a = spd_banded(64, 5, 8)
    z = inv_chol(a)
    assert factorization_residual(a, z) < 1e-4
    # Z upper triangular at the block level
    assert np.all(z.coords[:, 0] <= z.coords[:, 1])


def test_inv_chol_non_power_of_two_blocks():
    a = spd_banded(56, 5, 8)  # 7 block rows
    z = inv_chol(a)
    assert factorization_residual(a, z) < 1e-4


def test_localized_inverse_factorization():
    a = spd_banded(64, 3, 8)
    z, stats = localized_inverse_factorization(a, tol=1e-5, max_iter=60)
    hist = stats.residual_history
    assert hist[-1] < 1e-4
    assert hist[0] > hist[-1]  # refinement reduced the residual
    assert stats.factorization_residual <= hist[-1] + 1e-12


def test_localized_inverse_factorization_symbolic_cache():
    # the refinement loop threads its multiplies through a SymbolicCache;
    # once the iterate's sparsity pattern stabilizes, iterations are all hits
    a = spd_banded(64, 3, 8)
    cache = SymbolicCache()
    z, stats = localized_inverse_factorization(
        a, tol=1e-5, max_iter=60, cache=cache
    )
    assert stats.residual_history[-1] < 1e-4
    assert stats.symbolic_cache["hits"] > 0
    # the converged iteration's sparsity pattern has been seen -> all hits
    assert stats.cache_misses_history[-1] == 0
    assert stats.cache_hits_history[-1] > 0
    # SCF-style repeated solve on the same structure: zero symbolic work
    m0 = cache.misses
    z2, stats2 = localized_inverse_factorization(
        a, tol=1e-5, max_iter=60, cache=cache
    )
    assert cache.misses == m0
    assert all(m == 0 for m in stats2.cache_misses_history)
    assert np.array_equal(z2.coords, z.coords)


def test_inv_chol_symbolic_cache_and_parity():
    a = spd_banded(64, 5, 8)
    cache = SymbolicCache()
    z_cached = inv_chol(a, cache=cache)
    z_plain = inv_chol(a)
    assert np.array_equal(z_cached.coords, z_plain.coords)
    assert np.allclose(
        np.asarray(z_cached.data), np.asarray(z_plain.data), atol=1e-6
    )
    # repeated factorization on the same structure reuses every symbolic phase
    h0, m0 = cache.hits, cache.misses
    inv_chol(a, cache=cache)
    assert cache.misses == m0 and cache.hits > h0
    assert factorization_residual(a, z_cached, cache=cache) < 1e-4


def test_purification_matches_dense_eig():
    rng = np.random.default_rng(1)
    n, nocc = 48, 17
    h = rng.standard_normal((n, n)).astype(np.float32)
    h = (h + h.T) / 2
    f = BSMatrix.from_dense(h, 8)
    w = np.linalg.eigvalsh(h)
    d, stats = sp2_purify(f, nocc, float(w.min()) - 0.1, float(w.max()) + 0.1, idem_tol=1e-6)
    ev = np.linalg.eigh(h)
    dref = ev.eigenvectors[:, :nocc] @ ev.eigenvectors[:, :nocc].T
    assert np.abs(d.to_dense() - dref).max() < 1e-3
    assert abs(d.trace() - nocc) < 1e-2


def test_purification_truncation_keeps_sparsity():
    # banded hamiltonian with a gap -> density matrix has decay; truncation
    # keeps the iterates block-sparse (the paper's electronic-structure use)
    rng = np.random.default_rng(0)
    n = 128
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - 2), min(n, i + 3)
        a[i, lo:hi] = rng.standard_normal(hi - lo) * 0.1
    h = (a + a.T) / 2 + np.diag(np.linspace(-1, 1, n))
    f = BSMatrix.from_dense(h, 16)
    w = np.linalg.eigvalsh(h)
    d, stats = sp2_purify(
        f, 40, float(w.min()) - 0.05, float(w.max()) + 0.05, idem_tol=1e-5, trunc_tau=1e-4
    )
    nb = f.nblocks[0]
    assert d.nnzb < nb * nb  # stayed sparse
    ev = np.linalg.eigh(h)
    dref = ev.eigenvectors[:, :40] @ ev.eigenvectors[:, :40].T
    assert np.abs(d.to_dense() - dref).max() < 5e-3
